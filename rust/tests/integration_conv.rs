//! Integration tests over the full conv1d layer API: cross-backend
//! agreement at the paper's exact parameter corners (Sec. 4.3 sweep sets),
//! bf16 vs f32, layer-object semantics, and the FLOP bookkeeping used by
//! the efficiency harness.

use dilconv1d::conv1d::bf16::{to_bf16, to_f32};
use dilconv1d::conv1d::test_util::rnd;
use dilconv1d::conv1d::{Backend, Conv1dLayer, ConvParams};

fn close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= tol * (1.0 + y.abs()),
            "{what} idx {i}: {x} vs {y}"
        );
    }
}

/// The paper's Sec. 4.3 sweep corners, scaled widths.
fn paper_corners() -> Vec<(usize, usize, usize, usize, usize, usize)> {
    // (n, c, k, q, s, d)
    vec![
        (2, 15, 15, 1_000, 51, 8), // AtacWorks FP32 layer
        (2, 16, 16, 1_000, 51, 8), // AtacWorks BF16 layer
        (1, 64, 64, 2_000, 5, 1),  // Fig. 5 corner
        (1, 32, 32, 2_000, 9, 4),  // Fig. 6 corner
        (1, 1, 1, 1_000, 1, 1),    // minimum sweep values
        (2, 4, 10, 1_000, 15, 2),
        (1, 8, 64, 1_000, 25, 16), // max dilation in the sweep set
        (1, 10, 8, 2_000, 49, 2),
        (3, 15, 15, 977, 31, 4),   // Q not a multiple of the 64 block
    ]
}

#[test]
fn all_backends_agree_on_paper_corners() {
    for (n, c, k, q, s, d) in paper_corners() {
        let w = q + (s - 1) * d;
        let weights = rnd(k * c * s, 1);
        let x = rnd(n * c * w, 2);
        let mut layer = Conv1dLayer::new(c, k, s, d, weights);
        layer.backend = Backend::Brgemm;
        let ours = layer.forward(&x, n, w);
        layer.backend = Backend::Im2col;
        let lib = layer.forward(&x, n, w);
        layer.backend = Backend::Direct;
        let naive = layer.forward(&x, n, w);
        close(&ours, &naive, 1e-3, "brgemm/direct");
        close(&lib, &naive, 1e-3, "im2col/direct");
    }
}

#[test]
fn backward_passes_agree_on_paper_corners() {
    for (n, c, k, q, s, d) in paper_corners().into_iter().take(5) {
        let w = q + (s - 1) * d;
        let weights = rnd(k * c * s, 3);
        let x = rnd(n * c * w, 4);
        let gout = rnd(n * k * q, 5);
        let mut layer = Conv1dLayer::new(c, k, s, d, weights);
        layer.backend = Backend::Brgemm;
        let gd_ours = layer.backward_data(&gout, n, w);
        let gw_ours = layer.backward_weight(&gout, &x, n, w);
        layer.backend = Backend::Direct;
        let gd_naive = layer.backward_data(&gout, n, w);
        close(&gd_ours, &gd_naive, 1e-3, "bwd-data");
        // Direct bwd-weight oracle.
        let p = ConvParams::new(n, c, k, w, s, d).unwrap();
        let gw_naive = dilconv1d::conv1d::direct::backward_weight_direct(&p, &gout, &x);
        close(&gw_ours, &gw_naive, 5e-3, "bwd-weight");
    }
}

#[test]
fn bf16_forward_tracks_f32_within_precision() {
    // Paper Sec. 4.3: the bf16 path requires even C/K/W.
    let (n, c, k, q, s, d) = (2, 16, 16, 1_024, 5, 2);
    let w = q + (s - 1) * d;
    let weights = rnd(k * c * s, 6);
    let x = rnd(n * c * w, 7);
    let layer = Conv1dLayer::new(c, k, s, d, weights);
    let f32_out = layer.forward(&x, n, w);
    let bf_out = to_f32(&layer.forward_bf16(&to_bf16(&x), n, w));
    // bf16 has ~3 decimal digits; with k=C*S=80-long reductions in f32
    // accumulators the error stays ~1e-2 relative.
    close(&bf_out, &f32_out, 5e-2, "bf16 vs f32");
}

#[test]
fn layer_same_padding_matches_paper_figure1_shape() {
    // Fig. 1: C=5, W=17, K=4, S=3, d=3, Q=17 with zero padding.
    let (n, c, k, s, d, w) = (1, 5, 4, 3, 3, 17);
    let layer = Conv1dLayer::new(c, k, s, d, rnd(k * c * s, 8));
    let x = rnd(n * c * w, 9);
    let out = layer.forward_same(&x, n, w);
    assert_eq!(out.len(), n * k * w, "same-padded output width must be 17");
}

#[test]
fn flop_accounting_matches_both_backends() {
    // Efficiency denominators must be implementation-independent.
    let p = ConvParams::new(4, 15, 15, 1_400, 51, 8).unwrap();
    assert_eq!(p.flops(), 2 * 4 * 15 * 15 * 1000 * 51);
    assert!(p.favours_brgemm());
    let p_small = ConvParams::new(4, 15, 15, 999 + 4 * 50, 5, 50).unwrap();
    assert!(!p_small.favours_brgemm()); // Q = 999 < 1000
}

#[test]
fn param_count_matches_paper_model() {
    // 25 conv layers, ch=15, S=51 — the network the paper trains.
    use dilconv1d::model::NetConfig;
    let cfg = NetConfig::default();
    assert_eq!(cfg.n_conv_layers(), 25);
    // stem + 22 body convs + 2 heads, weights + biases:
    let expect: usize = cfg
        .layer_shapes()
        .iter()
        .map(|&(k, c, s)| k * c * s + k)
        .sum();
    assert_eq!(cfg.param_count(), expect);
    assert!(expect > 250_000 && expect < 300_000, "{expect}");
}

/// Finite-difference harness for the fused post-op backward: builds a
/// `ConvSame` with the given spec, runs one fused forward/backward, then
/// checks every analytic gradient (weights, bias, input, residual)
/// against central differences of `loss = Σ g ⊙ forward(...)`.
/// Returns `(checked, ok)` pairs per group so callers choose strictness.
fn fused_fd_check(post_name: &str) -> Vec<(usize, usize)> {
    use dilconv1d::conv1d::PostOps;
    use dilconv1d::model::{ConvSame, Tensor};
    let (c, k, s, d, n, w) = (2usize, 3usize, 3usize, 2usize, 1usize, 20usize);
    let w0 = rnd(k * c * s, 50);
    let b0 = rnd(k, 51);
    let x0 = rnd(n * c * w, 52);
    let r0 = rnd(n * k * w, 53);
    let g = rnd(n * k * w, 54);
    let post = PostOps::parse(post_name).unwrap();

    let make = |wv: &[f32], bv: &[f32]| {
        let mut l = ConvSame::new(c, k, s, d, wv.to_vec());
        l.conv.bias = bv.to_vec();
        l.set_post_ops(post);
        l
    };
    let loss = |wv: &[f32], bv: &[f32], xv: &[f32], rv: &[f32]| -> f64 {
        let mut l = make(wv, bv);
        let res_t = Tensor::from_vec(rv.to_vec(), n, k, w);
        let res = if post.residual { Some(&res_t) } else { None };
        let y = l.forward_fused(&Tensor::from_vec(xv.to_vec(), n, c, w), res, false);
        y.data.iter().zip(&g).map(|(a, b)| *a as f64 * *b as f64).sum()
    };

    let mut layer = make(&w0, &b0);
    let x = Tensor::from_vec(x0.clone(), n, c, w);
    let res_t = Tensor::from_vec(r0.clone(), n, k, w);
    let res = if post.residual { Some(&res_t) } else { None };
    layer.forward_fused(&x, res, true);
    let (gin, gres, grads) =
        layer.backward_fused(&Tensor::from_vec(g.clone(), n, k, w), true, post.residual);
    let gin = gin.unwrap();

    fn check_group(
        results: &mut Vec<(usize, usize)>,
        analytic: &[f32],
        eps: f32,
        mut perturb: impl FnMut(usize, f32) -> f64,
    ) {
        let (mut checked, mut ok) = (0usize, 0usize);
        for (i, a) in analytic.iter().enumerate() {
            let fd = (perturb(i, eps) - perturb(i, -eps)) / (2.0 * eps as f64);
            checked += 1;
            if (fd - *a as f64).abs() < 3e-2 * (1.0 + a.abs() as f64) {
                ok += 1;
            }
        }
        results.push((checked, ok));
    }

    let eps = 1e-2f32;
    let mut results = Vec::new();
    check_group(&mut results, &grads.w, eps, |i, e| {
        let mut v = w0.clone();
        v[i] += e;
        loss(&v, &b0, &x0, &r0)
    });
    check_group(&mut results, &grads.b, eps, |i, e| {
        let mut v = b0.clone();
        v[i] += e;
        loss(&w0, &v, &x0, &r0)
    });
    check_group(&mut results, &gin.data, eps, |i, e| {
        let mut v = x0.clone();
        v[i] += e;
        loss(&w0, &b0, &v, &r0)
    });
    if post.residual {
        let gres = gres.unwrap();
        check_group(&mut results, &gres.data, eps, |i, e| {
            let mut v = r0.clone();
            v[i] += e;
            loss(&w0, &b0, &x0, &v)
        });
    }
    results
}

#[test]
fn fused_sigmoid_backward_matches_finite_difference_exactly() {
    // Sigmoid is smooth: every single gradient entry must match its
    // central difference.
    for (checked, ok) in fused_fd_check("bias_sigmoid") {
        assert!(checked > 0);
        assert_eq!(ok, checked, "{ok}/{checked} sigmoid gradients matched");
    }
}

#[test]
fn fused_relu_residual_backward_matches_finite_difference() {
    // ReLU kinks make individual central differences unreliable exactly
    // at zero activations; require a large majority per gradient group
    // (the exact-equality lockdown lives in prop_conv.rs).
    for (checked, ok) in fused_fd_check("bias_relu_residual") {
        assert!(checked > 0);
        assert!(
            ok * 10 >= checked * 9,
            "only {ok}/{checked} relu/residual gradients matched"
        );
    }
}

#[test]
fn wide_track_regression_60k() {
    // Full paper width: 60 000-wide track through the AtacWorks layer.
    let (n, c, k, s, d) = (1, 15, 15, 51, 8);
    let w = 60_000;
    let p = ConvParams::new(n, c, k, w, s, d).unwrap();
    let layer = Conv1dLayer::new(c, k, s, d, rnd(k * c * s, 10));
    let x = rnd(n * c * w, 11);
    let out = layer.forward(&x, n, w);
    assert_eq!(out.len(), n * k * p.q());
    assert!(out.iter().all(|v| v.is_finite()));
}
