//! Property-based tests of the conv1d kernel invariants (DESIGN.md §9).
//!
//! The offline build has no proptest; properties are checked over many
//! deterministically-random cases drawn from a seeded PRNG — shrinkage is
//! traded for a printed failing seed.

use dilconv1d::conv1d::backward_data::backward_data;
use dilconv1d::conv1d::backward_weight::backward_weight;
use dilconv1d::conv1d::direct::{backward_data_direct, backward_weight_direct, forward_direct};
use dilconv1d::conv1d::forward::forward;
use dilconv1d::conv1d::im2col::forward_im2col;
use dilconv1d::conv1d::layout::{
    kcs_to_sck_flipped, kcs_to_skc, pad_width, sck_to_kcs, skc_to_kcs, unpad_width,
};
use dilconv1d::conv1d::test_util::rnd;
use dilconv1d::conv1d::{Backend, Conv1dLayer, ConvParams, ConvPlan, PostOps};
use dilconv1d::machine::Precision;
use dilconv1d::util::rng::Rng;

/// Draw a random valid conv problem.
fn arb_problem(rng: &mut Rng) -> ConvParams {
    loop {
        let n = 1 + rng.below(3);
        let c = 1 + rng.below(17);
        let k = 1 + rng.below(17);
        let s = 1 + rng.below(12);
        let d = 1 + rng.below(9);
        let q = 1 + rng.below(300);
        if let Some(p) = ConvParams::new(n, c, k, q + (s - 1) * d, s, d) {
            return p;
        }
    }
}

fn close(a: &[f32], b: &[f32], tol: f32, what: &str, case: u64) {
    assert_eq!(a.len(), b.len(), "{what} length, case {case}");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= tol * (1.0 + y.abs()),
            "{what} case {case} idx {i}: {x} vs {y}"
        );
    }
}

#[test]
fn prop_forward_all_backends_agree() {
    let mut rng = Rng::new(0xF0);
    for case in 0..60 {
        let p = arb_problem(&mut rng);
        let x = rnd(p.n * p.c * p.w, case);
        let wt = rnd(p.k * p.c * p.s, case + 1000);
        let skc = kcs_to_skc(&wt, p.k, p.c, p.s);
        let mut brgemm = vec![0.0; p.n * p.k * p.q()];
        forward(&p, &x, &skc, &mut brgemm, 1);
        let mut im2col = vec![0.0; p.n * p.k * p.q()];
        forward_im2col(&p, &x, &wt, &mut im2col, 1);
        let mut direct = vec![0.0; p.n * p.k * p.q()];
        forward_direct(&p, &x, &wt, &mut direct);
        close(&brgemm, &direct, 1e-3, "brgemm vs direct", case);
        close(&im2col, &direct, 1e-3, "im2col vs direct", case);
    }
}

#[test]
fn prop_backward_data_matches_direct() {
    let mut rng = Rng::new(0xF1);
    for case in 0..40 {
        let p = arb_problem(&mut rng);
        let gout = rnd(p.n * p.k * p.q(), case);
        let wt = rnd(p.k * p.c * p.s, case + 2000);
        let sck = kcs_to_sck_flipped(&wt, p.k, p.c, p.s);
        let mut ours = vec![0.0; p.n * p.c * p.w];
        backward_data(&p, &gout, &sck, &mut ours, 1);
        let mut want = vec![0.0; p.n * p.c * p.w];
        backward_data_direct(&p, &gout, &wt, &mut want);
        close(&ours, &want, 1e-3, "bwd-data", case);
    }
}

#[test]
fn prop_backward_weight_matches_direct() {
    let mut rng = Rng::new(0xF2);
    for case in 0..40 {
        let p = arb_problem(&mut rng);
        let gout = rnd(p.n * p.k * p.q(), case);
        let x = rnd(p.n * p.c * p.w, case + 3000);
        let ours = backward_weight(&p, &gout, &x, 1);
        let want = backward_weight_direct(&p, &gout, &x);
        close(&ours, &want, 5e-3, "bwd-weight", case);
    }
}

#[test]
fn prop_relayout_roundtrips() {
    let mut rng = Rng::new(0xF3);
    for case in 0..50 {
        let k = 1 + rng.below(20);
        let c = 1 + rng.below(20);
        let s = 1 + rng.below(60);
        let w = rnd(k * c * s, case);
        assert_eq!(skc_to_kcs(&kcs_to_skc(&w, k, c, s), s, k, c), w);
        // Double flip+transpose is the identity too.
        let sck = kcs_to_sck_flipped(&w, k, c, s);
        let back = sck_to_kcs(&sck, s, c, k);
        // back[k][c][s'] = w[k][c][S-1-s'] — flipping again restores.
        let mut unflipped = vec![0.0; w.len()];
        for ik in 0..k {
            for ic in 0..c {
                for is in 0..s {
                    unflipped[(ik * c + ic) * s + is] = back[(ik * c + ic) * s + (s - 1 - is)];
                }
            }
        }
        assert_eq!(unflipped, w, "case {case}");
    }
}

#[test]
fn prop_pad_roundtrip_and_zeroes() {
    let mut rng = Rng::new(0xF4);
    for case in 0..50 {
        let n = 1 + rng.below(3);
        let c = 1 + rng.below(5);
        let w = 1 + rng.below(200);
        let l = rng.below(20);
        let r = rng.below(20);
        let x = rnd(n * c * w, case);
        let padded = pad_width(&x, n, c, w, l, r);
        assert_eq!(padded.len(), n * c * (w + l + r));
        assert_eq!(unpad_width(&padded, n, c, w + l + r, l, r), x);
        for row in 0..n * c {
            let base = row * (w + l + r);
            assert!(padded[base..base + l].iter().all(|&v| v == 0.0));
            assert!(padded[base + l + w..base + l + r + w].iter().all(|&v| v == 0.0));
        }
    }
}

#[test]
fn prop_output_width_formula() {
    let mut rng = Rng::new(0xF5);
    for _ in 0..100 {
        let p = arb_problem(&mut rng);
        assert_eq!(p.q(), p.w - (p.s - 1) * p.d);
        let (l, r) = ConvParams::same_pad(p.s, p.d);
        assert_eq!(l + r, (p.s - 1) * p.d);
    }
}

#[test]
fn prop_linearity_of_forward() {
    // conv(a·x + b·y) == a·conv(x) + b·conv(y) — convolution is linear.
    let mut rng = Rng::new(0xF6);
    for case in 0..20 {
        let p = arb_problem(&mut rng);
        let x = rnd(p.n * p.c * p.w, case);
        let y = rnd(p.n * p.c * p.w, case + 500);
        let wt = rnd(p.k * p.c * p.s, case + 900);
        let skc = kcs_to_skc(&wt, p.k, p.c, p.s);
        let (a, b) = (0.7f32, -1.3f32);
        let mixed: Vec<f32> = x.iter().zip(&y).map(|(xv, yv)| a * xv + b * yv).collect();
        let mut out_mixed = vec![0.0; p.n * p.k * p.q()];
        forward(&p, &mixed, &skc, &mut out_mixed, 1);
        let mut ox = vec![0.0; p.n * p.k * p.q()];
        forward(&p, &x, &skc, &mut ox, 1);
        let mut oy = vec![0.0; p.n * p.k * p.q()];
        forward(&p, &y, &skc, &mut oy, 1);
        let want: Vec<f32> = ox.iter().zip(&oy).map(|(xv, yv)| a * xv + b * yv).collect();
        close(&out_mixed, &want, 5e-3, "linearity", case);
    }
}

#[test]
fn prop_dilation_equals_strided_dense_conv() {
    // A dilated filter equals a dense filter with zeros inserted between
    // taps: conv(x, w, d) == conv(x, expand(w, d), 1).
    let mut rng = Rng::new(0xF7);
    for case in 0..20 {
        let c = 1 + rng.below(4);
        let k = 1 + rng.below(4);
        let s = 2 + rng.below(4);
        let d = 2 + rng.below(4);
        let q = 1 + rng.below(100);
        let w_in = q + (s - 1) * d;
        let p_dil = ConvParams::new(1, c, k, w_in, s, d).unwrap();
        let s_dense = (s - 1) * d + 1;
        let p_dense = ConvParams::new(1, c, k, w_in, s_dense, 1).unwrap();
        assert_eq!(p_dil.q(), p_dense.q());
        let x = rnd(c * w_in, case);
        let wt = rnd(k * c * s, case + 100);
        // Expand taps with zeros.
        let mut dense = vec![0.0f32; k * c * s_dense];
        for ik in 0..k {
            for ic in 0..c {
                for is in 0..s {
                    dense[(ik * c + ic) * s_dense + is * d] = wt[(ik * c + ic) * s + is];
                }
            }
        }
        let mut o1 = vec![0.0; k * p_dil.q()];
        forward(&p_dil, &x, &kcs_to_skc(&wt, k, c, s), &mut o1, 1);
        let mut o2 = vec![0.0; k * p_dense.q()];
        forward(&p_dense, &x, &kcs_to_skc(&dense, k, c, s_dense), &mut o2, 1);
        close(&o1, &o2, 1e-3, "dilation-expansion", case);
    }
}

#[test]
fn prop_plan_reuse_matches_fresh_layer_bit_exact() {
    // A plan executed repeatedly with different inputs must match fresh
    // Conv1dLayer calls *bit-exactly*: across every dilation 1–8, odd
    // widths, and Q % WIDTH_BLOCK != 0 tails, and on every backend.
    for d in 1..=8usize {
        // Odd Q, and Q chosen so Q % 64 != 0 (97, 161, ... are all odd).
        let (n, c, k, s) = (2usize, 5usize, 6usize, 7usize);
        let q = 97 + 8 * d; // odd ∀d, never a multiple of 64 in this range
        assert_ne!(q % 64, 0);
        let p = ConvParams::new(n, c, k, q + (s - 1) * d, s, d).unwrap();
        let wt = rnd(k * c * s, 500 + d as u64);
        let x1 = rnd(n * c * p.w, 600 + d as u64);
        let x2 = rnd(n * c * p.w, 700 + d as u64);
        for backend in Backend::ALL {
            let mut plan = ConvPlan::new(p, backend, Precision::F32, 1, wt.clone()).unwrap();
            let mut o1 = vec![0.0; n * k * p.q()];
            let mut o2 = vec![0.0; n * k * p.q()];
            let mut o1_again = vec![0.0; n * k * p.q()];
            plan.execute_forward_into(&x1, &mut o1);
            plan.execute_forward_into(&x2, &mut o2);
            plan.execute_forward_into(&x1, &mut o1_again);
            assert_eq!(o1, o1_again, "d={d} {backend}: plan reuse leaked state");
            // Fresh layers as the oracle — one per call, no shared state.
            let fresh = |xv: &[f32]| {
                let mut l = Conv1dLayer::new(c, k, s, d, wt.clone());
                l.backend = backend;
                l.forward(xv, n, p.w)
            };
            assert_eq!(o1, fresh(&x1), "d={d} {backend}: forward(x1)");
            assert_eq!(o2, fresh(&x2), "d={d} {backend}: forward(x2)");
        }
        // Backward passes through a reused plan are bit-exact too.
        let gout = rnd(n * k * p.q(), 800 + d as u64);
        let mut plan = ConvPlan::new(p, Backend::Brgemm, Precision::F32, 1, wt.clone()).unwrap();
        let mut warm = vec![0.0; n * k * p.q()];
        plan.execute_forward_into(&x1, &mut warm); // dirty the workspace
        let mut gin = vec![0.0; n * c * p.w];
        plan.execute_backward_data_into(&gout, &mut gin);
        let mut gw = vec![0.0; k * c * s];
        plan.execute_backward_weight_into(&gout, &x1, &mut gw);
        let fresh = Conv1dLayer::new(c, k, s, d, wt);
        assert_eq!(gin, fresh.backward_data(&gout, n, p.w), "d={d}: bwd-data");
        assert_eq!(gw, fresh.backward_weight(&gout, &x1, n, p.w), "d={d}: bwd-weight");
    }
}

#[test]
fn prop_bf16_plan_is_deterministic_and_tracks_f32() {
    let mut rng = Rng::new(0xFA);
    for case in 0..10 {
        let p = arb_problem(&mut rng);
        let wt = rnd(p.k * p.c * p.s, 900 + case);
        let x = rnd(p.n * p.c * p.w, 950 + case);
        let mut plan = ConvPlan::by_name(p, "bf16", 1, wt.clone()).unwrap();
        let mut o1 = vec![0.0; p.n * p.k * p.q()];
        let mut o2 = vec![0.0; p.n * p.k * p.q()];
        plan.execute_forward_into(&x, &mut o1);
        plan.execute_forward_into(&x, &mut o2);
        assert_eq!(o1, o2, "case {case}: bf16 plan must be deterministic");
        let mut f32_out = vec![0.0; p.n * p.k * p.q()];
        ConvPlan::by_name(p, "brgemm", 1, wt)
            .unwrap()
            .execute_forward_into(&x, &mut f32_out);
        close(&o1, &f32_out, 6e-2, "bf16 vs f32", case);
    }
}

#[test]
fn prop_fused_forward_with_no_post_ops_is_bit_identical() {
    // PostOps::none(): the fused entry point must be indistinguishable —
    // bit for bit — from the raw forward, on every kernel.
    let mut rng = Rng::new(0xFB);
    for case in 0..12 {
        let p = arb_problem(&mut rng);
        let wt = rnd(p.k * p.c * p.s, 1100 + case);
        let x = rnd(p.n * p.c * p.w, 1150 + case);
        for name in ["brgemm", "im2col", "direct", "bf16"] {
            let mut plan = ConvPlan::by_name(p, name, 1, wt.clone()).unwrap();
            assert!(plan.post_ops().is_none(), "default spec is none");
            let mut raw = vec![0.0; p.n * p.k * p.q()];
            plan.execute_forward_into(&x, &mut raw);
            let mut fused = vec![0.0; p.n * p.k * p.q()];
            plan.execute_forward_post_into(&x, None, &mut fused);
            assert_eq!(raw, fused, "case {case} {name}: fused != unfused at none()");
        }
    }
}

#[test]
fn prop_fused_relu_backward_equals_masked_unfused_backward() {
    // Exact (bit-level) agreement: the fused relu backward must produce
    // the same gradients as masking the output gradient by `y > 0` and
    // running the raw backward passes — per kernel, across dilations.
    let mut rng = Rng::new(0xFC);
    for case in 0..10 {
        let p = arb_problem(&mut rng);
        let wt = rnd(p.k * p.c * p.s, 1200 + case);
        let x = rnd(p.n * p.c * p.w, 1250 + case);
        let bias = rnd(p.k, 1300 + case);
        let gout = rnd(p.n * p.k * p.q(), 1350 + case);
        for name in ["brgemm", "im2col", "direct"] {
            let mut plan = ConvPlan::by_name(p, name, 1, wt.clone())
                .unwrap()
                .with_post_ops(PostOps::bias_relu());
            plan.set_bias(&bias);
            let mut y = vec![0.0; p.n * p.k * p.q()];
            plan.execute_forward_post_into(&x, None, &mut y);
            let mut gin = vec![0.0; p.n * p.c * p.w];
            let mut gw = vec![0.0; p.k * p.c * p.s];
            let mut gb = vec![0.0; p.k];
            plan.execute_backward_fused_into(
                &gout,
                &y,
                &x,
                Some(&mut gin),
                &mut gw,
                Some(&mut gb),
                None,
            );
            // Unfused oracle: mask, then the raw backward executors.
            let masked: Vec<f32> = gout
                .iter()
                .zip(&y)
                .map(|(g, yy)| if *yy > 0.0 { *g } else { 0.0 })
                .collect();
            let mut gin_want = vec![0.0; p.n * p.c * p.w];
            plan.execute_backward_data_into(&masked, &mut gin_want);
            let mut gw_want = vec![0.0; p.k * p.c * p.s];
            plan.execute_backward_weight_into(&masked, &x, &mut gw_want);
            assert_eq!(gin, gin_want, "case {case} {name}: fused gin");
            assert_eq!(gw, gw_want, "case {case} {name}: fused gw");
            // Bias gradient = per-filter sum of the masked gradient.
            for ik in 0..p.k {
                let mut want = 0.0f32;
                for ib in 0..p.n {
                    want += masked[(ib * p.k + ik) * p.q()..(ib * p.k + ik + 1) * p.q()]
                        .iter()
                        .sum::<f32>();
                }
                assert!(
                    (gb[ik] - want).abs() <= 1e-5 * (1.0 + want.abs()),
                    "case {case} {name}: gb[{ik}] {} vs {want}",
                    gb[ik]
                );
            }
        }
    }
}

#[test]
fn prop_threading_bit_exact() {
    let mut rng = Rng::new(0xF8);
    for case in 0..15 {
        let p = arb_problem(&mut rng);
        let x = rnd(p.n * p.c * p.w, case);
        let wt = rnd(p.k * p.c * p.s, case + 1);
        let skc = kcs_to_skc(&wt, p.k, p.c, p.s);
        let mut o1 = vec![0.0; p.n * p.k * p.q()];
        forward(&p, &x, &skc, &mut o1, 1);
        let mut o2 = vec![0.0; p.n * p.k * p.q()];
        forward(&p, &x, &skc, &mut o2, 3);
        assert_eq!(o1, o2, "case {case}");
    }
}
