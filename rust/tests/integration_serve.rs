//! Integration: the batched inference serving subsystem (DESIGN.md §7).
//!
//! The load-bearing guarantee is **bit-identity**: serving a request in
//! a dynamic batch must produce exactly the bits that one-at-a-time
//! execution produces. The conv kernels compute each output element as
//! the same FMA reduction in the same order per image, for any batch
//! size and either work partition — so this is an `assert_eq!` on f32
//! vectors, not a tolerance. The matrix here covers ≥3 width buckets ×
//! {f32, bf16, i8} × {batch, grid}, at the engine level and end-to-end
//! through the server (dispatcher + worker pool + admission control).
//! The i8 column holds because activation scales are calibrated ONCE at
//! engine construction (never per batch), so batching cannot perturb
//! quantization.

use std::time::Duration;

use dilconv1d::conv1d::Partition;
use dilconv1d::machine::Precision;
use dilconv1d::model::{AtacWorksNet, MasterWeights, NetConfig, Tensor};
use dilconv1d::serve::{
    BatcherOpts, BucketSet, EngineOpts, InferenceEngine, ServeError, Server,
};
use dilconv1d::util::rng::Rng;

const BUCKETS: [usize; 3] = [128, 256, 384];

fn net_cfg() -> NetConfig {
    NetConfig::tiny()
}

fn params() -> Vec<f32> {
    AtacWorksNet::init(net_cfg(), 42).pack_params()
}

fn opts(max_batch: usize, precision: Precision, partition: Partition) -> EngineOpts {
    EngineOpts {
        buckets: BucketSet::new(&BUCKETS).expect("bucket widths"),
        max_batch,
        threads: 2,
        precision,
        partition,
        cache_capacity: BUCKETS.len(),
        ..EngineOpts::default()
    }
}

/// Synthetic Poisson coverage track of width `w`.
fn track(w: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..w).map(|_| rng.poisson(0.8) as f32).collect()
}

/// A width mix that hits every bucket, both exactly and with padding.
fn request_widths() -> Vec<usize> {
    vec![100, 128, 65, 200, 256, 129, 300, 384, 260, 90, 383, 128]
}

#[test]
fn batched_serving_is_bit_identical_to_sequential_across_the_matrix() {
    let p = params();
    for precision in [Precision::F32, Precision::Bf16, Precision::I8] {
        for partition in [Partition::Batch, Partition::Grid] {
            let mut batched =
                InferenceEngine::new(net_cfg(), &p, opts(4, precision, partition))
                    .expect("batched engine");
            let mut single =
                InferenceEngine::new(net_cfg(), &p, opts(1, precision, partition))
                    .expect("single engine");
            let reqs: Vec<Vec<f32>> = request_widths()
                .iter()
                .enumerate()
                .map(|(i, &w)| track(w, 100 + i as u64))
                .collect();
            let refs: Vec<&[f32]> = reqs.iter().map(Vec::as_slice).collect();
            let got = batched.infer_batch(&refs).expect("batched inference");
            assert_eq!(got.len(), reqs.len());
            for (i, (g, r)) in got.iter().zip(&reqs).enumerate() {
                let alone = single.infer_one(r).expect("sequential inference");
                assert_eq!(
                    g.denoised, alone.denoised,
                    "{precision:?}/{partition}: denoised row {i} (w={}) diverged from \
                     one-at-a-time execution",
                    r.len()
                );
                assert_eq!(
                    g.logits, alone.logits,
                    "{precision:?}/{partition}: logits row {i} (w={}) diverged",
                    r.len()
                );
                assert_eq!(g.denoised.len(), r.len(), "output truncated to request width");
            }
            // All three buckets were exercised.
            assert_eq!(batched.cache_len(), BUCKETS.len());
        }
    }
}

#[test]
fn grid_and_batch_partitions_serve_identical_bits() {
    // The partition is an execution detail, never a numerics one: the
    // same engine config under batch vs grid partitioning returns
    // identical responses.
    let p = params();
    for precision in [Precision::F32, Precision::Bf16, Precision::I8] {
        let mut a = InferenceEngine::new(net_cfg(), &p, opts(4, precision, Partition::Batch))
            .expect("batch engine");
        let mut b = InferenceEngine::new(net_cfg(), &p, opts(4, precision, Partition::Grid))
            .expect("grid engine");
        let reqs: Vec<Vec<f32>> = (0..6).map(|i| track(120 + 40 * i, 500 + i as u64)).collect();
        let refs: Vec<&[f32]> = reqs.iter().map(Vec::as_slice).collect();
        let ra = a.infer_batch(&refs).expect("batch partition");
        let rb = b.infer_batch(&refs).expect("grid partition");
        assert_eq!(ra, rb, "{precision:?}: grid vs batch partition");
    }
}

#[test]
fn serving_is_bucket_invariant_and_matches_native_width_evaluation() {
    // Width masking makes the bucket an execution shape only: the same
    // request through two engines with *different* bucket grids returns
    // identical bits, and both equal evaluating the model directly at
    // the request's native width (no serving stack at all).
    let p = params();
    for precision in [Precision::F32, Precision::Bf16, Precision::I8] {
        let mut coarse = InferenceEngine::new(
            net_cfg(),
            &p,
            EngineOpts {
                buckets: BucketSet::new(&[256]).expect("bucket"),
                ..opts(4, precision, Partition::Batch)
            },
        )
        .expect("coarse engine");
        let mut fine = InferenceEngine::new(
            net_cfg(),
            &p,
            EngineOpts {
                buckets: BucketSet::new(&[384]).expect("bucket"),
                ..opts(2, precision, Partition::Grid)
            },
        )
        .expect("fine engine");
        let r = track(200, 77);
        let a = coarse.infer_one(&r).expect("bucket 256");
        let b = fine.infer_one(&r).expect("bucket 384");
        assert_eq!(a, b, "{precision:?}: the bucket must never change the answer");
        // Native-width reference: the bare model, no serving stack. It
        // loads the same working copy the engines serve (bf16 rounds
        // biases too, which the f32 epilogue consumes directly). The i8
        // tier is excluded here only because its activation scales come
        // from the engine's one-time calibration pass, which the bare
        // model does not perform; engine-vs-engine identity above is the
        // i8 guarantee.
        if precision == Precision::I8 {
            continue;
        }
        let mut net = AtacWorksNet::init(net_cfg(), 0);
        net.unpack_params(&MasterWeights::working_copy(&p, precision));
        net.set_precision(precision);
        let x = Tensor::from_vec(r.clone(), 1, 1, r.len());
        let (den, logits, _) = net.forward(&x, false);
        assert_eq!(a.denoised, den.data, "{precision:?}: native-width denoised");
        assert_eq!(a.logits, logits.data, "{precision:?}: native-width logits");
    }
}

#[test]
fn server_end_to_end_matches_the_sequential_reference() {
    let p = params();
    for precision in [Precision::F32, Precision::Bf16, Precision::I8] {
        for partition in [Partition::Batch, Partition::Grid] {
            let server = Server::start(
                net_cfg(),
                &p,
                BatcherOpts {
                    engine: opts(4, precision, partition),
                    window: Duration::from_millis(2),
                    queue_depth: 64,
                    workers: 2,
                    warm: true,
                    ..BatcherOpts::default()
                },
            )
            .expect("server");
            let reqs: Vec<Vec<f32>> = request_widths()
                .iter()
                .enumerate()
                .map(|(i, &w)| track(w, 900 + i as u64))
                .collect();
            let tickets: Vec<_> = reqs
                .iter()
                .map(|r| server.submit(r.clone()).expect("submit"))
                .collect();
            let mut reference =
                InferenceEngine::new(net_cfg(), &p, opts(1, precision, partition))
                    .expect("reference engine");
            for (i, (t, r)) in tickets.into_iter().zip(&reqs).enumerate() {
                let resp = t.wait().expect("response");
                let want = reference.infer_one(r).expect("reference");
                assert_eq!(
                    resp.output, want,
                    "{precision:?}/{partition}: served request {i} (w={}) diverged",
                    r.len()
                );
                assert!(resp.batch_rows >= 1 && resp.batch_rows <= 4);
                assert!(BUCKETS.contains(&resp.bucket));
            }
            let m = server.shutdown();
            assert_eq!(m.completed, reqs.len() as u64);
            assert_eq!(m.rejected + m.failed, 0);
            assert_eq!(m.latency.count(), reqs.len() as u64);
            assert!(m.batches >= 3, "three buckets cannot share a batch");
            // Every observed bucket is a configured bucket.
            for b in m.per_bucket.keys() {
                assert!(BUCKETS.contains(b));
            }
        }
    }
}

#[test]
fn socket_sharded_serving_matches_flat_and_accounts_every_request() {
    // NUMA sharding is a placement policy, never a numerics one: the
    // same traffic through a flat pool and through socket-sharded pools
    // (2 and 4 emulated sockets) returns bit-identical responses, and
    // the per-socket routing counters account for every batch and row.
    let p = params();
    let reqs: Vec<Vec<f32>> = request_widths()
        .iter()
        .enumerate()
        .map(|(i, &w)| track(w, 4_000 + i as u64))
        .collect();
    let mut reference = InferenceEngine::new(
        net_cfg(),
        &p,
        opts(1, Precision::F32, Partition::Batch),
    )
    .expect("reference engine");
    let want: Vec<_> = reqs
        .iter()
        .map(|r| reference.infer_one(r).expect("reference"))
        .collect();
    for sockets in [1usize, 2, 4] {
        let server = Server::start(
            net_cfg(),
            &p,
            BatcherOpts::default()
                .with_engine(opts(4, Precision::F32, Partition::Batch))
                .with_window(Duration::from_millis(2))
                .with_queue_depth(64)
                .with_workers(4)
                .with_sockets(sockets),
        )
        .expect("server");
        assert_eq!(server.placement().n_sockets(), sockets);
        assert_eq!(server.placement().is_flat(), sockets == 1);
        let tickets: Vec<_> = reqs
            .iter()
            .map(|r| server.submit(r.clone()).expect("submit"))
            .collect();
        for (i, (t, w)) in tickets.into_iter().zip(&want).enumerate() {
            let resp = t.wait().expect("response");
            assert_eq!(
                resp.output, *w,
                "sockets={sockets}: request {i} diverged from the sequential reference"
            );
        }
        let m = server.shutdown();
        assert_eq!(m.completed, reqs.len() as u64);
        assert_eq!(m.rejected + m.failed, 0);
        // Routing accounting: every row and every batch lands on exactly
        // one socket, and spills balance (a batch spilled out of its home
        // socket is spilled into exactly one other).
        assert_eq!(m.per_socket.len(), sockets);
        let rows: u64 = m.per_socket.iter().map(|s| s.rows).sum();
        assert_eq!(rows, reqs.len() as u64);
        let dispatched: u64 = m.per_socket.iter().map(|s| s.routed + s.spilled_in).sum();
        assert_eq!(dispatched, m.batches);
        let spilled_out: u64 = m.per_socket.iter().map(|s| s.spilled_out).sum();
        let spilled_in: u64 = m.per_socket.iter().map(|s| s.spilled_in).sum();
        assert_eq!(spilled_out, spilled_in);
        assert!(
            m.per_socket.iter().any(|s| s.peak_inflight >= 1),
            "sockets={sockets}: no socket ever saw an in-flight batch"
        );
    }
}

#[test]
fn admission_control_backpressure_and_recovery() {
    // Park requests behind a long window so the in-flight budget fills
    // deterministically, assert QueueFull, then confirm the accepted
    // requests drain and the server keeps working afterwards.
    let server = Server::start(
        net_cfg(),
        &params(),
        BatcherOpts {
            engine: opts(64, Precision::F32, Partition::Batch),
            window: Duration::from_millis(300),
            queue_depth: 4,
            workers: 1,
            warm: false,
            ..BatcherOpts::default()
        },
    )
    .expect("server");
    let mut accepted = Vec::new();
    let mut rejected = 0;
    for i in 0..10 {
        match server.submit(track(100, i)) {
            Ok(t) => accepted.push(t),
            Err(ServeError::QueueFull { depth }) => {
                assert_eq!(depth, 4);
                rejected += 1;
            }
            Err(e) => panic!("unexpected error: {e}"),
        }
    }
    assert_eq!(accepted.len(), 4);
    assert_eq!(rejected, 6);
    for t in accepted {
        t.wait().expect("accepted request completes after the window");
    }
    // Capacity freed: a fresh submit is admitted again.
    let t = server.submit(track(64, 99)).expect("recovered after drain");
    let r = t.wait().expect("late request completes");
    assert_eq!(r.output.denoised.len(), 64);
    let m = server.shutdown();
    assert_eq!(m.completed, 5);
    assert_eq!(m.rejected, 6);
}

#[test]
fn oversized_requests_are_rejected_not_truncated() {
    let server = Server::start(
        net_cfg(),
        &params(),
        BatcherOpts {
            engine: opts(2, Precision::F32, Partition::Batch),
            window: Duration::from_millis(1),
            queue_depth: 8,
            workers: 1,
            warm: false,
            ..BatcherOpts::default()
        },
    )
    .expect("server");
    match server.submit(track(500, 1)) {
        Err(ServeError::TooWide { width, largest }) => {
            assert_eq!((width, largest), (500, 384));
        }
        other => panic!("expected TooWide, got {:?}", other.map(|_| ())),
    }
    assert!(matches!(server.submit(Vec::new()), Err(ServeError::EmptyRequest)));
    drop(server);
}

#[test]
fn bf16_serving_actually_rounds_and_differs_from_f32() {
    // Guard against bf16 serving silently running f32 kernels: the two
    // precisions must disagree somewhere on a non-trivial track.
    let p = params();
    let mut f32e = InferenceEngine::new(
        net_cfg(),
        &p,
        opts(1, Precision::F32, Partition::Batch),
    )
    .expect("f32 engine");
    let mut bf16e = InferenceEngine::new(
        net_cfg(),
        &p,
        opts(1, Precision::Bf16, Partition::Batch),
    )
    .expect("bf16 engine");
    let r = track(200, 7);
    let a = f32e.infer_one(&r).expect("f32");
    let b = bf16e.infer_one(&r).expect("bf16");
    assert_ne!(a.denoised, b.denoised, "bf16 path must not be f32 in disguise");
    // But they agree to bf16 tolerance — same model, rounded weights.
    for (x, y) in a.denoised.iter().zip(&b.denoised) {
        assert!((x - y).abs() < 4e-2 * (1.0 + x.abs()), "{x} vs {y}");
    }
}

#[test]
fn i8_serving_engages_the_quantized_tier_and_tracks_f32() {
    // Same guard for the int8 tier: it must not be f32 in disguise, and
    // the quantization error through the whole net stays small in a
    // relative-L2 sense (per-element budgets compound across layers, so
    // an aggregate norm is the right lock here).
    let p = params();
    let mut f32e = InferenceEngine::new(
        net_cfg(),
        &p,
        opts(1, Precision::F32, Partition::Batch),
    )
    .expect("f32 engine");
    let mut i8e = InferenceEngine::new(
        net_cfg(),
        &p,
        opts(1, Precision::I8, Partition::Batch),
    )
    .expect("i8 engine");
    let r = track(200, 7);
    let a = f32e.infer_one(&r).expect("f32");
    let b = i8e.infer_one(&r).expect("i8");
    assert_ne!(a.denoised, b.denoised, "i8 path must not be f32 in disguise");
    let (mut err, mut norm) = (0.0f64, 0.0f64);
    for (x, y) in a.denoised.iter().zip(&b.denoised) {
        err += ((x - y) as f64).powi(2);
        norm += (*x as f64).powi(2);
    }
    assert!(norm > 0.0, "degenerate reference output");
    let rel = (err / norm).sqrt();
    assert!(rel < 0.25, "i8 drifted too far from f32: rel L2 = {rel}");
}
