//! Integration: the TCP wire front-end (DESIGN.md §7b), loopback
//! end-to-end.
//!
//! Covers ≥100 concurrent mixed requests (in-bucket and over-wide →
//! streamed) with payload-exact responses against engine references,
//! backpressure surfacing as a `BUSY` wire status under a full queue,
//! protocol violations closing the connection with `MALFORMED`, the
//! connection cap, and graceful drain: a request in flight at shutdown
//! still gets its response.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::{Arc, Barrier};
use std::time::Duration;

use dilconv1d::model::{AtacWorksNet, NetConfig};
use dilconv1d::serve::net::wire::status;
use dilconv1d::serve::net::{
    encode_request_header, parse_response_header, NetOpts, NetServer, RESP_FLAG_STREAMED,
    RESP_HEADER_LEN,
};
use dilconv1d::serve::{
    round_up_to_block, BatcherOpts, BucketSet, EngineOpts, InferenceEngine, Server,
};
use dilconv1d::util::rng::Rng;

fn net_cfg() -> NetConfig {
    NetConfig::tiny()
}

fn params() -> Vec<f32> {
    AtacWorksNet::init(net_cfg(), 42).pack_params()
}

fn engine_opts(buckets: &[usize], max_batch: usize) -> EngineOpts {
    EngineOpts {
        buckets: BucketSet::new(buckets).expect("bucket widths"),
        max_batch,
        cache_capacity: buckets.len(),
        ..EngineOpts::default()
    }
}

fn batcher(queue_depth: usize, window: Duration, max_batch: usize, workers: usize) -> Server {
    Server::start(
        net_cfg(),
        &params(),
        BatcherOpts {
            engine: engine_opts(&[128, 256], max_batch),
            window,
            queue_depth,
            workers,
            warm: false,
            stream_window: Some(128),
            ..BatcherOpts::default()
        },
    )
    .expect("server")
}

fn track(w: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..w).map(|_| rng.poisson(0.8) as f32).collect()
}

// ------------------------------------------------------------ wire client

fn send_request(stream: &mut TcpStream, signal: &[f32]) -> std::io::Result<()> {
    stream.write_all(&encode_request_header(signal.len() as u32, 0))?;
    let mut bytes = Vec::with_capacity(signal.len() * 4);
    for v in signal {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    stream.write_all(&bytes)
}

fn read_f32s(stream: &mut TcpStream, n: usize) -> std::io::Result<Vec<f32>> {
    let mut buf = vec![0u8; n * 4];
    stream.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Read one response frame: `(status, flags, payload)` where the payload
/// (denoised, logits) is present only on `OK`.
#[allow(clippy::type_complexity)]
fn read_response(
    stream: &mut TcpStream,
) -> std::io::Result<(u8, u8, Option<(Vec<f32>, Vec<f32>)>)> {
    let mut hdr = [0u8; RESP_HEADER_LEN];
    stream.read_exact(&mut hdr)?;
    let (code, flags, width) = parse_response_header(&hdr);
    if code == status::OK {
        let den = read_f32s(stream, width)?;
        let log = read_f32s(stream, width)?;
        Ok((code, flags, Some((den, log))))
    } else {
        Ok((code, flags, None))
    }
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

// ------------------------------------------------------------------ tests

#[test]
fn loopback_serves_a_hundred_plus_concurrent_mixed_requests_exactly() {
    // Widths cycle per request: four in-bucket + one over-wide (400 >
    // largest bucket 256 → streamed). Seed = width, so every request of
    // a width shares one reference output.
    const WIDTHS: [usize; 5] = [90, 128, 200, 256, 400];
    const CLIENTS: usize = 25;
    const PER_CLIENT: usize = 5; // 125 requests total
    let mut references: HashMap<usize, (Vec<u32>, Vec<u32>)> = HashMap::new();
    for &w in &WIDTHS {
        // Whole-sequence reference — for the over-wide width this is
        // exactly what the streamed response must reproduce, bit for bit.
        let mut whole = InferenceEngine::new(
            net_cfg(),
            &params(),
            engine_opts(&[round_up_to_block(w)], 1),
        )
        .expect("reference engine");
        let out = whole.infer_one(&track(w, w as u64)).expect("reference");
        references.insert(w, (bits(&out.denoised), bits(&out.logits)));
    }
    let references = Arc::new(references);
    let net = NetServer::bind(
        "127.0.0.1:0",
        batcher(256, Duration::from_millis(1), 4, 2),
        NetOpts::default(),
    )
    .expect("bind");
    let addr = net.local_addr();
    let handles: Vec<_> = (0..CLIENTS)
        .map(|c| {
            let references = Arc::clone(&references);
            std::thread::spawn(move || {
                let mut stream = TcpStream::connect(addr).expect("connect");
                for i in 0..PER_CLIENT {
                    let w = WIDTHS[(c + i) % WIDTHS.len()];
                    send_request(&mut stream, &track(w, w as u64)).expect("send");
                    let (code, flags, payload) = read_response(&mut stream).expect("recv");
                    assert_eq!(code, status::OK, "client {c} request {i} (w={w})");
                    let streamed = flags & RESP_FLAG_STREAMED != 0;
                    assert_eq!(streamed, w > 256, "w={w} streamed flag");
                    let (den, log) = payload.expect("OK carries a payload");
                    let (want_den, want_log) = &references[&w];
                    assert_eq!(&bits(&den), want_den, "w={w} denoised");
                    assert_eq!(&bits(&log), want_log, "w={w} logits");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("client");
    }
    let (metrics, stats) = net.shutdown();
    let total = (CLIENTS * PER_CLIENT) as u64;
    assert_eq!(stats.connections_accepted, CLIENTS as u64);
    assert_eq!(stats.connections_rejected, 0);
    assert_eq!(stats.requests_ok, total);
    assert_eq!(stats.requests_malformed, 0);
    assert_eq!(stats.requests_backpressure, 0);
    // Each client cycles all five widths once → one streamed request each.
    assert_eq!(stats.requests_streamed, CLIENTS as u64);
    assert_eq!(metrics.completed, total);
    assert_eq!(metrics.streamed, CLIENTS as u64);
    assert!(stats.bytes_in > 0 && stats.bytes_out > 0);
}

#[test]
fn queue_full_surfaces_as_a_busy_wire_status() {
    // queue_depth 2 + a long batching window + huge max_batch: accepted
    // requests park in the dispatcher, so concurrent submits past the
    // budget must come back BUSY on the wire (connection stays open).
    let net = NetServer::bind(
        "127.0.0.1:0",
        batcher(2, Duration::from_millis(500), 64, 1),
        NetOpts::default(),
    )
    .expect("bind");
    let addr = net.local_addr();
    let barrier = Arc::new(Barrier::new(6));
    let handles: Vec<_> = (0..6)
        .map(|i| {
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                let mut stream = TcpStream::connect(addr).expect("connect");
                barrier.wait();
                send_request(&mut stream, &track(100, i as u64)).expect("send");
                let (code, _, payload) = read_response(&mut stream).expect("recv");
                match code {
                    c if c == status::OK => {
                        assert_eq!(payload.expect("payload").0.len(), 100);
                        true
                    }
                    c if c == status::BUSY => {
                        assert!(payload.is_none(), "BUSY carries no payload");
                        false
                    }
                    other => panic!("unexpected status {other}"),
                }
            })
        })
        .collect();
    let oks = handles
        .into_iter()
        .map(|h| h.join().expect("client"))
        .filter(|&ok| ok)
        .count() as u64;
    let busy = 6 - oks;
    assert!(busy >= 1, "a full queue must reject on the wire");
    assert!(oks >= 1, "accepted requests must still complete");
    let (metrics, stats) = net.shutdown();
    assert_eq!(stats.requests_ok, oks);
    assert_eq!(stats.requests_backpressure, busy);
    assert_eq!(metrics.completed, oks);
    assert_eq!(metrics.rejected, busy);
}

#[test]
fn malformed_frames_close_the_connection_with_a_malformed_status() {
    let net = NetServer::bind(
        "127.0.0.1:0",
        batcher(16, Duration::from_millis(1), 2, 1),
        NetOpts::default(),
    )
    .expect("bind");
    let addr = net.local_addr();
    // Bad magic: the parser cannot resync, so the server answers
    // MALFORMED and closes.
    let mut bad = TcpStream::connect(addr).expect("connect");
    bad.write_all(b"XXXXXXXXXXXX").expect("send garbage");
    let (code, _, payload) = read_response(&mut bad).expect("recv");
    assert_eq!(code, status::MALFORMED);
    assert!(payload.is_none());
    let mut rest = [0u8; 1];
    assert_eq!(bad.read(&mut rest).expect("EOF"), 0, "connection closed");
    // The server survives and serves fresh connections.
    let mut good = TcpStream::connect(addr).expect("reconnect");
    send_request(&mut good, &track(80, 3)).expect("send");
    let (code, _, payload) = read_response(&mut good).expect("recv");
    assert_eq!(code, status::OK);
    assert_eq!(payload.expect("payload").1.len(), 80);
    drop(good);
    let (_, stats) = net.shutdown();
    assert_eq!(stats.requests_malformed, 1);
    assert_eq!(stats.requests_ok, 1);
}

#[test]
fn the_connection_cap_rejects_with_busy_at_accept() {
    let net = NetServer::bind(
        "127.0.0.1:0",
        batcher(16, Duration::from_millis(1), 2, 1),
        NetOpts {
            max_connections: 1,
            ..NetOpts::default()
        },
    )
    .expect("bind");
    let addr = net.local_addr();
    let mut first = TcpStream::connect(addr).expect("connect");
    // A served request proves the accept loop registered the connection.
    send_request(&mut first, &track(64, 1)).expect("send");
    assert_eq!(read_response(&mut first).expect("recv").0, status::OK);
    // Over the cap: BUSY header, then close.
    let mut second = TcpStream::connect(addr).expect("connect #2");
    let (code, _, payload) = read_response(&mut second).expect("recv");
    assert_eq!(code, status::BUSY);
    assert!(payload.is_none());
    let mut rest = [0u8; 1];
    assert_eq!(second.read(&mut rest).expect("EOF"), 0);
    // Freeing the slot re-opens the door.
    drop(first);
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    while net.connections() > 0 && std::time::Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(net.connections(), 0, "handler must release its slot");
    let mut third = TcpStream::connect(addr).expect("connect #3");
    send_request(&mut third, &track(64, 2)).expect("send");
    assert_eq!(read_response(&mut third).expect("recv").0, status::OK);
    drop(third);
    let (_, stats) = net.shutdown();
    assert_eq!(stats.connections_accepted, 2);
    assert_eq!(stats.connections_rejected, 1);
}

#[test]
fn idle_connections_are_reaped_and_stop_pinning_slots() {
    // A dead client (connected, then silent) must be closed by the idle
    // reaper so it stops pinning a max_connections slot — here the cap
    // is 1, so the reaper is the only thing letting the next client in.
    let net = NetServer::bind(
        "127.0.0.1:0",
        batcher(16, Duration::from_millis(1), 2, 1),
        NetOpts {
            max_connections: 1,
            idle_timeout: Duration::from_millis(100),
            ..NetOpts::default()
        },
    )
    .expect("bind");
    let addr = net.local_addr();
    let mut dead = TcpStream::connect(addr).expect("connect");
    // A served request proves the connection is registered (and that
    // activity resets the idle clock rather than counting from accept).
    send_request(&mut dead, &track(64, 5)).expect("send");
    assert_eq!(read_response(&mut dead).expect("recv").0, status::OK);
    // Go silent. The reaper closes the connection from the server side.
    dead.set_read_timeout(Some(Duration::from_secs(5)))
        .expect("timeout");
    let mut rest = [0u8; 1];
    assert_eq!(
        dead.read(&mut rest).expect("server closes the idle conn"),
        0,
        "reaper sends EOF, not data"
    );
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    while net.connections() > 0 && std::time::Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(net.connections(), 0, "idle connection released its slot");
    // The freed slot admits a live client.
    let mut live = TcpStream::connect(addr).expect("connect #2");
    send_request(&mut live, &track(64, 6)).expect("send");
    assert_eq!(read_response(&mut live).expect("recv").0, status::OK);
    drop(live);
    let (_, stats) = net.shutdown();
    assert_eq!(stats.connections_idle_closed, 1);
    assert_eq!(stats.connections_rejected, 0, "nobody hit the cap");
    assert_eq!(stats.requests_ok, 2);
}

#[test]
fn graceful_drain_answers_requests_in_flight_at_shutdown() {
    // A long batching window parks the request in the dispatcher; the
    // shutdown path must flush it and deliver the response before the
    // connection is torn down — no accepted request is ever lost.
    let net = NetServer::bind(
        "127.0.0.1:0",
        batcher(16, Duration::from_millis(300), 8, 1),
        NetOpts::default(),
    )
    .expect("bind");
    let addr = net.local_addr();
    let client = std::thread::spawn(move || {
        let mut stream = TcpStream::connect(addr).expect("connect");
        send_request(&mut stream, &track(90, 17)).expect("send");
        let (code, _, payload) = read_response(&mut stream).expect("recv");
        (code, payload)
    });
    // Let the request reach the dispatcher, then shut down around it.
    std::thread::sleep(Duration::from_millis(100));
    let (metrics, stats) = net.shutdown();
    let (code, payload) = client.join().expect("client");
    assert_eq!(code, status::OK, "in-flight request answered during drain");
    assert_eq!(payload.expect("payload").0.len(), 90);
    assert_eq!(stats.requests_ok, 1);
    assert_eq!(metrics.completed, 1);
}
