//! Net-level plan conformance (DESIGN.md §7c): fused/arena execution is
//! **bit-identical** (`f32::to_bits`) to the per-layer reference
//! pipeline across {f32, bf16} × {batch, grid} × {1, 4 threads} ×
//! {masked, unmasked}, both directly on [`AtacWorksNet`] and through the
//! serving engine's `fuse` knob — and the arena holds strictly less
//! activation memory than the per-layer pipeline for both the tiny and
//! paper configs. Runs under `CONV1D_FORCE_ISA` in the isa-conformance
//! CI job, so the fused strips are exercised on every SIMD tier.

use dilconv1d::conv1d::{Backend, Partition};
use dilconv1d::machine::Precision;
use dilconv1d::model::{AtacWorksNet, NetConfig, NetPlan, Tensor};
use dilconv1d::serve::{BucketSet, EngineOpts, InferenceEngine, StreamingSession};
use dilconv1d::util::rng::Rng;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn track(w: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..w).map(|_| rng.poisson(0.7) as f32).collect()
}

fn batch(n: usize, w: usize, seed: u64) -> Tensor {
    let mut rng = Rng::new(seed);
    let data = (0..n * w).map(|_| rng.poisson(0.3) as f32).collect();
    Tensor::from_vec(data, n, 1, w)
}

fn configured(
    cfg: NetConfig,
    precision: Precision,
    partition: Partition,
    threads: usize,
) -> AtacWorksNet {
    let mut net = AtacWorksNet::init(cfg, 7);
    net.set_backend(Backend::Brgemm, threads);
    net.set_precision(precision);
    net.set_partition(partition);
    net
}

#[test]
fn netplan_matches_per_layer_reference_across_the_matrix() {
    let cfg = NetConfig::tiny();
    let (n, w) = (3usize, 160usize);
    let x = batch(n, w, 3);
    let widths = [150usize, 96, 133];
    for precision in [Precision::F32, Precision::Bf16] {
        for partition in [Partition::Batch, Partition::Grid] {
            for threads in [1usize, 4] {
                let tag = format!("{precision:?}/{partition:?}/t{threads}");
                let mut reference = configured(cfg, precision, partition, threads);
                reference.set_netplan(false);
                let (den_want, log_want, _) = reference.forward(&x, false);
                let (mden_want, mlog_want) = reference.infer_masked(&x, &widths);
                for fuse in [true, false] {
                    let mut planned = configured(cfg, precision, partition, threads);
                    planned.set_fuse(fuse);
                    let (den, log, _) = planned.forward(&x, false);
                    assert_eq!(
                        bits(&den.data),
                        bits(&den_want.data),
                        "{tag} fuse={fuse}: denoised"
                    );
                    assert_eq!(
                        bits(&log.data),
                        bits(&log_want.data),
                        "{tag} fuse={fuse}: logits"
                    );
                    let (mden, mlog) = planned.infer_masked(&x, &widths);
                    assert_eq!(
                        bits(&mden.data),
                        bits(&mden_want.data),
                        "{tag} fuse={fuse}: masked denoised"
                    );
                    assert_eq!(
                        bits(&mlog.data),
                        bits(&mlog_want.data),
                        "{tag} fuse={fuse}: masked logits"
                    );
                    if fuse {
                        assert!(
                            planned.netplan().expect("plan built").fused_active(),
                            "{tag}: fusion should engage on the BRGEMM backend"
                        );
                    }
                }
            }
        }
    }
}

fn engine(params: &[f32], precision: Precision, fuse: bool) -> InferenceEngine {
    InferenceEngine::new(
        NetConfig::tiny(),
        params,
        EngineOpts {
            buckets: BucketSet::new(&[128, 256]).expect("widths"),
            max_batch: 2,
            cache_capacity: 2,
            precision,
            fuse,
            ..EngineOpts::default()
        },
    )
    .expect("engine")
}

#[test]
fn engine_bits_are_identical_with_fusion_on_and_off() {
    let params = AtacWorksNet::init(NetConfig::tiny(), 5).pack_params();
    for precision in [Precision::F32, Precision::Bf16] {
        let mut fused = engine(&params, precision, true);
        let mut unfused = engine(&params, precision, false);
        for (i, w) in [100usize, 128, 200, 61].into_iter().enumerate() {
            let r = track(w, 40 + i as u64);
            let a = fused.infer_one(&r).expect("fused");
            let b = unfused.infer_one(&r).expect("unfused");
            assert_eq!(a, b, "{precision:?} width {w}: fuse knob changed bits");
        }
    }
}

#[test]
fn streamed_bits_are_identical_with_fusion_on_and_off() {
    let params = AtacWorksNet::init(NetConfig::tiny(), 5).pack_params();
    let signal = track(700, 9);
    let mut outs = Vec::new();
    for fuse in [true, false] {
        let mut e = engine(&params, Precision::F32, fuse);
        let mut s = StreamingSession::new(&mut e, 256).expect("session");
        outs.push(s.infer(&signal).expect("stream"));
    }
    assert_eq!(outs[0], outs[1], "stream-level fuse knob changed bits");
}

#[test]
fn arena_activation_bytes_stay_below_the_per_layer_sum() {
    // Tiny config, serving shape: warm builds the plan.
    let cfg = NetConfig::tiny();
    let mut net = AtacWorksNet::init(cfg, 1);
    net.set_inference(true);
    net.warm(4, 256).expect("warm");
    let plan = net.netplan().expect("warm built the net plan");
    assert!(plan.fused_active());
    let (arena, per_layer) = (
        plan.activation_bytes(),
        NetPlan::per_layer_activation_bytes(&cfg, 4, 256),
    );
    assert!(
        arena < per_layer,
        "tiny: arena {arena} B must stay below the per-layer sum {per_layer} B"
    );
    // Paper config (25 layers): the gap is the whole point — the live
    // set never exceeds 3 values while the per-layer pipeline holds 25.
    let paper = NetConfig::default();
    let pnet = AtacWorksNet::zeros(paper);
    for fuse in [true, false] {
        let plan = NetPlan::build(paper, &pnet.convs, 1, 4992, fuse);
        let (arena, per_layer) = (
            plan.activation_bytes(),
            NetPlan::per_layer_activation_bytes(&paper, 1, 4992),
        );
        assert!(
            arena < per_layer,
            "paper fuse={fuse}: arena {arena} B vs per-layer {per_layer} B"
        );
    }
}
