//! Zero-allocation guarantee of the serving steady state (ISSUE 7): a
//! **warmed** `infer_batch` call performs no heap allocations beyond the
//! returned [`InferOutput`]s. Every buffer a chunk touches is owned by
//! the bucket entry — input staging, the row-width vector, both head
//! tensors, and the net plan's activation arena — and request grouping
//! reuses an engine-held scratch instead of per-call maps.
//!
//! Verified with a counting `#[global_allocator]` (the
//! `plan_alloc.rs` / `wire_alloc.rs` pattern). One `#[test]` per file so
//! no concurrent test allocates inside a measurement window; the MINIMUM
//! over retries absorbs stray runtime allocations.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use dilconv1d::model::{AtacWorksNet, NetConfig};
use dilconv1d::serve::{BucketSet, EngineOpts, InferenceEngine};
use dilconv1d::util::rng::Rng;

struct CountingAllocator;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::SeqCst);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::SeqCst);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::SeqCst);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAllocator = CountingAllocator;

/// Allocation count of `f`, minimum over retries (see `plan_alloc.rs`).
fn allocs_during(mut f: impl FnMut()) -> usize {
    let mut min = usize::MAX;
    for _ in 0..3 {
        let before = ALLOCS.load(Ordering::SeqCst);
        f();
        let delta = ALLOCS.load(Ordering::SeqCst) - before;
        min = min.min(delta);
        if min == 0 {
            break;
        }
    }
    min
}

#[test]
fn warmed_infer_batch_allocates_only_the_returned_outputs() {
    let cfg = NetConfig::tiny();
    let params = AtacWorksNet::init(cfg, 5).pack_params();
    let mut engine = InferenceEngine::new(
        cfg,
        &params,
        EngineOpts {
            buckets: BucketSet::new(&[128, 256]).expect("widths"),
            max_batch: 2,
            threads: 1, // single worker: the strictly bounded configuration
            cache_capacity: 2,
            ..EngineOpts::default()
        },
    )
    .expect("engine");
    engine.warm().expect("warm");

    let mut rng = Rng::new(9);
    let reqs: Vec<Vec<f32>> = [100usize, 128, 200, 60]
        .iter()
        .map(|&w| (0..w).map(|_| rng.poisson(0.7) as f32).collect())
        .collect();
    let refs: Vec<&[f32]> = reqs.iter().map(|r| r.as_slice()).collect();

    // Warm-up call: grows the engine's grouping scratch to this batch
    // size and proves the warmed buckets serve without plan builds.
    let first = engine.infer_batch(&refs).expect("warm-up call");
    assert_eq!(first.len(), refs.len());
    drop(first);

    // Allowed allocations: the result vector, its Option staging twin,
    // and the two per-request output vectors — nothing else. The model
    // execution itself (arena, staging, widths, strips) is entirely
    // entry-owned and must contribute zero.
    let budget = 2 + 2 * refs.len();
    let allocs = allocs_during(|| {
        let out = engine.infer_batch(&refs).expect("warmed infer_batch");
        std::hint::black_box(&out);
    });
    assert!(
        allocs <= budget,
        "warmed infer_batch performed {allocs} heap allocations; only the \
         returned outputs (<= {budget}) are allowed"
    );
    // No plan was built or rebuilt while measuring.
    let (_, misses) = engine.cache_stats();
    assert_eq!(misses, 2, "both buckets built exactly once, at warm time");
}
