//! Fuzz-style property tests for [`WireParser`] (DESIGN.md §7b/§7d).
//!
//! The parser sits directly on attacker-controlled bytes, so its
//! contract is tested adversarially: for *arbitrary* byte streams —
//! seeded-random storms, junk biased to get deep into header
//! validation, and valid frames — delivered at *every* fragmentation,
//! the parser must
//!
//! * never panic (it is the process's first line of defence),
//! * always make progress (each step consumes input, or is a
//!   frame-`End`, or is an error the caller handles by `reset()`),
//! * never claim to consume more bytes than it was offered,
//! * only report `NeedMore` once the offered chunk is fully drained,
//! * and reassemble valid frames bit-exactly regardless of how the
//!   bytes were split across reads.
//!
//! No external fuzzer: the in-tree seeded [`Rng`] drives generation, so
//! every failure is reproducible from the printed seed.

use dilconv1d::serve::net::wire::{
    encode_request_header, encode_request_header_with_deadline, RequestHeader, WireError,
    WireEvent, WireParser, REQ_HEADER_LEN,
};
use dilconv1d::util::rng::Rng;

/// Feed `bytes` to a fresh parser in `frag`-byte reads, enforcing the
/// safety invariants on every step. Errors are handled the way the
/// frontend handles them — `reset()`, then resync by skipping one byte.
/// Returns `(events, errors)` seen.
fn drive(bytes: &[u8], frag: usize, max_width: usize) -> (usize, usize) {
    let mut parser = WireParser::new(max_width);
    let mut pos = 0usize;
    let mut steps = 0usize;
    let cap = 8 * bytes.len() + 1024;
    let (mut events, mut errors) = (0usize, 0usize);
    while pos < bytes.len() {
        let end = pos.saturating_add(frag).min(bytes.len());
        let mut chunk = &bytes[pos..end];
        loop {
            steps += 1;
            assert!(
                steps <= cap,
                "no termination: {steps} steps over {} bytes (frag {frag})",
                bytes.len()
            );
            match parser.pull(chunk) {
                Ok((n, ev)) => {
                    assert!(
                        n <= chunk.len(),
                        "consumed {n} of a {}-byte chunk",
                        chunk.len()
                    );
                    events += 1;
                    chunk = &chunk[n..];
                    match ev {
                        WireEvent::NeedMore => {
                            assert!(
                                chunk.is_empty(),
                                "NeedMore left {} bytes unread",
                                chunk.len()
                            );
                            break;
                        }
                        WireEvent::Payload(b) => {
                            assert!(!b.is_empty() && b.len() % 4 == 0);
                        }
                        WireEvent::Header(h) => {
                            assert!(h.width > 0 && h.width <= max_width);
                        }
                        WireEvent::PayloadSplit(_) | WireEvent::End => {}
                    }
                    if chunk.is_empty() {
                        break;
                    }
                }
                Err(_) => {
                    errors += 1;
                    parser.reset();
                    // Framing is lost; skip one byte and rescan.
                    match chunk.split_first() {
                        Some((_, rest)) => chunk = rest,
                        None => break,
                    }
                    if chunk.is_empty() {
                        break;
                    }
                }
            }
        }
        pos = end;
    }
    (events, errors)
}

const FRAGMENTATIONS: [usize; 8] = [1, 2, 3, 5, 8, 13, 64, usize::MAX];

fn random_bytes(rng: &mut Rng, len: usize) -> Vec<u8> {
    (0..len).map(|_| (rng.next_u64() & 0xff) as u8).collect()
}

/// A valid frame (header + payload bytes), plus its expected parse.
fn valid_frame(rng: &mut Rng) -> (Vec<u8>, RequestHeader, Vec<u8>) {
    let width = 1 + rng.below(64);
    let flags = (rng.next_u64() & 0xff) as u8;
    // Finite payload values so the f32 round trip through
    // `PayloadSplit` is trivially bit-stable.
    let payload: Vec<u8> = (0..width)
        .flat_map(|_| (rng.poisson(1.3) as f32).to_le_bytes())
        .collect();
    let (hdr, deadline_ms) = if rng.chance(0.5) {
        let d = (rng.next_u64() & 0xffff) as u16;
        (encode_request_header_with_deadline(width as u32, flags, d), d)
    } else {
        (encode_request_header(width as u32, flags), 0)
    };
    let mut bytes = hdr.to_vec();
    bytes.extend_from_slice(&payload);
    let want = RequestHeader {
        version: hdr[2],
        flags,
        dtype: hdr[4],
        deadline_ms,
        width,
    };
    (bytes, want, payload)
}

#[test]
fn arbitrary_byte_storms_never_panic_and_always_terminate() {
    for seed in 0..24u64 {
        let mut rng = Rng::new(0xF0_22 + seed);
        let len = 64 + rng.below(3000);
        let bytes = random_bytes(&mut rng, len);
        for &frag in &FRAGMENTATIONS {
            drive(&bytes, frag, 1 << 12);
        }
    }
}

/// Junk biased to survive the early header checks (magic, then magic +
/// version, …) drives the parser deep into validation and, sometimes,
/// into bogus-but-legal payload states. Same invariants must hold.
#[test]
fn adversarial_near_miss_headers_never_panic() {
    for seed in 0..24u64 {
        let mut rng = Rng::new(0xBAD_C0DE + seed);
        let mut bytes = Vec::new();
        for _ in 0..40 {
            match rng.below(4) {
                0 => bytes.extend_from_slice(&valid_frame(&mut rng).0),
                1 => {
                    // Magic + random remainder of a header.
                    bytes.extend_from_slice(b"DC");
                    let tail = random_bytes(&mut rng, REQ_HEADER_LEN - 2);
                    bytes.extend_from_slice(&tail);
                }
                2 => {
                    // Magic + valid version + random remainder — gets
                    // past version into dtype/width validation.
                    bytes.extend_from_slice(b"DC");
                    bytes.push(if rng.chance(0.5) { 1 } else { 2 });
                    let tail = random_bytes(&mut rng, REQ_HEADER_LEN - 3);
                    bytes.extend_from_slice(&tail);
                }
                _ => {
                    let n = 1 + rng.below(40);
                    let junk = random_bytes(&mut rng, n);
                    bytes.extend_from_slice(&junk);
                }
            }
        }
        for &frag in &FRAGMENTATIONS {
            drive(&bytes, frag, 1 << 12);
        }
    }
}

/// A stream of only valid frames parses with zero errors at every
/// fragmentation, and the reassembled headers + payload bytes are
/// exactly what was encoded — whether a sample arrived whole
/// (`Payload`) or split across reads (`PayloadSplit`).
#[test]
fn valid_streams_reassemble_bit_exactly_at_every_fragmentation() {
    let mut rng = Rng::new(0x60_0D);
    let mut bytes = Vec::new();
    let mut want: Vec<(RequestHeader, Vec<u8>)> = Vec::new();
    for _ in 0..12 {
        let (frame, hdr, payload) = valid_frame(&mut rng);
        bytes.extend_from_slice(&frame);
        want.push((hdr, payload));
    }
    for &frag in &FRAGMENTATIONS {
        let mut parser = WireParser::new(1 << 12);
        let mut got: Vec<(RequestHeader, Vec<u8>)> = Vec::new();
        let mut cur: Option<(RequestHeader, Vec<u8>)> = None;
        let mut pos = 0usize;
        while pos < bytes.len() {
            let end = pos.saturating_add(frag).min(bytes.len());
            let mut chunk = &bytes[pos..end];
            loop {
                let (n, ev) = parser.pull(chunk).expect("valid stream must not error");
                chunk = &chunk[n..];
                match ev {
                    WireEvent::NeedMore => break,
                    WireEvent::Header(h) => cur = Some((h, Vec::new())),
                    WireEvent::Payload(b) => {
                        cur.as_mut().expect("payload after header").1.extend(b)
                    }
                    WireEvent::PayloadSplit(v) => cur
                        .as_mut()
                        .expect("split after header")
                        .1
                        .extend(v.to_le_bytes()),
                    WireEvent::End => got.push(cur.take().expect("end after header")),
                }
                if chunk.is_empty() {
                    break;
                }
            }
            pos = end;
        }
        // The final End may still be pending (it is emitted on the pull
        // *after* the last payload byte).
        if let (0, WireEvent::End) = parser.pull(&[]).expect("trailing end") {
            if let Some(frame) = cur.take() {
                got.push(frame);
            }
        }
        assert_eq!(got.len(), want.len(), "frag {frag}: frame count");
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(g.0, w.0, "frag {frag}: header of frame {i}");
            assert_eq!(g.1, w.1, "frag {frag}: payload bytes of frame {i}");
        }
    }
}

/// Every rejection is a typed, terminal error: the parser refuses the
/// frame, `reset()` restores it, and the very next valid frame parses
/// to completion.
#[test]
fn every_error_class_is_terminal_and_reset_recovers() {
    let cases: Vec<(Vec<u8>, WireError)> = vec![
        (
            {
                let mut h = encode_request_header(4, 0).to_vec();
                h[0] = b'X';
                h
            },
            WireError::BadMagic([b'X', b'C']),
        ),
        (
            {
                let mut h = encode_request_header(4, 0).to_vec();
                h[2] = 0;
                h
            },
            WireError::BadVersion(0),
        ),
        (
            {
                let mut h = encode_request_header(4, 0).to_vec();
                h[2] = 77;
                h
            },
            WireError::BadVersion(77),
        ),
        (
            {
                let mut h = encode_request_header(4, 0).to_vec();
                h[4] = 9;
                h
            },
            WireError::BadDtype(9),
        ),
        (
            encode_request_header(0, 0).to_vec(),
            WireError::ZeroWidth,
        ),
        (
            encode_request_header(5000, 0).to_vec(),
            WireError::WidthTooLarge {
                width: 5000,
                max: 4096,
            },
        ),
    ];
    for (bad, want) in cases {
        let mut parser = WireParser::new(4096);
        let got = parser.pull(&bad).expect_err("must reject");
        assert_eq!(got, want);
        parser.reset();
        // Recovery: a full valid frame parses cleanly after the reset.
        let mut rng = Rng::new(1);
        let (frame, hdr, _) = valid_frame(&mut rng);
        let (n, ev) = parser.pull(&frame).expect("header after reset");
        assert_eq!(n, REQ_HEADER_LEN);
        assert_eq!(ev, WireEvent::Header(hdr));
        let (n, ev) = parser.pull(&frame[REQ_HEADER_LEN..]).expect("payload");
        assert_eq!(n, frame.len() - REQ_HEADER_LEN);
        assert!(matches!(ev, WireEvent::Payload(_)));
        assert!(matches!(parser.pull(&[]), Ok((0, WireEvent::End))));
    }
}
