//! ISA-dispatch and 2D-partition lockdown (ISSUE 4 acceptance criteria).
//!
//! * Every available micro-kernel ISA (scalar / AVX2+FMA / AVX-512F) must
//!   produce **bit-identical** BRGEMM outputs — across the n = 64 fast
//!   path, remainder widths (n < 64), odd k, row-4 tails (m % 4 ≠ 0),
//!   empty batch reductions and both β values. The f32/bf16 kernels all
//!   issue the same fused multiply-add per element in the same order, and
//!   the int8 kernels accumulate exactly in i32; this suite is what keeps
//!   that true.
//! * Grid (2D batch × width-block) partitioning must be bit-exact against
//!   batch partitioning through the full plan API, mirroring
//!   `multithreaded_equals_single`.
//! * The autotune cache key must carry the active ISA, so entries
//!   recorded under one ISA are never served under another.

use dilconv1d::conv1d::bf16::to_bf16;
use dilconv1d::conv1d::brgemm::{brgemm_bf16_with, brgemm_f32_with, brgemm_i8_with};
use dilconv1d::conv1d::quant::{absmax, scale_from_absmax};
use dilconv1d::conv1d::simd::{active, Isa, MicroKernelSet};
use dilconv1d::conv1d::test_util::rnd;
use dilconv1d::conv1d::{Autotuner, ConvParams, ConvPlan, Partition, PostOps};
use dilconv1d::machine::Precision;

/// The kernel-shape grid: (m, n, k, l_br) covering the n=64 fast path,
/// ragged tails, odd k, m % 4 ≠ 0, single-tap and empty reductions.
const SHAPES: &[(usize, usize, usize, usize)] = &[
    (15, 64, 15, 51), // AtacWorks block (row-4 + 3 tail rows)
    (8, 64, 16, 4),   // multiple-of-4 rows
    (3, 64, 1, 2),    // k = 1, tail rows only
    (5, 64, 7, 3),    // odd k, odd m
    (64, 64, 64, 5),  // Fig. 5 block
    (7, 48, 11, 5),   // remainder width n < 64
    (2, 31, 9, 7),    // remainder width, odd everything
    (1, 1, 1, 1),     // degenerate
    (6, 64, 15, 0),   // empty batch reduction (l_br = 0)
];

fn run_f32(
    set: &MicroKernelSet,
    (m, n, k, lbr): (usize, usize, usize, usize),
    beta_zero: bool,
) -> Vec<f32> {
    let a = rnd(lbr.max(1) * m * k, 0xA0 + m as u64);
    let b = rnd(lbr.max(1) * k * n, 0xB0 + n as u64);
    let a_offs: Vec<usize> = (0..lbr).map(|i| i * m * k).collect();
    let b_offs: Vec<usize> = (0..lbr).map(|i| i * k * n).collect();
    let mut c = rnd(m * n, 0xC0 + k as u64); // non-zero C exercises β = 1
    brgemm_f32_with(set, &a, &a_offs, k, &b, &b_offs, n, &mut c, n, m, n, k, beta_zero);
    c
}

fn run_bf16(
    set: &MicroKernelSet,
    (m, n, k, lbr): (usize, usize, usize, usize),
    beta_zero: bool,
) -> Vec<f32> {
    let a = to_bf16(&rnd(lbr.max(1) * m * k, 0xD0 + m as u64));
    let b = to_bf16(&rnd(lbr.max(1) * k * n, 0xE0 + n as u64));
    let a_offs: Vec<usize> = (0..lbr).map(|i| i * m * k).collect();
    let b_offs: Vec<usize> = (0..lbr).map(|i| i * k * n).collect();
    let mut c = rnd(m * n, 0xF0 + k as u64);
    brgemm_bf16_with(set, &a, &a_offs, k, &b, &b_offs, n, &mut c, n, m, n, k, beta_zero);
    c
}

fn run_i8(
    set: &MicroKernelSet,
    (m, n, k, lbr): (usize, usize, usize, usize),
    beta_zero: bool,
) -> Vec<i32> {
    // rnd() is in [-0.5, 0.5): ×254 spans the full i8 range.
    let q = |v: Vec<f32>| -> Vec<i8> { v.iter().map(|x| (x * 254.0).round() as i8).collect() };
    let a = q(rnd(lbr.max(1) * m * k, 0x10 + m as u64));
    let b = q(rnd(lbr.max(1) * k * n, 0x20 + n as u64));
    let a_offs: Vec<usize> = (0..lbr).map(|i| i * m * k).collect();
    let b_offs: Vec<usize> = (0..lbr).map(|i| i * k * n).collect();
    let mut c: Vec<i32> = (0..m * n).map(|i| i as i32 % 13 - 6).collect();
    brgemm_i8_with(set, &a, &a_offs, k, &b, &b_offs, n, &mut c, n, m, n, k, beta_zero);
    c
}

/// The vector ISAs this host + build can actually run (scalar excluded).
fn available_vector_isas() -> Vec<&'static MicroKernelSet> {
    [Isa::Avx2, Isa::Avx512]
        .into_iter()
        .filter(|&isa| MicroKernelSet::for_isa(isa).isa() == isa)
        .map(MicroKernelSet::for_isa)
        .collect()
}

#[test]
fn f32_kernels_bit_identical_across_isas() {
    let scalar = MicroKernelSet::for_isa(Isa::Scalar);
    let vectors = available_vector_isas();
    if vectors.is_empty() {
        eprintln!("no vector ISA available on this host/build; scalar-only lockdown");
    }
    for &shape in SHAPES {
        for beta_zero in [true, false] {
            let want = run_f32(scalar, shape, beta_zero);
            for set in &vectors {
                let got = run_f32(set, shape, beta_zero);
                assert_eq!(
                    got,
                    want,
                    "{} vs scalar at {shape:?} beta_zero={beta_zero}",
                    set.isa()
                );
            }
        }
    }
}

#[test]
fn bf16_kernels_bit_identical_across_isas() {
    let scalar = MicroKernelSet::for_isa(Isa::Scalar);
    let vectors = available_vector_isas();
    for &shape in SHAPES {
        for beta_zero in [true, false] {
            let want = run_bf16(scalar, shape, beta_zero);
            for set in &vectors {
                let got = run_bf16(set, shape, beta_zero);
                assert_eq!(
                    got,
                    want,
                    "{} vs scalar at {shape:?} beta_zero={beta_zero}",
                    set.isa()
                );
            }
        }
    }
}

#[test]
fn i8_kernels_bit_identical_across_isas() {
    // Int8 accumulates exactly in i32, so every ISA level must agree not
    // just bit-for-bit but *by construction* — any difference is a bug in
    // a widened-multiply lane path.
    let scalar = MicroKernelSet::for_isa(Isa::Scalar);
    let vectors = available_vector_isas();
    for &shape in SHAPES {
        for beta_zero in [true, false] {
            let want = run_i8(scalar, shape, beta_zero);
            for set in &vectors {
                let got = run_i8(set, shape, beta_zero);
                assert_eq!(
                    got,
                    want,
                    "{} vs scalar at {shape:?} beta_zero={beta_zero}",
                    set.isa()
                );
            }
        }
    }
}

#[test]
fn dispatched_process_set_matches_scalar_bit_exact() {
    // Whatever `active()` resolved to (env override or detection), the
    // production entry points must agree with the scalar floor.
    let scalar = MicroKernelSet::for_isa(Isa::Scalar);
    for &shape in SHAPES {
        assert_eq!(
            run_f32(active(), shape, true),
            run_f32(scalar, shape, true),
            "active ISA {} diverges at {shape:?}",
            active().isa()
        );
    }
}

#[test]
fn grid_partition_plan_bit_exact_vs_batch() {
    // Mirrors `multithreaded_equals_single` across the partition axis:
    // every kernel that supports the grid, N ∈ {1, 3}, ragged Q, fused
    // post-ops included. Forward and backward-data are bit-exact;
    // backward-weight (re-associated reduction) agrees to tolerance.
    for name in ["brgemm", "bf16", "i8"] {
        for &(n, threads) in &[(1usize, 8usize), (3, 4)] {
            let p = ConvParams::new(n, 5, 7, 500, 9, 4).unwrap(); // Q % 64 != 0
            let wt = rnd(p.k * p.c * p.s, 1);
            let x = rnd(p.n * p.c * p.w, 2);
            let bias = rnd(p.k, 3);
            let gout = rnd(p.n * p.k * p.q(), 4);
            let sx = scale_from_absmax(absmax(&x));
            let build = |partition| {
                let mut plan = ConvPlan::by_name(p, name, threads, wt.clone())
                    .unwrap()
                    .with_partition(partition)
                    .with_post_ops(PostOps::bias_relu());
                plan.set_bias(&bias);
                if name == "i8" {
                    // Without a calibrated activation scale the default
                    // (1.0) would quantize rnd() inputs to all zeros.
                    plan.set_input_scale(sx);
                }
                plan
            };
            let mut batch = build(Partition::Batch);
            let mut grid = build(Partition::Grid);
            let mut ob = vec![0.0; p.n * p.k * p.q()];
            let mut og = vec![0.0; p.n * p.k * p.q()];
            batch.execute_forward_post_into(&x, None, &mut ob);
            grid.execute_forward_post_into(&x, None, &mut og);
            assert_eq!(ob, og, "{name} N={n} t={threads}: fused forward");
            let mut gb = vec![0.0; p.n * p.c * p.w];
            let mut gg = vec![0.0; p.n * p.c * p.w];
            batch.execute_backward_data_into(&gout, &mut gb);
            grid.execute_backward_data_into(&gout, &mut gg);
            assert_eq!(gb, gg, "{name} N={n} t={threads}: backward-data");
            let mut wb = vec![0.0; p.k * p.c * p.s];
            let mut wg = vec![0.0; p.k * p.c * p.s];
            batch.execute_backward_weight_into(&gout, &x, &mut wb);
            grid.execute_backward_weight_into(&gout, &x, &mut wg);
            for (i, (a, b)) in wb.iter().zip(&wg).enumerate() {
                assert!(
                    (a - b).abs() < 1e-4 * (1.0 + b.abs()),
                    "{name} N={n} t={threads}: gw[{i}] {a} vs {b}"
                );
            }
        }
    }
}

#[test]
fn grid_partition_is_deterministic() {
    // Repeated grid executions (same plan, same threads) are bit-stable.
    let p = ConvParams::new(1, 6, 8, 700, 11, 3).unwrap();
    let wt = rnd(p.k * p.c * p.s, 7);
    let x = rnd(p.n * p.c * p.w, 8);
    let mut plan = ConvPlan::by_name(p, "brgemm", 6, wt)
        .unwrap()
        .with_partition(Partition::Grid);
    let mut o1 = vec![0.0; p.n * p.k * p.q()];
    let mut o2 = vec![0.0; p.n * p.k * p.q()];
    plan.execute_forward_into(&x, &mut o1);
    plan.execute_forward_into(&x, &mut o2);
    assert_eq!(o1, o2);
}

#[test]
fn tune_key_carries_the_active_isa_and_partition() {
    let p = ConvParams::new(1, 3, 4, 100, 5, 2).unwrap();
    let key = Autotuner::key(&p, 2, Precision::F32, Partition::Batch);
    let isa = active().isa().name();
    assert!(
        key.contains(&format!("i{isa}")),
        "key '{key}' must carry the active ISA 'i{isa}' — entries tuned \
         under one ISA must never be served under another"
    );
    // Partition flips the key too: a ranking measured under batch
    // splitting is meaningless for grid (and vice versa).
    let grid_key = Autotuner::key(&p, 2, Precision::F32, Partition::Grid);
    assert_ne!(key, grid_key);
    assert!(grid_key.ends_with("ptgrid"), "{grid_key}");
}

#[test]
fn plan_reports_isa_and_partition() {
    let p = ConvParams::new(1, 2, 3, 64, 3, 2).unwrap();
    let plan = ConvPlan::by_name(p, "brgemm", 1, vec![0.1; 3 * 2 * 3])
        .unwrap()
        .with_partition(Partition::Grid);
    assert_eq!(plan.isa(), active().isa());
    assert_eq!(plan.partition(), Partition::Grid);
    let dbg = format!("{plan:?}");
    assert!(dbg.contains("isa"), "{dbg}");
    assert!(dbg.contains("Grid"), "{dbg}");
}
