//! Integration tests of the end-to-end training stack: data pipeline →
//! native engine → coordinator → metrics, plus checkpoint round-trips and
//! backend interchangeability during training.

use dilconv1d::config::TrainConfig;
use dilconv1d::conv1d::Backend;
use dilconv1d::coordinator::{checkpoint, Trainer};
use dilconv1d::data::atacseq::TrackConfig;
use dilconv1d::data::{make_batch, Dataset};
use dilconv1d::metrics::auroc::auroc;
use dilconv1d::model::{Adam, AtacWorksNet, NetConfig, Tensor};

fn tiny_cfg() -> TrainConfig {
    TrainConfig {
        channels: 4,
        n_blocks: 1,
        filter_size: 9,
        dilation: 2,
        segment_width: 300,
        segment_pad: 30,
        train_segments: 8,
        batch_size: 2,
        epochs: 2,
        lr: 2e-3,
        ..TrainConfig::default()
    }
}

#[test]
fn full_pipeline_loss_decreases_and_auroc_improves() {
    let mut t = Trainer::new(tiny_cfg()).unwrap();
    let (mse0, _) = t.evaluate(8);
    let reports = t.train(|_| {});
    let last = reports.last().unwrap();
    assert!(last.train_loss < reports[0].train_loss);
    let (mse1, auroc1) = t.evaluate(8);
    assert!(mse1 < mse0, "val MSE should improve: {mse0} -> {mse1}");
    // With very few steps AUROC is noisy, but must be defined and ≥ ~chance.
    let a = auroc1.expect("validation has both classes");
    assert!(a > 0.4, "AUROC {a}");
}

#[test]
fn backends_train_identically() {
    // The library baseline computes the same math — same loss trajectory.
    let mut c1 = tiny_cfg();
    c1.epochs = 1;
    let mut c2 = c1.clone();
    c2.backend = Backend::Im2col;
    let r1 = Trainer::new(c1).unwrap().run_epoch(0);
    let r2 = Trainer::new(c2).unwrap().run_epoch(0);
    assert!((r1.train_loss - r2.train_loss).abs() < 1e-6 * (1.0 + r1.train_loss.abs()));
}

#[test]
fn checkpoint_roundtrip_through_trainer() {
    let dir = std::env::temp_dir().join("dilconv_integration_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("t.ckpt");
    let mut t = Trainer::new(tiny_cfg()).unwrap();
    t.run_epoch(0);
    checkpoint::save(&path, t.params()).unwrap();
    let loaded = checkpoint::load(&path).unwrap();
    assert_eq!(loaded, t.params());
    // A fresh trainer restored from the checkpoint evaluates identically.
    let mut t2 = Trainer::new(tiny_cfg()).unwrap();
    t2.set_params(loaded);
    let (m1, _) = t.evaluate(4);
    let (m2, _) = t2.evaluate(4);
    assert!((m1 - m2).abs() < 1e-9, "{m1} vs {m2}");
}

#[test]
fn trained_model_beats_untrained_on_peaks() {
    // Train briefly, then verify the peak head separates peak/background
    // better than the fresh network on held-out data.
    let cfg = NetConfig {
        channels: 6,
        n_blocks: 1,
        filter_size: 9,
        dilation: 2,
    };
    let track = TrackConfig {
        width: 400,
        pad: 40,
        ..TrackConfig::default()
    };
    let ds = Dataset::new(7, 64);
    let wp = track.padded_width();

    let mut fresh = AtacWorksNet::init(cfg, 3);
    let mut net = AtacWorksNet::init(cfg, 3);
    let mut params = net.pack_params();
    let mut opt = Adam::new(params.len(), 3e-3);
    for step in 0..25 {
        let idx = [ds.train[step % ds.train.len()], ds.train[(step + 1) % ds.train.len()]];
        let b = make_batch(&track, 7, &idx);
        let x = Tensor::from_vec(b.x, 2, 1, wp);
        let clean = Tensor::from_vec(b.clean, 2, 1, wp);
        let peaks = Tensor::from_vec(b.peaks, 2, 1, wp);
        net.unpack_params(&params);
        let (grads, _) = net.forward_backward(&x, &clean, &peaks);
        let g = net.pack_grads(&grads);
        opt.step(&mut params, &g);
    }
    net.unpack_params(&params);

    let val: Vec<u64> = ds.validation.iter().copied().take(4).collect();
    let b = make_batch(&track, 7, &val);
    let x = Tensor::from_vec(b.x.clone(), val.len(), 1, wp);
    let (_, logits_trained, _) = net.forward(&x, false);
    let (_, logits_fresh, _) = fresh.forward(&x, false);
    let a_trained = auroc(&logits_trained.data, &b.peaks).unwrap();
    let a_fresh = auroc(&logits_fresh.data, &b.peaks).unwrap();
    assert!(
        a_trained > a_fresh && a_trained > 0.6,
        "training must improve peak AUROC: fresh {a_fresh:.3} -> trained {a_trained:.3}"
    );
}

#[test]
fn epoch_shuffling_changes_batch_order_not_results_determinism() {
    let t = Trainer::new(tiny_cfg()).unwrap();
    let o0 = t.dataset.epoch_order(0);
    let o1 = t.dataset.epoch_order(1);
    assert_ne!(o0, o1);
    // Re-running the same trainer config is fully deterministic.
    let mut a = Trainer::new(tiny_cfg()).unwrap();
    let mut b = Trainer::new(tiny_cfg()).unwrap();
    let ra = a.run_epoch(0);
    let rb = b.run_epoch(0);
    assert_eq!(ra.train_loss, rb.train_loss);
    assert_eq!(a.params(), b.params());
}
