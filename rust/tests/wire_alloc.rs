//! Zero-allocation guarantee of the wire parser (ISSUE 6 acceptance
//! criterion): [`WireParser::pull`] performs **zero** heap allocations —
//! not just in steady state but from construction on. The parser is a
//! fixed-size state machine (a 12-byte scratch doubles as the split-f32
//! carry) and payload events *borrow* the caller's read buffer, so
//! nothing it does can touch the allocator.
//!
//! Verified with a counting `#[global_allocator]`. This file deliberately
//! contains a single `#[test]` so no concurrent test can allocate while a
//! window is measured; a short retry loop absorbs any one-off runtime
//! allocation that might land inside a window.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use dilconv1d::serve::net::{encode_request_header, WireEvent, WireParser};

struct CountingAllocator;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::SeqCst);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::SeqCst);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::SeqCst);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAllocator = CountingAllocator;

/// Run `f` and return the number of heap allocations it performed,
/// retrying a few times so a stray runtime allocation outside our code
/// (e.g. lazy stdio setup) cannot produce a false positive. The MINIMUM
/// over attempts is the honest count of what `f` itself allocates.
fn allocs_during(mut f: impl FnMut()) -> usize {
    let mut min = usize::MAX;
    for _ in 0..3 {
        let before = ALLOCS.load(Ordering::SeqCst);
        f();
        let delta = ALLOCS.load(Ordering::SeqCst) - before;
        min = min.min(delta);
        if min == 0 {
            break;
        }
    }
    min
}

/// Drive `frames` complete wire frames through `parser` in `chunk`-byte
/// slices (mimicking fragmented TCP reads), folding a checksum over the
/// events so nothing is optimized away. Panics on any parse error.
fn drive(parser: &mut WireParser, wire: &[u8], frames: usize, chunk: usize) -> (usize, f32) {
    let mut ends = 0usize;
    let mut sum = 0.0f32;
    while ends < frames {
        for piece in wire.chunks(chunk) {
            let mut pos = 0;
            while pos < piece.len() {
                let (used, ev) = parser.pull(&piece[pos..]).expect("valid frame");
                pos += used;
                match ev {
                    WireEvent::NeedMore => break,
                    WireEvent::Header(h) => sum += h.width as f32,
                    WireEvent::Payload(raw) => {
                        for c in raw.chunks_exact(4) {
                            sum += f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
                        }
                    }
                    WireEvent::PayloadSplit(v) => sum += v,
                    WireEvent::End => {
                        // `End` is emitted by the pull *after* the final
                        // payload byte, i.e. at the top of the next
                        // replay pass — stop right here or that pass
                        // would fold a fifth frame into the checksum.
                        ends += 1;
                        if ends == frames {
                            return (ends, sum);
                        }
                    }
                }
            }
        }
    }
    (ends, sum)
}

#[test]
fn the_wire_parser_never_allocates() {
    // One 37-sample frame (odd width: every chunk size splits an f32
    // somewhere, exercising the carry path).
    const WIDTH: usize = 37;
    let mut wire = encode_request_header(WIDTH as u32, 0).to_vec();
    for i in 0..WIDTH {
        wire.extend_from_slice(&(i as f32 * 0.5 - 3.0).to_le_bytes());
    }
    let expected_sum: f32 = WIDTH as f32 + (0..WIDTH).map(|i| i as f32 * 0.5 - 3.0).sum::<f32>();

    // Construction is allocation-free (fixed-size struct, const fn).
    let mut parser = WireParser::new(1 << 20);
    let construct = allocs_during(|| {
        let p = WireParser::new(1 << 20);
        std::hint::black_box(&p);
    });
    assert_eq!(construct, 0, "WireParser::new allocated");

    // Whole-buffer parsing and 7-byte fragmented parsing (header split
    // across pulls, payloads ending mid-f32) both stay at zero — the
    // parser holds carry bytes in its fixed scratch and hands payload
    // slices straight out of the input.
    for chunk in [wire.len(), 7, 3, 1] {
        let n = allocs_during(|| {
            let (ends, sum) = drive(&mut parser, &wire, 4, chunk);
            assert_eq!(ends, 4);
            assert!((sum - 4.0 * expected_sum).abs() < 1e-3);
        });
        assert_eq!(n, 0, "pull allocated at chunk size {chunk}");
    }
}
