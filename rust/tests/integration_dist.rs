//! Integration tests of the distributed substrate: ring all-reduce
//! (in-place and message-passing), the worker pool, topology accounting
//! and the communication model's consistency with the real byte counts.

use dilconv1d::dist::allreduce::{
    naive_allreduce, ring_allreduce, ring_allreduce_threaded, ring_bytes_per_rank,
};
use dilconv1d::dist::{CommModel, Topology, WorkerPool};
use dilconv1d::model::NetConfig;
use dilconv1d::util::rng::Rng;

#[test]
fn allreduce_at_model_gradient_size() {
    // The actual gradient length of the paper's 25-layer model.
    let len = NetConfig::default().param_count();
    let mut rng = Rng::new(1);
    for &p in &[2usize, 4, 16] {
        let base: Vec<Vec<f32>> = (0..p)
            .map(|_| (0..len).map(|_| rng.normal(0.0, 0.1) as f32).collect())
            .collect();
        let mut b1 = base.clone();
        ring_allreduce(&mut b1);
        let mut b2 = base.clone();
        naive_allreduce(&mut b2);
        let b3 = ring_allreduce_threaded(base);
        for r in 0..p {
            for i in (0..len).step_by(997) {
                assert!((b1[r][i] - b2[r][i]).abs() < 1e-4 * (1.0 + b2[r][i].abs()));
                assert!((b3[r][i] - b2[r][i]).abs() < 1e-4 * (1.0 + b2[r][i].abs()));
            }
        }
    }
}

#[test]
fn worker_pool_gradient_averaging_is_order_independent() {
    let pool = WorkerPool::new(5);
    // Each rank contributes rank-dependent gradients; mean is fixed.
    let r = pool.step(|rank| {
        let g: Vec<f32> = (0..100).map(|i| (rank * 100 + i) as f32).collect();
        (g, rank as f64)
    });
    for (i, &g) in r.grad.iter().enumerate() {
        let want: f32 = (0..5).map(|rk| (rk * 100 + i) as f32).sum::<f32>() / 5.0;
        assert!((g - want).abs() < 1e-3);
    }
    assert!((r.loss - 2.0).abs() < 1e-12);
}

#[test]
fn topology_reproduces_paper_core_accounting() {
    // Sec. 4.4: single socket reserves 1 core (27 compute);
    // Sec. 4.5: multi-socket reserves 2 (26 compute).
    assert_eq!(Topology::xeon(1).compute_cores(), 27);
    for s in [2usize, 4, 8, 16] {
        assert_eq!(Topology::xeon(s).compute_cores(), 26);
    }
    // Batch sizes from Sec. 4.5.1.
    let batches: Vec<usize> = [1usize, 2, 4, 8, 16]
        .iter()
        .map(|&s| Topology::xeon(s).paper_batch_size())
        .collect();
    assert_eq!(batches, vec![54, 52, 104, 208, 416]);
}

#[test]
fn comm_model_consistent_with_ring_bytes() {
    // The α–β model's bandwidth term must equal bytes/bandwidth for the
    // byte count the real ring implementation reports.
    let m = CommModel {
        latency: 0.0,
        bandwidth: 1e9,
    };
    let len = 1_000_000;
    for &p in &[2usize, 4, 8] {
        let t = m.ring_allreduce_secs(len, p);
        let bytes = ring_bytes_per_rank(len, p);
        assert!(
            (t - bytes as f64 / 1e9).abs() < 1e-9,
            "p={p}: model {t} vs bytes {bytes}"
        );
    }
}

#[test]
fn scaling_efficiency_of_the_modeled_collective() {
    // Ring all-reduce per-rank traffic saturates; the modeled time must
    // grow sub-linearly in rank count (this is what makes Fig. 8 linear).
    let m = CommModel::fabric();
    let len = NetConfig::default().param_count();
    let t2 = m.ring_allreduce_secs(len, 2);
    let t16 = m.ring_allreduce_secs(len, 16);
    // 8x the ranks must cost < ~4.5x the time (bandwidth term saturates,
    // latency term grows with 2(P-1)).
    assert!(t16 < 4.5 * t2, "t2={t2} t16={t16}");
}
