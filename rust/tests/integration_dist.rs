//! Integration tests of the distributed substrate: ring all-reduce
//! (in-place, message-passing, and bucket-aligned), the worker pools,
//! topology accounting, the communication model's consistency with the
//! real byte counts, and the trainer-level guarantees of the bucketed
//! overlapped path — f32 bit-identity with the monolithic path and BF16
//! mixed-precision convergence.

use dilconv1d::config::TrainConfig;
use dilconv1d::coordinator::Trainer;
use dilconv1d::dist::allreduce::{
    naive_allreduce, ring_allreduce, ring_allreduce_aligned, ring_allreduce_threaded,
    ring_bytes_per_rank,
};
use dilconv1d::dist::{
    hierarchical_allreduce, hierarchical_allreduce_aligned, BucketPlan, CommModel, Topology,
    WorkerPool,
};
use dilconv1d::machine::Precision;
use dilconv1d::model::NetConfig;
use dilconv1d::util::rng::Rng;

#[test]
fn allreduce_at_model_gradient_size() {
    // The actual gradient length of the paper's 25-layer model.
    let len = NetConfig::default().param_count();
    let mut rng = Rng::new(1);
    for &p in &[2usize, 4, 16] {
        let base: Vec<Vec<f32>> = (0..p)
            .map(|_| (0..len).map(|_| rng.normal(0.0, 0.1) as f32).collect())
            .collect();
        let mut b1 = base.clone();
        ring_allreduce(&mut b1);
        let mut b2 = base.clone();
        naive_allreduce(&mut b2);
        let b3 = ring_allreduce_threaded(base);
        for r in 0..p {
            for i in (0..len).step_by(997) {
                assert!((b1[r][i] - b2[r][i]).abs() < 1e-4 * (1.0 + b2[r][i].abs()));
                assert!((b3[r][i] - b2[r][i]).abs() < 1e-4 * (1.0 + b2[r][i].abs()));
            }
        }
    }
}

#[test]
fn worker_pool_gradient_averaging_is_order_independent() {
    let pool = WorkerPool::new(5);
    // Each rank contributes rank-dependent gradients; mean is fixed.
    let r = pool.step(|rank| {
        let g: Vec<f32> = (0..100).map(|i| (rank * 100 + i) as f32).collect();
        (g, rank as f64)
    });
    for (i, &g) in r.grad.iter().enumerate() {
        let want: f32 = (0..5).map(|rk| (rk * 100 + i) as f32).sum::<f32>() / 5.0;
        assert!((g - want).abs() < 1e-3);
    }
    assert!((r.loss - 2.0).abs() < 1e-12);
}

#[test]
fn topology_reproduces_paper_core_accounting() {
    // Sec. 4.4: single socket reserves 1 core (27 compute);
    // Sec. 4.5: multi-socket reserves 2 (26 compute).
    assert_eq!(Topology::xeon(1).compute_cores(), 27);
    for s in [2usize, 4, 8, 16] {
        assert_eq!(Topology::xeon(s).compute_cores(), 26);
    }
    // Batch sizes from Sec. 4.5.1.
    let batches: Vec<usize> = [1usize, 2, 4, 8, 16]
        .iter()
        .map(|&s| Topology::xeon(s).paper_batch_size())
        .collect();
    assert_eq!(batches, vec![54, 52, 104, 208, 416]);
}

#[test]
fn comm_model_consistent_with_ring_bytes() {
    // The α–β model's bandwidth term must equal bytes/bandwidth for the
    // byte count the real ring implementation reports.
    let m = CommModel {
        latency: 0.0,
        bandwidth: 1e9,
    };
    let len = 1_000_000;
    for &p in &[2usize, 4, 8] {
        let t = m.ring_allreduce_secs(len, p);
        let bytes = ring_bytes_per_rank(len, p);
        assert!(
            (t - bytes as f64 / 1e9).abs() < 1e-9,
            "p={p}: model {t} vs bytes {bytes}"
        );
    }
}

fn dist_cfg(sockets: usize, overlap: bool, precision: Precision) -> TrainConfig {
    TrainConfig {
        channels: 4,
        n_blocks: 1,
        filter_size: 9,
        dilation: 2,
        segment_width: 400,
        segment_pad: 40,
        train_segments: 8,
        batch_size: 4,
        epochs: 1,
        lr: 1e-3,
        sockets,
        overlap,
        precision,
        // Tiny budget → one bucket per layer for the tiny net: maximum
        // bucket-boundary coverage.
        bucket_mb: 0.0001,
        ..TrainConfig::default()
    }
}

#[test]
fn bucketed_overlapped_allreduce_is_bit_identical_to_monolithic() {
    // The overlapped path reduces completion-ordered buckets through the
    // globally-aligned ring; every element must see the exact
    // accumulation order of the monolithic post-backward ring — the
    // resulting parameter trajectory is bitwise equal.
    for sockets in [2usize, 3, 4] {
        let mut mono = Trainer::new(dist_cfg(sockets, false, Precision::F32)).unwrap();
        let mut over = Trainer::new(dist_cfg(sockets, true, Precision::F32)).unwrap();
        let rm = mono.run_epoch(0);
        let ro = over.run_epoch(0);
        assert_eq!(rm.steps, ro.steps);
        assert!(rm.steps > 0, "no steps ran at {sockets} sockets");
        assert_eq!(
            mono.params(),
            over.params(),
            "overlapped != monolithic at {sockets} sockets"
        );
        assert_eq!(rm.train_loss, ro.train_loss);
        // Overlap hides communication behind backward: the exposed part
        // never exceeds the serialized cost (and the serialized per-
        // bucket total is at least the monolithic single ring).
        assert!(ro.exposed_comm_secs <= ro.modeled_comm_secs + 1e-12);
        assert_eq!(rm.exposed_comm_secs, rm.modeled_comm_secs);
    }
}

#[test]
fn bucket_plan_covers_the_atacworks_gradient() {
    let net = NetConfig::default();
    let plan = BucketPlan::new(
        &net.layer_param_counts(),
        &net.backward_completion_order(),
        256 * 1024,
    );
    assert_eq!(plan.total_elems(), net.param_count());
    assert!(plan.n_buckets() > 1, "budget should split the gradient");
    let sum: usize = plan.elems_per_bucket().iter().sum();
    assert_eq!(sum, net.param_count());
    // Buckets reduced through the aligned ring agree with one monolithic
    // ring at the real gradient size.
    let len = net.param_count();
    let mut rng = Rng::new(3);
    let base: Vec<Vec<f32>> = (0..4)
        .map(|_| (0..len).map(|_| rng.normal(0.0, 0.1) as f32).collect())
        .collect();
    let mut want = base.clone();
    ring_allreduce(&mut want);
    for b in 0..plan.n_buckets() {
        let mut bufs: Vec<Vec<f32>> = base.iter().map(|full| plan.gather(b, full)).collect();
        ring_allreduce_aligned(&mut bufs, &plan.bucket(b).regions, len);
        for (rank, buf) in bufs.iter().enumerate() {
            assert_eq!(
                *buf,
                plan.gather(b, &want[rank]),
                "bucket {b} rank {rank} diverged"
            );
        }
    }
}

/// The topology matrix the CI runs this binary under via
/// `CONV1D_TOPOLOGY` — exercised here explicitly as well, so a plain
/// `cargo test` covers every shape without relying on the environment
/// (env mutation in tests is racy; CI layers the env override on top).
const TOPOLOGY_MATRIX: [Topology; 3] = [
    Topology {
        sockets: 1,
        cores_per_socket: 8,
    },
    Topology {
        sockets: 2,
        cores_per_socket: 4,
    },
    Topology {
        sockets: 4,
        cores_per_socket: 2,
    },
];

#[test]
fn hierarchical_allreduce_is_bit_identical_at_model_gradient_size() {
    // The NUMA-hierarchical reduction must be indistinguishable — at the
    // f32 bit level — from the monolithic global ring at the real
    // gradient length, for every CI-matrix shape, monolithic and
    // bucket-aligned alike.
    let net = NetConfig::default();
    let len = net.param_count();
    let mut rng = Rng::new(11);
    let base: Vec<Vec<f32>> = (0..4)
        .map(|_| (0..len).map(|_| rng.normal(0.0, 0.1) as f32).collect())
        .collect();
    let mut want = base.clone();
    ring_allreduce(&mut want);
    let plan = BucketPlan::new(
        &net.layer_param_counts(),
        &net.backward_completion_order(),
        256 * 1024,
    );
    for topo in TOPOLOGY_MATRIX {
        let placement = topo.placement(base.len());
        // Monolithic gradient.
        let mut bufs = base.clone();
        hierarchical_allreduce(&mut bufs, placement);
        for (rank, (got, exp)) in bufs.iter().zip(&want).enumerate() {
            for (i, (g, e)) in got.iter().zip(exp).enumerate() {
                assert_eq!(
                    g.to_bits(),
                    e.to_bits(),
                    "monolithic: rank {rank} elem {i} diverged under {topo}"
                );
            }
        }
        // Bucketed gradients on the same global grid.
        for b in 0..plan.n_buckets() {
            let mut bufs: Vec<Vec<f32>> = base.iter().map(|full| plan.gather(b, full)).collect();
            hierarchical_allreduce_aligned(&mut bufs, &plan.bucket(b).regions, len, placement);
            for (rank, buf) in bufs.iter().enumerate() {
                assert_eq!(
                    *buf,
                    plan.gather(b, &want[rank]),
                    "bucket {b} rank {rank} diverged under {topo}"
                );
            }
        }
    }
}

#[test]
fn numa_placed_training_matches_flat_at_every_matrix_shape() {
    // End-to-end: a trainer whose replicas are socket-placed and whose
    // gradients take the hierarchical path must produce the exact same
    // parameter bits as the flat single-socket layout — for both the
    // monolithic and the bucketed+overlapped all-reduce.
    for overlap in [false, true] {
        let cfg = dist_cfg(4, overlap, Precision::F32);
        let mut flat = Trainer::with_topology(cfg.clone(), TOPOLOGY_MATRIX[0]).unwrap();
        let r_flat = flat.run_epoch(0);
        assert!(r_flat.steps > 0);
        for topo in &TOPOLOGY_MATRIX[1..] {
            let mut placed = Trainer::with_topology(cfg.clone(), *topo).unwrap();
            let r = placed.run_epoch(0);
            assert_eq!(r.steps, r_flat.steps);
            assert_eq!(
                flat.params(),
                placed.params(),
                "placed params diverged from flat under {topo} (overlap={overlap})"
            );
            assert_eq!(r.train_loss, r_flat.train_loss);
        }
    }
}

#[test]
fn bf16_training_converges_close_to_f32() {
    // The paper's BF16 recipe (bf16 working weights + kernels, FP32
    // master + gradient accumulation) must still learn: loss decreases
    // over 3 epochs and lands near the f32 run on the same data.
    let mut f32_cfg = dist_cfg(1, false, Precision::F32);
    f32_cfg.epochs = 3;
    let mut bf16_cfg = dist_cfg(1, false, Precision::Bf16);
    bf16_cfg.epochs = 3;
    let f32_reports = Trainer::new(f32_cfg).unwrap().train(|_| {});
    let bf16_reports = Trainer::new(bf16_cfg).unwrap().train(|_| {});
    let (f0, fl) = (
        f32_reports.first().unwrap().train_loss,
        f32_reports.last().unwrap().train_loss,
    );
    let (b0, bl) = (
        bf16_reports.first().unwrap().train_loss,
        bf16_reports.last().unwrap().train_loss,
    );
    assert!(bl < b0, "bf16 loss did not decrease: {b0} -> {bl}");
    assert!(fl < f0, "f32 loss did not decrease: {f0} -> {fl}");
    // Same data, same schedule: bf16 tracks f32 within a loose band.
    assert!(
        (bl - fl).abs() <= 0.2 * fl.abs() + 0.05,
        "bf16 final loss {bl} too far from f32 {fl}"
    );
}

#[test]
fn scaling_efficiency_of_the_modeled_collective() {
    // Ring all-reduce per-rank traffic saturates; the modeled time must
    // grow sub-linearly in rank count (this is what makes Fig. 8 linear).
    let m = CommModel::fabric();
    let len = NetConfig::default().param_count();
    let t2 = m.ring_allreduce_secs(len, 2);
    let t16 = m.ring_allreduce_secs(len, 16);
    // 8x the ranks must cost < ~4.5x the time (bandwidth term saturates,
    // latency term grows with 2(P-1)).
    assert!(t16 < 4.5 * t2, "t2={t2} t16={t16}");
}
