//! Property tests of the int8 quantization tier (DESIGN.md §5d).
//!
//! Offline build, no proptest: properties are checked over many
//! deterministically-random cases from a seeded PRNG, like
//! `prop_conv.rs`. The four locked invariants:
//!
//! * round-trip: `|v − scale·quantize(v)| ≤ scale/2` for unsaturated `v`
//! * saturation: the clamp lands exactly on ±127, never wraps
//! * all-zero channels get the unit-scale guard (no 0/0 in dequant)
//! * i8 plan outputs are **bit-identical** across `Partition::{Batch,
//!   Grid}` and thread counts — exact i32 accumulation makes the
//!   reduction order irrelevant, so this holds by construction.

use dilconv1d::conv1d::quant::{absmax, channel_scales_kcs, quantize, scale_from_absmax};
use dilconv1d::conv1d::test_util::rnd;
use dilconv1d::conv1d::{ConvParams, ConvPlan, Partition, PostOps};
use dilconv1d::util::rng::Rng;

#[test]
fn prop_round_trip_error_at_most_half_scale() {
    let mut rng = Rng::new(0x18);
    for case in 0..200u64 {
        let scale = 1e-3 + rng.below(1000) as f32 * 1e-3;
        // Any value inside the representable range round-trips to
        // within half a quantization step.
        let v = (rnd(1, case)[0] * 2.0) * scale * 127.0;
        let q = quantize(v, scale);
        assert!((-127..=127).contains(&(q as i32)), "case {case}: q={q}");
        if v.abs() <= scale * 127.0 {
            let back = scale * q as f32;
            assert!(
                (v - back).abs() <= scale / 2.0 + 1e-6,
                "case {case}: v={v} scale={scale} back={back}"
            );
        }
    }
}

#[test]
fn prop_clamp_saturates_at_plus_minus_127() {
    for v in [1e6f32, 300.0, 127.6] {
        assert_eq!(quantize(v, 1.0), 127);
        assert_eq!(quantize(-v, 1.0), -127);
    }
    // The i8 value -128 is never produced: symmetric range only.
    assert_eq!(quantize(f32::MAX, 1e-3), 127);
    assert_eq!(quantize(-f32::MAX, 1e-3), -127);
}

#[test]
fn prop_all_zero_channel_gets_the_unit_scale_guard() {
    let mut rng = Rng::new(0x19);
    for case in 0..30u64 {
        let k = 1 + rng.below(8);
        let c = 1 + rng.below(6);
        let s = 1 + rng.below(9);
        let mut w = rnd(k * c * s, case);
        // Zero out a random output channel's whole K-row.
        let dead = rng.below(k);
        w[dead * c * s..(dead + 1) * c * s].fill(0.0);
        let scales = channel_scales_kcs(&w, k, c, s);
        assert_eq!(scales.len(), k);
        for (ik, &sc) in scales.iter().enumerate() {
            assert!(sc.is_finite() && sc > 0.0, "case {case}: scale[{ik}]={sc}");
            if ik == dead {
                assert_eq!(sc, 1.0, "case {case}: dead channel must guard to 1.0");
            } else {
                let row_absmax = absmax(&w[ik * c * s..(ik + 1) * c * s]);
                assert_eq!(sc, scale_from_absmax(row_absmax), "case {case}");
            }
        }
    }
}

/// Draw a random valid conv problem (small enough for many cases).
fn arb_problem(rng: &mut Rng) -> ConvParams {
    loop {
        let n = 1 + rng.below(3);
        let c = 1 + rng.below(12);
        let k = 1 + rng.below(12);
        let s = 1 + rng.below(9);
        let d = 1 + rng.below(6);
        let q = 1 + rng.below(200);
        if let Some(p) = ConvParams::new(n, c, k, q + (s - 1) * d, s, d) {
            return p;
        }
    }
}

#[test]
fn prop_i8_bit_identical_across_partitions_and_threads() {
    // The i32 accumulator is exact, so no (partition, threads) split can
    // change a single bit of the dequantized output — including through
    // the fused bias+relu epilogue.
    let mut rng = Rng::new(0x1A);
    for case in 0..12u64 {
        let p = arb_problem(&mut rng);
        let wt = rnd(p.k * p.c * p.s, 2000 + case);
        let x = rnd(p.n * p.c * p.w, 2100 + case);
        let bias = rnd(p.k, 2200 + case);
        let sx = scale_from_absmax(absmax(&x));
        let mut want: Option<Vec<f32>> = None;
        for partition in [Partition::Batch, Partition::Grid] {
            for threads in [1usize, 2, 5] {
                let mut plan = ConvPlan::by_name(p, "i8", threads, wt.clone())
                    .unwrap()
                    .with_partition(partition)
                    .with_post_ops(PostOps::bias_relu());
                plan.set_bias(&bias);
                plan.set_input_scale(sx);
                let mut out = vec![0.0f32; p.n * p.k * p.q()];
                plan.execute_forward_post_into(&x, None, &mut out);
                match &want {
                    None => {
                        assert!(
                            out.iter().any(|&v| v != 0.0),
                            "case {case}: i8 output must not be trivially zero"
                        );
                        want = Some(out);
                    }
                    Some(w) => {
                        let same = out.iter().zip(w).all(|(a, b)| a.to_bits() == b.to_bits());
                        assert!(
                            same,
                            "case {case} {p}: {partition:?} t={threads} diverges bitwise"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn prop_i8_plan_is_deterministic_and_tracks_f32() {
    // Twin of the bf16 property: repeated executions are bit-stable, and
    // the dequantized result stays within the derived error budget of
    // the f32 BRGEMM output (per-tap error ≤ Ax·s_w/2 + Aw·s_x/2).
    let mut rng = Rng::new(0x1B);
    for case in 0..10u64 {
        let p = arb_problem(&mut rng);
        let wt = rnd(p.k * p.c * p.s, 3000 + case);
        let x = rnd(p.n * p.c * p.w, 3100 + case);
        let sx = scale_from_absmax(absmax(&x));
        let mut plan = ConvPlan::by_name(p, "i8", 1, wt.clone()).unwrap();
        plan.set_input_scale(sx);
        let mut o1 = vec![0.0f32; p.n * p.k * p.q()];
        let mut o2 = vec![0.0f32; p.n * p.k * p.q()];
        plan.execute_forward_into(&x, &mut o1);
        plan.execute_forward_into(&x, &mut o2);
        assert_eq!(o1, o2, "case {case}: i8 plan must be deterministic");
        let mut f32_out = vec![0.0f32; p.n * p.k * p.q()];
        ConvPlan::by_name(p, "brgemm", 1, wt).unwrap().execute_forward_into(&x, &mut f32_out);
        // rnd() bounds: |x| ≤ 0.5, |w| ≤ 0.5 → per-tap ≤ 0.5·0.5/127,
        // summed over C·S taps, 2× headroom.
        let budget = (p.c * p.s) as f32 * (0.25 / 127.0) * 2.0;
        for (i, (a, b)) in o1.iter().zip(&f32_out).enumerate() {
            assert!(
                (a - b).abs() <= budget,
                "case {case} {p} idx {i}: i8 {a} vs f32 {b} (budget {budget})"
            );
        }
    }
}
