//! Cross-backend conformance matrix (ISSUE 2 acceptance criterion):
//! every registry kernel × every post-op combo, over an exhaustive
//! small-shape grid — kw ∈ {1,3,5,11}, dilation ∈ {1,2,4,8},
//! stride ∈ {1,2}, C,K ∈ {1,3,16,17}, odd input widths — compared
//! against a naive scalar reference written *in this file* (f64
//! accumulation, no shared code with the kernels), with per-case error
//! reporting on failure.
//!
//! Tolerances are the acceptance bounds: 1e-4 max abs error for f32
//! kernels, 2e-2 for the bf16 kernel, and a **shape-derived budget** for
//! the int8 kernel — per-product quantization error is at most
//! `Ax·s_w/2 + Aw·s_x/2` (with `s = absmax/127`), summed over the `C·S`
//! taps of one output, with 2× headroom. The i8 tier runs the same shape
//! grid and the same fused post-op combos as the f32/bf16 tiers.

use dilconv1d::conv1d::quant::{absmax, scale_from_absmax};
use dilconv1d::conv1d::test_util::rnd;
use dilconv1d::conv1d::{kernels, Activation, ConvParams, ConvPlan, PostOps};

/// The int8 acceptance budget for one output element at shape `p`:
/// inputs are `rnd()` (|x| ≤ 0.5), weights are `rnd() × 0.25`
/// (|w| ≤ 0.125), so each of the `C·S` products carries at most
/// `Ax·s_w/2 + Aw·s_x/2 = Ax·Aw/127` of rounding error. 2× headroom.
fn i8_budget(p: &ConvParams) -> f64 {
    (p.c * p.s) as f64 * (0.5 * 0.125 / 127.0) * 2.0
}

/// Scalar f64 reference of the raw convolution (valid, strided):
/// `out[n,k,j] = Σ_c Σ_s x[n,c,j·stride + s·d] · w[k,c,s]`.
fn reference_conv(p: &ConvParams, x: &[f32], wt: &[f32]) -> Vec<f64> {
    let (n, c, k, s, d, w, q, st) = (p.n, p.c, p.k, p.s, p.d, p.w, p.q(), p.stride);
    let mut out = vec![0.0f64; n * k * q];
    for ib in 0..n {
        for ik in 0..k {
            for j in 0..q {
                let mut acc = 0.0f64;
                for ic in 0..c {
                    for is in 0..s {
                        let xv = x[(ib * c + ic) * w + j * st + is * d] as f64;
                        let wv = wt[(ik * c + ic) * s + is] as f64;
                        acc += xv * wv;
                    }
                }
                out[(ib * k + ik) * q + j] = acc;
            }
        }
    }
    out
}

/// Scalar epilogue on the f64 reference: `act(scale·conv + bias + res)`.
fn reference_post(
    conv: &[f64],
    ops: &PostOps,
    bias: &[f32],
    res: Option<&[f32]>,
    n: usize,
    k: usize,
    q: usize,
) -> Vec<f64> {
    let mut out = vec![0.0f64; conv.len()];
    for ib in 0..n {
        for ik in 0..k {
            for j in 0..q {
                let at = (ib * k + ik) * q + j;
                let mut v = ops.scale as f64 * conv[at];
                if ops.bias {
                    v += bias[ik] as f64;
                }
                if ops.residual {
                    v += res.expect("residual data")[at] as f64;
                }
                out[at] = match ops.activation {
                    Activation::Identity => v,
                    Activation::Relu => v.max(0.0),
                    Activation::Sigmoid => 1.0 / (1.0 + (-v).exp()),
                };
            }
        }
    }
    out
}

/// Compare with per-case error reporting: on failure, print the case
/// label, the worst index and the full error statistics.
fn assert_close(case: &str, got: &[f32], want: &[f64], tol: f64) {
    assert_eq!(got.len(), want.len(), "{case}: length mismatch");
    let mut max_err = 0.0f64;
    let mut max_at = 0usize;
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let e = (*g as f64 - w).abs();
        if e > max_err {
            max_err = e;
            max_at = i;
        }
    }
    assert!(
        max_err <= tol,
        "{case}: max abs err {max_err:.3e} > {tol:.1e} at idx {max_at} \
         (got {}, want {})",
        got[max_at],
        want[max_at],
    );
}

/// The post-op combos the matrix crosses every kernel with.
fn post_combos() -> Vec<PostOps> {
    vec![
        PostOps::none(),
        PostOps::bias(),
        PostOps::bias_relu(),
        PostOps::parse("bias_sigmoid").unwrap(),
        PostOps::bias_relu_residual(),
        PostOps::bias_relu().with_scale(0.5),
    ]
}

#[test]
fn forward_matrix_all_kernels_all_post_ops() {
    let mut cases = 0usize;
    for &s in &[1usize, 3, 5, 11] {
        for &d in &[1usize, 2, 4, 8] {
            if s == 1 && d > 1 {
                continue; // dilation is meaningless for a 1-tap filter
            }
            for &stride in &[1usize, 2] {
                for &c in &[1usize, 3, 16, 17] {
                    for &k in &[1usize, 3, 16, 17] {
                        let span = (s - 1) * d + 1;
                        // Odd input width, ≥ 12 output columns at stride 1.
                        let mut w = span + 12;
                        if w % 2 == 0 {
                            w += 1;
                        }
                        let p = ConvParams::new(2, c, k, w, s, d)
                            .unwrap()
                            .with_stride(stride)
                            .unwrap();
                        run_forward_case(&p, &mut cases);
                    }
                }
            }
        }
    }
    // 13 distinct (kw, d) pairs (kw=1 collapses the dilation axis)
    // × 2 stride × 4 C × 4 K shapes, every kernel × combo.
    assert_eq!(cases, 13 * 2 * 16 * kernels().len() * post_combos().len());
}

fn run_forward_case(p: &ConvParams, cases: &mut usize) {
    let seed = (p.s * 31 + p.d * 7 + p.c * 3 + p.k + p.stride) as u64;
    let x = rnd(p.n * p.c * p.w, seed);
    // Modest weight magnitudes keep the bf16 accumulation error well
    // inside the 2e-2 acceptance bound even at C·S = 187 taps.
    let wt: Vec<f32> = rnd(p.k * p.c * p.s, seed + 1).iter().map(|v| v * 0.25).collect();
    let bias = rnd(p.k, seed + 2);
    let res = rnd(p.n * p.k * p.q(), seed + 3);
    let conv_ref = reference_conv(p, &x, &wt);
    for kernel in kernels() {
        let mut plan = ConvPlan::with_kernel(*p, *kernel, 1, wt.clone())
            .unwrap_or_else(|e| panic!("{p} {}: {e}", kernel.name()));
        plan.set_bias(&bias);
        if kernel.name() == "i8" {
            // Calibrate the activation scale: the default (1.0) would
            // quantize the rnd() inputs (|x| < 0.5) to all zeros.
            plan.set_input_scale(scale_from_absmax(absmax(&x)));
        }
        let mut out = vec![0.0f32; p.n * p.k * p.q()];
        for ops in post_combos() {
            plan.set_post_ops(ops);
            let residual = if ops.residual { Some(&res[..]) } else { None };
            plan.execute_forward_post_into(&x, residual, &mut out);
            let want = reference_post(&conv_ref, &ops, &bias, residual, p.n, p.k, p.q());
            let tol = match kernel.name() {
                "bf16" => 2e-2,
                "i8" => i8_budget(p),
                _ => 1e-4,
            };
            let case = format!("{p} kernel={} post={}", kernel.name(), ops);
            assert_close(&case, &out, &want, tol);
            *cases += 1;
        }
    }
}

/// Scalar backward-data reference at the problem's stride (f64):
/// the adjoint of [`reference_conv`].
fn reference_backward_data(p: &ConvParams, dconv: &[f64], wt: &[f32]) -> Vec<f64> {
    let (n, c, k, s, d, w, q, st) = (p.n, p.c, p.k, p.s, p.d, p.w, p.q(), p.stride);
    let mut gin = vec![0.0f64; n * c * w];
    for ib in 0..n {
        for ik in 0..k {
            for j in 0..q {
                let g = dconv[(ib * k + ik) * q + j];
                for ic in 0..c {
                    for is in 0..s {
                        let wv = wt[(ik * c + ic) * s + is] as f64;
                        gin[(ib * c + ic) * w + j * st + is * d] += g * wv;
                    }
                }
            }
        }
    }
    gin
}

/// Scalar backward-weight reference (f64).
fn reference_backward_weight(p: &ConvParams, dconv: &[f64], x: &[f32]) -> Vec<f64> {
    let (n, c, k, s, d, w, q, st) = (p.n, p.c, p.k, p.s, p.d, p.w, p.q(), p.stride);
    let mut gw = vec![0.0f64; k * c * s];
    for ib in 0..n {
        for ik in 0..k {
            for j in 0..q {
                let g = dconv[(ib * k + ik) * q + j];
                for ic in 0..c {
                    for is in 0..s {
                        gw[(ik * c + ic) * s + is] += g * x[(ib * c + ic) * w + j * st + is * d] as f64;
                    }
                }
            }
        }
    }
    gw
}

#[test]
fn fused_backward_matrix_subgrid() {
    // Every kernel × the fused backward-relevant combos on a compact
    // shape subgrid (both strides, odd widths).
    let combos = [
        PostOps::bias(),
        PostOps::bias_relu(),
        PostOps::bias_relu_residual().with_scale(0.5),
    ];
    for &(c, k, s, d) in &[(3usize, 16usize, 3usize, 1usize), (17, 3, 11, 4), (16, 16, 5, 2)] {
        for &stride in &[1usize, 2] {
            let span = (s - 1) * d + 1;
            let mut w = span + 12;
            if w % 2 == 0 {
                w += 1;
            }
            let p = ConvParams::new(2, c, k, w, s, d)
                .unwrap()
                .with_stride(stride)
                .unwrap();
            let seed = (c * 5 + k + s + d + stride) as u64;
            let x = rnd(p.n * p.c * p.w, seed);
            let wt: Vec<f32> = rnd(p.k * p.c * p.s, seed + 1).iter().map(|v| v * 0.25).collect();
            let bias = rnd(p.k, seed + 2);
            let res = rnd(p.n * p.k * p.q(), seed + 3);
            let gout = rnd(p.n * p.k * p.q(), seed + 4);
            for kernel in kernels() {
                for &ops in combos.iter() {
                    let mut plan = ConvPlan::with_kernel(*p, *kernel, 1, wt.clone())
                        .unwrap()
                        .with_post_ops(ops);
                    plan.set_bias(&bias);
                    if kernel.name() == "i8" {
                        plan.set_input_scale(scale_from_absmax(absmax(&x)));
                    }
                    let residual = if ops.residual { Some(&res[..]) } else { None };
                    let mut y = vec![0.0f32; p.n * p.k * p.q()];
                    plan.execute_forward_post_into(&x, residual, &mut y);
                    let mut gin = vec![0.0f32; p.n * p.c * p.w];
                    let mut gw = vec![0.0f32; p.k * p.c * p.s];
                    let mut gb = vec![0.0f32; p.k];
                    let mut gres = vec![0.0f32; p.n * p.k * p.q()];
                    plan.execute_backward_fused_into(
                        &gout,
                        &y,
                        &x,
                        Some(&mut gin),
                        &mut gw,
                        Some(&mut gb),
                        Some(&mut gres),
                    );
                    // Scalar reference of the fused backward, from the
                    // *same saved output* y (the contract of the API).
                    let (n, kk, q) = (p.n, p.k, p.q());
                    let mut dz = vec![0.0f64; n * kk * q];
                    let mut gb_want = vec![0.0f64; kk];
                    for ib in 0..n {
                        for ik in 0..kk {
                            for j in 0..q {
                                let at = (ib * kk + ik) * q + j;
                                let a = match ops.activation {
                                    Activation::Identity => 1.0f64,
                                    Activation::Relu => {
                                        if y[at] > 0.0 {
                                            1.0
                                        } else {
                                            0.0
                                        }
                                    }
                                    Activation::Sigmoid => {
                                        y[at] as f64 * (1.0 - y[at] as f64)
                                    }
                                };
                                dz[at] = gout[at] as f64 * a;
                                gb_want[ik] += dz[at];
                            }
                        }
                    }
                    let dconv: Vec<f64> = dz.iter().map(|v| v * ops.scale as f64).collect();
                    let gin_want = reference_backward_data(&p, &dconv, &wt);
                    let gw_want = reference_backward_weight(&p, &dconv, &x);
                    let tol = if kernel.name() == "bf16" { 2e-2 } else { 1e-4 };
                    let case = format!(
                        "{p} kernel={} post={} (fused backward)",
                        kernel.name(),
                        ops
                    );
                    // A residual that was never fused has zero gradient.
                    let gres_want = if ops.residual {
                        dz.clone()
                    } else {
                        vec![0.0f64; dz.len()]
                    };
                    assert_close(&format!("{case} / gres"), &gres, &gres_want, tol);
                    assert_close(&format!("{case} / gb"), &gb, &gb_want, 1e-3);
                    assert_close(&format!("{case} / gin"), &gin, &gin_want, 1e-3);
                    assert_close(&format!("{case} / gw"), &gw, &gw_want, 1e-3);
                }
            }
        }
    }
}
