//! Chaos suite: deterministic fault injection driven end-to-end over
//! TCP (DESIGN.md §7d). Requires the `fault` feature (Cargo skips this
//! target without it):
//!
//! ```text
//! cargo test --release --features fault --test chaos_serve
//! ```
//!
//! Every scenario scripts an exact [`FaultPlan`], drives real traffic
//! through the full stack (wire parser → handler → batcher → worker →
//! engine), and then holds the recovery telemetry to the plan — the
//! stack must report exactly the faults that were injected, nothing
//! more, and every surviving response must be bit-identical to a
//! fault-free run:
//!
//! * panic-storm: engine panics mid-forward across {f32, bf16, i8};
//!   victims get `INTERNAL`, survivors keep their bits, replicas rebuild
//! * kill + respawn: a worker thread dies outright; the supervisor
//!   respawns it and serving resumes over the same connection
//! * kill mid-stream: the panic lands inside a halo-overlapped
//!   streaming session; the next streamed request stitches perfectly
//! * slow worker + deadline: a delayed rank makes a queued request
//!   expire; it is shed with `DEADLINE_EXCEEDED` before any compute
//! * dropped/garbled connections: `DropConn` injection and protocol
//!   garbage both leave the server healthy for the next client
//! * handler panic while holding the server lock: poison recovery,
//!   handler cleanup, and shutdown still drains promptly
//!   (regression: the drain loop used to `lock().unwrap()` and deadlock)
//! * shutdown racing a worker restart with a streamed session in
//!   flight: drain waits for the respawned rank's tickets

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use dilconv1d::machine::Precision;
use dilconv1d::model::{AtacWorksNet, NetConfig};
use dilconv1d::serve::fault::silence_fault_panics;
use dilconv1d::serve::net::wire::status;
use dilconv1d::serve::net::{
    encode_request_header, encode_request_header_with_deadline, parse_response_header, NetOpts,
    NetServer, RESP_FLAG_STREAMED, RESP_HEADER_LEN,
};
use dilconv1d::serve::{
    round_up_to_block, BatcherOpts, BucketSet, EngineOpts, FaultPlan, InferenceEngine, ServeError,
    Server,
};
use dilconv1d::util::rng::Rng;

fn net_cfg() -> NetConfig {
    NetConfig::tiny()
}

fn params() -> Vec<f32> {
    AtacWorksNet::init(net_cfg(), 42).pack_params()
}

fn engine_opts(precision: Precision) -> EngineOpts {
    EngineOpts {
        buckets: BucketSet::new(&[128, 256]).expect("bucket widths"),
        max_batch: 1,
        cache_capacity: 2,
        precision,
        ..EngineOpts::default()
    }
}

/// Single-worker, batch-of-1 server with a fault plan attached: each
/// in-bucket request is exactly one `EngineForward` visit, so plan
/// `nth` indices line up with request arrival order on a serial
/// connection. The streaming route is on (window 128) for the
/// mid-stream scenarios.
fn faulty_batcher(plan: &Arc<FaultPlan>, precision: Precision, max_restarts: usize) -> Server {
    silence_fault_panics();
    Server::start(
        net_cfg(),
        &params(),
        BatcherOpts {
            engine: engine_opts(precision),
            window: Duration::from_millis(1),
            queue_depth: 16,
            workers: 1,
            warm: true,
            stream_window: Some(128),
            max_restarts,
            fault: Some(Arc::clone(plan)),
            ..BatcherOpts::default()
        },
    )
    .expect("server")
}

/// Fault-free reference bits for one in-bucket request.
fn reference(req: &[f32], precision: Precision) -> (Vec<f32>, Vec<f32>) {
    let mut engine =
        InferenceEngine::new(net_cfg(), &params(), engine_opts(precision)).expect("engine");
    let out = engine.infer_one(req).expect("reference");
    (out.denoised, out.logits)
}

/// Fault-free reference for an over-wide (streamed) request:
/// whole-sequence evaluation, which the streaming tests tie
/// bit-identically to the halo-overlapped route.
fn stream_reference(req: &[f32]) -> (Vec<f32>, Vec<f32>) {
    let opts = EngineOpts {
        buckets: BucketSet::new(&[round_up_to_block(req.len())]).expect("bucket widths"),
        max_batch: 1,
        cache_capacity: 1,
        ..EngineOpts::default()
    };
    let mut engine = InferenceEngine::new(net_cfg(), &params(), opts).expect("engine");
    let out = engine.infer_one(req).expect("reference");
    (out.denoised, out.logits)
}

fn track(w: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..w).map(|_| rng.poisson(0.8) as f32).collect()
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

// ------------------------------------------------------------ wire client

fn send_request(stream: &mut TcpStream, signal: &[f32]) -> std::io::Result<()> {
    stream.write_all(&encode_request_header(signal.len() as u32, 0))?;
    let mut bytes = Vec::with_capacity(signal.len() * 4);
    for v in signal {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    stream.write_all(&bytes)
}

/// v2 frame carrying a per-request deadline in the header.
fn send_request_with_deadline(
    stream: &mut TcpStream,
    signal: &[f32],
    deadline_ms: u16,
) -> std::io::Result<()> {
    stream.write_all(&encode_request_header_with_deadline(
        signal.len() as u32,
        0,
        deadline_ms,
    ))?;
    let mut bytes = Vec::with_capacity(signal.len() * 4);
    for v in signal {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    stream.write_all(&bytes)
}

fn read_f32s(stream: &mut TcpStream, n: usize) -> std::io::Result<Vec<f32>> {
    let mut buf = vec![0u8; n * 4];
    stream.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Read one response frame: `(status, flags, payload)` where the payload
/// (denoised, logits) is present only on `OK`.
#[allow(clippy::type_complexity)]
fn read_response(
    stream: &mut TcpStream,
) -> std::io::Result<(u8, u8, Option<(Vec<f32>, Vec<f32>)>)> {
    let mut hdr = [0u8; RESP_HEADER_LEN];
    stream.read_exact(&mut hdr)?;
    let (code, flags, width) = parse_response_header(&hdr);
    if code == status::OK {
        let den = read_f32s(stream, width)?;
        let log = read_f32s(stream, width)?;
        Ok((code, flags, Some((den, log))))
    } else {
        Ok((code, flags, None))
    }
}

fn wait_for_drain(net: &NetServer) {
    let deadline = Instant::now() + Duration::from_secs(10);
    while net.connections() > 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(net.connections(), 0, "handlers released their slots");
}

// ------------------------------------------------------------------ tests

/// Panic-storm: scripted engine panics on forward visits 1 and 4. The
/// victims get `INTERNAL` on the wire, the survivors are bit-identical
/// to a fault-free engine at the same precision, and the recovery
/// counters equal the plan — across all three serving precisions.
#[test]
fn panic_storm_isolates_victims_and_keeps_survivor_bits() {
    for precision in [Precision::F32, Precision::Bf16, Precision::I8] {
        let plan = Arc::new(FaultPlan::new().panic_in_forward(0, 1).panic_in_forward(0, 4));
        let net = NetServer::bind(
            "127.0.0.1:0",
            faulty_batcher(&plan, precision, 3),
            NetOpts::default(),
        )
        .expect("bind");
        let mut conn = TcpStream::connect(net.local_addr()).expect("connect");
        let reqs: Vec<Vec<f32>> = [100usize, 140, 200, 90, 250, 128]
            .iter()
            .enumerate()
            .map(|(i, &w)| track(w, 300 + i as u64))
            .collect();
        // Serial requests on one connection: arrival order == forward
        // visit order, so requests 1 and 4 are the victims.
        for (i, req) in reqs.iter().enumerate() {
            send_request(&mut conn, req).expect("send");
            let (code, _, payload) = read_response(&mut conn).expect("recv");
            if i == 1 || i == 4 {
                assert_eq!(code, status::INTERNAL, "{precision:?}: victim {i}");
                assert!(payload.is_none());
            } else {
                assert_eq!(code, status::OK, "{precision:?}: survivor {i}");
                let (den, log) = payload.expect("payload on OK");
                let (want_den, want_log) = reference(req, precision);
                assert_eq!(bits(&den), bits(&want_den), "{precision:?}: survivor {i}");
                assert_eq!(bits(&log), bits(&want_log), "{precision:?}: survivor {i}");
            }
        }
        drop(conn);
        wait_for_drain(&net);
        let (m, stats) = net.shutdown();
        assert_eq!(m.worker_panics, 2, "{precision:?}");
        assert_eq!(m.worker_panics, plan.panics_fired(), "{precision:?}");
        assert_eq!(m.restarts, 0, "{precision:?}: caught panics need no respawn");
        assert_eq!((m.completed, m.failed), (4, 2), "{precision:?}");
        assert_eq!(stats.requests_ok, 4, "{precision:?}");
        assert_eq!(stats.requests_error, 2, "{precision:?}");
        assert_eq!(stats.handler_panics, 0, "{precision:?}");
    }
}

/// A worker thread killed outright (panic outside the engine guard):
/// the victim still gets an answer (`INTERNAL`), the supervisor
/// respawns the rank, and the same connection keeps being served with
/// intact bits.
#[test]
fn killed_worker_is_respawned_and_the_connection_keeps_serving() {
    let plan = Arc::new(FaultPlan::new().kill_worker(0, 0));
    let net = NetServer::bind(
        "127.0.0.1:0",
        faulty_batcher(&plan, Precision::F32, 3),
        NetOpts::default(),
    )
    .expect("bind");
    let mut conn = TcpStream::connect(net.local_addr()).expect("connect");
    let req = track(120, 17);
    send_request(&mut conn, &req).expect("send victim");
    let (code, _, _) = read_response(&mut conn).expect("recv victim");
    assert_eq!(code, status::INTERNAL, "the killed rank's job is answered");
    send_request(&mut conn, &req).expect("send survivor");
    let (code, _, payload) = read_response(&mut conn).expect("recv survivor");
    assert_eq!(code, status::OK);
    let (den, log) = payload.expect("payload");
    let (want_den, want_log) = reference(&req, Precision::F32);
    assert_eq!(bits(&den), bits(&want_den));
    assert_eq!(bits(&log), bits(&want_log));
    drop(conn);
    wait_for_drain(&net);
    let (m, stats) = net.shutdown();
    assert_eq!(m.restarts, 1);
    assert_eq!(m.worker_panics, 0, "the unwind escaped the engine guard");
    assert_eq!(stats.requests_ok, 1);
    assert_eq!(stats.requests_error, 1);
}

/// The panic lands mid-stream — on the third window of a
/// halo-overlapped streaming session. The streamed request fails as a
/// unit, the replica rebuilds, and the next streamed request stitches
/// bit-identically to whole-sequence evaluation.
#[test]
fn mid_stream_panic_fails_the_stream_and_the_next_one_stitches_clean() {
    let plan = Arc::new(FaultPlan::new().panic_in_forward(0, 2));
    let net = NetServer::bind(
        "127.0.0.1:0",
        faulty_batcher(&plan, Precision::F32, 3),
        NetOpts::default(),
    )
    .expect("bind");
    let mut conn = TcpStream::connect(net.local_addr()).expect("connect");
    let signal = track(700, 23); // > largest bucket (256) → streamed
    send_request(&mut conn, &signal).expect("send victim");
    let (code, _, _) = read_response(&mut conn).expect("recv victim");
    assert_eq!(code, status::INTERNAL, "window 2 of the stream panicked");
    send_request(&mut conn, &signal).expect("send survivor");
    let (code, flags, payload) = read_response(&mut conn).expect("recv survivor");
    assert_eq!(code, status::OK);
    assert_ne!(flags & RESP_FLAG_STREAMED, 0, "took the streaming route");
    let (den, log) = payload.expect("payload");
    let (want_den, want_log) = stream_reference(&signal);
    assert_eq!(bits(&den), bits(&want_den), "stitched bits after rebuild");
    assert_eq!(bits(&log), bits(&want_log));
    drop(conn);
    wait_for_drain(&net);
    let (m, stats) = net.shutdown();
    assert_eq!(m.worker_panics, 1);
    assert_eq!(m.worker_panics, plan.panics_fired());
    assert_eq!(m.restarts, 0);
    assert_eq!((m.streamed, stats.requests_streamed), (1, 1));
}

/// Slow worker + deadline: rank 0's first forward stalls 400 ms, so a
/// second request with a 30 ms wire deadline expires while queued. It
/// is shed with `DEADLINE_EXCEEDED` before any compute; the slow
/// request itself completes with intact bits.
#[test]
fn queued_requests_past_their_wire_deadline_are_shed_not_computed() {
    let plan = Arc::new(FaultPlan::new().delay_forward(0, 0, Duration::from_millis(400)));
    let net = NetServer::bind(
        "127.0.0.1:0",
        faulty_batcher(&plan, Precision::F32, 3),
        NetOpts::default(),
    )
    .expect("bind");
    let slow_req = track(100, 31);
    let doomed_req = track(130, 32);
    let mut slow = TcpStream::connect(net.local_addr()).expect("connect slow");
    send_request(&mut slow, &slow_req).expect("send slow");
    // Let the slow request reach the (single) worker and start its
    // 400 ms stall before the doomed one is even submitted.
    std::thread::sleep(Duration::from_millis(100));
    let mut doomed = TcpStream::connect(net.local_addr()).expect("connect doomed");
    send_request_with_deadline(&mut doomed, &doomed_req, 30).expect("send doomed");
    let (code, _, payload) = read_response(&mut slow).expect("recv slow");
    assert_eq!(code, status::OK, "the stalled request still completes");
    let (den, log) = payload.expect("payload");
    let (want_den, want_log) = reference(&slow_req, Precision::F32);
    assert_eq!(bits(&den), bits(&want_den), "a shed neighbour changes no bits");
    assert_eq!(bits(&log), bits(&want_log));
    let (code, _, payload) = read_response(&mut doomed).expect("recv doomed");
    assert_eq!(code, status::DEADLINE_EXCEEDED);
    assert!(payload.is_none());
    drop(slow);
    drop(doomed);
    wait_for_drain(&net);
    let (m, stats) = net.shutdown();
    assert_eq!(m.deadline_shed, 1);
    assert_eq!(m.completed, 1);
    assert_eq!(m.failed, 0, "a shed request is not an engine failure");
    assert_eq!(plan.delays_fired(), 1);
    assert_eq!(stats.requests_deadline, 1);
    assert_eq!(stats.requests_ok, 1);
}

/// Connection hygiene under abuse: a `DropConn` injection closes one
/// client without an answer, a second client sends protocol garbage
/// and gets `MALFORMED`, and a third, well-behaved client is served
/// normally. Afterwards every connection slot is back.
#[test]
fn dropped_and_garbled_connections_leave_the_server_healthy() {
    let plan = Arc::new(FaultPlan::new().drop_conn(0));
    let net = NetServer::bind(
        "127.0.0.1:0",
        faulty_batcher(&plan, Precision::F32, 3),
        NetOpts {
            fault: Some(Arc::clone(&plan)),
            ..NetOpts::default()
        },
    )
    .expect("bind");
    let req = track(100, 41);
    // Victim: the server hangs up instead of answering.
    let mut victim = TcpStream::connect(net.local_addr()).expect("connect victim");
    send_request(&mut victim, &req).expect("send victim");
    let mut byte = [0u8; 1];
    assert_eq!(
        victim.read(&mut byte).expect("EOF, not data"),
        0,
        "DropConn closes without a response frame"
    );
    assert_eq!(plan.drops_fired(), 1);
    // Vandal: garbage where a frame header belongs.
    let mut vandal = TcpStream::connect(net.local_addr()).expect("connect vandal");
    vandal.write_all(b"this is not a frame").expect("send junk");
    let (code, _, _) = read_response(&mut vandal).expect("recv malformed");
    assert_eq!(code, status::MALFORMED);
    assert_eq!(vandal.read(&mut byte).expect("closed"), 0);
    // Citizen: served exactly as if the other two never happened.
    let mut citizen = TcpStream::connect(net.local_addr()).expect("connect citizen");
    send_request(&mut citizen, &req).expect("send");
    let (code, _, payload) = read_response(&mut citizen).expect("recv");
    assert_eq!(code, status::OK);
    let (den, log) = payload.expect("payload");
    let (want_den, want_log) = reference(&req, Precision::F32);
    assert_eq!(bits(&den), bits(&want_den));
    assert_eq!(bits(&log), bits(&want_log));
    drop(victim);
    drop(vandal);
    drop(citizen);
    wait_for_drain(&net);
    let (m, stats) = net.shutdown();
    assert_eq!(stats.requests_malformed, 1);
    assert_eq!(stats.requests_ok, 1);
    assert_eq!(m.worker_panics, 0);
}

/// Regression (satellite 2): a handler that panics while holding the
/// server lock used to poison it and deadlock `NetServer::shutdown`'s
/// drain loop (`lock().unwrap()` on `conns`/`handlers`). Now: the
/// panic is counted, the connection cleaned up, the next client served
/// through the recovered lock, and shutdown drains promptly.
#[test]
fn handler_panic_poisons_nothing_and_shutdown_still_drains() {
    silence_fault_panics();
    let plan = Arc::new(FaultPlan::new().panic_handler(0));
    let net = NetServer::bind(
        "127.0.0.1:0",
        faulty_batcher(&plan, Precision::F32, 3),
        NetOpts {
            drain: Duration::from_secs(5),
            fault: Some(Arc::clone(&plan)),
            ..NetOpts::default()
        },
    )
    .expect("bind");
    let req = track(100, 53);
    // Victim: the handler panics holding the server lock; the client
    // sees the connection close with no response frame.
    let mut victim = TcpStream::connect(net.local_addr()).expect("connect victim");
    send_request(&mut victim, &req).expect("send victim");
    let mut byte = [0u8; 1];
    assert_eq!(victim.read(&mut byte).expect("EOF"), 0);
    // Survivor: the poisoned lock is recovered, serving continues.
    let mut survivor = TcpStream::connect(net.local_addr()).expect("connect survivor");
    send_request(&mut survivor, &req).expect("send survivor");
    let (code, _, payload) = read_response(&mut survivor).expect("recv");
    assert_eq!(code, status::OK);
    let (den, log) = payload.expect("payload");
    let (want_den, want_log) = reference(&req, Precision::F32);
    assert_eq!(bits(&den), bits(&want_den));
    assert_eq!(bits(&log), bits(&want_log));
    drop(victim);
    drop(survivor);
    wait_for_drain(&net);
    let begin = Instant::now();
    let (_, stats) = net.shutdown();
    assert!(
        begin.elapsed() < Duration::from_secs(10),
        "shutdown drained instead of deadlocking on the poisoned lock"
    );
    assert_eq!(stats.handler_panics, 1);
    assert_eq!(plan.panics_fired(), 1);
    assert_eq!(stats.requests_ok, 1);
}

/// Satellite 3, direct server API: `Server::shutdown` races a worker
/// restart with a streamed session and a batched request in flight.
/// The drain must wait for the *respawned* rank's tickets — both
/// resolve with correct bits after shutdown returns.
#[test]
fn shutdown_drain_waits_for_the_respawned_workers_inflight_tickets() {
    let plan = Arc::new(FaultPlan::new().kill_worker(0, 0));
    let server = faulty_batcher(&plan, Precision::F32, 3);
    // Job 0 kills the only rank; the Reply-on-drop contract answers.
    let victim = server.submit(track(100, 61)).expect("admitted");
    assert!(matches!(victim.wait(), Err(ServeError::WorkerPanic)));
    // Queue a streamed session and a batched request against the dead
    // rank, then shut down immediately: the drain must respawn the
    // rank and wait out both tickets rather than dropping them.
    let wide = track(700, 62);
    let narrow = track(120, 63);
    let streamed = server.submit(wide.clone()).expect("streamed admitted");
    let batched = server.submit(narrow.clone()).expect("batched admitted");
    let m = server.shutdown();
    let rs = streamed.wait().expect("streamed ticket resolved by drain");
    let rb = batched.wait().expect("batched ticket resolved by drain");
    assert!(rs.streamed && !rb.streamed);
    let (want_den, want_log) = stream_reference(&wide);
    assert_eq!(bits(&rs.output.denoised), bits(&want_den));
    assert_eq!(bits(&rs.output.logits), bits(&want_log));
    let (want_den, want_log) = reference(&narrow, Precision::F32);
    assert_eq!(bits(&rb.output.denoised), bits(&want_den));
    assert_eq!(bits(&rb.output.logits), bits(&want_log));
    assert_eq!(m.restarts, 1, "the drain respawned the killed rank");
    assert_eq!(m.completed, 2);
    assert_eq!(m.streamed, 1);
    assert_eq!(m.worker_panics, 0);
}
