//! Autotuner determinism + persistence (ISSUE 2 satellite): the same
//! shape chosen twice re-measures nothing and returns the same kernel;
//! the tuning table round-trips through `util::json` and a reloaded
//! table is honored without any measurement.

use dilconv1d::conv1d::test_util::rnd;
use dilconv1d::conv1d::{Autotuner, ConvParams, ConvPlan, Partition, PostOps};
use dilconv1d::machine::Precision;
use dilconv1d::util::json::Json;

fn shape() -> ConvParams {
    ConvParams::new(2, 8, 8, 600, 9, 4).unwrap()
}

#[test]
fn same_shape_twice_measures_once_and_agrees() {
    let tuner = Autotuner::new();
    let p = shape();
    let first = tuner.choose(&p, 1, Precision::F32, Partition::Batch);
    let measured = tuner.measurement_count();
    assert!(measured > 0, "first choose must micro-benchmark candidates");
    assert_eq!(tuner.len(), 1);
    // Second choose: identical decision, ZERO re-measurement.
    let second = tuner.choose(&p, 1, Precision::F32, Partition::Batch);
    assert_eq!(first.name(), second.name());
    assert_eq!(
        tuner.measurement_count(),
        measured,
        "repeated shape must not re-measure"
    );
    // A different shape is a different key and measures again.
    let p2 = ConvParams::new(1, 3, 3, 300, 5, 2).unwrap();
    tuner.choose(&p2, 1, Precision::F32, Partition::Batch);
    assert!(tuner.measurement_count() > measured);
    assert_eq!(tuner.len(), 2);
}

#[test]
fn table_round_trips_through_util_json_and_is_honored_on_reload() {
    let tuner = Autotuner::new();
    let p = shape();
    let chosen = tuner.choose(&p, 1, Precision::F32, Partition::Batch);
    let json = tuner.to_json();
    // The persisted table is valid JSON for the in-tree parser and keeps
    // the entry under the shape key.
    let doc = Json::parse(&json).expect("tuning table must be valid JSON");
    assert_eq!(doc.get("version").and_then(Json::as_usize), Some(1));
    let entries = doc.get("entries").and_then(Json::as_obj).unwrap();
    assert_eq!(entries.len(), 1);
    let key = Autotuner::key(&p, 1, Precision::F32, Partition::Batch);
    assert_eq!(
        entries[&key].get("kernel").and_then(Json::as_str),
        Some(chosen.name())
    );

    // Reload into a fresh tuner: the decision is honored with zero
    // measurements.
    let fresh = Autotuner::new();
    assert_eq!(fresh.load_json(&json).unwrap(), 1);
    let again = fresh.choose(&p, 1, Precision::F32, Partition::Batch);
    assert_eq!(again.name(), chosen.name());
    assert_eq!(fresh.measurement_count(), 0, "reloaded table must preempt measurement");
}

#[test]
fn persisted_entry_overrides_measurement_even_for_a_slow_kernel() {
    // Force-load a table pinning the naive kernel: choose() must honor
    // it (the table is authoritative; it would never win a measurement).
    let tuner = Autotuner::new();
    let p = shape();
    let key = Autotuner::key(&p, 1, Precision::F32, Partition::Batch);
    let json = format!(
        "{{\"version\": 1, \"entries\": {{\"{key}\": {{\"kernel\": \"direct\", \"micros\": 1.0}}}}}}"
    );
    assert_eq!(tuner.load_json(&json).unwrap(), 1);
    let k = tuner.choose(&p, 1, Precision::F32, Partition::Batch);
    assert_eq!(k.name(), "direct");
    assert_eq!(tuner.measurement_count(), 0);
    // Unknown kernels in a persisted table are skipped, not honored.
    let bad = format!(
        "{{\"version\": 1, \"entries\": {{\"{key}\": {{\"kernel\": \"cuda\", \"micros\": 1.0}}}}}}"
    );
    let t2 = Autotuner::new();
    assert_eq!(t2.load_json(&bad).unwrap(), 0);
}

#[test]
fn unknown_precision_tags_are_skipped_not_fatal() {
    // Forward compat: a cache persisted by a FUTURE build with a
    // precision tier this binary doesn't know (`pfp4i...`) must be
    // skipped entry-by-entry — load succeeds, known entries survive.
    let tuner = Autotuner::new();
    let p = shape();
    tuner.choose(&p, 1, Precision::F32, Partition::Batch);
    let f32_key = Autotuner::key(&p, 1, Precision::F32, Partition::Batch);
    let i8_key = Autotuner::key(&p, 1, Precision::I8, Partition::Batch);
    let future_key = f32_key.replace("pf32i", "pfp4i");
    assert_ne!(future_key, f32_key, "key must carry a precision tag");
    let json = format!(
        "{{\"version\": 1, \"entries\": {{\
         \"{f32_key}\": {{\"kernel\": \"brgemm\", \"micros\": 1.0}}, \
         \"{i8_key}\": {{\"kernel\": \"i8\", \"micros\": 1.0}}, \
         \"{future_key}\": {{\"kernel\": \"fp4\", \"micros\": 1.0}}}}}}"
    );
    let fresh = Autotuner::new();
    // Two entries load (f32 + i8); the future-precision one is dropped.
    assert_eq!(fresh.load_json(&json).unwrap(), 2);
    assert_eq!(fresh.choose(&p, 1, Precision::F32, Partition::Batch).name(), "brgemm");
    assert_eq!(fresh.choose(&p, 1, Precision::I8, Partition::Batch).name(), "i8");
    assert_eq!(fresh.measurement_count(), 0);

    // A tampered table that maps an f32 key to a reduced-precision
    // kernel is also skipped: entries must be self-consistent.
    let crossed = format!(
        "{{\"version\": 1, \"entries\": {{\"{f32_key}\": {{\"kernel\": \"i8\", \"micros\": 1.0}}}}}}"
    );
    let t2 = Autotuner::new();
    assert_eq!(t2.load_json(&crossed).unwrap(), 0);
}

#[test]
fn i8_precision_short_circuits_to_the_i8_kernel() {
    // Like bf16: exactly one i8 candidate exists, so choose() never
    // spends a measurement on it.
    let tuner = Autotuner::new();
    let k = tuner.choose(&shape(), 1, Precision::I8, Partition::Batch);
    assert_eq!(k.name(), "i8");
    assert_eq!(tuner.measurement_count(), 0);
    // And the plan front door agrees.
    let p = shape();
    let wt = rnd(p.k * p.c * p.s, 14);
    let plan = ConvPlan::tuned(p, Precision::I8, 1, Partition::Batch, wt).unwrap();
    assert_eq!(plan.kernel_name(), "i8");
    assert_eq!(plan.precision(), Precision::I8);
}

#[test]
fn file_round_trip_and_plan_integration() {
    let tuner = Autotuner::new();
    let p = shape();
    tuner.choose(&p, 1, Precision::F32, Partition::Batch);
    let dir = std::env::temp_dir().join("dilconv_tune_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("tune.json");
    tuner.save(&path).unwrap();
    let fresh = Autotuner::new();
    assert_eq!(fresh.load(&path).unwrap(), 1);
    assert_eq!(
        fresh.entry(&p, 1, Precision::F32, Partition::Batch).unwrap().kernel,
        tuner.entry(&p, 1, Precision::F32, Partition::Batch).unwrap().kernel
    );

    // ConvPlan::tuned routes through the process-wide tuner and produces
    // the same numbers as an explicitly-selected plan of that kernel.
    let wt = rnd(p.k * p.c * p.s, 9);
    let x = rnd(p.n * p.c * p.w, 10);
    let mut tuned = ConvPlan::tuned(p, Precision::F32, 1, Partition::Batch, wt.clone()).unwrap();
    let mut fixed = ConvPlan::by_name(p, tuned.kernel_name(), 1, wt).unwrap();
    let mut a = vec![0.0f32; p.n * p.k * p.q()];
    let mut b = vec![0.0f32; p.n * p.k * p.q()];
    tuned.execute_forward_into(&x, &mut a);
    fixed.execute_forward_into(&x, &mut b);
    assert_eq!(a, b);
    // bf16 precision short-circuits to the bf16 kernel.
    let bf = ConvPlan::tuned(p, Precision::Bf16, 1, Partition::Batch, rnd(p.k * p.c * p.s, 11)).unwrap();
    assert_eq!(bf.kernel_name(), "bf16");
    assert_eq!(bf.precision(), Precision::Bf16);
    // Fused post-ops compose with tuned plans.
    let mut post = ConvPlan::tuned(p, Precision::F32, 1, Partition::Batch, rnd(p.k * p.c * p.s, 12))
        .unwrap()
        .with_post_ops(PostOps::bias_relu());
    post.set_bias(&rnd(p.k, 13));
    let mut out = vec![0.0f32; p.n * p.k * p.q()];
    post.execute_forward_post_into(&x, None, &mut out);
    assert!(out.iter().all(|v| *v >= 0.0), "relu epilogue must clamp");
}
