//! Integration tests over the PJRT runtime: load the AOT HLO-text
//! artifacts produced by `make artifacts`, execute them on the CPU
//! client, and cross-check against the native Rust kernels — proving the
//! L1 (Pallas) / L2 (JAX) / L3 (Rust) stack computes one consistent
//! function.
//!
//! These tests are skipped (with a message) when `artifacts/` has not
//! been built, so `cargo test` works on a fresh checkout; CI and the
//! Makefile run `make artifacts` first.

use dilconv1d::conv1d::test_util::rnd;
use dilconv1d::conv1d::ConvParams;
use dilconv1d::data::atacseq::TrackConfig;
use dilconv1d::data::make_batch;
use dilconv1d::runtime::{Registry, Session, TrainState};

fn registry() -> Option<Registry> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match Registry::load(&dir) {
        Ok(r) => Some(r),
        Err(_) => {
            eprintln!("artifacts/ not built; skipping runtime integration test");
            None
        }
    }
}

#[test]
fn conv_fwd_artifact_matches_native_kernel() {
    let Some(reg) = registry() else { return };
    let Ok(art) = reg.get("conv_fwd_atac") else {
        return;
    };
    let mut sess = Session::cpu().expect("pjrt cpu client");
    let shp = &art.inputs[0].shape;
    let wshp = &art.inputs[1].shape;
    let (n, c, w) = (shp[0], shp[1], shp[2]);
    let (s, k) = (wshp[0], wshp[1]);
    let q = art.outputs[0].shape[2];
    let d = (w - q) / (s - 1);
    let x = rnd(n * c * w, 41);
    let wt = rnd(s * k * c, 42);
    let got = dilconv1d::runtime::step::run_conv_fwd(&mut sess, art, &x, &wt).expect("run");
    let p = ConvParams::new(n, c, k, w, s, d).unwrap();
    let mut want = vec![0.0f32; n * k * q];
    dilconv1d::conv1d::forward::forward(&p, &x, &wt, &mut want, 1);
    for (i, (g, e)) in got.iter().zip(&want).enumerate() {
        assert!(
            (g - e).abs() < 1e-3 * (1.0 + e.abs()),
            "idx {i}: pjrt {g} vs native {e}"
        );
    }
}

#[test]
fn pjrt_training_reduces_loss_and_matches_abi() {
    let Some(reg) = registry() else { return };
    if !reg.artifacts.contains_key("train_step_tiny") {
        eprintln!("train_step_tiny not built; skipping");
        return;
    }
    let mut sess = Session::cpu().expect("pjrt cpu client");
    let mut st = TrainState::init(&reg, "tiny").expect("train state");
    sess.load(&st.train_key(), &reg.get(&st.train_key()).unwrap().path)
        .expect("compile train step");
    sess.load(&st.eval_key(), &reg.get(&st.eval_key()).unwrap().path)
        .expect("compile eval step");

    let mut track = TrackConfig::default().scaled(st.width);
    track.pad = 0;
    track.width = st.width;
    let idx: Vec<u64> = (0..st.batch as u64).collect();
    let b = make_batch(&track, 11, &idx);

    let mut losses = Vec::new();
    for _ in 0..4 {
        let l = st.step(&sess, &b.x, &b.clean, &b.peaks).expect("step");
        assert!(l.total.is_finite() && l.mse >= 0.0 && l.bce >= 0.0);
        losses.push(l.total);
    }
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "PJRT loss did not decrease: {losses:?}"
    );

    // Eval ABI: (denoised, probabilities in [0, 1]).
    let (den, probs) = st.eval(&sess, &b.x).expect("eval");
    assert_eq!(den.len(), st.batch * st.width);
    assert_eq!(probs.len(), st.batch * st.width);
    assert!(probs.iter().all(|&p| (0.0..=1.0).contains(&p)));
}

#[test]
fn registry_metadata_is_consistent() {
    let Some(reg) = registry() else { return };
    for (name, art) in &reg.artifacts {
        if art.kind == "params" {
            let params = reg.load_params(&name.replace("params_", "")).expect("params blob");
            let meta = art.model.as_ref().expect("params entries carry model meta");
            assert_eq!(params.len(), meta.param_count, "{name}");
            // Spec offsets tile the flat vector exactly.
            let mut expected_off = 0;
            for pe in &meta.param_spec {
                assert_eq!(pe.offset, expected_off, "{name}/{}", pe.name);
                assert_eq!(pe.size, pe.shape.iter().product::<usize>(), "{name}/{}", pe.name);
                expected_off += pe.size;
            }
            assert_eq!(expected_off, meta.param_count, "{name}");
        } else {
            assert!(art.path.exists(), "{name}: missing {:?}", art.path);
        }
    }
}
