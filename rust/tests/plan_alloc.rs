//! Zero-allocation guarantee of the plan executor (ISSUE 1 acceptance
//! criterion): after `ConvPlan` construction, steady-state
//! `execute_forward_into` / `execute_backward_*_into` calls perform
//! **zero** heap allocations (single-worker plans; multi-worker plans
//! additionally pay only the scoped thread spawns).
//!
//! Verified with a counting `#[global_allocator]`. This file deliberately
//! contains a single `#[test]` so no concurrent test can allocate while a
//! window is measured; a short retry loop absorbs any one-off runtime
//! allocation that might land inside a window.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use dilconv1d::conv1d::test_util::rnd;
use dilconv1d::conv1d::{ConvParams, ConvPlan, PostOps};

struct CountingAllocator;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::SeqCst);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::SeqCst);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::SeqCst);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAllocator = CountingAllocator;

/// Run `f` and return the number of heap allocations it performed,
/// retrying a few times so a stray runtime allocation outside our code
/// (e.g. lazy stdio setup) cannot produce a false positive. The MINIMUM
/// over attempts is the honest count of what `f` itself allocates.
fn allocs_during(mut f: impl FnMut()) -> usize {
    let mut min = usize::MAX;
    for _ in 0..3 {
        let before = ALLOCS.load(Ordering::SeqCst);
        f();
        let delta = ALLOCS.load(Ordering::SeqCst) - before;
        min = min.min(delta);
        if min == 0 {
            break;
        }
    }
    min
}

#[test]
fn steady_state_executors_do_not_allocate() {
    // Same-padded AtacWorks-flavoured shape, scaled for test speed, with a
    // Q % 64 != 0 tail so the remainder path is exercised too.
    let (n, c, k, s, d, wu) = (2usize, 5usize, 6usize, 9usize, 4usize, 450usize);
    let p = ConvParams::with_same_padding(n, c, k, wu, s, d).unwrap();
    let wt = rnd(k * c * s, 1);
    let x = rnd(n * c * p.w, 2);
    let x_unpadded = rnd(n * c * wu, 3);
    let gout = rnd(n * k * p.q(), 4);

    for kernel in ["brgemm", "im2col", "direct", "bf16"] {
        // threads = 1: the strictly zero-allocation configuration.
        let mut plan = ConvPlan::by_name(p, kernel, 1, wt.clone()).unwrap();
        let mut out = vec![0.0f32; n * k * p.q()];
        let mut gin = vec![0.0f32; n * c * p.w];
        let mut gw = vec![0.0f32; k * c * s];
        let mut gx = vec![0.0f32; n * c * wu];

        // Warm every path once (first call may lazily touch nothing, but
        // keep the measurement honest regardless).
        plan.execute_forward_into(&x, &mut out);
        plan.execute_forward_same_into(&x_unpadded, &mut out[..n * k * wu]);
        plan.execute_backward_data_into(&gout, &mut gin);
        plan.execute_backward_weight_into(&gout, &x, &mut gw);
        plan.execute_backward_data_same_into(&gout, &mut gx);

        let fwd = allocs_during(|| plan.execute_forward_into(&x, &mut out));
        assert_eq!(fwd, 0, "{kernel}: execute_forward_into allocated");

        let fwd_same =
            allocs_during(|| plan.execute_forward_same_into(&x_unpadded, &mut out[..n * k * wu]));
        assert_eq!(fwd_same, 0, "{kernel}: execute_forward_same_into allocated");

        let bwd_d = allocs_during(|| plan.execute_backward_data_into(&gout, &mut gin));
        assert_eq!(bwd_d, 0, "{kernel}: execute_backward_data_into allocated");

        let bwd_w = allocs_during(|| plan.execute_backward_weight_into(&gout, &x, &mut gw));
        assert_eq!(bwd_w, 0, "{kernel}: execute_backward_weight_into allocated");

        let bwd_same = allocs_during(|| plan.execute_backward_data_same_into(&gout, &mut gx));
        assert_eq!(bwd_same, 0, "{kernel}: execute_backward_data_same_into allocated");

        // set_weights refreshes every derived layout in place.
        let reweight = allocs_during(|| plan.set_weights(&wt));
        assert_eq!(reweight, 0, "{kernel}: set_weights allocated");

        // And the owned-output convenience path is allocation-free too.
        let fwd_owned = allocs_during(|| {
            plan.execute_forward(&x);
        });
        assert_eq!(fwd_owned, 0, "{kernel}: execute_forward allocated");

        // Fused post-op pipeline: the bias+relu+residual epilogue runs
        // inside the kernel's block loop (one pass over the output) and
        // the fused backward's prologue buffer is part of the workspace —
        // both must stay zero-allocation in steady state.
        let bias = rnd(k, 5);
        let residual = rnd(n * k * p.q(), 6);
        plan.set_post_ops(PostOps::bias_relu_residual());
        plan.set_bias(&bias);
        let mut y = vec![0.0f32; n * k * p.q()];
        let mut gb = vec![0.0f32; k];
        let mut gres = vec![0.0f32; n * k * p.q()];
        // Warm once (bias copy + gpre growth happen here).
        plan.execute_forward_post_into(&x, Some(&residual), &mut y);
        plan.execute_backward_fused_into(
            &gout,
            &y,
            &x,
            Some(&mut gin),
            &mut gw,
            Some(&mut gb),
            Some(&mut gres),
        );
        let fwd_post =
            allocs_during(|| plan.execute_forward_post_into(&x, Some(&residual), &mut y));
        assert_eq!(fwd_post, 0, "{kernel}: execute_forward_post_into allocated");
        let bwd_fused = allocs_during(|| {
            plan.execute_backward_fused_into(
                &gout,
                &y,
                &x,
                Some(&mut gin),
                &mut gw,
                Some(&mut gb),
                Some(&mut gres),
            )
        });
        assert_eq!(bwd_fused, 0, "{kernel}: execute_backward_fused_into allocated");
    }
}
