//! Integration: halo-overlapped streaming inference (DESIGN.md §7b).
//!
//! The load-bearing guarantee is **bit-identity**: stitching fixed-width
//! windows that overlap by the receptive-field reach must produce
//! exactly the bits that evaluating the whole sequence in one pass
//! produces. The matrix here covers signals ≥ 4 windows long ×
//! {f32, bf16, i8} × {batch, grid} × two dilation schedules, compared as
//! `f32::to_bits` vectors (no tolerance anywhere), plus the streaming
//! route end-to-end through the server. The i8 column holds because
//! activation scales are fixed at engine construction, so a halo window
//! quantizes exactly like the whole sequence.

use std::time::Duration;

use dilconv1d::conv1d::Partition;
use dilconv1d::machine::Precision;
use dilconv1d::model::{AtacWorksNet, NetConfig};
use dilconv1d::serve::{
    round_up_to_block, BatcherOpts, BucketSet, EngineOpts, InferenceEngine, StreamingSession,
};
use dilconv1d::util::rng::Rng;

/// The two model geometries under test: the tiny config (S=9, d=2 →
/// reach 32) and a second dilation schedule (S=5, d=3, deeper → 36).
fn geometries() -> Vec<(NetConfig, &'static str)> {
    vec![
        (NetConfig::tiny(), "tiny S9 d2"),
        (
            NetConfig {
                channels: 3,
                n_blocks: 2,
                filter_size: 5,
                dilation: 3,
            },
            "deep S5 d3",
        ),
    ]
}

fn engine_opts(buckets: &[usize], precision: Precision, partition: Partition) -> EngineOpts {
    EngineOpts {
        buckets: BucketSet::new(buckets).expect("bucket widths"),
        max_batch: 1,
        threads: 2,
        precision,
        partition,
        cache_capacity: buckets.len(),
        ..EngineOpts::default()
    }
}

fn track(w: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..w).map(|_| rng.poisson(0.8) as f32).collect()
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn streaming_is_bit_identical_to_whole_sequence_evaluation() {
    const WINDOW: usize = 128;
    // ≥ 4 windows long, and deliberately not window-aligned.
    let lens = [700usize, 4 * WINDOW, 5 * WINDOW + 17];
    for (cfg, name) in geometries() {
        let reach = cfg.receptive_field_reach();
        assert!(
            WINDOW > 2 * reach,
            "{name}: window {WINDOW} must fit two halos ({reach})"
        );
        let params = AtacWorksNet::init(cfg, 42).pack_params();
        for precision in [Precision::F32, Precision::Bf16, Precision::I8] {
            for partition in [Partition::Batch, Partition::Grid] {
                for (i, &len) in lens.iter().enumerate() {
                    let signal = track(len, 1000 + i as u64);
                    // Whole-sequence reference: one bucket wide enough
                    // for the entire signal, no streaming involved.
                    let mut whole = InferenceEngine::new(
                        cfg,
                        &params,
                        engine_opts(&[round_up_to_block(len)], precision, partition),
                    )
                    .expect("whole-sequence engine");
                    let want = whole.infer_one(&signal).expect("reference");
                    // Streamed: window-sized buckets only.
                    let mut windowed = InferenceEngine::new(
                        cfg,
                        &params,
                        engine_opts(&[WINDOW], precision, partition),
                    )
                    .expect("windowed engine");
                    let mut session =
                        StreamingSession::new(&mut windowed, WINDOW).expect("session");
                    let got = session.infer(&signal).expect("streamed");
                    assert_eq!(
                        bits(&got.denoised),
                        bits(&want.denoised),
                        "{name}/{precision:?}/{partition}/len {len}: denoised bits diverged"
                    );
                    assert_eq!(
                        bits(&got.logits),
                        bits(&want.logits),
                        "{name}/{precision:?}/{partition}/len {len}: logits bits diverged"
                    );
                }
            }
        }
    }
}

#[test]
fn streamed_spans_cover_the_signal_once_in_order() {
    let cfg = NetConfig::tiny();
    let params = AtacWorksNet::init(cfg, 42).pack_params();
    let mut engine = InferenceEngine::new(
        cfg,
        &params,
        engine_opts(&[128], Precision::F32, Partition::Batch),
    )
    .expect("engine");
    let mut session = StreamingSession::new(&mut engine, 128).expect("session");
    let signal = track(903, 7);
    let mut next = 0usize;
    let stats = session
        .infer_with(&signal, |start, d, l| {
            assert_eq!(start, next, "spans arrive contiguous and in order");
            assert_eq!(d.len(), l.len());
            next += d.len();
        })
        .expect("stream");
    assert_eq!(next, signal.len());
    assert_eq!(stats.emitted, signal.len());
    // Window k starts at 64·(k-1); the final window is the first whose
    // end reaches the signal, so 903 columns take ⌈(903−128)/64⌉+1 = 14.
    assert_eq!(stats.windows, (903usize - 128).div_ceil(64) + 1);
}

#[test]
fn server_streams_over_wide_requests_end_to_end() {
    let cfg = NetConfig::tiny();
    let params = AtacWorksNet::init(cfg, 42).pack_params();
    let server = dilconv1d::serve::Server::start(
        cfg,
        &params,
        BatcherOpts {
            engine: engine_opts(&[128, 256], Precision::F32, Partition::Batch),
            window: Duration::from_millis(1),
            queue_depth: 16,
            workers: 2,
            warm: false,
            stream_window: Some(128),
            ..BatcherOpts::default()
        },
    )
    .expect("server");
    // Mixed traffic: two streamed signals and one in-bucket request.
    let long_a = track(700, 31);
    let long_b = track(520, 32);
    let short = track(200, 33);
    let ta = server.submit(long_a.clone()).expect("stream a");
    let tb = server.submit(long_b.clone()).expect("stream b");
    let ts = server.submit(short.clone()).expect("batched");
    let ra = ta.wait().expect("a");
    let rb = tb.wait().expect("b");
    let rs = ts.wait().expect("s");
    assert!(ra.streamed && rb.streamed && !rs.streamed);
    assert_eq!((ra.bucket, ra.batch_rows), (128, 1));
    assert_eq!(rs.bucket, 256);
    // Streamed responses equal whole-sequence evaluation, bit for bit.
    for (signal, resp) in [(&long_a, &ra), (&long_b, &rb)] {
        let mut whole = InferenceEngine::new(
            cfg,
            &params,
            engine_opts(
                &[round_up_to_block(signal.len())],
                Precision::F32,
                Partition::Batch,
            ),
        )
        .expect("reference engine");
        let want = whole.infer_one(signal).expect("reference");
        assert_eq!(bits(&resp.output.denoised), bits(&want.denoised));
        assert_eq!(bits(&resp.output.logits), bits(&want.logits));
    }
    let m = server.shutdown();
    assert_eq!(m.completed, 3);
    assert_eq!(m.streamed, 2);
    // Windows per stream: ⌈(len−window)/core⌉+1 → 700 takes 10, 520 takes 8.
    assert_eq!(m.stream_windows, 10 + 8);
}
