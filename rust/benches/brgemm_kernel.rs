//! BENCH — the BRGEMM primitive itself (paper eq. 3 / Sec. 3):
//! GFLOP/s of the micro-kernel across the (m=K, n=64, k=C) shapes the
//! convolution produces, and the effect of the batch-reduce length l_br
//! (= filter width S). This is the §Perf working bench: the hot path all
//! three passes stand on.

use dilconv1d::bench_harness::{self, time_auto};
use dilconv1d::conv1d::bf16::to_bf16;
use dilconv1d::conv1d::brgemm::{brgemm_bf16_with, brgemm_f32, brgemm_f32_with, brgemm_i8_with};
use dilconv1d::conv1d::gemm::gemm_f32;
use dilconv1d::conv1d::simd::{active, Isa, MicroKernelSet};
use dilconv1d::conv1d::test_util::rnd;

/// Quantize a bench operand onto the full i8 range (inputs are in
/// `[-0.5, 0.5)`, so ×254 spans `[-127, 127]`).
fn to_i8(v: &[f32]) -> Vec<i8> {
    v.iter().map(|x| (x * 254.0).round() as i8).collect()
}

fn main() {
    let smoke = bench_harness::smoke();
    let budget = if smoke { 0.02 } else { 0.2 };
    let min_reps = if smoke { 1 } else { 10 };
    println!("# small-GEMM micro-kernel: C[m,64] += A[m,k] B[k,64]");
    println!("{:>4} {:>4} | {:>9} | {:>8}", "m", "k", "time", "GF/s");
    for &(m, k) in &[(1usize, 1usize), (4, 4), (8, 8), (15, 15), (16, 16), (32, 32), (64, 64)] {
        let n = 64;
        let a = rnd(m * k, 1);
        let b = rnd(k * n, 2);
        let mut c = vec![0.0f32; m * n];
        let t = time_auto(budget, min_reps, || {
            gemm_f32(&a, k, &b, n, &mut c, n, m, n, k);
            std::hint::black_box(&c);
        });
        let fl = 2.0 * (m * n * k) as f64;
        println!(
            "{m:>4} {k:>4} | {:>7.2}µs | {:>8.2}",
            t.median_secs * 1e6,
            fl / t.median_secs / 1e9
        );
    }

    println!("\n# BRGEMM: l_br sweep at the AtacWorks shape (m=15, n=64, k=15)");
    println!("{:>5} | {:>9} | {:>8} | vs l_br x single GEMMs", "l_br", "time", "GF/s");
    let (m, n, k) = (15usize, 64usize, 15usize);
    for &lbr in &[1usize, 5, 9, 21, 51] {
        let a = rnd(lbr * m * k, 3);
        let b = rnd(lbr * k * n, 4);
        let a_offs: Vec<usize> = (0..lbr).map(|i| i * m * k).collect();
        let b_offs: Vec<usize> = (0..lbr).map(|i| i * k * n).collect();
        let mut c = vec![0.0f32; m * n];
        let t = time_auto(budget, min_reps, || {
            brgemm_f32(&a, &a_offs, k, &b, &b_offs, n, &mut c, n, m, n, k, true);
            std::hint::black_box(&c);
        });
        // Serial-GEMM comparison (C re-loaded/stored l_br times).
        let mut c2 = vec![0.0f32; m * n];
        let t2 = time_auto(budget, min_reps, || {
            c2.fill(0.0);
            for i in 0..lbr {
                gemm_f32(&a[a_offs[i]..], k, &b[b_offs[i]..], n, &mut c2, n, m, n, k);
            }
            std::hint::black_box(&c2);
        });
        let fl = 2.0 * (m * n * k * lbr) as f64;
        println!(
            "{lbr:>5} | {:>7.2}µs | {:>8.2} | {:.2}x faster than serial GEMMs",
            t.median_secs * 1e6,
            fl / t.median_secs / 1e9,
            t2.median_secs / t.median_secs,
        );
    }
    // Per-ISA rows: the explicit SIMD row kernels at the AtacWorks and
    // Fig. 5 block shapes, across the precision ladder (f32 / bf16 /
    // i8·i32-accumulate). The dispatched ISA (env CONV1D_FORCE_ISA
    // honoured) is marked with '*'.
    println!("\n# per-ISA BRGEMM micro-kernels (n=64 width block)");
    println!(
        "{:>8} {:>4} {:>4} {:>5} | {:>10} | {:>8} | {:>10} | {:>10}",
        "isa", "m", "k", "l_br", "f32 GF/s", "vs scal", "bf16 GF/s", "i8 GOP/s"
    );
    let mut rows = String::new();
    for &(m, k, lbr) in &[(15usize, 15usize, 51usize), (64, 64, 5)] {
        let n = 64usize;
        let a = rnd(lbr * m * k, 5);
        let b = rnd(lbr * k * n, 6);
        let (a16, b16) = (to_bf16(&a), to_bf16(&b));
        let (a8, b8) = (to_i8(&a), to_i8(&b));
        let a_offs: Vec<usize> = (0..lbr).map(|i| i * m * k).collect();
        let b_offs: Vec<usize> = (0..lbr).map(|i| i * k * n).collect();
        let fl = 2.0 * (m * n * k * lbr) as f64;
        let mut scalar_gf = 0.0f64;
        for isa in Isa::ALL {
            let set = MicroKernelSet::for_isa(isa);
            if set.isa() != isa {
                println!(
                    "{:>8} {m:>4} {k:>4} {lbr:>5} | unavailable on this host/build",
                    isa.name()
                );
                continue;
            }
            let mut c = vec![0.0f32; m * n];
            let t = time_auto(budget, min_reps, || {
                brgemm_f32_with(set, &a, &a_offs, k, &b, &b_offs, n, &mut c, n, m, n, k, true);
                std::hint::black_box(&c);
            });
            let gf = fl / t.median_secs / 1e9;
            if isa == Isa::Scalar {
                scalar_gf = gf;
            }
            let mut cb = vec![0.0f32; m * n];
            let tb = time_auto(budget, min_reps, || {
                brgemm_bf16_with(
                    set, &a16, &a_offs, k, &b16, &b_offs, n, &mut cb, n, m, n, k, true,
                );
                std::hint::black_box(&cb);
            });
            let mut ci = vec![0i32; m * n];
            let ti = time_auto(budget, min_reps, || {
                brgemm_i8_with(set, &a8, &a_offs, k, &b8, &b_offs, n, &mut ci, n, m, n, k, true);
                std::hint::black_box(&ci);
            });
            let (bf_gf, i8_gf) = (fl / tb.median_secs / 1e9, fl / ti.median_secs / 1e9);
            println!(
                "{:>7}{} {m:>4} {k:>4} {lbr:>5} | {gf:>10.2} | {:>7.2}x | {bf_gf:>10.2} | {i8_gf:>10.2}",
                isa.name(),
                if active().isa() == isa { '*' } else { ' ' },
                gf / scalar_gf.max(1e-12),
            );
            if !rows.is_empty() {
                rows.push_str(",\n");
            }
            rows.push_str(&format!(
                "    {{\"isa\": \"{}\", \"m\": {m}, \"k\": {k}, \"l_br\": {lbr}, \
                 \"f32_gflops\": {gf:.2}, \"bf16_gflops\": {bf_gf:.2}, \"i8_gops\": {i8_gf:.2}}}",
                isa.name()
            ));
        }
    }

    // Bench trajectory rows (BENCH_*.json at the repo root): one row per
    // (ISA, shape) with all three precision tiers side by side.
    let json = format!(
        "{{\n  \"bench\": \"brgemm_kernel\",\n  \"smoke\": {smoke},\n  \"rows\": [\n{rows}\n  ]\n}}\n"
    );
    let out_path = if std::path::Path::new("../CHANGES.md").exists() {
        "../BENCH_brgemm.json"
    } else {
        "BENCH_brgemm.json"
    };
    match std::fs::write(out_path, &json) {
        Ok(()) => println!("bench rows written to {out_path}"),
        Err(e) => eprintln!("WARN: could not write {out_path}: {e}"),
    }
    println!("\nbrgemm_kernel bench done");
}
