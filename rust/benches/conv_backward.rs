//! BENCH — Fig. 4/5 (backward passes): Algorithm 3 (backward-data,
//! BRGEMM) and Algorithm 4 (backward-weight, small GEMMs) across the
//! paper's width/filter grid. The paper notes backward-weight is the
//! least efficient kernel — the printed efficiency gap reproduces that.

use dilconv1d::bench_harness::{self, run_point, Pass, SweepConfig};
use dilconv1d::conv1d::Backend;
use dilconv1d::machine::{calibrate_host, MachineSpec, Precision};

fn main() {
    let smoke = bench_harness::smoke();
    let quick = std::env::var("BENCH_FULL").is_err();
    let host = calibrate_host();
    println!("conv_backward: host ≈ {host:.2} GFLOP/s (1 core)");
    let cfg = SweepConfig {
        batch: 2,
        reps: if smoke { 1 } else if quick { 2 } else { 5 },
        max_measured_q: if quick { 10_000 } else { 60_000 },
        host_gflops_peak: host,
        threads: 1,
    };
    let clx = MachineSpec::cascade_lake();
    let widths: &[usize] = if smoke {
        &[1_000]
    } else if quick {
        &[1_000, 5_000, 10_000]
    } else {
        &[1_000, 5_000, 20_000, 60_000]
    };
    println!("{:>6} {:>3} | {:>12} {:>7} | {:>12} {:>7} | bwd-w/bwd-d ratio", "Q", "S", "bwd-data", "eff", "bwd-weight", "eff");
    for &s in &[5usize, 21, 51] {
        for &q in widths {
            let bd = run_point(&cfg, 15, 15, q, s, 8, Pass::BackwardData, Backend::Brgemm, Precision::F32, &clx);
            let bw = run_point(&cfg, 15, 15, q, s, 8, Pass::BackwardWeight, Backend::Brgemm, Precision::F32, &clx);
            println!(
                "{q:>6} {s:>3} | {:>10.2}ms {:>6.1}% | {:>10.2}ms {:>6.1}% | {:.2}x",
                bd.timing.median_secs * 1e3,
                bd.host_eff * 100.0,
                bw.timing.median_secs * 1e3,
                bw.host_eff * 100.0,
                bw.timing.median_secs / bd.timing.median_secs,
            );
        }
    }
    println!("\nconv_backward bench done");
}
