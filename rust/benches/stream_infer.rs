//! BENCH — halo-overlapped streaming inference (DESIGN.md §7b):
//! fixed-memory windowed evaluation vs whole-sequence evaluation on one
//! long signal. For each window size the bench asserts **bit-identity**
//! against the whole-sequence reference, then reports sustained cols/s,
//! the stitch overhead (a window recomputes its two halos, so ideal cost
//! grows by window/core), and the plan-workspace footprint that streaming
//! caps at O(window). Rows are written to `BENCH_stream.json`.
//!
//! `BENCH_SMOKE=1` shrinks to the tiny model geometry and a 4096-column
//! signal. Under `BENCH_STRICT` the windowed plan workspace must stay
//! strictly below the whole-sequence plan workspace — that inequality is
//! the subsystem's reason to exist.

use dilconv1d::bench_harness::{self, time_fn};
use dilconv1d::conv1d::Partition;
use dilconv1d::machine::Precision;
use dilconv1d::model::{AtacWorksNet, NetConfig};
use dilconv1d::serve::{
    round_up_to_block, BucketSet, EngineOpts, InferenceEngine, StreamingSession,
};
use dilconv1d::util::rng::Rng;

fn engine(cfg: NetConfig, params: &[f32], bucket: usize, threads: usize) -> InferenceEngine {
    InferenceEngine::new(
        cfg,
        params,
        EngineOpts {
            buckets: BucketSet::new(&[bucket]).expect("bucket width"),
            max_batch: 1,
            threads,
            precision: Precision::F32,
            partition: Partition::Grid,
            cache_capacity: 1,
            ..EngineOpts::default()
        },
    )
    .expect("engine")
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

struct Row {
    window: usize,
    halo: usize,
    core: usize,
    windows: usize,
    median_ms: f64,
    cols_per_sec: f64,
    workspace_bytes: usize,
}

fn main() {
    let smoke = bench_harness::smoke();
    let threads = 4usize;
    // Window sweep + signal length. The full geometry keeps the halo
    // moderate (reach 300) so kilobyte-scale windows are legal; the
    // paper-default schedule (reach 4800) needs > 9600-wide windows and
    // is covered by the config-level auto-resolution rules instead.
    let (cfg, windows, seq_len, reps) = if smoke {
        (NetConfig::tiny(), vec![128usize, 256, 384], 4_096usize, 2usize)
    } else {
        (
            NetConfig {
                channels: 15,
                n_blocks: 2,
                filter_size: 51,
                dilation: 2,
            },
            vec![1_024usize, 2_048, 4_096],
            16_384usize,
            5usize,
        )
    };
    let reach = cfg.receptive_field_reach();
    let params = AtacWorksNet::init(cfg, 42).pack_params();
    let mut rng = Rng::new(7);
    let signal: Vec<f32> = (0..seq_len).map(|_| rng.poisson(0.8) as f32).collect();

    println!(
        "# stream_infer: {seq_len}-col signal, reach {reach}, windows {windows:?}, \
         {threads} threads{}",
        if smoke { " [SMOKE]" } else { "" },
    );

    // Whole-sequence reference: one bucket wide enough for the signal.
    let mut whole = engine(cfg, &params, round_up_to_block(seq_len), threads);
    let want = whole.infer_one(&signal).expect("whole-sequence reference");
    let t_whole = time_fn(1, reps, || {
        let r = whole.infer_one(&signal).expect("whole-sequence inference");
        std::hint::black_box(&r);
    });
    let ws_whole = whole.plan_workspace_bytes();
    let whole_cols = seq_len as f64 / t_whole.median_secs;
    println!(
        "whole-sequence   bucket {:>5}  {:>8.2} ms  {:>10.0} cols/s  workspace {:>8} B",
        round_up_to_block(seq_len),
        t_whole.median_secs * 1e3,
        whole_cols,
        ws_whole,
    );

    let mut rows: Vec<Row> = Vec::new();
    for &window in &windows {
        let mut eng = engine(cfg, &params, window, threads);
        let (t, stats, halo, core) = {
            let mut session = StreamingSession::new(&mut eng, window).expect("session");
            // Bit-identity gate before anything is timed: stitched
            // windows must reproduce the whole-sequence bits exactly.
            let got = session.infer(&signal).expect("streamed inference");
            assert_eq!(
                bits(&got.denoised),
                bits(&want.denoised),
                "window {window}: denoised bits diverged from whole-sequence"
            );
            assert_eq!(
                bits(&got.logits),
                bits(&want.logits),
                "window {window}: logits bits diverged from whole-sequence"
            );
            let stats = session
                .infer_with(&signal, |_, _, _| {})
                .expect("window count");
            let t = time_fn(1, reps, || {
                let mut acc = 0.0f32;
                session
                    .infer_with(&signal, |_, d, _| acc += d[0])
                    .expect("streamed inference");
                std::hint::black_box(acc);
            });
            (t, stats, session.halo(), session.core())
        };
        let ws = eng.plan_workspace_bytes();
        let cols = seq_len as f64 / t.median_secs;
        // A window re-derives its two halos, so ideal overhead is
        // window/core; report measured cost against the whole pass.
        println!(
            "window {window:>5} (halo {halo:>4}, {:>3} windows)  {:>8.2} ms  {:>10.0} cols/s  \
             {:.2}x whole  workspace {:>8} B",
            stats.windows,
            t.median_secs * 1e3,
            cols,
            t.median_secs / t_whole.median_secs,
            ws,
        );
        if ws >= ws_whole {
            eprintln!(
                "WARN: window {window} plan workspace {ws} B not below whole-sequence \
                 {ws_whole} B"
            );
        }
        if bench_harness::strict() {
            assert!(
                ws < ws_whole,
                "streaming must cap the plan workspace below the whole-sequence plan: \
                 window {window} used {ws} B vs {ws_whole} B"
            );
        }
        rows.push(Row {
            window,
            halo,
            core,
            windows: stats.windows,
            median_ms: t.median_secs * 1e3,
            cols_per_sec: cols,
            workspace_bytes: ws,
        });
    }

    // Bench trajectory rows (BENCH_*.json at the repo root).
    let mut json = format!(
        "{{\n  \"bench\": \"stream_infer\",\n  \"smoke\": {smoke},\n  \"threads\": {threads},\n  \
         \"seq_len\": {seq_len},\n  \"reach\": {reach},\n  \
         \"whole_bucket\": {},\n  \"whole_ms\": {:.4},\n  \"whole_cols_per_sec\": {:.1},\n  \
         \"whole_workspace_bytes\": {ws_whole},\n  \"rows\": [\n",
        round_up_to_block(seq_len),
        t_whole.median_secs * 1e3,
        whole_cols,
    );
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"window\": {}, \"halo\": {}, \"core\": {}, \"windows\": {}, \
             \"median_ms\": {:.4}, \"cols_per_sec\": {:.1}, \"workspace_bytes\": {}, \
             \"overhead_vs_whole\": {:.4}}}{}\n",
            r.window,
            r.halo,
            r.core,
            r.windows,
            r.median_ms,
            r.cols_per_sec,
            r.workspace_bytes,
            r.median_ms / (t_whole.median_secs * 1e3),
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    // Benches run from rust/; place the trajectory file at the repo root
    // when it is visible, else in the working directory.
    let out_path = if std::path::Path::new("../CHANGES.md").exists() {
        "../BENCH_stream.json"
    } else {
        "BENCH_stream.json"
    };
    match std::fs::write(out_path, &json) {
        Ok(()) => println!("bench rows written to {out_path}"),
        Err(e) => eprintln!("WARN: could not write {out_path}: {e}"),
    }
    println!("stream_infer bench done");
}
