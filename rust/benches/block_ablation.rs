//! BENCH — ablation of the paper's design choices (DESIGN.md §8):
//!
//! 1. **Width-block length**: the paper fixes the cache block at 64
//!    (Sec. 3, LIBXSMM's `(mnk)^{1/3} ≤ 64` heuristic). Sweep
//!    WB ∈ {16..128} at the AtacWorks shape to show 64 is (near-)optimal
//!    and that the register-resident specialisation at 64 matters.
//! 2. **Batch-reduce vs serial GEMMs**: the BRGEMM accumulator-residency
//!    advantage as a function of the tap count (covered in more depth by
//!    `brgemm_kernel.rs`).

use dilconv1d::bench_harness::{self, time_fn};
use dilconv1d::conv1d::forward::forward_single_wb;
use dilconv1d::conv1d::layout::kcs_to_skc;
use dilconv1d::conv1d::test_util::rnd;
use dilconv1d::conv1d::ConvParams;
use dilconv1d::machine::gflops;

fn main() {
    let smoke = bench_harness::smoke();
    let q_pick = if smoke { 2_000usize } else { 10_000 };
    let (c, k, s, d, q) = (15usize, 15usize, 51usize, 8usize, q_pick);
    let p = ConvParams::new(1, c, k, q + (s - 1) * d, s, d).unwrap();
    let x = rnd(p.c * p.w, 1);
    let wt = rnd(k * c * s, 2);
    let skc = kcs_to_skc(&wt, k, c, s);
    let mut out = vec![0.0f32; k * p.q()];
    println!("# width-block ablation at the AtacWorks shape ({p})");
    println!("{:>4} | {:>10} | {:>8} | note", "WB", "median", "GF/s");
    let mut best = (0usize, f64::INFINITY);
    let reps = if smoke { 1 } else { 5 };
    for &wb in &[16usize, 32, 48, 64, 96, 128] {
        let t = time_fn(1, reps, || {
            forward_single_wb(&p, &x, &skc, &mut out, wb);
            std::hint::black_box(&out);
        });
        if t.median_secs < best.1 {
            best = (wb, t.median_secs);
        }
        println!(
            "{wb:>4} | {:>8.2}ms | {:>8.2} | {}",
            t.median_secs * 1e3,
            gflops(p.flops(), t.median_secs),
            if wb == 64 { "paper's choice (+ n=64 fast path)" } else { "" },
        );
    }
    println!("best WB = {} ({:.2}ms)", best.0, best.1 * 1e3);

    // Sanity: all block sizes compute the same function.
    let mut ref_out = vec![0.0f32; k * p.q()];
    forward_single_wb(&p, &x, &skc, &mut ref_out, 64);
    for &wb in &[16usize, 48, 128] {
        let mut o = vec![0.0f32; k * p.q()];
        forward_single_wb(&p, &x, &skc, &mut o, wb);
        let max_err = o
            .iter()
            .zip(&ref_out)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < 1e-3, "WB={wb} diverged: {max_err}");
    }
    println!("all block sizes agree numerically ✓");
    println!("\nblock_ablation bench done");
}
