//! BENCH — Table 1 / Fig. 7 (end-to-end training epoch): measured epoch
//! time of the full 25-layer AtacWorks-like network at host scale under
//! the BRGEMM backend vs the im2col library baseline, plus the machine
//! model's paper-scale Table 1 projection.

use dilconv1d::config::TrainConfig;
use dilconv1d::conv1d::Backend;
use dilconv1d::coordinator::{experiment, Trainer};
use dilconv1d::dist::{CommModel, Topology};
use dilconv1d::machine::workload::{model_epoch, Workload};
use dilconv1d::machine::{MachineSpec, Precision, Strategy};

fn main() {
    println!("# measured: one epoch of the 25-layer network (scaled: W=1000, 16 segments)");
    let mut measured = Vec::new();
    for (label, backend) in [("BRGEMM (ours)", Backend::Brgemm), ("im2col (oneDNN-analog)", Backend::Im2col)] {
        let cfg = TrainConfig {
            segment_width: 1_000,
            segment_pad: 100,
            train_segments: 16,
            batch_size: 4,
            epochs: 1,
            backend,
            ..TrainConfig::default()
        };
        let mut t = Trainer::new(cfg).expect("trainer");
        let r = t.run_epoch(0);
        println!(
            "{label:<24} epoch {:>7.2}s  (train {:.2}s eval {:.2}s, loss {:.4})",
            r.timing.total(),
            r.timing.train_secs,
            r.timing.eval_secs,
            r.train_loss
        );
        measured.push((label, r.timing.train_secs));
    }
    if measured.len() == 2 {
        println!(
            "measured train-epoch speedup BRGEMM vs baseline: {:.2}x (paper Table 1: 6.86x at full scale on 28-core CLX)",
            measured[1].1 / measured[0].1
        );
    }

    println!("\n# modeled: paper-scale Table 1 (single socket)");
    let w = Workload::paper();
    let comm = CommModel::upi();
    for row in experiment::TABLE1 {
        if row.device == "1 V100" {
            continue;
        }
        let (spec, prec, strat) = match (row.device, row.code, row.precision) {
            ("1s CLX", "oneDNN", _) => (MachineSpec::cascade_lake(), Precision::F32, Strategy::Im2col),
            ("1s CLX", _, _) => (MachineSpec::cascade_lake(), Precision::F32, Strategy::Brgemm),
            ("1s CPX", _, "BF16") => (MachineSpec::cooper_lake(), Precision::Bf16, Strategy::Brgemm),
            _ => (MachineSpec::cooper_lake(), Precision::F32, Strategy::Brgemm),
        };
        let t = model_epoch(&w, &spec, prec, strat, &Topology::xeon(1), &comm);
        println!(
            "{} {} ({}): modeled {:>8.1}s | paper {:>8.1}s",
            row.device, row.code, row.precision, t.total(), row.time_per_epoch
        );
    }
    println!("\ne2e_epoch bench done");
}
