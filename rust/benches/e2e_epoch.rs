//! BENCH — Table 1 / Fig. 7 (end-to-end training epoch): measured epoch
//! time of the full 25-layer AtacWorks-like network at host scale under
//! the BRGEMM backend vs the im2col library baseline, the machine
//! model's paper-scale Table 1 projection, and the distributed-training
//! grid (DESIGN.md §6): {f32, bf16} × {monolithic, bucketed+overlapped
//! all-reduce} at 4 in-process sockets, written to `BENCH_e2e_epoch.json`.

use dilconv1d::bench_harness;
use dilconv1d::config::TrainConfig;
use dilconv1d::conv1d::Backend;
use dilconv1d::coordinator::{experiment, EpochReport, Trainer};
use dilconv1d::dist::{CommModel, Topology};
use dilconv1d::machine::workload::{model_epoch, Workload};
use dilconv1d::machine::{MachineSpec, Precision, Strategy};

/// One epoch of the 25-layer AtacWorks shape (scaled width) under the
/// given precision / all-reduce mode / socket count. Best-of-3 on train
/// wall-clock (fresh, identically-seeded trainer per rep) to keep the
/// monolithic-vs-overlap comparison out of scheduler noise.
fn run_case(precision: Precision, overlap: bool, sockets: usize) -> EpochReport {
    let mut best: Option<EpochReport> = None;
    let (reps, width, segments) = if bench_harness::smoke() {
        (1, 400, 8)
    } else {
        (3, 1_000, 16)
    };
    for _ in 0..reps {
        let cfg = TrainConfig {
            segment_width: width,
            segment_pad: width / 10,
            train_segments: segments,
            batch_size: 4,
            epochs: 1,
            sockets,
            precision,
            overlap,
            // ~1 MB of gradients for the default net: a 0.25 MiB budget
            // cuts it into a handful of buckets, enough to overlap.
            bucket_mb: 0.25,
            ..TrainConfig::default()
        };
        let mut t = Trainer::new(cfg).expect("trainer");
        let r = t.run_epoch(0);
        let better = match &best {
            None => true,
            Some(b) => r.timing.train_secs < b.timing.train_secs,
        };
        if better {
            best = Some(r);
        }
    }
    best.expect("at least one rep ran")
}

fn main() {
    let (width, segments) = if bench_harness::smoke() { (400, 8) } else { (1_000, 16) };
    println!(
        "# measured: one epoch of the 25-layer network (scaled: W={width}, {segments} segments)"
    );
    let mut measured = Vec::new();
    for (label, backend) in [
        ("BRGEMM (ours)", Backend::Brgemm),
        ("im2col (oneDNN-analog)", Backend::Im2col),
    ] {
        let cfg = TrainConfig {
            segment_width: width,
            segment_pad: width / 10,
            train_segments: segments,
            batch_size: 4,
            epochs: 1,
            backend,
            ..TrainConfig::default()
        };
        let mut t = Trainer::new(cfg).expect("trainer");
        let r = t.run_epoch(0);
        println!(
            "{label:<24} epoch {:>7.2}s  (train {:.2}s eval {:.2}s, loss {:.4})",
            r.timing.total(),
            r.timing.train_secs,
            r.timing.eval_secs,
            r.train_loss
        );
        measured.push((label, r.timing.train_secs));
    }
    if measured.len() == 2 {
        println!(
            "measured train-epoch speedup BRGEMM vs baseline: {:.2}x (paper Table 1: 6.86x at full scale on 28-core CLX)",
            measured[1].1 / measured[0].1
        );
    }

    // ---- distributed-training grid (DESIGN.md §6) ----
    // {f32, bf16} × {monolithic, bucketed+overlap} at 4 in-process
    // sockets. "total (model)" = measured train wall-clock + the α–β
    // model's *exposed* communication on the paper's links — the epoch
    // time the paper's multi-socket board would see. Overlap hides most
    // of the collective behind backward, so its total is lower.
    let sockets = 4;
    println!(
        "\n# distributed grid: {{f32, bf16}} x {{monolithic, bucketed+overlap}} at {sockets} sockets"
    );
    println!(
        "{:<10} {:<20} {:>9} {:>12} {:>12} {:>13} {:>9}",
        "precision", "all-reduce", "train s", "comm(model)", "exposed", "total (model)", "loss"
    );
    let mut rows = Vec::new();
    for (prec, pname) in [(Precision::F32, "f32"), (Precision::Bf16, "bf16")] {
        for (overlap, mode) in [(false, "monolithic"), (true, "bucketed+overlap")] {
            let r = run_case(prec, overlap, sockets);
            let total_model = r.timing.train_secs + r.exposed_comm_secs;
            println!(
                "{:<10} {:<20} {:>9.2} {:>12.4} {:>12.4} {:>13.2} {:>9.4}",
                pname,
                mode,
                r.timing.train_secs,
                r.modeled_comm_secs,
                r.exposed_comm_secs,
                total_model,
                r.train_loss
            );
            rows.push((pname, mode, r, total_model));
        }
    }
    for pname in ["f32", "bf16"] {
        let mono = rows
            .iter()
            .find(|row| row.0 == pname && row.1 == "monolithic")
            .expect("monolithic row");
        let over = rows
            .iter()
            .find(|row| row.0 == pname && row.1 == "bucketed+overlap")
            .expect("overlap row");
        println!(
            "{pname}: overlap hides {:.1}% of the collective; modeled epoch {:.3}s -> {:.3}s",
            100.0 * (1.0 - over.2.exposed_comm_secs / over.2.modeled_comm_secs.max(1e-12)),
            mono.3,
            over.3
        );
        let regressed = over.3 > mono.3;
        if regressed {
            eprintln!(
                "WARN: bucketed+overlap modeled epoch not below monolithic ({} vs {})",
                over.3, mono.3
            );
        }
        if bench_harness::strict() {
            assert!(
                !regressed,
                "{pname}: bucketed+overlap must beat monolithic at {sockets} sockets: {} vs {}",
                over.3, mono.3
            );
        }
    }

    // Bench trajectory rows (BENCH_*.json at the repo root).
    let mut json = String::from(
        "{\n  \"bench\": \"e2e_epoch\",\n  \"shape\": \"atacworks_25layer_W1000\",\n  \
         \"sockets\": 4,\n  \"rows\": [\n",
    );
    for (i, (pname, mode, r, total_model)) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"precision\": \"{}\", \"allreduce\": \"{}\", \"train_secs\": {:.4}, \
             \"comm_model_secs\": {:.6}, \"exposed_comm_secs\": {:.6}, \
             \"total_modeled_secs\": {:.4}, \"loss\": {:.6}}}{}\n",
            pname,
            mode,
            r.timing.train_secs,
            r.modeled_comm_secs,
            r.exposed_comm_secs,
            total_model,
            r.train_loss,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    // Benches run from rust/; place the trajectory file at the repo root
    // when it is visible, else in the working directory.
    let out_path = if std::path::Path::new("../CHANGES.md").exists() {
        "../BENCH_e2e_epoch.json"
    } else {
        "BENCH_e2e_epoch.json"
    };
    match std::fs::write(out_path, &json) {
        Ok(()) => println!("bench rows written to {out_path}"),
        Err(e) => eprintln!("WARN: could not write {out_path}: {e}"),
    }

    println!("\n# modeled: paper-scale Table 1 (single socket)");
    let w = Workload::paper();
    let comm = CommModel::upi();
    for row in experiment::TABLE1 {
        if row.device == "1 V100" {
            continue;
        }
        let (spec, prec, strat) = match (row.device, row.code, row.precision) {
            ("1s CLX", "oneDNN", _) => (MachineSpec::cascade_lake(), Precision::F32, Strategy::Im2col),
            ("1s CLX", _, _) => (MachineSpec::cascade_lake(), Precision::F32, Strategy::Brgemm),
            ("1s CPX", _, "BF16") => (MachineSpec::cooper_lake(), Precision::Bf16, Strategy::Brgemm),
            _ => (MachineSpec::cooper_lake(), Precision::F32, Strategy::Brgemm),
        };
        let t = model_epoch(&w, &spec, prec, strat, &Topology::xeon(1), &comm);
        println!(
            "{} {} ({}): modeled {:>8.1}s | paper {:>8.1}s",
            row.device, row.code, row.precision, t.total(), row.time_per_epoch
        );
    }
    println!("\ne2e_epoch bench done");
}
