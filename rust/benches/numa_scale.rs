//! BENCH — NUMA scale-out (DESIGN.md §6b): the hierarchical all-reduce
//! vs the monolithic global ring at the AtacWorks gradient size across
//! emulated socket shapes (8 ranks split 1/2/4 ways), and the
//! socket-sharded serve dispatcher vs the flat pool in sequences/second
//! — both paths are bit-identical to their flat counterparts, so the
//! only question this bench answers is time. Written to
//! `BENCH_numa.json`; under `BENCH_STRICT` the hierarchical reduction
//! must not be slower than the monolithic ring at ≥2 emulated sockets.

use dilconv1d::bench_harness::{self, time_auto};
use dilconv1d::dist::allreduce::ring_allreduce;
use dilconv1d::dist::{hierarchical_allreduce, CommModel, Placement, Topology};
use dilconv1d::machine::workload::{model_epoch, Workload};
use dilconv1d::machine::{MachineSpec, Precision, Strategy};
use dilconv1d::model::{AtacWorksNet, NetConfig};
use dilconv1d::serve::{BatcherOpts, BucketSet, EngineOpts, Server, WidthMix};
use dilconv1d::util::rng::Rng;

fn bufs(p: usize, len: usize) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(13);
    (0..p)
        .map(|_| (0..len).map(|_| rng.normal(0.0, 1.0) as f32).collect())
        .collect()
}

fn main() {
    let smoke = bench_harness::smoke();
    let budget = if smoke { 0.02 } else { 0.3 };
    let reps = if smoke { 1 } else { 5 };

    // ---- hierarchical vs monolithic reduction ----
    const RANKS: usize = 8;
    let grad_len = NetConfig::default().param_count();
    println!(
        "numa_scale bench: {RANKS} ranks at gradient length {grad_len} \
         (the 25-layer AtacWorks model)"
    );
    println!(
        "{:>8} | {:>14} | {:>14} | note",
        "sockets", "monolithic", "hierarchical"
    );
    let base = bufs(RANKS, grad_len);
    let mut b = base.clone();
    let t_mono = time_auto(budget, reps, || {
        b.clone_from(&base);
        ring_allreduce(&mut b);
        std::hint::black_box(&b);
    });
    let mut want = base.clone();
    ring_allreduce(&mut want);
    let mut reduce_rows = Vec::new();
    for sockets in [1usize, 2, 4] {
        let placement = Placement::new(RANKS, sockets);
        let mut h = base.clone();
        let t_hier = time_auto(budget, reps, || {
            h.clone_from(&base);
            hierarchical_allreduce(&mut h, placement);
            std::hint::black_box(&h);
        });
        // Bit-identity spot check before trusting the timing.
        for (rank, (got, exp)) in h.iter().zip(&want).enumerate() {
            for (i, (g, e)) in got.iter().zip(exp).enumerate() {
                assert_eq!(
                    g.to_bits(),
                    e.to_bits(),
                    "hierarchical diverged at {sockets} sockets (rank {rank}, elem {i})"
                );
            }
        }
        let slower = t_hier.median_secs > t_mono.median_secs;
        println!(
            "{sockets:>8} | {:>12.2}ms | {:>12.2}ms | {}",
            t_mono.median_secs * 1e3,
            t_hier.median_secs * 1e3,
            if sockets == 1 {
                "flat placement: degenerates to the ring"
            } else if slower {
                "slower than the monolithic ring"
            } else {
                "per-socket threads pipeline the chunks"
            }
        );
        if sockets >= 2 && slower {
            eprintln!(
                "WARN: hierarchical all-reduce slower than monolithic at {sockets} sockets: \
                 {:.3}ms vs {:.3}ms",
                t_hier.median_secs * 1e3,
                t_mono.median_secs * 1e3
            );
            if bench_harness::strict() {
                panic!(
                    "hierarchical all-reduce must not lose to the monolithic ring at \
                     {sockets} emulated sockets / {RANKS} ranks"
                );
            }
        }
        reduce_rows.push((sockets, t_mono.median_secs, t_hier.median_secs));
    }

    // ---- socket-sharded vs flat serving ----
    let net_cfg = NetConfig::tiny();
    let params = AtacWorksNet::init(net_cfg, 42).pack_params();
    let buckets = BucketSet::new(&[128, 256]).expect("bucket widths");
    let requests = if smoke { 32 } else { 256 };
    let rate = 2_000.0;
    println!("\nserve: {requests} open-loop requests at {rate}/s, 4 workers");
    println!(
        "{:>8} | {:>9} | {:>9} | {:>9}",
        "sockets", "seq/s", "p50 ms", "p99 ms"
    );
    let mut serve_rows = Vec::new();
    for sockets in [1usize, 2, 4] {
        let server = Server::start(
            net_cfg,
            &params,
            BatcherOpts::default()
                .with_engine(
                    EngineOpts::default()
                        .with_buckets(buckets.clone())
                        .with_max_batch(4)
                        .with_cache_capacity(2),
                )
                .with_window(std::time::Duration::from_millis(1))
                .with_queue_depth(256)
                .with_workers(4)
                .with_sockets(sockets),
        )
        .expect("server");
        let mix = WidthMix::bucket_mix(&buckets).expect("width mix");
        let report = dilconv1d::serve::run_open_loop(&server, &mix, rate, requests, 5);
        let m = server.shutdown();
        assert_eq!(m.per_socket.len(), sockets, "per-socket telemetry rows");
        println!(
            "{sockets:>8} | {:>9.1} | {:>9.2} | {:>9.2}",
            report.seq_per_sec(),
            report.latency.p50() * 1e3,
            report.latency.p99() * 1e3,
        );
        serve_rows.push((sockets, report.seq_per_sec(), report.latency.p50() * 1e3));
    }

    // ---- modeled roofline: per-socket vs whole-node efficiency ----
    // The per-socket column divides by one socket's peak, the node
    // column by `MachineSpec::peak_node` — the gap is the communication
    // + reserved-core cost of scaling out (paper Sec. 4.5).
    let spec = MachineSpec::cooper_lake();
    let w = Workload::paper();
    let comm = CommModel::fabric();
    let flops = w.train_flops_per_sample() as f64 * w.train_segments as f64;
    println!("\nmodeled CPX f32 epoch: per-socket vs whole-node efficiency");
    for s in [1usize, 8] {
        let t = model_epoch(&w, &spec, Precision::F32, Strategy::Brgemm, &Topology::xeon(s), &comm);
        let socket_eff = flops / s as f64 / t.compute_secs / spec.peak(Precision::F32);
        let node_eff = flops / (t.compute_secs + t.comm_secs) / spec.peak_node(Precision::F32, s);
        println!(
            "{s:>2} socket(s): socket eff {:>5.1}%  node eff {:>5.1}%",
            socket_eff * 100.0,
            node_eff * 100.0
        );
    }

    // ---- trajectory rows (BENCH_numa.json at the repo root) ----
    let mut json = String::from(
        "{\n  \"bench\": \"numa_scale\",\n  \"ranks\": 8,\n  \"grad_len\": ",
    );
    json.push_str(&format!("{grad_len},\n  \"reduce\": [\n"));
    for (i, (s, mono, hier)) in reduce_rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"sockets\": {s}, \"monolithic_ms\": {:.4}, \"hierarchical_ms\": {:.4}}}{}\n",
            mono * 1e3,
            hier * 1e3,
            if i + 1 < reduce_rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ],\n  \"serve\": [\n");
    for (i, (s, sps, p50)) in serve_rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"sockets\": {s}, \"seq_per_sec\": {sps:.2}, \"p50_ms\": {p50:.3}}}{}\n",
            if i + 1 < serve_rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    let out_path = if std::path::Path::new("../CHANGES.md").exists() {
        "../BENCH_numa.json"
    } else {
        "BENCH_numa.json"
    };
    match std::fs::write(out_path, &json) {
        Ok(()) => println!("\nbench rows written to {out_path}"),
        Err(e) => eprintln!("WARN: could not write {out_path}: {e}"),
    }
    println!("numa_scale bench done");
}
