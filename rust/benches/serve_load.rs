//! BENCH — batched inference serving under open-loop load (DESIGN.md
//! §7): dynamic batching (max_batch = 8) vs batch-size-1 serving over a
//! mix of request widths, reporting p50/p99 end-to-end latency and
//! sustained seq/s per mode, plus per-bucket fill. Rows are written to
//! `BENCH_serve.json`.
//!
//! Under `BENCH_STRICT` (and ≥ 8 available cores), dynamic batching
//! must sustain ≥ 2× the seq/s of batch-size-1 serving at 8 kernel
//! threads: a batch of 8 shards its 8 images across the threads, while
//! a batch of 1 under the same (batch-partitioned) engine keeps one.
//! The precision ladder is also measured (f32 / bf16 / i8 dynamic
//! batching), with a strict floor that the int8 tier sustains at least
//! bf16 seq/s — its weights are half the bf16 bytes and it accumulates
//! in i32, so falling behind bf16 means the quantized path regressed.
//! `BENCH_SMOKE=1` shrinks widths/requests and skips the assertions.
//!
//! With the `fault` feature, a fault-rate column re-runs the batched
//! operating point under seeded 1% injected worker panics
//! (DESIGN.md §7d): each panicked batch fails, its replica rebuilds,
//! and the row reports the fraction of fault-free seq/s retained
//! (`fault_retained` in the JSON; strict floor ≥ 0.80 at 8 threads).
//! Without the feature the column is reported as `null`.

use dilconv1d::bench_harness;
use dilconv1d::config::ServeConfig;
use dilconv1d::machine::Precision;
use dilconv1d::model::AtacWorksNet;
use dilconv1d::serve::{run_open_loop, BucketSet, LoadReport, Server, WidthMix};

struct Case {
    label: &'static str,
    max_batch: usize,
    precision: Precision,
    report: LoadReport,
    occupancy: f64,
}

fn run_case(
    label: &'static str,
    cfg: &ServeConfig,
    params: &[f32],
    max_batch: usize,
    precision: Precision,
    mix: &WidthMix,
    rate: f64,
    requests: usize,
) -> Case {
    let mut cfg = cfg.clone();
    cfg.max_batch = max_batch;
    cfg.precision = precision;
    let server = Server::start(cfg.net_config(), params, cfg.batcher_opts())
        .expect("server start");
    let report = run_open_loop(&server, mix, rate, requests, 42);
    let metrics = server.shutdown();
    println!(
        "{label:<22} completed {:>4}/{:<4} rejected {:>3}  {:>7.1} seq/s  \
         p50 {:>7.2} ms  p99 {:>7.2} ms  fill {:.2}/{}",
        report.completed,
        report.offered,
        report.rejected,
        report.seq_per_sec(),
        report.latency.p50() * 1e3,
        report.latency.p99() * 1e3,
        metrics.mean_batch_occupancy(),
        max_batch,
    );
    Case {
        label,
        max_batch,
        precision,
        occupancy: metrics.mean_batch_occupancy(),
        report,
    }
}

/// The batched operating point under seeded injected worker panics:
/// every `EngineForward` visit fires with 1% probability, decided by a
/// pure hash of the seed and visit — identical across runs. Returns the
/// case plus how many panics actually fired.
#[cfg(feature = "fault")]
fn run_fault_case(
    cfg: &ServeConfig,
    params: &[f32],
    mix: &WidthMix,
    rate: f64,
    requests: usize,
) -> (Case, u64) {
    use std::sync::Arc;

    use dilconv1d::serve::fault::silence_fault_panics;
    use dilconv1d::serve::FaultPlan;

    silence_fault_panics();
    let label = "batched + 1% panics";
    let mut cfg = cfg.clone();
    cfg.max_batch = 8;
    cfg.precision = Precision::F32;
    let plan = Arc::new(FaultPlan::seeded_forward_panics(0xFA17, 0.01));
    let mut opts = cfg.batcher_opts();
    opts.fault = Some(Arc::clone(&plan));
    let server = Server::start(cfg.net_config(), params, opts).expect("server start");
    let report = run_open_loop(&server, mix, rate, requests, 42);
    let metrics = server.shutdown();
    assert_eq!(
        metrics.worker_panics,
        plan.panics_fired(),
        "recovery counters must equal the injected plan"
    );
    println!(
        "{label:<22} completed {:>4}/{:<4} failed {:>3}  {:>7.1} seq/s  \
         p50 {:>7.2} ms  p99 {:>7.2} ms  panics {}",
        report.completed,
        report.offered,
        report.failed,
        report.seq_per_sec(),
        report.latency.p50() * 1e3,
        report.latency.p99() * 1e3,
        plan.panics_fired(),
    );
    (
        Case {
            label,
            max_batch: 8,
            precision: Precision::F32,
            occupancy: metrics.mean_batch_occupancy(),
            report,
        },
        plan.panics_fired(),
    )
}

fn main() {
    let smoke = bench_harness::smoke();
    let threads = 8usize;
    // Width mix: genomics-style heterogeneous tracks over three buckets.
    let (buckets, requests, rate) = if smoke {
        (vec![128usize, 256, 384], 24usize, 400.0)
    } else {
        (vec![1024usize, 2048, 4096], 192usize, 2_000.0)
    };
    let bucket_set = BucketSet::new(&buckets).expect("buckets");
    // Exact-fit + partial-fill width per bucket, same derivation as
    // `dilconv serve`.
    let mix = WidthMix::bucket_mix(&bucket_set).expect("width mix");
    let widths = mix.widths();

    let mut cfg = ServeConfig {
        buckets: bucket_set,
        threads,
        workers: 1,
        queue_depth: requests, // open loop: admit the whole schedule
        window_ms: 2.0,
        cache_capacity: buckets.len(),
        ..ServeConfig::default()
    };
    if smoke {
        // Tiny model so the smoke run finishes in seconds.
        cfg.channels = 4;
        cfg.n_blocks = 1;
        cfg.filter_size = 9;
        cfg.dilation = 2;
    }
    cfg.validate().expect("bench serve config");
    let params = AtacWorksNet::init(cfg.net_config(), cfg.seed).pack_params();

    println!(
        "# serve_load: open-loop Poisson arrivals at {rate}/s, {requests} requests, \
         widths {widths:?}, {threads} threads, window {} ms{}",
        cfg.window_ms,
        if smoke { " [SMOKE]" } else { "" },
    );
    // The offered rate is far above single-thread capacity, so both
    // modes saturate and seq/s measures each mode's throughput ceiling.
    let batched = run_case(
        "dynamic batching (8)",
        &cfg,
        &params,
        8,
        Precision::F32,
        &mix,
        rate,
        requests,
    );
    let single = run_case(
        "batch-size-1 serving",
        &cfg,
        &params,
        1,
        Precision::F32,
        &mix,
        rate,
        requests,
    );
    // Precision ladder at the batched operating point.
    let bf16_case = run_case(
        "dynamic batching bf16",
        &cfg,
        &params,
        8,
        Precision::Bf16,
        &mix,
        rate,
        requests,
    );
    let i8_case = run_case(
        "dynamic batching i8",
        &cfg,
        &params,
        8,
        Precision::I8,
        &mix,
        rate,
        requests,
    );

    let speedup = batched.report.seq_per_sec() / single.report.seq_per_sec().max(1e-9);
    println!(
        "dynamic batching vs batch-size-1: {speedup:.2}x seq/s at {threads} threads \
         (mean fill {:.2}/8)",
        batched.occupancy
    );
    let cores = std::thread::available_parallelism().map_or(1, |c| c.get());
    if speedup < 2.0 {
        eprintln!(
            "WARN: dynamic batching below the 2x floor ({speedup:.2}x) — \
             expected on hosts with < {threads} cores (this one: {cores})"
        );
    }
    if bench_harness::strict() && cores >= threads {
        assert!(
            speedup >= 2.0,
            "dynamic batching must sustain >= 2x batch-size-1 seq/s at {threads} threads, \
             got {speedup:.2}x"
        );
    }

    // Fault-rate column: the batched point under 1% injected panics.
    #[cfg(feature = "fault")]
    let (fault_case, fault_retained) = {
        let (case, fired) = run_fault_case(&cfg, &params, &mix, rate, requests);
        let retained = case.report.seq_per_sec() / batched.report.seq_per_sec().max(1e-9);
        println!(
            "seq/s retained under 1% injected panics: {:.0}% ({fired} panics fired)",
            retained * 100.0
        );
        if retained < 0.8 {
            eprintln!(
                "WARN: fault-rate throughput below the 80% floor ({:.0}%) — \
                 expected on noisy or undersized hosts (this one: {cores} cores)",
                retained * 100.0
            );
        }
        if bench_harness::strict() && cores >= threads {
            assert!(
                retained >= 0.8,
                "serving must retain >= 80% of fault-free seq/s under 1% injected \
                 worker panics at {threads} threads, got {:.0}%",
                retained * 100.0
            );
        }
        (case, retained)
    };
    #[cfg(not(feature = "fault"))]
    println!("fault-rate column skipped (build with --features fault to measure it)");

    let quant_ratio = i8_case.report.seq_per_sec() / bf16_case.report.seq_per_sec().max(1e-9);
    println!("i8 vs bf16 dynamic batching: {quant_ratio:.2}x seq/s at {threads} threads");
    if quant_ratio < 1.0 {
        eprintln!(
            "WARN: int8 serving below the bf16 floor ({quant_ratio:.2}x) — \
             expected only on noisy or undersized hosts (this one: {cores} cores)"
        );
    }
    if bench_harness::strict() && cores >= threads {
        assert!(
            quant_ratio >= 1.0,
            "int8 serving must sustain >= bf16 seq/s at {threads} threads, \
             got {quant_ratio:.2}x"
        );
    }

    // Bench trajectory rows (BENCH_*.json at the repo root).
    #[cfg(feature = "fault")]
    let fault_retained_json = format!("{fault_retained:.4}");
    #[cfg(not(feature = "fault"))]
    let fault_retained_json = String::from("null");
    let mut json = format!(
        "{{\n  \"bench\": \"serve_load\",\n  \"smoke\": {smoke},\n  \"threads\": {threads},\n  \
         \"rate_per_sec\": {rate},\n  \"requests\": {requests},\n  \
         \"buckets\": \"{}\",\n  \"speedup_batched_vs_single\": {speedup:.4},\n  \
         \"speedup_i8_vs_bf16\": {quant_ratio:.4},\n  \
         \"fault_retained\": {fault_retained_json},\n  \"rows\": [\n",
        cfg.buckets,
    );
    #[cfg(feature = "fault")]
    let cases = [&batched, &single, &bf16_case, &i8_case, &fault_case];
    #[cfg(not(feature = "fault"))]
    let cases = [&batched, &single, &bf16_case, &i8_case];
    for (i, c) in cases.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"mode\": \"{}\", \"precision\": \"{:?}\", \"max_batch\": {}, \
             \"completed\": {}, \"rejected\": {}, \
             \"wall_secs\": {:.4}, \"seq_per_sec\": {:.2}, \"p50_ms\": {:.3}, \
             \"p99_ms\": {:.3}, \"mean_ms\": {:.3}, \"mean_batch_fill\": {:.3}}}{}\n",
            c.label,
            c.precision,
            c.max_batch,
            c.report.completed,
            c.report.rejected,
            c.report.wall_secs,
            c.report.seq_per_sec(),
            c.report.latency.p50() * 1e3,
            c.report.latency.p99() * 1e3,
            c.report.latency.mean() * 1e3,
            c.occupancy,
            if i + 1 < cases.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    // Benches run from rust/; place the trajectory file at the repo root
    // when it is visible, else in the working directory.
    let out_path = if std::path::Path::new("../CHANGES.md").exists() {
        "../BENCH_serve.json"
    } else {
        "BENCH_serve.json"
    };
    match std::fs::write(out_path, &json) {
        Ok(()) => println!("bench rows written to {out_path}"),
        Err(e) => eprintln!("WARN: could not write {out_path}: {e}"),
    }
    println!("serve_load bench done");
}
