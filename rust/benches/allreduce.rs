//! BENCH — Figs. 8/9 substrate: the ring all-reduce at the AtacWorks
//! gradient size across rank counts, in-place and message-passing
//! (threaded) variants, vs the naive reduce — plus the α–β model's
//! prediction of the same collective between the paper's sockets.

use dilconv1d::bench_harness::time_auto;
use dilconv1d::dist::allreduce::{naive_allreduce, ring_allreduce, ring_allreduce_threaded};
use dilconv1d::dist::CommModel;
use dilconv1d::model::NetConfig;
use dilconv1d::util::rng::Rng;

fn bufs(p: usize, len: usize) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(7);
    (0..p)
        .map(|_| (0..len).map(|_| rng.normal(0.0, 1.0) as f32).collect())
        .collect()
}

fn main() {
    let grad_len = NetConfig::default().param_count();
    println!("allreduce bench: gradient length {grad_len} (the 25-layer AtacWorks model)");
    println!(
        "{:>5} | {:>12} | {:>12} | {:>12} | modeled fabric time",
        "ranks", "ring (inproc)", "ring (threads)", "naive"
    );
    let comm = CommModel::fabric();
    for &p in &[2usize, 4, 8, 16] {
        let base = bufs(p, grad_len);
        let mut b1 = base.clone();
        let t_ring = time_auto(0.3, 5, || {
            b1.clone_from(&base);
            ring_allreduce(&mut b1);
            std::hint::black_box(&b1);
        });
        let t_thr = time_auto(0.3, 3, || {
            let out = ring_allreduce_threaded(base.clone());
            std::hint::black_box(&out);
        });
        let mut b2 = base.clone();
        let t_naive = time_auto(0.3, 5, || {
            b2.clone_from(&base);
            naive_allreduce(&mut b2);
            std::hint::black_box(&b2);
        });
        println!(
            "{p:>5} | {:>10.2}ms | {:>10.2}ms | {:>10.2}ms | {:>8.3}ms",
            t_ring.median_secs * 1e3,
            t_thr.median_secs * 1e3,
            t_naive.median_secs * 1e3,
            comm.ring_allreduce_secs(grad_len, p) * 1e3,
        );
    }
    println!("\nallreduce bench done");
}
