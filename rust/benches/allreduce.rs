//! BENCH — Figs. 8/9 substrate: the ring all-reduce at the AtacWorks
//! gradient size across rank counts, in-place and message-passing
//! (threaded) variants, vs the naive reduce — plus the α–β model's
//! prediction of the same collective between the paper's sockets, and
//! the bucketed variant (DESIGN.md §6): per-bucket aligned rings with
//! the modeled overlap efficiency against a synthetic backward timeline.

use dilconv1d::bench_harness::{self, time_auto};
use dilconv1d::dist::allreduce::{
    naive_allreduce, ring_allreduce, ring_allreduce_aligned, ring_allreduce_threaded,
};
use dilconv1d::dist::{BucketPlan, CommModel};
use dilconv1d::model::NetConfig;
use dilconv1d::util::rng::Rng;

fn bufs(p: usize, len: usize) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(7);
    (0..p)
        .map(|_| (0..len).map(|_| rng.normal(0.0, 1.0) as f32).collect())
        .collect()
}

fn main() {
    let smoke = bench_harness::smoke();
    let budget = if smoke { 0.02 } else { 0.3 };
    let grad_len = NetConfig::default().param_count();
    println!("allreduce bench: gradient length {grad_len} (the 25-layer AtacWorks model)");
    println!(
        "{:>5} | {:>12} | {:>12} | {:>12} | modeled fabric time",
        "ranks", "ring (inproc)", "ring (threads)", "naive"
    );
    let comm = CommModel::fabric();
    let rank_list: &[usize] = if smoke { &[2, 4] } else { &[2, 4, 8, 16] };
    for &p in rank_list {
        let base = bufs(p, grad_len);
        let mut b1 = base.clone();
        let t_ring = time_auto(budget, if smoke { 1 } else { 5 }, || {
            b1.clone_from(&base);
            ring_allreduce(&mut b1);
            std::hint::black_box(&b1);
        });
        let t_thr = time_auto(budget, if smoke { 1 } else { 3 }, || {
            let out = ring_allreduce_threaded(base.clone());
            std::hint::black_box(&out);
        });
        let mut b2 = base.clone();
        let t_naive = time_auto(budget, if smoke { 1 } else { 5 }, || {
            b2.clone_from(&base);
            naive_allreduce(&mut b2);
            std::hint::black_box(&b2);
        });
        println!(
            "{p:>5} | {:>10.2}ms | {:>10.2}ms | {:>10.2}ms | {:>8.3}ms",
            t_ring.median_secs * 1e3,
            t_thr.median_secs * 1e3,
            t_naive.median_secs * 1e3,
            comm.ring_allreduce_secs(grad_len, p) * 1e3,
        );
    }

    // ---- bucketed variant (DESIGN.md §6) ----
    // The trainer's overlapped path reduces the gradient bucket by
    // bucket through the *aligned* ring (global chunk grid), which is
    // bit-identical to one monolithic ring. Time the bucketed sweep and
    // model how much of it a backward pass would hide.
    let net = NetConfig::default();
    let plan = BucketPlan::new(
        &net.layer_param_counts(),
        &net.backward_completion_order(),
        256 * 1024, // 0.25 MiB buckets
    );
    println!(
        "\nbucketed (aligned) ring: {} buckets of <= 0.25 MiB over {} elems",
        plan.n_buckets(),
        plan.total_elems()
    );
    println!(
        "{:>5} | {:>12} | {:>12} | modeled overlap efficiency (fabric)",
        "ranks", "monolithic", "bucketed sum"
    );
    let bucketed_ranks: &[usize] = if smoke { &[2] } else { &[2, 4, 8] };
    for &p in bucketed_ranks {
        let base = bufs(p, grad_len);
        let mut b1 = base.clone();
        let t_mono = time_auto(budget, if smoke { 1 } else { 5 }, || {
            b1.clone_from(&base);
            ring_allreduce(&mut b1);
            std::hint::black_box(&b1);
        });
        // Pre-gather pristine per-bucket copies once; the timed loop only
        // resets via clone_from (allocation-free), mirroring the
        // monolithic baseline's reset so the two columns are comparable.
        let pristine: Vec<Vec<Vec<f32>>> = (0..plan.n_buckets())
            .map(|b| base.iter().map(|full| plan.gather(b, full)).collect())
            .collect();
        let mut bucket_bufs = pristine.clone();
        let t_bucketed = time_auto(budget, if smoke { 1 } else { 5 }, || {
            for (b, bufs_b) in bucket_bufs.iter_mut().enumerate() {
                for (buf, fresh) in bufs_b.iter_mut().zip(&pristine[b]) {
                    buf.clone_from(fresh);
                }
                ring_allreduce_aligned(bufs_b, &plan.bucket(b).regions, grad_len);
            }
            std::hint::black_box(&bucket_bufs);
        });
        // Bit-identity spot check against the monolithic result.
        let mut want = base.clone();
        ring_allreduce(&mut want);
        for (b, bufs_b) in bucket_bufs.iter().enumerate() {
            for (rank, buf) in bufs_b.iter().enumerate() {
                assert_eq!(
                    *buf,
                    plan.gather(b, &want[rank]),
                    "bucketed reduce diverged from monolithic (bucket {b}, rank {rank})"
                );
            }
        }
        // Synthetic backward timeline: buckets become ready evenly over
        // 100 ms of backward; the model prices each bucket's ring on the
        // fabric link and reports how much stays exposed.
        let ready: Vec<f64> = (0..plan.n_buckets())
            .map(|b| 0.1 * (b + 1) as f64 / plan.n_buckets() as f64)
            .collect();
        let rep = comm.bucketed_overlap(&plan.elems_per_bucket(), p, &ready);
        println!(
            "{p:>5} | {:>10.2}ms | {:>10.2}ms | comm {:.3}ms exposed {:.3}ms ({:.0}% hidden)",
            t_mono.median_secs * 1e3,
            t_bucketed.median_secs * 1e3,
            rep.comm_secs * 1e3,
            rep.exposed_secs * 1e3,
            rep.efficiency * 100.0,
        );
    }
    println!("\nallreduce bench done");
}
