//! BENCH — eq. (4): the paper's optimisation-condition grid. Crosses the
//! claimed boundary (S ≥ 5 ∧ Q ≥ 1000) and reports who wins at each grid
//! point, BRGEMM vs the im2col library baseline vs the naive direct loop.
//! The reproduced claim is the *region shape*: ours wins everywhere the
//! condition holds.

use dilconv1d::bench_harness::{self, run_point, Pass, SweepConfig};
use dilconv1d::conv1d::Backend;
use dilconv1d::coordinator::experiment::eq4_grid;
use dilconv1d::machine::{calibrate_host, MachineSpec, Precision};

fn main() {
    let smoke = bench_harness::smoke();
    let host = calibrate_host();
    println!("baseline_vs_brgemm (eq. 4 grid): host ≈ {host:.2} GFLOP/s");
    let cfg = SweepConfig {
        batch: 2,
        reps: if smoke { 1 } else { 3 },
        max_measured_q: if smoke { 5_000 } else { 20_000 },
        host_gflops_peak: host,
        threads: 1,
    };
    let clx = MachineSpec::cascade_lake();
    println!(
        "{:>6} {:>3} | {:>10} {:>10} {:>10} | winner | eq4 predicts ours",
        "Q", "S", "brgemm", "im2col", "direct"
    );
    let mut violations = 0;
    let mut in_region = 0;
    let grid: Vec<_> = if smoke {
        // Smoke mode: the four corners of the eq.-4 region only
        // (S ∈ {1, 51} × Q ∈ {200, 5000}).
        eq4_grid()
            .into_iter()
            .filter(|&(_, _, q, s, _)| (s == 1 || s == 51) && (q == 200 || q == 5_000))
            .collect()
    } else {
        eq4_grid()
    };
    for (c, k, q, s, d) in grid {
        let ours = run_point(&cfg, c, k, q, s, d, Pass::Forward, Backend::Brgemm, Precision::F32, &clx);
        let im2col = run_point(&cfg, c, k, q, s, d, Pass::Forward, Backend::Im2col, Precision::F32, &clx);
        let direct = run_point(&cfg, c, k, q, s, d, Pass::Forward, Backend::Direct, Precision::F32, &clx);
        let t = [
            ours.timing.median_secs,
            im2col.timing.median_secs,
            direct.timing.median_secs,
        ];
        let winner = ["brgemm", "im2col", "direct"][t
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0];
        let predicted = s >= 5 && q >= 1000;
        if predicted {
            in_region += 1;
            if winner != "brgemm" {
                violations += 1;
            }
        }
        println!(
            "{q:>6} {s:>3} | {:>8.2}ms {:>8.2}ms {:>8.2}ms | {winner:>6} | {}",
            t[0] * 1e3,
            t[1] * 1e3,
            t[2] * 1e3,
            if predicted { "yes" } else { "no" },
        );
    }
    println!(
        "\neq. 4 region: {in_region} points, {violations} violations \
         (paper claims 0; small-point noise may flip ties)"
    );
    println!("baseline_vs_brgemm bench done");
}
