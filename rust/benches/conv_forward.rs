//! BENCH — Fig. 4 / Fig. 5 (forward pass): efficiency of the BRGEMM
//! forward kernel vs output width and filter size, FP32, plus the bf16
//! path (Fig. 6 series). Prints paper-style rows: measured host GFLOP/s,
//! host efficiency, and modeled efficiency on the paper's socket.
//!
//! Run: `cargo bench --bench conv_forward` (in `cargo bench` the binary
//! runs with `--bench`, which we ignore).

use dilconv1d::bench_harness::{self, run_point, run_point_tuned, time_fn, Pass, SweepConfig};
use dilconv1d::conv1d::forward::{forward, forward_a_offs, forward_with_scratch};
use dilconv1d::conv1d::layout::kcs_to_skc;
use dilconv1d::conv1d::simd::{active, Isa, MicroKernelSet};
use dilconv1d::conv1d::test_util::rnd;
use dilconv1d::conv1d::{Backend, ConvParams, ConvPlan, ExecCtx, Partition, PlanOptions, PostOps};
use dilconv1d::machine::{calibrate_host, project, MachineSpec, Precision, Strategy};
use dilconv1d::model::{AtacWorksNet, NetConfig, NetPlan, Tensor};

fn main() {
    // BENCH_SMOKE shrinks every shape/rep below "quick" (CI smoke job);
    // BENCH_FULL expands to the paper grid.
    let smoke = bench_harness::smoke();
    let quick = std::env::var("BENCH_FULL").is_err();
    let host = calibrate_host();
    println!("conv_forward: host ≈ {host:.2} GFLOP/s (1 core); quick={quick} smoke={smoke}");
    let cfg = SweepConfig {
        batch: 2,
        reps: if smoke { 1 } else if quick { 2 } else { 5 },
        max_measured_q: if quick { 10_000 } else { 60_000 },
        host_gflops_peak: host,
        threads: 1,
    };
    let clx = MachineSpec::cascade_lake();
    let cpx = MachineSpec::cooper_lake();

    // Fig. 4 series: C=15 K=15 d=8.
    println!("\n# Fig. 4 series (C=15 K=15 d=8, FP32)");
    println!("{:>6} {:>3} | {:>10} {:>8} {:>6} | modeled CLX eff", "Q", "S", "median", "GF/s", "eff");
    let widths: &[usize] = if smoke {
        &[1_000]
    } else if quick {
        &[1_000, 5_000, 10_000]
    } else {
        &[1_000, 2_000, 5_000, 10_000, 20_000, 60_000]
    };
    for &s in &[5usize, 21, 51] {
        for &q in widths {
            let r = run_point(&cfg, 15, 15, q, s, 8, Pass::Forward, Backend::Brgemm, Precision::F32, &clx);
            println!(
                "{q:>6} {s:>3} | {:>8.2}ms {:>8.2} {:>5.1}% | {:>5.1}%",
                r.timing.median_secs * 1e3,
                r.host_gflops,
                r.host_eff * 100.0,
                r.modeled_eff * 100.0,
            );
        }
    }

    // Fig. 5 series: C=64 K=64 d=1.
    println!("\n# Fig. 5 series (C=64 K=64 d=1, FP32)");
    for &s in &[5usize, 51] {
        for &q in widths {
            let r = run_point(&cfg, 64, 64, q, s, 1, Pass::Forward, Backend::Brgemm, Precision::F32, &clx);
            println!(
                "{q:>6} {s:>3} | {:>8.2}ms {:>8.2} {:>5.1}% | {:>5.1}%",
                r.timing.median_secs * 1e3,
                r.host_gflops,
                r.host_eff * 100.0,
                r.modeled_eff * 100.0,
            );
        }
    }

    // Fig. 6 series: C=32 K=32 d=4, bf16 vs f32.
    println!("\n# Fig. 6 series (C=32 K=32 d=4): bf16 GFLOP/s vs f32");
    for &q in widths {
        let f = run_point(&cfg, 32, 32, q, 9, 4, Pass::Forward, Backend::Brgemm, Precision::F32, &cpx);
        let b = run_point(&cfg, 32, 32, q, 9, 4, Pass::Forward, Backend::Brgemm, Precision::Bf16, &cpx);
        println!(
            "Q {q:>6}: f32 {:>8.2} GF/s | bf16-path {:>8.2} GF/s | modeled CPX bf16 {:>5.1}% of 9.32 TF peak",
            f.host_gflops,
            b.host_gflops,
            b.modeled_eff * 100.0,
        );
    }
    // Planned vs eager on the paper's AtacWorks shape (C=15, K=15, S=51,
    // W=60 000): the eager path re-derives the offset tables and allocates
    // the output on every call (the pre-plan Conv1dLayer::forward shape);
    // the plan executes into preallocated buffers with zero allocations.
    let big_w = if smoke { 6_000usize } else { 60_000 };
    println!("\n# planned vs eager (AtacWorks layer: C=15 K=15 S=51 d=8 W={big_w})");
    let (n, c, k, s, d, w) = (1usize, 15usize, 15usize, 51usize, 8usize, big_w);
    let p = ConvParams::new(n, c, k, w, s, d).unwrap();
    let wt = rnd(k * c * s, 0xE1);
    let x = rnd(n * c * w, 0xE2);
    let reps = if smoke { 1 } else if quick { 3 } else { 7 };
    let skc = kcs_to_skc(&wt, k, c, s);
    let t_eager = time_fn(1, reps, || {
        let mut out = vec![0.0f32; n * k * p.q()];
        forward(&p, &x, &skc, &mut out, 1);
        std::hint::black_box(&out);
    });
    let mut plan =
        ConvPlan::build(p, wt, PlanOptions::new().backend(Backend::Brgemm)).expect("plan");
    let mut out = vec![0.0f32; n * k * p.q()];
    let t_plan = time_fn(1, reps, || {
        plan.execute_forward_into(&x, &mut out);
        std::hint::black_box(&out);
    });
    println!(
        "eager  {:>8.2} ms   planned {:>8.2} ms   ratio {:.3} (workspace {} KiB)",
        t_eager.median_secs * 1e3,
        t_plan.median_secs * 1e3,
        t_plan.median_secs / t_eager.median_secs,
        plan.workspace_bytes() / 1024,
    );
    // Visible regression signal; hard-fail only under BENCH_STRICT so a
    // noisy shared host can't spuriously kill the bench binary.
    let regressed = t_plan.min_secs > t_eager.min_secs * 1.10;
    if regressed {
        eprintln!(
            "WARN: planned path slower than eager: {} vs {}",
            t_plan.min_secs, t_eager.min_secs
        );
    }
    if bench_harness::strict() {
        assert!(
            !regressed,
            "planned path must not be slower than eager: {} vs {}",
            t_plan.min_secs, t_eager.min_secs
        );
    }

    // Fused vs unfused post-ops on the same AtacWorks shape: the fused
    // path applies bias+relu inside the kernel's output-block loop (one
    // pass over the output); the unfused path reproduces the pre-fusion
    // layer stack — conv, then a bias sweep, then a relu sweep.
    println!("\n# fused vs unfused post-ops (bias+relu, AtacWorks layer)");
    let bias = rnd(k, 0xE3);
    plan.set_post_ops(PostOps::bias_relu());
    plan.set_bias(&bias);
    let mut y = vec![0.0f32; n * k * p.q()];
    let t_fused = time_fn(1, reps, || {
        plan.execute_forward_post_into(&x, None, &mut y);
        std::hint::black_box(&y);
    });
    plan.set_post_ops(PostOps::none());
    let q = p.q();
    let t_unfused = time_fn(1, reps, || {
        plan.execute_forward_into(&x, &mut out);
        for ib in 0..n {
            for ik in 0..k {
                let row = &mut out[(ib * k + ik) * q..(ib * k + ik + 1) * q];
                let b = bias[ik];
                for v in row.iter_mut() {
                    *v += b;
                }
            }
        }
        for v in out.iter_mut() {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
        std::hint::black_box(&out);
    });
    let fused_ratio = t_fused.median_secs / t_unfused.median_secs;
    println!(
        "unfused (3 passes) {:>8.2} ms   fused (1 pass) {:>8.2} ms   ratio {:.3}",
        t_unfused.median_secs * 1e3,
        t_fused.median_secs * 1e3,
        fused_ratio,
    );
    let fused_regressed = t_fused.min_secs > t_unfused.min_secs * 1.05;
    if fused_regressed {
        eprintln!(
            "WARN: fused post-ops slower than unfused: {} vs {}",
            t_fused.min_secs, t_unfused.min_secs
        );
    }
    if bench_harness::strict() {
        assert!(
            !fused_regressed,
            "fused must be <= unfused on the AtacWorks shape: {} vs {}",
            t_fused.min_secs, t_unfused.min_secs
        );
    }

    // Autotuned point: the harness routes kernel selection through the
    // shape-keyed autotuner (first call measures, later calls memoize).
    let tuned_q = if smoke { 2_000 } else { 10_000 };
    let (t_tuned, tuned_kernel) = run_point_tuned(&cfg, 15, 15, tuned_q, 51, 8, PostOps::bias_relu());
    println!(
        "autotuned kernel for C=15 K=15 Q={tuned_q} S=51 d=8: {} ({:.2} ms fused fwd)",
        tuned_kernel,
        t_tuned.median_secs * 1e3
    );

    // Per-ISA kernel rows (acceptance: dispatched ≥ 1.5× scalar-forced on
    // AVX2 hosts): the same forward driven through each available
    // micro-kernel set, with host + modeled CLX roofline efficiency.
    let isa_q = if smoke { 2_000 } else { 10_000 };
    println!("\n# per-ISA forward (AtacWorks shape N=2 C=15 K=15 S=51 d=8, Q={isa_q})");
    println!(
        "{:>8} | {:>9} | {:>8} | {:>8} | {:>8}",
        "isa", "median", "GF/s", "host eff", "CLX eff"
    );
    let pa = ConvParams::new(2, 15, 15, isa_q + 50 * 8, 51, 8).unwrap();
    let wa = rnd(pa.k * pa.c * pa.s, 0xA1);
    let xa = rnd(pa.n * pa.c * pa.w, 0xA2);
    let ska = kcs_to_skc(&wa, pa.k, pa.c, pa.s);
    let a_offs = forward_a_offs(&pa);
    let mut isa_rows = String::new();
    let mut isa_gflops = [0.0f64; 3];
    for (idx, isa) in Isa::ALL.into_iter().enumerate() {
        let set = MicroKernelSet::for_isa(isa);
        if set.isa() != isa {
            println!("{:>8} | unavailable on this host/build", isa.name());
            continue;
        }
        let ctx = ExecCtx::serial().with_uks(set);
        let mut b_offs = vec![0usize; pa.s];
        let mut stage: [f32; 0] = []; // batch partitioning needs no staging
        let mut out_a = vec![0.0f32; pa.n * pa.k * pa.q()];
        let t = time_fn(1, reps, || {
            forward_with_scratch(&pa, &xa, &ska, &mut out_a, ctx, &a_offs, &mut b_offs, &mut stage);
            std::hint::black_box(&out_a);
        });
        let gf = pa.flops() as f64 / t.median_secs / 1e9;
        isa_gflops[idx] = gf;
        let host_eff = gf / host;
        let modeled = project(&pa, Strategy::Brgemm, &clx, Precision::F32, 1);
        let mark = if active().isa() == isa { "*" } else { " " };
        println!(
            "{:>7}{mark} | {:>7.2}ms | {gf:>8.2} | {:>7.1}% | {:>7.1}%",
            isa.name(),
            t.median_secs * 1e3,
            host_eff * 100.0,
            modeled.efficiency * 100.0,
        );
        if !isa_rows.is_empty() {
            isa_rows.push_str(",\n    ");
        }
        isa_rows.push_str(&format!(
            "{{\"isa\": \"{}\", \"gflops\": {gf:.3}, \"host_eff\": {host_eff:.4}, \
             \"modeled_clx_eff\": {:.4}}}",
            isa.name(),
            modeled.efficiency,
        ));
    }
    let dispatch_speedup = if active().isa() != Isa::Scalar && isa_gflops[0] > 0.0 {
        let active_idx = Isa::ALL.iter().position(|&i| i == active().isa()).unwrap();
        isa_gflops[active_idx] / isa_gflops[0]
    } else {
        1.0
    };
    println!(
        "dispatched ISA: {} ({dispatch_speedup:.2}x the scalar-forced kernel)",
        active().isa()
    );
    if bench_harness::strict() && active().isa() != Isa::Scalar {
        assert!(
            dispatch_speedup >= 1.5,
            "dispatched kernel must be >= 1.5x scalar on the AtacWorks shape, got {dispatch_speedup:.2}x"
        );
    }

    // Grid vs batch partitioning at N=1 (acceptance: grid >= 2x batch at
    // 8 threads, Q >= 8192): with one image the batch split degenerates
    // to a single worker; the 2D width-block grid uses all of them.
    let threads = 8usize;
    let grid_q = if smoke { 4_096 } else { 16_384 };
    let pg = ConvParams::new(1, 15, 15, grid_q + 50 * 8, 51, 8).unwrap();
    let wg = rnd(pg.k * pg.c * pg.s, 0xB1);
    let xg = rnd(pg.n * pg.c * pg.w, 0xB2);
    let mut out_g = vec![0.0f32; pg.n * pg.k * pg.q()];
    let mut plan_batch = ConvPlan::build(
        pg,
        wg.clone(),
        PlanOptions::new().backend(Backend::Brgemm).threads(threads),
    )
    .expect("plan");
    let t_batch = time_fn(1, reps, || {
        plan_batch.execute_forward_into(&xg, &mut out_g);
        std::hint::black_box(&out_g);
    });
    let mut plan_grid = ConvPlan::build(
        pg,
        wg,
        PlanOptions::new()
            .backend(Backend::Brgemm)
            .threads(threads)
            .partition(Partition::Grid),
    )
    .expect("plan");
    let t_grid = time_fn(1, reps, || {
        plan_grid.execute_forward_into(&xg, &mut out_g);
        std::hint::black_box(&out_g);
    });
    let grid_speedup = t_batch.median_secs / t_grid.median_secs;
    println!(
        "\n# partition at N=1 (C=15 K=15 S=51 d=8, Q={grid_q}, {threads} threads)\n\
         batch {:>8.2} ms   grid {:>8.2} ms   grid speedup {grid_speedup:.2}x",
        t_batch.median_secs * 1e3,
        t_grid.median_secs * 1e3,
    );
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    if bench_harness::strict() && cores >= threads {
        assert!(
            grid_speedup >= 2.0,
            "grid partitioning must be >= 2x batch at N=1 with {threads} threads, \
             got {grid_speedup:.2}x"
        );
    }

    // Net-level plan (DESIGN.md §7c): the fused/arena execution of the
    // whole AtacWorks net vs the per-layer reference pipeline, plus the
    // arena footprint vs the per-layer activation sum. N=8 under batch
    // partitioning so both paths parallelize across images.
    let net_threads = 8usize;
    let (net_cfg, net_n, net_w) = if smoke {
        (NetConfig::tiny(), 4usize, 512usize)
    } else {
        (NetConfig::default(), 8usize, 4992usize)
    };
    println!(
        "\n# net plan: fused/arena vs per-layer ({} conv layers, N={net_n} W={net_w}, \
         {net_threads} threads)",
        net_cfg.n_conv_layers()
    );
    let xt = Tensor::from_vec(rnd(net_n * net_w, 0xC1), net_n, 1, net_w);
    let mut fused_net = AtacWorksNet::init(net_cfg, 11);
    fused_net.set_backend(Backend::Brgemm, net_threads);
    fused_net.set_inference(true);
    fused_net.warm(net_n, net_w).expect("fused net warm");
    let t_net_fused = time_fn(1, reps, || {
        let (d, l, _) = fused_net.forward(&xt, false);
        std::hint::black_box((&d, &l));
    });
    let mut layer_net = AtacWorksNet::init(net_cfg, 11);
    layer_net.set_backend(Backend::Brgemm, net_threads);
    layer_net.set_inference(true);
    layer_net.set_netplan(false);
    layer_net.warm(net_n, net_w).expect("per-layer net warm");
    let t_net_layer = time_fn(1, reps, || {
        let (d, l, _) = layer_net.forward(&xt, false);
        std::hint::black_box((&d, &l));
    });
    let net_ratio = t_net_fused.median_secs / t_net_layer.median_secs;
    let plan = fused_net.netplan().expect("warm built the net plan");
    let arena_bytes = plan.activation_bytes();
    let per_layer_bytes = NetPlan::per_layer_activation_bytes(&net_cfg, net_n, net_w);
    let arena_ratio = arena_bytes as f64 / per_layer_bytes as f64;
    println!(
        "per-layer {:>8.2} ms   fused {:>8.2} ms   ratio {net_ratio:.3}",
        t_net_layer.median_secs * 1e3,
        t_net_fused.median_secs * 1e3,
    );
    println!(
        "activation memory: arena {} KiB vs per-layer {} KiB ({:.1}%)",
        arena_bytes / 1024,
        per_layer_bytes / 1024,
        arena_ratio * 100.0,
    );
    // The arena floor is deterministic arithmetic, not a timing: the
    // live set must undercut the per-layer sum unconditionally.
    assert!(
        arena_bytes < per_layer_bytes,
        "arena ({arena_bytes} B) must stay below the per-layer activation sum \
         ({per_layer_bytes} B)"
    );
    let net_regressed = t_net_fused.min_secs > t_net_layer.min_secs * 1.05;
    if net_regressed {
        eprintln!(
            "WARN: fused net plan slower than per-layer: {} vs {}",
            t_net_fused.min_secs, t_net_layer.min_secs
        );
    }
    if bench_harness::strict() && cores >= net_threads {
        assert!(
            !net_regressed,
            "fused net plan must be <= per-layer at {net_threads} threads: {} vs {}",
            t_net_fused.min_secs, t_net_layer.min_secs
        );
    }

    // Bench trajectory row (BENCH_*.json at the repo root).
    let json = format!(
        "{{\n  \"bench\": \"conv_forward\",\n  \"shape\": \"C15_K15_S51_d8_W60000\",\n  \
         \"eager_ms\": {:.4},\n  \"planned_ms\": {:.4},\n  \"planned_over_eager\": {:.4},\n  \
         \"unfused_ms\": {:.4},\n  \"fused_ms\": {:.4},\n  \"fused_over_unfused\": {:.4},\n  \
         \"autotuned_kernel\": \"{}\",\n  \"autotuned_fused_ms\": {:.4},\n  \
         \"dispatched_isa\": \"{}\",\n  \"dispatch_speedup_vs_scalar\": {:.4},\n  \
         \"isa_rows\": [\n    {}\n  ],\n  \
         \"partition_n1_batch_ms\": {:.4},\n  \"partition_n1_grid_ms\": {:.4},\n  \
         \"partition_n1_grid_speedup\": {:.4},\n  \
         \"net_per_layer_ms\": {:.4},\n  \"net_fused_ms\": {:.4},\n  \
         \"net_fused_over_per_layer\": {:.4},\n  \
         \"net_arena_bytes\": {},\n  \"net_per_layer_activation_bytes\": {},\n  \
         \"net_arena_over_per_layer\": {:.4}\n}}\n",
        t_eager.median_secs * 1e3,
        t_plan.median_secs * 1e3,
        t_plan.median_secs / t_eager.median_secs,
        t_unfused.median_secs * 1e3,
        t_fused.median_secs * 1e3,
        fused_ratio,
        tuned_kernel,
        t_tuned.median_secs * 1e3,
        active().isa(),
        dispatch_speedup,
        isa_rows,
        t_batch.median_secs * 1e3,
        t_grid.median_secs * 1e3,
        grid_speedup,
        t_net_layer.median_secs * 1e3,
        t_net_fused.median_secs * 1e3,
        net_ratio,
        arena_bytes,
        per_layer_bytes,
        arena_ratio,
    );
    // Benches run from rust/; place the trajectory file at the repo root
    // when it is visible, else in the working directory.
    let out_path = if std::path::Path::new("../CHANGES.md").exists() {
        "../BENCH_conv_forward.json"
    } else {
        "BENCH_conv_forward.json"
    };
    match std::fs::write(out_path, &json) {
        Ok(()) => println!("bench row written to {out_path}"),
        Err(e) => eprintln!("WARN: could not write {out_path}: {e}"),
    }

    println!("\nconv_forward bench done");
}
