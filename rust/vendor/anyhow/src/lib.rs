//! Minimal, dependency-free stand-in for the `anyhow` crate, covering the
//! subset of its API this workspace uses: [`Error`], [`Result`], the
//! [`Context`] extension trait on `Result`/`Option`, and the `anyhow!`,
//! `bail!`, `ensure!` macros.
//!
//! Errors are flattened to strings at construction time (context chains
//! become `"outer: inner"`), which is all the callers ever observe — they
//! print with `{e}` / `{e:#}` and never downcast.

use std::fmt::{self, Debug, Display};

/// A string-backed error value.
pub struct Error(String);

/// `std::result::Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from anything printable.
    pub fn msg<M: Display>(m: M) -> Error {
        Error(m.to_string())
    }

    /// Prepend a context layer: `"ctx: cause"`.
    pub fn context<C: Display>(self, ctx: C) -> Error {
        Error(format!("{ctx}: {}", self.0))
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

// `?` conversions from any std error (io, parse, custom impls, ...).
// `Error` itself deliberately does not implement `std::error::Error`, so
// this blanket impl cannot overlap with the reflexive `From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error(e.to_string())
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T> {
    fn context<C: Display>(self, ctx: C) -> Result<T, Error>;
    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Display> Context<T> for std::result::Result<T, E> {
    fn context<C: Display>(self, ctx: C) -> Result<T, Error> {
        self.map_err(|e| Error(format!("{ctx}: {e}")))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: Display>(self, ctx: C) -> Result<T, Error> {
        self.ok_or_else(|| Error(ctx.to_string()))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string or a printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Early-return with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<i32> {
        let v: i32 = s.parse().context("not an integer")?;
        ensure!(v >= 0, "negative value {v}");
        if v > 100 {
            bail!("too large: {v}");
        }
        Ok(v)
    }

    #[test]
    fn happy_path() {
        assert_eq!(parse("42").unwrap(), 42);
    }

    #[test]
    fn context_chains() {
        let e = parse("x").unwrap_err();
        assert!(e.to_string().starts_with("not an integer: "), "{e}");
    }

    #[test]
    fn ensure_and_bail() {
        assert_eq!(parse("-1").unwrap_err().to_string(), "negative value -1");
        assert_eq!(parse("101").unwrap_err().to_string(), "too large: 101");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert_eq!(v.context("missing").unwrap_err().to_string(), "missing");
    }

    #[test]
    fn question_mark_from_std_error() {
        fn read() -> Result<String> {
            Ok(std::fs::read_to_string("/definitely/not/a/path/xyz")?)
        }
        assert!(read().is_err());
    }

    #[test]
    fn anyhow_macro_forms() {
        assert_eq!(anyhow!("plain").to_string(), "plain");
        let s = String::from("from-expr");
        assert_eq!(anyhow!(s).to_string(), "from-expr");
        assert_eq!(anyhow!("{} {}", 1, 2).to_string(), "1 2");
    }
}
