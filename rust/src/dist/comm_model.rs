//! α–β (latency–bandwidth) cost model of the collectives on the paper's
//! links: a P-rank ring all-reduce costs `2(P−1)·α + bytes/β`, where the
//! byte count is taken from the *real* ring implementation
//! ([`super::allreduce::ring_bytes_per_rank`]) so model and algorithm
//! agree by construction.

use super::allreduce::ring_bytes_per_rank;

/// A point-to-point link: per-message latency (seconds) and sustained
/// bandwidth (bytes/second).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CommModel {
    pub latency: f64,
    pub bandwidth: f64,
}

impl CommModel {
    /// Intra-node UPI link between the sockets of one Xeon board
    /// (~10.4 GT/s per link, two links): low latency, high bandwidth.
    pub fn upi() -> CommModel {
        CommModel {
            latency: 600e-9,
            bandwidth: 20.8e9,
        }
    }

    /// Inter-node 100 Gb/s fabric (the multi-node scaling runs of
    /// Sec. 4.5): higher latency, ~12.5 GB/s per direction.
    pub fn fabric() -> CommModel {
        CommModel {
            latency: 5e-6,
            bandwidth: 12.5e9,
        }
    }

    /// Modeled seconds for a ring all-reduce of `elems` f32 values across
    /// `ranks` peers: `2(P−1)` latency hops plus the per-rank byte count
    /// of the real ring at this link's bandwidth.
    pub fn ring_allreduce_secs(&self, elems: usize, ranks: usize) -> f64 {
        if ranks <= 1 {
            return 0.0;
        }
        let hops = 2 * (ranks - 1);
        hops as f64 * self.latency + ring_bytes_per_rank(elems, ranks) as f64 / self.bandwidth
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_rank_is_free() {
        assert_eq!(CommModel::upi().ring_allreduce_secs(1_000_000, 1), 0.0);
    }

    #[test]
    fn bandwidth_term_saturates_with_ranks() {
        // Per-rank traffic approaches 2·len·4 bytes as P grows, so the
        // bandwidth term must grow sub-linearly in P.
        let m = CommModel {
            latency: 0.0,
            bandwidth: 1e9,
        };
        let t2 = m.ring_allreduce_secs(1_000_000, 2);
        let t16 = m.ring_allreduce_secs(1_000_000, 16);
        assert!(t16 < 2.0 * t2, "t2={t2} t16={t16}");
    }

    #[test]
    fn latency_term_counts_hops() {
        let m = CommModel {
            latency: 1e-6,
            bandwidth: f64::INFINITY,
        };
        assert!((m.ring_allreduce_secs(10, 4) - 6e-6).abs() < 1e-12);
    }
}
