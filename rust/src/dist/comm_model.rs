//! α–β (latency–bandwidth) cost model of the collectives on the paper's
//! links: a P-rank ring all-reduce costs `2(P−1)·α + bytes/β`, where the
//! byte count is taken from the *real* ring implementation
//! ([`super::allreduce::ring_bytes_per_rank`]) so model and algorithm
//! agree by construction.

use super::allreduce::ring_bytes_per_rank;

/// A point-to-point link: per-message latency (seconds) and sustained
/// bandwidth (bytes/second).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CommModel {
    pub latency: f64,
    pub bandwidth: f64,
}

/// Modeled outcome of a bucketed, backward-overlapped all-reduce
/// ([`CommModel::bucketed_overlap`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OverlapReport {
    /// Serialized cost: Σ ring time over the buckets — what a blocking,
    /// post-backward reduction of the same buckets would add to the step.
    pub comm_secs: f64,
    /// The part of `comm_secs` that is *not* hidden behind backward
    /// compute: how long the collective runs past the last bucket's
    /// gradients becoming available.
    pub exposed_secs: f64,
    /// `1 − exposed/comm` — 1.0 means fully hidden (also reported when
    /// the collective is free, e.g. a single rank).
    pub efficiency: f64,
}

impl CommModel {
    /// Intra-node UPI link between the sockets of one Xeon board
    /// (~10.4 GT/s per link, two links): low latency, high bandwidth.
    pub fn upi() -> CommModel {
        CommModel {
            latency: 600e-9,
            bandwidth: 20.8e9,
        }
    }

    /// Inter-node 100 Gb/s fabric (the multi-node scaling runs of
    /// Sec. 4.5): higher latency, ~12.5 GB/s per direction.
    pub fn fabric() -> CommModel {
        CommModel {
            latency: 5e-6,
            bandwidth: 12.5e9,
        }
    }

    /// Modeled seconds for a ring all-reduce of `elems` f32 values across
    /// `ranks` peers: `2(P−1)` latency hops plus the per-rank byte count
    /// of the real ring at this link's bandwidth.
    pub fn ring_allreduce_secs(&self, elems: usize, ranks: usize) -> f64 {
        if ranks <= 1 {
            return 0.0;
        }
        let hops = 2 * (ranks - 1);
        hops as f64 * self.latency + ring_bytes_per_rank(elems, ranks) as f64 / self.bandwidth
    }

    /// Timeline model of bucketed, backward-overlapped all-reduce: bucket
    /// `i` (`bucket_elems[i]` f32s) becomes available `ready_secs[i]`
    /// seconds after backward starts, and a single communication channel
    /// serves the buckets in order — bucket `i` starts at
    /// `max(ready_i, channel free)` and runs for its ring time on this
    /// link. Returns the serialized total, the part running past the end
    /// of backward (the *exposed* cost that actually extends the step),
    /// and the hiding efficiency.
    pub fn bucketed_overlap(
        &self,
        bucket_elems: &[usize],
        ranks: usize,
        ready_secs: &[f64],
    ) -> OverlapReport {
        assert_eq!(
            bucket_elems.len(),
            ready_secs.len(),
            "one ready time per bucket"
        );
        let mut channel_free = 0.0f64;
        let mut total = 0.0f64;
        let mut backward_end = 0.0f64;
        for (&elems, &ready) in bucket_elems.iter().zip(ready_secs) {
            let t = self.ring_allreduce_secs(elems, ranks);
            total += t;
            channel_free = channel_free.max(ready) + t;
            backward_end = backward_end.max(ready);
        }
        let exposed = (channel_free - backward_end).max(0.0);
        let efficiency = if total > 0.0 { 1.0 - exposed / total } else { 1.0 };
        OverlapReport {
            comm_secs: total,
            exposed_secs: exposed,
            efficiency,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_rank_is_free() {
        assert_eq!(CommModel::upi().ring_allreduce_secs(1_000_000, 1), 0.0);
    }

    #[test]
    fn bandwidth_term_saturates_with_ranks() {
        // Per-rank traffic approaches 2·len·4 bytes as P grows, so the
        // bandwidth term must grow sub-linearly in P.
        let m = CommModel {
            latency: 0.0,
            bandwidth: 1e9,
        };
        let t2 = m.ring_allreduce_secs(1_000_000, 2);
        let t16 = m.ring_allreduce_secs(1_000_000, 16);
        assert!(t16 < 2.0 * t2, "t2={t2} t16={t16}");
    }

    #[test]
    fn latency_term_counts_hops() {
        let m = CommModel {
            latency: 1e-6,
            bandwidth: f64::INFINITY,
        };
        assert!((m.ring_allreduce_secs(10, 4) - 6e-6).abs() < 1e-12);
    }

    #[test]
    fn overlap_nothing_hidden_when_all_buckets_arrive_at_the_end() {
        // Every bucket ready at the same instant backward ends: the
        // collective is fully serialized after compute, efficiency 0.
        let m = CommModel::upi();
        let r = m.bucketed_overlap(&[1000, 1000, 1000], 4, &[1.0, 1.0, 1.0]);
        assert!(r.comm_secs > 0.0);
        assert!((r.exposed_secs - r.comm_secs).abs() < 1e-12);
        assert!(r.efficiency.abs() < 1e-12);
    }

    #[test]
    fn overlap_hides_early_buckets_behind_compute() {
        // Early buckets arrive long before backward ends: only the final
        // bucket's collective can be exposed.
        let m = CommModel::upi();
        let elems = [50_000usize, 50_000, 50_000];
        let r = m.bucketed_overlap(&elems, 4, &[0.0, 0.5, 1.0]);
        let last = m.ring_allreduce_secs(elems[2], 4);
        assert!((r.exposed_secs - last).abs() < 1e-9, "exposed {}", r.exposed_secs);
        assert!(r.efficiency > 0.6, "efficiency {}", r.efficiency);
        // Serialized total matches the sum of per-bucket rings.
        let want: f64 = elems.iter().map(|&e| m.ring_allreduce_secs(e, 4)).sum();
        assert!((r.comm_secs - want).abs() < 1e-12);
    }

    #[test]
    fn overlap_single_rank_is_free_and_fully_hidden() {
        let m = CommModel::fabric();
        let r = m.bucketed_overlap(&[1000, 1000], 1, &[0.0, 0.1]);
        assert_eq!(r.comm_secs, 0.0);
        assert_eq!(r.exposed_secs, 0.0);
        assert_eq!(r.efficiency, 1.0);
    }
}
