//! All-reduce collectives over per-rank gradient buffers.
//!
//! [`ring_allreduce`] is the bandwidth-optimal ring algorithm (reduce-
//! scatter + all-gather over P−1 steps each); every rank ends with the
//! **sum** across ranks. [`naive_allreduce`] is the obviously-correct
//! reference (gather-to-root + broadcast). [`ring_allreduce_threaded`]
//! runs the same ring with real message passing: one OS thread per rank,
//! chunks travelling over mpsc channels — the in-process analog of the
//! paper's inter-socket collective.
//!
//! [`hierarchical_allreduce`] is the NUMA-aware path (DESIGN.md §6b):
//! one thread per socket, each chunk's accumulator built socket-locally
//! and handed around a socket-leader ring, then broadcast back — with
//! the adds applied in *exactly* the monolithic ring's per-chunk order,
//! so the result is bit-identical to [`ring_allreduce`] at every
//! `(sockets, cores)` shape while touching remote memory only
//! `O(sockets)` times per chunk instead of `O(ranks)`.

use super::topology::Placement;

/// Per-rank chunk boundaries: rank/chunk `i` owns `[i·⌈len/P⌉, …)`.
fn chunk_bounds(len: usize, ranks: usize) -> Vec<(usize, usize)> {
    let chunk = len.div_ceil(ranks);
    (0..ranks)
        .map(|i| ((i * chunk).min(len), ((i + 1) * chunk).min(len)))
        .collect()
}

/// Bytes each rank transmits in a full ring all-reduce of `elems` f32s:
/// `2·(P−1)` messages of one ⌈len/P⌉-element chunk each. The α–β model
/// ([`super::comm_model::CommModel`]) uses exactly this count, so model
/// and implementation cannot drift apart.
pub fn ring_bytes_per_rank(elems: usize, ranks: usize) -> u64 {
    if ranks <= 1 {
        return 0;
    }
    2 * (ranks as u64 - 1) * elems.div_ceil(ranks) as u64 * 4
}

/// Borrow two distinct ranks' buffers mutably.
fn two_bufs(bufs: &mut [Vec<f32>], a: usize, b: usize) -> (&mut Vec<f32>, &mut Vec<f32>) {
    assert_ne!(a, b);
    if a < b {
        let (lo, hi) = bufs.split_at_mut(b);
        (&mut lo[a], &mut hi[0])
    } else {
        let (lo, hi) = bufs.split_at_mut(a);
        let (x, y) = (&mut hi[0], &mut lo[b]);
        (x, y)
    }
}

/// Naive all-reduce: sum every rank into rank 0, then broadcast.
/// Reference implementation; `P·len` adds, `2(P−1)·len` element moves.
pub fn naive_allreduce(bufs: &mut [Vec<f32>]) {
    let p = bufs.len();
    if p <= 1 {
        return;
    }
    let (head, rest) = bufs.split_at_mut(1);
    for r in rest.iter() {
        for (a, b) in head[0].iter_mut().zip(r) {
            *a += *b;
        }
    }
    for r in rest.iter_mut() {
        r.copy_from_slice(&head[0]);
    }
}

/// In-place ring all-reduce: every `bufs[r]` ends with the element-wise
/// sum across ranks. Deterministic: chunk `c` accumulates in ring order,
/// identical to the message-passing variant.
pub fn ring_allreduce(bufs: &mut [Vec<f32>]) {
    let p = bufs.len();
    if p <= 1 {
        return;
    }
    let len = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == len), "ragged rank buffers");
    let bounds = chunk_bounds(len, p);

    // Reduce-scatter: at step t, rank r sends chunk (r − t) mod p to rank
    // r+1, which accumulates it. Within a step no rank's outgoing chunk
    // has been touched yet (sender r transmits chunk r−t; the only chunk
    // written at r so far this step is r−1−t), so sequential application
    // is exact.
    for step in 0..p - 1 {
        for r in 0..p {
            let ci = (r + p - step) % p;
            let (lo, hi) = bounds[ci];
            if lo >= hi {
                continue;
            }
            let (src, dst) = two_bufs(bufs, r, (r + 1) % p);
            for (d, s) in dst[lo..hi].iter_mut().zip(&src[lo..hi]) {
                *d += *s;
            }
        }
    }
    // All-gather: rank r now owns the fully-reduced chunk (r + 1) mod p
    // and circulates it; receivers overwrite.
    for step in 0..p - 1 {
        for r in 0..p {
            let ci = (r + 1 + p - step) % p;
            let (lo, hi) = bounds[ci];
            if lo >= hi {
                continue;
            }
            let (src, dst) = two_bufs(bufs, r, (r + 1) % p);
            dst[lo..hi].copy_from_slice(&src[lo..hi]);
        }
    }
}

/// Ring all-reduce over one *bucket* of a larger flat vector, preserving
/// the exact per-element accumulation order of a monolithic
/// [`ring_allreduce`] over the full vector.
///
/// `bufs[r]` holds rank `r`'s copy of the bucket: the concatenation of
/// `regions` (each a `(global_offset, len)` span of the conceptual
/// `global_len`-element gradient), packed back-to-back. Chunking follows
/// the **global** grid — each element is processed under the chunk index
/// it would have in a full-vector ring — so reducing a gradient bucket by
/// bucket is bit-identical to reducing it in one monolithic call. This is
/// what lets the overlapped trainer path promise bitwise equality with
/// the serialized path (see `tests/integration_dist.rs`).
pub fn ring_allreduce_aligned(
    bufs: &mut [Vec<f32>],
    regions: &[(usize, usize)],
    global_len: usize,
) {
    let p = bufs.len();
    if p <= 1 || global_len == 0 {
        return;
    }
    let local_len: usize = regions.iter().map(|&(_, l)| l).sum();
    assert!(
        bufs.iter().all(|b| b.len() == local_len),
        "ragged rank buffers"
    );
    let bounds = chunk_local_ranges(regions, global_len, p);
    // Reduce-scatter, then all-gather — the same schedule as
    // [`ring_allreduce`], restricted to the bucket's ranges.
    for step in 0..p - 1 {
        for r in 0..p {
            let ci = (r + p - step) % p;
            if bounds[ci].is_empty() {
                continue;
            }
            let (src, dst) = two_bufs(bufs, r, (r + 1) % p);
            for &(lo, hi) in &bounds[ci] {
                for (d, s) in dst[lo..hi].iter_mut().zip(&src[lo..hi]) {
                    *d += *s;
                }
            }
        }
    }
    for step in 0..p - 1 {
        for r in 0..p {
            let ci = (r + 1 + p - step) % p;
            if bounds[ci].is_empty() {
                continue;
            }
            let (src, dst) = two_bufs(bufs, r, (r + 1) % p);
            for &(lo, hi) in &bounds[ci] {
                dst[lo..hi].copy_from_slice(&src[lo..hi]);
            }
        }
    }
}

/// Local ranges covered by each *global* chunk: `out[c]` lists the
/// `(lo, hi)` spans of the packed local buffer that fall under global
/// chunk `c` of a `global_len`-element vector split `p` ways. A region
/// may straddle chunk boundaries; a chunk may receive ranges from
/// several regions. Shared by the aligned ring and the hierarchical
/// path, so both walk the identical global grid.
fn chunk_local_ranges(
    regions: &[(usize, usize)],
    global_len: usize,
    p: usize,
) -> Vec<Vec<(usize, usize)>> {
    let chunk = global_len.div_ceil(p);
    let mut bounds: Vec<Vec<(usize, usize)>> = vec![Vec::new(); p];
    let mut local = 0usize;
    for &(goff, glen) in regions {
        assert!(
            goff + glen <= global_len,
            "region ({goff}, {glen}) outside the global vector of {global_len}"
        );
        let gend = goff + glen;
        let mut g = goff;
        while g < gend {
            let ci = g / chunk;
            let cend = ((ci + 1) * chunk).min(gend);
            bounds[ci].push((local, local + (cend - g)));
            local += cend - g;
            g = cend;
        }
    }
    bounds
}

/// NUMA-aware all-reduce: [`hierarchical_allreduce_aligned`] over the
/// whole vector (one region spanning everything).
pub fn hierarchical_allreduce(bufs: &mut [Vec<f32>], placement: Placement) {
    let len = bufs.first().map_or(0, |b| b.len());
    hierarchical_allreduce_aligned(bufs, &[(0, len)], len, placement);
}

/// NUMA-aware all-reduce, **bit-identical** to the monolithic
/// [`ring_allreduce_aligned`] at every placement shape.
///
/// The monolithic ring reduces global chunk `c` as a left fold over
/// ranks in ring-visit order `c, c+1, …, p−1, 0, …, c−1`, each step
/// computing `acc = x_r + acc`. With contiguous socket groups that visit
/// order decomposes cleanly by socket: the origin socket (the one owning
/// rank `c`) contributes its suffix `[c, hi)`, every other socket its
/// full range in increasing rank order, and the origin finally its
/// prefix `[lo, c)`. This function executes exactly that fold with one
/// thread per socket: the accumulator is gathered socket-locally, handed
/// around a socket ring over channels (each leg folding in that socket's
/// ranks), and on completion circulates once more as a broadcast that
/// each socket scatters into its own members' buffers. Different chunks
/// pipeline through different sockets concurrently, so the span is
/// `O((p/S)·len/p)` per socket rather than the ring's `O(len)` on one
/// thread — while every f32 add happens in the monolithic order, which
/// is the whole bit-identity argument (DESIGN.md §6b).
///
/// Degenerates to [`ring_allreduce_aligned`] on a flat placement.
pub fn hierarchical_allreduce_aligned(
    bufs: &mut [Vec<f32>],
    regions: &[(usize, usize)],
    global_len: usize,
    placement: Placement,
) {
    let p = bufs.len();
    if p <= 1 || global_len == 0 {
        return;
    }
    assert_eq!(
        placement.n_ranks(),
        p,
        "placement ranks must match buffer count"
    );
    let sockets = placement.n_sockets();
    if sockets <= 1 {
        ring_allreduce_aligned(bufs, regions, global_len);
        return;
    }
    let local_len: usize = regions.iter().map(|&(_, l)| l).sum();
    assert!(
        bufs.iter().all(|b| b.len() == local_len),
        "ragged rank buffers"
    );
    let bounds = chunk_local_ranges(regions, global_len, p);

    enum HierMsg {
        /// A chunk accumulator on its reduce cycle.
        Reduce(usize, Vec<f32>),
        /// A finished chunk on its broadcast cycle.
        Bcast(usize, Vec<f32>),
    }

    // Channel s carries messages socket s−1 → socket s. Unbounded sends
    // mean a socket can kick off all its chunks before draining its
    // inbox — no deadlock, and the pipeline fills itself.
    let mut txs = Vec::with_capacity(sockets);
    let mut rxs = Vec::with_capacity(sockets);
    for _ in 0..sockets {
        let (tx, rx) = std::sync::mpsc::channel::<HierMsg>();
        txs.push(tx);
        rxs.push(Some(rx));
    }

    // Per-socket exclusive views of the rank buffers: socket threads
    // only ever touch their own members' memory (plus the travelling
    // accumulator), which is the NUMA point of the exercise.
    let mut parts: Vec<&mut [Vec<f32>]> = Vec::with_capacity(sockets);
    let mut rest = bufs;
    for s in 0..sockets {
        let (head, tail) = rest.split_at_mut(placement.ranks_of(s).len());
        parts.push(head);
        rest = tail;
    }

    let bounds = &bounds;
    std::thread::scope(|scope| {
        for (s, part) in parts.into_iter().enumerate() {
            let tx_next = txs[(s + 1) % sockets].clone();
            let rx = rxs[s].take().expect("receiver taken twice");
            let my = placement.ranks_of(s);
            scope.spawn(move || {
                let mut part = part;
                // `acc[i] = x_r[i] + acc[i]` over chunk c's ranges — the
                // exact operand order of the monolithic ring's
                // `dst += src` step (the incoming rank's value on the
                // left, the travelling accumulator on the right).
                let add = |part: &[Vec<f32>], r: usize, c: usize, acc: &mut [f32]| {
                    let buf = &part[r - my.start];
                    let mut i = 0usize;
                    for &(lo, hi) in &bounds[c] {
                        for j in lo..hi {
                            acc[i] = buf[j] + acc[i];
                            i += 1;
                        }
                    }
                };
                let write = |part: &mut [Vec<f32>], c: usize, data: &[f32]| {
                    for buf in part.iter_mut() {
                        let mut i = 0usize;
                        for &(lo, hi) in &bounds[c] {
                            buf[lo..hi].copy_from_slice(&data[i..i + (hi - lo)]);
                            i += hi - lo;
                        }
                    }
                };
                // Kick off every chunk whose chain starts here: copy the
                // head rank's values, fold in the rest of this socket's
                // ranks in increasing order, send the accumulator on.
                for c in my.clone() {
                    let csize: usize = bounds[c].iter().map(|&(lo, hi)| hi - lo).sum();
                    let mut acc = Vec::with_capacity(csize);
                    for &(lo, hi) in &bounds[c] {
                        acc.extend_from_slice(&part[c - my.start][lo..hi]);
                    }
                    for r in c + 1..my.end {
                        add(part, r, c, &mut acc);
                    }
                    tx_next.send(HierMsg::Reduce(c, acc)).expect("ring send");
                }
                // Every chunk's accumulator passes through every socket
                // exactly once on the reduce cycle (the origin receives
                // it last and closes the chain); finished chunks pass
                // through every socket except their origin on the
                // broadcast cycle. Empty chunks circulate too, so the
                // counts stay uniform.
                let mut reduce_left = p;
                let mut bcast_left = p - my.len();
                while reduce_left > 0 || bcast_left > 0 {
                    match rx.recv().expect("ring recv") {
                        HierMsg::Reduce(c, mut acc) => {
                            reduce_left -= 1;
                            if placement.socket_of(c) == s {
                                // The cycle closed: fold in this socket's
                                // prefix (the ranks before the chain
                                // head), then start the broadcast.
                                for r in my.start..c {
                                    add(part, r, c, &mut acc);
                                }
                                write(&mut part, c, &acc);
                                tx_next.send(HierMsg::Bcast(c, acc)).expect("ring send");
                            } else {
                                for r in my.clone() {
                                    add(part, r, c, &mut acc);
                                }
                                tx_next.send(HierMsg::Reduce(c, acc)).expect("ring send");
                            }
                        }
                        HierMsg::Bcast(c, data) => {
                            bcast_left -= 1;
                            write(&mut part, c, &data);
                            if placement.socket_of(c) != (s + 1) % sockets {
                                tx_next.send(HierMsg::Bcast(c, data)).expect("ring send");
                            }
                        }
                    }
                }
            });
        }
    });
}

/// Ring all-reduce with real message passing: one thread per rank, chunk
/// copies over mpsc channels (unbounded sends ⇒ no deadlock). Returns the
/// reduced buffers in rank order; numerically identical to
/// [`ring_allreduce`] (same accumulation order per chunk).
pub fn ring_allreduce_threaded(bufs: Vec<Vec<f32>>) -> Vec<Vec<f32>> {
    let p = bufs.len();
    if p <= 1 {
        return bufs;
    }
    let len = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == len), "ragged rank buffers");
    let bounds = chunk_bounds(len, p);

    // Channel i carries messages rank i → rank (i+1) mod p.
    let mut txs = Vec::with_capacity(p);
    let mut rxs = Vec::with_capacity(p);
    for _ in 0..p {
        let (tx, rx) = std::sync::mpsc::channel::<Vec<f32>>();
        txs.push(tx);
        rxs.push(Some(rx));
    }

    let mut handles = Vec::with_capacity(p);
    for (r, mut buf) in bufs.into_iter().enumerate() {
        let tx = txs[r].clone();
        let rx = rxs[(r + p - 1) % p].take().expect("receiver taken twice");
        let bounds = bounds.clone();
        handles.push(std::thread::spawn(move || {
            // Reduce-scatter.
            for step in 0..p - 1 {
                let cs = (r + p - step) % p;
                let (lo, hi) = bounds[cs];
                tx.send(buf[lo..hi].to_vec()).expect("ring send");
                let cr = (r + p - 1 - step) % p;
                let (lo, hi) = bounds[cr];
                let msg = rx.recv().expect("ring recv");
                for (d, s) in buf[lo..hi].iter_mut().zip(&msg) {
                    *d += *s;
                }
            }
            // All-gather.
            for step in 0..p - 1 {
                let cs = (r + 1 + p - step) % p;
                let (lo, hi) = bounds[cs];
                tx.send(buf[lo..hi].to_vec()).expect("ring send");
                let cr = (r + p - step) % p;
                let (lo, hi) = bounds[cr];
                let msg = rx.recv().expect("ring recv");
                buf[lo..hi].copy_from_slice(&msg);
            }
            buf
        }));
    }
    handles
        .into_iter()
        .map(|h| h.join().expect("ring rank panicked"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ranks(p: usize, len: usize) -> Vec<Vec<f32>> {
        (0..p)
            .map(|r| (0..len).map(|i| (r * len + i) as f32 * 0.25 - 3.0).collect())
            .collect()
    }

    fn sums(bufs: &[Vec<f32>]) -> Vec<f32> {
        let len = bufs[0].len();
        (0..len).map(|i| bufs.iter().map(|b| b[i]).sum()).collect()
    }

    #[test]
    fn ring_equals_sum_small() {
        for p in 1..=6 {
            for len in [1usize, 5, 7, 64, 130] {
                let base = ranks(p, len);
                let want = sums(&base);
                let mut got = base.clone();
                ring_allreduce(&mut got);
                for r in 0..p {
                    for i in 0..len {
                        assert!(
                            (got[r][i] - want[i]).abs() < 1e-3 * (1.0 + want[i].abs()),
                            "p={p} len={len} rank {r} idx {i}: {} vs {}",
                            got[r][i],
                            want[i]
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn threaded_matches_in_place_bitwise() {
        let base = ranks(5, 97);
        let mut a = base.clone();
        ring_allreduce(&mut a);
        let b = ring_allreduce_threaded(base);
        assert_eq!(a, b, "same accumulation order ⇒ bit-identical");
    }

    #[test]
    fn naive_is_the_oracle() {
        let base = ranks(4, 33);
        let want = sums(&base);
        let mut got = base;
        naive_allreduce(&mut got);
        for b in &got {
            for (x, w) in b.iter().zip(&want) {
                assert!((x - w).abs() < 1e-4 * (1.0 + w.abs()));
            }
        }
    }

    #[test]
    fn aligned_ring_is_bitwise_identical_to_monolithic() {
        // Reducing a vector bucket-by-bucket through the aligned ring must
        // reproduce the monolithic full-vector ring bit for bit — the
        // invariant the overlapped trainer path relies on.
        for p in 2..=5 {
            for len in [16usize, 103, 130] {
                let base = ranks(p, len);
                let mut want = base.clone();
                ring_allreduce(&mut want);
                // Three buckets covering the vector; the last one is split
                // into two regions to exercise the region-list path.
                let a = len / 5;
                let b = len / 2;
                let c = (b + len) / 2;
                let splits: Vec<Vec<(usize, usize)>> = vec![
                    vec![(0, a)],
                    vec![(a, b - a)],
                    vec![(b, c - b), (c, len - c)],
                ];
                for regions in &splits {
                    let mut bufs: Vec<Vec<f32>> = base
                        .iter()
                        .map(|full| {
                            let mut v = Vec::new();
                            for &(off, l) in regions {
                                v.extend_from_slice(&full[off..off + l]);
                            }
                            v
                        })
                        .collect();
                    ring_allreduce_aligned(&mut bufs, regions, len);
                    for r in 0..p {
                        let mut local = 0;
                        for &(off, l) in regions {
                            assert_eq!(
                                bufs[r][local..local + l],
                                want[r][off..off + l],
                                "p={p} len={len} rank {r} region ({off},{l})"
                            );
                            local += l;
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn aligned_ring_full_vector_degenerates_to_monolithic() {
        let base = ranks(4, 97);
        let mut want = base.clone();
        ring_allreduce(&mut want);
        let mut got = base;
        ring_allreduce_aligned(&mut got, &[(0, 97)], 97);
        assert_eq!(got, want);
    }

    fn bits(bufs: &[Vec<f32>]) -> Vec<Vec<u32>> {
        bufs.iter()
            .map(|b| b.iter().map(|x| x.to_bits()).collect())
            .collect()
    }

    #[test]
    fn hierarchical_is_bitwise_identical_to_ring() {
        // Every (ranks, sockets) shape — even splits, ragged splits,
        // one rank per socket — must reproduce the monolithic ring's
        // f32 accumulation chain exactly.
        for &(p, s) in &[(8usize, 2usize), (8, 4), (8, 8), (4, 2), (5, 2), (6, 3), (7, 3)] {
            for len in [1usize, 5, 97, 130] {
                let base = ranks(p, len);
                let mut want = base.clone();
                ring_allreduce(&mut want);
                let mut got = base.clone();
                hierarchical_allreduce(&mut got, Placement::new(p, s));
                assert_eq!(bits(&got), bits(&want), "p={p} s={s} len={len}");
            }
        }
    }

    #[test]
    fn hierarchical_aligned_is_bitwise_identical_to_aligned_ring() {
        // Bucket-by-bucket reduction on the global grid, hierarchically:
        // must match the aligned ring (itself bit-identical to the
        // monolithic full-vector ring) region for region.
        for &(p, s) in &[(4usize, 2usize), (8, 2), (8, 4), (7, 3)] {
            let len = 103usize;
            let base = ranks(p, len);
            let mut want = base.clone();
            ring_allreduce(&mut want);
            let a = len / 5;
            let b = len / 2;
            let regions = vec![(a, b - a), (b + 3, len - b - 3)];
            let mut bufs: Vec<Vec<f32>> = base
                .iter()
                .map(|full| {
                    let mut v = Vec::new();
                    for &(off, l) in &regions {
                        v.extend_from_slice(&full[off..off + l]);
                    }
                    v
                })
                .collect();
            hierarchical_allreduce_aligned(&mut bufs, &regions, len, Placement::new(p, s));
            for r in 0..p {
                let mut local = 0;
                for &(off, l) in &regions {
                    let got: Vec<u32> = bufs[r][local..local + l]
                        .iter()
                        .map(|x| x.to_bits())
                        .collect();
                    let exp: Vec<u32> = want[r][off..off + l]
                        .iter()
                        .map(|x| x.to_bits())
                        .collect();
                    assert_eq!(got, exp, "p={p} s={s} rank {r} region ({off},{l})");
                    local += l;
                }
            }
        }
    }

    #[test]
    fn hierarchical_flat_placement_degenerates_to_ring() {
        let base = ranks(6, 64);
        let mut want = base.clone();
        ring_allreduce(&mut want);
        let mut got = base;
        hierarchical_allreduce(&mut got, Placement::flat(6));
        assert_eq!(bits(&got), bits(&want));
    }

    #[test]
    fn byte_accounting() {
        assert_eq!(ring_bytes_per_rank(100, 1), 0);
        // p=4, len=100: chunk 25, 2·3 messages of 25 f32 = 600 bytes.
        assert_eq!(ring_bytes_per_rank(100, 4), 600);
    }
}
