//! Simulated multi-socket substrate (paper Sec. 4.4/4.5): real collective
//! algorithms executed in-process plus the α–β cost model that projects
//! them onto the paper's UPI / fabric links.
//!
//! * [`allreduce`]  — ring + naive all-reduce (in-place, message-passing,
//!   the bucket-aligned variant whose per-element accumulation order
//!   matches the monolithic ring bit for bit, and the NUMA-aware
//!   hierarchical path that reproduces that order socket-by-socket)
//! * [`bucket`]     — fixed-byte-budget gradient buckets in backward
//!   completion order, the unit of communication/compute overlap
//! * [`comm_model`] — α–β (latency–bandwidth) collective cost model,
//!   including the bucketed-overlap timeline ([`OverlapReport`])
//! * [`topology`]   — the unified machine-shape API: paper accounting,
//!   real NUMA detection ([`Topology::detect`]) and the rank→socket
//!   [`Placement`] descriptor every placed consumer shares
//! * [`worker`]     — persistent data-parallel worker pool (one long-lived
//!   thread per rank, each owning its model replica; socket-placed
//!   first-touch spawning via [`PersistentPool::new_placed`])
//!
//! The coordinator runs the *real* ring all-reduce over replica gradients
//! each step — monolithically after backward, or bucket-by-bucket
//! overlapped with it — and separately accumulates what the collective
//! *would* cost between physical sockets via [`CommModel`], so measured
//! numbers stay honest on a single host while the projections use the
//! paper's links (DESIGN.md §6).

pub mod allreduce;
pub mod bucket;
pub mod comm_model;
pub mod topology;
pub mod worker;

pub use allreduce::{hierarchical_allreduce, hierarchical_allreduce_aligned};
pub use bucket::{Bucket, BucketPlan};
pub use comm_model::{CommModel, OverlapReport};
pub use topology::{Placement, Topology, TOPOLOGY_ENV};
pub use worker::{Job, PersistentPool, StepResult, WorkerPool};
