//! Simulated multi-socket substrate (paper Sec. 4.4/4.5): real collective
//! algorithms executed in-process plus the α–β cost model that projects
//! them onto the paper's UPI / fabric links.
//!
//! * [`allreduce`]  — ring + naive all-reduce (in-place and message-passing)
//! * [`comm_model`] — α–β (latency–bandwidth) collective cost model
//! * [`topology`]   — socket/core accounting of the paper's Xeon testbeds
//! * [`worker`]     — data-parallel worker pool (one rank per "socket")
//!
//! The coordinator runs the *real* ring all-reduce over replica gradients
//! each step and separately accumulates what the collective *would* cost
//! between physical sockets via [`CommModel`] — so measured numbers stay
//! honest on a single host while the projections use the paper's links.

pub mod allreduce;
pub mod comm_model;
pub mod topology;
pub mod worker;

pub use comm_model::CommModel;
pub use topology::Topology;
pub use worker::{StepResult, WorkerPool};
