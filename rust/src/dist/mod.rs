//! Simulated multi-socket substrate (paper Sec. 4.4/4.5): real collective
//! algorithms executed in-process plus the α–β cost model that projects
//! them onto the paper's UPI / fabric links.
//!
//! * [`allreduce`]  — ring + naive all-reduce (in-place, message-passing,
//!   and the bucket-aligned variant whose per-element accumulation order
//!   matches the monolithic ring bit for bit)
//! * [`bucket`]     — fixed-byte-budget gradient buckets in backward
//!   completion order, the unit of communication/compute overlap
//! * [`comm_model`] — α–β (latency–bandwidth) collective cost model,
//!   including the bucketed-overlap timeline ([`OverlapReport`])
//! * [`topology`]   — socket/core accounting of the paper's Xeon testbeds
//! * [`worker`]     — persistent data-parallel worker pool (one long-lived
//!   thread per "socket", each owning its model replica)
//!
//! The coordinator runs the *real* ring all-reduce over replica gradients
//! each step — monolithically after backward, or bucket-by-bucket
//! overlapped with it — and separately accumulates what the collective
//! *would* cost between physical sockets via [`CommModel`], so measured
//! numbers stay honest on a single host while the projections use the
//! paper's links (DESIGN.md §6).

pub mod allreduce;
pub mod bucket;
pub mod comm_model;
pub mod topology;
pub mod worker;

pub use bucket::{Bucket, BucketPlan};
pub use comm_model::{CommModel, OverlapReport};
pub use topology::Topology;
pub use worker::{Job, PersistentPool, StepResult, WorkerPool};
