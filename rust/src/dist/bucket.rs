//! Gradient bucketing for backward-overlapped all-reduce (DESIGN.md §6).
//!
//! A [`BucketPlan`] partitions the flat gradient vector into fixed-byte-
//! budget buckets of *whole layers*, ordered by backward completion: the
//! heads finish first, then the residual blocks in reverse, the stem
//! last. As soon as every rank has produced a bucket's layers, that
//! bucket's ring all-reduce can fire while the ranks are still computing
//! earlier layers — the DDP-style overlap of communication with backward
//! compute. Reduction goes through
//! [`ring_allreduce_aligned`](super::allreduce::ring_allreduce_aligned),
//! which chunks on the *global* grid, so the bucketed result is
//! bit-identical to one monolithic ring over the whole gradient.
//!
//! ```
//! use dilconv1d::dist::BucketPlan;
//!
//! // Three layers of 100/50/25 params completing in reverse order,
//! // bucketed under a 400-byte (100-element) budget.
//! let plan = BucketPlan::new(&[100, 50, 25], &[2, 1, 0], 400);
//! assert_eq!(plan.n_buckets(), 2);
//! assert_eq!(plan.elems_per_bucket(), vec![75, 100]); // {L2, L1}, {L0}
//! let (bucket, offset) = plan.slot(1);
//! assert_eq!((bucket, offset), (0, 25)); // L1 packs after L2's 25 elems
//! ```

/// One bucket: whole layers packed back-to-back in completion order.
#[derive(Debug, Clone)]
pub struct Bucket {
    /// Layer ids (packing-order indices) in completion order.
    pub layers: Vec<usize>,
    /// `(global_offset, len)` of each layer's span in the flat vector,
    /// in the order the layers are packed into the bucket buffer.
    pub regions: Vec<(usize, usize)>,
    /// Total f32 elements in the bucket.
    pub elems: usize,
}

/// A fixed partition of the flat gradient vector into completion-ordered
/// buckets under a byte budget. Built once per training run from the
/// network's per-layer parameter counts and its backward completion
/// order; steady-state steps only do table lookups.
#[derive(Debug, Clone)]
pub struct BucketPlan {
    buckets: Vec<Bucket>,
    /// layer id → (bucket index, offset inside the bucket buffer).
    slots: Vec<(usize, usize)>,
    total_elems: usize,
}

impl BucketPlan {
    /// Partition `layer_elems` (flat parameter counts per layer, packing
    /// order) into buckets of at most `budget_bytes` (f32 = 4 bytes),
    /// walking the layers in `completion_order`. A bucket always holds at
    /// least one layer, so a single layer larger than the budget gets a
    /// bucket of its own.
    pub fn new(
        layer_elems: &[usize],
        completion_order: &[usize],
        budget_bytes: usize,
    ) -> BucketPlan {
        let n = layer_elems.len();
        assert_eq!(
            completion_order.len(),
            n,
            "completion order must cover every layer"
        );
        let mut seen = vec![false; n];
        for &l in completion_order {
            assert!(
                l < n && !seen[l],
                "completion order must be a permutation of 0..{n}"
            );
            seen[l] = true;
        }
        let mut offsets = vec![0usize; n];
        let mut total = 0usize;
        for (off, &e) in offsets.iter_mut().zip(layer_elems) {
            *off = total;
            total += e;
        }
        let budget_elems = (budget_bytes / 4).max(1);
        let mut buckets: Vec<Bucket> = Vec::new();
        let mut slots = vec![(0usize, 0usize); n];
        let mut cur = Bucket {
            layers: Vec::new(),
            regions: Vec::new(),
            elems: 0,
        };
        for &l in completion_order {
            if !cur.layers.is_empty() && cur.elems + layer_elems[l] > budget_elems {
                buckets.push(std::mem::replace(
                    &mut cur,
                    Bucket {
                        layers: Vec::new(),
                        regions: Vec::new(),
                        elems: 0,
                    },
                ));
            }
            slots[l] = (buckets.len(), cur.elems);
            cur.layers.push(l);
            cur.regions.push((offsets[l], layer_elems[l]));
            cur.elems += layer_elems[l];
        }
        if !cur.layers.is_empty() {
            buckets.push(cur);
        }
        BucketPlan {
            buckets,
            slots,
            total_elems: total,
        }
    }

    pub fn n_buckets(&self) -> usize {
        self.buckets.len()
    }

    /// Flat length of the full gradient vector the plan partitions.
    pub fn total_elems(&self) -> usize {
        self.total_elems
    }

    pub fn bucket(&self, b: usize) -> &Bucket {
        &self.buckets[b]
    }

    pub fn bucket_elems(&self, b: usize) -> usize {
        self.buckets[b].elems
    }

    /// Per-bucket element counts, in completion order.
    pub fn elems_per_bucket(&self) -> Vec<usize> {
        self.buckets.iter().map(|b| b.elems).collect()
    }

    /// Per-bucket layer counts — the countdown a streaming backward uses
    /// to detect bucket completion.
    pub fn layers_per_bucket(&self) -> Vec<usize> {
        self.buckets.iter().map(|b| b.layers.len()).collect()
    }

    /// `(bucket index, offset inside the bucket buffer)` of `layer`.
    pub fn slot(&self, layer: usize) -> (usize, usize) {
        self.slots[layer]
    }

    /// Copy a (reduced) bucket buffer back into the flat vector.
    pub fn scatter(&self, b: usize, data: &[f32], flat: &mut [f32]) {
        let bk = &self.buckets[b];
        assert_eq!(data.len(), bk.elems, "bucket buffer length mismatch");
        assert_eq!(flat.len(), self.total_elems, "flat vector length mismatch");
        let mut off = 0;
        for &(goff, len) in &bk.regions {
            flat[goff..goff + len].copy_from_slice(&data[off..off + len]);
            off += len;
        }
    }

    /// Pack a bucket's regions out of a flat vector (the inverse of
    /// [`Self::scatter`]; tests and comparison paths).
    pub fn gather(&self, b: usize, flat: &[f32]) -> Vec<f32> {
        let bk = &self.buckets[b];
        assert_eq!(flat.len(), self.total_elems, "flat vector length mismatch");
        let mut out = Vec::with_capacity(bk.elems);
        for &(goff, len) in &bk.regions {
            out.extend_from_slice(&flat[goff..goff + len]);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partitions_every_layer_exactly_once() {
        let elems = [100usize, 50, 50, 25, 25, 7];
        let order = [4usize, 5, 3, 2, 1, 0];
        let plan = BucketPlan::new(&elems, &order, 300); // 75-elem budget
        let mut covered = vec![false; elems.len()];
        let mut walked = Vec::new();
        for b in 0..plan.n_buckets() {
            for &l in &plan.bucket(b).layers {
                assert!(!covered[l], "layer {l} in two buckets");
                covered[l] = true;
                walked.push(l);
            }
        }
        assert!(covered.iter().all(|&c| c), "every layer bucketed");
        assert_eq!(walked, order, "buckets preserve completion order");
        assert_eq!(plan.total_elems(), elems.iter().sum::<usize>());
    }

    #[test]
    fn budget_bounds_buckets_except_oversized_layers() {
        let elems = [10usize, 500, 10, 10];
        let order = [3usize, 2, 1, 0];
        let plan = BucketPlan::new(&elems, &order, 25 * 4); // 25-elem budget
        for b in 0..plan.n_buckets() {
            let bk = plan.bucket(b);
            assert!(
                bk.elems <= 25 || bk.layers.len() == 1,
                "bucket {b} over budget with {} layers",
                bk.layers.len()
            );
        }
        // {3, 2} fits the 25-elem budget, {1} is oversized, {0} trails.
        assert_eq!(plan.elems_per_bucket(), vec![20, 500, 10]);
    }

    #[test]
    fn slot_scatter_gather_round_trip() {
        let elems = [8usize, 4, 6];
        let order = [2usize, 1, 0];
        let plan = BucketPlan::new(&elems, &order, 10 * 4);
        let flat: Vec<f32> = (0..18).map(|i| i as f32).collect();
        let mut rebuilt = vec![0.0f32; 18];
        for b in 0..plan.n_buckets() {
            let data = plan.gather(b, &flat);
            assert_eq!(data.len(), plan.bucket_elems(b));
            plan.scatter(b, &data, &mut rebuilt);
        }
        assert_eq!(rebuilt, flat);
        // Writing via slot offsets lands each layer at its gather position.
        for (l, &e) in elems.iter().enumerate() {
            let (b, off) = plan.slot(l);
            let goff: usize = elems[..l].iter().sum();
            let data = plan.gather(b, &flat);
            assert_eq!(
                data[off..off + e],
                flat[goff..goff + e],
                "layer {l} slot mismatch"
            );
        }
    }

    #[test]
    fn single_bucket_when_budget_is_huge() {
        let plan = BucketPlan::new(&[5, 6, 7], &[2, 1, 0], usize::MAX);
        assert_eq!(plan.n_buckets(), 1);
        assert_eq!(plan.bucket_elems(0), 18);
    }
}
