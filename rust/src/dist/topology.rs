//! Socket/core accounting of the paper's Xeon testbeds (Sec. 4.4/4.5):
//! 28-core sockets, one core reserved for the data loader on a single
//! socket, two (loader + communication proxy) when scaling out, and the
//! per-topology global batch sizes of Sec. 4.5.1.

/// A multi-socket machine shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Topology {
    pub sockets: usize,
    pub cores_per_socket: usize,
}

impl Topology {
    pub fn new(sockets: usize, cores_per_socket: usize) -> Topology {
        assert!(sockets > 0 && cores_per_socket > 2);
        Topology {
            sockets,
            cores_per_socket,
        }
    }

    /// The paper's 28-core Xeon sockets (CLX-AP / CPX).
    pub fn xeon(sockets: usize) -> Topology {
        Topology::new(sockets, 28)
    }

    /// Compute cores per socket: 27 on a single socket (1 reserved for
    /// the DataLoader worker, Sec. 4.4), 26 when multi-socket (a second
    /// core feeds the collective, Sec. 4.5).
    pub fn compute_cores(&self) -> usize {
        if self.sockets <= 1 {
            self.cores_per_socket - 1
        } else {
            self.cores_per_socket - 2
        }
    }

    /// Total compute cores across the machine.
    pub fn total_compute_cores(&self) -> usize {
        self.compute_cores() * self.sockets
    }

    /// Global batch size used by the paper at this topology (Sec. 4.5.1):
    /// 54 on one socket (2 samples per compute core), 26 per socket when
    /// scaled out.
    pub fn paper_batch_size(&self) -> usize {
        if self.sockets <= 1 {
            2 * self.compute_cores()
        } else {
            self.compute_cores() * self.sockets
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn core_reservation() {
        assert_eq!(Topology::xeon(1).compute_cores(), 27);
        assert_eq!(Topology::xeon(2).compute_cores(), 26);
        assert_eq!(Topology::xeon(16).total_compute_cores(), 416);
    }

    #[test]
    fn paper_batches() {
        let got: Vec<usize> = [1usize, 2, 4, 8, 16]
            .iter()
            .map(|&s| Topology::xeon(s).paper_batch_size())
            .collect();
        assert_eq!(got, vec![54, 52, 104, 208, 416]);
    }
}
