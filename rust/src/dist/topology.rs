//! The unified machine-shape API: one [`Topology`] type serves both
//! roles that used to be separate —
//!
//! * **paper accounting** (Sec. 4.4/4.5): 28-core Xeon sockets, one core
//!   reserved for the data loader on a single socket, two (loader +
//!   communication proxy) when scaling out, and the per-topology global
//!   batch sizes of Sec. 4.5.1 ([`Topology::xeon`] and friends);
//! * **real placement**: [`Topology::detect`] reads the host's NUMA
//!   layout from `/sys/devices/system/node/node*/cpulist` (Linux),
//!   honours the `CONV1D_TOPOLOGY=SxC` override so any layout is
//!   testable on any host, and falls back to a single socket.
//!
//! A [`Placement`] maps worker ranks onto sockets (contiguous near-even
//! groups) and is the descriptor every placement-aware consumer shares:
//! socket-sharded worker pools ([`super::PersistentPool::new_placed`]),
//! the hierarchical all-reduce
//! ([`super::allreduce::hierarchical_allreduce`]), the serving
//! dispatcher's bucket→socket routing, and the kernel-level
//! [`crate::conv1d::ExecCtx`].

use std::ops::Range;

/// Environment override for [`Topology::detect`]: `"SxC"` = `S` sockets
/// of `C` cores each (e.g. `CONV1D_TOPOLOGY=2x4`).
pub const TOPOLOGY_ENV: &str = "CONV1D_TOPOLOGY";

/// A multi-socket machine shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Topology {
    pub sockets: usize,
    pub cores_per_socket: usize,
}

impl Topology {
    /// Paper-accounting constructor: shapes with at least 3 cores per
    /// socket, so the reserved-core arithmetic of
    /// [`Self::compute_cores`] stays meaningful.
    pub fn new(sockets: usize, cores_per_socket: usize) -> Topology {
        assert!(sockets > 0 && cores_per_socket > 2);
        Topology {
            sockets,
            cores_per_socket,
        }
    }

    /// General placement constructor: any positive shape, including the
    /// tiny emulated layouts the topology test matrix uses (`2x4`,
    /// `4x2`). The paper-accounting helpers ([`Self::compute_cores`],
    /// [`Self::paper_batch_size`]) describe the Xeon testbeds and
    /// assume a [`Self::new`]-legal shape; placement consumers only
    /// need [`Self::placement`].
    pub fn shape(sockets: usize, cores_per_socket: usize) -> Topology {
        assert!(
            sockets > 0 && cores_per_socket > 0,
            "topology needs at least one socket and one core"
        );
        Topology {
            sockets,
            cores_per_socket,
        }
    }

    /// The paper's 28-core Xeon sockets (CLX-AP / CPX).
    pub fn xeon(sockets: usize) -> Topology {
        Topology::new(sockets, 28)
    }

    /// The machine shape this process runs on.
    ///
    /// Resolution order:
    /// 1. the [`TOPOLOGY_ENV`] (`CONV1D_TOPOLOGY=SxC`) override — how
    ///    the CI matrix emulates any layout on any host; malformed
    ///    values are a hard error, because a typo silently falling back
    ///    to the host shape would invalidate the run;
    /// 2. the Linux NUMA sysfs (`/sys/devices/system/node`);
    /// 3. a single socket spanning the available parallelism.
    pub fn detect() -> Topology {
        if let Ok(spec) = std::env::var(TOPOLOGY_ENV) {
            return spec
                .parse()
                .unwrap_or_else(|e| panic!("{TOPOLOGY_ENV}={spec}: {e}"));
        }
        if let Some(t) = Self::detect_sysfs("/sys/devices/system/node") {
            return t;
        }
        let cores = std::thread::available_parallelism().map_or(1, |n| n.get());
        Topology {
            sockets: 1,
            cores_per_socket: cores.max(1),
        }
    }

    /// Parse the NUMA sysfs tree: one socket per `node<N>` directory
    /// with a non-empty `cpulist`, cores per socket = the smallest
    /// node's CPU count (conservative for asymmetric layouts).
    fn detect_sysfs(root: &str) -> Option<Topology> {
        let entries = std::fs::read_dir(root).ok()?;
        let mut nodes = 0usize;
        let mut min_cores = usize::MAX;
        for entry in entries.flatten() {
            let name = entry.file_name();
            let name = name.to_string_lossy();
            let is_node = matches!(
                name.strip_prefix("node"),
                Some(d) if !d.is_empty() && d.bytes().all(|b| b.is_ascii_digit())
            );
            if !is_node {
                continue;
            }
            let cpulist = match std::fs::read_to_string(entry.path().join("cpulist")) {
                Ok(s) => s,
                Err(_) => continue,
            };
            let cores = count_cpulist(cpulist.trim());
            if cores > 0 {
                nodes += 1;
                min_cores = min_cores.min(cores);
            }
        }
        (nodes > 0).then(|| Topology {
            sockets: nodes,
            cores_per_socket: min_cores.max(1),
        })
    }

    /// Compute cores per socket: 27 on a single socket (1 reserved for
    /// the DataLoader worker, Sec. 4.4), 26 when multi-socket (a second
    /// core feeds the collective, Sec. 4.5).
    pub fn compute_cores(&self) -> usize {
        if self.sockets <= 1 {
            self.cores_per_socket - 1
        } else {
            self.cores_per_socket - 2
        }
    }

    /// Total compute cores across the machine.
    pub fn total_compute_cores(&self) -> usize {
        self.compute_cores() * self.sockets
    }

    /// Global batch size used by the paper at this topology (Sec. 4.5.1):
    /// 54 on one socket (2 samples per compute core), 26 per socket when
    /// scaled out.
    pub fn paper_batch_size(&self) -> usize {
        if self.sockets <= 1 {
            2 * self.compute_cores()
        } else {
            self.compute_cores() * self.sockets
        }
    }

    /// Total cores across the machine (no reservation accounting).
    pub fn total_cores(&self) -> usize {
        self.sockets * self.cores_per_socket
    }

    /// Place `ranks` workers onto this topology's sockets: contiguous
    /// near-even groups, never more sockets than ranks.
    pub fn placement(&self, ranks: usize) -> Placement {
        Placement::new(ranks, self.sockets)
    }
}

/// Number of CPUs in a sysfs `cpulist` string (`"0-3,8,10-11"` → 6).
/// Malformed fragments count zero rather than failing detection.
fn count_cpulist(list: &str) -> usize {
    if list.is_empty() {
        return 0;
    }
    list.split(',')
        .map(|part| {
            let part = part.trim();
            match part.split_once('-') {
                Some((lo, hi)) => match (lo.trim().parse::<usize>(), hi.trim().parse::<usize>()) {
                    (Ok(lo), Ok(hi)) if hi >= lo => hi - lo + 1,
                    _ => 0,
                },
                None => usize::from(part.parse::<usize>().is_ok()),
            }
        })
        .sum()
}

impl std::fmt::Display for Topology {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}x{}", self.sockets, self.cores_per_socket)
    }
}

impl std::str::FromStr for Topology {
    type Err = String;

    /// `"SxC"` — sockets × cores per socket, both positive.
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let (sockets, cores) = s
            .trim()
            .split_once(['x', 'X'])
            .ok_or_else(|| format!("expected SxC (e.g. 2x4), got '{s}'"))?;
        let sockets: usize = sockets
            .trim()
            .parse()
            .map_err(|_| format!("bad socket count in '{s}'"))?;
        let cores: usize = cores
            .trim()
            .parse()
            .map_err(|_| format!("bad core count in '{s}'"))?;
        if sockets == 0 || cores == 0 {
            return Err(format!("'{s}' names an empty topology"));
        }
        Ok(Topology::shape(sockets, cores))
    }
}

/// Socket id → worker ranks: `ranks` workers split into `sockets`
/// contiguous near-even groups (sizes differ by at most one, lower
/// socket ids take the extras). Compact and `Copy`, so it travels
/// inside [`crate::conv1d::ExecCtx`] next to `threads`/`partition`.
///
/// ```
/// use dilconv1d::dist::Placement;
///
/// let p = Placement::new(8, 2);
/// assert_eq!(p.ranks_of(0), 0..4);
/// assert_eq!(p.ranks_of(1), 4..8);
/// assert_eq!(p.socket_of(5), 1);
/// assert_eq!(p.leader(1), 4);
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Placement {
    ranks: usize,
    sockets: usize,
}

impl Placement {
    /// Place `ranks` workers on `sockets` sockets. Sockets are clamped
    /// to `1..=ranks`, so every socket owns at least one rank.
    pub fn new(ranks: usize, sockets: usize) -> Placement {
        assert!(ranks > 0, "placement needs at least one rank");
        Placement {
            ranks,
            sockets: sockets.clamp(1, ranks),
        }
    }

    /// Everything on one socket — the topology-blind layout every
    /// placed code path degenerates to.
    pub fn flat(ranks: usize) -> Placement {
        Placement::new(ranks, 1)
    }

    pub fn n_ranks(&self) -> usize {
        self.ranks
    }

    pub fn n_sockets(&self) -> usize {
        self.sockets
    }

    /// Whether this is the single-socket (flat) layout.
    pub fn is_flat(&self) -> bool {
        self.sockets <= 1
    }

    /// The contiguous rank range socket `socket` owns.
    pub fn ranks_of(&self, socket: usize) -> Range<usize> {
        assert!(socket < self.sockets, "socket {socket} out of range");
        let base = self.ranks / self.sockets;
        let extra = self.ranks % self.sockets;
        let start = socket * base + socket.min(extra);
        let len = base + usize::from(socket < extra);
        start..start + len
    }

    /// The socket owning `rank`.
    pub fn socket_of(&self, rank: usize) -> usize {
        assert!(rank < self.ranks, "rank {rank} out of range");
        let base = self.ranks / self.sockets;
        let extra = self.ranks % self.sockets;
        let fat = extra * (base + 1);
        if rank < fat {
            rank / (base + 1)
        } else {
            extra + (rank - fat) / base
        }
    }

    /// The socket's leader rank (its first rank) — the rank whose
    /// thread carries the inter-socket legs of the hierarchical
    /// all-reduce.
    pub fn leader(&self, socket: usize) -> usize {
        self.ranks_of(socket).start
    }
}

impl std::fmt::Display for Placement {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} ranks / {} sockets", self.ranks, self.sockets)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn core_reservation() {
        assert_eq!(Topology::xeon(1).compute_cores(), 27);
        assert_eq!(Topology::xeon(2).compute_cores(), 26);
        assert_eq!(Topology::xeon(16).total_compute_cores(), 416);
    }

    #[test]
    fn paper_batches() {
        let got: Vec<usize> = [1usize, 2, 4, 8, 16]
            .iter()
            .map(|&s| Topology::xeon(s).paper_batch_size())
            .collect();
        assert_eq!(got, vec![54, 52, 104, 208, 416]);
    }

    #[test]
    fn parse_round_trips_and_rejects_garbage() {
        let t: Topology = "2x4".parse().expect("parse");
        assert_eq!((t.sockets, t.cores_per_socket), (2, 4));
        assert_eq!(t.to_string(), "2x4");
        assert_eq!(" 4X2 ".parse::<Topology>().expect("parse").total_cores(), 8);
        for bad in ["", "2", "x4", "2x", "0x4", "2x0", "axb"] {
            assert!(bad.parse::<Topology>().is_err(), "'{bad}' must not parse");
        }
    }

    #[test]
    fn shape_allows_tiny_layouts_for_placement() {
        let t = Topology::shape(4, 2);
        assert_eq!(t.total_cores(), 8);
        let p = t.placement(8);
        assert_eq!(p.n_sockets(), 4);
        assert_eq!(p.ranks_of(3), 6..8);
    }

    #[test]
    fn detect_returns_a_positive_shape() {
        // Whatever the host (or the env override in a CI matrix run)
        // says, the result must be usable for placement.
        let t = Topology::detect();
        assert!(t.sockets >= 1 && t.cores_per_socket >= 1);
        assert_eq!(t.placement(4).n_ranks(), 4);
    }

    #[test]
    fn sysfs_parser_handles_real_and_missing_trees() {
        // The real sysfs may or may not exist in the test environment;
        // when it does, detection must produce a positive shape.
        if let Some(t) = Topology::detect_sysfs("/sys/devices/system/node") {
            assert!(t.sockets >= 1 && t.cores_per_socket >= 1);
        }
        assert_eq!(Topology::detect_sysfs("/nonexistent/path"), None);
    }

    #[test]
    fn cpulist_counting() {
        assert_eq!(count_cpulist("0-3,8,10-11"), 6);
        assert_eq!(count_cpulist("0"), 1);
        assert_eq!(count_cpulist("0-27"), 28);
        assert_eq!(count_cpulist(""), 0);
        assert_eq!(count_cpulist("garbage"), 0);
    }

    #[test]
    fn placement_groups_are_contiguous_and_near_even() {
        for ranks in 1..=9 {
            for sockets in 1..=6 {
                let p = Placement::new(ranks, sockets);
                let mut covered = 0usize;
                let mut sizes = Vec::new();
                for s in 0..p.n_sockets() {
                    let r = p.ranks_of(s);
                    assert_eq!(r.start, covered, "groups must be contiguous");
                    assert_eq!(p.leader(s), r.start);
                    for rank in r.clone() {
                        assert_eq!(p.socket_of(rank), s);
                    }
                    sizes.push(r.len());
                    covered = r.end;
                }
                assert_eq!(covered, ranks, "every rank placed exactly once");
                let (min, max) = (
                    *sizes.iter().min().expect("non-empty"),
                    *sizes.iter().max().expect("non-empty"),
                );
                assert!(min >= 1 && max - min <= 1, "near-even split");
            }
        }
    }

    #[test]
    fn flat_placement_is_one_socket() {
        let p = Placement::flat(5);
        assert!(p.is_flat());
        assert_eq!(p.n_sockets(), 1);
        assert_eq!(p.ranks_of(0), 0..5);
    }
}
