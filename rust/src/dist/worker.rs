//! Data-parallel worker pool: one scoped thread per rank computes a
//! `(gradient, loss)` pair, gradients are combined with the real ring
//! all-reduce and averaged — the in-process version of one synchronous
//! data-parallel step (paper Sec. 4.4).

use super::allreduce::ring_allreduce;

/// A fixed-size pool of data-parallel ranks.
#[derive(Debug, Clone, Copy)]
pub struct WorkerPool {
    ranks: usize,
}

/// Result of one pooled step: rank-averaged gradient and loss.
#[derive(Debug, Clone)]
pub struct StepResult {
    pub grad: Vec<f32>,
    pub loss: f64,
}

impl WorkerPool {
    pub fn new(ranks: usize) -> WorkerPool {
        assert!(ranks > 0, "pool needs at least one rank");
        WorkerPool { ranks }
    }

    pub fn ranks(&self) -> usize {
        self.ranks
    }

    /// Run `f(rank)` on every rank concurrently, ring-all-reduce the
    /// gradients, and return the mean gradient and mean loss.
    pub fn step<F>(&self, f: F) -> StepResult
    where
        F: Fn(usize) -> (Vec<f32>, f64) + Sync,
    {
        let p = self.ranks;
        let mut slots: Vec<Option<(Vec<f32>, f64)>> = (0..p).map(|_| None).collect();
        std::thread::scope(|scope| {
            for (rank, slot) in slots.iter_mut().enumerate() {
                let f = &f;
                scope.spawn(move || {
                    *slot = Some(f(rank));
                });
            }
        });
        let mut grads = Vec::with_capacity(p);
        let mut loss = 0.0f64;
        for slot in slots {
            let (g, l) = slot.expect("rank produced no result");
            grads.push(g);
            loss += l;
        }
        ring_allreduce(&mut grads);
        let mut grad = grads.swap_remove(0);
        let inv = 1.0 / p as f32;
        for g in grad.iter_mut() {
            *g *= inv;
        }
        StepResult {
            grad,
            loss: loss / p as f64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn averages_rank_contributions() {
        let pool = WorkerPool::new(3);
        let r = pool.step(|rank| (vec![rank as f32; 8], rank as f64 * 10.0));
        for &g in &r.grad {
            assert!((g - 1.0).abs() < 1e-6); // mean of 0, 1, 2
        }
        assert!((r.loss - 10.0).abs() < 1e-12);
        assert_eq!(pool.ranks(), 3);
    }

    #[test]
    fn single_rank_passthrough() {
        let pool = WorkerPool::new(1);
        let r = pool.step(|_| (vec![2.5; 4], 7.0));
        assert_eq!(r.grad, vec![2.5; 4]);
        assert_eq!(r.loss, 7.0);
    }
}
