//! Data-parallel worker pools.
//!
//! [`PersistentPool`] is the training substrate: one long-lived OS thread
//! per rank, each *owning* its rank state (the coordinator hands every
//! rank its model replica once, at construction), with jobs dispatched
//! over channels. Spawning happens once per run, not once per step — the
//! steady state of a training epoch is channel sends only, and a rank's
//! jobs execute in submission order, which is what lets the bucketed
//! all-reduce overlap with a still-running backward pass.
//!
//! [`PersistentPool::new_placed`] is the NUMA-aware constructor: rank
//! states are built *on the rank's own thread*, so the pages backing a
//! replica's weights, workspaces and staging buffers are first-touched
//! by the thread that will run its jobs. Under the default Linux
//! first-touch policy that keeps each replica's memory on the socket the
//! [`Placement`] assigns it to.
//!
//! [`WorkerPool`] is the older scoped-thread convenience (one spawn per
//! step) kept for the simple fork-join collectives in tests and benches.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use super::allreduce::ring_allreduce;
use super::topology::Placement;

/// A job executed on a rank's thread against its owned state. Public so
/// callers that supervise ranks (the serving dispatcher) can hold a job
/// as a value and re-route it when a rank dies ([`PersistentPool::try_exec`]).
pub type Job<W> = Box<dyn FnOnce(&mut W) + Send + 'static>;

enum Msg<W> {
    Job(Job<W>),
    Sync(Sender<()>),
    Stop,
}

/// A rank's job loop: run jobs from `rx` in submission order against the
/// owned state, hand the state back when stopped. The receiver is
/// dropped if a job unwinds the thread, which is exactly how a dead rank
/// is detected: subsequent sends to it fail.
fn run_rank<W>(mut state: W, rx: Receiver<Msg<W>>) -> W {
    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Job(job) => job(&mut state),
            Msg::Sync(ack) => {
                let _ = ack.send(());
            }
            Msg::Stop => break,
        }
    }
    state
}

/// Spawn one rank thread owning an already-built `state`. The `Option`
/// in the handle type matches the placed spawn path, where a thread
/// whose builder failed has no state to hand back.
fn spawn_rank<W: Send + 'static>(state: W, rx: Receiver<Msg<W>>) -> JoinHandle<Option<W>> {
    std::thread::spawn(move || Some(run_rank(state, rx)))
}

/// A pool of long-lived rank threads, each owning a state `W` (e.g. a
/// model replica). Jobs submitted to a rank run on its thread in
/// submission order; different ranks run concurrently.
///
/// ```
/// use dilconv1d::dist::PersistentPool;
///
/// // Three ranks, each owning a counter.
/// let pool = PersistentPool::new(vec![0u64, 0, 0]);
/// let (tx, rx) = std::sync::mpsc::channel();
/// for rank in 0..pool.ranks() {
///     let tx = tx.clone();
///     pool.exec(rank, move |count| {
///         *count += rank as u64 + 1;
///         let _ = tx.send(*count);
///     });
/// }
/// let total: u64 = rx.iter().take(3).sum();
/// assert_eq!(total, 6); // 1 + 2 + 3
/// assert_eq!(pool.join(), vec![1, 2, 3]);
/// ```
pub struct PersistentPool<W> {
    txs: Vec<Sender<Msg<W>>>,
    handles: Vec<JoinHandle<Option<W>>>,
    placement: Placement,
}

impl<W: Send + 'static> PersistentPool<W> {
    /// Spawn one thread per state; thread `r` owns `states[r]` for the
    /// pool's lifetime and hands it back at [`Self::join`]. States were
    /// built by the caller's thread, so this is the topology-blind
    /// (flat placement) constructor — see [`Self::new_placed`] for the
    /// first-touch path.
    pub fn new(states: Vec<W>) -> PersistentPool<W> {
        assert!(!states.is_empty(), "pool needs at least one rank");
        let placement = Placement::flat(states.len());
        let mut txs = Vec::with_capacity(states.len());
        let mut handles = Vec::with_capacity(states.len());
        for state in states {
            let (tx, rx) = channel::<Msg<W>>();
            txs.push(tx);
            handles.push(spawn_rank(state, rx));
        }
        PersistentPool {
            txs,
            handles,
            placement,
        }
    }

    /// Spawn `placement.n_ranks()` threads, each building its own state
    /// with `build(rank, socket)` **on the rank's thread** — the
    /// first-touch rule that keeps replica memory socket-local. Blocks
    /// until every rank has finished building.
    pub fn new_placed<F>(placement: Placement, build: F) -> PersistentPool<W>
    where
        F: Fn(usize, usize) -> W + Send + Sync + 'static,
    {
        let result = Self::try_new_placed::<std::convert::Infallible, _>(placement, move |r, s| {
            Ok(build(r, s))
        });
        match result {
            Ok(pool) => pool,
            Err(never) => match never {},
        }
    }

    /// Fallible [`Self::new_placed`]: if any rank's builder returns an
    /// error, every already-spawned thread is stopped and joined and the
    /// lowest-ranked error is returned (deterministic regardless of
    /// which builder finished first).
    pub fn try_new_placed<E, F>(placement: Placement, build: F) -> Result<PersistentPool<W>, E>
    where
        E: Send + 'static,
        F: Fn(usize, usize) -> Result<W, E> + Send + Sync + 'static,
    {
        let build = Arc::new(build);
        let ranks = placement.n_ranks();
        let (status_tx, status_rx) = channel::<(usize, Option<E>)>();
        let mut txs = Vec::with_capacity(ranks);
        let mut handles = Vec::with_capacity(ranks);
        for rank in 0..ranks {
            let socket = placement.socket_of(rank);
            let (tx, rx) = channel::<Msg<W>>();
            txs.push(tx);
            let build = Arc::clone(&build);
            let status = status_tx.clone();
            handles.push(std::thread::spawn(move || match build(rank, socket) {
                Ok(state) => {
                    let _ = status.send((rank, None));
                    Some(run_rank(state, rx))
                }
                Err(e) => {
                    let _ = status.send((rank, Some(e)));
                    None
                }
            }));
        }
        drop(status_tx);
        let mut errors: Vec<(usize, E)> = Vec::new();
        for _ in 0..ranks {
            match status_rx.recv() {
                Ok((_, None)) => {}
                Ok((rank, Some(e))) => errors.push((rank, e)),
                // A builder thread panicked before reporting; surface it
                // the same way a dead rank is surfaced everywhere else —
                // via bounced sends — rather than blocking here forever.
                Err(_) => break,
            }
        }
        if let Some((_, first)) = errors.into_iter().min_by_key(|e| e.0) {
            for tx in &txs {
                let _ = tx.send(Msg::Stop);
            }
            for h in handles {
                let _ = h.join();
            }
            return Err(first);
        }
        Ok(PersistentPool {
            txs,
            handles,
            placement,
        })
    }

    pub fn ranks(&self) -> usize {
        self.handles.len()
    }

    /// The rank→socket layout this pool was spawned with (flat for
    /// [`Self::new`]).
    pub fn placement(&self) -> Placement {
        self.placement
    }

    /// Queue `job` on rank `rank`'s thread. Jobs on one rank run in
    /// submission order; results travel through whatever channel the
    /// closure captured. Panics if the rank's thread has died (a previous
    /// job panicked).
    pub fn exec(&self, rank: usize, job: impl FnOnce(&mut W) + Send + 'static) {
        self.txs[rank]
            .send(Msg::Job(Box::new(job)))
            .unwrap_or_else(|_| panic!("rank {rank} worker thread died"));
    }

    /// Like [`Self::exec`], but hands the boxed job back instead of
    /// panicking when the rank's thread has died, so a supervisor can
    /// re-route the work or [`Self::respawn`] the rank. Jobs that were
    /// already queued on the dead rank are gone — their closures were
    /// dropped when the rank's channel receiver unwound — so any
    /// cleanup they carry must live in the closure's captured values'
    /// `Drop` impls.
    pub fn try_exec(&self, rank: usize, job: Job<W>) -> Result<(), Job<W>> {
        match self.txs[rank].send(Msg::Job(job)) {
            Ok(()) => Ok(()),
            Err(std::sync::mpsc::SendError(Msg::Job(job))) => Err(job),
            Err(_) => unreachable!("send bounced a message this call never sent"),
        }
    }

    /// Replace a dead rank's thread with a fresh one owning `state`.
    /// The old thread's handle is reaped and its panic payload, if any,
    /// discarded — the caller has already observed the death via a
    /// bounced [`Self::try_exec`] and decided on a restart policy.
    ///
    /// `state` was built by the supervising thread, not the rank's own,
    /// so a respawned replica loses the first-touch guarantee of
    /// [`Self::new_placed`] — an accepted cost on this rare recovery
    /// path (the alternative, building inside the new thread, would
    /// leave the supervisor unable to report build errors synchronously).
    pub fn respawn(&mut self, rank: usize, state: W) {
        let (tx, rx) = channel::<Msg<W>>();
        let handle = spawn_rank(state, rx);
        self.txs[rank] = tx;
        let old = std::mem::replace(&mut self.handles[rank], handle);
        let _ = old.join();
    }

    /// Block until every rank has drained its job queue.
    pub fn sync(&self) {
        let acks: Vec<_> = self
            .txs
            .iter()
            .enumerate()
            .map(|(rank, tx)| {
                let (ack, ack_rx) = channel();
                tx.send(Msg::Sync(ack))
                    .unwrap_or_else(|_| panic!("rank {rank} worker thread died"));
                ack_rx
            })
            .collect();
        for (rank, rx) in acks.into_iter().enumerate() {
            rx.recv()
                .unwrap_or_else(|_| panic!("rank {rank} worker thread died"));
        }
    }

    /// Like [`Self::sync`], but skips dead ranks instead of panicking —
    /// the serving supervisor owns their restart policy, and a drain
    /// must still wait out every *live* rank's queue. Returns how many
    /// ranks acknowledged.
    pub fn sync_lossy(&self) -> usize {
        let acks: Vec<_> = self
            .txs
            .iter()
            .filter_map(|tx| {
                let (ack, ack_rx) = channel();
                tx.send(Msg::Sync(ack)).ok().map(|()| ack_rx)
            })
            .collect();
        acks.into_iter().filter(|rx| rx.recv().is_ok()).count()
    }

    /// Stop every thread and return the rank states in rank order.
    pub fn join(mut self) -> Vec<W> {
        self.send_stop();
        self.handles
            .drain(..)
            .map(|h| {
                h.join()
                    .expect("worker thread panicked")
                    .expect("a constructed pool's ranks all hold state")
            })
            .collect()
    }
}

impl<W> PersistentPool<W> {
    fn send_stop(&mut self) {
        for tx in &self.txs {
            let _ = tx.send(Msg::Stop);
        }
        self.txs.clear();
    }
}

impl<W> Drop for PersistentPool<W> {
    fn drop(&mut self) {
        self.send_stop();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// A fixed-size pool of data-parallel ranks.
#[derive(Debug, Clone, Copy)]
pub struct WorkerPool {
    ranks: usize,
}

/// Result of one pooled step: rank-averaged gradient and loss.
#[derive(Debug, Clone)]
pub struct StepResult {
    pub grad: Vec<f32>,
    pub loss: f64,
}

impl WorkerPool {
    pub fn new(ranks: usize) -> WorkerPool {
        assert!(ranks > 0, "pool needs at least one rank");
        WorkerPool { ranks }
    }

    pub fn ranks(&self) -> usize {
        self.ranks
    }

    /// Run `f(rank)` on every rank concurrently, ring-all-reduce the
    /// gradients, and return the mean gradient and mean loss.
    pub fn step<F>(&self, f: F) -> StepResult
    where
        F: Fn(usize) -> (Vec<f32>, f64) + Sync,
    {
        let p = self.ranks;
        let mut slots: Vec<Option<(Vec<f32>, f64)>> = (0..p).map(|_| None).collect();
        std::thread::scope(|scope| {
            for (rank, slot) in slots.iter_mut().enumerate() {
                let f = &f;
                scope.spawn(move || {
                    *slot = Some(f(rank));
                });
            }
        });
        let mut grads = Vec::with_capacity(p);
        let mut loss = 0.0f64;
        for slot in slots {
            let (g, l) = slot.expect("rank produced no result");
            grads.push(g);
            loss += l;
        }
        ring_allreduce(&mut grads);
        let mut grad = grads.swap_remove(0);
        let inv = 1.0 / p as f32;
        for g in grad.iter_mut() {
            *g *= inv;
        }
        StepResult {
            grad,
            loss: loss / p as f64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn averages_rank_contributions() {
        let pool = WorkerPool::new(3);
        let r = pool.step(|rank| (vec![rank as f32; 8], rank as f64 * 10.0));
        for &g in &r.grad {
            assert!((g - 1.0).abs() < 1e-6); // mean of 0, 1, 2
        }
        assert!((r.loss - 10.0).abs() < 1e-12);
        assert_eq!(pool.ranks(), 3);
    }

    #[test]
    fn single_rank_passthrough() {
        let pool = WorkerPool::new(1);
        let r = pool.step(|_| (vec![2.5; 4], 7.0));
        assert_eq!(r.grad, vec![2.5; 4]);
        assert_eq!(r.loss, 7.0);
    }

    #[test]
    fn persistent_pool_owns_state_across_jobs() {
        let pool = PersistentPool::new(vec![Vec::<u32>::new(), Vec::new()]);
        for i in 0..5u32 {
            for rank in 0..pool.ranks() {
                pool.exec(rank, move |log| log.push(i));
            }
        }
        pool.sync();
        let states = pool.join();
        // Per-rank jobs ran in submission order against persistent state.
        assert_eq!(states, vec![vec![0, 1, 2, 3, 4], vec![0, 1, 2, 3, 4]]);
    }

    #[test]
    fn persistent_pool_ranks_run_concurrently() {
        // Rank 0 blocks until rank 1's job has run — only possible if the
        // two ranks execute on different threads.
        let pool = PersistentPool::new(vec![(), ()]);
        let (tx, rx) = std::sync::mpsc::channel::<()>();
        pool.exec(0, move |_| {
            rx.recv_timeout(std::time::Duration::from_secs(10))
                .expect("rank 1 never signalled");
        });
        pool.exec(1, move |_| {
            let _ = tx.send(());
        });
        pool.sync();
    }

    #[test]
    fn persistent_pool_drop_terminates_threads() {
        let pool = PersistentPool::new(vec![0u8]);
        pool.exec(0, |s| *s += 1);
        drop(pool); // must not hang
    }

    #[test]
    fn placed_pool_builds_state_on_rank_threads() {
        let placement = Placement::new(4, 2);
        let main = std::thread::current().id();
        let pool = PersistentPool::new_placed(placement, move |rank, socket| {
            // First-touch contract: the builder runs off the spawning
            // thread, on the rank's own.
            assert_ne!(std::thread::current().id(), main);
            (rank, socket)
        });
        assert_eq!(pool.ranks(), 4);
        assert_eq!(pool.placement().n_sockets(), 2);
        pool.sync();
        assert_eq!(pool.join(), vec![(0, 0), (1, 0), (2, 1), (3, 1)]);
    }

    #[test]
    fn flat_pool_reports_flat_placement() {
        let pool = PersistentPool::new(vec![0u8, 0]);
        assert!(pool.placement().is_flat());
        assert_eq!(pool.placement().n_ranks(), 2);
    }

    #[test]
    fn placed_pool_surfaces_the_lowest_rank_build_error() {
        let err = PersistentPool::<u32>::try_new_placed(Placement::new(3, 3), |rank, _| {
            if rank == 0 {
                Ok(1u32)
            } else {
                Err(format!("rank {rank} refused"))
            }
        })
        .err()
        .expect("build must fail");
        // Two ranks errored; the lowest rank's error wins, deterministically.
        assert_eq!(err, "rank 1 refused");
    }

    /// Silence the panic-handler backtrace for a deliberately killed
    /// rank without disturbing other tests' hooks.
    fn kill_rank_quietly(pool: &PersistentPool<u32>, rank: usize) {
        pool.exec(rank, |_| {
            std::panic::panic_any("rank killed by test");
        });
        // Wait until the thread has actually unwound: a sync ack channel
        // dropped without a reply means the rank is dead.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while pool.sync_lossy() > pool.ranks() - 1 {
            assert!(std::time::Instant::now() < deadline, "rank never died");
            std::thread::yield_now();
        }
    }

    #[test]
    fn try_exec_returns_the_job_when_a_rank_is_dead_and_respawn_revives_it() {
        let hook = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {}));
        let mut pool = PersistentPool::new(vec![10u32]);
        kill_rank_quietly(&pool, 0);
        std::panic::set_hook(hook);

        // The bounced job comes back intact and can be re-routed.
        let (tx, rx) = std::sync::mpsc::channel::<u32>();
        let job: Job<u32> = Box::new(move |s| {
            *s += 1;
            let _ = tx.send(*s);
        });
        let job = match pool.try_exec(0, job) {
            Err(job) => job,
            Ok(()) => panic!("dead rank must bounce the job"),
        };
        assert_eq!(pool.sync_lossy(), 0);

        pool.respawn(0, 20u32);
        assert!(pool.try_exec(0, job).is_ok(), "respawned rank accepts jobs");
        assert_eq!(rx.recv_timeout(std::time::Duration::from_secs(10)), Ok(21));
        assert_eq!(pool.sync_lossy(), 1);
        assert_eq!(pool.join(), vec![21]);
    }
}
