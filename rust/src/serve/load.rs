//! Open-loop load generation (DESIGN.md §7).
//!
//! An *open-loop* driver submits requests on a Poisson arrival schedule
//! that never waits for responses — exactly how real traffic behaves —
//! so queueing delay shows up in the measured latency instead of being
//! absorbed by a closed feedback loop (the coordinated-omission trap).
//! Shared by `benches/serve_load.rs` and the `dilconv serve` demo.

use std::time::{Duration, Instant};

use crate::metrics::LatencyHistogram;
use crate::util::rng::Rng;

use super::batcher::{Server, Ticket};
use super::ServeError;

/// A weighted mix of request widths.
#[derive(Debug, Clone)]
pub struct WidthMix {
    /// `(width, weight)`; weights need not be normalised.
    entries: Vec<(usize, f64)>,
    total: f64,
}

impl WidthMix {
    pub fn new(entries: Vec<(usize, f64)>) -> Result<WidthMix, String> {
        if entries.is_empty() {
            return Err("width mix must name at least one width".into());
        }
        if entries.iter().any(|&(w, p)| w == 0 || p.is_nan() || p <= 0.0) {
            return Err("width-mix entries need positive widths and weights".into());
        }
        let total = entries.iter().map(|&(_, p)| p).sum();
        Ok(WidthMix { entries, total })
    }

    /// Equal-weight mix over `widths`.
    pub fn uniform(widths: &[usize]) -> Result<WidthMix, String> {
        Self::new(widths.iter().map(|&w| (w, 1.0)).collect())
    }

    /// Equal-weight mix derived from a bucket grid: for every bucket, an
    /// exact-fit width plus a partial-fill width that still routes to
    /// that bucket (strictly above the next-smaller bucket, so the
    /// truncation path of *this* bucket is exercised, not a smaller
    /// one's exact fit). Shared by `dilconv serve` and the load bench.
    pub fn bucket_mix(buckets: &super::BucketSet) -> Result<WidthMix, String> {
        let mut widths = Vec::new();
        let mut prev = 0usize;
        for &b in buckets.widths() {
            widths.push(b);
            let partial = (b - b / 5).max(prev + 1);
            if partial < b {
                widths.push(partial);
            }
            prev = b;
        }
        Self::uniform(&widths)
    }

    /// The distinct widths in the mix.
    pub fn widths(&self) -> Vec<usize> {
        self.entries.iter().map(|&(w, _)| w).collect()
    }

    fn sample(&self, rng: &mut Rng) -> usize {
        let mut t = rng.uniform() * self.total;
        for &(w, p) in &self.entries {
            if t < p {
                return w;
            }
            t -= p;
        }
        self.entries.last().expect("mix is non-empty").0
    }
}

/// What one open-loop run observed.
#[derive(Debug, Clone)]
pub struct LoadReport {
    /// Requests the schedule offered.
    pub offered: usize,
    /// Requests that completed with a response.
    pub completed: usize,
    /// Requests refused at admission (backpressure).
    pub rejected: usize,
    /// Requests that failed — rejected at submit for a non-backpressure
    /// reason (e.g. wider than every bucket) or dropped by the server.
    pub failed: usize,
    /// First submit → last response, seconds.
    pub wall_secs: f64,
    /// End-to-end latency of completed requests.
    pub latency: LatencyHistogram,
    /// Sum over responses of the rows that shared their batch / count —
    /// the request-weighted mean batch size.
    pub mean_batch_rows: f64,
}

impl LoadReport {
    /// Completed sequences per wall-clock second — the serving
    /// throughput this run sustained.
    pub fn seq_per_sec(&self) -> f64 {
        self.completed as f64 / self.wall_secs.max(1e-9)
    }
}

/// Drive `server` with `total` requests at `rate_per_sec` (exponential
/// interarrivals, seeded), widths drawn from `mix`, synthetic Poisson
/// coverage tracks as payloads. Blocks until every accepted request has
/// responded.
pub fn run_open_loop(
    server: &Server,
    mix: &WidthMix,
    rate_per_sec: f64,
    total: usize,
    seed: u64,
) -> LoadReport {
    assert!(rate_per_sec > 0.0, "arrival rate must be positive");
    let mut rng = Rng::new(seed);
    let mut tickets: Vec<Ticket> = Vec::with_capacity(total);
    let mut rejected = 0usize;
    let mut failed = 0usize;
    let start = Instant::now();
    let mut next_arrival = 0.0f64; // seconds after start
    for _ in 0..total {
        // Exponential interarrival: Poisson process at the target rate.
        let u = rng.uniform().max(1e-12);
        next_arrival += -u.ln() / rate_per_sec;
        let due = start + Duration::from_secs_f64(next_arrival);
        let now = Instant::now();
        if due > now {
            std::thread::sleep(due - now);
        }
        let w = mix.sample(&mut rng);
        let data: Vec<f32> = (0..w).map(|_| rng.poisson(0.6) as f32).collect();
        match server.submit(data) {
            Ok(t) => tickets.push(t),
            Err(ServeError::QueueFull { .. }) => rejected += 1,
            // Any other submit error (mix wider than the server's
            // buckets, shutdown) is the driver's measurement to report,
            // not a reason to abort with tickets outstanding.
            Err(_) => failed += 1,
        }
    }
    let mut latency = LatencyHistogram::new();
    let mut completed = 0usize;
    let mut batch_rows_sum = 0.0f64;
    for t in tickets {
        match t.wait() {
            Ok(r) => {
                latency.record(r.latency_secs);
                batch_rows_sum += r.batch_rows as f64;
                completed += 1;
            }
            Err(_) => failed += 1,
        }
    }
    LoadReport {
        offered: total,
        completed,
        rejected,
        failed,
        wall_secs: start.elapsed().as_secs_f64(),
        latency,
        mean_batch_rows: batch_rows_sum / completed.max(1) as f64,
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]

    use super::*;

    #[test]
    fn mix_sampling_respects_weights() {
        let mix = WidthMix::new(vec![(100, 3.0), (200, 1.0)]).unwrap();
        let mut rng = Rng::new(7);
        let mut count_100 = 0;
        for _ in 0..1000 {
            if mix.sample(&mut rng) == 100 {
                count_100 += 1;
            }
        }
        // 75% expected; allow generous slack.
        assert!((650..=850).contains(&count_100), "{count_100}");
        assert_eq!(mix.widths(), vec![100, 200]);
    }

    #[test]
    fn mix_rejects_degenerate_specs() {
        assert!(WidthMix::new(vec![]).is_err());
        assert!(WidthMix::new(vec![(0, 1.0)]).is_err());
        assert!(WidthMix::new(vec![(10, 0.0)]).is_err());
        assert!(WidthMix::uniform(&[64, 128]).is_ok());
    }

    #[test]
    fn bucket_mix_partial_widths_stay_in_their_bucket() {
        use crate::serve::BucketSet;
        // Closely spaced grid: the naive b - b/5 partial for 1280 would
        // be 1024 — an exact fit for the smaller bucket, not a partial
        // fill of this one. bucket_mix must keep it strictly above the
        // next-smaller bucket.
        let buckets = BucketSet::new(&[1024, 1280]).unwrap();
        let mix = WidthMix::bucket_mix(&buckets).unwrap();
        // Exact fits for both buckets, and the 1280 partial is clamped
        // to 1025 (smallest width that still routes to 1280) instead of
        // the naive 1024.
        assert_eq!(mix.widths(), vec![1024, 820, 1280, 1025]);
        for w in mix.widths() {
            assert!(buckets.bucket_for(w).is_some(), "{w} must fit a bucket");
        }
        // Wide spacing keeps the 20% partials.
        let wide = BucketSet::new(&[512, 4096]).unwrap();
        let m2 = WidthMix::bucket_mix(&wide).unwrap();
        assert!(m2.widths().contains(&(4096 - 4096 / 5)));
    }
}
