//! Batched inference serving (DESIGN.md §7).
//!
//! The training side of this repo amortizes plans, weight relayouts and
//! autotune probes across *steps*; this module amortizes them across
//! *requests*. The pipeline:
//!
//! ```text
//!  submit(track) ──► admission (bounded in-flight budget, QueueFull)
//!       │
//!       ▼
//!  dispatcher: group by width bucket ──► flush on max_batch | window
//!       │                                     (round-robin ranks)
//!       ▼
//!  worker pool (PersistentPool): each rank owns an InferenceEngine
//!       │          └─ PlanCache: bucket → forward-only AtacWorksNet
//!       │                        (ConvPlan + workspace per layer,
//!       │                         pinned at N = max_batch, W = bucket,
//!       │                         LRU-evicted, warmed at startup)
//!       ▼
//!  Response { denoised, logits, latency } + latency/throughput metrics
//! ```
//!
//! * [`bucket`]  — the width-bucket vocabulary (64-aligned grid)
//! * [`cache`]   — the shape-bucketed LRU plan cache
//! * [`engine`]  — bucket-pinned forward-only execution; the
//!   **bit-identity contract**: a batched row equals the same request
//!   served alone, bit for bit (per-image kernel loops)
//! * [`batcher`] — dynamic batcher, admission control, worker pool,
//!   telemetry
//! * [`stream`]  — halo-overlapped fixed-memory windows: requests wider
//!   than every bucket stream through bucket-sized windows and stitch
//!   bit-identically to whole-sequence evaluation (DESIGN.md §7b)
//! * [`net`]     — the TCP wire: length-prefixed frames, a
//!   zero-allocation pull parser, per-connection state machines,
//!   backpressure on the wire and graceful drain (DESIGN.md §7b)
//! * [`load`]    — open-loop load generation (benches + `dilconv serve`)
//! * `fault`     — deterministic fault injection (chaos tests only;
//!   compiled under `cfg(any(test, feature = "fault"))`, so plain doc
//!   builds do not carry it)
//!
//! Serving is the crate's always-on surface, so the whole module tree
//! denies raw unwraps: a poisoned mutex or a stray `unwrap()` must never
//! take the process down (DESIGN.md §7d). Lock through
//! [`lock_unpoisoned`]; test modules opt back in locally.
#![deny(clippy::unwrap_used)]

pub mod batcher;
pub mod bucket;
pub mod cache;
pub mod engine;
#[cfg(any(test, feature = "fault"))]
pub mod fault;
pub mod load;
pub mod net;
pub mod stream;

pub use batcher::{
    BatcherOpts, BucketMetrics, Response, ServeMetrics, Server, SocketMetrics, Ticket,
};
#[cfg(any(test, feature = "fault"))]
pub use fault::{FaultAction, FaultPlan, FaultSite};
pub use bucket::{round_up_to_block, BucketSet};
pub use cache::PlanCache;
pub use engine::{EngineOpts, InferOutput, InferenceEngine};
pub use load::{run_open_loop, LoadReport, WidthMix};
pub use net::{NetOpts, NetServer, NetStats, WireError, WireEvent, WireParser};
pub use stream::{StreamStats, StreamingSession};

use std::sync::{Mutex, MutexGuard};

use crate::conv1d::PlanError;

/// Lock `m`, recovering the data if a panicking holder poisoned it.
///
/// Serving mutexes guard telemetry counters, connection lists and the
/// server handle — values that stay internally consistent even when a
/// holder panicked mid-update (worst case: one counter increment is
/// lost). Propagating the poison instead would cascade a single worker
/// or handler panic into every thread that later touches the lock,
/// which is exactly what the self-healing contract (DESIGN.md §7d)
/// forbids.
pub(crate) fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(guard) => guard,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Everything that can go wrong between `submit` and a response.
#[derive(Debug, Clone, PartialEq)]
pub enum ServeError {
    /// Request wider than the largest configured bucket and streaming is
    /// disabled (padding *down* would corrupt it; with a
    /// [`BatcherOpts::stream_window`] configured such requests take the
    /// halo-overlapped streaming route instead).
    TooWide { width: usize, largest: usize },
    /// Zero-length request.
    EmptyRequest,
    /// Admission control: the bounded in-flight budget is exhausted —
    /// backpressure, retry later.
    QueueFull { depth: usize },
    /// The server dropped the request while shutting down.
    ShuttingDown,
    /// The request's deadline expired while it was queued; it was shed
    /// before any compute ran (DESIGN.md §7d).
    DeadlineExceeded,
    /// A worker panicked while this request was on it — either mid
    /// forward pass (the replica was rebuilt before the next batch) or
    /// while the request sat in a dead rank's queue. The request itself
    /// is not retried; the caller decides.
    WorkerPanic,
    /// Plan construction failed for a bucket entry.
    Plan(PlanError),
    /// Invalid serving configuration.
    Config(String),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::TooWide { width, largest } => write!(
                f,
                "request width {width} exceeds the largest bucket ({largest})"
            ),
            ServeError::EmptyRequest => write!(f, "empty request"),
            ServeError::QueueFull { depth } => {
                write!(f, "queue full ({depth} requests in flight)")
            }
            ServeError::ShuttingDown => write!(f, "server is shutting down"),
            ServeError::DeadlineExceeded => {
                write!(f, "request deadline exceeded (shed before dispatch)")
            }
            ServeError::WorkerPanic => {
                write!(f, "worker panicked while holding the request")
            }
            ServeError::Plan(e) => write!(f, "{e}"),
            ServeError::Config(msg) => write!(f, "serve config error: {msg}"),
        }
    }
}

impl std::error::Error for ServeError {}

impl From<PlanError> for ServeError {
    fn from(e: PlanError) -> ServeError {
        ServeError::Plan(e)
    }
}
