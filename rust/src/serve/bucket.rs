//! Shape buckets — the serving subsystem's width vocabulary
//! (DESIGN.md §7).
//!
//! Variable-length requests are grouped by rounding their width **up**
//! to a configured bucket, and every bucket width sits on the kernels'
//! 64-wide block grid ([`WIDTH_BLOCK`]), so a bucket's plans always run
//! full-width BRGEMM blocks with no scalar remainder columns. One plan
//! per bucket (not per request width) is what lets the plan cache
//! amortize construction, relayouts and autotune probes across every
//! width that maps into it.

use crate::conv1d::WIDTH_BLOCK;

/// Round a width up to the next multiple of the kernel block width.
pub fn round_up_to_block(w: usize) -> usize {
    w.div_ceil(WIDTH_BLOCK) * WIDTH_BLOCK
}

/// An ordered, deduplicated set of block-aligned width buckets.
///
/// ```
/// use dilconv1d::serve::BucketSet;
///
/// let b = BucketSet::parse("1000, 2048,4096").unwrap();
/// // 1000 is rounded up onto the 64-wide block grid.
/// assert_eq!(b.widths(), &[1024, 2048, 4096]);
/// assert_eq!(b.bucket_for(900), Some(1024));
/// assert_eq!(b.bucket_for(1024), Some(1024));
/// assert_eq!(b.bucket_for(1025), Some(2048));
/// assert_eq!(b.bucket_for(5000), None); // over the largest bucket
/// assert_eq!(b.to_string(), "1024,2048,4096");
/// ```
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BucketSet {
    /// Ascending, unique, multiples of [`WIDTH_BLOCK`].
    widths: Vec<usize>,
}

impl BucketSet {
    /// Build from raw widths: each is rounded up to the block grid, then
    /// the set is sorted and deduplicated. An empty set (or any zero
    /// width) is a configuration error, not a default.
    pub fn new(widths: &[usize]) -> Result<BucketSet, String> {
        if widths.is_empty() {
            return Err("bucket set must name at least one width".to_string());
        }
        if widths.contains(&0) {
            return Err("bucket widths must be positive".to_string());
        }
        let mut w: Vec<usize> = widths.iter().map(|&x| round_up_to_block(x)).collect();
        w.sort_unstable();
        w.dedup();
        Ok(BucketSet { widths: w })
    }

    /// Parse a comma-separated width list (the `[serve] buckets` config
    /// key / `--buckets` flag vocabulary).
    pub fn parse(spec: &str) -> Result<BucketSet, String> {
        let mut widths = Vec::new();
        for tok in spec.split(',') {
            let tok = tok.trim();
            if tok.is_empty() {
                continue;
            }
            widths.push(
                tok.parse::<usize>()
                    .map_err(|_| format!("bad bucket width '{tok}' in '{spec}'"))?,
            );
        }
        Self::new(&widths)
    }

    /// The bucket widths, ascending.
    pub fn widths(&self) -> &[usize] {
        &self.widths
    }

    pub fn len(&self) -> usize {
        self.widths.len()
    }

    pub fn is_empty(&self) -> bool {
        self.widths.is_empty()
    }

    /// Largest width this set can serve.
    pub fn largest(&self) -> usize {
        *self.widths.last().expect("bucket set is never empty")
    }

    /// Smallest bucket that fits a request of width `w`; `None` when `w`
    /// exceeds the largest bucket (the request must be rejected — padding
    /// *down* would corrupt it) or `w` is zero.
    pub fn bucket_for(&self, w: usize) -> Option<usize> {
        if w == 0 {
            return None;
        }
        self.widths.iter().copied().find(|&b| b >= w)
    }
}

impl std::fmt::Display for BucketSet {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for (i, w) in self.widths.iter().enumerate() {
            if i > 0 {
                f.write_str(",")?;
            }
            write!(f, "{w}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]

    use super::*;

    #[test]
    fn rounds_sorts_and_dedups() {
        let b = BucketSet::new(&[4096, 100, 128, 1000]).unwrap();
        assert_eq!(b.widths(), &[128, 1024, 4096]);
        assert_eq!(b.len(), 3);
        assert_eq!(b.largest(), 4096);
        assert!(!b.is_empty());
    }

    #[test]
    fn rejects_empty_and_zero() {
        assert!(BucketSet::new(&[]).is_err());
        assert!(BucketSet::new(&[0, 128]).is_err());
        assert!(BucketSet::parse("").is_err());
        assert!(BucketSet::parse("128,x").is_err());
    }

    #[test]
    fn bucket_lookup_boundaries() {
        let b = BucketSet::parse("128,256").unwrap();
        assert_eq!(b.bucket_for(1), Some(128));
        assert_eq!(b.bucket_for(128), Some(128));
        assert_eq!(b.bucket_for(129), Some(256));
        assert_eq!(b.bucket_for(256), Some(256));
        assert_eq!(b.bucket_for(257), None);
        assert_eq!(b.bucket_for(0), None);
    }

    #[test]
    fn single_bucket_set_serves_only_itself() {
        // The smallest legal vocabulary: one bucket is both the smallest
        // and largest, and everything over it is a streaming/TooWide
        // decision for the layer above.
        let b = BucketSet::new(&[64]).unwrap();
        assert_eq!(b.widths(), &[64]);
        assert_eq!((b.len(), b.largest()), (1, 64));
        assert_eq!(b.bucket_for(1), Some(64));
        assert_eq!(b.bucket_for(64), Some(64));
        assert_eq!(b.bucket_for(65), None);
    }

    #[test]
    fn width_one_and_exact_block_boundaries() {
        let b = BucketSet::parse("64,128,192").unwrap();
        // Width 1 maps to the smallest bucket (63 pad columns are masked
        // out by the engine, never returned).
        assert_eq!(b.bucket_for(1), Some(64));
        // Exactly on a 64-wide block boundary: no spill to the next
        // bucket — the boundary bucket itself fits.
        for (w, want) in [(64, 64), (128, 128), (192, 192)] {
            assert_eq!(b.bucket_for(w), Some(want), "width {w}");
        }
        // One past each boundary spills up (or out, at the top).
        assert_eq!(b.bucket_for(65), Some(128));
        assert_eq!(b.bucket_for(129), Some(192));
        assert_eq!(b.bucket_for(193), None);
    }

    #[test]
    fn display_parse_round_trip() {
        let b = BucketSet::parse("192, 64,1024").unwrap();
        let again = BucketSet::parse(&b.to_string()).unwrap();
        assert_eq!(b, again);
        assert_eq!(b.to_string(), "64,192,1024");
    }

    #[test]
    fn block_rounding() {
        assert_eq!(round_up_to_block(1), 64);
        assert_eq!(round_up_to_block(64), 64);
        assert_eq!(round_up_to_block(65), 128);
    }
}
