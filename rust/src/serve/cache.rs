//! Shape-bucketed LRU plan cache (DESIGN.md §7).
//!
//! One cache entry per width bucket, holding everything a bucket needs
//! to execute — for the serving engine that is a whole forward-only
//! model replica (25 `ConvPlan`s, their workspaces and, when autotuning,
//! their memoized tune entries). Entries are built once (usually warmed
//! at startup), reused on every hit, and evicted strictly in
//! least-recently-used order when the configured capacity is exceeded —
//! a traffic mix wider than the capacity thrashes loudly in the
//! `evictions` counter instead of silently ballooning memory.
//!
//! The cache is deliberately generic over the entry type so the
//! eviction policy is unit-testable without building real plans.

/// An LRU cache keyed by bucket width.
///
/// ```
/// use dilconv1d::serve::PlanCache;
///
/// let mut c: PlanCache<&'static str> = PlanCache::new(2);
/// c.get_or_insert_with(128, || "a");
/// c.get_or_insert_with(256, || "b");
/// c.get_or_insert_with(128, || unreachable!("hit"));
/// c.get_or_insert_with(512, || "c"); // evicts 256 (the LRU entry)
/// assert_eq!(c.evicted(), &[256]);
/// assert_eq!(c.keys_mru(), vec![512, 128]);
/// assert_eq!((c.hits(), c.misses()), (1, 3));
/// ```
#[derive(Debug)]
pub struct PlanCache<V> {
    capacity: usize,
    /// MRU-first: index 0 is the most recently used entry.
    entries: Vec<(usize, V)>,
    evicted: Vec<usize>,
    hits: u64,
    misses: u64,
}

impl<V> PlanCache<V> {
    /// A cache holding at most `capacity` entries (`capacity >= 1`).
    pub fn new(capacity: usize) -> PlanCache<V> {
        assert!(capacity >= 1, "plan cache capacity must be at least 1");
        PlanCache {
            capacity,
            entries: Vec::new(),
            evicted: Vec::new(),
            hits: 0,
            misses: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Cache hits so far.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Cache misses (= entry builds) so far.
    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Keys evicted so far, oldest eviction first.
    pub fn evicted(&self) -> &[usize] {
        &self.evicted
    }

    /// Keys from most- to least-recently used.
    pub fn keys_mru(&self) -> Vec<usize> {
        self.entries.iter().map(|(k, _)| *k).collect()
    }

    /// Whether `key` is resident (does not touch recency).
    pub fn contains(&self, key: usize) -> bool {
        self.entries.iter().any(|(k, _)| *k == key)
    }

    /// Fetch `key`'s entry, building it with `build` on a miss. Both
    /// paths move the entry to the front (most recently used); a miss
    /// that overflows the capacity evicts the least-recently-used entry.
    pub fn get_or_insert_with(&mut self, key: usize, build: impl FnOnce() -> V) -> &mut V {
        if let Some(i) = self.entries.iter().position(|(k, _)| *k == key) {
            self.hits += 1;
            let e = self.entries.remove(i);
            self.entries.insert(0, e);
        } else {
            self.misses += 1;
            self.entries.insert(0, (key, build()));
            if self.entries.len() > self.capacity {
                let (k, _) = self.entries.pop().expect("overflowing cache is non-empty");
                self.evicted.push(k);
            }
        }
        &mut self.entries[0].1
    }

    /// Fallible twin of [`Self::get_or_insert_with`]: a build error
    /// leaves the cache unchanged (no half-inserted entry, no eviction).
    pub fn try_get_or_insert_with<E>(
        &mut self,
        key: usize,
        build: impl FnOnce() -> Result<V, E>,
    ) -> Result<&mut V, E> {
        if !self.contains(key) {
            let v = build()?;
            self.misses += 1;
            self.entries.insert(0, (key, v));
            if self.entries.len() > self.capacity {
                let (k, _) = self.entries.pop().expect("overflowing cache is non-empty");
                self.evicted.push(k);
            }
            return Ok(&mut self.entries[0].1);
        }
        Ok(self.get_or_insert_with(key, || unreachable!("entry is resident")))
    }

    /// Iterate resident `(key, entry)` pairs, MRU first (read-only; does
    /// not touch recency).
    pub fn iter(&self) -> impl Iterator<Item = (usize, &V)> {
        self.entries.iter().map(|(k, v)| (*k, v))
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]

    use super::*;

    #[test]
    fn evicts_in_lru_order() {
        let mut c: PlanCache<u32> = PlanCache::new(2);
        c.get_or_insert_with(64, || 1);
        c.get_or_insert_with(128, || 2);
        // Touch 64 so 128 becomes the LRU entry.
        assert_eq!(*c.get_or_insert_with(64, || unreachable!()), 1);
        c.get_or_insert_with(256, || 3);
        assert_eq!(c.evicted(), &[128], "LRU entry must go first");
        c.get_or_insert_with(512, || 4);
        // 64 was older than 256 at this point.
        assert_eq!(c.evicted(), &[128, 64]);
        assert_eq!(c.keys_mru(), vec![512, 256]);
        assert_eq!(c.len(), 2);
        assert_eq!(c.capacity(), 2);
    }

    #[test]
    fn rebuild_after_eviction_is_a_miss() {
        let mut c: PlanCache<u32> = PlanCache::new(1);
        let mut builds = 0;
        for _ in 0..2 {
            c.get_or_insert_with(64, || {
                builds += 1;
                7
            });
        }
        assert_eq!(builds, 1, "second access is a hit");
        c.get_or_insert_with(128, || 8); // evicts 64
        c.get_or_insert_with(64, || {
            builds += 1;
            7
        });
        assert_eq!(builds, 2, "evicted entry rebuilds");
        assert_eq!(c.hits(), 1);
        assert_eq!(c.misses(), 3);
        assert_eq!(c.evicted(), &[64, 128]);
    }

    #[test]
    fn failed_build_leaves_cache_unchanged() {
        let mut c: PlanCache<u32> = PlanCache::new(1);
        c.get_or_insert_with(64, || 1);
        let r: Result<&mut u32, &'static str> = c.try_get_or_insert_with(128, || Err("boom"));
        assert!(r.is_err());
        assert_eq!(c.keys_mru(), vec![64], "no eviction on failed build");
        assert!(c.evicted().is_empty());
        // Successful fallible build works and evicts normally.
        let v = c
            .try_get_or_insert_with::<&'static str>(128, || Ok(2))
            .unwrap();
        assert_eq!(*v, 2);
        assert_eq!(c.evicted(), &[64]);
    }

    #[test]
    #[should_panic(expected = "capacity must be at least 1")]
    fn zero_capacity_is_rejected() {
        let _ = PlanCache::<u32>::new(0);
    }
}
