//! Halo-overlapped streaming inference — fixed-memory windows over
//! arbitrarily long signals (DESIGN.md §7b).
//!
//! The bucket grid caps request width at the largest configured bucket;
//! genomics tracks are arbitrarily long. A [`StreamingSession`] closes
//! that gap: it slides a fixed-width window (on the kernels' 64-wide
//! block grid) along the signal, runs each window through the existing
//! per-bucket [`InferenceEngine`], and emits only the columns whose
//! receptive field lies entirely inside the window. Consecutive windows
//! overlap by the net's one-sided receptive-field reach
//! ([`NetConfig::receptive_field_reach`]), so every emitted column saw
//! exactly the input a whole-sequence evaluation would have shown it —
//! the stitched output is **bit-identical** (u32-exact) to evaluating
//! the full signal in one `infer_masked` pass, at O(window) activation
//! memory regardless of sequence length.
//!
//! ## Why the stitch is exact, not approximate
//!
//! Output column `j` of the net depends on input columns
//! `[j - R, j + R]` only, where `R` is the receptive-field reach (each
//! same-padded conv widens the cone by `ceil((S-1)/2)·d` per side, and
//! the deepest input→head path is `2·n_blocks + 2` convs). The session
//! emits a window column only when it is ≥ `R` columns away from every
//! *artificial* window edge; the true signal boundaries need no margin
//! because both the window and the whole-sequence evaluation see the
//! same same-padding zeros there. Per-element FMA order inside the
//! kernels is width-independent, and `infer_masked` makes the bucket an
//! execution shape only — so equality holds bit for bit, and the
//! serving tests assert it with `assert_eq!` on `f32::to_bits`.
//!
//! [`NetConfig`]: crate::model::NetConfig
//! [`NetConfig::receptive_field_reach`]: crate::model::NetConfig::receptive_field_reach

use super::bucket::round_up_to_block;
use super::engine::{InferOutput, InferenceEngine};
use super::ServeError;

/// Progress counters of one streamed signal.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StreamStats {
    /// Windows executed through the engine.
    pub windows: usize,
    /// Output columns emitted (= the signal length).
    pub emitted: usize,
}

/// A fixed-memory streaming evaluator borrowing a bucket-pinned engine.
///
/// Construction validates the window geometry once; [`Self::infer_with`]
/// then streams any number of signals through the same session. The
/// session holds no per-signal state — memory is bounded by the
/// engine's bucket staging plus one window's outputs.
pub struct StreamingSession<'e> {
    engine: &'e mut InferenceEngine,
    window: usize,
    halo: usize,
}

impl<'e> StreamingSession<'e> {
    /// Borrow `engine` for streaming with the given window width. The
    /// window is rounded up to the 64-wide block grid, must fit the
    /// engine's largest bucket, and is then **snapped to the bucket
    /// that will actually serve it** (`bucket_for(window)`): a window
    /// strictly between two buckets would otherwise execute zero-padded
    /// inside the larger bucket on every step — with buckets
    /// `[1024, 4096]` and a requested window of 2048, each window would
    /// silently pay for 4096 columns of compute. After the snap the
    /// window must still exceed **twice** the receptive-field reach,
    /// otherwise no window column is far enough from both artificial
    /// edges and the stitch cannot advance.
    pub fn new(
        engine: &'e mut InferenceEngine,
        window: usize,
    ) -> Result<StreamingSession<'e>, ServeError> {
        if window == 0 {
            return Err(ServeError::Config(
                "stream window must be positive".into(),
            ));
        }
        let window = round_up_to_block(window);
        let largest = engine.opts().buckets.largest();
        if window > largest {
            return Err(ServeError::Config(format!(
                "stream window {window} exceeds the largest bucket ({largest})"
            )));
        }
        let window = engine
            .opts()
            .buckets
            .bucket_for(window)
            .expect("window fits the largest bucket");
        let halo = engine.net_config().receptive_field_reach();
        if window <= 2 * halo {
            return Err(ServeError::Config(format!(
                "stream window {window} must exceed twice the receptive-field \
                 reach (2 x {halo}) so interior columns exist to emit"
            )));
        }
        Ok(StreamingSession {
            engine,
            window,
            halo,
        })
    }

    /// The window width windows execute at — always one of the engine's
    /// configured bucket widths (`bucket_for(window()) == window()`).
    pub fn window(&self) -> usize {
        self.window
    }

    /// The one-sided receptive-field reach windows overlap by.
    pub fn halo(&self) -> usize {
        self.halo
    }

    /// Columns each interior window contributes (`window - 2·halo`) —
    /// the stride the stitch advances by in steady state.
    pub fn core(&self) -> usize {
        self.window - 2 * self.halo
    }

    /// Stream `signal` through halo-overlapped windows, handing each
    /// emitted span to `sink(start_col, denoised, logits)`. Spans are
    /// contiguous, in order, and cover `0..signal.len()` exactly once;
    /// concatenated they are bit-identical to whole-sequence
    /// evaluation. Signals no longer than one window pass through as a
    /// single full-width span.
    pub fn infer_with(
        &mut self,
        signal: &[f32],
        mut sink: impl FnMut(usize, &[f32], &[f32]),
    ) -> Result<StreamStats, ServeError> {
        if signal.is_empty() {
            return Err(ServeError::EmptyRequest);
        }
        let len = signal.len();
        let mut emit_from = 0usize; // first column not yet emitted
        let mut win_start = 0usize;
        let mut windows = 0usize;
        loop {
            let win_end = (win_start + self.window).min(len);
            // Every window — including the short final one — executes
            // pinned to the session bucket. Routing the tail through
            // `bucket_for(win_w)` could land it in a *smaller* bucket:
            // a mid-stream plan build, and at `cache_capacity = 1` an
            // eviction of the streaming bucket itself on every signal.
            // Bit-identity is bucket-invariant, so pinning only changes
            // which plan runs, never the emitted bits.
            let out = self
                .engine
                .infer_one_pinned(&signal[win_start..win_end], self.window)?;
            windows += 1;
            // Columns valid in this window: everything ≥ halo from an
            // *artificial* edge. The left margin is already enforced by
            // where `emit_from` sits (window 0 starts at the true
            // boundary; later windows start halo columns before
            // `emit_from`); on the right, hold back a halo unless this
            // window reaches the true end of the signal.
            let win_w = win_end - win_start;
            let right_valid = if win_end == len {
                win_w
            } else {
                win_w - self.halo
            };
            let lo = emit_from - win_start;
            sink(
                emit_from,
                &out.denoised[lo..right_valid],
                &out.logits[lo..right_valid],
            );
            emit_from = win_start + right_valid;
            if win_end == len {
                break;
            }
            // Overlap: the next window re-computes a halo's worth of
            // context left of the first unemitted column. Since
            // window > 2·halo, this always advances (`win_start` grows
            // by `core()` each interior step).
            win_start = emit_from - self.halo;
        }
        Ok(StreamStats {
            windows,
            emitted: len,
        })
    }

    /// Convenience: stream `signal` and collect the stitched heads into
    /// one [`InferOutput`] (lengths = the signal length). Peak memory is
    /// the output itself plus one window of activations.
    pub fn infer(&mut self, signal: &[f32]) -> Result<InferOutput, ServeError> {
        let mut denoised = Vec::with_capacity(signal.len());
        let mut logits = Vec::with_capacity(signal.len());
        self.infer_with(signal, |_, d, l| {
            denoised.extend_from_slice(d);
            logits.extend_from_slice(l);
        })?;
        Ok(InferOutput { denoised, logits })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{AtacWorksNet, NetConfig};
    use crate::serve::{BucketSet, EngineOpts};
    use crate::util::rng::Rng;

    fn engine(buckets: &[usize]) -> InferenceEngine {
        let cfg = NetConfig::tiny(); // halo 32
        let params = AtacWorksNet::init(cfg, 9).pack_params();
        let opts = EngineOpts {
            buckets: BucketSet::new(buckets).expect("widths"),
            max_batch: 1,
            cache_capacity: buckets.len(),
            ..EngineOpts::default()
        };
        InferenceEngine::new(cfg, &params, opts).expect("engine")
    }

    fn track(w: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..w).map(|_| rng.poisson(0.7) as f32).collect()
    }

    #[test]
    fn window_geometry_is_validated() {
        let mut e = engine(&[128, 256]);
        // Rounded onto the block grid, snapped to its serving bucket,
        // halo derived from the config.
        let s = StreamingSession::new(&mut e, 100).expect("window 100 -> 128");
        assert_eq!((s.window(), s.halo(), s.core()), (128, 32, 64));
        // A sub-bucket window snaps *up* to the smallest bucket that
        // serves it — 64 would pass the halo check on its own, but its
        // windows would execute inside the 128 bucket anyway.
        let s = StreamingSession::new(&mut e, 64).expect("window 64 -> 128");
        assert_eq!(s.window(), 128);
        // Zero and over-bucket windows fail.
        assert!(matches!(
            StreamingSession::new(&mut e, 0),
            Err(ServeError::Config(_))
        ));
        assert!(matches!(
            StreamingSession::new(&mut e, 512),
            Err(ServeError::Config(_))
        ));
        // Too small for the halo: with a 64-wide bucket the snapped
        // window is 64 <= 2*32 — no interior columns to emit.
        let mut tiny = engine(&[64]);
        assert!(matches!(
            StreamingSession::new(&mut tiny, 64),
            Err(ServeError::Config(_))
        ));
    }

    #[test]
    fn session_window_snaps_to_its_serving_bucket() {
        let mut e = engine(&[128, 512]);
        // 200 rounds to 256 on the block grid; without the snap every
        // window would execute zero-padded inside the 512 bucket while
        // the session believed its window was 256 (~2x wasted compute
        // per window). The invariant: the window IS a bucket width.
        let s = StreamingSession::new(&mut e, 200).expect("session");
        assert_eq!(s.window(), 512);
        let w = s.window();
        drop(s);
        assert_eq!(e.bucket_for(w).expect("bucket"), w);
    }

    #[test]
    fn streaming_never_leaves_the_session_bucket() {
        // Tight cache: capacity 1 with two buckets. Every window —
        // including the short tail — must execute in the session
        // bucket. Before the tail was pinned, the final 124-wide window
        // routed to the 128 bucket: a mid-stream plan build that
        // evicted the streaming bucket itself on every signal.
        let cfg = NetConfig::tiny();
        let params = AtacWorksNet::init(cfg, 9).pack_params();
        let opts = EngineOpts {
            buckets: BucketSet::new(&[128, 256]).expect("widths"),
            max_batch: 1,
            cache_capacity: 1,
            ..EngineOpts::default()
        };
        let mut e = InferenceEngine::new(cfg, &params, opts).expect("engine");
        e.warm().expect("warm");
        let (_, misses_after_warm) = e.cache_stats();
        let signal = track(700, 7); // final window: 700 - 576 = 124 < 256
        let mut s = StreamingSession::new(&mut e, 256).expect("session");
        let got = s.infer(&signal).expect("stream");
        drop(s);
        assert!(e.cache_evictions().is_empty(), "no build/evict thrash");
        assert_eq!(
            e.cache_stats().1,
            misses_after_warm,
            "no post-warm plan builds"
        );
        assert_eq!(e.cache_len(), 1);
        // Pinning changes which plan runs, never the bits: a
        // single-bucket engine streaming the same signal agrees exactly.
        let mut ref_e = engine(&[256]);
        let mut ref_s = StreamingSession::new(&mut ref_e, 256).expect("ref session");
        assert_eq!(ref_s.infer(&signal).expect("ref stream"), got);
    }

    #[test]
    fn short_signals_pass_through_as_one_window() {
        let mut e = engine(&[128, 256]);
        let (short, exact) = (track(90, 1), track(128, 2));
        let want_short = e.infer_one(&short).expect("reference");
        let want_exact = e.infer_one(&exact).expect("reference");
        let mut s = StreamingSession::new(&mut e, 128).expect("session");
        assert_eq!(s.infer(&short).expect("stream"), want_short);
        assert_eq!(s.infer(&exact).expect("stream"), want_exact);
        assert!(matches!(s.infer(&[]), Err(ServeError::EmptyRequest)));
    }

    #[test]
    fn emitted_spans_are_contiguous_and_windows_overlap_by_the_halo() {
        let mut e = engine(&[128]);
        let signal = track(500, 3);
        let mut s = StreamingSession::new(&mut e, 128).expect("session");
        let mut next = 0usize;
        let mut spans = Vec::new();
        let stats = s
            .infer_with(&signal, |start, d, l| {
                assert_eq!(start, next, "spans must be contiguous");
                assert_eq!(d.len(), l.len());
                next += d.len();
                spans.push(d.len());
            })
            .expect("stream");
        assert_eq!(next, signal.len());
        assert_eq!(stats.emitted, signal.len());
        assert_eq!(stats.windows, spans.len());
        // First window keeps its true left boundary (128 - 32 = 96
        // columns); interior windows emit one core (64) each.
        assert_eq!(spans[0], 96);
        for &w in &spans[1..spans.len() - 1] {
            assert_eq!(w, 64);
        }
    }
}
