//! The bucket-pinned inference engine (DESIGN.md §7).
//!
//! An [`InferenceEngine`] owns the model parameters (as the
//! precision-appropriate working copy) and a [`PlanCache`] of
//! **bucket entries**: one forward-only [`AtacWorksNet`] replica per
//! width bucket, its plans pinned at `(N = max_batch, W = bucket)` and
//! built with [`crate::conv1d::ConvPlan::with_inference`] (no backward
//! scratch). A batch of requests is zero-padded into the bucket's
//! persistent staging tensor and executed in one fused forward pass.
//!
//! ## The bit-identity contract
//!
//! Two properties compose:
//!
//! * **Batch invariance.** Every conv kernel computes each output
//!   element as the same fused-multiply-add reduction over
//!   `(tap, channel)` in the same order, **per image** — images never
//!   mix (batch partitioning shards whole images; grid partitioning
//!   shards `(image, width-block)` cells). A request row in a batch of
//!   `max_batch` is bit-identical to the same request through a
//!   `max_batch = 1` engine.
//! * **Bucket invariance.** Execution goes through
//!   [`AtacWorksNet::infer_masked`]: each row's zero-pad tail is
//!   re-zeroed after every layer, so the tail always holds exactly the
//!   zeros same-padding at the row's native width would supply, and the
//!   per-element FMA order is width-independent. A served request is
//!   therefore bit-identical to evaluating it at its **native width** —
//!   which bucket (if any) it landed in can never change the answer.
//!
//! Batching and bucketing are pure throughput transforms, never
//! numerics ones. `tests/integration_serve.rs` locks both across
//! buckets × precisions × partitions.

use crate::conv1d::{Backend, Partition};
use crate::machine::Precision;
use crate::model::{AtacWorksNet, MasterWeights, NetConfig, Tensor};

use super::bucket::BucketSet;
use super::cache::PlanCache;
use super::ServeError;

/// Execution options of one engine (a worker's slice of the
/// `[serve]` config).
#[derive(Debug, Clone)]
pub struct EngineOpts {
    /// Width buckets this engine serves.
    pub buckets: BucketSet,
    /// Batch capacity every bucket's plans are pinned at. Underfilled
    /// batches zero-pad up to it (wasted rows are the price of plan
    /// stability; the batching window exists to keep batches full).
    pub max_batch: usize,
    /// Kernel-level threads per forward pass.
    pub threads: usize,
    /// Forward precision (bf16 = bf16-rounded weights + bf16 kernels).
    pub precision: Precision,
    /// Work partitioning (`Grid` keeps every thread busy even when a
    /// batch window closes with a single request).
    pub partition: Partition,
    /// Kernel backend (ignored when `autotune` is set).
    pub backend: Backend,
    /// Choose each layer's kernel per bucket via the autotuner.
    pub autotune: bool,
    /// Maximum resident bucket entries (LRU beyond this).
    pub cache_capacity: usize,
    /// Conv→conv fusion inside each bucket's net-level plan
    /// ([`crate::model::NetPlan`]). Off, the plan still runs per-layer
    /// kernels out of the shared liveness arena; either way the output
    /// bits are identical.
    pub fuse: bool,
}

impl Default for EngineOpts {
    fn default() -> Self {
        EngineOpts {
            buckets: BucketSet::new(&[1024, 2048, 4096]).expect("static widths"),
            max_batch: 8,
            threads: 1,
            precision: Precision::F32,
            partition: Partition::Batch,
            backend: Backend::Brgemm,
            autotune: false,
            cache_capacity: 8,
            fuse: true,
        }
    }
}

/// Builder-style setters so call sites (and [`crate::config::ServeConfig`])
/// state only what differs from [`Default`].
impl EngineOpts {
    /// Replace the width-bucket vocabulary.
    pub fn with_buckets(mut self, buckets: BucketSet) -> Self {
        self.buckets = buckets;
        self
    }

    /// Pin every bucket's plans at this batch capacity.
    pub fn with_max_batch(mut self, max_batch: usize) -> Self {
        self.max_batch = max_batch;
        self
    }

    /// Kernel-level threads per forward pass.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Forward precision.
    pub fn with_precision(mut self, precision: Precision) -> Self {
        self.precision = precision;
        self
    }

    /// Work partitioning.
    pub fn with_partition(mut self, partition: Partition) -> Self {
        self.partition = partition;
        self
    }

    /// Kernel backend (ignored when autotune is set).
    pub fn with_backend(mut self, backend: Backend) -> Self {
        self.backend = backend;
        self
    }

    /// Per-bucket autotuned kernel selection.
    pub fn with_autotune(mut self, autotune: bool) -> Self {
        self.autotune = autotune;
        self
    }

    /// Maximum resident bucket entries.
    pub fn with_cache_capacity(mut self, cache_capacity: usize) -> Self {
        self.cache_capacity = cache_capacity;
        self
    }

    /// Conv→conv fusion inside each bucket's net plan.
    pub fn with_fuse(mut self, fuse: bool) -> Self {
        self.fuse = fuse;
        self
    }
}

/// One cache entry: a forward-only replica pinned to a bucket (its
/// net-level plan owns the single activation arena), plus the
/// persistent per-chunk buffers — input staging `(max_batch, 1,
/// bucket)`, the row-width vector, and both head outputs. Everything a
/// chunk touches lives here, so the serving steady state allocates
/// nothing beyond the returned [`InferOutput`]s
/// (`tests/serve_alloc.rs`).
struct BucketEntry {
    net: AtacWorksNet,
    x: Tensor,
    widths: Vec<usize>,
    den: Tensor,
    logits: Tensor,
}

/// Output of one request: the two head tensors truncated back to the
/// request's own width.
#[derive(Debug, Clone, PartialEq)]
pub struct InferOutput {
    /// Denoised coverage track (regression head), length = request width.
    pub denoised: Vec<f32>,
    /// Peak-call logits (classification head), length = request width.
    pub logits: Vec<f32>,
}

/// A bucket-pinned, plan-cached, forward-only model executor.
pub struct InferenceEngine {
    net_cfg: NetConfig,
    /// Working-copy parameters (bf16-rounded under bf16 serving).
    working: Vec<f32>,
    opts: EngineOpts,
    cache: PlanCache<BucketEntry>,
    /// Buckets [`Self::warm`] declined to build because they could never
    /// stay resident under `cache_capacity`.
    warm_skipped: usize,
    /// Reusable request-index scratch for [`Self::infer_batch`] grouping
    /// (no per-call BTreeMap/Vec churn on the steady-state path).
    group_scratch: Vec<usize>,
    /// Per-layer activation quantization scales for i8 serving,
    /// calibrated **once** at engine construction (`None` under
    /// f32/bf16). Static by design: were scales per-batch or
    /// per-bucket, the same request would quantize differently
    /// depending on its neighbours and the bit-identity contract
    /// above would break.
    calib_scales: Option<Vec<f32>>,
    /// Fault-injection plan + the rank identity keying its counters
    /// (chaos tests only; see [`super::fault`]).
    #[cfg(any(test, feature = "fault"))]
    fault: Option<(std::sync::Arc<super::fault::FaultPlan>, usize)>,
}

/// One-time activation calibration for i8 serving: run a deterministic
/// synthetic warm-up batch (fixed seed, fixed shape — independent of
/// the engine's buckets) through a temporary **f32** net and record
/// each conv layer's input absmax scale
/// ([`AtacWorksNet::calibrate_input_scales`]).
fn calibrate_scales(net_cfg: NetConfig, params: &[f32]) -> Vec<f32> {
    let mut net = AtacWorksNet::zeros(net_cfg);
    net.unpack_params(params);
    net.set_netplan(false);
    let (n, w) = (2usize, 256usize);
    let mut rng = crate::util::rng::Rng::new(0xCA11B);
    let data: Vec<f32> = (0..n * w).map(|_| rng.poisson(1.0) as f32).collect();
    net.calibrate_input_scales(&Tensor::from_vec(data, n, 1, w))
}

/// Build one bucket entry: replica + pinned, warmed, forward-only plans.
/// The replica starts from [`AtacWorksNet::zeros`] — `unpack_params`
/// overwrites every value, so the He-init RNG fill `init` would pay is
/// skipped. Under i8 serving the engine's one-time calibration scales
/// are applied to every replica, so all buckets quantize identically.
fn build_entry(
    net_cfg: NetConfig,
    working: &[f32],
    opts: &EngineOpts,
    calib: Option<&[f32]>,
    bucket: usize,
) -> Result<BucketEntry, ServeError> {
    let mut net = AtacWorksNet::zeros(net_cfg);
    net.unpack_params(working);
    net.set_backend(opts.backend, opts.threads);
    net.set_partition(opts.partition);
    net.set_precision(opts.precision);
    if let Some(scales) = calib {
        net.set_input_scales(scales);
    }
    net.set_autotune(opts.autotune);
    net.set_inference(true);
    net.set_fuse(opts.fuse);
    net.warm(opts.max_batch, bucket).map_err(ServeError::Plan)?;
    Ok(BucketEntry {
        net,
        x: Tensor::zeros(opts.max_batch, 1, bucket),
        widths: vec![0; opts.max_batch],
        den: Tensor::zeros(opts.max_batch, 1, bucket),
        logits: Tensor::zeros(opts.max_batch, 1, bucket),
    })
}

impl InferenceEngine {
    /// Build an engine over `params` (the flat packing of
    /// [`AtacWorksNet::pack_params`], e.g. a training checkpoint). The
    /// stored copy is the precision's working copy
    /// ([`MasterWeights::working_copy`]), mirroring what training
    /// replicas compute with.
    pub fn new(
        net_cfg: NetConfig,
        params: &[f32],
        opts: EngineOpts,
    ) -> Result<InferenceEngine, ServeError> {
        if params.len() != net_cfg.param_count() {
            return Err(ServeError::Config(format!(
                "parameter vector has {} values, the model needs {}",
                params.len(),
                net_cfg.param_count()
            )));
        }
        if opts.max_batch == 0 {
            return Err(ServeError::Config("max_batch must be at least 1".into()));
        }
        if opts.cache_capacity == 0 {
            return Err(ServeError::Config(
                "plan cache capacity must be at least 1".into(),
            ));
        }
        // i8 serving calibrates activation scales once, here, on the f32
        // parameters — every bucket replica then shares the same static
        // quantization (see `calib_scales`).
        let calib_scales = (opts.precision == Precision::I8)
            .then(|| calibrate_scales(net_cfg, params));
        Ok(InferenceEngine {
            net_cfg,
            working: MasterWeights::working_copy(params, opts.precision),
            cache: PlanCache::new(opts.cache_capacity),
            opts,
            warm_skipped: 0,
            group_scratch: Vec::new(),
            calib_scales,
            #[cfg(any(test, feature = "fault"))]
            fault: None,
        })
    }

    /// Attach a deterministic fault-injection plan (chaos tests only).
    /// `rank` keys this engine's injection-point counters; a rebuilt
    /// replica re-attaches the same plan, so counters continue across
    /// the rebuild.
    #[cfg(any(test, feature = "fault"))]
    pub fn set_fault(&mut self, plan: std::sync::Arc<super::fault::FaultPlan>, rank: usize) {
        self.fault = Some((plan, rank));
    }

    /// The engine's options (what the plans are pinned to).
    pub fn opts(&self) -> &EngineOpts {
        &self.opts
    }

    /// The model geometry this engine executes (the streaming layer
    /// derives its halo from it).
    pub fn net_config(&self) -> NetConfig {
        self.net_cfg
    }

    /// Warm the plan cache: build an entry for every bucket that can
    /// stay resident. When `cache_capacity < buckets.len()` only the
    /// **largest `cache_capacity` buckets** (the MRU-surviving suffix)
    /// are built, ascending — constructing the smaller ones would pay
    /// full plan builds for entries evicted before any request arrives,
    /// and would pollute [`Self::cache_evictions`] with phantom churn.
    /// The number of buckets skipped is reported by
    /// [`Self::warm_skipped`]; they build lazily on first use like any
    /// cold bucket.
    pub fn warm(&mut self) -> Result<(), ServeError> {
        let n = self.opts.buckets.widths().len();
        let skip = n.saturating_sub(self.opts.cache_capacity);
        self.warm_skipped = skip;
        for bi in skip..n {
            let b = self.opts.buckets.widths()[bi];
            let (cfg, working, opts) = (self.net_cfg, &self.working, &self.opts);
            let calib = self.calib_scales.as_deref();
            self.cache
                .try_get_or_insert_with(b, || build_entry(cfg, working, opts, calib, b))?;
        }
        Ok(())
    }

    /// Buckets the last [`Self::warm`] call skipped because they could
    /// not stay resident under `cache_capacity`.
    pub fn warm_skipped(&self) -> usize {
        self.warm_skipped
    }

    /// Resident bucket entries.
    pub fn cache_len(&self) -> usize {
        self.cache.len()
    }

    /// `(hits, misses)` of the plan cache so far.
    pub fn cache_stats(&self) -> (u64, u64) {
        (self.cache.hits(), self.cache.misses())
    }

    /// Buckets evicted so far, oldest first.
    pub fn cache_evictions(&self) -> &[usize] {
        self.cache.evicted()
    }

    /// Total conv-plan workspace bytes resident across cached buckets.
    pub fn plan_workspace_bytes(&self) -> usize {
        self.cache
            .iter()
            .map(|(_, e)| e.net.plan_workspace_bytes())
            .sum()
    }

    /// Smallest bucket serving a request of width `w` (`Err` when the
    /// request exceeds the largest configured bucket).
    pub fn bucket_for(&self, w: usize) -> Result<usize, ServeError> {
        if w == 0 {
            return Err(ServeError::EmptyRequest);
        }
        self.opts
            .buckets
            .bucket_for(w)
            .ok_or_else(|| ServeError::TooWide {
                width: w,
                largest: self.opts.buckets.largest(),
            })
    }

    /// Run a set of requests (each a raw coverage track; its length is
    /// its width). Requests are grouped by bucket, each group executes
    /// in chunks of `max_batch` through the bucket's cached plans, and
    /// outputs come back in request order, truncated to each request's
    /// width. Every row is bit-identical to the same request served
    /// alone (see the module docs).
    pub fn infer_batch(&mut self, reqs: &[&[f32]]) -> Result<Vec<InferOutput>, ServeError> {
        // Validate everything up front: one bad request fails the call
        // before any compute runs.
        for r in reqs {
            self.bucket_for(r.len())?;
        }
        let mut out: Vec<Option<InferOutput>> = reqs.iter().map(|_| None).collect();
        // Group by bucket (ascending) without building per-call maps:
        // one pass over the requests per configured bucket, indices
        // collected into the engine's reusable scratch.
        let mut scratch = std::mem::take(&mut self.group_scratch);
        let mut result = Ok(());
        let n_buckets = self.opts.buckets.widths().len();
        'buckets: for bi in 0..n_buckets {
            let bucket = self.opts.buckets.widths()[bi];
            scratch.clear();
            for (i, r) in reqs.iter().enumerate() {
                if self.opts.buckets.bucket_for(r.len()) == Some(bucket) {
                    scratch.push(i);
                }
            }
            for chunk in scratch.chunks(self.opts.max_batch) {
                if let Err(e) = self.run_chunk(bucket, chunk, reqs, &mut out) {
                    result = Err(e);
                    break 'buckets;
                }
            }
        }
        self.group_scratch = scratch;
        result?;
        Ok(out
            .into_iter()
            .map(|o| o.expect("every request was grouped"))
            .collect())
    }

    /// Single-request convenience (the "one-at-a-time" serving mode when
    /// `max_batch = 1`; also the sequential reference in tests).
    pub fn infer_one(&mut self, req: &[f32]) -> Result<InferOutput, ServeError> {
        Ok(self
            .infer_batch(&[req])?
            .pop()
            .expect("one request, one output"))
    }

    /// Single request through a **caller-chosen** bucket instead of
    /// `bucket_for(req.len())`. Bucket invariance makes the bits
    /// identical either way; what changes is *which plan executes* —
    /// [`crate::serve::StreamingSession`] pins every window of a stream
    /// (including the short tail) to the session bucket so a whole
    /// stream touches exactly one cache entry. `bucket` must be one of
    /// the configured bucket widths and at least as wide as the request.
    pub fn infer_one_pinned(
        &mut self,
        req: &[f32],
        bucket: usize,
    ) -> Result<InferOutput, ServeError> {
        if req.is_empty() {
            return Err(ServeError::EmptyRequest);
        }
        if !self.opts.buckets.widths().contains(&bucket) {
            return Err(ServeError::Config(format!(
                "pinned bucket {bucket} is not a configured bucket width"
            )));
        }
        if req.len() > bucket {
            return Err(ServeError::Config(format!(
                "request of width {} cannot be pinned to bucket {bucket}",
                req.len()
            )));
        }
        let mut out = [None];
        self.run_chunk(bucket, &[0], &[req], &mut out)?;
        Ok(out[0].take().expect("one request, one output"))
    }

    fn run_chunk(
        &mut self,
        bucket: usize,
        chunk: &[usize],
        reqs: &[&[f32]],
        out: &mut [Option<InferOutput>],
    ) -> Result<(), ServeError> {
        debug_assert!(chunk.len() <= self.opts.max_batch);
        // Injection point `EngineForward`: one visit per chunk, before
        // any state is touched, so a `Panic` leaves the previous entry
        // intact (the worker rebuilds the replica regardless — its state
        // is untrusted after an unwind) and an `Error` runs no compute.
        #[cfg(any(test, feature = "fault"))]
        if let Some((plan, rank)) = &self.fault {
            use super::fault::{FaultAction, FaultSite};
            match plan.check(FaultSite::EngineForward, *rank) {
                Some(FaultAction::Panic) => {
                    panic!("fault-injected engine panic (rank {rank}, bucket {bucket})")
                }
                Some(FaultAction::Delay(d)) => std::thread::sleep(d),
                Some(FaultAction::Error) => {
                    return Err(ServeError::Plan(crate::conv1d::PlanError(
                        "fault-injected engine error".into(),
                    )));
                }
                Some(FaultAction::DropConn) | None => {}
            }
        }
        let (cfg, working, opts) = (self.net_cfg, &self.working, &self.opts);
        let calib = self.calib_scales.as_deref();
        let entry = self
            .cache
            .try_get_or_insert_with(bucket, || build_entry(cfg, working, opts, calib, bucket))?;
        // Zero-pad the staging tensor: row r carries request chunk[r],
        // rows beyond the chunk stay zero (their outputs are discarded).
        entry.x.data.fill(0.0);
        entry.widths.fill(0);
        for (row, &i) in chunk.iter().enumerate() {
            entry.x.data[row * bucket..row * bucket + reqs[i].len()].copy_from_slice(reqs[i]);
            entry.widths[row] = reqs[i].len();
        }
        // Width-masked inference: each row's pad tail is re-zeroed at
        // every layer (fusion-boundary masking inside the net plan), so
        // its output is bit-identical to native-width execution — the
        // bucket is an execution shape, not model input. All buffers are
        // entry-owned: the call touches the heap not at all.
        let BucketEntry {
            net,
            x,
            widths,
            den,
            logits,
        } = entry;
        net.infer_masked_into(x, Some(widths.as_slice()), den, logits)
            .map_err(ServeError::Plan)?;
        for (row, &i) in chunk.iter().enumerate() {
            let w = reqs[i].len();
            out[i] = Some(InferOutput {
                denoised: den.data[row * bucket..row * bucket + w].to_vec(),
                logits: logits.data[row * bucket..row * bucket + w].to_vec(),
            });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tiny_opts() -> EngineOpts {
        EngineOpts {
            buckets: BucketSet::new(&[128, 256]).expect("widths"),
            max_batch: 3,
            cache_capacity: 2,
            ..EngineOpts::default()
        }
    }

    fn tiny_engine(opts: EngineOpts) -> InferenceEngine {
        let cfg = NetConfig::tiny();
        let params = AtacWorksNet::init(cfg, 5).pack_params();
        InferenceEngine::new(cfg, &params, opts).expect("engine")
    }

    fn track(w: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..w).map(|_| rng.poisson(0.7) as f32).collect()
    }

    #[test]
    fn routes_widths_to_buckets_and_truncates_outputs() {
        let mut e = tiny_engine(tiny_opts());
        let reqs = [track(100, 1), track(128, 2), track(200, 3)];
        let got = e
            .infer_batch(&[&reqs[0], &reqs[1], &reqs[2]])
            .expect("infer");
        assert_eq!(got.len(), 3);
        for (g, r) in got.iter().zip(&reqs) {
            assert_eq!(g.denoised.len(), r.len());
            assert_eq!(g.logits.len(), r.len());
        }
        // 100 and 128 share the 128 bucket; 200 built the 256 bucket.
        assert_eq!(e.cache_len(), 2);
        assert_eq!(e.cache_stats().1, 2, "two bucket builds");
    }

    #[test]
    fn batched_rows_match_single_request_execution_bitwise() {
        let mut batched = tiny_engine(tiny_opts());
        let mut single = tiny_engine(EngineOpts {
            max_batch: 1,
            ..tiny_opts()
        });
        let reqs = [track(90, 10), track(128, 11), track(60, 12)];
        let got = batched
            .infer_batch(&[&reqs[0], &reqs[1], &reqs[2]])
            .expect("batched");
        for (g, r) in got.iter().zip(&reqs) {
            let alone = single.infer_one(r).expect("single");
            assert_eq!(g, &alone, "batched row must be bit-identical");
        }
    }

    #[test]
    fn warm_prebuilds_every_bucket_so_requests_only_hit() {
        let mut e = tiny_engine(tiny_opts());
        e.warm().expect("warm");
        assert_eq!(e.cache_len(), 2);
        assert!(e.plan_workspace_bytes() > 0);
        let (_, misses_after_warm) = e.cache_stats();
        let r = track(70, 20);
        e.infer_one(&r).expect("infer");
        let (hits, misses) = e.cache_stats();
        assert_eq!(misses, misses_after_warm, "no build after warming");
        assert!(hits >= 1);
    }

    #[test]
    fn warm_builds_only_the_resident_suffix() {
        let mut e = tiny_engine(EngineOpts {
            buckets: BucketSet::new(&[64, 128, 256]).expect("widths"),
            cache_capacity: 1,
            max_batch: 1,
            ..EngineOpts::default()
        });
        e.warm().expect("warm");
        // Only the largest bucket can stay resident; building 64 and 128
        // would be wasted work immediately evicted.
        assert_eq!(e.cache_len(), 1);
        assert_eq!(e.warm_skipped(), 2);
        assert!(e.cache_evictions().is_empty(), "warming must not evict");
        // Serving the resident bucket after warm is a pure hit.
        let r = track(200, 50);
        let (_, misses_after_warm) = e.cache_stats();
        e.infer_one(&r).expect("infer");
        assert_eq!(e.cache_stats().1, misses_after_warm);
        // A skipped bucket still builds lazily on first use.
        e.infer_one(&track(60, 51)).expect("cold 64 bucket");
        assert_eq!(e.cache_stats().1, misses_after_warm + 1);
    }

    #[test]
    fn pinned_bucket_execution_is_bit_identical_and_validated() {
        let mut e = tiny_engine(tiny_opts());
        let r = track(100, 60);
        let natural = e.infer_one(&r).expect("natural 128 bucket");
        let pinned = e.infer_one_pinned(&r, 256).expect("pinned 256 bucket");
        assert_eq!(natural, pinned, "bucket invariance under pinning");
        assert!(
            e.infer_one_pinned(&r, 100).is_err(),
            "100 is not a configured bucket"
        );
        assert!(
            e.infer_one_pinned(&track(200, 61), 128).is_err(),
            "request wider than the pinned bucket"
        );
        assert!(e.infer_one_pinned(&[], 128).is_err());
    }

    #[test]
    fn i8_engine_batched_matches_single_and_engages_the_tier() {
        let mut batched = tiny_engine(EngineOpts {
            precision: Precision::I8,
            ..tiny_opts()
        });
        let mut single = tiny_engine(EngineOpts {
            precision: Precision::I8,
            max_batch: 1,
            ..tiny_opts()
        });
        let reqs = [track(90, 70), track(128, 71)];
        let got = batched.infer_batch(&[&reqs[0], &reqs[1]]).expect("batched");
        // Both engines calibrate from the same params on the same fixed
        // synthetic batch, so batched rows are bit-identical to
        // one-at-a-time serving under i8 exactly as under f32.
        for (g, r) in got.iter().zip(&reqs) {
            let alone = single.infer_one(r).expect("single");
            assert_eq!(g, &alone, "i8 batched row must be bit-identical");
        }
        // And the tier actually engaged: i8 output differs from f32.
        let mut f32e = tiny_engine(tiny_opts());
        let f = f32e.infer_one(&reqs[0]).expect("f32");
        assert_ne!(got[0].denoised, f.denoised, "i8 tier did not engage");
    }

    #[test]
    fn rejects_oversized_and_empty_requests() {
        let mut e = tiny_engine(tiny_opts());
        let too_wide = track(300, 30);
        match e.infer_batch(&[&too_wide]) {
            Err(ServeError::TooWide { width, largest }) => {
                assert_eq!((width, largest), (300, 256));
            }
            other => panic!("expected TooWide, got {other:?}"),
        }
        assert!(matches!(
            e.infer_batch(&[&[][..]]),
            Err(ServeError::EmptyRequest)
        ));
        // A failed batch runs nothing: the cache stays empty.
        assert_eq!(e.cache_len(), 0);
    }

    #[test]
    fn cache_eviction_keeps_serving_correctly() {
        let mut e = tiny_engine(EngineOpts {
            buckets: BucketSet::new(&[64, 128, 256]).expect("widths"),
            cache_capacity: 1,
            max_batch: 2,
            ..EngineOpts::default()
        });
        let (a, b, c) = (track(64, 40), track(128, 41), track(256, 42));
        let first = e.infer_one(&a).expect("64");
        e.infer_one(&b).expect("128");
        e.infer_one(&c).expect("256");
        assert_eq!(e.cache_len(), 1);
        assert_eq!(e.cache_evictions(), &[64, 128]);
        // A rebuilt bucket still produces the same bits.
        let again = e.infer_one(&a).expect("64 again");
        assert_eq!(first, again);
    }

    #[test]
    fn rejects_bad_parameter_vector() {
        let cfg = NetConfig::tiny();
        assert!(matches!(
            InferenceEngine::new(cfg, &[0.0; 3], EngineOpts::default()),
            Err(ServeError::Config(_))
        ));
    }
}
