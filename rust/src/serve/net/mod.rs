//! The TCP front-end — a length-prefixed binary wire onto the serving
//! stack (DESIGN.md §7b).
//!
//! ```text
//!  TcpListener (bounded accept loop)
//!       │  one thread per connection, capped; over the cap → BUSY
//!       ▼
//!  WireParser: zero-allocation pull parser over caller buffers
//!       │  header {magic, version, flags, dtype, width} + f32 payload
//!       ▼
//!  Server::submit ──► batcher / streaming route ──► Ticket::wait
//!       │  QueueFull → BUSY status on the wire (backpressure, retry)
//!       ▼
//!  response header {status, flags, width} + denoised ++ logits
//! ```
//!
//! * [`wire`]     — the frame layout, status codes and the pull parser.
//!   The parser follows the picojson-rs discipline (SNIPPETS.md):
//!   pull-style, non-recursive, panic-free, zero heap allocations, and
//!   payload bytes are **borrowed from the caller's read buffer**, never
//!   copied (`tests/wire_alloc.rs` proves the zero-allocation claim with
//!   a counting global allocator).
//! * [`frontend`] — the listener, per-connection state machines, the
//!   connection cap, the idle-connection reaper, handler panic
//!   isolation, per-connection/stream counters ([`NetStats`]) and
//!   graceful drain on shutdown (in-flight requests finish, stragglers
//!   past the drain budget are force-closed).
//!
//! Protocol version 2 adds an optional per-request deadline (ms) to the
//! request header; v1 frames are still accepted (no deadline).

pub mod frontend;
pub mod wire;

pub use frontend::{NetOpts, NetServer, NetStats};
pub use wire::{
    encode_request_header, encode_request_header_with_deadline, encode_response_header,
    parse_response_header, RequestHeader, WireError, WireEvent, WireParser, DTYPE_F32,
    REQ_HEADER_LEN, RESP_FLAG_STREAMED, RESP_HEADER_LEN, WIRE_MAGIC, WIRE_VERSION,
    WIRE_VERSION_MIN,
};
