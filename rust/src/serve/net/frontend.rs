//! The TCP listener, per-connection state machines and graceful drain
//! (DESIGN.md §7b).
//!
//! A [`NetServer`] owns the batcher [`Server`] and a bounded accept
//! loop: each accepted connection gets a handler thread with a fixed
//! read buffer, one persistent [`WireParser`] and a reusable payload
//! vector, so steady-state request handling performs no per-frame
//! allocations beyond the submit copy the batcher requires. Admission
//! pressure surfaces on the wire instead of in latency:
//!
//! * over the connection cap → a `BUSY` response at accept, then close;
//! * [`ServeError::QueueFull`] from the batcher → a `BUSY` response on
//!   the request, connection stays open (the client may retry);
//! * protocol violations → a `MALFORMED` response, then close (framing
//!   cannot be re-synchronized).
//!
//! Shutdown drains: the accept loop stops, handlers finish the frame
//! they are on (every accepted ticket resolves — the batcher flushes
//! pending groups before its workers stop), and only connections that
//! outlive the drain budget are force-closed.
//!
//! Robustness (DESIGN.md §7d): handler threads run under
//! `catch_unwind`, so a panic mid-connection closes that connection —
//! cleanup still runs — and never takes the accept loop or another
//! handler with it; every shared lock is acquired poison-recovering. An
//! **idle reaper** closes connections that have sent nothing for
//! [`NetOpts::idle_timeout`], so dead clients stop pinning
//! `max_connections` slots. Version-2 request frames may carry a
//! deadline, forwarded to the batcher's deadline-aware admission.

use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::super::batcher::{ServeMetrics, Server};
#[cfg(any(test, feature = "fault"))]
use super::super::fault::{FaultAction, FaultPlan, FaultSite};
use super::super::{lock_unpoisoned, ServeError};
use super::wire::{encode_response_header, status, WireEvent, WireParser, RESP_FLAG_STREAMED};

/// Front-end policy knobs.
#[derive(Debug, Clone)]
pub struct NetOpts {
    /// Live-connection cap; connections over it get `BUSY` and close.
    pub max_connections: usize,
    /// Largest request width accepted on the wire, in samples (a
    /// denial-of-service guard applied before any buffer is sized).
    pub max_width: usize,
    /// Graceful-drain budget at shutdown: connections still serving
    /// after this long are force-closed.
    pub drain: Duration,
    /// Idle reaper: a connection that has sent nothing for this long
    /// (and is between frames) is closed, so dead clients stop pinning
    /// connection slots. `Duration::ZERO` disables the reaper.
    pub idle_timeout: Duration,
    /// Deterministic fault-injection plan (chaos tests only).
    #[cfg(any(test, feature = "fault"))]
    pub fault: Option<Arc<FaultPlan>>,
}

impl Default for NetOpts {
    fn default() -> Self {
        NetOpts {
            max_connections: 64,
            max_width: 1 << 22,
            drain: Duration::from_secs(5),
            idle_timeout: Duration::from_secs(60),
            #[cfg(any(test, feature = "fault"))]
            fault: None,
        }
    }
}

/// Snapshot of the per-connection / per-request wire counters.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct NetStats {
    pub connections_accepted: u64,
    /// Connections refused at accept (over the connection cap).
    pub connections_rejected: u64,
    pub requests_ok: u64,
    /// Requests answered `BUSY` (admission backpressure).
    pub requests_backpressure: u64,
    /// Requests that failed server-side (non-backpressure errors).
    pub requests_error: u64,
    /// Frames that violated the protocol (connection closed).
    pub requests_malformed: u64,
    /// OK responses that took the streaming path.
    pub requests_streamed: u64,
    /// Requests shed with `DEADLINE_EXCEEDED` (expired while queued).
    pub requests_deadline: u64,
    /// Handler threads that panicked (their connection closed; the
    /// server kept serving).
    pub handler_panics: u64,
    /// Connections closed by the idle reaper.
    pub connections_idle_closed: u64,
    pub bytes_in: u64,
    pub bytes_out: u64,
}

/// State shared between the accept loop, handlers and the owner.
struct Shared {
    /// The batcher; taken (and shut down) exactly once, by
    /// [`NetServer::shutdown`].
    server: Mutex<Option<Server>>,
    stop: AtomicBool,
    live: AtomicUsize,
    next_id: AtomicU64,
    /// Clone per live connection, so drain expiry can force-close.
    conns: Mutex<Vec<(u64, TcpStream)>>,
    handlers: Mutex<Vec<JoinHandle<()>>>,
    opts: NetOpts,
    connections_accepted: AtomicU64,
    connections_rejected: AtomicU64,
    requests_ok: AtomicU64,
    requests_backpressure: AtomicU64,
    requests_error: AtomicU64,
    requests_malformed: AtomicU64,
    requests_streamed: AtomicU64,
    requests_deadline: AtomicU64,
    handler_panics: AtomicU64,
    connections_idle_closed: AtomicU64,
    bytes_in: AtomicU64,
    bytes_out: AtomicU64,
}

impl Shared {
    fn snapshot(&self) -> NetStats {
        NetStats {
            connections_accepted: self.connections_accepted.load(Ordering::Relaxed),
            connections_rejected: self.connections_rejected.load(Ordering::Relaxed),
            requests_ok: self.requests_ok.load(Ordering::Relaxed),
            requests_backpressure: self.requests_backpressure.load(Ordering::Relaxed),
            requests_error: self.requests_error.load(Ordering::Relaxed),
            requests_malformed: self.requests_malformed.load(Ordering::Relaxed),
            requests_streamed: self.requests_streamed.load(Ordering::Relaxed),
            requests_deadline: self.requests_deadline.load(Ordering::Relaxed),
            handler_panics: self.handler_panics.load(Ordering::Relaxed),
            connections_idle_closed: self.connections_idle_closed.load(Ordering::Relaxed),
            bytes_in: self.bytes_in.load(Ordering::Relaxed),
            bytes_out: self.bytes_out.load(Ordering::Relaxed),
        }
    }
}

/// The TCP front-end: owns the batcher [`Server`] plus the accept loop.
pub struct NetServer {
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
    local_addr: SocketAddr,
    done: bool,
}

impl NetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:7878"`; port 0 picks a free port)
    /// and start accepting wire-protocol traffic for `server`. The
    /// listener, accept loop and handlers compose with `anyhow` at the
    /// CLI boundary through plain `io::Error` / [`ServeError`].
    pub fn bind(
        addr: impl ToSocketAddrs,
        server: Server,
        opts: NetOpts,
    ) -> std::io::Result<NetServer> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local_addr = listener.local_addr()?;
        let shared = Arc::new(Shared {
            server: Mutex::new(Some(server)),
            stop: AtomicBool::new(false),
            live: AtomicUsize::new(0),
            next_id: AtomicU64::new(0),
            conns: Mutex::new(Vec::new()),
            handlers: Mutex::new(Vec::new()),
            opts,
            connections_accepted: AtomicU64::new(0),
            connections_rejected: AtomicU64::new(0),
            requests_ok: AtomicU64::new(0),
            requests_backpressure: AtomicU64::new(0),
            requests_error: AtomicU64::new(0),
            requests_malformed: AtomicU64::new(0),
            requests_streamed: AtomicU64::new(0),
            requests_deadline: AtomicU64::new(0),
            handler_panics: AtomicU64::new(0),
            connections_idle_closed: AtomicU64::new(0),
            bytes_in: AtomicU64::new(0),
            bytes_out: AtomicU64::new(0),
        });
        let accept_shared = Arc::clone(&shared);
        let accept = std::thread::spawn(move || accept_loop(&listener, &accept_shared));
        Ok(NetServer {
            shared,
            accept: Some(accept),
            local_addr,
            done: false,
        })
    }

    /// The bound address (resolves port 0 to the picked port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Snapshot of the wire counters.
    pub fn stats(&self) -> NetStats {
        self.shared.snapshot()
    }

    /// Live connections right now.
    pub fn connections(&self) -> usize {
        self.shared.live.load(Ordering::SeqCst)
    }

    /// Stop accepting, drain connections (bounded by the drain budget,
    /// then force-close), shut the batcher down (which drains every
    /// accepted ticket) and return the final serving + wire telemetry.
    pub fn shutdown(mut self) -> (ServeMetrics, NetStats) {
        self.stop_net();
        self.done = true;
        let stats = self.shared.snapshot();
        let server = lock_unpoisoned(&self.shared.server).take();
        let metrics = server
            .expect("the batcher is taken only here, once")
            .shutdown();
        (metrics, stats)
    }

    /// Stop the accept loop, wait for live connections to finish (up to
    /// the drain budget), force-close stragglers, join every thread.
    fn stop_net(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let deadline = Instant::now() + self.shared.opts.drain;
        while self.shared.live.load(Ordering::SeqCst) > 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        // Anything still live overstayed the drain budget: force-close
        // its socket so the handler unblocks and exits. Poison-recovering
        // locks keep this drain working even after a handler panicked
        // while holding `conns` or `handlers` (the self-healing contract:
        // one panic must never deadlock shutdown).
        for (_, s) in lock_unpoisoned(&self.shared.conns).drain(..) {
            let _ = s.shutdown(Shutdown::Both);
        }
        let handlers: Vec<JoinHandle<()>> =
            std::mem::take(&mut *lock_unpoisoned(&self.shared.handlers));
        for h in handlers {
            let _ = h.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        if !self.done {
            self.stop_net();
            // The batcher (still inside `shared`) stops via its own Drop
            // when the last Arc goes away.
        }
    }
}

/// Bounded accept loop: non-blocking accept + stop polling, connection
/// cap enforcement, handler spawning.
fn accept_loop(listener: &TcpListener, shared: &Arc<Shared>) {
    loop {
        if shared.stop.load(Ordering::SeqCst) {
            return;
        }
        match listener.accept() {
            Ok((mut stream, _)) => {
                let _ = stream.set_nodelay(true);
                if shared.live.load(Ordering::SeqCst) >= shared.opts.max_connections {
                    shared.connections_rejected.fetch_add(1, Ordering::Relaxed);
                    let hdr = encode_response_header(status::BUSY, 0, 0);
                    let _ = stream.write_all(&hdr);
                    continue; // dropped: closed
                }
                shared.live.fetch_add(1, Ordering::SeqCst);
                shared.connections_accepted.fetch_add(1, Ordering::Relaxed);
                let id = shared.next_id.fetch_add(1, Ordering::Relaxed);
                if let Ok(clone) = stream.try_clone() {
                    lock_unpoisoned(&shared.conns).push((id, clone));
                }
                let conn_shared = Arc::clone(shared);
                let handle = std::thread::spawn(move || {
                    // Panic isolation: a handler that unwinds (a bug, or
                    // an injected NetRespond fault) closes only its own
                    // connection — the cleanup below still runs, so the
                    // connection slot and the force-close list stay
                    // consistent and the rest of the server is untouched.
                    let outcome =
                        catch_unwind(AssertUnwindSafe(|| handle_conn(&conn_shared, id, stream)));
                    if outcome.is_err() {
                        conn_shared.handler_panics.fetch_add(1, Ordering::Relaxed);
                    }
                    lock_unpoisoned(&conn_shared.conns).retain(|(cid, _)| *cid != id);
                    conn_shared.live.fetch_sub(1, Ordering::SeqCst);
                });
                // Reap handles of handlers that already exited so the
                // list stays bounded by the live-connection count over a
                // long-running server's lifetime (finished threads need
                // no join — dropping their handle detaches nothing that
                // still runs).
                let mut handlers = lock_unpoisoned(&shared.handlers);
                handlers.retain(|h| !h.is_finished());
                handlers.push(handle);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(_) => {
                // Transient accept failure (e.g. EMFILE): back off.
                std::thread::sleep(Duration::from_millis(2));
            }
        }
    }
}

/// One connection's state machine: read → pull-parse → submit → reply,
/// until EOF, a protocol violation, a dead peer, or shutdown observed
/// at a frame boundary.
fn handle_conn(shared: &Shared, _id: u64, mut stream: TcpStream) {
    // A short read timeout lets the handler observe shutdown (and count
    // idle time) between frames without a dedicated wake-up channel.
    let _ = stream.set_read_timeout(Some(Duration::from_millis(50)));
    let mut parser = WireParser::new(shared.opts.max_width);
    let mut buf = vec![0u8; 16 * 1024];
    let mut payload: Vec<f32> = Vec::new();
    let mut filled = 0usize;
    let mut mid_request = false;
    let mut deadline_ms: u16 = 0;
    let idle_timeout = shared.opts.idle_timeout;
    let mut last_activity = Instant::now();
    'conn: loop {
        // Parse everything buffered, looping until the parser asks for
        // more input. The loop must not gate on `pos < filled`: a frame
        // that ends exactly at the buffered bytes (the normal case for a
        // send-then-wait client) leaves the parser in its done state,
        // and only a further pull — legal on empty input — surfaces
        // `WireEvent::End`. Every NeedMore means the buffered bytes are
        // fully consumed (the parser always takes what it can), so the
        // buffer resets to empty afterwards.
        let mut pos = 0usize;
        loop {
            match parser.pull(&buf[pos..filled]) {
                Ok((n, ev)) => {
                    pos += n;
                    match ev {
                        WireEvent::NeedMore => break,
                        WireEvent::Header(h) => {
                            payload.clear();
                            payload.reserve(h.width);
                            mid_request = true;
                            deadline_ms = h.deadline_ms;
                        }
                        WireEvent::Payload(raw) => {
                            for c in raw.chunks_exact(4) {
                                payload.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
                            }
                        }
                        WireEvent::PayloadSplit(v) => payload.push(v),
                        WireEvent::End => {
                            mid_request = false;
                            if !respond(shared, &mut stream, &payload, deadline_ms) {
                                break 'conn;
                            }
                            if shared.stop.load(Ordering::SeqCst) {
                                break 'conn; // drain: frame boundary
                            }
                        }
                    }
                }
                Err(_) => {
                    shared.requests_malformed.fetch_add(1, Ordering::Relaxed);
                    let hdr = encode_response_header(status::MALFORMED, 0, 0);
                    if stream.write_all(&hdr).is_ok() {
                        shared
                            .bytes_out
                            .fetch_add(hdr.len() as u64, Ordering::Relaxed);
                    }
                    break 'conn; // framing lost: close
                }
            }
        }
        filled = 0;
        match stream.read(&mut buf) {
            Ok(0) => break, // EOF
            Ok(n) => {
                filled = n;
                last_activity = Instant::now();
                shared.bytes_in.fetch_add(n as u64, Ordering::Relaxed);
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if shared.stop.load(Ordering::SeqCst) && !mid_request {
                    break;
                }
                // Idle reaper: a silent peer (even one that went dark
                // mid-frame) stops pinning a connection slot. The reply
                // to its unfinished frame is simply never written.
                if !idle_timeout.is_zero() && last_activity.elapsed() >= idle_timeout {
                    shared
                        .connections_idle_closed
                        .fetch_add(1, Ordering::Relaxed);
                    break;
                }
            }
            Err(_) => break,
        }
    }
}

/// Submit one parsed request (forwarding its wire deadline, if any) and
/// write the response frame. Returns false when the connection is no
/// longer writable (or an injected fault dropped it).
fn respond(shared: &Shared, stream: &mut TcpStream, payload: &[f32], deadline_ms: u16) -> bool {
    // Injection point `NetRespond`: a `Panic` here unwinds the handler
    // while it holds the server lock — poisoning it — to prove the
    // poison-recovering accessors and handler cleanup; `DropConn`
    // closes the connection without answering (chaos tests only).
    #[cfg(any(test, feature = "fault"))]
    if let Some(plan) = &shared.opts.fault {
        match plan.check(FaultSite::NetRespond, 0) {
            Some(FaultAction::Panic) => {
                let _guard = lock_unpoisoned(&shared.server);
                panic!("fault-injected handler panic (holding the server lock)");
            }
            Some(FaultAction::DropConn) => return false,
            _ => {}
        }
    }
    let deadline = (deadline_ms > 0).then(|| Duration::from_millis(u64::from(deadline_ms)));
    let submitted = {
        let guard = lock_unpoisoned(&shared.server);
        match guard.as_ref() {
            Some(server) => server.submit_with_deadline(payload.to_vec(), deadline),
            None => Err(ServeError::ShuttingDown),
        }
    };
    // wait() outside the lock: other connections keep submitting while
    // this one's batch window fills.
    match submitted.and_then(|t| t.wait()) {
        Ok(resp) => {
            shared.requests_ok.fetch_add(1, Ordering::Relaxed);
            let flags = if resp.streamed {
                shared.requests_streamed.fetch_add(1, Ordering::Relaxed);
                RESP_FLAG_STREAMED
            } else {
                0
            };
            let hdr = encode_response_header(status::OK, flags, payload.len() as u32);
            if stream.write_all(&hdr).is_err() {
                return false;
            }
            let body = write_samples(stream, &resp.output.denoised)
                .and_then(|a| write_samples(stream, &resp.output.logits).map(|b| a + b));
            match body {
                Ok(n) => {
                    shared
                        .bytes_out
                        .fetch_add((hdr.len() + n) as u64, Ordering::Relaxed);
                    true
                }
                Err(_) => false,
            }
        }
        Err(e) => {
            match e {
                ServeError::QueueFull { .. } => {
                    shared.requests_backpressure.fetch_add(1, Ordering::Relaxed);
                }
                ServeError::DeadlineExceeded => {
                    shared.requests_deadline.fetch_add(1, Ordering::Relaxed);
                }
                _ => {
                    shared.requests_error.fetch_add(1, Ordering::Relaxed);
                }
            }
            let hdr = encode_response_header(e.wire_status(), 0, 0);
            if stream.write_all(&hdr).is_ok() {
                shared
                    .bytes_out
                    .fetch_add(hdr.len() as u64, Ordering::Relaxed);
                true
            } else {
                false
            }
        }
    }
}

/// Write `data` as little-endian f32 bytes through a fixed stack
/// scratch (bounded memory even for streamed, sequence-long outputs).
fn write_samples(stream: &mut TcpStream, data: &[f32]) -> std::io::Result<usize> {
    let mut scratch = [0u8; 4096];
    for chunk in data.chunks(scratch.len() / 4) {
        for (slot, v) in scratch.chunks_exact_mut(4).zip(chunk) {
            slot.copy_from_slice(&v.to_le_bytes());
        }
        stream.write_all(&scratch[..chunk.len() * 4])?;
    }
    Ok(data.len() * 4)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{AtacWorksNet, NetConfig};
    use crate::serve::{BatcherOpts, BucketSet, EngineOpts};

    fn tiny_batcher() -> Server {
        let cfg = NetConfig::tiny();
        let params = AtacWorksNet::init(cfg, 5).pack_params();
        let opts = BatcherOpts {
            engine: EngineOpts {
                buckets: BucketSet::new(&[128]).expect("widths"),
                max_batch: 2,
                cache_capacity: 1,
                ..EngineOpts::default()
            },
            window: Duration::from_millis(1),
            queue_depth: 8,
            workers: 1,
            warm: false,
            ..BatcherOpts::default()
        };
        Server::start(cfg, &params, opts).expect("server")
    }

    #[test]
    fn a_single_send_then_wait_request_gets_its_response() {
        // Regression: a frame ending exactly at the buffered read
        // boundary — the normal shape for a client that sends one
        // request then waits — must still surface `WireEvent::End`
        // (which takes one pull past the payload bytes) and produce a
        // response rather than deadlocking both sides.
        use super::super::wire::{encode_request_header, parse_response_header, RESP_HEADER_LEN};
        let net = NetServer::bind("127.0.0.1:0", tiny_batcher(), NetOpts::default())
            .expect("bind loopback");
        let mut stream = TcpStream::connect(net.local_addr()).expect("connect");
        stream
            .set_read_timeout(Some(Duration::from_secs(10)))
            .expect("read timeout");
        let mut frame = encode_request_header(100, 0).to_vec();
        for v in 0..100 {
            frame.extend_from_slice(&(v as f32).to_le_bytes());
        }
        stream.write_all(&frame).expect("send one exact frame");
        let mut hdr = [0u8; RESP_HEADER_LEN];
        stream
            .read_exact(&mut hdr)
            .expect("response header arrives (no frame-boundary deadlock)");
        let (code, _flags, width) = parse_response_header(&hdr);
        assert_eq!(code, status::OK);
        assert_eq!(width, 100);
        let mut payload = vec![0u8; width * 8];
        stream.read_exact(&mut payload).expect("denoised ++ logits payload");
        drop(stream);
        let (metrics, stats) = net.shutdown();
        assert_eq!(stats.requests_ok, 1);
        assert_eq!(metrics.completed, 1);
    }

    #[test]
    fn binds_reports_its_address_and_shuts_down_clean() {
        let net = NetServer::bind("127.0.0.1:0", tiny_batcher(), NetOpts::default())
            .expect("bind loopback");
        let addr = net.local_addr();
        assert_ne!(addr.port(), 0, "port 0 resolves to a real port");
        assert_eq!(net.connections(), 0);
        let (metrics, stats) = net.shutdown();
        assert_eq!(metrics.completed, 0);
        assert_eq!(stats, NetStats::default());
    }
}
