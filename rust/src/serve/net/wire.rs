//! Wire format + the zero-allocation pull parser (DESIGN.md §7b).
//!
//! ## Frames
//!
//! Request (all integers little-endian):
//!
//! ```text
//!  offset  size  field
//!       0     2  magic "DC"
//!       2     1  protocol version (= 2; version 1 still accepted)
//!       3     1  flags (reserved, must-ignore)
//!       4     1  dtype (0 = f32)
//!       5     1  reserved
//!       6     2  deadline_ms: u16 (v2; 0 = no deadline. Reserved in v1)
//!       8     4  width: u32, payload sample count (> 0)
//!      12  4·width  payload: width f32 samples
//! ```
//!
//! Version 2 adds the request deadline in milliseconds at offsets 6–7 —
//! bytes that were reserved-zero in v1, so a v1 frame parses under the
//! v2 rules as "no deadline" and the version bump is backward
//! compatible: the parser accepts both versions and zeroes the deadline
//! for v1.
//!
//! Response:
//!
//! ```text
//!  offset  size  field
//!       0     1  status (0 = OK; see the status module)
//!       1     1  flags (bit 0: request took the streaming path)
//!       2     2  reserved
//!       4     4  width: u32 (0 on error)
//!       8  8·width  payload: width f32 denoised ++ width f32 logits
//! ```
//!
//! ## The parser
//!
//! [`WireParser`] is pull-style in the picojson-rs sense: the caller
//! owns the read buffer and calls [`WireParser::pull`] with whatever
//! bytes it has; the parser consumes a prefix and returns one event.
//! It is non-recursive (a flat three-state machine), panic-free (every
//! slice index is bounds-derived), and performs **zero heap
//! allocations** — its only storage is a fixed header scratch that
//! doubles as the carry buffer for an f32 split across reads. Payload
//! bytes are returned as a borrow of the caller's buffer
//! ([`WireEvent::Payload`]), never copied.

use crate::conv1d::PlanError;
use crate::serve::ServeError;

/// First two bytes of every request frame.
pub const WIRE_MAGIC: [u8; 2] = *b"DC";
/// Protocol version this build emits (it accepts
/// [`WIRE_VERSION_MIN`]`..=`[`WIRE_VERSION`]).
pub const WIRE_VERSION: u8 = 2;
/// Oldest protocol version still accepted.
pub const WIRE_VERSION_MIN: u8 = 1;
/// Request dtype code for f32 little-endian samples (the only dtype).
pub const DTYPE_F32: u8 = 0;
/// Request header length in bytes.
pub const REQ_HEADER_LEN: usize = 12;
/// Response header length in bytes.
pub const RESP_HEADER_LEN: usize = 8;
/// Response flag bit 0: the request was served by the streaming path.
pub const RESP_FLAG_STREAMED: u8 = 1;

/// Response status codes — one per [`ServeError`] variant plus OK and
/// a protocol-level MALFORMED.
pub mod status {
    /// Request served; payload follows.
    pub const OK: u8 = 0;
    /// Backpressure: admission queue full, retry later.
    pub const BUSY: u8 = 1;
    /// Width exceeds the largest bucket and streaming is disabled.
    pub const TOO_WIDE: u8 = 2;
    /// Zero-width request.
    pub const EMPTY: u8 = 3;
    /// Server is draining; no new work accepted.
    pub const SHUTTING_DOWN: u8 = 4;
    /// Plan construction failed server-side.
    pub const PLAN: u8 = 5;
    /// Invalid serving configuration.
    pub const CONFIG: u8 = 6;
    /// The request frame violated the wire protocol.
    pub const MALFORMED: u8 = 7;
    /// The request's deadline expired while it was queued; it was shed
    /// before any compute ran (v2).
    pub const DEADLINE_EXCEEDED: u8 = 8;
    /// A worker panicked while holding the request; the replica was
    /// rebuilt or the rank respawned, but this request was lost (v2).
    pub const INTERNAL: u8 = 9;
}

impl ServeError {
    /// The wire status code this error maps to.
    pub fn wire_status(&self) -> u8 {
        match self {
            ServeError::TooWide { .. } => status::TOO_WIDE,
            ServeError::EmptyRequest => status::EMPTY,
            ServeError::QueueFull { .. } => status::BUSY,
            ServeError::ShuttingDown => status::SHUTTING_DOWN,
            ServeError::DeadlineExceeded => status::DEADLINE_EXCEEDED,
            ServeError::WorkerPanic => status::INTERNAL,
            ServeError::Plan(_) => status::PLAN,
            ServeError::Config(_) => status::CONFIG,
        }
    }

    /// A representative error for a wire status code; `None` for OK,
    /// MALFORMED and unknown codes.
    ///
    /// Field values are **not** carried on the wire, so variants with
    /// payloads come back zeroed/empty (e.g. `TooWide { width: 0,
    /// largest: 0 }`): only the *variant* is meaningful to a client,
    /// never the fabricated field values — do not surface them as
    /// diagnostics.
    pub fn from_wire_status(code: u8) -> Option<ServeError> {
        match code {
            status::TOO_WIDE => Some(ServeError::TooWide {
                width: 0,
                largest: 0,
            }),
            status::EMPTY => Some(ServeError::EmptyRequest),
            status::BUSY => Some(ServeError::QueueFull { depth: 0 }),
            status::SHUTTING_DOWN => Some(ServeError::ShuttingDown),
            status::DEADLINE_EXCEEDED => Some(ServeError::DeadlineExceeded),
            status::INTERNAL => Some(ServeError::WorkerPanic),
            status::PLAN => Some(ServeError::Plan(PlanError(String::new()))),
            status::CONFIG => Some(ServeError::Config(String::new())),
            _ => None,
        }
    }
}

/// A validated request header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RequestHeader {
    pub version: u8,
    pub flags: u8,
    pub dtype: u8,
    /// Request deadline in milliseconds (0 = none; always 0 for a v1
    /// frame, whose bytes 6–7 are reserved-zero).
    pub deadline_ms: u16,
    /// Payload sample count (validated: non-zero, within the cap).
    pub width: usize,
}

/// Protocol violations the parser rejects (the connection cannot be
/// re-synchronized after any of these — the handler replies MALFORMED
/// and closes).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireError {
    BadMagic([u8; 2]),
    BadVersion(u8),
    BadDtype(u8),
    ZeroWidth,
    /// Width beyond the caller's cap (a denial-of-service guard: the
    /// header is read before any payload buffer is sized).
    WidthTooLarge { width: u32, max: usize },
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::BadMagic(m) => write!(f, "bad magic {m:?} (want {WIRE_MAGIC:?})"),
            WireError::BadVersion(v) => write!(
                f,
                "unsupported version {v} (want {WIRE_VERSION_MIN}..={WIRE_VERSION})"
            ),
            WireError::BadDtype(d) => write!(f, "unsupported dtype {d} (want {DTYPE_F32} = f32)"),
            WireError::ZeroWidth => write!(f, "zero-width request"),
            WireError::WidthTooLarge { width, max } => {
                write!(f, "request width {width} exceeds the wire cap ({max})")
            }
        }
    }
}

impl std::error::Error for WireError {}

/// One parsing step's outcome. `Payload` borrows the caller's buffer —
/// whole samples are handed back as raw bytes with no copy; only an f32
/// split across two reads is reassembled in the parser's fixed scratch
/// and surfaced as `PayloadSplit`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum WireEvent<'a> {
    /// Input exhausted mid-frame; feed more bytes.
    NeedMore,
    /// A complete, validated request header.
    Header(RequestHeader),
    /// A run of whole payload samples (`len % 4 == 0`), borrowed.
    Payload(&'a [u8]),
    /// One sample whose four bytes straddled a read boundary.
    PayloadSplit(f32),
    /// Frame complete; the parser has reset for the next request.
    End,
}

#[derive(Clone, Copy)]
enum State {
    /// Accumulating the fixed-size header; `have` bytes so far.
    Header { have: usize },
    /// Consuming `remaining` payload bytes; `carry` bytes of a split
    /// sample sit in the scratch.
    Payload { remaining: usize, carry: usize },
    /// Frame finished; next pull emits `End` and resets.
    Done,
}

/// Zero-allocation, non-recursive, panic-free pull parser for request
/// frames. One parser per connection; it persists across frames (after
/// [`WireEvent::End`] it is ready for the next header).
pub struct WireParser {
    state: State,
    /// Header bytes, reused as the ≤ 3-byte split-sample carry.
    scratch: [u8; REQ_HEADER_LEN],
    /// Maximum accepted request width, in samples.
    max_width: usize,
}

impl WireParser {
    /// A parser that rejects any request wider than `max_width` samples
    /// before sizing any payload buffer.
    pub const fn new(max_width: usize) -> WireParser {
        WireParser {
            state: State::Header { have: 0 },
            scratch: [0u8; REQ_HEADER_LEN],
            max_width,
        }
    }

    /// Abandon the current frame (e.g. after an error) and await a
    /// fresh header.
    pub fn reset(&mut self) {
        self.state = State::Header { have: 0 };
    }

    /// Consume a prefix of `input` and return `(bytes_consumed, event)`.
    /// Call in a loop, advancing the input by `bytes_consumed`, until
    /// [`WireEvent::NeedMore`] (then read more bytes) or an error (then
    /// close the connection — framing is lost). Errors leave the parser
    /// mid-header; call [`Self::reset`] to reuse it.
    pub fn pull<'a>(&mut self, input: &'a [u8]) -> Result<(usize, WireEvent<'a>), WireError> {
        match self.state {
            State::Header { have } => {
                let need = REQ_HEADER_LEN - have;
                let take = need.min(input.len());
                self.scratch[have..have + take].copy_from_slice(&input[..take]);
                if have + take < REQ_HEADER_LEN {
                    self.state = State::Header { have: have + take };
                    return Ok((take, WireEvent::NeedMore));
                }
                let h = self.scratch;
                if h[0] != WIRE_MAGIC[0] || h[1] != WIRE_MAGIC[1] {
                    return Err(WireError::BadMagic([h[0], h[1]]));
                }
                if !(WIRE_VERSION_MIN..=WIRE_VERSION).contains(&h[2]) {
                    return Err(WireError::BadVersion(h[2]));
                }
                if h[4] != DTYPE_F32 {
                    return Err(WireError::BadDtype(h[4]));
                }
                let width = u32::from_le_bytes([h[8], h[9], h[10], h[11]]);
                if width == 0 {
                    return Err(WireError::ZeroWidth);
                }
                if width as usize > self.max_width {
                    return Err(WireError::WidthTooLarge {
                        width,
                        max: self.max_width,
                    });
                }
                self.state = State::Payload {
                    remaining: width as usize * 4,
                    carry: 0,
                };
                Ok((
                    take,
                    WireEvent::Header(RequestHeader {
                        version: h[2],
                        flags: h[3],
                        dtype: h[4],
                        // v1 reserves bytes 6–7 (must be sent zero, but
                        // robustness demands we not trust that).
                        deadline_ms: if h[2] >= 2 {
                            u16::from_le_bytes([h[6], h[7]])
                        } else {
                            0
                        },
                        width: width as usize,
                    }),
                ))
            }
            State::Payload { remaining, carry } => {
                if input.is_empty() {
                    return Ok((0, WireEvent::NeedMore));
                }
                if carry > 0 {
                    // Finish the sample split across the previous read.
                    // `remaining` is what is still owed from the wire, so
                    // it covers the rest of this sample.
                    let need = 4 - carry;
                    let take = need.min(input.len());
                    self.scratch[carry..carry + take].copy_from_slice(&input[..take]);
                    let remaining = remaining - take;
                    if carry + take < 4 {
                        self.state = State::Payload {
                            remaining,
                            carry: carry + take,
                        };
                        return Ok((take, WireEvent::NeedMore));
                    }
                    let v = f32::from_le_bytes([
                        self.scratch[0],
                        self.scratch[1],
                        self.scratch[2],
                        self.scratch[3],
                    ]);
                    self.state = if remaining == 0 {
                        State::Done
                    } else {
                        State::Payload {
                            remaining,
                            carry: 0,
                        }
                    };
                    return Ok((take, WireEvent::PayloadSplit(v)));
                }
                let avail = remaining.min(input.len());
                let whole = avail - (avail % 4);
                if whole > 0 {
                    let remaining = remaining - whole;
                    self.state = if remaining == 0 {
                        State::Done
                    } else {
                        State::Payload {
                            remaining,
                            carry: 0,
                        }
                    };
                    return Ok((whole, WireEvent::Payload(&input[..whole])));
                }
                // 1..=3 trailing bytes of a sample: stash them. Payload
                // lengths are multiples of 4, so `avail < 4` here means
                // the *input* ran short, never the frame.
                self.scratch[..avail].copy_from_slice(&input[..avail]);
                self.state = State::Payload {
                    remaining: remaining - avail,
                    carry: avail,
                };
                Ok((avail, WireEvent::NeedMore))
            }
            State::Done => {
                self.state = State::Header { have: 0 };
                Ok((0, WireEvent::End))
            }
        }
    }
}

/// Encode a request header for `width` f32 samples (no deadline).
pub fn encode_request_header(width: u32, flags: u8) -> [u8; REQ_HEADER_LEN] {
    encode_request_header_with_deadline(width, flags, 0)
}

/// Encode a request header carrying a deadline in milliseconds
/// (0 = none). Always emits the current protocol version.
pub fn encode_request_header_with_deadline(
    width: u32,
    flags: u8,
    deadline_ms: u16,
) -> [u8; REQ_HEADER_LEN] {
    let w = width.to_le_bytes();
    let d = deadline_ms.to_le_bytes();
    [
        WIRE_MAGIC[0],
        WIRE_MAGIC[1],
        WIRE_VERSION,
        flags,
        DTYPE_F32,
        0,
        d[0],
        d[1],
        w[0],
        w[1],
        w[2],
        w[3],
    ]
}

/// Encode a response header.
pub fn encode_response_header(status: u8, flags: u8, width: u32) -> [u8; RESP_HEADER_LEN] {
    let w = width.to_le_bytes();
    [status, flags, 0, 0, w[0], w[1], w[2], w[3]]
}

/// Decode a response header into `(status, flags, width)`.
pub fn parse_response_header(h: &[u8; RESP_HEADER_LEN]) -> (u8, u8, usize) {
    (
        h[0],
        h[1],
        u32::from_le_bytes([h[4], h[5], h[6], h[7]]) as usize,
    )
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]

    use super::*;

    /// Drive a parser over `bytes` in chunks of `chunk`, decoding the
    /// payload back into f32s.
    fn run(parser: &mut WireParser, bytes: &[u8], chunk: usize) -> (RequestHeader, Vec<f32>, bool) {
        let mut header = None;
        let mut payload = Vec::new();
        let mut ended = false;
        let mut off = 0;
        while off < bytes.len() || !ended {
            let end = (off + chunk).min(bytes.len());
            let mut input = &bytes[off..end];
            loop {
                let (n, ev) = parser.pull(input).expect("valid frame");
                input = &input[n..];
                off += n;
                match ev {
                    WireEvent::NeedMore => break,
                    WireEvent::Header(h) => header = Some(h),
                    WireEvent::Payload(raw) => {
                        for c in raw.chunks_exact(4) {
                            payload.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
                        }
                    }
                    WireEvent::PayloadSplit(v) => payload.push(v),
                    WireEvent::End => {
                        ended = true;
                        break;
                    }
                }
            }
            if ended {
                break;
            }
            assert!(off < bytes.len(), "parser starved before the frame ended");
        }
        (header.expect("header seen"), payload, ended)
    }

    fn frame(samples: &[f32], flags: u8) -> Vec<u8> {
        let mut out = encode_request_header(samples.len() as u32, flags).to_vec();
        for s in samples {
            out.extend_from_slice(&s.to_le_bytes());
        }
        out
    }

    #[test]
    fn parses_whole_and_fragmented_frames_identically() {
        let samples: Vec<f32> = (0..37).map(|i| i as f32 * 0.5 - 3.0).collect();
        let bytes = frame(&samples, 0);
        // Every fragmentation, including ones that split the header and
        // every f32, must reconstruct the same request.
        for chunk in [1, 2, 3, 4, 5, 7, 11, 12, 13, 64, bytes.len()] {
            let mut p = WireParser::new(1 << 20);
            let (h, payload, ended) = run(&mut p, &bytes, chunk);
            assert!(ended, "chunk {chunk}");
            assert_eq!(h.width, samples.len(), "chunk {chunk}");
            assert_eq!(h.version, WIRE_VERSION);
            assert_eq!(h.dtype, DTYPE_F32);
            assert_eq!(payload, samples, "chunk {chunk}");
        }
    }

    #[test]
    fn parser_persists_across_back_to_back_frames() {
        let a: Vec<f32> = (0..5).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..9).map(|i| -(i as f32)).collect();
        let mut bytes = frame(&a, 0);
        bytes.extend_from_slice(&frame(&b, 0));
        let mut p = WireParser::new(1 << 20);
        let mut widths = Vec::new();
        let mut got = Vec::new();
        let mut input = &bytes[..];
        let mut frames = 0;
        while frames < 2 {
            let (n, ev) = p.pull(input).expect("valid frames");
            input = &input[n..];
            match ev {
                WireEvent::Header(h) => widths.push(h.width),
                WireEvent::Payload(raw) => {
                    for c in raw.chunks_exact(4) {
                        got.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
                    }
                }
                WireEvent::PayloadSplit(v) => got.push(v),
                WireEvent::End => frames += 1,
                WireEvent::NeedMore => panic!("both frames are fully buffered"),
            }
        }
        assert_eq!(widths, vec![5, 9]);
        let want: Vec<f32> = a.iter().chain(b.iter()).copied().collect();
        assert_eq!(got, want);
    }

    #[test]
    fn rejects_protocol_violations() {
        let good = encode_request_header(8, 0);
        let mut bad_magic = good;
        bad_magic[0] = b'X';
        let mut bad_version = good;
        bad_version[2] = 9;
        let mut bad_dtype = good;
        bad_dtype[4] = 7;
        let zero_width = encode_request_header(0, 0);
        let cases: [(&[u8; REQ_HEADER_LEN], WireError); 4] = [
            (&bad_magic, WireError::BadMagic([b'X', b'C'])),
            (&bad_version, WireError::BadVersion(9)),
            (&bad_dtype, WireError::BadDtype(7)),
            (&zero_width, WireError::ZeroWidth),
        ];
        for (bytes, want) in cases {
            let mut p = WireParser::new(1 << 20);
            assert_eq!(p.pull(&bytes[..]).unwrap_err(), want);
            // After reset the parser accepts a good frame again.
            p.reset();
            assert!(matches!(
                p.pull(&good[..]),
                Ok((REQ_HEADER_LEN, WireEvent::Header(_)))
            ));
        }
        // The width cap guards payload-buffer sizing.
        let mut p = WireParser::new(16);
        let wide = encode_request_header(17, 0);
        assert_eq!(
            p.pull(&wide[..]).unwrap_err(),
            WireError::WidthTooLarge { width: 17, max: 16 }
        );
    }

    #[test]
    fn serve_errors_round_trip_through_wire_status_codes() {
        // Every ServeError variant maps to a distinct non-OK status and
        // comes back as the same variant.
        let variants = [
            ServeError::TooWide {
                width: 500,
                largest: 384,
            },
            ServeError::EmptyRequest,
            ServeError::QueueFull { depth: 256 },
            ServeError::ShuttingDown,
            ServeError::DeadlineExceeded,
            ServeError::WorkerPanic,
            ServeError::Plan(PlanError("boom".into())),
            ServeError::Config("bad".into()),
        ];
        let mut seen = std::collections::BTreeSet::new();
        for e in &variants {
            let code = e.wire_status();
            assert_ne!(code, status::OK);
            assert_ne!(code, status::MALFORMED);
            assert!(seen.insert(code), "status {code} assigned twice");
            // Only the variant round-trips — field values are fabricated
            // (zeroed/empty) on the way back, per the from_wire_status doc.
            let back = ServeError::from_wire_status(code).expect("round-trip");
            assert_eq!(
                std::mem::discriminant(&back),
                std::mem::discriminant(e),
                "status {code} came back as a different variant"
            );
            assert_eq!(back.wire_status(), code);
        }
        // OK, MALFORMED and unknown codes do not decode to an error.
        assert_eq!(ServeError::from_wire_status(status::OK), None);
        assert_eq!(ServeError::from_wire_status(status::MALFORMED), None);
        assert_eq!(ServeError::from_wire_status(200), None);
        // And ServeError composes with anyhow at the net boundary.
        let any: anyhow::Error = ServeError::ShuttingDown.into();
        assert!(any.to_string().contains("shutting down"));
    }

    #[test]
    fn v1_frames_still_parse_with_no_deadline() {
        // A v1 client's frame: version byte 1, bytes 5..8 reserved-zero.
        let samples: Vec<f32> = (0..7).map(|i| i as f32 * 1.5).collect();
        let mut bytes = frame(&samples, 3);
        bytes[2] = 1;
        for chunk in [1, 5, bytes.len()] {
            let mut p = WireParser::new(1 << 20);
            let (h, payload, ended) = run(&mut p, &bytes, chunk);
            assert!(ended, "chunk {chunk}");
            assert_eq!(h.version, 1);
            assert_eq!(h.flags, 3);
            assert_eq!(h.deadline_ms, 0, "v1 carries no deadline");
            assert_eq!(payload, samples);
        }
        // Stale garbage in a v1 frame's reserved deadline bytes must be
        // ignored, not misread as a deadline.
        let mut dirty = frame(&samples, 0);
        dirty[2] = 1;
        dirty[6] = 0xff;
        dirty[7] = 0xff;
        let mut p = WireParser::new(1 << 20);
        let (h, _, _) = run(&mut p, &dirty, dirty.len());
        assert_eq!(h.deadline_ms, 0);
    }

    #[test]
    fn header_encoding_round_trips() {
        let h = encode_request_header(12345, 2);
        let mut p = WireParser::new(1 << 20);
        match p.pull(&h[..]) {
            Ok((REQ_HEADER_LEN, WireEvent::Header(got))) => {
                assert_eq!(got.width, 12345);
                assert_eq!(got.flags, 2);
                assert_eq!(got.version, WIRE_VERSION);
                assert_eq!(got.deadline_ms, 0);
            }
            other => panic!("unexpected {other:?}"),
        }
        let hd = encode_request_header_with_deadline(99, 0, 1500);
        let mut p = WireParser::new(1 << 20);
        match p.pull(&hd[..]) {
            Ok((REQ_HEADER_LEN, WireEvent::Header(got))) => {
                assert_eq!(got.width, 99);
                assert_eq!(got.deadline_ms, 1500);
            }
            other => panic!("unexpected {other:?}"),
        }
        let r = encode_response_header(status::BUSY, RESP_FLAG_STREAMED, 77);
        assert_eq!(
            parse_response_header(&r),
            (status::BUSY, RESP_FLAG_STREAMED, 77)
        );
    }
}
