//! Deterministic fault injection for the serve stack (DESIGN.md §7d).
//!
//! A [`FaultPlan`] is a scripted set of failures — "panic in worker `k`'s
//! forward pass on its `n`-th chunk", "delay rank 0 by 150 ms", "drop the
//! connection instead of answering request 2" — shared as an
//! `Arc<FaultPlan>` between the chaos test and the components it attacks
//! (engine, batcher worker, net handler). Each injection *site* keeps a
//! per-rank sequence counter, so a plan describes failures by position in
//! the deterministic execution order, and the test can assert afterwards
//! that the stack's recovery counters (`ServeMetrics::{worker_panics,
//! restarts, deadline_shed}`, `NetStats::handler_panics`) equal what was
//! injected — exactly, not approximately.
//!
//! The module is test-only: compiled under `cfg(any(test, feature =
//! "fault"))` so production builds carry no injection branches. The
//! `fault` feature exists for the integration chaos suite
//! (`tests/chaos_serve.rs`) and the fault-rate column of the
//! `serve_load` bench, which run against the release library.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use super::lock_unpoisoned;

/// Where a fault can fire.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum FaultSite {
    /// Inside [`super::InferenceEngine`] chunk execution — guarded by the
    /// worker's `catch_unwind`, so a `Panic` here exercises replica
    /// rebuild, not the supervisor.
    EngineForward,
    /// In the worker job prologue, *outside* the `catch_unwind` guard —
    /// a `Panic` here kills the rank thread for real and exercises the
    /// dispatcher's supervised restart path.
    WorkerJob,
    /// In the net handler while it holds the server lock — a `Panic`
    /// here poisons the lock and kills the handler thread, exercising
    /// poison recovery and handler cleanup.
    NetRespond,
}

/// What happens when an injection point fires.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultAction {
    /// Panic with a payload containing `"fault-injected"` (chaos tests
    /// filter the default panic hook on that marker).
    Panic,
    /// Sleep this long before continuing (slow worker / stalled engine).
    Delay(Duration),
    /// Return a deterministic engine error instead of computing.
    Error,
    /// Close the connection without answering (`NetRespond` only).
    DropConn,
}

#[derive(Debug)]
struct Point {
    site: FaultSite,
    /// `None` matches every rank.
    rank: Option<usize>,
    /// Fires on the `nth` visit (0-based) of `(site, rank)`.
    nth: u64,
    action: FaultAction,
}

/// A deterministic, seed-driven fault schedule. See the module docs.
#[derive(Debug, Default)]
pub struct FaultPlan {
    points: Vec<Point>,
    /// Seeded rate mode: fire `Panic` at `EngineForward` with this
    /// probability per visit, decided by a pure hash of
    /// `(seed, rank, seq)` — reproducible across runs and threads.
    seeded: Option<(u64, f64)>,
    /// Per-`(site, rank)` visit counters.
    seq: Mutex<BTreeMap<(FaultSite, usize), u64>>,
    fired_panics: AtomicU64,
    fired_delays: AtomicU64,
    fired_errors: AtomicU64,
    fired_drops: AtomicU64,
}

/// splitmix64 finalizer — a cheap, well-mixed pure hash.
fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

impl FaultPlan {
    pub fn new() -> FaultPlan {
        FaultPlan::default()
    }

    /// Seeded rate mode for the `serve_load` bench: each
    /// `EngineForward` visit panics with probability `rate`, decided
    /// deterministically from `seed` and the visit's `(rank, seq)`.
    pub fn seeded_forward_panics(seed: u64, rate: f64) -> FaultPlan {
        FaultPlan {
            seeded: Some((seed, rate.clamp(0.0, 1.0))),
            ..FaultPlan::default()
        }
    }

    fn point(mut self, site: FaultSite, rank: Option<usize>, nth: u64, action: FaultAction) -> Self {
        self.points.push(Point {
            site,
            rank,
            nth,
            action,
        });
        self
    }

    /// Panic inside rank `rank`'s engine on its `nth` forward chunk
    /// (caught by the worker; the replica is rebuilt).
    pub fn panic_in_forward(self, rank: usize, nth: u64) -> Self {
        self.point(FaultSite::EngineForward, Some(rank), nth, FaultAction::Panic)
    }

    /// Delay rank `rank`'s `nth` forward chunk by `d` (slow worker).
    pub fn delay_forward(self, rank: usize, nth: u64, d: Duration) -> Self {
        self.point(FaultSite::EngineForward, Some(rank), nth, FaultAction::Delay(d))
    }

    /// Make rank `rank`'s `nth` forward chunk fail with an engine error.
    pub fn error_forward(self, rank: usize, nth: u64) -> Self {
        self.point(FaultSite::EngineForward, Some(rank), nth, FaultAction::Error)
    }

    /// Kill rank `rank`'s worker thread on its `nth` job (panics outside
    /// the worker's `catch_unwind`; the supervisor must respawn).
    pub fn kill_worker(self, rank: usize, nth: u64) -> Self {
        self.point(FaultSite::WorkerJob, Some(rank), nth, FaultAction::Panic)
    }

    /// Panic the `nth` net-handler response while it holds the server
    /// lock (poisons it; rank is ignored at this site).
    pub fn panic_handler(self, nth: u64) -> Self {
        self.point(FaultSite::NetRespond, None, nth, FaultAction::Panic)
    }

    /// Drop the connection instead of answering the `nth` response.
    pub fn drop_conn(self, nth: u64) -> Self {
        self.point(FaultSite::NetRespond, None, nth, FaultAction::DropConn)
    }

    /// Consult the plan at an injection point. Increments the
    /// `(site, rank)` visit counter and returns the scheduled action,
    /// if any. Sites with no rank identity pass `rank = 0`.
    pub fn check(&self, site: FaultSite, rank: usize) -> Option<FaultAction> {
        let n = {
            let mut seq = lock_unpoisoned(&self.seq);
            let c = seq.entry((site, rank)).or_insert(0);
            let n = *c;
            *c += 1;
            n
        };
        let action = self
            .points
            .iter()
            .find(|p| p.site == site && p.rank.is_none_or(|r| r == rank) && p.nth == n)
            .map(|p| p.action)
            .or_else(|| {
                let (seed, rate) = self.seeded?;
                if site != FaultSite::EngineForward {
                    return None;
                }
                let h = mix64(seed ^ mix64(((rank as u64) << 32) | n));
                // Top 53 bits → uniform in [0, 1).
                let u = (h >> 11) as f64 / (1u64 << 53) as f64;
                (u < rate).then_some(FaultAction::Panic)
            });
        match action {
            Some(FaultAction::Panic) => self.fired_panics.fetch_add(1, Ordering::SeqCst),
            Some(FaultAction::Delay(_)) => self.fired_delays.fetch_add(1, Ordering::SeqCst),
            Some(FaultAction::Error) => self.fired_errors.fetch_add(1, Ordering::SeqCst),
            Some(FaultAction::DropConn) => self.fired_drops.fetch_add(1, Ordering::SeqCst),
            None => 0,
        };
        action
    }

    /// How many `Panic` actions have fired so far.
    pub fn panics_fired(&self) -> u64 {
        self.fired_panics.load(Ordering::SeqCst)
    }

    /// How many `Delay` actions have fired so far.
    pub fn delays_fired(&self) -> u64 {
        self.fired_delays.load(Ordering::SeqCst)
    }

    /// How many `Error` actions have fired so far.
    pub fn errors_fired(&self) -> u64 {
        self.fired_errors.load(Ordering::SeqCst)
    }

    /// How many `DropConn` actions have fired so far.
    pub fn drops_fired(&self) -> u64 {
        self.fired_drops.load(Ordering::SeqCst)
    }
}

/// Install (once, process-wide) a panic hook that suppresses the default
/// backtrace noise for deliberately injected panics — payloads containing
/// `"fault-injected"` — and defers to the previous hook for everything
/// else. Chaos tests call this so a green run's output isn't a wall of
/// expected panic reports; a *real* panic still prints normally.
pub fn silence_fault_panics() {
    use std::sync::Once;
    static ONCE: Once = Once::new();
    ONCE.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let msg = info
                .payload()
                .downcast_ref::<String>()
                .map(String::as_str)
                .or_else(|| info.payload().downcast_ref::<&str>().copied())
                .unwrap_or("");
            if !msg.contains("fault-injected") {
                prev(info);
            }
        }));
    });
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]

    use super::*;

    #[test]
    fn points_fire_on_their_exact_visit_and_count() {
        let plan = FaultPlan::new()
            .panic_in_forward(1, 2)
            .delay_forward(0, 0, Duration::from_millis(5))
            .kill_worker(1, 0);
        // Rank 0 forward: delay on visit 0, nothing after.
        assert_eq!(
            plan.check(FaultSite::EngineForward, 0),
            Some(FaultAction::Delay(Duration::from_millis(5)))
        );
        assert_eq!(plan.check(FaultSite::EngineForward, 0), None);
        // Rank 1 forward: visits 0 and 1 clean, 2 panics — its counter
        // is independent of rank 0's.
        assert_eq!(plan.check(FaultSite::EngineForward, 1), None);
        assert_eq!(plan.check(FaultSite::EngineForward, 1), None);
        assert_eq!(
            plan.check(FaultSite::EngineForward, 1),
            Some(FaultAction::Panic)
        );
        // WorkerJob counts separately from EngineForward.
        assert_eq!(
            plan.check(FaultSite::WorkerJob, 1),
            Some(FaultAction::Panic)
        );
        assert_eq!(plan.panics_fired(), 2);
        assert_eq!(plan.delays_fired(), 1);
        assert_eq!(plan.errors_fired(), 0);
    }

    #[test]
    fn rankless_sites_match_any_rank() {
        let plan = FaultPlan::new().drop_conn(1).panic_handler(2);
        assert_eq!(plan.check(FaultSite::NetRespond, 0), None);
        assert_eq!(
            plan.check(FaultSite::NetRespond, 0),
            Some(FaultAction::DropConn)
        );
        assert_eq!(
            plan.check(FaultSite::NetRespond, 0),
            Some(FaultAction::Panic)
        );
        assert_eq!(plan.drops_fired(), 1);
    }

    #[test]
    fn seeded_rate_is_deterministic_and_roughly_calibrated() {
        let a = FaultPlan::seeded_forward_panics(7, 0.05);
        let b = FaultPlan::seeded_forward_panics(7, 0.05);
        let fire_a: Vec<bool> = (0..2000)
            .map(|_| a.check(FaultSite::EngineForward, 0).is_some())
            .collect();
        let fire_b: Vec<bool> = (0..2000)
            .map(|_| b.check(FaultSite::EngineForward, 0).is_some())
            .collect();
        assert_eq!(fire_a, fire_b, "same seed must fire identically");
        let hits = fire_a.iter().filter(|&&f| f).count();
        assert!(
            (50..=150).contains(&hits),
            "5% rate over 2000 visits fired {hits} times"
        );
        // Other sites are untouched by rate mode.
        assert_eq!(a.check(FaultSite::WorkerJob, 0), None);
    }
}
