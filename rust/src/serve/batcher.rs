//! The dynamic batcher — request-oriented serving over the bucket-pinned
//! engines (DESIGN.md §7).
//!
//! Topology: callers [`Server::submit`] single requests; a **dispatcher
//! thread** groups them by width bucket and flushes a group to a worker
//! the moment it reaches `max_batch` *or* its oldest request has waited
//! one batching `window`; a pool of long-lived **worker threads** (the
//! [`PersistentPool`] pattern from distributed training — spawn once,
//! channel jobs forever) each owns a private [`InferenceEngine`] whose
//! plan cache was warmed at startup. Admission control is a bounded
//! in-flight budget: once `queue_depth` requests are queued or
//! executing, further submits fail fast with
//! [`ServeError::QueueFull`] instead of growing an unbounded queue —
//! callers see backpressure, latency stays bounded.
//!
//! Telemetry: every completed request records its end-to-end latency
//! (enqueue → response) in a global and a per-bucket
//! [`LatencyHistogram`]; batches record their occupancy so an
//! over-generous window or an over-wide bucket grid shows up as
//! underfilled batches, not just as mysterious latency.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::dist::PersistentPool;
use crate::metrics::LatencyHistogram;
use crate::model::NetConfig;

use super::bucket::round_up_to_block;
use super::engine::{EngineOpts, InferOutput, InferenceEngine};
use super::stream::StreamingSession;
use super::ServeError;

/// Server options: the engine slice plus the batching/queueing policy.
#[derive(Debug, Clone)]
pub struct BatcherOpts {
    /// Per-worker engine options (buckets, max_batch, precision, …).
    pub engine: EngineOpts,
    /// Batching window: a non-full group is flushed once its oldest
    /// request has waited this long. The window bounds the latency cost
    /// of batching: worst-case added latency = one window.
    pub window: Duration,
    /// Admission budget: maximum requests queued or executing at once.
    pub queue_depth: usize,
    /// Worker threads, each owning a private engine + plan cache.
    pub workers: usize,
    /// Warm every worker's plan cache for every bucket before accepting
    /// traffic (startup cost instead of first-request latency).
    pub warm: bool,
    /// Streaming window for requests wider than every bucket: `Some(w)`
    /// routes them through a halo-overlapped [`StreamingSession`] at
    /// window `w` (rounded up to the block grid; must fit the largest
    /// bucket and exceed twice the receptive-field reach), `None`
    /// rejects them with [`ServeError::TooWide`].
    pub stream_window: Option<usize>,
}

impl Default for BatcherOpts {
    fn default() -> Self {
        BatcherOpts {
            engine: EngineOpts::default(),
            window: Duration::from_millis(2),
            queue_depth: 256,
            workers: 1,
            warm: true,
            stream_window: None,
        }
    }
}

/// One completed request.
#[derive(Debug, Clone)]
pub struct Response {
    /// The two model heads, truncated to the request width.
    pub output: InferOutput,
    /// End-to-end latency (submit → response), seconds.
    pub latency_secs: f64,
    /// Width bucket the request executed in (for a streamed request:
    /// the streaming window width).
    pub bucket: usize,
    /// How many real requests shared the batch (1..=max_batch; always 1
    /// for a streamed request).
    pub batch_rows: usize,
    /// Whether the request took the halo-overlapped streaming route.
    pub streamed: bool,
}

/// A claim on a submitted request's response.
pub struct Ticket {
    rx: Receiver<Result<Response, ServeError>>,
}

impl Ticket {
    /// Block until the response arrives (or the server drops the
    /// request during shutdown).
    pub fn wait(self) -> Result<Response, ServeError> {
        match self.rx.recv() {
            Ok(r) => r,
            Err(_) => Err(ServeError::ShuttingDown),
        }
    }
}

/// Aggregated serving telemetry (cloneable snapshot).
#[derive(Debug, Clone)]
pub struct ServeMetrics {
    /// End-to-end latency across every completed request.
    pub latency: LatencyHistogram,
    /// Per-bucket request counts and latency.
    pub per_bucket: BTreeMap<usize, BucketMetrics>,
    /// Requests completed successfully.
    pub completed: u64,
    /// Requests refused at admission (queue full).
    pub rejected: u64,
    /// Requests that failed inside the engine (plan errors).
    pub failed: u64,
    /// Batches executed.
    pub batches: u64,
    /// Sum of real rows over all batches (occupancy numerator).
    pub batch_rows: u64,
    /// Requests that took the streaming route (these count in
    /// `completed` and the global latency histogram but not in the
    /// per-bucket/batch occupancy numbers — a stream is not a batch).
    pub streamed: u64,
    /// Halo-overlapped windows executed across all streamed requests.
    pub stream_windows: u64,
    started: Instant,
    /// Set when this value became a snapshot ([`Server::metrics`] /
    /// [`Server::shutdown`]): freezes `elapsed_secs`, so a stored
    /// snapshot's throughput doesn't decay with wall-clock time.
    frozen_at: Option<Instant>,
}

/// Per-bucket slice of the serving telemetry.
#[derive(Debug, Clone, Default)]
pub struct BucketMetrics {
    pub requests: u64,
    pub batches: u64,
    pub latency: LatencyHistogram,
}

impl ServeMetrics {
    fn new() -> ServeMetrics {
        ServeMetrics {
            latency: LatencyHistogram::new(),
            per_bucket: BTreeMap::new(),
            completed: 0,
            rejected: 0,
            failed: 0,
            batches: 0,
            batch_rows: 0,
            streamed: 0,
            stream_windows: 0,
            started: Instant::now(),
            frozen_at: None,
        }
    }

    /// Serving seconds covered by this value: up to now for the live
    /// struct, up to snapshot time for a snapshot.
    pub fn elapsed_secs(&self) -> f64 {
        self.frozen_at
            .unwrap_or_else(Instant::now)
            .duration_since(self.started)
            .as_secs_f64()
    }

    /// Completed sequences per second of server uptime.
    pub fn seq_per_sec(&self) -> f64 {
        self.completed as f64 / self.elapsed_secs().max(1e-9)
    }

    /// Mean real rows per executed batch (how full batches ran).
    pub fn mean_batch_occupancy(&self) -> f64 {
        self.batch_rows as f64 / self.batches.max(1) as f64
    }
}

/// One enqueued request travelling dispatcher → worker.
struct Pending {
    data: Vec<f32>,
    /// Execution width: the bucket, or the streaming window when
    /// `stream` is set.
    bucket: usize,
    stream: bool,
    enqueued: Instant,
    reply: Sender<Result<Response, ServeError>>,
}

/// A worker thread's owned state: private engine + shared telemetry.
struct Worker {
    engine: InferenceEngine,
    stream_window: Option<usize>,
    metrics: Arc<Mutex<ServeMetrics>>,
    inflight: Arc<AtomicUsize>,
}

impl Worker {
    /// Execute one same-bucket batch and deliver every response.
    /// Streamed requests arrive as singleton groups and divert to
    /// [`Self::run_stream`].
    fn run_batch(&mut self, mut batch: Vec<Pending>) {
        if batch.len() == 1 && batch[0].stream {
            let p = batch.pop().expect("len checked");
            return self.run_stream(p);
        }
        let bucket = batch[0].bucket;
        debug_assert!(batch.iter().all(|p| p.bucket == bucket));
        let refs: Vec<&[f32]> = batch.iter().map(|p| p.data.as_slice()).collect();
        let result = self.engine.infer_batch(&refs);
        let rows = batch.len();
        let done = Instant::now();
        let mut m = self.metrics.lock().unwrap();
        match result {
            Ok(outputs) => {
                m.batches += 1;
                m.batch_rows += rows as u64;
                let pb = m.per_bucket.entry(bucket).or_default();
                pb.batches += 1;
                for (p, output) in batch.into_iter().zip(outputs) {
                    let latency_secs = done.duration_since(p.enqueued).as_secs_f64();
                    m.latency.record(latency_secs);
                    m.completed += 1;
                    let pb = m.per_bucket.entry(bucket).or_default();
                    pb.requests += 1;
                    pb.latency.record(latency_secs);
                    // Free the admission slot *before* delivering the
                    // reply: a caller that wait()s and immediately
                    // resubmits must never see QueueFull for capacity
                    // its own completed request still holds.
                    self.inflight.fetch_sub(1, Ordering::SeqCst);
                    let _ = p.reply.send(Ok(Response {
                        output,
                        latency_secs,
                        bucket,
                        batch_rows: rows,
                        streamed: false,
                    }));
                }
            }
            Err(e) => {
                // Requests are bucket-validated at submit, so this is a
                // plan-level failure; every caller learns why.
                m.failed += rows as u64;
                for p in batch {
                    self.inflight.fetch_sub(1, Ordering::SeqCst);
                    let _ = p.reply.send(Err(e.clone()));
                }
            }
        }
    }

    /// Stream one over-wide request through halo-overlapped windows and
    /// deliver the stitched (bit-identical) whole-sequence output.
    fn run_stream(&mut self, p: Pending) {
        let window = self
            .stream_window
            .expect("stream requests exist only when a window is configured");
        let mut denoised = Vec::with_capacity(p.data.len());
        let mut logits = Vec::with_capacity(p.data.len());
        let result = StreamingSession::new(&mut self.engine, window).and_then(|mut s| {
            s.infer_with(&p.data, |_, d, l| {
                denoised.extend_from_slice(d);
                logits.extend_from_slice(l);
            })
        });
        let done = Instant::now();
        let mut m = self.metrics.lock().unwrap();
        match result {
            Ok(stats) => {
                let latency_secs = done.duration_since(p.enqueued).as_secs_f64();
                m.latency.record(latency_secs);
                m.completed += 1;
                m.streamed += 1;
                m.stream_windows += stats.windows as u64;
                self.inflight.fetch_sub(1, Ordering::SeqCst);
                let _ = p.reply.send(Ok(Response {
                    output: InferOutput { denoised, logits },
                    latency_secs,
                    bucket: window,
                    batch_rows: 1,
                    streamed: true,
                }));
            }
            Err(e) => {
                m.failed += 1;
                self.inflight.fetch_sub(1, Ordering::SeqCst);
                let _ = p.reply.send(Err(e));
            }
        }
    }
}

/// A pending same-bucket group accumulating toward a flush.
struct Group {
    reqs: Vec<Pending>,
    oldest: Instant,
}

/// The serving front end: dynamic batching over a warmed worker pool.
pub struct Server {
    tx: Option<Sender<Pending>>,
    inflight: Arc<AtomicUsize>,
    queue_depth: usize,
    engine_opts: EngineOpts,
    /// Block-aligned streaming window, when the streaming route is on.
    stream_window: Option<usize>,
    metrics: Arc<Mutex<ServeMetrics>>,
    dispatcher: Option<JoinHandle<()>>,
}

impl Server {
    /// Build the workers (warming each plan cache when `opts.warm`),
    /// spawn the dispatcher and start accepting traffic.
    pub fn start(
        net_cfg: NetConfig,
        params: &[f32],
        opts: BatcherOpts,
    ) -> Result<Server, ServeError> {
        if opts.workers == 0 {
            return Err(ServeError::Config("workers must be at least 1".into()));
        }
        if opts.queue_depth == 0 {
            return Err(ServeError::Config("queue_depth must be at least 1".into()));
        }
        if opts.window.is_zero() {
            return Err(ServeError::Config(
                "batching window must be positive".into(),
            ));
        }
        // Validate the streaming geometry once, up front, against the
        // same rules StreamingSession enforces per construction.
        let stream_window = match opts.stream_window {
            None => None,
            Some(0) => {
                return Err(ServeError::Config(
                    "stream window must be positive".into(),
                ))
            }
            Some(w) => {
                let w = round_up_to_block(w);
                let largest = opts.engine.buckets.largest();
                if w > largest {
                    return Err(ServeError::Config(format!(
                        "stream window {w} exceeds the largest bucket ({largest})"
                    )));
                }
                // Snap to the bucket the session will execute in, so the
                // server's window metadata matches the actual plan.
                let w = opts
                    .engine
                    .buckets
                    .bucket_for(w)
                    .expect("window fits the largest bucket");
                let halo = net_cfg.receptive_field_reach();
                if w <= 2 * halo {
                    return Err(ServeError::Config(format!(
                        "stream window {w} must exceed twice the receptive-field \
                         reach (2 x {halo})"
                    )));
                }
                Some(w)
            }
        };
        let metrics = Arc::new(Mutex::new(ServeMetrics::new()));
        let inflight = Arc::new(AtomicUsize::new(0));
        let mut workers = Vec::with_capacity(opts.workers);
        for _ in 0..opts.workers {
            let mut engine = InferenceEngine::new(net_cfg, params, opts.engine.clone())?;
            if opts.warm {
                engine.warm()?;
            }
            workers.push(Worker {
                engine,
                stream_window,
                metrics: Arc::clone(&metrics),
                inflight: Arc::clone(&inflight),
            });
        }
        let (tx, rx) = channel::<Pending>();
        let max_batch = opts.engine.max_batch;
        let window = opts.window;
        let n_workers = opts.workers;
        // Serving starts now — warming must not count against uptime
        // throughput (seq_per_sec), so re-stamp after the builds above.
        metrics.lock().unwrap().started = Instant::now();
        let dispatcher = std::thread::spawn(move || {
            let pool = PersistentPool::new(workers);
            dispatch_loop(rx, &pool, max_batch, window, n_workers);
            // Drain: every queued job runs before the pool's Stop
            // message, so dropping the pool here completes all work.
            pool.sync();
        });
        Ok(Server {
            tx: Some(tx),
            inflight,
            queue_depth: opts.queue_depth,
            engine_opts: opts.engine,
            stream_window,
            metrics,
            dispatcher: Some(dispatcher),
        })
    }

    /// Submit one request (its length is its width). Fails fast with
    /// [`ServeError::QueueFull`] when the admission budget is exhausted,
    /// both before any queueing. Requests wider than every bucket take
    /// the halo-overlapped streaming route when a
    /// [`BatcherOpts::stream_window`] is configured, and fail with
    /// [`ServeError::TooWide`] otherwise.
    pub fn submit(&self, data: Vec<f32>) -> Result<Ticket, ServeError> {
        if data.is_empty() {
            return Err(ServeError::EmptyRequest);
        }
        let (bucket, stream) = match self.engine_opts.buckets.bucket_for(data.len()) {
            Some(b) => (b, false),
            None => match self.stream_window {
                Some(w) => (w, true),
                None => {
                    return Err(ServeError::TooWide {
                        width: data.len(),
                        largest: self.engine_opts.buckets.largest(),
                    })
                }
            },
        };
        // Admission: reserve an in-flight slot or reject.
        let mut cur = self.inflight.load(Ordering::SeqCst);
        loop {
            if cur >= self.queue_depth {
                self.metrics.lock().unwrap().rejected += 1;
                return Err(ServeError::QueueFull {
                    depth: self.queue_depth,
                });
            }
            match self.inflight.compare_exchange(
                cur,
                cur + 1,
                Ordering::SeqCst,
                Ordering::SeqCst,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
        let (reply, rx) = channel();
        let pending = Pending {
            data,
            bucket,
            stream,
            enqueued: Instant::now(),
            reply,
        };
        let sent = self.tx.as_ref().is_some_and(|tx| tx.send(pending).is_ok());
        if !sent {
            self.inflight.fetch_sub(1, Ordering::SeqCst);
            return Err(ServeError::ShuttingDown);
        }
        Ok(Ticket { rx })
    }

    /// Requests currently queued or executing.
    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::SeqCst)
    }

    /// Snapshot of the serving telemetry (elapsed time frozen at the
    /// moment of the snapshot).
    pub fn metrics(&self) -> ServeMetrics {
        let mut m = self.metrics.lock().unwrap().clone();
        m.frozen_at = Some(Instant::now());
        m
    }

    /// Stop accepting requests, drain everything in flight, join the
    /// dispatcher and workers, and return the final telemetry (elapsed
    /// time frozen at shutdown).
    pub fn shutdown(mut self) -> ServeMetrics {
        self.stop();
        let mut m = self.metrics.lock().unwrap().clone();
        m.frozen_at = Some(Instant::now());
        m
    }

    fn stop(&mut self) {
        self.tx.take(); // dispatcher's recv() disconnects → drain + exit
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Dispatcher: accumulate per-bucket groups, flush on full or window
/// expiry, round-robin flushed batches across the worker ranks.
fn dispatch_loop(
    rx: Receiver<Pending>,
    pool: &PersistentPool<Worker>,
    max_batch: usize,
    window: Duration,
    n_workers: usize,
) {
    let mut pending: BTreeMap<usize, Group> = BTreeMap::new();
    let mut next_rank = 0usize;
    let mut flush = |group: Group, next_rank: &mut usize| {
        let rank = *next_rank % n_workers;
        *next_rank += 1;
        pool.exec(rank, move |w| w.run_batch(group.reqs));
    };
    loop {
        if pending.is_empty() {
            // Nothing waiting: block until traffic or shutdown.
            match rx.recv() {
                Ok(p) => enqueue(&mut pending, p, max_batch, &mut flush, &mut next_rank),
                Err(_) => break,
            }
            continue;
        }
        // Sleep at most until the oldest group's window expires.
        let deadline = pending
            .values()
            .map(|g| g.oldest + window)
            .min()
            .expect("pending is non-empty");
        let now = Instant::now();
        if deadline <= now {
            flush_expired(&mut pending, window, &mut flush, &mut next_rank);
            continue;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(p) => enqueue(&mut pending, p, max_batch, &mut flush, &mut next_rank),
            Err(RecvTimeoutError::Timeout) => {
                flush_expired(&mut pending, window, &mut flush, &mut next_rank)
            }
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    // Shutdown: flush whatever is still pending.
    for (_, group) in std::mem::take(&mut pending) {
        flush(group, &mut next_rank);
    }
}

/// Add one request to its bucket group; flush the group if it is full.
/// Streamed requests never batch (each owns a worker for many windows),
/// so they flush immediately as singleton groups.
fn enqueue(
    pending: &mut BTreeMap<usize, Group>,
    p: Pending,
    max_batch: usize,
    flush: &mut impl FnMut(Group, &mut usize),
    next_rank: &mut usize,
) {
    if p.stream {
        let oldest = p.enqueued;
        flush(
            Group {
                reqs: vec![p],
                oldest,
            },
            next_rank,
        );
        return;
    }
    // Flushed groups are removed outright, so a resident group is never
    // empty — `oldest` is always the first (oldest) request's enqueue time.
    let group = pending.entry(p.bucket).or_insert_with(|| Group {
        reqs: Vec::with_capacity(max_batch),
        oldest: p.enqueued,
    });
    let bucket = p.bucket;
    group.reqs.push(p);
    if group.reqs.len() >= max_batch {
        let group = pending.remove(&bucket).expect("group just filled");
        flush(group, next_rank);
    }
}

/// Flush every group whose oldest request has aged past the window.
fn flush_expired(
    pending: &mut BTreeMap<usize, Group>,
    window: Duration,
    flush: &mut impl FnMut(Group, &mut usize),
    next_rank: &mut usize,
) {
    let now = Instant::now();
    let expired: Vec<usize> = pending
        .iter()
        .filter(|(_, g)| g.oldest + window <= now)
        .map(|(&b, _)| b)
        .collect();
    for b in expired {
        let group = pending.remove(&b).expect("listed as expired");
        flush(group, next_rank);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::AtacWorksNet;
    use crate::serve::BucketSet;
    use crate::util::rng::Rng;

    fn tiny_server(queue_depth: usize, max_batch: usize, window: Duration) -> Server {
        let cfg = NetConfig::tiny();
        let params = AtacWorksNet::init(cfg, 5).pack_params();
        let opts = BatcherOpts {
            engine: EngineOpts {
                buckets: BucketSet::new(&[128, 256]).expect("widths"),
                max_batch,
                cache_capacity: 2,
                ..EngineOpts::default()
            },
            window,
            queue_depth,
            workers: 1,
            warm: true,
            stream_window: None,
        };
        Server::start(cfg, &params, opts).expect("server")
    }

    fn streaming_server(stream_window: Option<usize>) -> Server {
        let cfg = NetConfig::tiny(); // receptive-field reach 32
        let params = AtacWorksNet::init(cfg, 5).pack_params();
        let opts = BatcherOpts {
            engine: EngineOpts {
                buckets: BucketSet::new(&[128, 256]).expect("widths"),
                max_batch: 2,
                cache_capacity: 2,
                ..EngineOpts::default()
            },
            window: Duration::from_millis(1),
            queue_depth: 16,
            workers: 1,
            warm: false,
            stream_window,
        };
        Server::start(cfg, &params, opts).expect("server")
    }

    fn track(w: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..w).map(|_| rng.poisson(0.7) as f32).collect()
    }

    #[test]
    fn serves_requests_and_records_metrics() {
        let server = tiny_server(64, 4, Duration::from_millis(1));
        let tickets: Vec<Ticket> = (0..6)
            .map(|i| server.submit(track(100 + i * 20, i as u64)).expect("submit"))
            .collect();
        for t in tickets {
            let r = t.wait().expect("response");
            assert!(r.latency_secs >= 0.0);
            assert!(r.batch_rows >= 1 && r.batch_rows <= 4);
            assert!(r.bucket == 128 || r.bucket == 256);
        }
        let m = server.shutdown();
        assert_eq!(m.completed, 6);
        assert_eq!(m.rejected, 0);
        assert_eq!(m.failed, 0);
        assert_eq!(m.latency.count(), 6);
        assert!(m.batches >= 2, "two buckets cannot share a batch");
        assert!(m.mean_batch_occupancy() >= 1.0);
        let widths: Vec<usize> = m.per_bucket.keys().copied().collect();
        assert_eq!(widths, vec![128, 256]);
    }

    #[test]
    fn rejects_oversized_before_queueing() {
        let server = tiny_server(4, 2, Duration::from_millis(1));
        assert!(matches!(
            server.submit(track(300, 1)),
            Err(ServeError::TooWide {
                width: 300,
                largest: 256
            })
        ));
        assert!(matches!(
            server.submit(Vec::new()),
            Err(ServeError::EmptyRequest)
        ));
        assert_eq!(server.inflight(), 0);
    }

    #[test]
    fn backpressure_rejects_when_queue_is_full() {
        // A long window and a large max_batch park accepted requests in
        // the dispatcher, so the in-flight budget fills deterministically.
        let server = tiny_server(3, 64, Duration::from_millis(500));
        let mut accepted = Vec::new();
        let mut rejected = 0usize;
        for i in 0..8 {
            match server.submit(track(100, i)) {
                Ok(t) => accepted.push(t),
                Err(ServeError::QueueFull { depth }) => {
                    assert_eq!(depth, 3);
                    rejected += 1;
                }
                Err(e) => panic!("unexpected {e}"),
            }
        }
        assert_eq!(accepted.len(), 3);
        assert_eq!(rejected, 5);
        // Accepted requests still complete (window expiry flushes them).
        for t in accepted {
            t.wait().expect("accepted requests complete");
        }
        let m = server.shutdown();
        assert_eq!(m.completed, 3);
        assert_eq!(m.rejected, 5);
    }

    #[test]
    fn over_wide_requests_stream_when_a_window_is_configured() {
        let server = streaming_server(Some(100)); // rounds to 128
        let signal = track(700, 11); // > largest bucket (256)
        let r = server
            .submit(signal.clone())
            .expect("streams instead of TooWide")
            .wait()
            .expect("streamed response");
        assert!(r.streamed);
        assert_eq!(r.bucket, 128);
        assert_eq!(r.batch_rows, 1);
        assert_eq!(r.output.denoised.len(), 700);
        assert_eq!(r.output.logits.len(), 700);
        // Bit-identical to a direct StreamingSession over the same
        // engine geometry (which the stream tests tie to whole-sequence
        // evaluation).
        let cfg = NetConfig::tiny();
        let params = AtacWorksNet::init(cfg, 5).pack_params();
        let opts = EngineOpts {
            buckets: BucketSet::new(&[128, 256]).expect("widths"),
            max_batch: 2,
            cache_capacity: 2,
            ..EngineOpts::default()
        };
        let mut engine = InferenceEngine::new(cfg, &params, opts).expect("engine");
        let want = StreamingSession::new(&mut engine, 128)
            .expect("session")
            .infer(&signal)
            .expect("reference");
        assert_eq!(r.output, want);
        // In-bucket traffic still batches normally alongside streams.
        let small = server.submit(track(100, 12)).expect("submit");
        let rs = small.wait().expect("batched response");
        assert!(!rs.streamed);
        assert_eq!(rs.bucket, 128);
        let m = server.shutdown();
        assert_eq!(m.completed, 2);
        assert_eq!(m.streamed, 1);
        // 700 columns at window 128 / halo 32: spans 96 + 64·k + tail.
        assert!(m.stream_windows >= 7, "expected >= 7 windows for 700 cols");
    }

    #[test]
    fn streaming_stays_off_and_geometry_is_validated() {
        // Default-off: over-wide still rejects.
        let server = streaming_server(None);
        assert!(matches!(
            server.submit(track(700, 1)),
            Err(ServeError::TooWide {
                width: 700,
                largest: 256
            })
        ));
        drop(server);
        // A window that cannot hold two halos is a config error. (The
        // bucket snap means the window to test against is the bucket
        // itself: with a 128 bucket a 64 request would legally snap up.)
        let cfg = NetConfig::tiny();
        let params = AtacWorksNet::init(cfg, 5).pack_params();
        let opts = BatcherOpts {
            engine: EngineOpts {
                buckets: BucketSet::new(&[64]).expect("widths"),
                max_batch: 1,
                cache_capacity: 1,
                ..EngineOpts::default()
            },
            window: Duration::from_millis(1),
            queue_depth: 4,
            workers: 1,
            warm: false,
            stream_window: Some(64), // snapped window 64 <= 2 * 32
        };
        assert!(matches!(
            Server::start(cfg, &params, opts.clone()),
            Err(ServeError::Config(_))
        ));
        let over = BatcherOpts {
            stream_window: Some(512), // exceeds the largest bucket
            ..opts
        };
        assert!(matches!(
            Server::start(cfg, &params, over),
            Err(ServeError::Config(_))
        ));
    }

    #[test]
    fn shutdown_drains_streamed_and_batched_requests_together() {
        // Mixed in-flight work at shutdown: nothing accepted is lost.
        let server = streaming_server(Some(128));
        let stream_t = server.submit(track(600, 21)).expect("stream accepted");
        let batch_t = server.submit(track(90, 22)).expect("batch accepted");
        let m = server.shutdown();
        let rs = stream_t.wait().expect("streamed request drained");
        let rb = batch_t.wait().expect("batched request drained");
        assert!(rs.streamed && !rb.streamed);
        assert_eq!(rs.output.denoised.len(), 600);
        assert_eq!(rb.output.denoised.len(), 90);
        assert_eq!(m.completed, 2);
        assert_eq!(m.streamed, 1);
    }

    #[test]
    fn shutdown_drains_pending_requests() {
        // Submit then immediately shut down: the pending group must be
        // flushed, not dropped.
        let server = tiny_server(16, 8, Duration::from_secs(5));
        let t = server.submit(track(80, 9)).expect("submit");
        let m = server.shutdown();
        let r = t.wait().expect("drained on shutdown");
        assert_eq!(r.output.denoised.len(), 80);
        assert_eq!(m.completed, 1);
    }
}
