//! The dynamic batcher — request-oriented serving over the bucket-pinned
//! engines (DESIGN.md §7), supervised and deadline-aware (§7d).
//!
//! Topology: callers [`Server::submit`] single requests; a **dispatcher
//! thread** groups them by width bucket and flushes a group to a worker
//! the moment it reaches `max_batch` *or* its oldest request has waited
//! one batching `window`; a pool of long-lived **worker threads** (the
//! [`PersistentPool`] pattern from distributed training — spawn once,
//! channel jobs forever) each owns a private [`InferenceEngine`] whose
//! plan cache was warmed at startup. Admission control is a bounded
//! in-flight budget: once `queue_depth` requests are queued or
//! executing, further submits fail fast with
//! [`ServeError::QueueFull`] instead of growing an unbounded queue —
//! callers see backpressure, latency stays bounded.
//!
//! Fault model (DESIGN.md §7d): a worker's forward pass runs under
//! `catch_unwind`, and a panicking replica is rebuilt from the retained
//! parameters before the rank takes another batch — the affected
//! requests answer [`ServeError::WorkerPanic`], nothing else notices. A
//! panic that escapes the guard (or kills the rank thread outright) is
//! handled by the dispatcher's **supervisor**: dead ranks are respawned
//! with a fresh engine under a bounded restart budget with exponential
//! backoff, and a fully-retired pool degrades to fast
//! [`ServeError::WorkerPanic`] answers instead of wedging the queue.
//! Requests may carry a **deadline**; one that expires while queued is
//! shed with [`ServeError::DeadlineExceeded`] before any compute runs.
//!
//! Telemetry: every completed request records its end-to-end latency
//! (enqueue → response) in a global and a per-bucket
//! [`LatencyHistogram`]; batches record their occupancy so an
//! over-generous window or an over-wide bucket grid shows up as
//! underfilled batches, not just as mysterious latency. Recovery events
//! count in [`ServeMetrics::worker_panics`], [`ServeMetrics::restarts`]
//! and [`ServeMetrics::deadline_shed`].
//!
//! NUMA sharding (DESIGN.md §6b): with [`BatcherOpts::sockets`] > 1 the
//! worker ranks are spawned in socket groups — each rank's engine is
//! built **on its own thread** ([`PersistentPool::try_new_placed`]), so
//! replica state is first-touched by the socket that serves from it —
//! and the bucket vocabulary is sharded across sockets: every bucket
//! has a *home socket* (its index in the bucket list, modulo sockets),
//! and a flushed group goes to its home socket (round-robin within the
//! group) unless the home is dead or saturated, in which case it spills
//! to the least-loaded live socket. [`ServeMetrics::per_socket`]
//! accounts every batch as routed or spilled, so the policy is
//! observable. Sharding is a placement transform only: which socket
//! executes a batch can never change its bits (batch/bucket invariance,
//! DESIGN.md §7).

use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::dist::{Job, PersistentPool, Placement, Topology};
use crate::metrics::LatencyHistogram;
use crate::model::NetConfig;

use super::bucket::round_up_to_block;
use super::engine::{EngineOpts, InferOutput, InferenceEngine};
#[cfg(any(test, feature = "fault"))]
use super::fault::{FaultAction, FaultPlan, FaultSite};
use super::stream::StreamingSession;
use super::{lock_unpoisoned, ServeError};

/// First-restart backoff; doubles per consumed restart on the rank.
const RESTART_BACKOFF_BASE: Duration = Duration::from_millis(25);
/// Backoff ceiling — a crash-looping rank retries at most this slowly
/// until its restart budget runs out.
const RESTART_BACKOFF_CAP: Duration = Duration::from_secs(2);

/// Server options: the engine slice plus the batching/queueing policy.
#[derive(Debug, Clone)]
pub struct BatcherOpts {
    /// Per-worker engine options (buckets, max_batch, precision, …).
    pub engine: EngineOpts,
    /// Batching window: a non-full group is flushed once its oldest
    /// request has waited this long. The window bounds the latency cost
    /// of batching: worst-case added latency = one window.
    pub window: Duration,
    /// Admission budget: maximum requests queued or executing at once.
    pub queue_depth: usize,
    /// Worker threads, each owning a private engine + plan cache.
    pub workers: usize,
    /// Warm every worker's plan cache for every bucket before accepting
    /// traffic (startup cost instead of first-request latency).
    pub warm: bool,
    /// Streaming window for requests wider than every bucket: `Some(w)`
    /// routes them through a halo-overlapped [`StreamingSession`] at
    /// window `w` (rounded up to the block grid; must fit the largest
    /// bucket and exceed twice the receptive-field reach), `None`
    /// rejects them with [`ServeError::TooWide`].
    pub stream_window: Option<usize>,
    /// Default per-request deadline. A request still queued when its
    /// deadline passes is shed with [`ServeError::DeadlineExceeded`]
    /// before any compute runs; a request already executing completes.
    /// [`Server::submit_with_deadline`] overrides per request; `None`
    /// means no default deadline.
    pub deadline: Option<Duration>,
    /// Restart budget per worker rank: how many times the supervisor
    /// respawns a dead rank (exponential backoff between attempts)
    /// before retiring it. With every rank retired the server answers
    /// [`ServeError::WorkerPanic`] instead of wedging.
    pub max_restarts: usize,
    /// Socket groups the worker ranks are sharded into. `1` (default)
    /// is the flat pool; `0` detects the machine shape
    /// ([`Topology::detect`], `CONV1D_TOPOLOGY` override). Clamped to
    /// the worker count. See the module docs for the routing policy.
    pub sockets: usize,
    /// Deterministic fault-injection plan (chaos tests and the
    /// fault-rate bench column only; absent from production builds).
    #[cfg(any(test, feature = "fault"))]
    pub fault: Option<Arc<FaultPlan>>,
}

impl Default for BatcherOpts {
    fn default() -> Self {
        BatcherOpts {
            engine: EngineOpts::default(),
            window: Duration::from_millis(2),
            queue_depth: 256,
            workers: 1,
            warm: true,
            stream_window: None,
            deadline: None,
            max_restarts: 3,
            sockets: 1,
            #[cfg(any(test, feature = "fault"))]
            fault: None,
        }
    }
}

/// Builder-style setters so call sites (and [`crate::config::ServeConfig`])
/// state only what differs from [`Default`].
impl BatcherOpts {
    /// Replace the per-worker engine options.
    pub fn with_engine(mut self, engine: EngineOpts) -> Self {
        self.engine = engine;
        self
    }

    /// Batching window.
    pub fn with_window(mut self, window: Duration) -> Self {
        self.window = window;
        self
    }

    /// Admission budget (queued or executing requests).
    pub fn with_queue_depth(mut self, queue_depth: usize) -> Self {
        self.queue_depth = queue_depth;
        self
    }

    /// Worker threads.
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers;
        self
    }

    /// Warm every worker's plan cache before accepting traffic.
    pub fn with_warm(mut self, warm: bool) -> Self {
        self.warm = warm;
        self
    }

    /// Streaming window for over-wide requests (`None` rejects them).
    pub fn with_stream_window(mut self, stream_window: Option<usize>) -> Self {
        self.stream_window = stream_window;
        self
    }

    /// Default per-request deadline (`None` = no default).
    pub fn with_deadline(mut self, deadline: Option<Duration>) -> Self {
        self.deadline = deadline;
        self
    }

    /// Restart budget per worker rank.
    pub fn with_max_restarts(mut self, max_restarts: usize) -> Self {
        self.max_restarts = max_restarts;
        self
    }

    /// Socket groups for the worker pool (`0` = detect).
    pub fn with_sockets(mut self, sockets: usize) -> Self {
        self.sockets = sockets;
        self
    }
}

/// One completed request.
#[derive(Debug, Clone)]
pub struct Response {
    /// The two model heads, truncated to the request width.
    pub output: InferOutput,
    /// End-to-end latency (submit → response), seconds.
    pub latency_secs: f64,
    /// Width bucket the request executed in (for a streamed request:
    /// the streaming window width).
    pub bucket: usize,
    /// How many real requests shared the batch (1..=max_batch; always 1
    /// for a streamed request).
    pub batch_rows: usize,
    /// Whether the request took the halo-overlapped streaming route.
    pub streamed: bool,
}

/// A claim on a submitted request's response.
pub struct Ticket {
    rx: Receiver<Result<Response, ServeError>>,
}

impl Ticket {
    /// Block until the response arrives. Every admitted request is
    /// answered — even one caught on a dying worker comes back as
    /// [`ServeError::WorkerPanic`] (see [`Reply`]'s drop contract) — so
    /// the channel closing without a reply is a defensive fallback.
    pub fn wait(self) -> Result<Response, ServeError> {
        match self.rx.recv() {
            Ok(r) => r,
            Err(_) => Err(ServeError::ShuttingDown),
        }
    }
}

/// Aggregated serving telemetry (cloneable snapshot).
#[derive(Debug, Clone)]
pub struct ServeMetrics {
    /// End-to-end latency across every completed request.
    pub latency: LatencyHistogram,
    /// Per-bucket request counts and latency.
    pub per_bucket: BTreeMap<usize, BucketMetrics>,
    /// Requests completed successfully.
    pub completed: u64,
    /// Requests refused at admission (queue full).
    pub rejected: u64,
    /// Requests that failed inside the engine (plan errors, and rows
    /// answered `WorkerPanic` by a worker that caught its engine's
    /// unwind or by a fully-retired pool).
    pub failed: u64,
    /// Batches executed.
    pub batches: u64,
    /// Sum of real rows over all batches (occupancy numerator).
    pub batch_rows: u64,
    /// Requests that took the streaming route (these count in
    /// `completed` and the global latency histogram but not in the
    /// per-bucket/batch occupancy numbers — a stream is not a batch).
    pub streamed: u64,
    /// Halo-overlapped windows executed across all streamed requests.
    pub stream_windows: u64,
    /// Engine forward passes that panicked and were caught (each one
    /// rebuilt the rank's replica; the affected requests answered
    /// [`ServeError::WorkerPanic`]).
    pub worker_panics: u64,
    /// Dead worker ranks respawned by the supervisor.
    pub restarts: u64,
    /// Requests shed because their deadline expired while queued.
    pub deadline_shed: u64,
    /// Per-socket routing/occupancy counters (one entry per socket
    /// group; a single entry for the flat pool). Every dispatched batch
    /// counts exactly once, as routed or spilled, on the socket that
    /// executed it.
    pub per_socket: Vec<SocketMetrics>,
    started: Instant,
    /// Set when this value became a snapshot ([`Server::metrics`] /
    /// [`Server::shutdown`]): freezes `elapsed_secs`, so a stored
    /// snapshot's throughput doesn't decay with wall-clock time.
    frozen_at: Option<Instant>,
}

/// Per-bucket slice of the serving telemetry.
#[derive(Debug, Clone, Default)]
pub struct BucketMetrics {
    pub requests: u64,
    pub batches: u64,
    pub latency: LatencyHistogram,
}

/// Per-socket slice of the serving telemetry (NUMA sharding).
#[derive(Debug, Clone, Default)]
pub struct SocketMetrics {
    /// Batches this socket executed as their home socket.
    pub routed: u64,
    /// Batches this socket executed for another socket (its home was
    /// dead or saturated).
    pub spilled_in: u64,
    /// Batches homed here but executed elsewhere.
    pub spilled_out: u64,
    /// Request rows dispatched to this socket (routed + spilled-in).
    pub rows: u64,
    /// Highest number of batches in flight on this socket at once.
    pub peak_inflight: u64,
}

impl ServeMetrics {
    fn new(sockets: usize) -> ServeMetrics {
        ServeMetrics {
            latency: LatencyHistogram::new(),
            per_bucket: BTreeMap::new(),
            completed: 0,
            rejected: 0,
            failed: 0,
            batches: 0,
            batch_rows: 0,
            streamed: 0,
            stream_windows: 0,
            worker_panics: 0,
            restarts: 0,
            deadline_shed: 0,
            per_socket: vec![SocketMetrics::default(); sockets.max(1)],
            started: Instant::now(),
            frozen_at: None,
        }
    }

    /// Serving seconds covered by this value: up to now for the live
    /// struct, up to snapshot time for a snapshot.
    pub fn elapsed_secs(&self) -> f64 {
        self.frozen_at
            .unwrap_or_else(Instant::now)
            .duration_since(self.started)
            .as_secs_f64()
    }

    /// Completed sequences per second of server uptime.
    pub fn seq_per_sec(&self) -> f64 {
        self.completed as f64 / self.elapsed_secs().max(1e-9)
    }

    /// Mean real rows per executed batch (how full batches ran).
    pub fn mean_batch_occupancy(&self) -> f64 {
        self.batch_rows as f64 / self.batches.max(1) as f64
    }
}

/// RAII admission slot: decrements the in-flight budget exactly once —
/// explicitly via [`Self::release`] right before the reply is sent (so
/// a caller that `wait()`s and immediately resubmits never sees
/// `QueueFull` for capacity its own completed request still holds), or
/// on drop. The drop path is what keeps the budget honest under
/// faults: jobs queued on a rank that dies are dropped with the rank's
/// channel receiver, and without the guard their slots would leak
/// forever.
struct SlotGuard {
    inflight: Arc<AtomicUsize>,
    released: bool,
}

impl SlotGuard {
    fn new(inflight: Arc<AtomicUsize>) -> SlotGuard {
        SlotGuard {
            inflight,
            released: false,
        }
    }

    fn release(&mut self) {
        if !self.released {
            self.released = true;
            self.inflight.fetch_sub(1, Ordering::SeqCst);
        }
    }
}

impl Drop for SlotGuard {
    fn drop(&mut self) {
        self.release();
    }
}

/// Reply channel that answers [`ServeError::WorkerPanic`] if dropped
/// before any reply was sent. A request can only be dropped unreplied
/// by a dying worker (mid-unwind, or sitting in a dead rank's queue)
/// or by a fully-retired pool — every admitted request therefore gets
/// an answer, whatever happens to the thread holding it.
struct Reply(Option<Sender<Result<Response, ServeError>>>);

impl Reply {
    fn send(&mut self, r: Result<Response, ServeError>) {
        if let Some(tx) = self.0.take() {
            let _ = tx.send(r);
        }
    }
}

impl Drop for Reply {
    fn drop(&mut self) {
        if let Some(tx) = self.0.take() {
            let _ = tx.send(Err(ServeError::WorkerPanic));
        }
    }
}

/// One enqueued request travelling dispatcher → worker.
struct Pending {
    data: Vec<f32>,
    /// Execution width: the bucket, or the streaming window when
    /// `stream` is set.
    bucket: usize,
    stream: bool,
    /// Shed with [`ServeError::DeadlineExceeded`] if still queued past
    /// this instant.
    deadline: Option<Instant>,
    enqueued: Instant,
    reply: Reply,
    slot: SlotGuard,
}

impl Pending {
    fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| d <= now)
    }
}

/// A worker thread's owned state: private engine + shared telemetry,
/// plus everything needed to rebuild the engine after a caught panic.
struct Worker {
    rank: usize,
    engine: InferenceEngine,
    net_cfg: NetConfig,
    params: Arc<Vec<f32>>,
    warm: bool,
    stream_window: Option<usize>,
    metrics: Arc<Mutex<ServeMetrics>>,
    #[cfg(any(test, feature = "fault"))]
    fault: Option<Arc<FaultPlan>>,
}

impl Worker {
    /// Execute one same-bucket batch and deliver every response.
    /// Streamed requests arrive as singleton groups and divert to
    /// [`Self::run_stream`]. Expired deadlines are shed first — this is
    /// the last pre-compute checkpoint, catching requests whose
    /// deadline ran out while they waited in the batch window or behind
    /// a slow batch on this rank.
    fn run_batch(&mut self, batch: Vec<Pending>) {
        // Injection point `WorkerJob`: outside the catch_unwind guard
        // below, so a `Panic` here unwinds the rank thread for real and
        // exercises the supervisor (chaos tests only).
        #[cfg(any(test, feature = "fault"))]
        if let Some(plan) = &self.fault {
            if let Some(FaultAction::Panic) = plan.check(FaultSite::WorkerJob, self.rank) {
                panic!("fault-injected worker kill (rank {})", self.rank);
            }
        }
        let mut batch = self.shed_expired(batch);
        if batch.is_empty() {
            return;
        }
        if batch.len() == 1 && batch[0].stream {
            let p = batch.pop().expect("len checked");
            return self.run_stream(p);
        }
        let bucket = batch[0].bucket;
        debug_assert!(batch.iter().all(|p| p.bucket == bucket));
        let refs: Vec<&[f32]> = batch.iter().map(|p| p.data.as_slice()).collect();
        // The engine's internals are not unwind-safe in the type-system
        // sense (caches, staging buffers), which is fine: a panicked
        // replica is discarded and rebuilt below, never reused.
        let engine = &mut self.engine;
        let result = catch_unwind(AssertUnwindSafe(|| engine.infer_batch(&refs)));
        drop(refs);
        let rows = batch.len();
        let done = Instant::now();
        match result {
            Ok(Ok(outputs)) => {
                let mut m = lock_unpoisoned(&self.metrics);
                m.batches += 1;
                m.batch_rows += rows as u64;
                let pb = m.per_bucket.entry(bucket).or_default();
                pb.batches += 1;
                for (mut p, output) in batch.into_iter().zip(outputs) {
                    let latency_secs = done.duration_since(p.enqueued).as_secs_f64();
                    m.latency.record(latency_secs);
                    m.completed += 1;
                    let pb = m.per_bucket.entry(bucket).or_default();
                    pb.requests += 1;
                    pb.latency.record(latency_secs);
                    // Free the admission slot *before* delivering the
                    // reply: a caller that wait()s and immediately
                    // resubmits must never see QueueFull for capacity
                    // its own completed request still holds.
                    p.slot.release();
                    p.reply.send(Ok(Response {
                        output,
                        latency_secs,
                        bucket,
                        batch_rows: rows,
                        streamed: false,
                    }));
                }
            }
            Ok(Err(e)) => {
                // Requests are bucket-validated at submit, so this is a
                // plan-level failure; every caller learns why.
                let mut m = lock_unpoisoned(&self.metrics);
                m.failed += rows as u64;
                drop(m);
                for mut p in batch {
                    p.slot.release();
                    p.reply.send(Err(e.clone()));
                }
            }
            Err(_) => {
                let mut m = lock_unpoisoned(&self.metrics);
                m.worker_panics += 1;
                m.failed += rows as u64;
                drop(m);
                for mut p in batch {
                    p.slot.release();
                    p.reply.send(Err(ServeError::WorkerPanic));
                }
                self.rebuild_engine();
            }
        }
    }

    /// Shed every request whose deadline passed while it was queued —
    /// before any compute — and return the survivors. Shedding rows
    /// from a batch cannot change the survivors' bits: batch and bucket
    /// invariance (DESIGN.md §7) make every row independent of its
    /// neighbours.
    fn shed_expired(&self, batch: Vec<Pending>) -> Vec<Pending> {
        let now = Instant::now();
        let (expired, live): (Vec<Pending>, Vec<Pending>) =
            batch.into_iter().partition(|p| p.expired(now));
        if !expired.is_empty() {
            lock_unpoisoned(&self.metrics).deadline_shed += expired.len() as u64;
            for mut p in expired {
                p.slot.release();
                p.reply.send(Err(ServeError::DeadlineExceeded));
            }
        }
        live
    }

    /// Stream one over-wide request through halo-overlapped windows and
    /// deliver the stitched (bit-identical) whole-sequence output.
    fn run_stream(&mut self, mut p: Pending) {
        let window = self
            .stream_window
            .expect("stream requests exist only when a window is configured");
        let engine = &mut self.engine;
        let data = &p.data;
        let result = catch_unwind(AssertUnwindSafe(|| {
            let mut denoised = Vec::with_capacity(data.len());
            let mut logits = Vec::with_capacity(data.len());
            let stats = StreamingSession::new(engine, window).and_then(|mut s| {
                s.infer_with(data, |_, d, l| {
                    denoised.extend_from_slice(d);
                    logits.extend_from_slice(l);
                })
            })?;
            Ok::<_, ServeError>((stats, denoised, logits))
        }));
        let done = Instant::now();
        match result {
            Ok(Ok((stats, denoised, logits))) => {
                let mut m = lock_unpoisoned(&self.metrics);
                let latency_secs = done.duration_since(p.enqueued).as_secs_f64();
                m.latency.record(latency_secs);
                m.completed += 1;
                m.streamed += 1;
                m.stream_windows += stats.windows as u64;
                p.slot.release();
                p.reply.send(Ok(Response {
                    output: InferOutput { denoised, logits },
                    latency_secs,
                    bucket: window,
                    batch_rows: 1,
                    streamed: true,
                }));
            }
            Ok(Err(e)) => {
                lock_unpoisoned(&self.metrics).failed += 1;
                p.slot.release();
                p.reply.send(Err(e));
            }
            Err(_) => {
                let mut m = lock_unpoisoned(&self.metrics);
                m.worker_panics += 1;
                m.failed += 1;
                drop(m);
                p.slot.release();
                p.reply.send(Err(ServeError::WorkerPanic));
                self.rebuild_engine();
            }
        }
    }

    /// Replace a replica whose forward pass unwound: caches and staging
    /// buffers are in an unknown state after a panic, and the
    /// bit-identity contract forbids serving from one. A failed rebuild
    /// panics out of the job — the rank dies and the dispatcher's
    /// supervisor takes over (restart budget + backoff).
    fn rebuild_engine(&mut self) {
        let opts = self.engine.opts().clone();
        match InferenceEngine::new(self.net_cfg, &self.params, opts) {
            Ok(mut engine) => {
                if self.warm {
                    if let Err(e) = engine.warm() {
                        panic!("engine re-warm failed after a worker panic: {e}");
                    }
                }
                #[cfg(any(test, feature = "fault"))]
                if let Some(plan) = &self.fault {
                    engine.set_fault(Arc::clone(plan), self.rank);
                }
                self.engine = engine;
            }
            Err(e) => panic!("engine rebuild failed after a worker panic: {e}"),
        }
    }
}

/// A pending same-bucket group accumulating toward a flush.
struct Group {
    reqs: Vec<Pending>,
    oldest: Instant,
}

/// Per-rank supervision state (DESIGN.md §7d):
/// `Live → Backoff → Live` per consumed restart, `→ Retired` when the
/// budget runs out.
enum RankHealth {
    Live,
    /// Dead; eligible to respawn once `until` passes.
    Backoff { until: Instant },
    /// Restart budget exhausted: never dispatched to again.
    Retired,
}

/// Everything needed to build one rank's [`Worker`]: shared between the
/// placed pool spawner (which builds each engine **on the rank's own
/// thread**, so replica state is first-touched by the socket serving
/// from it) and the supervisor's respawn path.
#[derive(Clone)]
struct WorkerFactory {
    net_cfg: NetConfig,
    params: Arc<Vec<f32>>,
    engine_opts: EngineOpts,
    warm: bool,
    stream_window: Option<usize>,
    metrics: Arc<Mutex<ServeMetrics>>,
    #[cfg(any(test, feature = "fault"))]
    fault: Option<Arc<FaultPlan>>,
}

impl WorkerFactory {
    /// Build one rank's worker: fresh engine (warmed when configured)
    /// plus the rebuild ingredients it retains for panic recovery.
    fn build(&self, rank: usize) -> Result<Worker, ServeError> {
        let mut engine = InferenceEngine::new(self.net_cfg, &self.params, self.engine_opts.clone())?;
        if self.warm {
            engine.warm()?;
        }
        #[cfg(any(test, feature = "fault"))]
        if let Some(plan) = &self.fault {
            engine.set_fault(Arc::clone(plan), rank);
        }
        Ok(Worker {
            rank,
            engine,
            net_cfg: self.net_cfg,
            params: Arc::clone(&self.params),
            warm: self.warm,
            stream_window: self.stream_window,
            metrics: Arc::clone(&self.metrics),
            #[cfg(any(test, feature = "fault"))]
            fault: self.fault.clone(),
        })
    }
}

/// RAII in-flight counter for one socket's dispatch load: incremented
/// when a batch is offered to the socket, decremented when the job
/// finishes — or is dropped anywhere along the way (bounced dispatch,
/// dead rank's queue), so the spill policy never reads a leaked count.
struct LoadGuard {
    load: Arc<AtomicUsize>,
}

impl LoadGuard {
    /// Increment `load` and return the guard plus the new depth.
    fn acquire(load: &Arc<AtomicUsize>) -> (LoadGuard, usize) {
        let depth = load.fetch_add(1, Ordering::SeqCst) + 1;
        (
            LoadGuard {
                load: Arc::clone(load),
            },
            depth,
        )
    }
}

impl Drop for LoadGuard {
    fn drop(&mut self) {
        self.load.fetch_sub(1, Ordering::SeqCst);
    }
}

/// The dispatcher's supervisor: rank health and restart budgets, plus
/// the socket-sharded routing state (home-socket map, per-socket
/// round-robin cursors and in-flight load).
struct Supervisor {
    factory: WorkerFactory,
    max_restarts: usize,
    metrics: Arc<Mutex<ServeMetrics>>,
    /// Rank → socket layout of the worker pool.
    placement: Placement,
    health: Vec<RankHealth>,
    /// Restarts consumed per rank.
    used: Vec<usize>,
    /// Per-socket round-robin cursor.
    cursors: Vec<usize>,
    /// Per-socket batches in flight (shared with the job closures).
    load: Vec<Arc<AtomicUsize>>,
}

impl Supervisor {
    /// Exponential backoff before the rank's next restart:
    /// `base · 2^used`, capped.
    fn backoff(&self, rank: usize) -> Duration {
        let exp = self.used[rank].min(16) as u32;
        RESTART_BACKOFF_BASE
            .saturating_mul(1u32 << exp)
            .min(RESTART_BACKOFF_CAP)
    }

    /// A dispatch to `rank` bounced — its thread is dead. Start (or
    /// keep) its backoff clock, or retire it if the budget is spent.
    fn note_death(&mut self, rank: usize) {
        if matches!(self.health[rank], RankHealth::Live) {
            self.health[rank] = if self.used[rank] >= self.max_restarts {
                RankHealth::Retired
            } else {
                RankHealth::Backoff {
                    until: Instant::now() + self.backoff(rank),
                }
            };
        }
    }

    /// Respawn `rank` with a fresh worker. On build failure the rank is
    /// retired outright: the parameters and geometry are unchanged, so
    /// a failed build would fail identically on every retry. (The
    /// respawned replica is built on this thread, not the rank's — the
    /// first-touch exception documented on [`PersistentPool::respawn`].)
    fn respawn(&mut self, pool: &mut PersistentPool<Worker>, rank: usize) {
        match self.factory.build(rank) {
            Ok(w) => {
                pool.respawn(rank, w);
                self.used[rank] += 1;
                self.health[rank] = RankHealth::Live;
                lock_unpoisoned(&self.metrics).restarts += 1;
            }
            Err(_) => self.health[rank] = RankHealth::Retired,
        }
    }

    /// Live ranks in socket `s`'s group.
    fn live_ranks_on(&self, s: usize) -> usize {
        self.placement
            .ranks_of(s)
            .filter(|&r| matches!(self.health[r], RankHealth::Live))
            .count()
    }

    /// The socket owning `bucket`: its index in the bucket vocabulary,
    /// modulo sockets. A streamed request's execution width is snapped
    /// to a real bucket at startup, so it shards like any other.
    fn home_socket(&self, bucket: usize) -> usize {
        let idx = self
            .factory
            .engine_opts
            .buckets
            .widths()
            .iter()
            .position(|&w| w == bucket)
            .unwrap_or(0);
        idx % self.placement.n_sockets()
    }

    /// Target socket for a group homed on `home`: the home socket,
    /// unless it has no live rank or is saturated (≥ 2 batches in
    /// flight per live rank) — then the least-loaded live socket
    /// (ties → lowest id), provided it is actually less loaded. `None`
    /// when no socket has a live rank.
    fn choose_socket(&self, home: usize) -> Option<usize> {
        let live_home = self.live_ranks_on(home);
        let load_home = self.load[home].load(Ordering::SeqCst);
        if live_home > 0 && load_home < 2 * live_home {
            return Some(home);
        }
        let mut best: Option<(usize, usize)> = None; // (load, socket)
        for s in 0..self.placement.n_sockets() {
            if s == home || self.live_ranks_on(s) == 0 {
                continue;
            }
            let l = self.load[s].load(Ordering::SeqCst);
            if best.is_none_or(|(bl, _)| l < bl) {
                best = Some((l, s));
            }
        }
        match best {
            // A saturated home keeps its batch when every spill target
            // is at least as loaded.
            Some((l, _)) if live_home > 0 && l >= load_home => Some(home),
            Some((_, s)) => Some(s),
            None => (live_home > 0).then_some(home),
        }
    }

    /// Dispatch one flushed group, supervising and routing: pick the
    /// target socket ([`Self::choose_socket`]), offer the batch to its
    /// live ranks round-robin; a bounce marks the rank dead and moves
    /// on (re-choosing the socket once the group is exhausted); with no
    /// rank live anywhere, wait out the earliest backoff and respawn;
    /// with every rank retired, answer the group `WorkerPanic` instead
    /// of wedging the queue. The requests travel in a shared cell so a
    /// bounced offer (whose job closure died with its guard) can be
    /// re-offered elsewhere without cloning the data.
    fn dispatch(&mut self, pool: &mut PersistentPool<Worker>, group: Group) {
        let rows = group.reqs.len() as u64;
        let Some(bucket) = group.reqs.first().map(|p| p.bucket) else {
            return;
        };
        let home = self.home_socket(bucket);
        let cell: Arc<Mutex<Option<Vec<Pending>>>> = Arc::new(Mutex::new(Some(group.reqs)));
        loop {
            let Some(target) = self.choose_socket(home) else {
                // No rank is live anywhere. Respawn the one whose
                // backoff expires soonest — under total worker failure
                // the dispatcher has nothing more useful to do than
                // wait for it.
                let mut soonest: Option<(usize, Instant)> = None;
                for rank in 0..self.health.len() {
                    if let RankHealth::Backoff { until } = self.health[rank] {
                        if soonest.is_none_or(|(_, u)| until < u) {
                            soonest = Some((rank, until));
                        }
                    }
                }
                match soonest {
                    Some((rank, until)) => {
                        let now = Instant::now();
                        if until > now {
                            std::thread::sleep(until - now);
                        }
                        self.respawn(pool, rank);
                        continue;
                    }
                    None => {
                        // Every rank retired: degrade gracefully.
                        // Dropping the cell releases the admission
                        // slots (SlotGuard) and answers every caller
                        // (Reply's drop contract).
                        drop(cell);
                        lock_unpoisoned(&self.metrics).failed += rows;
                        return;
                    }
                }
            };
            let ranks = self.placement.ranks_of(target);
            let n = ranks.len();
            for _ in 0..n {
                let rank = ranks.start + self.cursors[target] % n;
                self.cursors[target] = self.cursors[target].wrapping_add(1);
                if !matches!(self.health[rank], RankHealth::Live) {
                    continue;
                }
                let (guard, depth) = LoadGuard::acquire(&self.load[target]);
                let cell_ref = Arc::clone(&cell);
                let job: Job<Worker> = Box::new(move |w: &mut Worker| {
                    let _inflight = guard;
                    if let Some(reqs) = lock_unpoisoned(&cell_ref).take() {
                        w.run_batch(reqs);
                    }
                });
                match pool.try_exec(rank, job) {
                    Ok(()) => {
                        let mut m = lock_unpoisoned(&self.metrics);
                        let sm = &mut m.per_socket[target];
                        sm.rows += rows;
                        sm.peak_inflight = sm.peak_inflight.max(depth as u64);
                        if target == home {
                            sm.routed += 1;
                        } else {
                            sm.spilled_in += 1;
                            m.per_socket[home].spilled_out += 1;
                        }
                        return;
                    }
                    Err(bounced) => {
                        // Dropping the bounced job frees its load slot;
                        // the requests stay in the cell for the retry.
                        drop(bounced);
                        self.note_death(rank);
                    }
                }
            }
            // Every rank on the chosen socket died during the offers:
            // loop back and re-choose (possibly a spill target).
        }
    }
}

/// The serving front end: dynamic batching over a warmed worker pool.
pub struct Server {
    tx: Option<Sender<Pending>>,
    inflight: Arc<AtomicUsize>,
    queue_depth: usize,
    engine_opts: EngineOpts,
    /// Block-aligned streaming window, when the streaming route is on.
    stream_window: Option<usize>,
    default_deadline: Option<Duration>,
    /// Rank → socket layout the worker pool was spawned with.
    placement: Placement,
    metrics: Arc<Mutex<ServeMetrics>>,
    dispatcher: Option<JoinHandle<()>>,
}

impl Server {
    /// Build the workers (warming each plan cache when `opts.warm`),
    /// spawn the dispatcher and start accepting traffic.
    pub fn start(
        net_cfg: NetConfig,
        params: &[f32],
        opts: BatcherOpts,
    ) -> Result<Server, ServeError> {
        if opts.workers == 0 {
            return Err(ServeError::Config("workers must be at least 1".into()));
        }
        if opts.queue_depth == 0 {
            return Err(ServeError::Config("queue_depth must be at least 1".into()));
        }
        if opts.window.is_zero() {
            return Err(ServeError::Config(
                "batching window must be positive".into(),
            ));
        }
        // Validate the streaming geometry once, up front, against the
        // same rules StreamingSession enforces per construction.
        let stream_window = match opts.stream_window {
            None => None,
            Some(0) => {
                return Err(ServeError::Config(
                    "stream window must be positive".into(),
                ))
            }
            Some(w) => {
                let w = round_up_to_block(w);
                let largest = opts.engine.buckets.largest();
                if w > largest {
                    return Err(ServeError::Config(format!(
                        "stream window {w} exceeds the largest bucket ({largest})"
                    )));
                }
                // Snap to the bucket the session will execute in, so the
                // server's window metadata matches the actual plan.
                let w = opts
                    .engine
                    .buckets
                    .bucket_for(w)
                    .expect("window fits the largest bucket");
                let halo = net_cfg.receptive_field_reach();
                if w <= 2 * halo {
                    return Err(ServeError::Config(format!(
                        "stream window {w} must exceed twice the receptive-field \
                         reach (2 x {halo})"
                    )));
                }
                Some(w)
            }
        };
        // Socket layout: explicit, or detected from the machine
        // (`sockets: 0`); either way clamped to the worker count by
        // `Placement::new`.
        let sockets = match opts.sockets {
            0 => Topology::detect().sockets,
            s => s,
        };
        let placement = Placement::new(opts.workers, sockets);
        let metrics = Arc::new(Mutex::new(ServeMetrics::new(placement.n_sockets())));
        let inflight = Arc::new(AtomicUsize::new(0));
        let factory = WorkerFactory {
            net_cfg,
            params: Arc::new(params.to_vec()),
            engine_opts: opts.engine.clone(),
            warm: opts.warm,
            stream_window,
            metrics: Arc::clone(&metrics),
            #[cfg(any(test, feature = "fault"))]
            fault: opts.fault.clone(),
        };
        let mut sup = Supervisor {
            factory: factory.clone(),
            max_restarts: opts.max_restarts,
            metrics: Arc::clone(&metrics),
            placement,
            health: (0..opts.workers).map(|_| RankHealth::Live).collect(),
            used: vec![0; opts.workers],
            cursors: vec![0; placement.n_sockets()],
            load: (0..placement.n_sockets())
                .map(|_| Arc::new(AtomicUsize::new(0)))
                .collect(),
        };
        // Spawn the pool socket-placed: each rank's engine builds on its
        // own thread (first-touch on the owning socket group). A build
        // error — the lowest rank's — surfaces here, before any traffic.
        let mut pool =
            PersistentPool::try_new_placed(placement, move |rank, _socket| factory.build(rank))?;
        let (tx, rx) = channel::<Pending>();
        let max_batch = opts.engine.max_batch;
        let window = opts.window;
        // Serving starts now — warming must not count against uptime
        // throughput (seq_per_sec), so re-stamp after the builds above.
        lock_unpoisoned(&metrics).started = Instant::now();
        let dispatcher = std::thread::spawn(move || {
            dispatch_loop(rx, &mut pool, &mut sup, max_batch, window);
            // Drain: every queued job runs before the pool's Stop
            // message, so waiting out every live rank completes all
            // accepted work — including jobs a respawned rank took
            // during the drain itself.
            pool.sync_lossy();
        });
        Ok(Server {
            tx: Some(tx),
            inflight,
            queue_depth: opts.queue_depth,
            engine_opts: opts.engine,
            stream_window,
            default_deadline: opts.deadline,
            placement,
            metrics,
            dispatcher: Some(dispatcher),
        })
    }

    /// The rank → socket layout the worker pool was spawned with.
    pub fn placement(&self) -> Placement {
        self.placement
    }

    /// Submit one request (its length is its width) under the
    /// configured default deadline, if any. Fails fast with
    /// [`ServeError::QueueFull`] when the admission budget is exhausted,
    /// both before any queueing. Requests wider than every bucket take
    /// the halo-overlapped streaming route when a
    /// [`BatcherOpts::stream_window`] is configured, and fail with
    /// [`ServeError::TooWide`] otherwise.
    pub fn submit(&self, data: Vec<f32>) -> Result<Ticket, ServeError> {
        self.submit_with_deadline(data, None)
    }

    /// [`Self::submit`] with an explicit per-request deadline
    /// (`None` falls back to [`BatcherOpts::deadline`]). The clock
    /// starts now: a request still queued when the deadline passes is
    /// shed with [`ServeError::DeadlineExceeded`] before any compute
    /// runs; one already executing completes normally.
    pub fn submit_with_deadline(
        &self,
        data: Vec<f32>,
        deadline: Option<Duration>,
    ) -> Result<Ticket, ServeError> {
        if data.is_empty() {
            return Err(ServeError::EmptyRequest);
        }
        let (bucket, stream) = match self.engine_opts.buckets.bucket_for(data.len()) {
            Some(b) => (b, false),
            None => match self.stream_window {
                Some(w) => (w, true),
                None => {
                    return Err(ServeError::TooWide {
                        width: data.len(),
                        largest: self.engine_opts.buckets.largest(),
                    })
                }
            },
        };
        // Admission: reserve an in-flight slot or reject.
        let mut cur = self.inflight.load(Ordering::SeqCst);
        loop {
            if cur >= self.queue_depth {
                lock_unpoisoned(&self.metrics).rejected += 1;
                return Err(ServeError::QueueFull {
                    depth: self.queue_depth,
                });
            }
            match self.inflight.compare_exchange(
                cur,
                cur + 1,
                Ordering::SeqCst,
                Ordering::SeqCst,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
        let now = Instant::now();
        let deadline = deadline.or(self.default_deadline).map(|d| now + d);
        let (reply, rx) = channel();
        let pending = Pending {
            data,
            bucket,
            stream,
            deadline,
            enqueued: now,
            reply: Reply(Some(reply)),
            slot: SlotGuard::new(Arc::clone(&self.inflight)),
        };
        // A failed send drops `pending` inside the error: the slot
        // frees via SlotGuard and the reply channel closes.
        let sent = self.tx.as_ref().is_some_and(|tx| tx.send(pending).is_ok());
        if !sent {
            return Err(ServeError::ShuttingDown);
        }
        Ok(Ticket { rx })
    }

    /// Requests currently queued or executing.
    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::SeqCst)
    }

    /// Snapshot of the serving telemetry (elapsed time frozen at the
    /// moment of the snapshot).
    pub fn metrics(&self) -> ServeMetrics {
        let mut m = lock_unpoisoned(&self.metrics).clone();
        m.frozen_at = Some(Instant::now());
        m
    }

    /// Stop accepting requests, drain everything in flight, join the
    /// dispatcher and workers, and return the final telemetry (elapsed
    /// time frozen at shutdown).
    pub fn shutdown(mut self) -> ServeMetrics {
        self.stop();
        let mut m = lock_unpoisoned(&self.metrics).clone();
        m.frozen_at = Some(Instant::now());
        m
    }

    fn stop(&mut self) {
        self.tx.take(); // dispatcher's recv() disconnects → drain + exit
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Dispatcher: accumulate per-bucket groups, flush on full or window
/// expiry, hand flushed batches to the supervisor for (re-routed,
/// respawn-backed) round-robin dispatch.
fn dispatch_loop(
    rx: Receiver<Pending>,
    pool: &mut PersistentPool<Worker>,
    sup: &mut Supervisor,
    max_batch: usize,
    window: Duration,
) {
    let mut pending: BTreeMap<usize, Group> = BTreeMap::new();
    loop {
        if pending.is_empty() {
            // Nothing waiting: block until traffic or shutdown.
            match rx.recv() {
                Ok(p) => enqueue(&mut pending, p, max_batch, pool, sup),
                Err(_) => break,
            }
            continue;
        }
        // Sleep at most until the oldest group's window expires.
        let deadline = pending
            .values()
            .map(|g| g.oldest + window)
            .min()
            .expect("pending is non-empty");
        let now = Instant::now();
        if deadline <= now {
            flush_expired(&mut pending, window, pool, sup);
            continue;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(p) => enqueue(&mut pending, p, max_batch, pool, sup),
            Err(RecvTimeoutError::Timeout) => flush_expired(&mut pending, window, pool, sup),
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    // Shutdown: flush whatever is still pending.
    for (_, group) in std::mem::take(&mut pending) {
        sup.dispatch(pool, group);
    }
}

/// Add one request to its bucket group; flush the group if it is full.
/// Streamed requests never batch (each owns a worker for many windows),
/// so they flush immediately as singleton groups. Requests arriving
/// already past their deadline shed here, before occupying any batch
/// slot.
fn enqueue(
    pending: &mut BTreeMap<usize, Group>,
    mut p: Pending,
    max_batch: usize,
    pool: &mut PersistentPool<Worker>,
    sup: &mut Supervisor,
) {
    if p.expired(Instant::now()) {
        lock_unpoisoned(&sup.metrics).deadline_shed += 1;
        p.slot.release();
        p.reply.send(Err(ServeError::DeadlineExceeded));
        return;
    }
    if p.stream {
        let oldest = p.enqueued;
        sup.dispatch(
            pool,
            Group {
                reqs: vec![p],
                oldest,
            },
        );
        return;
    }
    // Flushed groups are removed outright, so a resident group is never
    // empty — `oldest` is always the first (oldest) request's enqueue time.
    let group = pending.entry(p.bucket).or_insert_with(|| Group {
        reqs: Vec::with_capacity(max_batch),
        oldest: p.enqueued,
    });
    let bucket = p.bucket;
    group.reqs.push(p);
    if group.reqs.len() >= max_batch {
        let group = pending.remove(&bucket).expect("group just filled");
        sup.dispatch(pool, group);
    }
}

/// Flush every group whose oldest request has aged past the window.
fn flush_expired(
    pending: &mut BTreeMap<usize, Group>,
    window: Duration,
    pool: &mut PersistentPool<Worker>,
    sup: &mut Supervisor,
) {
    let now = Instant::now();
    let expired: Vec<usize> = pending
        .iter()
        .filter(|(_, g)| g.oldest + window <= now)
        .map(|(&b, _)| b)
        .collect();
    for b in expired {
        let group = pending.remove(&b).expect("listed as expired");
        sup.dispatch(pool, group);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::AtacWorksNet;
    use crate::serve::fault::silence_fault_panics;
    use crate::serve::BucketSet;
    use crate::util::rng::Rng;

    fn tiny_server(queue_depth: usize, max_batch: usize, window: Duration) -> Server {
        let cfg = NetConfig::tiny();
        let params = AtacWorksNet::init(cfg, 5).pack_params();
        let opts = BatcherOpts {
            engine: EngineOpts {
                buckets: BucketSet::new(&[128, 256]).expect("widths"),
                max_batch,
                cache_capacity: 2,
                ..EngineOpts::default()
            },
            window,
            queue_depth,
            workers: 1,
            warm: true,
            stream_window: None,
            ..BatcherOpts::default()
        };
        Server::start(cfg, &params, opts).expect("server")
    }

    fn streaming_server(stream_window: Option<usize>) -> Server {
        let cfg = NetConfig::tiny(); // receptive-field reach 32
        let params = AtacWorksNet::init(cfg, 5).pack_params();
        let opts = BatcherOpts {
            engine: EngineOpts {
                buckets: BucketSet::new(&[128, 256]).expect("widths"),
                max_batch: 2,
                cache_capacity: 2,
                ..EngineOpts::default()
            },
            window: Duration::from_millis(1),
            queue_depth: 16,
            workers: 1,
            warm: false,
            stream_window,
            ..BatcherOpts::default()
        };
        Server::start(cfg, &params, opts).expect("server")
    }

    /// Single-worker, batch-of-1 server with a fault plan attached:
    /// each request is exactly one engine-forward visit, so plan `nth`
    /// indices line up with request submission order.
    fn faulty_server(plan: Arc<FaultPlan>, max_restarts: usize) -> Server {
        silence_fault_panics();
        let cfg = NetConfig::tiny();
        let params = AtacWorksNet::init(cfg, 5).pack_params();
        let opts = BatcherOpts {
            engine: EngineOpts {
                buckets: BucketSet::new(&[128, 256]).expect("widths"),
                max_batch: 1,
                cache_capacity: 2,
                ..EngineOpts::default()
            },
            window: Duration::from_millis(1),
            queue_depth: 16,
            workers: 1,
            warm: true,
            max_restarts,
            fault: Some(plan),
            ..BatcherOpts::default()
        };
        Server::start(cfg, &params, opts).expect("server")
    }

    fn track(w: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..w).map(|_| rng.poisson(0.7) as f32).collect()
    }

    /// Fault-free reference bits for one request.
    fn reference(req: &[f32]) -> InferOutput {
        let cfg = NetConfig::tiny();
        let params = AtacWorksNet::init(cfg, 5).pack_params();
        let opts = EngineOpts {
            buckets: BucketSet::new(&[128, 256]).expect("widths"),
            max_batch: 1,
            cache_capacity: 2,
            ..EngineOpts::default()
        };
        let mut engine = InferenceEngine::new(cfg, &params, opts).expect("engine");
        engine.infer_one(req).expect("reference")
    }

    #[test]
    fn serves_requests_and_records_metrics() {
        let server = tiny_server(64, 4, Duration::from_millis(1));
        let tickets: Vec<Ticket> = (0..6)
            .map(|i| server.submit(track(100 + i * 20, i as u64)).expect("submit"))
            .collect();
        for t in tickets {
            let r = t.wait().expect("response");
            assert!(r.latency_secs >= 0.0);
            assert!(r.batch_rows >= 1 && r.batch_rows <= 4);
            assert!(r.bucket == 128 || r.bucket == 256);
        }
        let m = server.shutdown();
        assert_eq!(m.completed, 6);
        assert_eq!(m.rejected, 0);
        assert_eq!(m.failed, 0);
        assert_eq!(m.worker_panics, 0);
        assert_eq!(m.restarts, 0);
        assert_eq!(m.deadline_shed, 0);
        assert_eq!(m.latency.count(), 6);
        assert!(m.batches >= 2, "two buckets cannot share a batch");
        assert!(m.mean_batch_occupancy() >= 1.0);
        let widths: Vec<usize> = m.per_bucket.keys().copied().collect();
        assert_eq!(widths, vec![128, 256]);
    }

    #[test]
    fn rejects_oversized_before_queueing() {
        let server = tiny_server(4, 2, Duration::from_millis(1));
        assert!(matches!(
            server.submit(track(300, 1)),
            Err(ServeError::TooWide {
                width: 300,
                largest: 256
            })
        ));
        assert!(matches!(
            server.submit(Vec::new()),
            Err(ServeError::EmptyRequest)
        ));
        assert_eq!(server.inflight(), 0);
    }

    #[test]
    fn backpressure_rejects_when_queue_is_full() {
        // A long window and a large max_batch park accepted requests in
        // the dispatcher, so the in-flight budget fills deterministically.
        let server = tiny_server(3, 64, Duration::from_millis(500));
        let mut accepted = Vec::new();
        let mut rejected = 0usize;
        for i in 0..8 {
            match server.submit(track(100, i)) {
                Ok(t) => accepted.push(t),
                Err(ServeError::QueueFull { depth }) => {
                    assert_eq!(depth, 3);
                    rejected += 1;
                }
                Err(e) => panic!("unexpected {e}"),
            }
        }
        assert_eq!(accepted.len(), 3);
        assert_eq!(rejected, 5);
        // Accepted requests still complete (window expiry flushes them).
        for t in accepted {
            t.wait().expect("accepted requests complete");
        }
        let m = server.shutdown();
        assert_eq!(m.completed, 3);
        assert_eq!(m.rejected, 5);
    }

    #[test]
    fn expired_deadlines_are_shed_before_compute_and_free_their_slots() {
        // A long window parks the batch, so a short per-request deadline
        // expires while queued and the worker sheds it pre-compute.
        let server = tiny_server(4, 64, Duration::from_millis(100));
        let doomed = server
            .submit_with_deadline(track(100, 1), Some(Duration::from_millis(5)))
            .expect("admitted");
        let alive = server
            .submit_with_deadline(track(100, 2), Some(Duration::from_secs(30)))
            .expect("admitted");
        assert!(matches!(
            doomed.wait(),
            Err(ServeError::DeadlineExceeded)
        ));
        let r = alive.wait().expect("generous deadline completes");
        assert_eq!(r.output, reference(&track(100, 2)), "survivor bits intact");
        // The shed request's admission slot came back.
        assert_eq!(server.inflight(), 0);
        let m = server.shutdown();
        assert_eq!(m.deadline_shed, 1);
        assert_eq!(m.completed, 1);
        assert_eq!(m.failed, 0, "a shed request is not an engine failure");
    }

    #[test]
    fn default_deadline_applies_to_plain_submits() {
        let cfg = NetConfig::tiny();
        let params = AtacWorksNet::init(cfg, 5).pack_params();
        let opts = BatcherOpts {
            engine: EngineOpts {
                buckets: BucketSet::new(&[128]).expect("widths"),
                max_batch: 64,
                cache_capacity: 1,
                ..EngineOpts::default()
            },
            window: Duration::from_millis(100),
            queue_depth: 4,
            workers: 1,
            warm: false,
            deadline: Some(Duration::from_millis(5)),
            ..BatcherOpts::default()
        };
        let server = Server::start(cfg, &params, opts).expect("server");
        let t = server.submit(track(100, 3)).expect("admitted");
        assert!(matches!(t.wait(), Err(ServeError::DeadlineExceeded)));
        // An explicit deadline overrides the tight default.
        let t = server
            .submit_with_deadline(track(100, 4), Some(Duration::from_secs(30)))
            .expect("admitted");
        t.wait().expect("explicit deadline overrides the default");
        let m = server.shutdown();
        assert_eq!(m.deadline_shed, 1);
        assert_eq!(m.completed, 1);
    }

    #[test]
    fn engine_panic_is_isolated_and_the_replica_rebuilt() {
        let plan = Arc::new(FaultPlan::new().panic_in_forward(0, 0));
        let server = faulty_server(Arc::clone(&plan), 3);
        let req = track(100, 7);
        // Request 0 hits the injected panic: its caller learns, the
        // worker thread survives.
        let t0 = server.submit(req.clone()).expect("admitted");
        assert!(matches!(t0.wait(), Err(ServeError::WorkerPanic)));
        // Request 1 runs on the rebuilt replica, bit-identical to the
        // fault-free reference.
        let t1 = server.submit(req.clone()).expect("still serving");
        let r1 = t1.wait().expect("rebuilt replica serves");
        assert_eq!(r1.output, reference(&req));
        assert_eq!(server.inflight(), 0);
        let m = server.shutdown();
        assert_eq!(m.worker_panics, 1);
        assert_eq!(m.worker_panics, plan.panics_fired());
        assert_eq!(m.restarts, 0, "a caught panic needs no thread restart");
        assert_eq!(m.failed, 1);
        assert_eq!(m.completed, 1);
    }

    #[test]
    fn killed_worker_is_respawned_and_serving_resumes() {
        let plan = Arc::new(FaultPlan::new().kill_worker(0, 0));
        let server = faulty_server(Arc::clone(&plan), 3);
        let req = track(100, 8);
        // Request 0's job kills the rank thread outright; the Reply
        // drop contract still answers the caller.
        let t0 = server.submit(req.clone()).expect("admitted");
        assert!(matches!(t0.wait(), Err(ServeError::WorkerPanic)));
        // Request 1 bounces off the dead rank, waits out the backoff,
        // and lands on the respawned worker.
        let t1 = server.submit(req.clone()).expect("still serving");
        let r1 = t1.wait().expect("respawned worker serves");
        assert_eq!(r1.output, reference(&req));
        let m = server.shutdown();
        assert_eq!(m.restarts, 1);
        assert_eq!(m.worker_panics, 0, "the unwind escaped the guard");
        assert_eq!(m.completed, 1);
    }

    #[test]
    fn restart_budget_exhaustion_degrades_to_fast_errors() {
        let plan = Arc::new(FaultPlan::new().kill_worker(0, 0).kill_worker(0, 1));
        let server = faulty_server(plan, 1);
        let req = track(100, 9);
        // Kill 1 consumes the rank; kill 2 consumes its only restart.
        for _ in 0..2 {
            let t = server.submit(req.clone()).expect("admitted");
            assert!(matches!(t.wait(), Err(ServeError::WorkerPanic)));
        }
        // The pool is (or is about to be) fully retired: the server
        // keeps answering — with errors, promptly — instead of wedging.
        for _ in 0..2 {
            let t = server.submit(req.clone()).expect("admission still works");
            assert!(matches!(t.wait(), Err(ServeError::WorkerPanic)));
        }
        assert_eq!(server.inflight(), 0, "no slot leaks through retirement");
        let m = server.shutdown();
        assert_eq!(m.restarts, 1, "budget of 1 allows exactly one respawn");
        assert_eq!(m.completed, 0);
    }

    #[test]
    fn over_wide_requests_stream_when_a_window_is_configured() {
        let server = streaming_server(Some(100)); // rounds to 128
        let signal = track(700, 11); // > largest bucket (256)
        let r = server
            .submit(signal.clone())
            .expect("streams instead of TooWide")
            .wait()
            .expect("streamed response");
        assert!(r.streamed);
        assert_eq!(r.bucket, 128);
        assert_eq!(r.batch_rows, 1);
        assert_eq!(r.output.denoised.len(), 700);
        assert_eq!(r.output.logits.len(), 700);
        // Bit-identical to a direct StreamingSession over the same
        // engine geometry (which the stream tests tie to whole-sequence
        // evaluation).
        let cfg = NetConfig::tiny();
        let params = AtacWorksNet::init(cfg, 5).pack_params();
        let opts = EngineOpts {
            buckets: BucketSet::new(&[128, 256]).expect("widths"),
            max_batch: 2,
            cache_capacity: 2,
            ..EngineOpts::default()
        };
        let mut engine = InferenceEngine::new(cfg, &params, opts).expect("engine");
        let want = StreamingSession::new(&mut engine, 128)
            .expect("session")
            .infer(&signal)
            .expect("reference");
        assert_eq!(r.output, want);
        // In-bucket traffic still batches normally alongside streams.
        let small = server.submit(track(100, 12)).expect("submit");
        let rs = small.wait().expect("batched response");
        assert!(!rs.streamed);
        assert_eq!(rs.bucket, 128);
        let m = server.shutdown();
        assert_eq!(m.completed, 2);
        assert_eq!(m.streamed, 1);
        // 700 columns at window 128 / halo 32: spans 96 + 64·k + tail.
        assert!(m.stream_windows >= 7, "expected >= 7 windows for 700 cols");
    }

    #[test]
    fn streaming_stays_off_and_geometry_is_validated() {
        // Default-off: over-wide still rejects.
        let server = streaming_server(None);
        assert!(matches!(
            server.submit(track(700, 1)),
            Err(ServeError::TooWide {
                width: 700,
                largest: 256
            })
        ));
        drop(server);
        // A window that cannot hold two halos is a config error. (The
        // bucket snap means the window to test against is the bucket
        // itself: with a 128 bucket a 64 request would legally snap up.)
        let cfg = NetConfig::tiny();
        let params = AtacWorksNet::init(cfg, 5).pack_params();
        let opts = BatcherOpts {
            engine: EngineOpts {
                buckets: BucketSet::new(&[64]).expect("widths"),
                max_batch: 1,
                cache_capacity: 1,
                ..EngineOpts::default()
            },
            window: Duration::from_millis(1),
            queue_depth: 4,
            workers: 1,
            warm: false,
            stream_window: Some(64), // snapped window 64 <= 2 * 32
            ..BatcherOpts::default()
        };
        assert!(matches!(
            Server::start(cfg, &params, opts.clone()),
            Err(ServeError::Config(_))
        ));
        let over = BatcherOpts {
            stream_window: Some(512), // exceeds the largest bucket
            ..opts
        };
        assert!(matches!(
            Server::start(cfg, &params, over),
            Err(ServeError::Config(_))
        ));
    }

    #[test]
    fn shutdown_drains_streamed_and_batched_requests_together() {
        // Mixed in-flight work at shutdown: nothing accepted is lost.
        let server = streaming_server(Some(128));
        let stream_t = server.submit(track(600, 21)).expect("stream accepted");
        let batch_t = server.submit(track(90, 22)).expect("batch accepted");
        let m = server.shutdown();
        let rs = stream_t.wait().expect("streamed request drained");
        let rb = batch_t.wait().expect("batched request drained");
        assert!(rs.streamed && !rb.streamed);
        assert_eq!(rs.output.denoised.len(), 600);
        assert_eq!(rb.output.denoised.len(), 90);
        assert_eq!(m.completed, 2);
        assert_eq!(m.streamed, 1);
    }

    #[test]
    fn socket_sharded_serving_is_bit_identical_and_accounted() {
        let cfg = NetConfig::tiny();
        let params = AtacWorksNet::init(cfg, 5).pack_params();
        let opts = BatcherOpts::default()
            .with_engine(
                EngineOpts::default()
                    .with_buckets(BucketSet::new(&[128, 256]).expect("widths"))
                    .with_max_batch(2)
                    .with_cache_capacity(2),
            )
            .with_window(Duration::from_millis(1))
            .with_queue_depth(64)
            .with_workers(4)
            .with_sockets(2);
        let server = Server::start(cfg, &params, opts).expect("server");
        assert_eq!(server.placement().n_sockets(), 2);
        assert_eq!(server.placement().n_ranks(), 4);
        // Alternate between the two buckets so both home sockets see
        // traffic.
        let reqs: Vec<Vec<f32>> = (0..8)
            .map(|i| track(100 + (i % 2) * 100, i as u64))
            .collect();
        let tickets: Vec<Ticket> = reqs
            .iter()
            .map(|r| server.submit(r.clone()).expect("submit"))
            .collect();
        for (t, r) in tickets.into_iter().zip(&reqs) {
            let got = t.wait().expect("response");
            assert_eq!(
                got.output,
                reference(r),
                "socket sharding must not change the bits"
            );
        }
        let m = server.shutdown();
        assert_eq!(m.completed, 8);
        assert_eq!(m.per_socket.len(), 2);
        let rows: u64 = m.per_socket.iter().map(|s| s.rows).sum();
        assert_eq!(rows, 8, "every request row accounted to a socket");
        let dispatched: u64 = m.per_socket.iter().map(|s| s.routed + s.spilled_in).sum();
        assert_eq!(dispatched, m.batches, "every batch routed or spilled");
        let spills_out: u64 = m.per_socket.iter().map(|s| s.spilled_out).sum();
        let spills_in: u64 = m.per_socket.iter().map(|s| s.spilled_in).sum();
        assert_eq!(spills_out, spills_in, "spill books must balance");
        assert!(m.per_socket.iter().any(|s| s.peak_inflight >= 1));
    }

    #[test]
    fn flat_pool_keeps_single_socket_metrics() {
        let server = tiny_server(16, 2, Duration::from_millis(1));
        assert!(server.placement().is_flat());
        let t = server.submit(track(80, 33)).expect("submit");
        t.wait().expect("response");
        let m = server.shutdown();
        assert_eq!(m.per_socket.len(), 1);
        assert_eq!(m.per_socket[0].routed, m.batches);
        assert_eq!(m.per_socket[0].spilled_in, 0);
        assert_eq!(m.per_socket[0].spilled_out, 0);
    }

    #[test]
    fn shutdown_drains_pending_requests() {
        // Submit then immediately shut down: the pending group must be
        // flushed, not dropped.
        let server = tiny_server(16, 8, Duration::from_secs(5));
        let t = server.submit(track(80, 9)).expect("submit");
        let m = server.shutdown();
        let r = t.wait().expect("drained on shutdown");
        assert_eq!(r.output.denoised.len(), 80);
        assert_eq!(m.completed, 1);
    }
}
