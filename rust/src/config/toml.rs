//! Minimal TOML-subset parser — the config-file substrate (no `toml`
//! crate offline). Supports exactly what our config files use:
//!
//! * `[section]` headers (one level),
//! * `key = value` with string, integer, float and boolean values,
//! * `#` comments and blank lines.
//!
//! Unknown syntax is an error, not silently ignored — config typos should
//! fail loudly.

use std::collections::BTreeMap;

/// A scalar config value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Value::Int(i) if *i >= 0 => Some(*i as usize),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parsed document: section → key → value. Top-level keys live under "".
pub type Doc = BTreeMap<String, BTreeMap<String, Value>>;

/// Parse a TOML-subset document.
pub fn parse(src: &str) -> Result<Doc, String> {
    let mut doc: Doc = BTreeMap::new();
    let mut section = String::new();
    doc.entry(section.clone()).or_default();
    for (lineno, raw) in src.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| format!("line {}: unterminated section", lineno + 1))?
                .trim();
            if name.is_empty() {
                return Err(format!("line {}: empty section name", lineno + 1));
            }
            section = name.to_string();
            doc.entry(section.clone()).or_default();
            continue;
        }
        let (key, val) = line
            .split_once('=')
            .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
        let key = key.trim();
        if key.is_empty() {
            return Err(format!("line {}: empty key", lineno + 1));
        }
        // Strip trailing comments outside strings.
        let val = val.trim();
        let val = if val.starts_with('"') {
            val
        } else {
            val.split('#').next().unwrap().trim()
        };
        let value = parse_value(val)
            .ok_or_else(|| format!("line {}: cannot parse value '{val}'", lineno + 1))?;
        doc.get_mut(&section)
            .unwrap()
            .insert(key.to_string(), value);
    }
    Ok(doc)
}

fn parse_value(v: &str) -> Option<Value> {
    if v.is_empty() {
        return None;
    }
    if let Some(stripped) = v.strip_prefix('"') {
        let inner = stripped.strip_suffix('"')?;
        return Some(Value::Str(inner.to_string()));
    }
    match v {
        "true" => return Some(Value::Bool(true)),
        "false" => return Some(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = v.parse::<i64>() {
        return Some(Value::Int(i));
    }
    if let Ok(f) = v.parse::<f64>() {
        return Some(Value::Float(f));
    }
    None
}

/// Typed lookup helpers over a parsed document.
pub fn get_usize(doc: &Doc, section: &str, key: &str) -> Option<usize> {
    doc.get(section)?.get(key)?.as_usize()
}

pub fn get_f64(doc: &Doc, section: &str, key: &str) -> Option<f64> {
    doc.get(section)?.get(key)?.as_f64()
}

pub fn get_str<'a>(doc: &'a Doc, section: &str, key: &str) -> Option<&'a str> {
    doc.get(section)?.get(key)?.as_str()
}

pub fn get_bool(doc: &Doc, section: &str, key: &str) -> Option<bool> {
    doc.get(section)?.get(key)?.as_bool()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_typical_config() {
        let doc = parse(
            r#"
# training config
backend = "brgemm"

[model]
channels = 15
filter_size = 51

[train]
lr = 0.0002      # adam
epochs = 25
bf16 = false
"#,
        )
        .unwrap();
        assert_eq!(get_str(&doc, "", "backend"), Some("brgemm"));
        assert_eq!(get_usize(&doc, "model", "channels"), Some(15));
        assert_eq!(get_f64(&doc, "train", "lr"), Some(0.0002));
        assert_eq!(get_bool(&doc, "train", "bf16"), Some(false));
        assert_eq!(get_usize(&doc, "train", "missing"), None);
    }

    #[test]
    fn ints_coerce_to_float() {
        let doc = parse("x = 3\n").unwrap();
        assert_eq!(get_f64(&doc, "", "x"), Some(3.0));
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse("[unterminated\n").is_err());
        assert!(parse("keywithoutvalue\n").is_err());
        assert!(parse("k = \n").is_err());
        assert!(parse("k = what\n").is_err());
    }
}
