//! Configuration system: a TOML-subset reader ([`toml`]) plus the typed
//! experiment/training ([`TrainConfig`]) and serving ([`ServeConfig`])
//! configurations used by the launcher, coordinator and serve CLI.

pub mod toml;

use crate::conv1d::{Backend, Partition, PostOps};
use crate::machine::Precision;
use crate::model::NetConfig;
use crate::serve::{round_up_to_block, BatcherOpts, BucketSet, EngineOpts, NetOpts};

use anyhow::{anyhow, Context, Result};
use std::path::Path;
use std::time::Duration;

/// Shared `precision` vocabulary of the `[train]`/`[serve]` sections and
/// the `--precision` flags.
fn parse_precision(s: &str) -> Result<Precision> {
    match s.to_ascii_lowercase().as_str() {
        "f32" | "fp32" => Ok(Precision::F32),
        "bf16" | "bfloat16" => Ok(Precision::Bf16),
        "i8" | "int8" => Ok(Precision::I8),
        other => Err(anyhow!("unknown precision '{other}' (f32|bf16|i8)")),
    }
}

/// Shared `backend` vocabulary: resolve a registry kernel name (any
/// [`crate::conv1d::lookup_kernel`] alias) to the `(Backend, Precision)`
/// pair it implies — `"bf16"` means the BRGEMM backend at bf16, `"i8"`
/// the BRGEMM backend at the int8 quantized tier, every other kernel
/// pins f32. One resolver, so `train` and `serve` can never drift on
/// what a backend name selects.
fn resolve_backend_name(name: &str) -> Result<(Backend, Precision), String> {
    let kernel = crate::conv1d::lookup_kernel(name)
        .ok_or_else(|| format!("unknown backend '{name}'"))?;
    Ok(match kernel.name() {
        "bf16" => (Backend::Brgemm, Precision::Bf16),
        "i8" => (Backend::Brgemm, Precision::I8),
        canonical => (canonical.parse::<Backend>()?, Precision::F32),
    })
}

/// Strict CLI boolean vocabulary: bad values fail loudly, matching the
/// TOML path's typed `get_bool` (a typo must never silently mean false).
fn parse_bool_flag(key: &str, value: &str) -> Result<bool> {
    match value.to_ascii_lowercase().as_str() {
        "true" | "1" | "yes" => Ok(true),
        "false" | "0" | "no" => Ok(false),
        other => Err(anyhow!("--{key} expects true|false, got '{other}'")),
    }
}

/// Override `dst` with `[section] key` when present — the one usize
/// reader every config loader goes through.
fn set_usize(doc: &toml::Doc, sec: &str, key: &str, dst: &mut usize) {
    if let Some(v) = toml::get_usize(doc, sec, key) {
        *dst = v;
    }
}

/// Apply the `[model]`/`[data]` keys both loaders share — one parser, so
/// `train` and `serve` can never read the same TOML differently.
fn apply_model_data_keys(
    doc: &toml::Doc,
    channels: &mut usize,
    n_blocks: &mut usize,
    filter_size: &mut usize,
    dilation: &mut usize,
    seed: &mut u64,
) {
    set_usize(doc, "model", "channels", channels);
    set_usize(doc, "model", "n_blocks", n_blocks);
    set_usize(doc, "model", "filter_size", filter_size);
    set_usize(doc, "model", "dilation", dilation);
    if let Some(v) = toml::get_usize(doc, "data", "seed") {
        *seed = v as u64;
    }
}

/// Full training-run configuration (CLI defaults ≈ a width-scaled version
/// of the paper's Sec. 4.2 setup that runs in seconds on this host).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    // Model (paper Sec. 4.2).
    pub channels: usize,
    pub n_blocks: usize,
    pub filter_size: usize,
    pub dilation: usize,
    // Data.
    pub segment_width: usize,
    pub segment_pad: usize,
    pub train_segments: usize,
    pub seed: u64,
    // Training.
    pub batch_size: usize,
    pub epochs: usize,
    pub lr: f64,
    pub precision: Precision,
    pub backend: Backend,
    /// Fused post-op spec for the network body (`post_ops = "bias_relu"`):
    /// the activation is applied inside the conv kernels' output-block
    /// loop; the ResNet block tails additionally fuse the residual add.
    pub post_ops: PostOps,
    /// Work partitioning the conv kernels split across threads
    /// (`partition = "batch"` or `"grid"`): `grid` splits the
    /// `N × ceil(Q/64)` width-block grid so small-batch / long-sequence
    /// runs still use every thread.
    pub partition: Partition,
    /// Choose each layer's kernel per shape via the autotuner
    /// (`autotune = true`) instead of pinning `backend`.
    pub autotune: bool,
    /// Persisted tuning table (JSON): loaded before training to
    /// warm-start the autotuner, written back after.
    pub tune_cache: Option<String>,
    // Distributed training (DESIGN.md §6).
    /// Overlap gradient communication with the backward pass: fire each
    /// gradient bucket's ring all-reduce the moment its layers finish
    /// differentiating (`overlap = true`), instead of one monolithic
    /// all-reduce after backward. Bit-identical results either way.
    pub overlap: bool,
    /// Gradient bucket budget in MiB (`bucket_mb = 4.0`): the flat
    /// gradient is cut into whole-layer buckets of at most this many
    /// bytes, in backward completion order.
    pub bucket_mb: f64,
    // Topology.
    pub sockets: usize,
    pub threads_per_socket: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            channels: 15,
            n_blocks: 11,
            filter_size: 51,
            dilation: 8,
            segment_width: 2_000, // paper: 50_000 (scaled for this host)
            segment_pad: 200,     // paper: 5_000
            train_segments: 64,   // paper: 32_000
            seed: 42,
            batch_size: 4,        // paper: 54/64 per socket
            epochs: 3,            // paper: 25
            lr: 2e-4,
            precision: Precision::F32,
            backend: Backend::Brgemm,
            post_ops: PostOps::bias_relu(),
            partition: Partition::Batch,
            autotune: false,
            tune_cache: None,
            overlap: false,
            bucket_mb: 4.0,
            sockets: 1,
            threads_per_socket: 1,
        }
    }
}

impl TrainConfig {
    /// The paper's full-scale configuration (Sec. 4.2) — hours of compute;
    /// used by the machine-model projections, not for local runs.
    pub fn paper_full() -> Self {
        TrainConfig {
            segment_width: 50_000,
            segment_pad: 5_000,
            train_segments: 32_000,
            batch_size: 54,
            epochs: 25,
            threads_per_socket: 27,
            ..Default::default()
        }
    }

    /// Load from a TOML file, starting from `Default` and overriding any
    /// key present.
    pub fn from_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading config {:?}", path.as_ref()))?;
        let doc = toml::parse(&text).map_err(|e| anyhow!("config parse error: {e}"))?;
        let mut cfg = TrainConfig::default();
        apply_model_data_keys(
            &doc,
            &mut cfg.channels,
            &mut cfg.n_blocks,
            &mut cfg.filter_size,
            &mut cfg.dilation,
            &mut cfg.seed,
        );
        set_usize(&doc, "data", "segment_width", &mut cfg.segment_width);
        set_usize(&doc, "data", "segment_pad", &mut cfg.segment_pad);
        set_usize(&doc, "data", "train_segments", &mut cfg.train_segments);
        set_usize(&doc, "train", "batch_size", &mut cfg.batch_size);
        set_usize(&doc, "train", "epochs", &mut cfg.epochs);
        set_usize(&doc, "topology", "sockets", &mut cfg.sockets);
        set_usize(&doc, "topology", "threads_per_socket", &mut cfg.threads_per_socket);
        if let Some(v) = toml::get_f64(&doc, "train", "lr") {
            cfg.lr = v;
        }
        // Backend before precision: a backend name implies a precision
        // (see apply_backend_name), so an explicit `precision` key stays
        // authoritative when both are given.
        if let Some(s) = toml::get_str(&doc, "train", "backend") {
            cfg.apply_backend_name(s).map_err(|e| anyhow!(e))?;
        }
        if let Some(s) = toml::get_str(&doc, "train", "precision") {
            cfg.precision = parse_precision(s)?;
        }
        if let Some(s) = toml::get_str(&doc, "train", "post_ops") {
            cfg.post_ops = PostOps::parse(s).map_err(|e| anyhow!(e))?;
        }
        if let Some(s) = toml::get_str(&doc, "train", "partition") {
            cfg.partition = s.parse().map_err(|e: String| anyhow!(e))?;
        }
        if let Some(b) = toml::get_bool(&doc, "train", "autotune") {
            cfg.autotune = b;
        }
        if let Some(s) = toml::get_str(&doc, "train", "tune_cache") {
            cfg.tune_cache = Some(s.to_string());
        }
        if let Some(b) = toml::get_bool(&doc, "train", "overlap") {
            cfg.overlap = b;
        }
        if let Some(v) = toml::get_f64(&doc, "train", "bucket_mb") {
            if v <= 0.0 {
                return Err(anyhow!("bucket_mb must be positive, got {v}"));
            }
            cfg.bucket_mb = v;
        }
        Ok(cfg)
    }

    /// Select the conv backend by **registry name** (any alias accepted by
    /// [`crate::conv1d::lookup_kernel`]) — so configs pick any registered
    /// kernel without the enum ever growing. A kernel name pins the
    /// precision too: `"bf16"` means the BRGEMM backend at
    /// `Precision::Bf16`, every other name means f32 — a later
    /// `precision` setting can still override.
    pub fn apply_backend_name(&mut self, name: &str) -> Result<(), String> {
        (self.backend, self.precision) = resolve_backend_name(name)?;
        Ok(())
    }

    /// Padded track width the network sees.
    pub fn padded_width(&self) -> usize {
        self.segment_width + 2 * self.segment_pad
    }

    /// The gradient bucket budget in bytes (f32 elements × 4).
    pub fn bucket_bytes(&self) -> usize {
        (self.bucket_mb * 1024.0 * 1024.0).max(4.0) as usize
    }
}

/// Configuration of the batched inference server (`[serve]` section +
/// `dilconv serve` flags; DESIGN.md §7). The `[model]`/`[data]` keys are
/// shared with [`TrainConfig`], so one TOML file describes both the
/// training run and the server that loads its checkpoint.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    // Model geometry (must match the checkpoint being served).
    pub channels: usize,
    pub n_blocks: usize,
    pub filter_size: usize,
    pub dilation: usize,
    /// Weight-init seed when serving without a checkpoint (demos/tests).
    pub seed: u64,
    // Serving policy.
    /// Width buckets (`buckets = "1024,2048,4096"`), each rounded up to
    /// the kernels' 64-wide block grid.
    pub buckets: BucketSet,
    /// Batch capacity each bucket's plans are pinned at.
    pub max_batch: usize,
    /// Batching window in milliseconds (must be positive): a non-full
    /// batch is flushed once its oldest request has waited this long.
    pub window_ms: f64,
    /// Admission budget: maximum requests queued or executing at once.
    pub queue_depth: usize,
    /// Worker threads, each owning a private engine + warmed plan cache.
    pub workers: usize,
    /// NUMA sockets to shard the worker pool across (`sockets = 2`;
    /// DESIGN.md §6b): worker ranks are split into per-socket groups,
    /// replica state is first-touched on the owning group's threads and
    /// the dispatcher routes each bucket to its home socket. `1` keeps
    /// the flat single-socket pool; `0` detects the machine topology
    /// (`CONV1D_TOPOLOGY`, then sysfs). Sharding never changes bits.
    pub sockets: usize,
    /// Kernel-level threads per forward pass.
    pub threads: usize,
    /// Forward precision (`bf16` serves bf16-rounded weights on the bf16
    /// kernels — the working copy training replicas compute with).
    pub precision: Precision,
    /// Work partitioning (`grid` keeps every thread busy even when a
    /// window closes with one request).
    pub partition: Partition,
    /// Kernel backend (ignored when `autotune` is set).
    pub backend: Backend,
    /// Choose each layer's kernel per bucket via the autotuner.
    pub autotune: bool,
    /// Maximum resident bucket entries per worker (LRU beyond this).
    pub cache_capacity: usize,
    /// Conv→conv fusion inside each bucket's net-level plan
    /// (`fuse = true|false`; the liveness arena is on either way, and
    /// the bits are identical either way — DESIGN.md §7c).
    pub fuse: bool,
    /// Pre-build the resident bucket suffix's plans before accepting
    /// traffic (buckets that cannot stay under `cache_capacity` build
    /// lazily on first use).
    pub warm: bool,
    /// TCP listen address (`listen = "127.0.0.1:7878"`; `--listen`).
    /// `None` keeps the server in-process (load-generator mode).
    pub listen: Option<String>,
    /// Route requests wider than every bucket through halo-overlapped
    /// streaming windows instead of rejecting them (`stream = true`).
    pub stream: bool,
    /// Streaming window width in samples; `0` means auto (the largest
    /// bucket, when it can hold two receptive-field halos — deep
    /// geometries whose halo exceeds every bucket keep streaming off).
    pub stream_window: usize,
    /// Network drain budget at shutdown, milliseconds: connections
    /// still serving after this long are force-closed.
    pub drain_ms: f64,
    /// Default per-request deadline, milliseconds: a request still
    /// queued when its deadline passes is shed with a
    /// `DEADLINE_EXCEEDED` response before any compute runs. `0`
    /// disables the default (wire requests may still carry their own).
    pub deadline_ms: f64,
    /// Idle-connection reaper, milliseconds: a connection that has sent
    /// nothing for this long is closed so dead clients stop pinning
    /// connection slots. `0` disables the reaper.
    pub idle_timeout_ms: f64,
    /// Supervisor restart budget per worker rank: how many times a dead
    /// worker is respawned (with exponential backoff) before the rank
    /// is retired.
    pub max_restarts: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        let t = TrainConfig::default();
        ServeConfig {
            channels: t.channels,
            n_blocks: t.n_blocks,
            filter_size: t.filter_size,
            dilation: t.dilation,
            seed: t.seed,
            buckets: BucketSet::new(&[1024, 2048, 4096]).expect("static widths"),
            max_batch: 8,
            window_ms: 2.0,
            queue_depth: 256,
            workers: 1,
            sockets: 1,
            threads: 1,
            precision: Precision::F32,
            partition: Partition::Batch,
            backend: Backend::Brgemm,
            autotune: false,
            cache_capacity: 8,
            fuse: true,
            warm: true,
            listen: None,
            stream: true,
            stream_window: 0,
            drain_ms: 5_000.0,
            deadline_ms: 0.0,
            idle_timeout_ms: 60_000.0,
            max_restarts: 3,
        }
    }
}

impl ServeConfig {
    /// Load from a TOML file: `[model]`/`[data]` keys shared with
    /// [`TrainConfig`], serving keys under `[serve]`. Starts from
    /// `Default` and overrides any key present; invalid values fail
    /// loudly (see [`Self::validate`]).
    pub fn from_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading config {:?}", path.as_ref()))?;
        let doc = toml::parse(&text).map_err(|e| anyhow!("config parse error: {e}"))?;
        let mut cfg = ServeConfig::default();
        apply_model_data_keys(
            &doc,
            &mut cfg.channels,
            &mut cfg.n_blocks,
            &mut cfg.filter_size,
            &mut cfg.dilation,
            &mut cfg.seed,
        );
        set_usize(&doc, "serve", "max_batch", &mut cfg.max_batch);
        set_usize(&doc, "serve", "queue_depth", &mut cfg.queue_depth);
        set_usize(&doc, "serve", "workers", &mut cfg.workers);
        set_usize(&doc, "serve", "sockets", &mut cfg.sockets);
        set_usize(&doc, "serve", "threads", &mut cfg.threads);
        set_usize(&doc, "serve", "cache_capacity", &mut cfg.cache_capacity);
        if let Some(s) = toml::get_str(&doc, "serve", "buckets") {
            cfg.buckets = BucketSet::parse(s).map_err(|e| anyhow!(e))?;
        }
        if let Some(v) = toml::get_f64(&doc, "serve", "window_ms") {
            cfg.window_ms = v;
        }
        if let Some(s) = toml::get_str(&doc, "serve", "backend") {
            cfg.apply_backend_name(s)?;
        }
        if let Some(s) = toml::get_str(&doc, "serve", "precision") {
            cfg.precision = parse_precision(s)?;
        }
        if let Some(s) = toml::get_str(&doc, "serve", "partition") {
            cfg.partition = s.parse().map_err(|e: String| anyhow!(e))?;
        }
        if let Some(b) = toml::get_bool(&doc, "serve", "autotune") {
            cfg.autotune = b;
        }
        if let Some(b) = toml::get_bool(&doc, "serve", "fuse") {
            cfg.fuse = b;
        }
        if let Some(b) = toml::get_bool(&doc, "serve", "warm") {
            cfg.warm = b;
        }
        if let Some(s) = toml::get_str(&doc, "serve", "listen") {
            cfg.listen = Some(s.to_string());
        }
        if let Some(b) = toml::get_bool(&doc, "serve", "stream") {
            cfg.stream = b;
        }
        set_usize(&doc, "serve", "stream_window", &mut cfg.stream_window);
        if let Some(v) = toml::get_f64(&doc, "serve", "drain_ms") {
            cfg.drain_ms = v;
        }
        if let Some(v) = toml::get_f64(&doc, "serve", "deadline_ms") {
            cfg.deadline_ms = v;
        }
        if let Some(v) = toml::get_f64(&doc, "serve", "idle_timeout_ms") {
            cfg.idle_timeout_ms = v;
        }
        set_usize(&doc, "serve", "max_restarts", &mut cfg.max_restarts);
        cfg.validate()?;
        Ok(cfg)
    }

    /// Apply one `--key value` CLI flag (the `dilconv serve` vocabulary).
    /// Returns `Ok(false)` for keys this config does not own, so the CLI
    /// can report unknown flags.
    pub fn apply_flag(&mut self, key: &str, value: &str) -> Result<bool> {
        let uint = |v: &str, k: &str| -> Result<usize> {
            v.parse()
                .with_context(|| format!("--{k} must be an integer, got '{v}'"))
        };
        match key {
            "buckets" => self.buckets = BucketSet::parse(value).map_err(|e| anyhow!(e))?,
            "max-batch" => self.max_batch = uint(value, key)?,
            "window-ms" => {
                self.window_ms = value
                    .parse()
                    .with_context(|| format!("--window-ms must be a number, got '{value}'"))?
            }
            "queue" => self.queue_depth = uint(value, key)?,
            "workers" => self.workers = uint(value, key)?,
            "sockets" => self.sockets = uint(value, key)?,
            "threads" => self.threads = uint(value, key)?,
            "cache-capacity" => self.cache_capacity = uint(value, key)?,
            "precision" => self.precision = parse_precision(value)?,
            "partition" => self.partition = value.parse().map_err(|e: String| anyhow!(e))?,
            "backend" => self.apply_backend_name(value)?,
            "autotune" => self.autotune = parse_bool_flag(key, value)?,
            "fuse" => self.fuse = parse_bool_flag(key, value)?,
            "no-warm" => self.warm = !parse_bool_flag(key, value)?,
            "listen" => self.listen = Some(value.to_string()),
            "stream" => self.stream = parse_bool_flag(key, value)?,
            "stream-window" => self.stream_window = uint(value, key)?,
            "drain-ms" => {
                self.drain_ms = value
                    .parse()
                    .with_context(|| format!("--drain-ms must be a number, got '{value}'"))?
            }
            "deadline-ms" => {
                self.deadline_ms = value
                    .parse()
                    .with_context(|| format!("--deadline-ms must be a number, got '{value}'"))?
            }
            "idle-timeout-ms" => {
                self.idle_timeout_ms = value.parse().with_context(|| {
                    format!("--idle-timeout-ms must be a number, got '{value}'")
                })?
            }
            "max-restarts" => self.max_restarts = uint(value, key)?,
            _ => return Ok(false),
        }
        Ok(true)
    }

    /// Select the serve backend by registry name — the same shared
    /// resolver as [`TrainConfig::apply_backend_name`], so `train` and
    /// `serve` can never drift on what a backend name selects (`"bf16"`
    /// pins the BRGEMM backend at bf16 precision).
    pub fn apply_backend_name(&mut self, name: &str) -> Result<()> {
        (self.backend, self.precision) = resolve_backend_name(name).map_err(|e| anyhow!(e))?;
        Ok(())
    }

    /// Reject configurations the server cannot run: a zero batching
    /// window (a window is what amortizes batches; "no batching" is
    /// `max_batch = 1`), zero batch/queue/worker/cache sizes. The bucket
    /// set enforces its own non-emptiness at construction.
    pub fn validate(&self) -> Result<()> {
        if self.window_ms.is_nan() || self.window_ms <= 0.0 {
            return Err(anyhow!(
                "serve.window_ms must be positive, got {} (for unbatched serving set max_batch = 1)",
                self.window_ms
            ));
        }
        if self.max_batch == 0 {
            return Err(anyhow!("serve.max_batch must be at least 1"));
        }
        if self.queue_depth == 0 {
            return Err(anyhow!("serve.queue_depth must be at least 1"));
        }
        if self.workers == 0 {
            return Err(anyhow!("serve.workers must be at least 1"));
        }
        if self.threads == 0 {
            return Err(anyhow!("serve.threads must be at least 1"));
        }
        if self.cache_capacity == 0 {
            return Err(anyhow!("serve.cache_capacity must be at least 1"));
        }
        if self.drain_ms.is_nan() || self.drain_ms <= 0.0 {
            return Err(anyhow!(
                "serve.drain_ms must be positive, got {}",
                self.drain_ms
            ));
        }
        if !self.deadline_ms.is_finite() || self.deadline_ms < 0.0 {
            return Err(anyhow!(
                "serve.deadline_ms must be zero (off) or positive, got {}",
                self.deadline_ms
            ));
        }
        if !self.idle_timeout_ms.is_finite() || self.idle_timeout_ms < 0.0 {
            return Err(anyhow!(
                "serve.idle_timeout_ms must be zero (off) or positive, got {}",
                self.idle_timeout_ms
            ));
        }
        if self.stream && self.stream_window != 0 {
            let w = round_up_to_block(self.stream_window);
            let largest = self.buckets.largest();
            if w > largest {
                return Err(anyhow!(
                    "serve.stream_window {w} exceeds the largest bucket ({largest})"
                ));
            }
            let halo = self.net_config().receptive_field_reach();
            if w <= 2 * halo {
                return Err(anyhow!(
                    "serve.stream_window {w} must exceed twice the receptive-field \
                     reach (2 x {halo}) of this model geometry"
                ));
            }
        }
        Ok(())
    }

    /// The streaming window the batcher should run with: `None` when
    /// streaming is off, the block-rounded explicit width when one was
    /// given, else the largest bucket — but only when that bucket can
    /// hold two receptive-field halos (the paper-default geometry's
    /// 4800-column halo exceeds the default 4096 bucket, so auto keeps
    /// streaming off there rather than failing startup).
    pub fn resolved_stream_window(&self) -> Option<usize> {
        if !self.stream {
            return None;
        }
        let halo = self.net_config().receptive_field_reach();
        if self.stream_window != 0 {
            return Some(round_up_to_block(self.stream_window));
        }
        let largest = self.buckets.largest();
        (largest > 2 * halo).then_some(largest)
    }

    /// The model geometry this server executes.
    pub fn net_config(&self) -> NetConfig {
        NetConfig {
            channels: self.channels,
            n_blocks: self.n_blocks,
            filter_size: self.filter_size,
            dilation: self.dilation,
        }
    }

    /// The per-worker engine slice of this config.
    pub fn engine_opts(&self) -> EngineOpts {
        EngineOpts::default()
            .with_buckets(self.buckets.clone())
            .with_max_batch(self.max_batch)
            .with_threads(self.threads)
            .with_precision(self.precision)
            .with_partition(self.partition)
            .with_backend(self.backend)
            .with_autotune(self.autotune)
            .with_cache_capacity(self.cache_capacity)
            .with_fuse(self.fuse)
    }

    /// The one config → options mapping: everything the batcher (and the
    /// per-worker engines inside it) runs with, stated through the
    /// [`BatcherOpts`]/[`EngineOpts`] builders so a new option added with
    /// a `Default` never needs a copy-site edit here.
    pub fn into_opts(self) -> BatcherOpts {
        BatcherOpts::default()
            .with_engine(self.engine_opts())
            .with_window(Duration::from_secs_f64(self.window_ms / 1e3))
            .with_queue_depth(self.queue_depth)
            .with_workers(self.workers)
            .with_sockets(self.sockets)
            .with_warm(self.warm)
            .with_stream_window(self.resolved_stream_window())
            .with_deadline(
                (self.deadline_ms > 0.0).then(|| Duration::from_secs_f64(self.deadline_ms / 1e3)),
            )
            .with_max_restarts(self.max_restarts)
    }

    /// The full batcher options of this config (alias of
    /// [`Self::into_opts`] kept for existing call sites).
    pub fn batcher_opts(&self) -> BatcherOpts {
        self.clone().into_opts()
    }

    /// The network front-end options of this config.
    pub fn net_opts(&self) -> NetOpts {
        NetOpts {
            drain: Duration::from_secs_f64(self.drain_ms / 1e3),
            idle_timeout: Duration::from_secs_f64(self.idle_timeout_ms / 1e3),
            ..NetOpts::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = TrainConfig::default();
        assert_eq!(c.channels, 15);
        assert_eq!(c.filter_size, 51);
        assert_eq!(c.dilation, 8);
        assert_eq!(c.padded_width(), 2_400);
    }

    #[test]
    fn file_overrides() {
        let dir = std::env::temp_dir().join("dilconv_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.toml");
        std::fs::write(
            &p,
            r#"
[model]
channels = 16
[train]
lr = 0.001
precision = "bf16"
backend = "onednn"
[topology]
sockets = 4
"#,
        )
        .unwrap();
        let c = TrainConfig::from_file(&p).unwrap();
        assert_eq!(c.channels, 16);
        assert_eq!(c.lr, 0.001);
        assert_eq!(c.precision, Precision::Bf16);
        assert_eq!(c.backend, Backend::Im2col);
        assert_eq!(c.sockets, 4);
        // Untouched defaults survive.
        assert_eq!(c.filter_size, 51);
    }

    #[test]
    fn post_ops_and_autotune_keys() {
        let dir = std::env::temp_dir().join("dilconv_cfg_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.toml");
        std::fs::write(
            &p,
            r#"
[train]
post_ops = "bias_sigmoid"
autotune = true
tune_cache = "tune.json"
"#,
        )
        .unwrap();
        let c = TrainConfig::from_file(&p).unwrap();
        assert_eq!(c.post_ops, PostOps::parse("bias_sigmoid").unwrap());
        assert!(c.autotune);
        assert_eq!(c.tune_cache.as_deref(), Some("tune.json"));
        // Partition defaults to the paper's batch split.
        assert_eq!(c.partition, Partition::Batch);
        // Distributed keys default off / 4 MiB.
        assert!(!c.overlap);
        assert_eq!(c.bucket_mb, 4.0);
        assert_eq!(c.bucket_bytes(), 4 * 1024 * 1024);
        // Defaults: fused bias+relu, no autotune.
        let d = TrainConfig::default();
        assert_eq!(d.post_ops, PostOps::bias_relu());
        assert!(!d.autotune);
        // Bad post-op spec fails loudly.
        std::fs::write(&p, "[train]\npost_ops = \"bias_tanh\"\n").unwrap();
        assert!(TrainConfig::from_file(&p).is_err());
    }

    #[test]
    fn registry_backend_names() {
        let mut c = TrainConfig::default();
        c.apply_backend_name("libxsmm").unwrap();
        assert_eq!(c.backend, Backend::Brgemm);
        c.apply_backend_name("bf16").unwrap();
        assert_eq!(c.backend, Backend::Brgemm);
        assert_eq!(c.precision, Precision::Bf16);
        // Selecting a non-bf16 kernel afterwards resets the implied
        // precision — no sticky bf16 from an earlier choice.
        c.apply_backend_name("onednn").unwrap();
        assert_eq!(c.backend, Backend::Im2col);
        assert_eq!(c.precision, Precision::F32);
        // The i8 kernel name pins the quantized tier, alias included.
        c.apply_backend_name("int8").unwrap();
        assert_eq!(c.backend, Backend::Brgemm);
        assert_eq!(c.precision, Precision::I8);
        assert!(c.apply_backend_name("cuda").is_err());
    }

    #[test]
    fn precision_key_parses_i8() {
        let dir = std::env::temp_dir().join("dilconv_cfg_i8");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.toml");
        std::fs::write(&p, "[serve]\nprecision = \"i8\"\n").unwrap();
        let c = ServeConfig::from_file(&p).unwrap();
        assert_eq!(c.precision, Precision::I8);
        assert_eq!(c.backend, Backend::Brgemm);
        // And the error message names the full vocabulary.
        std::fs::write(&p, "[serve]\nprecision = \"fp8\"\n").unwrap();
        let err = ServeConfig::from_file(&p).unwrap_err().to_string();
        assert!(err.contains("f32|bf16|i8"), "got: {err}");
    }

    #[test]
    fn partition_key_parses() {
        let dir = std::env::temp_dir().join("dilconv_cfg_test5");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.toml");
        std::fs::write(&p, "[train]\npartition = \"grid\"\n").unwrap();
        let c = TrainConfig::from_file(&p).unwrap();
        assert_eq!(c.partition, Partition::Grid);
        // Unknown strategies fail loudly.
        std::fs::write(&p, "[train]\npartition = \"diagonal\"\n").unwrap();
        assert!(TrainConfig::from_file(&p).is_err());
    }

    #[test]
    fn overlap_and_bucket_keys() {
        let dir = std::env::temp_dir().join("dilconv_cfg_test4");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.toml");
        std::fs::write(&p, "[train]\noverlap = true\nbucket_mb = 0.5\n").unwrap();
        let c = TrainConfig::from_file(&p).unwrap();
        assert!(c.overlap);
        assert_eq!(c.bucket_mb, 0.5);
        assert_eq!(c.bucket_bytes(), 512 * 1024);
        // Non-positive budgets fail loudly.
        std::fs::write(&p, "[train]\nbucket_mb = 0\n").unwrap();
        assert!(TrainConfig::from_file(&p).is_err());
    }

    #[test]
    fn serve_section_round_trips() {
        let dir = std::env::temp_dir().join("dilconv_cfg_serve1");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.toml");
        std::fs::write(
            &p,
            r#"
[model]
channels = 8
n_blocks = 2
dilation = 1
[serve]
buckets = "500,2048"
max_batch = 16
window_ms = 5.5
queue_depth = 32
workers = 2
sockets = 2
threads = 4
precision = "bf16"
partition = "grid"
autotune = true
cache_capacity = 3
fuse = false
warm = false
listen = "127.0.0.1:0"
stream_window = 500
drain_ms = 250.0
deadline_ms = 40.0
idle_timeout_ms = 1500.0
max_restarts = 5
"#,
        )
        .unwrap();
        let c = ServeConfig::from_file(&p).unwrap();
        assert_eq!(c.channels, 8);
        assert_eq!(c.n_blocks, 2);
        // 500 rounds up onto the 64-wide block grid.
        assert_eq!(c.buckets.widths(), &[512, 2048]);
        assert_eq!(c.max_batch, 16);
        assert_eq!(c.window_ms, 5.5);
        assert_eq!(c.queue_depth, 32);
        assert_eq!(c.workers, 2);
        assert_eq!(c.sockets, 2);
        assert_eq!(c.threads, 4);
        assert_eq!(c.precision, Precision::Bf16);
        assert_eq!(c.partition, Partition::Grid);
        assert!(c.autotune);
        assert_eq!(c.cache_capacity, 3);
        assert!(!c.fuse);
        assert!(!c.warm);
        // Untouched keys keep defaults.
        assert_eq!(c.filter_size, 51);
        assert_eq!(c.backend, Backend::Brgemm);
        // The derived option structs mirror the config.
        let b = c.batcher_opts();
        assert_eq!(b.engine.max_batch, 16);
        assert_eq!(b.engine.buckets, c.buckets);
        assert!(!b.engine.fuse);
        assert_eq!(b.window, Duration::from_secs_f64(0.0055));
        assert_eq!(b.queue_depth, 32);
        assert_eq!(b.workers, 2);
        assert_eq!(b.sockets, 2);
        assert!(!b.warm);
        assert_eq!(c.net_config().channels, 8);
        // Network/streaming keys: listen address, block-rounded window
        // (n_blocks 2 / dilation 1 keep the halo at 6·25 = 150, so the
        // 512 window clears the 2·halo floor), drain budget.
        assert_eq!(c.listen.as_deref(), Some("127.0.0.1:0"));
        assert!(c.stream);
        assert_eq!(c.drain_ms, 250.0);
        assert_eq!(c.resolved_stream_window(), Some(512));
        assert_eq!(b.stream_window, Some(512));
        // Robustness keys (DESIGN.md §7d) flow into the option structs.
        assert_eq!(c.deadline_ms, 40.0);
        assert_eq!(c.idle_timeout_ms, 1500.0);
        assert_eq!(c.max_restarts, 5);
        assert_eq!(b.deadline, Some(Duration::from_secs_f64(0.040)));
        assert_eq!(b.max_restarts, 5);
        let n = c.net_opts();
        assert_eq!(n.drain, Duration::from_secs_f64(0.250));
        assert_eq!(n.idle_timeout, Duration::from_secs_f64(1.5));
        // deadline_ms = 0 (the default) means no default deadline.
        assert_eq!(ServeConfig::default().batcher_opts().deadline, None);
    }

    #[test]
    fn stream_window_auto_resolution_respects_the_halo() {
        // Default geometry: halo 24 * 200 = 4800 > 4096 (largest default
        // bucket) — auto streaming stays off instead of failing startup.
        let c = ServeConfig::default();
        assert!(c.stream);
        assert_eq!(c.resolved_stream_window(), None);
        assert_eq!(c.batcher_opts().stream_window, None);
        // A shallow geometry auto-streams at the largest bucket.
        let shallow = ServeConfig {
            channels: 4,
            n_blocks: 1,
            filter_size: 9,
            dilation: 2, // halo 32
            ..ServeConfig::default()
        };
        assert_eq!(shallow.resolved_stream_window(), Some(4096));
        // `stream = false` switches the route off entirely.
        let off = ServeConfig {
            stream: false,
            ..shallow
        };
        assert_eq!(off.resolved_stream_window(), None);
    }

    #[test]
    fn serve_flags_round_trip() {
        let mut c = ServeConfig::default();
        for (k, v) in [
            ("buckets", "128,256"),
            ("max-batch", "4"),
            ("window-ms", "1.5"),
            ("queue", "10"),
            ("workers", "3"),
            ("sockets", "2"),
            ("threads", "2"),
            ("cache-capacity", "2"),
            ("precision", "bf16"),
            ("partition", "grid"),
            ("autotune", "true"),
            ("fuse", "false"),
            ("no-warm", "true"),
            ("listen", "0.0.0.0:9000"),
            // `stream = false`: the default geometry's halo (4800) fits
            // no 256-wide bucket, so an *active* explicit window would
            // fail validate below — ownership is what this test checks.
            ("stream", "false"),
            ("stream-window", "100"),
            ("drain-ms", "100"),
            ("deadline-ms", "25"),
            ("idle-timeout-ms", "0"),
            ("max-restarts", "2"),
        ] {
            assert!(c.apply_flag(k, v).unwrap(), "--{k} must be owned");
        }
        assert_eq!(c.buckets.widths(), &[128, 256]);
        assert_eq!(c.max_batch, 4);
        assert_eq!(c.window_ms, 1.5);
        assert_eq!(c.queue_depth, 10);
        assert_eq!(c.workers, 3);
        assert_eq!(c.sockets, 2);
        assert_eq!(c.threads, 2);
        assert_eq!(c.cache_capacity, 2);
        assert_eq!(c.precision, Precision::Bf16);
        assert_eq!(c.partition, Partition::Grid);
        assert!(c.autotune && !c.warm && !c.fuse);
        assert_eq!(c.listen.as_deref(), Some("0.0.0.0:9000"));
        assert!(!c.stream);
        assert_eq!(c.stream_window, 100);
        assert_eq!(c.drain_ms, 100.0);
        assert_eq!(c.deadline_ms, 25.0);
        assert_eq!(c.idle_timeout_ms, 0.0);
        assert_eq!(c.max_restarts, 2);
        assert_eq!(
            c.net_opts().idle_timeout,
            Duration::ZERO,
            "0 disables the idle reaper"
        );
        assert_eq!(c.resolved_stream_window(), None, "stream=false wins");
        c.validate().unwrap();
        // Backend names resolve through the registry; "bf16" pins both.
        c.apply_flag("backend", "onednn").unwrap();
        assert_eq!((c.backend, c.precision), (Backend::Im2col, Precision::F32));
        c.apply_flag("backend", "bf16").unwrap();
        assert_eq!((c.backend, c.precision), (Backend::Brgemm, Precision::Bf16));
        // Unknown keys are not owned; bad values fail loudly.
        assert!(!c.apply_flag("epochs", "3").unwrap());
        assert!(c.apply_flag("max-batch", "x").is_err());
        assert!(c.apply_flag("buckets", "0").is_err());
        assert!(c.apply_flag("backend", "cuda").is_err());
        assert!(c.apply_flag("precision", "fp8").is_err());
        // Booleans are strict: a typo must fail, not silently mean false.
        assert!(c.apply_flag("autotune", "ture").is_err());
        assert!(c.apply_flag("no-warm", "maybe").is_err());
        c.apply_flag("autotune", "false").unwrap();
        assert!(!c.autotune);
    }

    #[test]
    fn serve_rejects_invalid_values() {
        let dir = std::env::temp_dir().join("dilconv_cfg_serve2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.toml");
        // Zero batching window.
        std::fs::write(&p, "[serve]\nwindow_ms = 0\n").unwrap();
        assert!(ServeConfig::from_file(&p).is_err());
        std::fs::write(&p, "[serve]\nwindow_ms = -1.0\n").unwrap();
        assert!(ServeConfig::from_file(&p).is_err());
        // Empty bucket set.
        std::fs::write(&p, "[serve]\nbuckets = \"\"\n").unwrap();
        assert!(ServeConfig::from_file(&p).is_err());
        std::fs::write(&p, "[serve]\nbuckets = \"1024,0\"\n").unwrap();
        assert!(ServeConfig::from_file(&p).is_err());
        // Negative robustness knobs (0 is legal: it means "off").
        std::fs::write(&p, "[serve]\ndeadline_ms = -1.0\n").unwrap();
        assert!(ServeConfig::from_file(&p).is_err());
        std::fs::write(&p, "[serve]\nidle_timeout_ms = -5.0\n").unwrap();
        assert!(ServeConfig::from_file(&p).is_err());
        std::fs::write(&p, "[serve]\ndeadline_ms = 0.0\nidle_timeout_ms = 0.0\n").unwrap();
        assert!(ServeConfig::from_file(&p).is_ok());
        // Zero sizes.
        for key in ["max_batch", "queue_depth", "workers", "threads", "cache_capacity"] {
            std::fs::write(&p, format!("[serve]\n{key} = 0\n")).unwrap();
            assert!(ServeConfig::from_file(&p).is_err(), "{key} = 0 must fail");
        }
        // Non-positive drain budget.
        std::fs::write(&p, "[serve]\ndrain_ms = 0\n").unwrap();
        assert!(ServeConfig::from_file(&p).is_err());
        // An active stream window must clear the geometry checks: the
        // default model's halo is 4800, so 128 (≤ 2·halo) must fail …
        std::fs::write(&p, "[serve]\nstream_window = 128\n").unwrap();
        assert!(ServeConfig::from_file(&p).is_err());
        // … and any window must fit the largest bucket.
        std::fs::write(
            &p,
            "[model]\nchannels = 4\nn_blocks = 1\nfilter_size = 9\ndilation = 2\n\
             [serve]\nbuckets = \"128\"\nstream_window = 512\n",
        )
        .unwrap();
        assert!(ServeConfig::from_file(&p).is_err());
        // A default config validates.
        ServeConfig::default().validate().unwrap();
    }

    #[test]
    fn bad_precision_fails() {
        let dir = std::env::temp_dir().join("dilconv_cfg_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.toml");
        std::fs::write(&p, "[train]\nprecision = \"fp8\"\n").unwrap();
        assert!(TrainConfig::from_file(&p).is_err());
    }
}
