//! Configuration system: a TOML-subset reader ([`toml`]) plus the typed
//! experiment/training configuration used by the launcher and coordinator.

pub mod toml;

use crate::conv1d::{Backend, Partition, PostOps};
use crate::machine::Precision;

use anyhow::{anyhow, Context, Result};
use std::path::Path;

/// Full training-run configuration (CLI defaults ≈ a width-scaled version
/// of the paper's Sec. 4.2 setup that runs in seconds on this host).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    // Model (paper Sec. 4.2).
    pub channels: usize,
    pub n_blocks: usize,
    pub filter_size: usize,
    pub dilation: usize,
    // Data.
    pub segment_width: usize,
    pub segment_pad: usize,
    pub train_segments: usize,
    pub seed: u64,
    // Training.
    pub batch_size: usize,
    pub epochs: usize,
    pub lr: f64,
    pub precision: Precision,
    pub backend: Backend,
    /// Fused post-op spec for the network body (`post_ops = "bias_relu"`):
    /// the activation is applied inside the conv kernels' output-block
    /// loop; the ResNet block tails additionally fuse the residual add.
    pub post_ops: PostOps,
    /// Work partitioning the conv kernels split across threads
    /// (`partition = "batch"` or `"grid"`): `grid` splits the
    /// `N × ceil(Q/64)` width-block grid so small-batch / long-sequence
    /// runs still use every thread.
    pub partition: Partition,
    /// Choose each layer's kernel per shape via the autotuner
    /// (`autotune = true`) instead of pinning `backend`.
    pub autotune: bool,
    /// Persisted tuning table (JSON): loaded before training to
    /// warm-start the autotuner, written back after.
    pub tune_cache: Option<String>,
    // Distributed training (DESIGN.md §6).
    /// Overlap gradient communication with the backward pass: fire each
    /// gradient bucket's ring all-reduce the moment its layers finish
    /// differentiating (`overlap = true`), instead of one monolithic
    /// all-reduce after backward. Bit-identical results either way.
    pub overlap: bool,
    /// Gradient bucket budget in MiB (`bucket_mb = 4.0`): the flat
    /// gradient is cut into whole-layer buckets of at most this many
    /// bytes, in backward completion order.
    pub bucket_mb: f64,
    // Topology.
    pub sockets: usize,
    pub threads_per_socket: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            channels: 15,
            n_blocks: 11,
            filter_size: 51,
            dilation: 8,
            segment_width: 2_000, // paper: 50_000 (scaled for this host)
            segment_pad: 200,     // paper: 5_000
            train_segments: 64,   // paper: 32_000
            seed: 42,
            batch_size: 4,        // paper: 54/64 per socket
            epochs: 3,            // paper: 25
            lr: 2e-4,
            precision: Precision::F32,
            backend: Backend::Brgemm,
            post_ops: PostOps::bias_relu(),
            partition: Partition::Batch,
            autotune: false,
            tune_cache: None,
            overlap: false,
            bucket_mb: 4.0,
            sockets: 1,
            threads_per_socket: 1,
        }
    }
}

impl TrainConfig {
    /// The paper's full-scale configuration (Sec. 4.2) — hours of compute;
    /// used by the machine-model projections, not for local runs.
    pub fn paper_full() -> Self {
        TrainConfig {
            segment_width: 50_000,
            segment_pad: 5_000,
            train_segments: 32_000,
            batch_size: 54,
            epochs: 25,
            threads_per_socket: 27,
            ..Default::default()
        }
    }

    /// Load from a TOML file, starting from `Default` and overriding any
    /// key present.
    pub fn from_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading config {:?}", path.as_ref()))?;
        let doc = toml::parse(&text).map_err(|e| anyhow!("config parse error: {e}"))?;
        let mut cfg = TrainConfig::default();
        let u = |doc: &toml::Doc, sec: &str, key: &str, dst: &mut usize| {
            if let Some(v) = toml::get_usize(doc, sec, key) {
                *dst = v;
            }
        };
        u(&doc, "model", "channels", &mut cfg.channels);
        u(&doc, "model", "n_blocks", &mut cfg.n_blocks);
        u(&doc, "model", "filter_size", &mut cfg.filter_size);
        u(&doc, "model", "dilation", &mut cfg.dilation);
        u(&doc, "data", "segment_width", &mut cfg.segment_width);
        u(&doc, "data", "segment_pad", &mut cfg.segment_pad);
        u(&doc, "data", "train_segments", &mut cfg.train_segments);
        u(&doc, "train", "batch_size", &mut cfg.batch_size);
        u(&doc, "train", "epochs", &mut cfg.epochs);
        u(&doc, "topology", "sockets", &mut cfg.sockets);
        u(&doc, "topology", "threads_per_socket", &mut cfg.threads_per_socket);
        if let Some(v) = toml::get_usize(&doc, "data", "seed") {
            cfg.seed = v as u64;
        }
        if let Some(v) = toml::get_f64(&doc, "train", "lr") {
            cfg.lr = v;
        }
        // Backend before precision: a backend name implies a precision
        // (see apply_backend_name), so an explicit `precision` key stays
        // authoritative when both are given.
        if let Some(s) = toml::get_str(&doc, "train", "backend") {
            cfg.apply_backend_name(s).map_err(|e| anyhow!(e))?;
        }
        if let Some(s) = toml::get_str(&doc, "train", "precision") {
            cfg.precision = match s.to_ascii_lowercase().as_str() {
                "f32" | "fp32" => Precision::F32,
                "bf16" | "bfloat16" => Precision::Bf16,
                other => return Err(anyhow!("unknown precision '{other}'")),
            };
        }
        if let Some(s) = toml::get_str(&doc, "train", "post_ops") {
            cfg.post_ops = PostOps::parse(s).map_err(|e| anyhow!(e))?;
        }
        if let Some(s) = toml::get_str(&doc, "train", "partition") {
            cfg.partition = s.parse().map_err(|e: String| anyhow!(e))?;
        }
        if let Some(b) = toml::get_bool(&doc, "train", "autotune") {
            cfg.autotune = b;
        }
        if let Some(s) = toml::get_str(&doc, "train", "tune_cache") {
            cfg.tune_cache = Some(s.to_string());
        }
        if let Some(b) = toml::get_bool(&doc, "train", "overlap") {
            cfg.overlap = b;
        }
        if let Some(v) = toml::get_f64(&doc, "train", "bucket_mb") {
            if v <= 0.0 {
                return Err(anyhow!("bucket_mb must be positive, got {v}"));
            }
            cfg.bucket_mb = v;
        }
        Ok(cfg)
    }

    /// Select the conv backend by **registry name** (any alias accepted by
    /// [`crate::conv1d::lookup_kernel`]) — so configs pick any registered
    /// kernel without the enum ever growing. A kernel name pins the
    /// precision too: `"bf16"` means the BRGEMM backend at
    /// `Precision::Bf16`, every other name means f32 — a later
    /// `precision` setting can still override.
    pub fn apply_backend_name(&mut self, name: &str) -> Result<(), String> {
        let kernel = crate::conv1d::lookup_kernel(name)
            .ok_or_else(|| format!("unknown backend '{name}'"))?;
        match kernel.name() {
            "bf16" => {
                self.backend = Backend::Brgemm;
                self.precision = Precision::Bf16;
            }
            canonical => {
                self.backend = canonical.parse()?;
                self.precision = Precision::F32;
            }
        }
        Ok(())
    }

    /// Padded track width the network sees.
    pub fn padded_width(&self) -> usize {
        self.segment_width + 2 * self.segment_pad
    }

    /// The gradient bucket budget in bytes (f32 elements × 4).
    pub fn bucket_bytes(&self) -> usize {
        (self.bucket_mb * 1024.0 * 1024.0).max(4.0) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = TrainConfig::default();
        assert_eq!(c.channels, 15);
        assert_eq!(c.filter_size, 51);
        assert_eq!(c.dilation, 8);
        assert_eq!(c.padded_width(), 2_400);
    }

    #[test]
    fn file_overrides() {
        let dir = std::env::temp_dir().join("dilconv_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.toml");
        std::fs::write(
            &p,
            r#"
[model]
channels = 16
[train]
lr = 0.001
precision = "bf16"
backend = "onednn"
[topology]
sockets = 4
"#,
        )
        .unwrap();
        let c = TrainConfig::from_file(&p).unwrap();
        assert_eq!(c.channels, 16);
        assert_eq!(c.lr, 0.001);
        assert_eq!(c.precision, Precision::Bf16);
        assert_eq!(c.backend, Backend::Im2col);
        assert_eq!(c.sockets, 4);
        // Untouched defaults survive.
        assert_eq!(c.filter_size, 51);
    }

    #[test]
    fn post_ops_and_autotune_keys() {
        let dir = std::env::temp_dir().join("dilconv_cfg_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.toml");
        std::fs::write(
            &p,
            r#"
[train]
post_ops = "bias_sigmoid"
autotune = true
tune_cache = "tune.json"
"#,
        )
        .unwrap();
        let c = TrainConfig::from_file(&p).unwrap();
        assert_eq!(c.post_ops, PostOps::parse("bias_sigmoid").unwrap());
        assert!(c.autotune);
        assert_eq!(c.tune_cache.as_deref(), Some("tune.json"));
        // Partition defaults to the paper's batch split.
        assert_eq!(c.partition, Partition::Batch);
        // Distributed keys default off / 4 MiB.
        assert!(!c.overlap);
        assert_eq!(c.bucket_mb, 4.0);
        assert_eq!(c.bucket_bytes(), 4 * 1024 * 1024);
        // Defaults: fused bias+relu, no autotune.
        let d = TrainConfig::default();
        assert_eq!(d.post_ops, PostOps::bias_relu());
        assert!(!d.autotune);
        // Bad post-op spec fails loudly.
        std::fs::write(&p, "[train]\npost_ops = \"bias_tanh\"\n").unwrap();
        assert!(TrainConfig::from_file(&p).is_err());
    }

    #[test]
    fn registry_backend_names() {
        let mut c = TrainConfig::default();
        c.apply_backend_name("libxsmm").unwrap();
        assert_eq!(c.backend, Backend::Brgemm);
        c.apply_backend_name("bf16").unwrap();
        assert_eq!(c.backend, Backend::Brgemm);
        assert_eq!(c.precision, Precision::Bf16);
        // Selecting a non-bf16 kernel afterwards resets the implied
        // precision — no sticky bf16 from an earlier choice.
        c.apply_backend_name("onednn").unwrap();
        assert_eq!(c.backend, Backend::Im2col);
        assert_eq!(c.precision, Precision::F32);
        assert!(c.apply_backend_name("cuda").is_err());
    }

    #[test]
    fn partition_key_parses() {
        let dir = std::env::temp_dir().join("dilconv_cfg_test5");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.toml");
        std::fs::write(&p, "[train]\npartition = \"grid\"\n").unwrap();
        let c = TrainConfig::from_file(&p).unwrap();
        assert_eq!(c.partition, Partition::Grid);
        // Unknown strategies fail loudly.
        std::fs::write(&p, "[train]\npartition = \"diagonal\"\n").unwrap();
        assert!(TrainConfig::from_file(&p).is_err());
    }

    #[test]
    fn overlap_and_bucket_keys() {
        let dir = std::env::temp_dir().join("dilconv_cfg_test4");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.toml");
        std::fs::write(&p, "[train]\noverlap = true\nbucket_mb = 0.5\n").unwrap();
        let c = TrainConfig::from_file(&p).unwrap();
        assert!(c.overlap);
        assert_eq!(c.bucket_mb, 0.5);
        assert_eq!(c.bucket_bytes(), 512 * 1024);
        // Non-positive budgets fail loudly.
        std::fs::write(&p, "[train]\nbucket_mb = 0\n").unwrap();
        assert!(TrainConfig::from_file(&p).is_err());
    }

    #[test]
    fn bad_precision_fails() {
        let dir = std::env::temp_dir().join("dilconv_cfg_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.toml");
        std::fs::write(&p, "[train]\nprecision = \"fp8\"\n").unwrap();
        assert!(TrainConfig::from_file(&p).is_err());
    }
}
