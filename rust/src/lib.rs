//! # dilconv1d
//!
//! Rust + JAX + Pallas reproduction of *"Efficient and Generic 1D Dilated
//! Convolution Layer for Deep Learning"* (Chaudhary et al., 2021).
//!
//! The crate is a three-layer system (see `rust/DESIGN.md`):
//!
//! * **L3 (this crate)** — the framework: the paper's BRGEMM convolution
//!   kernels ([`conv1d`]), a native training engine with BF16
//!   mixed-precision support ([`model`]), a data pipeline ([`data`]),
//!   metrics ([`metrics`]), a simulated multi-socket runtime with
//!   bucketed backward-overlapped all-reduce ([`dist`]), machine models
//!   of the paper's testbeds ([`machine`]), the training coordinator
//!   ([`coordinator`]), a batched inference serving subsystem with a
//!   shape-bucketed plan cache ([`serve`]), the benchmark harness
//!   ([`bench_harness`]) and a TOML config system ([`config`]).
//! * **L2/L1 (Python, build-time only)** — a JAX AtacWorks model with
//!   Pallas conv kernels, AOT-lowered to HLO text executed by [`runtime`]
//!   through the PJRT CPU client. Python never runs on the training path.
//!
//! ## Quickstart
//!
//! The core object is the *setup-once, run-many* [`ConvPlan`]
//! (DESIGN.md §5a): build it from a problem descriptor and a registry
//! kernel name, then execute with zero steady-state allocations —
//!
//! ```
//! use dilconv1d::{ConvParams, ConvPlan, PostOps};
//!
//! let p = ConvParams::new(1, 1, 1, 16, 3, 2).unwrap(); // Q = 12
//! let mut plan = ConvPlan::by_name(p, "brgemm", 1, vec![1.0f32; 3])
//!     .unwrap()
//!     .with_post_ops(PostOps::parse("relu").unwrap());
//! let x = vec![1.0f32; 16];
//! let mut y = vec![0.0f32; 12];
//! plan.execute_forward_post_into(&x, None, &mut y); // fused epilogue
//! assert!(y.iter().all(|&v| (v - 3.0).abs() < 1e-6)); // 3 taps of 1·1
//! ```
//!
//! End-to-end training (data → kernels → collectives → Adam) lives
//! behind [`coordinator::Trainer`]; `dilconv train` (see `main.rs` and
//! the repository README) is the CLI over it.

pub mod bench_harness;
pub mod config;
pub mod conv1d;
pub mod coordinator;
pub mod data;
pub mod dist;
pub mod machine;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod serve;
pub mod util;

pub use conv1d::{
    autotuner, Activation, Autotuner, Backend, Conv1dLayer, ConvKernel, ConvParams, ConvPlan,
    PostOps,
};
