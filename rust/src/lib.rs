//! # dilconv1d
//!
//! Rust + JAX + Pallas reproduction of *"Efficient and Generic 1D Dilated
//! Convolution Layer for Deep Learning"* (Chaudhary et al., 2021).
//!
//! The crate is a three-layer system (see `rust/DESIGN.md`):
//!
//! * **L3 (this crate)** — the framework: the paper's BRGEMM convolution
//!   kernels ([`conv1d`]), a native training engine ([`model`]), a data
//!   pipeline ([`data`]), metrics ([`metrics`]), a simulated multi-socket
//!   runtime ([`dist`]), machine models of the paper's testbeds
//!   ([`machine`]), the training coordinator ([`coordinator`]), the
//!   benchmark harness ([`bench_harness`]) and a TOML config system
//!   ([`config`]).
//! * **L2/L1 (Python, build-time only)** — a JAX AtacWorks model with
//!   Pallas conv kernels, AOT-lowered to HLO text executed by [`runtime`]
//!   through the PJRT CPU client. Python never runs on the training path.

pub mod bench_harness;
pub mod config;
pub mod conv1d;
pub mod coordinator;
pub mod data;
pub mod dist;
pub mod machine;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod util;

pub use conv1d::{
    autotuner, Activation, Autotuner, Backend, Conv1dLayer, ConvKernel, ConvParams, ConvPlan,
    PostOps,
};
