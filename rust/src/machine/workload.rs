//! End-to-end AtacWorks epoch-time model: composes the per-layer roofline
//! projections, the data-parallel topology and the α–β communication model
//! into the paper's Table 1 / Table 2 / Figs 7–10 quantities.
//!
//! Time per epoch =
//!     Σ_steps [ max-shard compute (fwd + bwd over 25 conv layers) ]
//!   + Σ_steps [ ring all-reduce of the parameter-sized gradient ]
//!   + eval time (single-threaded-per-socket, does not scale — Sec. 4.5.2)

use crate::conv1d::ConvParams;
use crate::dist::comm_model::CommModel;
use crate::dist::topology::Topology;
use crate::machine::roofline::{project, Strategy};
use crate::machine::spec::{MachineSpec, Precision};
use crate::model::NetConfig;

/// The paper's end-to-end workload constants (Sec. 4.2).
#[derive(Debug, Clone, Copy)]
pub struct Workload {
    pub net: NetConfig,
    /// Padded segment width (60 000).
    pub width: usize,
    /// Training segments per epoch (32 000).
    pub train_segments: usize,
    /// Validation segments (1 280).
    pub val_segments: usize,
}

impl Workload {
    pub fn paper() -> Self {
        Workload {
            net: NetConfig::default(),
            width: 60_000,
            train_segments: 32_000,
            val_segments: 1_280,
        }
    }

    /// §4.5.3 long-segment variant: 600 000-wide, 4 191 segments.
    pub fn long_segments() -> Self {
        Workload {
            net: NetConfig::default(),
            width: 600_000,
            train_segments: 4_191,
            val_segments: 101,
        }
    }

    /// §4.5.4 large-dataset variant: 293 242 segments.
    pub fn large_dataset() -> Self {
        Workload {
            train_segments: 293_242,
            val_segments: 2_520,
            ..Workload::paper()
        }
    }

    /// Forward FLOPs of one sample through all conv layers.
    pub fn fwd_flops_per_sample(&self) -> u64 {
        self.net
            .layer_shapes()
            .iter()
            .map(|&(k, c, s)| 2 * (k * c * s * self.width) as u64)
            .sum()
    }

    /// Train FLOPs of one sample: forward + backward-data + backward-weight
    /// ≈ 3× forward (each backward pass has the same MAC count, Alg. 3/4).
    pub fn train_flops_per_sample(&self) -> u64 {
        3 * self.fwd_flops_per_sample()
    }

    /// Flat parameter count (gradient length for the all-reduce).
    pub fn param_count(&self) -> usize {
        self.net.param_count()
    }
}

/// Modelled epoch-time breakdown.
#[derive(Debug, Clone, Copy)]
pub struct EpochModel {
    pub compute_secs: f64,
    pub comm_secs: f64,
    pub eval_secs: f64,
}

impl EpochModel {
    pub fn total(&self) -> f64 {
        self.compute_secs + self.comm_secs + self.eval_secs
    }
}

/// Sustained per-socket training throughput (FLOP/s) for the workload's
/// dominant layer under a kernel strategy.
pub fn socket_throughput(
    w: &Workload,
    spec: &MachineSpec,
    prec: Precision,
    strategy: Strategy,
    topo: &Topology,
) -> f64 {
    // Dominant layer: the ch→ch dilated conv.
    let p = ConvParams::with_same_padding(
        topo.paper_batch_size() / topo.sockets,
        w.net.channels,
        w.net.channels,
        w.width,
        w.net.filter_size,
        w.net.dilation,
    )
    .expect("invalid workload layer");
    let proj = project(&p, strategy, spec, prec, topo.compute_cores());
    proj.efficiency * spec.peak_per_core(prec) * topo.compute_cores() as f64
}

/// Model a full training epoch on `topo` sockets of `spec`.
pub fn model_epoch(
    w: &Workload,
    spec: &MachineSpec,
    prec: Precision,
    strategy: Strategy,
    topo: &Topology,
    comm: &CommModel,
) -> EpochModel {
    let tput = socket_throughput(w, spec, prec, strategy, topo);
    let total_flops = w.train_flops_per_sample() as f64 * w.train_segments as f64;
    let compute_secs = total_flops / (tput * topo.sockets as f64);

    let global_batch = topo.paper_batch_size();
    let steps = w.train_segments / global_batch.max(1);
    let comm_secs = steps as f64 * comm.ring_allreduce_secs(w.param_count(), topo.sockets);

    // Evaluation "is single threaded and doesn't scale" (Sec. 4.5.2):
    // one socket's throughput regardless of the topology.
    let topo1 = Topology::new(1, topo.cores_per_socket);
    let tput1 = socket_throughput(w, spec, prec, strategy, &topo1);
    let eval_secs = w.fwd_flops_per_sample() as f64 * w.val_segments as f64 / tput1;

    EpochModel {
        compute_secs,
        comm_secs,
        eval_secs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flops_per_sample_matches_hand_count() {
        let w = Workload::paper();
        // Σ 2·k·c·s·W: stem 15·1 + 22 blocks·225 + heads 2·15 channels·filters
        let units: usize = w.net.layer_shapes().iter().map(|&(k, c, _)| k * c).sum();
        assert_eq!(units, 15 + 22 * 225 + 2 * 15);
        assert_eq!(
            w.fwd_flops_per_sample(),
            2 * (units * 51 * 60_000) as u64
        );
    }

    #[test]
    fn table1_shape_onednn_vs_brgemm() {
        // Paper Table 1: oneDNN 9690 s vs LIBXSMM 1412 s on 1s CLX (6.86×).
        let w = Workload::paper();
        let clx = MachineSpec::cascade_lake();
        let topo = Topology::xeon(1);
        let comm = CommModel::upi();
        let ours = model_epoch(&w, &clx, Precision::F32, Strategy::Brgemm, &topo, &comm);
        let lib = model_epoch(&w, &clx, Precision::F32, Strategy::Im2col, &topo, &comm);
        let speedup = lib.total() / ours.total();
        assert!(
            speedup > 2.0 && speedup < 12.0,
            "modeled oneDNN/BRGEMM speedup {speedup} out of plausible band"
        );
        // Modeled LIBXSMM CLX epoch in the same order of magnitude as 1412 s.
        assert!(
            ours.total() > 300.0 && ours.total() < 5_000.0,
            "modeled epoch {}s",
            ours.total()
        );
    }

    #[test]
    fn scaling_is_near_linear_to_16_sockets() {
        let w = Workload::paper();
        let cpx = MachineSpec::cooper_lake();
        let comm = CommModel::fabric();
        let t1 = model_epoch(&w, &cpx, Precision::F32, Strategy::Brgemm, &Topology::xeon(1), &comm);
        let t16 = model_epoch(&w, &cpx, Precision::F32, Strategy::Brgemm, &Topology::xeon(16), &comm);
        // Compute scales ~16x, eval does not; speedup lands well below 16
        // but comfortably above 4 (paper Fig. 8 shows near-linear *train*).
        let sp = t1.total() / t16.total();
        assert!(sp > 4.0 && sp <= 16.0, "16-socket speedup {sp}");
        let train_sp = t1.compute_secs / (t16.compute_secs + t16.comm_secs);
        assert!(train_sp > 10.0, "train-only speedup {train_sp}");
    }

    #[test]
    fn bf16_on_cpx_beats_f32() {
        let w = Workload::paper();
        let cpx = MachineSpec::cooper_lake();
        let comm = CommModel::fabric();
        let topo = Topology::xeon(16);
        let f = model_epoch(&w, &cpx, Precision::F32, Strategy::Brgemm, &topo, &comm);
        let b = model_epoch(&w, &cpx, Precision::Bf16, Strategy::Brgemm, &topo, &comm);
        let sp = f.total() / b.total();
        assert!(sp > 1.2 && sp < 2.1, "bf16 speedup {sp}");
    }

    #[test]
    fn long_segment_epoch_larger_per_segment() {
        let w = Workload::long_segments();
        assert_eq!(w.fwd_flops_per_sample() / Workload::paper().fwd_flops_per_sample(), 10);
    }
}
