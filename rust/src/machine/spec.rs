//! Machine models of the paper's testbeds (Sec. 4.1) — the hardware
//! substitution substrate (DESIGN.md §4, substitution 3).

/// Numeric precision of a kernel run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    F32,
    Bf16,
    /// Int8 per-channel symmetric quantized inference (i32 accumulate,
    /// f32 dequantized output). Inference-only: gradients stay f32.
    I8,
}

/// A CPU-socket (or GPU) performance description.
#[derive(Debug, Clone, Copy)]
pub struct MachineSpec {
    pub name: &'static str,
    /// Physical cores per socket.
    pub cores: usize,
    /// Base clock (Hz).
    pub base_hz: f64,
    /// All-core turbo clock (Hz) — the paper enables turbo.
    pub turbo_hz: f64,
    /// Peak FP32 FLOP/s per socket.
    pub peak_f32: f64,
    /// Peak BF16 FLOP/s per socket (== f32 peak when unsupported).
    pub peak_bf16: f64,
    /// Per-core L2 bytes.
    pub l2_bytes: usize,
    /// Shared L3 bytes.
    pub l3_bytes: usize,
    /// Sustainable DRAM bandwidth per socket (bytes/s).
    pub dram_bw: f64,
}

impl MachineSpec {
    /// Intel Xeon Platinum 8280 — Cascade Lake (paper Sec. 4.1):
    /// 28 cores @ 2.7 GHz base / 4.0 GHz max turbo, AVX-512,
    /// 4.3 TFLOPS FP32 peak, 1 MB L2/core, 38.5 MB L3.
    pub fn cascade_lake() -> Self {
        MachineSpec {
            name: "CLX",
            cores: 28,
            base_hz: 2.7e9,
            turbo_hz: 4.0e9,
            peak_f32: 4.3e12,
            peak_bf16: 4.3e12, // no AVX512-BF16 on CLX
            l2_bytes: 1 << 20,
            l3_bytes: 38_500_000,
            dram_bw: 120e9,
        }
    }

    /// Intel Xeon Platinum 8380HL — Cooper Lake (paper Sec. 4.1):
    /// 28 cores @ 2.9 GHz / 4.3 GHz turbo, AVX-512 + AVX512-BF16,
    /// 4.66 TFLOPS FP32 / 9.32 TFLOPS BF16.
    pub fn cooper_lake() -> Self {
        MachineSpec {
            name: "CPX",
            cores: 28,
            base_hz: 2.9e9,
            turbo_hz: 4.3e9,
            peak_f32: 4.66e12,
            peak_bf16: 9.32e12,
            l2_bytes: 1 << 20,
            l3_bytes: 38_500_000,
            dram_bw: 140e9,
        }
    }

    /// Nvidia V100 (DGX-1 member, paper Sec. 4.5.2 comparison).
    /// 15.7 TFLOPS FP32; AtacWorks uses the CUDA FP32 path.
    pub fn v100() -> Self {
        MachineSpec {
            name: "V100",
            cores: 80, // SMs
            base_hz: 1.53e9,
            turbo_hz: 1.53e9,
            peak_f32: 15.7e12,
            peak_bf16: 15.7e12,
            l2_bytes: 6 << 20,
            l3_bytes: 6 << 20,
            dram_bw: 900e9,
        }
    }

    /// The host this repository actually runs on: a single core with
    /// `measured_gflops` sustained f32 GEMM throughput (calibrated at
    /// startup by [`super::roofline::calibrate_host`]).
    pub fn host(measured_gflops: f64) -> Self {
        MachineSpec {
            name: "HOST",
            cores: 1,
            base_hz: 3.0e9,
            turbo_hz: 3.0e9,
            peak_f32: measured_gflops * 1e9,
            peak_bf16: measured_gflops * 1e9,
            l2_bytes: 1 << 20,
            l3_bytes: 32 << 20,
            dram_bw: 20e9,
        }
    }

    /// Peak FLOP/s for a precision. Int8 is modelled as 2× the bf16
    /// rate — the VNNI dot-product pipeline doubles MACs per cycle over
    /// the bf16 FMA path on the same hardware generation (and degrades
    /// to the bf16 rate where neither instruction set exists, since
    /// `peak_bf16 == peak_f32` on those specs).
    pub fn peak(&self, prec: Precision) -> f64 {
        match prec {
            Precision::F32 => self.peak_f32,
            Precision::Bf16 => self.peak_bf16,
            Precision::I8 => 2.0 * self.peak_bf16,
        }
    }

    /// Peak per core.
    pub fn peak_per_core(&self, prec: Precision) -> f64 {
        self.peak(prec) / self.cores as f64
    }

    /// Whole-node peak: `sockets` sockets of this spec. [`Self::peak`]
    /// stays per-socket, so multi-socket roofline rows must divide by
    /// this — not the per-socket peak — when they quote node
    /// efficiency; quoting both makes the communication loss visible
    /// (per-socket efficiency holds up while node efficiency drops).
    pub fn peak_node(&self, prec: Precision, sockets: usize) -> f64 {
        self.peak(prec) * sockets.max(1) as f64
    }

    /// Parse a spec by name ("clx", "cpx", "v100").
    pub fn by_name(name: &str) -> Option<MachineSpec> {
        match name.to_ascii_lowercase().as_str() {
            "clx" | "cascade" | "cascadelake" => Some(Self::cascade_lake()),
            "cpx" | "cooper" | "cooperlake" => Some(Self::cooper_lake()),
            "v100" | "gpu" => Some(Self::v100()),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_peaks() {
        let clx = MachineSpec::cascade_lake();
        assert_eq!(clx.peak(Precision::F32), 4.3e12);
        let cpx = MachineSpec::cooper_lake();
        assert_eq!(cpx.peak(Precision::Bf16), 9.32e12);
        // BF16 peak is exactly 2× the FP32 peak on CPX (paper Sec. 4.1).
        assert_eq!(cpx.peak_bf16 / cpx.peak_f32, 2.0);
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(MachineSpec::by_name("CLX").unwrap().name, "CLX");
        assert_eq!(MachineSpec::by_name("cooper").unwrap().name, "CPX");
        assert!(MachineSpec::by_name("tpu").is_none());
    }

    #[test]
    fn node_peak_scales_with_sockets() {
        let cpx = MachineSpec::cooper_lake();
        assert_eq!(cpx.peak_node(Precision::F32, 1), cpx.peak(Precision::F32));
        assert_eq!(
            cpx.peak_node(Precision::Bf16, 16),
            16.0 * cpx.peak(Precision::Bf16)
        );
        // Degenerate socket counts clamp to one socket.
        assert_eq!(cpx.peak_node(Precision::F32, 0), cpx.peak(Precision::F32));
    }

    #[test]
    fn per_core_peak() {
        let clx = MachineSpec::cascade_lake();
        // 4.3 TF / 28 cores ≈ 153.6 GF per core.
        let pc = clx.peak_per_core(Precision::F32);
        assert!((pc - 153.57e9).abs() < 1e9);
    }
}
