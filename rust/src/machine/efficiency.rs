//! Efficiency accounting: converts measured kernel wall-clock into the
//! paper's "% of machine peak" metric, and translates host measurements
//! onto the paper's testbeds at equal efficiency (DESIGN.md §4,
//! substitution 3).

use super::spec::{MachineSpec, Precision};

/// One measured kernel run.
#[derive(Debug, Clone, Copy)]
pub struct Measurement {
    /// FLOPs of the pass (2·N·C·K·Q·S).
    pub flops: u64,
    /// Wall-clock seconds.
    pub secs: f64,
    /// Threads (cores) the run used.
    pub threads: usize,
}

impl Measurement {
    /// Achieved FLOP/s.
    pub fn flops_per_sec(&self) -> f64 {
        self.flops as f64 / self.secs
    }

    /// Efficiency versus `spec`'s peak using `threads` cores of it.
    pub fn efficiency_on(&self, spec: &MachineSpec, prec: Precision) -> f64 {
        let peak = spec.peak_per_core(prec) * self.threads.min(spec.cores) as f64;
        (self.flops_per_sec() / peak).min(1.5)
    }

    /// Project this measurement's *efficiency* onto another machine:
    /// time the same problem would take on `target` at equal fraction of
    /// peak, using `target_threads` cores.
    pub fn project_time(
        &self,
        host: &MachineSpec,
        target: &MachineSpec,
        prec: Precision,
        target_threads: usize,
    ) -> f64 {
        let eff = self.efficiency_on(host, prec);
        let target_peak =
            target.peak_per_core(prec) * target_threads.min(target.cores) as f64;
        self.flops as f64 / (eff.max(1e-6) * target_peak)
    }
}

/// GFLOP/s pretty formatting for report tables.
pub fn gflops(flops: u64, secs: f64) -> f64 {
    flops as f64 / secs / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn efficiency_math() {
        let host = MachineSpec::host(10.0); // 10 GFLOP/s, 1 core
        let m = Measurement {
            flops: 5_000_000_000,
            secs: 1.0,
            threads: 1,
        };
        // 5 GFLOP/s on a 10 GFLOP/s core = 50 %.
        assert!((m.efficiency_on(&host, Precision::F32) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn projection_preserves_efficiency() {
        let host = MachineSpec::host(10.0);
        let clx = MachineSpec::cascade_lake();
        let m = Measurement {
            flops: 8_000_000_000,
            secs: 1.0,
            threads: 1,
        };
        let t = m.project_time(&host, &clx, Precision::F32, 27);
        // Equal efficiency on 27 CLX cores (27 · 153.6 GF = 4.147 TF peak):
        // time = 8e9 / (0.8 · 4.147e12) ≈ 2.41 ms.
        assert!((t - 8e9 / (0.8 * 27.0 * (4.3e12 / 28.0))).abs() / t < 1e-6);
    }

    #[test]
    fn gflops_helper() {
        assert!((gflops(2_000_000_000, 0.5) - 4.0).abs() < 1e-12);
    }
}
