//! Machine models of the paper's testbeds (CLX / CPX / V100) plus the
//! roofline+cache projection used to report paper-scale numbers from this
//! host's measurements. See DESIGN.md §4, substitution 3.

pub mod efficiency;
pub mod roofline;
pub mod spec;
pub mod workload;

pub use efficiency::{gflops, Measurement};
pub use roofline::{calibrate_host, project, Projection, Strategy};
pub use spec::{MachineSpec, Precision};
