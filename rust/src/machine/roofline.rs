//! Analytic roofline + cache model: projects a convolution problem onto a
//! [`MachineSpec`] under a given kernel strategy.
//!
//! The model captures the three effects that produce the paper's Figs 4–6
//! shapes:
//!
//! 1. **GEMM-shape efficiency**: the per-block GEMM runs the MXU/FMA
//!    pipeline well only when the `(m, n, k)` block is big enough; tiny
//!    `C·K` (e.g. 1×1) cannot fill the SIMD lanes (paper Sec. 3.1's
//!    `(mnk)^{1/3} ≤ 64` sweet spot has a lower cliff too).
//! 2. **Cache residency**: BRGEMM streams the input once when weight +
//!    input panel + output block fit in L2; im2col moves `S×` more data.
//! 3. **Roofline**: time = max(compute time, memory time).

use super::spec::{MachineSpec, Precision};
use crate::conv1d::im2col::im2col_extra_bytes;
use crate::conv1d::{ConvParams, WIDTH_BLOCK};

/// Kernel strategy being modelled.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// The paper's width-blocked BRGEMM (Algorithms 2–4).
    Brgemm,
    /// im2col + GEMM library baseline (oneDNN-analog).
    Im2col,
    /// Naive direct loops.
    Direct,
}

/// Modelled outcome for one pass.
#[derive(Debug, Clone, Copy)]
pub struct Projection {
    /// Seconds for the pass on one socket.
    pub secs: f64,
    /// Fraction of machine peak achieved.
    pub efficiency: f64,
    /// Bytes moved from/to memory beyond cache.
    pub bytes: u64,
}

/// Fraction of peak the per-block GEMM can reach as a function of its
/// `(m, n, k)` shape: saturates once every dimension feeds the SIMD/FMA
/// pipeline, collapses for skinny problems. Tuned so the paper's corners
/// reproduce: C=K=15,S=51 ≈ 0.8 peak; C=K=64 ≈ 0.85; C=K=1 ≈ tiny.
fn gemm_shape_efficiency(m: usize, n: usize, k: usize) -> f64 {
    // SIMD lanes fill along n (width block), FMA chains along k, register
    // rows along m. Model each as a saturating term.
    let fill = |dim: usize, sat: f64| -> f64 {
        let d = dim as f64;
        (d / (d + sat)).min(1.0)
    };
    // n=64 with sat 4 → 0.94; m=15 sat 2 → 0.88; k=15 sat 2 → 0.88.
    let e = fill(n, 4.0) * fill(m, 2.0) * fill(k, 2.0);
    e.clamp(0.01, 0.95)
}

/// Working set of one BRGEMM width block (bytes, f32 elements × size).
pub fn brgemm_block_working_set(p: &ConvParams, elem: usize) -> usize {
    let panel_w = WIDTH_BLOCK + (p.s - 1) * p.d;
    (p.s * p.k * p.c + p.c * panel_w + p.k * WIDTH_BLOCK) * elem
}

/// Memory traffic (bytes) of one forward pass under a strategy.
pub fn pass_bytes(p: &ConvParams, strategy: Strategy, elem: usize) -> u64 {
    let base = (p.n * p.c * p.w + p.k * p.c * p.s + p.n * p.k * p.q()) * elem;
    match strategy {
        Strategy::Brgemm => {
            // Input panels overlap by (S−1)·d per block: streamed ~once
            // plus the overlap re-reads.
            let overlap = (p.s - 1) * p.d;
            let reread = (p.n * p.c * overlap * p.q_blocks()) * elem;
            (base + reread) as u64
        }
        Strategy::Im2col => base as u64 + im2col_extra_bytes(p) / 4 * elem as u64,
        Strategy::Direct => {
            // Every tap re-streams the input row (no blocking).
            (base + p.n * p.c * p.w * (p.s - 1) * elem) as u64
        }
    }
}

/// Project one forward (or backward-data; same shape) pass.
///
/// `threads` = compute cores used (batch-dim parallelism, capped at N).
pub fn project(
    p: &ConvParams,
    strategy: Strategy,
    spec: &MachineSpec,
    prec: Precision,
    threads: usize,
) -> Projection {
    let elem = match prec {
        Precision::F32 => 4,
        Precision::Bf16 => 2,
        Precision::I8 => 1,
    };
    let cores = threads.min(p.n.max(1)).min(spec.cores).max(1);
    let peak = spec.peak_per_core(prec) * cores as f64;

    // Shape efficiency of the inner GEMM.
    let shape_eff = match strategy {
        Strategy::Brgemm => gemm_shape_efficiency(p.k, WIDTH_BLOCK.min(p.q()), p.c),
        // im2col's big GEMM has k = C·S (good shape) but pays the
        // materialisation; shape term is high.
        Strategy::Im2col => gemm_shape_efficiency(p.k, WIDTH_BLOCK.min(p.q()), p.c * p.s),
        // Direct convolution has no register blocking: scalar-ish.
        Strategy::Direct => 0.05,
    };

    // Cache penalty: working set spilling L2 degrades the compute rate.
    let ws = brgemm_block_working_set(p, elem);
    let cache_eff = match strategy {
        Strategy::Brgemm => {
            if ws <= spec.l2_bytes {
                1.0
            } else if ws <= spec.l3_bytes {
                0.7
            } else {
                0.4
            }
        }
        Strategy::Im2col | Strategy::Direct => 1.0, // captured in bytes instead
    };

    // Short-width penalty: with Q < 1000 the per-block setup overhead and
    // ragged tail dominate (paper eq. 4's Q ≥ 1000 condition).
    let q = p.q() as f64;
    let width_eff = (q / (q + 256.0)).min(1.0);

    let flops = p.flops() as f64;
    let t_compute = flops / (peak * shape_eff * cache_eff * width_eff);
    let bytes = pass_bytes(p, strategy, elem);
    let t_mem = bytes as f64 / spec.dram_bw * (spec.cores as f64 / cores as f64).min(4.0);
    let secs = t_compute.max(t_mem);
    Projection {
        secs,
        efficiency: flops / (secs * spec.peak(prec)) * (spec.cores as f64 / cores as f64),
        bytes,
    }
}

/// Calibrate the host's sustained single-core f32 GFLOP/s by timing the
/// real BRGEMM micro-kernel (the optimized n=64 fast path the convolution
/// kernels run on) at an in-cache, AtacWorks-shaped problem.
pub fn calibrate_host() -> f64 {
    use crate::conv1d::brgemm::brgemm_f32;
    let (m, n, k, lbr) = (16usize, 64usize, 16usize, 16usize);
    let a = vec![1.000_1f32; lbr * m * k];
    let b = vec![0.999_9f32; lbr * k * n];
    let a_offs: Vec<usize> = (0..lbr).map(|i| i * m * k).collect();
    let b_offs: Vec<usize> = (0..lbr).map(|i| i * k * n).collect();
    let mut c = vec![0.0f32; m * n];
    // Warm up, then time.
    for _ in 0..20 {
        brgemm_f32(&a, &a_offs, k, &b, &b_offs, n, &mut c, n, m, n, k, true);
    }
    let reps = 500;
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        brgemm_f32(&a, &a_offs, k, &b, &b_offs, n, &mut c, n, m, n, k, true);
    }
    let dt = t0.elapsed().as_secs_f64();
    std::hint::black_box(&c);
    let flops = 2.0 * (m * n * k * lbr) as f64 * reps as f64;
    flops / dt / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(c: usize, k: usize, q: usize, s: usize, d: usize) -> ConvParams {
        ConvParams::new(56, c, k, q + (s - 1) * d, s, d).unwrap()
    }

    #[test]
    fn brgemm_beats_baseline_in_eq4_region() {
        // Paper eq. 4: S ≥ 5 ∧ Q ≥ 1000 ⇒ BRGEMM wins.
        let clx = MachineSpec::cascade_lake();
        for &(c, k, q, s, d) in &[
            (15, 15, 60_000, 51, 8),
            (15, 15, 1_000, 5, 1),
            (64, 64, 20_000, 9, 1),
            (32, 32, 5_000, 25, 4),
        ] {
            let prm = p(c, k, q, s, d);
            let ours = project(&prm, Strategy::Brgemm, &clx, Precision::F32, 27);
            let lib = project(&prm, Strategy::Im2col, &clx, Precision::F32, 27);
            assert!(
                ours.secs < lib.secs,
                "BRGEMM should win at C{c} K{k} Q{q} S{s}: {} vs {}",
                ours.secs,
                lib.secs
            );
        }
    }

    #[test]
    fn atacworks_layer_efficiency_near_paper() {
        // Paper: up to ~80% efficiency for large S and Q on CLX.
        let clx = MachineSpec::cascade_lake();
        let prm = p(15, 15, 60_000, 51, 8);
        let pr = project(&prm, Strategy::Brgemm, &clx, Precision::F32, 28);
        assert!(
            pr.efficiency > 0.6 && pr.efficiency <= 0.95,
            "efficiency {}",
            pr.efficiency
        );
    }

    #[test]
    fn efficiency_grows_with_width_and_filter() {
        let clx = MachineSpec::cascade_lake();
        let small = project(&p(15, 15, 1_000, 5, 8), Strategy::Brgemm, &clx, Precision::F32, 28);
        let large = project(&p(15, 15, 60_000, 51, 8), Strategy::Brgemm, &clx, Precision::F32, 28);
        assert!(large.efficiency > small.efficiency);
    }

    #[test]
    fn bf16_on_cpx_is_faster() {
        let cpx = MachineSpec::cooper_lake();
        let prm = p(32, 32, 20_000, 9, 4);
        let f = project(&prm, Strategy::Brgemm, &cpx, Precision::F32, 28);
        let b = project(&prm, Strategy::Brgemm, &cpx, Precision::Bf16, 28);
        // Paper reports ~1.6× from BF16.
        let speedup = f.secs / b.secs;
        assert!(speedup > 1.3 && speedup < 2.1, "speedup {speedup}");
    }

    #[test]
    fn direct_is_much_slower() {
        let clx = MachineSpec::cascade_lake();
        let prm = p(15, 15, 10_000, 51, 8);
        let ours = project(&prm, Strategy::Brgemm, &clx, Precision::F32, 27);
        let naive = project(&prm, Strategy::Direct, &clx, Precision::F32, 27);
        assert!(naive.secs > 5.0 * ours.secs);
    }

    #[test]
    fn calibration_returns_plausible_rate() {
        let g = calibrate_host();
        assert!(g > 0.1 && g < 1_000.0, "host GFLOP/s {g}");
    }
}
