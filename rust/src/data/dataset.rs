//! Dataset bookkeeping: chromosome-style splits and epoch shuffling.
//!
//! The paper holds out chromosome 20 for validation and chromosome 10 for
//! testing, training on all other autosomes (Sec. 4.2). We reproduce the
//! same protocol: every synthetic segment is deterministically assigned to
//! one of 22 "autosomes" (weighted roughly like human chromosome lengths),
//! and the three splits are carved out by chromosome — so train/val/test
//! never share a chromosome, exactly like the paper.

use crate::util::rng::Rng;

/// Which split a segment belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    Train,
    Validation,
    Test,
}

/// Chromosome held out for validation (paper: chr20).
pub const VAL_CHROMOSOME: u8 = 20;
/// Chromosome held out for testing (paper: chr10).
pub const TEST_CHROMOSOME: u8 = 10;

/// Deterministic chromosome assignment of a segment index: 1..=22,
/// weighted by a coarse human-autosome length profile.
pub fn chromosome_of(seed: u64, index: u64) -> u8 {
    // Relative autosome lengths (Mb, rounded): chr1..chr22.
    const LEN: [u32; 22] = [
        249, 243, 198, 190, 182, 171, 159, 146, 141, 136, 135, 133, 114, 107, 102, 90, 83, 80,
        59, 63, 47, 51,
    ];
    const TOTAL: u32 = {
        let mut t = 0;
        let mut i = 0;
        while i < 22 {
            t += LEN[i];
            i += 1;
        }
        t
    };
    let mut rng = Rng::new(seed ^ 0xC0FF_EE00 ^ index.wrapping_mul(0x2545_F491_4F6C_DD1D));
    let mut r = rng.below(TOTAL as usize) as u32;
    for (i, &l) in LEN.iter().enumerate() {
        if r < l {
            return (i + 1) as u8;
        }
        r -= l;
    }
    22
}

/// Split of a segment index under the paper's protocol.
pub fn split_of(seed: u64, index: u64) -> Split {
    match chromosome_of(seed, index) {
        VAL_CHROMOSOME => Split::Validation,
        TEST_CHROMOSOME => Split::Test,
        _ => Split::Train,
    }
}

/// A logical dataset: `total` segments generated from `seed`, partitioned
/// into chromosome-based splits.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub seed: u64,
    pub train: Vec<u64>,
    pub validation: Vec<u64>,
    pub test: Vec<u64>,
}

impl Dataset {
    /// Scan `total` segment indices into splits.
    pub fn new(seed: u64, total: u64) -> Self {
        let mut train = Vec::new();
        let mut validation = Vec::new();
        let mut test = Vec::new();
        for i in 0..total {
            match split_of(seed, i) {
                Split::Train => train.push(i),
                Split::Validation => validation.push(i),
                Split::Test => test.push(i),
            }
        }
        Dataset {
            seed,
            train,
            validation,
            test,
        }
    }

    /// Build a dataset whose *train* split has (at least) `train_target`
    /// segments — the paper quotes training-set sizes (e.g. 32 000).
    pub fn with_train_size(seed: u64, train_target: usize) -> Self {
        // Train fraction ≈ (TOTAL − len20 − len10) / TOTAL ≈ 0.90.
        let mut total = (train_target as f64 / 0.88) as u64 + 64;
        loop {
            let ds = Dataset::new(seed, total);
            // Also require non-empty held-out splits so evaluation is
            // always defined, even for tiny test datasets.
            if ds.train.len() >= train_target
                && !ds.validation.is_empty()
                && !ds.test.is_empty()
            {
                let mut ds = ds;
                ds.train.truncate(train_target);
                return ds;
            }
            total += (train_target / 10 + 64) as u64;
        }
    }

    /// Fisher–Yates shuffle of the training order for one epoch
    /// (deterministic in `(seed, epoch)`).
    pub fn epoch_order(&self, epoch: u64) -> Vec<u64> {
        let mut order = self.train.clone();
        let mut rng = Rng::new(self.seed ^ 0xE90C_17 ^ epoch.wrapping_mul(0x9E37_79B9));
        for i in (1..order.len()).rev() {
            let j = rng.below(i + 1);
            order.swap(i, j);
        }
        order
    }

    /// Shard a segment list across `shards` workers (contiguous blocks;
    /// the remainder spreads over the leading shards).
    pub fn shard(list: &[u64], shards: usize) -> Vec<Vec<u64>> {
        let n = list.len();
        let base = n / shards;
        let extra = n % shards;
        let mut out = Vec::with_capacity(shards);
        let mut off = 0;
        for sh in 0..shards {
            let len = base + usize::from(sh < extra);
            out.push(list[off..off + len].to_vec());
            off += len;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splits_are_disjoint_and_cover() {
        let ds = Dataset::new(3, 5_000);
        assert_eq!(
            ds.train.len() + ds.validation.len() + ds.test.len(),
            5_000
        );
        for &i in &ds.validation {
            assert_eq!(chromosome_of(3, i), VAL_CHROMOSOME);
        }
        for &i in &ds.test {
            assert_eq!(chromosome_of(3, i), TEST_CHROMOSOME);
        }
    }

    #[test]
    fn split_proportions_match_chromosome_weights() {
        let ds = Dataset::new(1, 50_000);
        let vf = ds.validation.len() as f64 / 50_000.0;
        let tf = ds.test.len() as f64 / 50_000.0;
        // chr20 ≈ 63/2779 ≈ 2.3%, chr10 ≈ 136/2779 ≈ 4.9%.
        assert!((vf - 0.023).abs() < 0.006, "val fraction {vf}");
        assert!((tf - 0.049).abs() < 0.008, "test fraction {tf}");
    }

    #[test]
    fn with_train_size_hits_target() {
        let ds = Dataset::with_train_size(9, 1_000);
        assert_eq!(ds.train.len(), 1_000);
        assert!(!ds.validation.is_empty());
    }

    #[test]
    fn epoch_order_is_permutation_and_varies() {
        let ds = Dataset::new(5, 2_000);
        let e0 = ds.epoch_order(0);
        let e1 = ds.epoch_order(1);
        assert_ne!(e0, e1);
        let mut s0 = e0.clone();
        let mut st = ds.train.clone();
        s0.sort_unstable();
        st.sort_unstable();
        assert_eq!(s0, st);
        // Deterministic.
        assert_eq!(e0, ds.epoch_order(0));
    }

    #[test]
    fn sharding_is_balanced_partition() {
        let list: Vec<u64> = (0..103).collect();
        let shards = Dataset::shard(&list, 4);
        assert_eq!(shards.len(), 4);
        let sizes: Vec<usize> = shards.iter().map(Vec::len).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 103);
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
        let mut all: Vec<u64> = shards.concat();
        all.sort_unstable();
        assert_eq!(all, list);
    }
}
