//! Prefetching batch loader — the PyTorch-`DataLoader()`-worker analog.
//!
//! The paper reserves one CPU core per socket for the `DataLoader()`
//! worker (Sec. 4.4); here a dedicated OS thread generates batches ahead
//! of the trainer through a bounded channel, overlapping data synthesis
//! with compute exactly like the paper's pipeline. The machine model
//! accounts for the reserved core when projecting socket-level timings.

use std::sync::mpsc;
use std::thread::JoinHandle;

use super::atacseq::{make_batch, Batch, TrackConfig};

/// A background loader streaming batches for one epoch.
pub struct Loader {
    /// `Some` while the epoch is live; dropped before joining the worker
    /// so a blocked `send` unblocks with an error instead of deadlocking.
    rx: Option<mpsc::Receiver<Batch>>,
    handle: Option<JoinHandle<()>>,
    /// Number of batches this epoch will produce.
    pub n_batches: usize,
}

impl Loader {
    /// Spawn a prefetch worker over `order` (segment indices), producing
    /// `batch_size`-sized batches (last ragged batch dropped, as the
    /// paper's fixed-batch training does). `depth` bounds the prefetch
    /// queue (1–2 emulates the single DataLoader worker).
    pub fn spawn(cfg: TrackConfig, seed: u64, order: Vec<u64>, batch_size: usize, depth: usize) -> Loader {
        assert!(batch_size > 0);
        let n_batches = order.len() / batch_size;
        let (tx, rx) = mpsc::sync_channel(depth.max(1));
        let handle = std::thread::spawn(move || {
            for b in 0..n_batches {
                let idx = &order[b * batch_size..(b + 1) * batch_size];
                let batch = make_batch(&cfg, seed, idx);
                if tx.send(batch).is_err() {
                    return; // consumer dropped early
                }
            }
        });
        Loader {
            rx: Some(rx),
            handle: Some(handle),
            n_batches,
        }
    }

    /// Blocking receive of the next batch; `None` when the epoch ends.
    pub fn next_batch(&mut self) -> Option<Batch> {
        self.rx.as_ref()?.recv().ok()
    }
}

impl Drop for Loader {
    fn drop(&mut self) {
        // Drop the receiver FIRST: a worker blocked in `send` sees the
        // disconnect and exits; only then is joining safe.
        drop(self.rx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Synchronous (no-thread) batch iterator used by tests and benches where
/// determinism of scheduling matters more than overlap.
pub struct SyncLoader {
    cfg: TrackConfig,
    seed: u64,
    order: Vec<u64>,
    batch_size: usize,
    cursor: usize,
}

impl SyncLoader {
    pub fn new(cfg: TrackConfig, seed: u64, order: Vec<u64>, batch_size: usize) -> Self {
        SyncLoader {
            cfg,
            seed,
            order,
            batch_size,
            cursor: 0,
        }
    }

    pub fn n_batches(&self) -> usize {
        self.order.len() / self.batch_size
    }
}

impl Iterator for SyncLoader {
    type Item = Batch;
    fn next(&mut self) -> Option<Batch> {
        if self.cursor + self.batch_size > self.order.len() {
            return None;
        }
        let idx = &self.order[self.cursor..self.cursor + self.batch_size];
        self.cursor += self.batch_size;
        Some(make_batch(&self.cfg, self.seed, idx))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> TrackConfig {
        TrackConfig::default().scaled(1_000)
    }

    #[test]
    fn loader_streams_all_batches() {
        let order: Vec<u64> = (0..10).collect();
        let mut l = Loader::spawn(cfg(), 7, order, 3, 2);
        assert_eq!(l.n_batches, 3);
        let mut seen = 0;
        while let Some(b) = l.next_batch() {
            assert_eq!(b.n, 3);
            assert_eq!(b.width, cfg().padded_width());
            seen += 1;
        }
        assert_eq!(seen, 3); // ragged tail (index 9) dropped
    }

    #[test]
    fn loader_matches_sync_loader() {
        let order: Vec<u64> = (0..6).collect();
        let mut l = Loader::spawn(cfg(), 9, order.clone(), 2, 1);
        let s = SyncLoader::new(cfg(), 9, order, 2);
        for sync_batch in s {
            let async_batch = l.next_batch().unwrap();
            assert_eq!(async_batch.x, sync_batch.x);
            assert_eq!(async_batch.peaks, sync_batch.peaks);
        }
        assert!(l.next_batch().is_none());
    }

    #[test]
    fn early_drop_does_not_deadlock() {
        let order: Vec<u64> = (0..100).collect();
        let mut l = Loader::spawn(cfg(), 1, order, 2, 1);
        let _ = l.next_batch();
        drop(l); // must not hang
    }
}
