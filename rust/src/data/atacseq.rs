//! Synthetic ATAC-seq signal-track generator — the dataset substrate.
//!
//! The paper trains AtacWorks on real dsc-ATAC-seq coverage tracks that we
//! do not have; this module synthesises tracks with the same computational
//! and statistical structure (DESIGN.md §4, substitution 1):
//!
//! * 1D integer-ish coverage (reads per base) with a low Poisson background,
//! * sparse *peaks* — regions of a few hundred bases with lognormal
//!   amplitude and smooth (Gaussian-bump) shape,
//! * a paired *noisy* track produced by read subsampling
//!   (`noisy ~ Poisson(clean · rate) / rate`) — the "low-coverage /
//!   low-quality" input AtacWorks denoises,
//! * binary peak labels for the classification head.
//!
//! Tracks are generated deterministically from `(seed, segment_index)`, so
//! the "dataset" needs no storage: any worker can materialise any shard.

use crate::util::rng::Rng;

/// Generation parameters for one segment family.
#[derive(Debug, Clone, Copy)]
pub struct TrackConfig {
    /// Unpadded segment width (paper: 50 000).
    pub width: usize,
    /// Zero padding added to both sides (paper: 5 000 → total 60 000).
    pub pad: usize,
    /// Background read rate per base (Poisson λ).
    pub background_rate: f64,
    /// Expected number of peaks per 10 000 bases.
    pub peaks_per_10kb: f64,
    /// Mean peak half-width in bases.
    pub peak_halfwidth: f64,
    /// Lognormal (μ, σ) of peak amplitude.
    pub amp_mu: f64,
    pub amp_sigma: f64,
    /// Read subsampling rate for the noisy track (paper-style low coverage).
    pub subsample: f64,
}

impl Default for TrackConfig {
    fn default() -> Self {
        TrackConfig {
            width: 50_000,
            pad: 5_000,
            background_rate: 0.4,
            peaks_per_10kb: 1.2,
            peak_halfwidth: 150.0,
            amp_mu: 2.2,
            amp_sigma: 0.6,
            subsample: 0.1,
        }
    }
}

impl TrackConfig {
    /// A width-scaled copy (keeps densities constant). Used to run the
    /// paper's workload at reduced width on this host.
    pub fn scaled(&self, width: usize) -> TrackConfig {
        TrackConfig {
            width,
            pad: (self.pad as f64 * width as f64 / self.width as f64).round() as usize,
            ..*self
        }
    }

    /// Total (padded) track width — the convolution input width.
    pub fn padded_width(&self) -> usize {
        self.width + 2 * self.pad
    }
}

/// One (noisy, clean, peak-label) training triple, all at padded width.
#[derive(Debug, Clone)]
pub struct SignalTrack {
    /// Noisy low-coverage input (network input).
    pub noisy: Vec<f32>,
    /// Clean high-coverage target (regression target).
    pub clean: Vec<f32>,
    /// Binary peak labels (classification target).
    pub peaks: Vec<f32>,
}

/// Generate the segment with the given index, deterministically.
pub fn generate_track(cfg: &TrackConfig, seed: u64, index: u64) -> SignalTrack {
    let mut rng = Rng::new(seed ^ index.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(1));
    let w = cfg.width;
    let wp = cfg.padded_width();

    // 1. Smooth peak intensity field.
    let mut intensity = vec![0.0f64; w];
    let n_peaks = rng
        .poisson(cfg.peaks_per_10kb * w as f64 / 10_000.0)
        .max(1);
    let mut peak_mask = vec![false; w];
    for _ in 0..n_peaks {
        let center = rng.below(w) as f64;
        let half = (cfg.peak_halfwidth * rng.lognormal(0.0, 0.35)).max(20.0);
        let amp = rng.lognormal(cfg.amp_mu, cfg.amp_sigma);
        let lo = ((center - 4.0 * half).floor().max(0.0)) as usize;
        let hi = ((center + 4.0 * half).ceil() as usize).min(w);
        for i in lo..hi {
            let z = (i as f64 - center) / half;
            intensity[i] += amp * (-0.5 * z * z).exp();
            if z.abs() <= 1.5 {
                peak_mask[i] = true;
            }
        }
    }

    // 2. Clean coverage: Poisson(background + intensity).
    // 3. Noisy coverage: Poisson(rate · λ) — a subsampled sequencing run.
    let mut clean = vec![0.0f32; wp];
    let mut noisy = vec![0.0f32; wp];
    let mut peaks = vec![0.0f32; wp];
    for i in 0..w {
        let lam = cfg.background_rate + intensity[i];
        clean[cfg.pad + i] = rng.poisson(lam) as f32;
        noisy[cfg.pad + i] = rng.poisson(lam * cfg.subsample) as f32;
        peaks[cfg.pad + i] = if peak_mask[i] { 1.0 } else { 0.0 };
    }
    SignalTrack { noisy, clean, peaks }
}

/// Assemble `indices` into `(N, 1, Wp)` batch tensors.
pub struct Batch {
    pub x: Vec<f32>,
    pub clean: Vec<f32>,
    pub peaks: Vec<f32>,
    pub n: usize,
    pub width: usize,
}

/// Materialise a batch of tracks (row-major `(N, 1, Wp)`).
pub fn make_batch(cfg: &TrackConfig, seed: u64, indices: &[u64]) -> Batch {
    let wp = cfg.padded_width();
    let n = indices.len();
    let mut x = vec![0.0f32; n * wp];
    let mut clean = vec![0.0f32; n * wp];
    let mut peaks = vec![0.0f32; n * wp];
    for (row, &idx) in indices.iter().enumerate() {
        let t = generate_track(cfg, seed, idx);
        x[row * wp..(row + 1) * wp].copy_from_slice(&t.noisy);
        clean[row * wp..(row + 1) * wp].copy_from_slice(&t.clean);
        peaks[row * wp..(row + 1) * wp].copy_from_slice(&t.peaks);
    }
    Batch {
        x,
        clean,
        peaks,
        n,
        width: wp,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> TrackConfig {
        TrackConfig::default().scaled(2_000)
    }

    #[test]
    fn deterministic_per_index() {
        let cfg = small();
        let a = generate_track(&cfg, 42, 7);
        let b = generate_track(&cfg, 42, 7);
        let c = generate_track(&cfg, 42, 8);
        assert_eq!(a.clean, b.clean);
        assert_ne!(a.clean, c.clean);
    }

    #[test]
    fn padding_is_zero() {
        let cfg = small();
        let t = generate_track(&cfg, 1, 0);
        assert_eq!(t.clean.len(), cfg.padded_width());
        assert!(t.clean[..cfg.pad].iter().all(|&v| v == 0.0));
        assert!(t.clean[cfg.pad + cfg.width..].iter().all(|&v| v == 0.0));
        assert!(t.peaks[..cfg.pad].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn coverage_is_nonnegative_integerish() {
        let cfg = small();
        let t = generate_track(&cfg, 3, 1);
        for &v in &t.clean {
            assert!(v >= 0.0 && v.fract() == 0.0);
        }
    }

    #[test]
    fn noisy_is_subsampled() {
        let cfg = small();
        let mut tot_clean = 0.0f64;
        let mut tot_noisy = 0.0f64;
        for i in 0..20 {
            let t = generate_track(&cfg, 5, i);
            tot_clean += t.clean.iter().map(|&v| v as f64).sum::<f64>();
            tot_noisy += t.noisy.iter().map(|&v| v as f64).sum::<f64>();
        }
        let ratio = tot_noisy / tot_clean;
        assert!(
            (ratio - cfg.subsample).abs() < 0.05,
            "subsample ratio {ratio}"
        );
    }

    #[test]
    fn peaks_are_sparse_but_present() {
        let cfg = small();
        let mut frac = 0.0;
        for i in 0..10 {
            let t = generate_track(&cfg, 9, i);
            frac += t.peaks.iter().sum::<f32>() as f64 / cfg.width as f64;
        }
        frac /= 10.0;
        assert!(frac > 0.005 && frac < 0.5, "peak fraction {frac}");
    }

    #[test]
    fn peak_regions_have_higher_signal() {
        let cfg = small();
        let mut in_peak = (0.0f64, 0u64);
        let mut out_peak = (0.0f64, 0u64);
        for i in 0..10 {
            let t = generate_track(&cfg, 11, i);
            for j in cfg.pad..cfg.pad + cfg.width {
                if t.peaks[j] > 0.5 {
                    in_peak = (in_peak.0 + t.clean[j] as f64, in_peak.1 + 1);
                } else {
                    out_peak = (out_peak.0 + t.clean[j] as f64, out_peak.1 + 1);
                }
            }
        }
        let mi = in_peak.0 / in_peak.1.max(1) as f64;
        let mo = out_peak.0 / out_peak.1.max(1) as f64;
        assert!(mi > 3.0 * mo, "in-peak {mi} vs background {mo}");
    }

    #[test]
    fn batch_layout() {
        let cfg = small();
        let b = make_batch(&cfg, 1, &[0, 1, 2]);
        assert_eq!(b.n, 3);
        assert_eq!(b.x.len(), 3 * cfg.padded_width());
        let t1 = generate_track(&cfg, 1, 1);
        assert_eq!(
            &b.x[cfg.padded_width()..2 * cfg.padded_width()],
            &t1.noisy[..]
        );
    }
}
