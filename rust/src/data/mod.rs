//! Data pipeline: synthetic ATAC-seq generation ([`atacseq`]),
//! chromosome-split datasets ([`dataset`]) and the prefetching batch
//! loader ([`loader`]) — the DataLoader-worker analog of paper Sec. 4.4.

pub mod atacseq;
pub mod dataset;
pub mod loader;

pub use atacseq::{generate_track, make_batch, Batch, SignalTrack, TrackConfig};
pub use dataset::{Dataset, Split};
pub use loader::{Loader, SyncLoader};
