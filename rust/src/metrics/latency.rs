//! Latency histogram — the serving subsystem's per-request telemetry
//! (DESIGN.md §7).
//!
//! Request latencies span four orders of magnitude (a cache-warm small
//! bucket vs a cold plan build on a huge one), so the histogram uses
//! geometrically-spaced buckets: ~18 buckets per decade from 1 µs to
//! ~12 s (slower outliers clamp into the last bucket, with their exact
//! max still tracked). Recording is O(1) with no allocation; quantile queries walk
//! the fixed bucket array. Exact min/max/mean ride along in a
//! [`Stats`] accumulator, so the common "p50/p99 + mean" report never
//! misstates the extremes by a bucket width.

use super::timing::Stats;

/// Lower edge of bucket 0, in seconds (1 µs).
const BASE_SECS: f64 = 1e-6;
/// Geometric growth factor between bucket edges (≈ 18 buckets/decade,
/// ~13 % relative resolution).
const GROWTH: f64 = 1.136;
/// Bucket count: `BASE · GROWTH^128` ≈ 12 s — ample for request
/// latencies; slower outliers clamp into the last bucket (their exact
/// max is still tracked by the [`Stats`] accumulator).
const BUCKETS: usize = 128;

/// A fixed-size geometric latency histogram with quantile queries.
///
/// ```
/// use dilconv1d::metrics::LatencyHistogram;
///
/// let mut h = LatencyHistogram::new();
/// for ms in 1..=100u32 {
///     h.record(ms as f64 * 1e-3);
/// }
/// assert_eq!(h.count(), 100);
/// // Quantiles are exact to one bucket (~13% relative resolution).
/// assert!((h.p50() - 0.050).abs() < 0.010);
/// assert!((h.p99() - 0.100).abs() < 0.015);
/// assert!(h.p50() <= h.p99());
/// ```
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    counts: [u64; BUCKETS],
    stats: Stats,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> LatencyHistogram {
        LatencyHistogram {
            counts: [0; BUCKETS],
            stats: Stats::new(),
        }
    }

    /// Bucket index for a latency (clamped to the histogram range).
    fn index(secs: f64) -> usize {
        if secs <= BASE_SECS {
            return 0;
        }
        let i = (secs / BASE_SECS).ln() / GROWTH.ln();
        (i as usize).min(BUCKETS - 1)
    }

    /// Record one latency in seconds. O(1), allocation-free.
    pub fn record(&mut self, secs: f64) {
        self.counts[Self::index(secs)] += 1;
        self.stats.push(secs);
    }

    /// Total recorded samples.
    pub fn count(&self) -> u64 {
        self.stats.count()
    }

    /// Exact mean of every recorded latency.
    pub fn mean(&self) -> f64 {
        self.stats.mean()
    }

    /// Exact minimum (0 when empty).
    pub fn min(&self) -> f64 {
        self.stats.min()
    }

    /// Exact maximum (0 when empty).
    pub fn max(&self) -> f64 {
        self.stats.max()
    }

    /// The `q`-quantile (`0 < q <= 1`) as the geometric midpoint of the
    /// bucket holding the rank-`ceil(q·n)` sample, clamped to the exact
    /// observed [min, max]. Returns 0 for an empty histogram.
    pub fn quantile(&self, q: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let rank = ((q.clamp(0.0, 1.0) * n as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                let lo = BASE_SECS * GROWTH.powi(i as i32);
                let mid = lo * GROWTH.sqrt();
                return mid.clamp(self.stats.min(), self.stats.max());
            }
        }
        self.stats.max()
    }

    /// Median latency.
    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    /// 99th-percentile latency.
    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    /// Fold another histogram into this one (per-bucket merge; count,
    /// mean, min and max stay exact via the parallel [`Stats`] merge).
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.stats.merge(&other.stats);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_all_zero() {
        let h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.p50(), 0.0);
        assert_eq!(h.p99(), 0.0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn single_sample_quantiles_are_exact() {
        let mut h = LatencyHistogram::new();
        h.record(3.3e-3);
        // Every quantile of one sample is that sample (clamped to the
        // exact observed extremes).
        assert_eq!(h.p50(), 3.3e-3);
        assert_eq!(h.p99(), 3.3e-3);
        assert_eq!(h.min(), 3.3e-3);
        assert_eq!(h.max(), 3.3e-3);
    }

    #[test]
    fn quantiles_track_a_uniform_sweep() {
        let mut h = LatencyHistogram::new();
        for i in 1..=1000u32 {
            h.record(i as f64 * 1e-4); // 0.1 ms .. 100 ms
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.p50();
        let p99 = h.p99();
        assert!((p50 - 0.05).abs() < 0.05 * 0.2, "p50 {p50}");
        assert!((p99 - 0.099).abs() < 0.099 * 0.2, "p99 {p99}");
        assert!(h.min() <= p50 && p50 <= p99 && p99 <= h.max());
    }

    #[test]
    fn out_of_range_samples_clamp() {
        let mut h = LatencyHistogram::new();
        h.record(1e-9); // below the first bucket
        h.record(1e4); // beyond the last bucket
        assert_eq!(h.count(), 2);
        assert_eq!(h.min(), 1e-9);
        assert_eq!(h.max(), 1e4);
        // Quantiles stay within the observed extremes.
        assert!(h.p99() <= 1e4);
    }

    #[test]
    fn merge_adds_counts_and_keeps_extremes() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        for _ in 0..10 {
            a.record(1e-3);
        }
        for _ in 0..30 {
            b.record(4e-3);
        }
        a.merge(&b);
        assert_eq!(a.count(), 40);
        assert_eq!(a.min(), 1e-3);
        assert_eq!(a.max(), 4e-3);
        // 75% of mass at 4 ms → p50 lands in the 4 ms bucket.
        assert!((a.p50() - 4e-3).abs() < 4e-3 * 0.2);
        // Merged mean is the sample-weighted mean.
        assert!((a.mean() - (10.0 * 1e-3 + 30.0 * 4e-3) / 40.0).abs() < 1e-9);
    }
}
