//! Exact AUROC (area under the ROC curve) — the paper's accuracy metric
//! for peak calling (Tables 1–2 report AUROC ≈ 0.93).
//!
//! Computed by the rank statistic (Mann–Whitney U): sort by score, assign
//! average ranks to ties, then
//! `AUROC = (Σ ranks(positives) − P(P+1)/2) / (P·N)`.
//! Exact for any score distribution, `O(n log n)`.

/// Compute AUROC for binary `labels` (0/1) against real-valued `scores`.
///
/// Returns `None` when either class is absent (AUROC undefined).
pub fn auroc(scores: &[f32], labels: &[f32]) -> Option<f64> {
    assert_eq!(scores.len(), labels.len(), "length mismatch");
    let n = scores.len();
    let pos = labels.iter().filter(|&&l| l > 0.5).count();
    let neg = n - pos;
    if pos == 0 || neg == 0 {
        return None;
    }
    let mut idx: Vec<u32> = (0..n as u32).collect();
    idx.sort_unstable_by(|&a, &b| {
        scores[a as usize]
            .partial_cmp(&scores[b as usize])
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    // Average ranks over tie groups; accumulate positive ranks.
    let mut rank_sum_pos = 0.0f64;
    let mut i = 0usize;
    while i < n {
        let mut j = i + 1;
        while j < n && scores[idx[j] as usize] == scores[idx[i] as usize] {
            j += 1;
        }
        // Ranks are 1-based: group spans ranks i+1 ..= j.
        let avg_rank = (i + 1 + j) as f64 / 2.0;
        for &ix in &idx[i..j] {
            if labels[ix as usize] > 0.5 {
                rank_sum_pos += avg_rank;
            }
        }
        i = j;
    }
    let p = pos as f64;
    let u = rank_sum_pos - p * (p + 1.0) / 2.0;
    Some(u / (p * neg as f64))
}

/// Streaming AUROC accumulator for epoch-level evaluation: collects
/// (score, label) pairs across batches, then computes once.
#[derive(Default)]
pub struct AurocAccumulator {
    scores: Vec<f32>,
    labels: Vec<f32>,
}

impl AurocAccumulator {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, scores: &[f32], labels: &[f32]) {
        assert_eq!(scores.len(), labels.len());
        self.scores.extend_from_slice(scores);
        self.labels.extend_from_slice(labels);
    }

    /// Subsampled push for very wide tracks (every `stride`-th point) —
    /// keeps epoch evaluation memory bounded without biasing AUROC
    /// (uniform subsampling preserves the score/label joint distribution).
    pub fn push_strided(&mut self, scores: &[f32], labels: &[f32], stride: usize) {
        assert_eq!(scores.len(), labels.len());
        let s = stride.max(1);
        for i in (0..scores.len()).step_by(s) {
            self.scores.push(scores[i]);
            self.labels.push(labels[i]);
        }
    }

    pub fn len(&self) -> usize {
        self.scores.len()
    }

    pub fn is_empty(&self) -> bool {
        self.scores.is_empty()
    }

    pub fn compute(&self) -> Option<f64> {
        auroc(&self.scores, &self.labels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn perfect_separation_is_one() {
        let scores = [0.1, 0.2, 0.3, 0.8, 0.9];
        let labels = [0.0, 0.0, 0.0, 1.0, 1.0];
        assert_eq!(auroc(&scores, &labels), Some(1.0));
    }

    #[test]
    fn inverted_separation_is_zero() {
        let scores = [0.9, 0.8, 0.1, 0.2];
        let labels = [0.0, 0.0, 1.0, 1.0];
        assert_eq!(auroc(&scores, &labels), Some(0.0));
    }

    #[test]
    fn random_scores_near_half() {
        let mut rng = Rng::new(31);
        let n = 20_000;
        let scores: Vec<f32> = (0..n).map(|_| rng.uniform() as f32).collect();
        let labels: Vec<f32> = (0..n).map(|_| f32::from(rng.chance(0.3))).collect();
        let a = auroc(&scores, &labels).unwrap();
        assert!((a - 0.5).abs() < 0.02, "auroc {a}");
    }

    #[test]
    fn undefined_for_single_class() {
        assert_eq!(auroc(&[0.1, 0.2], &[1.0, 1.0]), None);
        assert_eq!(auroc(&[0.1, 0.2], &[0.0, 0.0]), None);
    }

    #[test]
    fn ties_handled_by_average_rank() {
        // All scores equal: AUROC must be exactly 0.5 regardless of labels.
        let scores = [0.7; 10];
        let labels = [1.0, 0.0, 1.0, 0.0, 0.0, 1.0, 0.0, 0.0, 1.0, 0.0];
        assert_eq!(auroc(&scores, &labels), Some(0.5));
    }

    #[test]
    fn rank_invariance() {
        // AUROC depends only on the score ordering.
        let scores = [0.1f32, 0.4, 0.35, 0.8];
        let labels = [0.0, 0.0, 1.0, 1.0];
        let a1 = auroc(&scores, &labels).unwrap();
        let transformed: Vec<f32> = scores.iter().map(|&s| s * s * 10.0 + 3.0).collect();
        let a2 = auroc(&transformed, &labels).unwrap();
        assert!((a1 - a2).abs() < 1e-12);
    }

    #[test]
    fn accumulator_matches_direct() {
        let mut rng = Rng::new(77);
        let scores: Vec<f32> = (0..500).map(|_| rng.uniform() as f32).collect();
        let labels: Vec<f32> = (0..500).map(|_| f32::from(rng.chance(0.4))).collect();
        let mut acc = AurocAccumulator::new();
        acc.push(&scores[..200], &labels[..200]);
        acc.push(&scores[200..], &labels[200..]);
        assert_eq!(acc.compute(), auroc(&scores, &labels));
    }

    #[test]
    fn strided_subsample_approximates() {
        let mut rng = Rng::new(99);
        let n = 50_000;
        // Informative scores: positives shifted up.
        let labels: Vec<f32> = (0..n).map(|_| f32::from(rng.chance(0.2))).collect();
        let scores: Vec<f32> = labels
            .iter()
            .map(|&l| (rng.gauss() as f32) + l * 1.5)
            .collect();
        let full = auroc(&scores, &labels).unwrap();
        let mut acc = AurocAccumulator::new();
        acc.push_strided(&scores, &labels, 10);
        let sub = acc.compute().unwrap();
        assert!((full - sub).abs() < 0.02, "{full} vs {sub}");
    }
}
