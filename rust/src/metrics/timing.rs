//! Timing utilities: wall-clock timers, per-epoch statistics and simple
//! latency histograms for the coordinator's telemetry.

use std::time::{Duration, Instant};

/// A running wall-clock timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer {
            start: Instant::now(),
        }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Online mean/min/max/stddev accumulator (Welford).
#[derive(Debug, Default, Clone, Copy)]
pub struct Stats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Stats {
    pub fn new() -> Self {
        Stats {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, v: f64) {
        self.n += 1;
        let d = v - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (v - self.mean);
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }

    pub fn stddev(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }

    /// Fold another accumulator into this one (parallel Welford merge:
    /// count, mean, variance, min and max all stay exact).
    pub fn merge(&mut self, other: &Stats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = *other;
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        self.m2 += other.m2 + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.mean += d * other.n as f64 / n as f64;
        self.n = n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Per-epoch timing breakdown recorded by the trainer (paper Fig. 10
/// separates training and evaluation time).
#[derive(Debug, Default, Clone, Copy)]
pub struct EpochTiming {
    pub train_secs: f64,
    pub eval_secs: f64,
    pub data_secs: f64,
    pub comm_secs: f64,
}

impl EpochTiming {
    pub fn total(&self) -> f64 {
        self.train_secs + self.eval_secs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_moments() {
        let mut s = Stats::new();
        for v in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(v);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
        // Sample stddev of that classic set is ~2.138.
        assert!((s.stddev() - 2.138).abs() < 0.01);
    }

    #[test]
    fn merge_matches_sequential_pushes() {
        let all = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut whole = Stats::new();
        for v in all {
            whole.push(v);
        }
        let mut a = Stats::new();
        let mut b = Stats::new();
        for v in &all[..3] {
            a.push(*v);
        }
        for v in &all[3..] {
            b.push(*v);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert!((a.mean() - whole.mean()).abs() < 1e-12);
        assert!((a.stddev() - whole.stddev()).abs() < 1e-12);
        assert_eq!(a.min(), whole.min());
        assert_eq!(a.max(), whole.max());
        // Merging an empty accumulator is the identity, both ways.
        let empty = Stats::new();
        let before = (a.count(), a.mean());
        a.merge(&empty);
        assert_eq!((a.count(), a.mean()), before);
        let mut e = Stats::new();
        e.merge(&a);
        assert_eq!(e.count(), a.count());
        assert_eq!(e.max(), a.max());
    }

    #[test]
    fn empty_stats_are_safe() {
        let s = Stats::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.stddev(), 0.0);
    }

    #[test]
    fn timer_advances() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(5));
        assert!(t.elapsed_secs() >= 0.004);
    }

    #[test]
    fn epoch_total() {
        let e = EpochTiming {
            train_secs: 10.0,
            eval_secs: 2.5,
            data_secs: 1.0,
            comm_secs: 0.5,
        };
        assert_eq!(e.total(), 12.5);
    }
}
