//! Classification metrics for the peak-calling head: numerically stable
//! BCE on logits, plus precision/recall/F1 at a threshold.

/// Binary cross-entropy on logits, `mean(max(z,0) − z·y + log1p(exp(−|z|)))`
/// — identical to the L2 model's loss (model.py `bce_with_logits`).
pub fn bce_with_logits(logits: &[f32], labels: &[f32]) -> f64 {
    assert_eq!(logits.len(), labels.len());
    if logits.is_empty() {
        return 0.0;
    }
    let s: f64 = logits
        .iter()
        .zip(labels)
        .map(|(&z, &y)| {
            let z = z as f64;
            let y = y as f64;
            z.max(0.0) - z * y + (-z.abs()).exp().ln_1p()
        })
        .sum();
    s / logits.len() as f64
}

/// Logistic sigmoid.
#[inline]
pub fn sigmoid(z: f32) -> f32 {
    if z >= 0.0 {
        1.0 / (1.0 + (-z).exp())
    } else {
        let e = z.exp();
        e / (1.0 + e)
    }
}

/// Confusion counts at `threshold` over probabilities.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct Confusion {
    pub tp: u64,
    pub fp: u64,
    pub tn: u64,
    pub fn_: u64,
}

impl Confusion {
    pub fn from_probs(probs: &[f32], labels: &[f32], threshold: f32) -> Self {
        assert_eq!(probs.len(), labels.len());
        let mut c = Confusion::default();
        for (&p, &y) in probs.iter().zip(labels) {
            match (p >= threshold, y > 0.5) {
                (true, true) => c.tp += 1,
                (true, false) => c.fp += 1,
                (false, false) => c.tn += 1,
                (false, true) => c.fn_ += 1,
            }
        }
        c
    }

    pub fn precision(&self) -> f64 {
        if self.tp + self.fp == 0 {
            0.0
        } else {
            self.tp as f64 / (self.tp + self.fp) as f64
        }
    }

    pub fn recall(&self) -> f64 {
        if self.tp + self.fn_ == 0 {
            0.0
        } else {
            self.tp as f64 / (self.tp + self.fn_) as f64
        }
    }

    pub fn f1(&self) -> f64 {
        let p = self.precision();
        let r = self.recall();
        if p + r == 0.0 {
            0.0
        } else {
            2.0 * p * r / (p + r)
        }
    }

    pub fn accuracy(&self) -> f64 {
        let total = self.tp + self.fp + self.tn + self.fn_;
        if total == 0 {
            0.0
        } else {
            (self.tp + self.tn) as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bce_matches_hand_values() {
        // z=0 ⇒ loss = ln 2 regardless of label.
        let l = bce_with_logits(&[0.0], &[1.0]);
        assert!((l - std::f64::consts::LN_2).abs() < 1e-12);
        // Large confident correct logit ⇒ ~0.
        assert!(bce_with_logits(&[20.0], &[1.0]) < 1e-8);
        // Large confident wrong logit ⇒ ~|z|.
        assert!((bce_with_logits(&[-20.0], &[1.0]) - 20.0).abs() < 1e-6);
    }

    #[test]
    fn bce_is_stable_at_extremes() {
        let v = bce_with_logits(&[1e4, -1e4], &[1.0, 0.0]);
        assert!(v.is_finite() && v < 1e-6);
    }

    #[test]
    fn sigmoid_symmetry() {
        for z in [-5.0f32, -1.0, 0.0, 2.5, 8.0] {
            assert!((sigmoid(z) + sigmoid(-z) - 1.0).abs() < 1e-6);
        }
        assert_eq!(sigmoid(0.0), 0.5);
    }

    #[test]
    fn confusion_counts() {
        let probs = [0.9f32, 0.8, 0.2, 0.4, 0.6];
        let labels = [1.0f32, 0.0, 0.0, 1.0, 1.0];
        let c = Confusion::from_probs(&probs, &labels, 0.5);
        assert_eq!(
            c,
            Confusion {
                tp: 2,
                fp: 1,
                tn: 1,
                fn_: 1
            }
        );
        assert!((c.precision() - 2.0 / 3.0).abs() < 1e-12);
        assert!((c.recall() - 2.0 / 3.0).abs() < 1e-12);
        assert!((c.f1() - 2.0 / 3.0).abs() < 1e-12);
        assert!((c.accuracy() - 0.6).abs() < 1e-12);
    }

    #[test]
    fn degenerate_confusion() {
        let c = Confusion::default();
        assert_eq!(c.precision(), 0.0);
        assert_eq!(c.recall(), 0.0);
        assert_eq!(c.f1(), 0.0);
    }
}
