//! Regression metrics for the denoising head: MSE and Pearson correlation
//! (AtacWorks reports both for the denoised track quality).

/// Mean squared error.
pub fn mse(pred: &[f32], target: &[f32]) -> f64 {
    assert_eq!(pred.len(), target.len());
    if pred.is_empty() {
        return 0.0;
    }
    let s: f64 = pred
        .iter()
        .zip(target)
        .map(|(&p, &t)| {
            let d = (p - t) as f64;
            d * d
        })
        .sum();
    s / pred.len() as f64
}

/// Pearson correlation coefficient; `None` if either side is constant.
pub fn pearson(pred: &[f32], target: &[f32]) -> Option<f64> {
    assert_eq!(pred.len(), target.len());
    let n = pred.len();
    if n < 2 {
        return None;
    }
    let nf = n as f64;
    let mp: f64 = pred.iter().map(|&v| v as f64).sum::<f64>() / nf;
    let mt: f64 = target.iter().map(|&v| v as f64).sum::<f64>() / nf;
    let (mut spt, mut spp, mut stt) = (0.0f64, 0.0f64, 0.0f64);
    for (&p, &t) in pred.iter().zip(target) {
        let dp = p as f64 - mp;
        let dt = t as f64 - mt;
        spt += dp * dt;
        spp += dp * dp;
        stt += dt * dt;
    }
    if spp <= 0.0 || stt <= 0.0 {
        return None;
    }
    Some(spt / (spp.sqrt() * stt.sqrt()))
}

/// Streaming MSE accumulator (per-epoch evaluation).
#[derive(Default, Debug, Clone, Copy)]
pub struct MseAccumulator {
    sum_sq: f64,
    count: u64,
}

impl MseAccumulator {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, pred: &[f32], target: &[f32]) {
        assert_eq!(pred.len(), target.len());
        for (&p, &t) in pred.iter().zip(target) {
            let d = (p - t) as f64;
            self.sum_sq += d * d;
        }
        self.count += pred.len() as u64;
    }

    pub fn compute(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_sq / self.count as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mse_basics() {
        assert_eq!(mse(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert_eq!(mse(&[0.0, 0.0], &[3.0, 4.0]), 12.5);
    }

    #[test]
    fn pearson_perfect_and_inverse() {
        let x = [1.0f32, 2.0, 3.0, 4.0];
        let y: Vec<f32> = x.iter().map(|&v| 2.0 * v + 1.0).collect();
        assert!((pearson(&x, &y).unwrap() - 1.0).abs() < 1e-12);
        let z: Vec<f32> = x.iter().map(|&v| -v).collect();
        assert!((pearson(&x, &z).unwrap() + 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_constant_is_undefined() {
        assert_eq!(pearson(&[1.0, 1.0, 1.0], &[1.0, 2.0, 3.0]), None);
    }

    #[test]
    fn accumulator_matches_direct() {
        let p = [0.5f32, 1.5, -2.0, 3.0];
        let t = [0.0f32, 1.0, -1.0, 4.0];
        let mut acc = MseAccumulator::new();
        acc.push(&p[..2], &t[..2]);
        acc.push(&p[2..], &t[2..]);
        assert!((acc.compute() - mse(&p, &t)).abs() < 1e-12);
    }
}
