//! Evaluation metrics and telemetry: exact AUROC ([`auroc`], the paper's
//! peak-calling accuracy metric), regression metrics ([`regression`]),
//! classification metrics ([`classification`]) and timing ([`timing`]).

pub mod auroc;
pub mod classification;
pub mod regression;
pub mod timing;

pub use auroc::{auroc, AurocAccumulator};
pub use classification::{bce_with_logits, sigmoid, Confusion};
pub use regression::{mse, pearson, MseAccumulator};
pub use timing::{EpochTiming, Stats, Timer};
