//! Evaluation metrics and telemetry: exact AUROC ([`auroc`], the paper's
//! peak-calling accuracy metric), regression metrics ([`regression`]),
//! classification metrics ([`classification`]), timing ([`timing`]) and
//! the serving subsystem's latency histograms ([`latency`]).

pub mod auroc;
pub mod classification;
pub mod latency;
pub mod regression;
pub mod timing;

pub use auroc::{auroc, AurocAccumulator};
pub use classification::{bce_with_logits, sigmoid, Confusion};
pub use latency::LatencyHistogram;
pub use regression::{mse, pearson, MseAccumulator};
pub use timing::{EpochTiming, Stats, Timer};
