//! `dilconv` — the launcher CLI for the dilconv1d framework.
//!
//! Subcommands (see README.md):
//!   train            end-to-end AtacWorks training (native engine)
//!   serve            batched inference serving over synthetic traffic
//!   sweep            regenerate Fig. 4/5/6 and the eq. 4 grid
//!   scaling          regenerate Figs. 8/9/10 and Table 2
//!   bench            regenerate Table 1 / §4.5.3 / §4.5.4 projections
//!   calibrate        measure host peak GFLOP/s
//!   artifacts-check  verify the AOT artifacts against the native kernels
//!   data-gen         inspect the synthetic ATAC-seq generator
//!
//! Argument parsing is hand-rolled (`--key value` / `--key=value`); the
//! offline build has no clap.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

use dilconv1d::bench_harness::tables::{backend_cell, markdown, pct, secs, speedup, write_csv};
use dilconv1d::bench_harness::{run_point, Pass, SweepConfig};
use dilconv1d::config::{ServeConfig, TrainConfig};
use dilconv1d::conv1d::test_util::rnd;
use dilconv1d::conv1d::{Backend, ConvParams};
use dilconv1d::coordinator::{checkpoint, experiment, Trainer};
use dilconv1d::data::atacseq::TrackConfig;
use dilconv1d::data::generate_track;
use dilconv1d::dist::{CommModel, Topology};
use dilconv1d::machine::workload::{model_epoch, Workload};
use dilconv1d::machine::{calibrate_host, MachineSpec, Precision, Strategy};
use dilconv1d::runtime::{Registry, Session, TrainState};

/// Parsed command line: subcommand + `--key value` flags.
struct Args {
    cmd: String,
    flags: BTreeMap<String, String>,
}

impl Args {
    fn parse() -> Result<Args> {
        let mut it = std::env::args().skip(1);
        let cmd = it.next().unwrap_or_else(|| "help".to_string());
        let mut flags = BTreeMap::new();
        let rest: Vec<String> = it.collect();
        let mut i = 0;
        while i < rest.len() {
            let a = &rest[i];
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("unexpected argument '{a}' (flags are --key value)"))?;
            if let Some((k, v)) = key.split_once('=') {
                flags.insert(k.to_string(), v.to_string());
            } else if i + 1 < rest.len() && !rest[i + 1].starts_with("--") {
                flags.insert(key.to_string(), rest[i + 1].clone());
                i += 1;
            } else {
                flags.insert(key.to_string(), "true".to_string());
            }
            i += 1;
        }
        Ok(Args { cmd, flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    fn usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} must be an integer")),
        }
    }

    fn f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} must be a number")),
        }
    }

    fn bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::parse()?;
    match args.cmd.as_str() {
        "train" => cmd_train(&args),
        "serve" => cmd_serve(&args),
        "sweep" => cmd_sweep(&args),
        "scaling" => cmd_scaling(&args),
        "bench" => cmd_bench(&args),
        "calibrate" => cmd_calibrate(),
        "artifacts-check" => cmd_artifacts_check(&args),
        "data-gen" => cmd_data_gen(&args),
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            Ok(())
        }
        other => bail!("unknown subcommand '{other}' (try `dilconv help`)"),
    }
}

const HELP: &str = "\
dilconv — efficient & generic 1D dilated convolution layer (paper reproduction)

USAGE: dilconv <subcommand> [--flags]

  train            train the AtacWorks-like network on synthetic ATAC-seq
                   [--config cfg.toml] [--epochs N] [--batch N] [--sockets N]
                   [--width N] [--pad N] [--segments N] [--channels N]
                   [--blocks N] [--backend brgemm|onednn|direct|bf16|i8]
                   [--lr F] [--threads N] [--seed N] [--checkpoint out.ckpt]
                   [--autotune] [--tune-cache tune.json]
                   [--partition batch|grid] (grid: split the N x ceil(Q/64)
                   width-block grid, so N=1 still uses every thread)
                   [--post-ops bias_relu|bias_sigmoid|bias]
                   [--precision f32|bf16|i8] (bf16 = split Adam: fp32
                   master weights, bf16 working copies + kernels)
                   [--overlap] [--bucket-mb F] (bucketed all-reduce fired
                   as each layer's backward completes)
  serve            batched inference serving: dynamic batcher + shape-
                   bucketed plan cache, driven by an open-loop synthetic
                   load (reports p50/p99 latency, seq/s, per-bucket stats)
                   [--config cfg.toml] [--checkpoint ckpt]
                   [--buckets 1024,2048,4096] [--max-batch N]
                   [--window-ms F] [--queue N] [--workers N] [--threads N]
                   [--sockets N] shard the worker pool across N NUMA
                   sockets: first-touch replica placement + bucket-home
                   routing (0 = detect via CONV1D_TOPOLOGY / sysfs;
                   bits identical either way)
                   [--backend brgemm|onednn|direct|bf16|i8]
                   [--precision f32|bf16|i8] (i8 = per-channel symmetric
                   weights + one-time calibrated activation scales)
                   [--partition batch|grid]
                   [--autotune] [--cache-capacity N] [--no-warm]
                   [--fuse true|false] net-level fused/arena plan
                   (default on; bits identical either way)
                   [--requests N] [--rate F] [--seed N]
                   [--listen addr:port] serve the TCP wire protocol
                   instead of synthetic load ([--duration-secs F] then
                   drain and print stats; default: run until killed)
                   [--stream true|false] [--stream-window N] route
                   requests wider than every bucket through halo-
                   overlapped streaming windows (bit-identical to
                   whole-sequence evaluation) [--drain-ms F]
                   [--deadline-ms F] default per-request deadline
                   (0 = off; expired requests shed before compute)
                   [--idle-timeout-ms F] close silent connections
                   (0 = off) [--max-restarts N] supervisor respawn
                   budget per worker rank
  sweep            efficiency sweeps (Figs. 4/5/6, eq. 4 grid)
                   --figure fig4|fig5|fig6|eq4 [--quick] [--csv out.csv]
                   [--reps N] [--batch N] [--max-q N]
  scaling          multi-socket scaling (Figs. 8/9/10, Table 2)
                   [--precision fp32|bf16] [--measure]
  bench            end-to-end projections --experiment table1|table2|
                   long-segment|large-dataset
  calibrate        measure host sustained GFLOP/s
  artifacts-check  run AOT HLO artifacts and compare with native kernels
                   [--dir artifacts] [--train-steps N]
  data-gen         synthetic ATAC-seq stats [--segments N] [--width N]
";

// ------------------------------------------------------------------ train

fn cmd_train(args: &Args) -> Result<()> {
    let mut cfg = match args.get("config") {
        Some(p) => TrainConfig::from_file(p)?,
        None => TrainConfig::default(),
    };
    cfg.epochs = args.usize("epochs", cfg.epochs)?;
    cfg.batch_size = args.usize("batch", cfg.batch_size)?;
    cfg.sockets = args.usize("sockets", cfg.sockets)?;
    cfg.segment_width = args.usize("width", cfg.segment_width)?;
    cfg.segment_pad = args.usize("pad", cfg.segment_pad)?;
    cfg.train_segments = args.usize("segments", cfg.train_segments)?;
    cfg.channels = args.usize("channels", cfg.channels)?;
    cfg.n_blocks = args.usize("blocks", cfg.n_blocks)?;
    cfg.threads_per_socket = args.usize("threads", cfg.threads_per_socket)?;
    cfg.seed = args.usize("seed", cfg.seed as usize)? as u64;
    cfg.lr = args.f64("lr", cfg.lr)?;
    if let Some(b) = args.get("backend") {
        // Registry-name selection: any conv1d::lookup_kernel alias,
        // including "bf16" (BRGEMM backend at bf16 precision).
        cfg.apply_backend_name(b).map_err(|e| anyhow!(e))?;
    }
    if let Some(p) = args.get("precision") {
        // After --backend, so an explicit precision stays authoritative.
        cfg.precision = match p.to_ascii_lowercase().as_str() {
            "f32" | "fp32" => Precision::F32,
            "bf16" | "bfloat16" => Precision::Bf16,
            "i8" | "int8" => Precision::I8,
            other => bail!("unknown precision '{other}' (f32|bf16|i8)"),
        };
    }
    if let Some(s) = args.get("partition") {
        cfg.partition = s.parse().map_err(|e: String| anyhow!(e))?;
    }
    if args.bool("autotune") {
        cfg.autotune = true;
    }
    if let Some(p) = args.get("tune-cache") {
        cfg.tune_cache = Some(p.to_string());
    }
    if let Some(s) = args.get("post-ops") {
        cfg.post_ops = dilconv1d::conv1d::PostOps::parse(s).map_err(|e| anyhow!(e))?;
    }
    if args.bool("overlap") {
        cfg.overlap = true;
    }
    let bucket_mb = args.f64("bucket-mb", cfg.bucket_mb)?;
    if bucket_mb <= 0.0 {
        bail!("--bucket-mb must be positive, got {bucket_mb}");
    }
    cfg.bucket_mb = bucket_mb;
    println!(
        "training AtacWorks-like net: {} conv layers, ch={}, S={}, d={}, W={} (padded {}), \
         {} train segments, batch {}, {} sockets, backend {:?}, precision {:?}, \
         partition {}, isa {}{}",
        1 + 2 * cfg.n_blocks + 2,
        cfg.channels,
        cfg.filter_size,
        cfg.dilation,
        cfg.segment_width,
        cfg.padded_width(),
        cfg.train_segments,
        cfg.batch_size,
        cfg.sockets,
        cfg.backend,
        cfg.precision,
        cfg.partition,
        dilconv1d::conv1d::simd::active().isa(),
        if cfg.overlap {
            format!(", overlapped all-reduce ({} MiB buckets)", cfg.bucket_mb)
        } else {
            String::new()
        },
    );
    let mut trainer = Trainer::new(cfg.clone())?;
    println!("parameters: {}", trainer.param_count());
    let reports = trainer.train(|r| {
        println!(
            "epoch {:>3}  loss {:.5}  (mse {:.5} bce {:.5})  val_mse {:.5}  val_auroc {}  \
             train {:.2}s eval {:.2}s comm(model) {:.3}s exposed {:.3}s  [{} steps]",
            r.epoch,
            r.train_loss,
            r.train_mse,
            r.train_bce,
            r.val_mse,
            r.val_auroc.map_or("n/a".into(), |a| format!("{a:.4}")),
            r.timing.train_secs,
            r.timing.eval_secs,
            r.modeled_comm_secs,
            r.exposed_comm_secs,
            r.steps,
        );
    });
    if let (Some(first), Some(last)) = (reports.first(), reports.last()) {
        println!(
            "loss {:.5} -> {:.5} over {} epochs; final AUROC {}",
            first.train_loss,
            last.train_loss,
            reports.len(),
            last.val_auroc.map_or("n/a".into(), |a| format!("{a:.4}")),
        );
    }
    if let Some(path) = args.get("checkpoint") {
        checkpoint::save(path, trainer.params())?;
        println!("checkpoint written to {path}");
    }
    Ok(())
}

// ------------------------------------------------------------------ serve

fn cmd_serve(args: &Args) -> Result<()> {
    let mut cfg = match args.get("config") {
        Some(p) => ServeConfig::from_file(p)?,
        None => ServeConfig::default(),
    };
    // Load-driver flags are owned here, everything else by the config.
    let driver_flags = ["config", "checkpoint", "requests", "rate", "seed", "duration-secs"];
    for (k, v) in &args.flags {
        if driver_flags.contains(&k.as_str()) {
            continue;
        }
        if !cfg.apply_flag(k, v)? {
            bail!("unknown flag --{k} for serve (try `dilconv help`)");
        }
    }
    cfg.validate()?;
    let net_cfg = cfg.net_config();
    let params = match args.get("checkpoint") {
        Some(p) => {
            let params = checkpoint::load(p)?;
            println!("loaded checkpoint {p} ({} parameters)", params.len());
            params
        }
        None => dilconv1d::model::AtacWorksNet::init(net_cfg, cfg.seed).pack_params(),
    };
    println!(
        "serving AtacWorks-like net: {} conv layers, ch={}, buckets [{}], max_batch {}, \
         window {} ms, queue {}, {} worker(s) x {} thread(s) on {}, backend {}, \
         precision {:?}, partition {}, autotune {}, warm {}, fuse {}",
        net_cfg.n_conv_layers(),
        net_cfg.channels,
        cfg.buckets,
        cfg.max_batch,
        cfg.window_ms,
        cfg.queue_depth,
        cfg.workers,
        cfg.threads,
        match cfg.sockets {
            0 => "auto-detected sockets".to_string(),
            1 => "1 socket (flat pool)".to_string(),
            s => format!("{s} sockets"),
        },
        cfg.backend,
        cfg.precision,
        cfg.partition,
        cfg.autotune,
        cfg.warm,
        cfg.fuse,
    );
    match cfg.resolved_stream_window() {
        Some(w) => println!(
            "streaming: over-wide requests run in {w}-wide windows overlapping by the \
             receptive-field halo ({} columns)",
            net_cfg.receptive_field_reach()
        ),
        None => println!("streaming: off (over-wide requests are rejected)"),
    }
    let t0 = std::time::Instant::now();
    let server = dilconv1d::serve::Server::start(net_cfg, &params, cfg.batcher_opts())
        .map_err(|e| anyhow!(e))?;
    println!(
        "server up in {:.2}s ({})",
        t0.elapsed().as_secs_f64(),
        if cfg.warm {
            "plan cache warmed for the resident bucket suffix"
        } else {
            "cold plan cache; first requests pay plan builds"
        }
    );
    if cfg.listen.is_some() {
        return run_listen(&cfg, server, args);
    }

    // Synthetic open-loop traffic: for each bucket, an exact-fit width
    // and a partial-fill width (exercises the truncation path).
    let requests = args.usize("requests", 64)?;
    let rate = args.f64("rate", 100.0)?;
    if rate.is_nan() || rate <= 0.0 {
        bail!("--rate must be a positive arrival rate, got {rate}");
    }
    if requests == 0 {
        bail!("--requests must be at least 1");
    }
    let seed = args.usize("seed", 7)? as u64;
    // Exact-fit + partial-fill width per bucket (exercises truncation).
    let mix = dilconv1d::serve::WidthMix::bucket_mix(&cfg.buckets).map_err(|e| anyhow!(e))?;
    println!(
        "open-loop load: {requests} requests at {rate}/s over widths {:?}",
        mix.widths()
    );
    let report = dilconv1d::serve::run_open_loop(&server, &mix, rate, requests, seed);
    let metrics = server.shutdown();

    println!(
        "\ncompleted {}/{} (rejected {}, failed {}) in {:.2}s -> {:.1} seq/s",
        report.completed,
        report.offered,
        report.rejected,
        report.failed,
        report.wall_secs,
        report.seq_per_sec(),
    );
    println!(
        "latency: p50 {:.2} ms  p99 {:.2} ms  mean {:.2} ms  max {:.2} ms  | mean batch fill {:.2}/{}",
        report.latency.p50() * 1e3,
        report.latency.p99() * 1e3,
        report.latency.mean() * 1e3,
        report.latency.max() * 1e3,
        report.mean_batch_rows,
        cfg.max_batch,
    );
    let mut rows = Vec::new();
    for (bucket, m) in &metrics.per_bucket {
        rows.push(vec![
            bucket.to_string(),
            m.requests.to_string(),
            m.batches.to_string(),
            format!("{:.2}", m.requests as f64 / m.batches.max(1) as f64),
            format!("{:.2}", m.latency.p50() * 1e3),
            format!("{:.2}", m.latency.p99() * 1e3),
        ]);
    }
    println!(
        "{}",
        markdown(
            &["bucket", "requests", "batches", "fill", "p50 ms", "p99 ms"],
            &rows
        )
    );
    Ok(())
}

/// `dilconv serve --listen`: hand the batcher to the TCP front-end and
/// serve the wire protocol instead of generating synthetic load.
fn run_listen(cfg: &ServeConfig, server: dilconv1d::serve::Server, args: &Args) -> Result<()> {
    let addr = cfg.listen.as_deref().expect("listen mode requires an address");
    let opts = cfg.net_opts();
    let net = dilconv1d::serve::NetServer::bind(addr, server, opts)
        .with_context(|| format!("binding {addr}"))?;
    println!(
        "listening on {} (wire protocol v{})",
        net.local_addr(),
        dilconv1d::serve::net::WIRE_VERSION
    );
    match args.get("duration-secs") {
        Some(_) => {
            let secs = args.f64("duration-secs", 0.0)?;
            if secs.is_nan() || secs <= 0.0 {
                bail!("--duration-secs must be positive, got {secs}");
            }
            std::thread::sleep(std::time::Duration::from_secs_f64(secs));
        }
        None => loop {
            // Serve until the process is killed (no --duration-secs).
            std::thread::sleep(std::time::Duration::from_secs(3600));
        },
    }
    let (metrics, stats) = net.shutdown();
    println!(
        "\nconnections: {} accepted, {} rejected (busy), {} idle-closed",
        stats.connections_accepted, stats.connections_rejected, stats.connections_idle_closed
    );
    println!(
        "requests: {} ok ({} streamed), {} busy, {} deadline, {} error, {} malformed",
        stats.requests_ok,
        stats.requests_streamed,
        stats.requests_backpressure,
        stats.requests_deadline,
        stats.requests_error,
        stats.requests_malformed
    );
    println!(
        "recovery: {} worker panics, {} restarts, {} deadline-shed, {} handler panics",
        metrics.worker_panics, metrics.restarts, metrics.deadline_shed, stats.handler_panics
    );
    println!(
        "wire: {} in, {} out",
        dilconv1d::util::human_bytes(stats.bytes_in),
        dilconv1d::util::human_bytes(stats.bytes_out)
    );
    println!(
        "served {} requests in {:.2}s -> {:.1} seq/s; latency p50 {:.2} ms p99 {:.2} ms; \
         {} streamed ({} windows)",
        metrics.completed,
        metrics.elapsed_secs(),
        metrics.seq_per_sec(),
        metrics.latency.p50() * 1e3,
        metrics.latency.p99() * 1e3,
        metrics.streamed,
        metrics.stream_windows,
    );
    Ok(())
}

// ------------------------------------------------------------------ sweep

fn cmd_sweep(args: &Args) -> Result<()> {
    let figure = args.get("figure").unwrap_or("fig4");
    let quick = args.bool("quick");
    let reps = args.usize("reps", if quick { 2 } else { 3 })?;
    let batch = args.usize("batch", 2)?;
    let max_q = args.usize("max-q", if quick { 5_000 } else { 60_000 })?;
    let (grid, precision, machine, label) = match figure {
        "fig4" => (experiment::fig4_grid(), Precision::F32, MachineSpec::cascade_lake(), "Fig. 4: C=15 K=15 d=8, FP32, CLX"),
        "fig5" => (experiment::fig5_grid(), Precision::F32, MachineSpec::cascade_lake(), "Fig. 5: C=64 K=64 d=1, FP32, CLX"),
        "fig6" => (experiment::fig6_grid(), Precision::Bf16, MachineSpec::cooper_lake(), "Fig. 6: C=32 K=32 d=4, BF16, CPX"),
        "eq4" => (experiment::eq4_grid(), Precision::F32, MachineSpec::cascade_lake(), "Eq. 4 condition grid"),
        other => bail!("unknown figure '{other}'"),
    };
    let grid: Vec<_> = if quick {
        grid.into_iter()
            .filter(|&(_, _, q, s, _)| (s == 5 || s == 51 || s == 9) && q <= 20_000)
            .collect()
    } else {
        grid
    };
    println!("# {label}\n# host calibration...");
    let host_peak = calibrate_host();
    println!("# host sustained ≈ {host_peak:.2} GFLOP/s (1 core)\n");
    let cfg = SweepConfig {
        batch,
        reps,
        max_measured_q: max_q,
        host_gflops_peak: host_peak,
        threads: 1,
    };
    let mut rows = Vec::new();
    for &(c, k, q, s, d) in &grid {
        let ours = run_point(&cfg, c, k, q, s, d, Pass::Forward, Backend::Brgemm, precision, &machine);
        let base = run_point(&cfg, c, k, q, s, d, Pass::Forward, Backend::Im2col, Precision::F32, &machine);
        let bwd = run_point(&cfg, c, k, q, s, d, Pass::BackwardData, Backend::Brgemm, precision, &machine);
        rows.push(vec![
            format!("{c}x{k}"),
            q.to_string(),
            s.to_string(),
            d.to_string(),
            backend_cell(ours.backend),
            secs(ours.timing.median_secs),
            format!("{:.2}", ours.host_gflops),
            pct(ours.host_eff),
            secs(base.timing.median_secs),
            speedup(base.timing.median_secs / ours.timing.median_secs),
            secs(bwd.timing.median_secs),
            pct(ours.modeled_eff),
            pct(base.modeled_eff),
        ]);
    }
    let headers = vec![
        "CxK", "Q", "S", "d", "kernel", "ours fwd", "GF/s", "host eff", "baseline fwd",
        "speedup", "ours bwd-d", "modeled eff (paper hw)", "modeled eff baseline",
    ];
    println!("{}", markdown(&headers, &rows));
    if let Some(path) = args.get("csv") {
        write_csv(path, &headers, &rows)?;
        println!("# csv written to {path}");
    }
    Ok(())
}

// ---------------------------------------------------------------- scaling

fn cmd_scaling(args: &Args) -> Result<()> {
    let prec = match args.get("precision").unwrap_or("fp32") {
        "fp32" | "f32" => Precision::F32,
        "bf16" => Precision::Bf16,
        other => bail!("unknown precision '{other}'"),
    };
    let w = Workload::paper();
    let comm = CommModel::fabric();
    println!(
        "# Figs. 8/9: modeled AtacWorks epoch time on CPX sockets ({prec:?})"
    );
    let spec = MachineSpec::cooper_lake();
    let t1 = model_epoch(&w, &spec, prec, Strategy::Brgemm, &Topology::xeon(1), &comm);
    let total_flops = w.train_flops_per_sample() as f64 * w.train_segments as f64;
    let mut rows = Vec::new();
    for &s in &[1usize, 2, 4, 8, 16] {
        let t = model_epoch(&w, &spec, prec, Strategy::Brgemm, &Topology::xeon(s), &comm);
        // Per-socket efficiency rates the kernels against one socket's
        // peak; node efficiency divides by `peak_node` and includes the
        // collective, so the gap between the two columns is exactly the
        // communication + reserved-core loss of scaling out.
        let socket_eff = total_flops / s as f64 / t.compute_secs / spec.peak(prec);
        let node_eff =
            total_flops / (t.compute_secs + t.comm_secs) / spec.peak_node(prec, s);
        rows.push(vec![
            s.to_string(),
            Topology::xeon(s).paper_batch_size().to_string(),
            secs(t.compute_secs),
            secs(t.comm_secs),
            secs(t.eval_secs),
            secs(t.total()),
            speedup(t1.total() / t.total()),
            speedup((t1.compute_secs + t1.comm_secs) / (t.compute_secs + t.comm_secs)),
            pct(socket_eff),
            pct(node_eff),
        ]);
    }
    println!(
        "{}",
        markdown(
            &["sockets", "batch", "compute", "comm", "eval", "total", "speedup", "train-only speedup", "socket eff", "node eff"],
            &rows
        )
    );

    // Table 2 / Fig. 10: vs 8 V100 (162 s from the AtacWorks paper).
    println!("# Table 2: sockets vs 8 V100 (paper: CLX 1.41x, CPX fp32 1.57x, CPX bf16 2.27x)");
    let mut rows = Vec::new();
    let v100 = 162.0;
    for (dev, spec, p2, sockets) in [
        ("16s CLX", MachineSpec::cascade_lake(), Precision::F32, 16usize),
        ("16s CPX", MachineSpec::cooper_lake(), Precision::F32, 16),
        ("8s CPX", MachineSpec::cooper_lake(), Precision::Bf16, 8),
        ("16s CPX", MachineSpec::cooper_lake(), Precision::Bf16, 16),
    ] {
        let t = model_epoch(&w, &spec, p2, Strategy::Brgemm, &Topology::xeon(sockets), &comm);
        let paper = experiment::TABLE2
            .iter()
            .find(|r| r.device == dev && r.precision == (if p2 == Precision::F32 { "FP32" } else { "BF16" }));
        rows.push(vec![
            dev.to_string(),
            if p2 == Precision::F32 { "FP32" } else { "BF16" }.to_string(),
            secs(t.total()),
            speedup(v100 / t.total()),
            paper.map_or("—".into(), |r| secs(r.time_per_epoch)),
            paper.map_or("—".into(), |r| speedup(r.speedup_vs_v100)),
        ]);
    }
    println!(
        "{}",
        markdown(
            &["device", "precision", "modeled epoch", "modeled speedup vs V100", "paper epoch", "paper speedup"],
            &rows
        )
    );

    // Optional measured mini-scaling on this host (sockets = worker replicas).
    if args.bool("measure") {
        println!("# measured mini-scaling on this host (scaled workload, in-process sockets)");
        let mut rows = Vec::new();
        let mut base = None;
        for &s in &[1usize, 2, 4] {
            let cfg = TrainConfig {
                channels: 8,
                n_blocks: 2,
                filter_size: 15,
                dilation: 4,
                segment_width: 800,
                segment_pad: 80,
                train_segments: 16,
                batch_size: 4,
                epochs: 1,
                sockets: s,
                ..TrainConfig::default()
            };
            let mut tr = Trainer::new(cfg)?;
            let r = tr.run_epoch(0);
            base.get_or_insert(r.timing.train_secs);
            rows.push(vec![
                s.to_string(),
                secs(r.timing.train_secs),
                format!("{:.4}", r.train_loss),
                speedup(base.unwrap() / r.timing.train_secs),
            ]);
        }
        println!("{}", markdown(&["sockets", "train secs", "loss", "speedup"], &rows));
        println!("# note: this host has 1 physical core; measured 'sockets' share it.");
    }
    Ok(())
}

// ------------------------------------------------------------------ bench

fn cmd_bench(args: &Args) -> Result<()> {
    let exp = args.get("experiment").unwrap_or("table1");
    let comm = CommModel::fabric();
    match exp {
        "table1" => {
            let w = Workload::paper();
            println!("# Table 1: single-socket end-to-end training (paper vs modeled)");
            let mut rows = Vec::new();
            let cases: [(&str, &str, MachineSpec, Precision, Strategy); 4] = [
                ("1s CLX", "oneDNN (FP32)", MachineSpec::cascade_lake(), Precision::F32, Strategy::Im2col),
                ("1s CLX", "LIBXSMM (FP32)", MachineSpec::cascade_lake(), Precision::F32, Strategy::Brgemm),
                ("1s CPX", "LIBXSMM (FP32)", MachineSpec::cooper_lake(), Precision::F32, Strategy::Brgemm),
                ("1s CPX", "LIBXSMM (BF16)", MachineSpec::cooper_lake(), Precision::Bf16, Strategy::Brgemm),
            ];
            for (dev, code, spec, prec, strat) in cases {
                let t = model_epoch(&w, &spec, prec, strat, &Topology::xeon(1), &comm);
                let paper = experiment::TABLE1
                    .iter()
                    .find(|r| {
                        r.device == dev
                            && code.starts_with(r.code)
                            && code.contains(r.precision)
                    })
                    .map(|r| r.time_per_epoch);
                rows.push(vec![
                    dev.into(),
                    code.into(),
                    secs(t.total()),
                    paper.map_or("—".into(), secs),
                ]);
            }
            println!("{}", markdown(&["device", "code", "modeled epoch", "paper epoch"], &rows));
            let ours = model_epoch(&w, &MachineSpec::cascade_lake(), Precision::F32, Strategy::Brgemm, &Topology::xeon(1), &comm);
            let lib = model_epoch(&w, &MachineSpec::cascade_lake(), Precision::F32, Strategy::Im2col, &Topology::xeon(1), &comm);
            println!(
                "modeled CLX speedup (oneDNN-analog / BRGEMM): {} — paper: {}",
                speedup(lib.total() / ours.total()),
                speedup(experiment::table1_clx_speedup()),
            );
        }
        "long-segment" => {
            // §4.5.3: 600k-wide segments, 2 CLX sockets, batch 52 → 977.4 s.
            let w = Workload::long_segments();
            let t = model_epoch(&w, &MachineSpec::cascade_lake(), Precision::F32, Strategy::Brgemm, &Topology::xeon(2), &comm);
            println!("# §4.5.3 long segments (600k wide, 4191 segs, 2s CLX)");
            println!("modeled epoch: {} — paper: 977.4s", secs(t.total()));
            let bytes_per_track = 600_000usize * 4 * 3; // x, clean, peaks
            let batch_bytes = 52 * bytes_per_track;
            println!(
                "activation footprint at batch 52 x 27 layers ≈ {} (fits CPU DRAM; a 16 GB V100 OOMs — paper could not run this on V100)",
                dilconv1d::util::human_bytes((batch_bytes * 27) as u64),
            );
        }
        "large-dataset" => {
            // §4.5.4: 9.16× dataset on 16s CLX → 872.1 s/epoch (train only).
            let w = Workload::large_dataset();
            let t = model_epoch(&w, &MachineSpec::cascade_lake(), Precision::F32, Strategy::Brgemm, &Topology::xeon(16), &comm);
            println!("# §4.5.4 large dataset (293242 segs, 16s CLX)");
            println!(
                "modeled train-only epoch: {} — paper: 872.1s (dataset ratio {:.2}x of the 32k-segment run)",
                secs(t.compute_secs + t.comm_secs),
                w.train_segments as f64 / 32_000.0,
            );
        }
        other => bail!("unknown experiment '{other}'"),
    }
    Ok(())
}

// ------------------------------------------------------------- calibrate

fn cmd_calibrate() -> Result<()> {
    println!("calibrating host sustained GEMM throughput...");
    let g = calibrate_host();
    println!("host ≈ {g:.2} GFLOP/s (single core, f32 micro-kernel)");
    Ok(())
}

// ------------------------------------------------------- artifacts-check

fn cmd_artifacts_check(args: &Args) -> Result<()> {
    let dir = args.get("dir").unwrap_or("artifacts");
    let reg = Registry::load(dir)?;
    println!("registry: {} artifacts in {dir}", reg.artifacts.len());
    let mut sess = Session::cpu()?;
    println!("PJRT: {}", sess.platform());

    // 1. conv_fwd artifacts vs the native BRGEMM kernel.
    let conv_names: Vec<String> = reg
        .artifacts
        .values()
        .filter(|a| a.kind == "conv_fwd")
        .map(|a| a.name.clone())
        .collect();
    for name in conv_names {
        let art = reg.get(&name)?.clone();
        let shp = &art.inputs[0].shape; // (n, c, w)
        let wshp = &art.inputs[1].shape; // (s, k, c)
        let (n, c, w) = (shp[0], shp[1], shp[2]);
        let (s, k) = (wshp[0], wshp[1]);
        let q = art.outputs[0].shape[2];
        let d = if s > 1 { (w - q) / (s - 1) } else { 1 };
        let x = rnd(n * c * w, 7);
        let wt = rnd(s * k * c, 8);
        let got = dilconv1d::runtime::step::run_conv_fwd(&mut sess, &art, &x, &wt)?;
        let p = ConvParams::new(n, c, k, w, s, d).unwrap();
        let mut want = vec![0.0f32; n * k * q];
        // Native kernel takes (S,K,C) directly — same layout as the artifact.
        dilconv1d::conv1d::forward::forward(&p, &x, &wt, &mut want, 1);
        let mut max_err = 0.0f32;
        for (g, w_) in got.iter().zip(&want) {
            max_err = max_err.max((g - w_).abs() / (1.0 + w_.abs()));
        }
        println!(
            "{name}: PJRT vs native max rel err {max_err:.2e} {}",
            if max_err < 1e-4 { "OK" } else { "MISMATCH" }
        );
        if max_err >= 1e-4 {
            bail!("artifact {name} disagrees with the native kernel");
        }
    }

    // 2. Train a few steps of the tiny model through PJRT.
    let steps = args.usize("train-steps", 3)?;
    if reg.artifacts.contains_key("train_step_tiny") {
        let art = reg.get("train_step_tiny")?.clone();
        sess.load("train_step_tiny", &art.path)?;
        let eval_art = reg.get("eval_step_tiny")?.clone();
        sess.load("eval_step_tiny", &eval_art.path)?;
        let mut st = TrainState::init(&reg, "tiny")?;
        let mut track = TrackConfig::default().scaled(st.width);
        track.pad = 0;
        track.width = st.width;
        let mut first = None;
        let mut last = 0.0;
        for i in 0..steps {
            let idx: Vec<u64> = (0..st.batch as u64)
                .map(|r| (i * st.batch) as u64 + r)
                .collect();
            let b = dilconv1d::data::make_batch(&track, 1, &idx);
            let l = st.step(&sess, &b.x, &b.clean, &b.peaks)?;
            println!(
                "pjrt train step {i}: loss {:.5} (mse {:.5} bce {:.5})",
                l.total, l.mse, l.bce
            );
            first.get_or_insert(l.total);
            last = l.total;
        }
        if steps >= 3 {
            anyhow::ensure!(
                last < first.unwrap(),
                "PJRT training loss did not decrease: {} -> {last}",
                first.unwrap()
            );
        }
        let idx: Vec<u64> = (0..st.batch as u64).collect();
        let b = dilconv1d::data::make_batch(&track, 1, &idx);
        let (den, probs) = st.eval(&sess, &b.x)?;
        println!(
            "pjrt eval: denoised len {}, probs in [{:.3}, {:.3}]",
            den.len(),
            probs.iter().cloned().fold(f32::INFINITY, f32::min),
            probs.iter().cloned().fold(f32::NEG_INFINITY, f32::max),
        );
        println!("artifacts-check OK");
    } else {
        println!("(no train_step_tiny artifact; model check skipped)");
    }
    Ok(())
}

// ------------------------------------------------------------- data-gen

fn cmd_data_gen(args: &Args) -> Result<()> {
    let segments = args.usize("segments", 8)?;
    let width = args.usize("width", 5_000)?;
    let cfg = TrackConfig::default().scaled(width);
    println!(
        "synthetic ATAC-seq: width {} (+{} pad/side), bg rate {}, subsample {}",
        cfg.width, cfg.pad, cfg.background_rate, cfg.subsample
    );
    let mut rows = Vec::new();
    for i in 0..segments as u64 {
        let t = generate_track(&cfg, 42, i);
        let cov: f64 = t.clean.iter().map(|&v| v as f64).sum::<f64>() / cfg.width as f64;
        let noisy: f64 = t.noisy.iter().map(|&v| v as f64).sum::<f64>() / cfg.width as f64;
        let peak_frac: f64 = t.peaks.iter().sum::<f32>() as f64 / cfg.width as f64;
        rows.push(vec![
            i.to_string(),
            format!("{cov:.3}"),
            format!("{noisy:.3}"),
            format!("{:.2}%", peak_frac * 100.0),
            format!("{:?}", dilconv1d::data::dataset::split_of(42, i)),
        ]);
    }
    println!(
        "{}",
        markdown(
            &["segment", "clean cov/base", "noisy cov/base", "peak frac", "split"],
            &rows
        )
    );
    Ok(())
}
