//! im2col + GEMM convolution — the "library baseline" (oneDNN-analog).
//!
//! Classical lowering of convolution to one large GEMM (paper Sec. 1,
//! refs [1, 33]): materialise the patch matrix
//!
//! ```text
//! Col[(c·S + s), q] = In[c, q + d·s]        # (C·S, Q)
//! Out = W_mat · Col                          # (K, C·S) × (C·S, Q)
//! ```
//!
//! This is what generic 2D-conv libraries degenerate to on 1D data with
//! long widths: the Col matrix is `S×` larger than the input, so for
//! `S = 51` the pass moves ~51× more bytes than the BRGEMM formulation —
//! precisely the inefficiency the paper's Figs. 4–6 show for oneDNN as
//! `S` and `Q` grow. It is numerically exact, so it doubles as a second
//! independent oracle for the BRGEMM kernels.

use super::gemm::gemm_f32;
use super::params::{ConvParams, WIDTH_BLOCK};
use super::post::{apply_block, PostOps};
use super::threading::{par_batch_chunks_scratch, ExecCtx};

/// Materialise the im2col patch matrix for one batch element: `(C·S, Q)`.
pub fn im2col_single(p: &ConvParams, x: &[f32], col: &mut [f32]) {
    let (c, s, d, w, q) = (p.c, p.s, p.d, p.w, p.q());
    debug_assert_eq!(x.len(), c * w);
    debug_assert_eq!(col.len(), c * s * q);
    for ic in 0..c {
        for is in 0..s {
            let src = &x[ic * w + is * d..ic * w + is * d + q];
            let dst = &mut col[(ic * s + is) * q..(ic * s + is) * q + q];
            dst.copy_from_slice(src);
        }
    }
}

/// Flatten the `(K, C, S)` weight into the `(K, C·S)` GEMM operand.
/// (The KCS layout is already row-major contiguous in (C, S), so this is
/// a no-op view; provided for API symmetry and documentation.)
#[inline]
pub fn weight_matrix(w_kcs: &[f32]) -> &[f32] {
    w_kcs
}

/// Forward pass for one batch element via im2col + blocked GEMM.
pub fn forward_im2col_single(
    p: &ConvParams,
    x: &[f32],
    w_kcs: &[f32],
    col: &mut [f32],
    out: &mut [f32],
) {
    forward_im2col_single_post(p, x, w_kcs, col, out, &PostOps::none(), &[], None);
}

/// [`forward_im2col_single`] with the post-op epilogue fused into the
/// width block loop (each `(K, nb)` block gets its epilogue right after
/// the block GEMM, while it is still cache-hot).
#[allow(clippy::too_many_arguments)]
pub fn forward_im2col_single_post(
    p: &ConvParams,
    x: &[f32],
    w_kcs: &[f32],
    col: &mut [f32],
    out: &mut [f32],
    ops: &PostOps,
    bias: &[f32],
    res_row: Option<&[f32]>,
) {
    let (c, k, s, q) = (p.c, p.k, p.s, p.q());
    debug_assert_eq!(p.stride, 1, "kernels compute at stride 1");
    im2col_single(p, x, col);
    out[..k * q].fill(0.0);
    // Blocked over the width so the GEMM micro-kernel's stack accumulator
    // applies; the data movement cost of `col` dominates regardless.
    let mut pos = 0;
    while pos < q {
        let nb = WIDTH_BLOCK.min(q - pos);
        gemm_f32(
            weight_matrix(w_kcs),
            c * s,
            &col[pos..],
            q,
            &mut out[pos..],
            q,
            k,
            nb,
            c * s,
        );
        apply_block(ops, bias, res_row, out, k, q, pos, nb);
        pos += nb;
    }
}

/// Batched im2col forward with a caller-owned patch matrix — the plan
/// executor's entry point. `col` must hold `min(ctx.threads, N)·C·S·Q`
/// elements (one patch matrix per worker); with `ctx.threads <= 1` the
/// call performs zero heap allocations.
///
/// This baseline always splits across the batch dimension — its per-image
/// patch-matrix materialisation has no width-block grid to shard
/// (`ctx.partition` is ignored; the BRGEMM kernels are the grid-capable
/// ones, which is itself part of what the baseline comparison shows).
pub fn forward_im2col_with_scratch(
    p: &ConvParams,
    x: &[f32],
    w_kcs: &[f32],
    out: &mut [f32],
    ctx: ExecCtx,
    col: &mut [f32],
) {
    let (n, c, k, s, w, q) = (p.n, p.c, p.k, p.s, p.w, p.q());
    assert_eq!(x.len(), n * c * w, "input shape mismatch for {p}");
    assert_eq!(w_kcs.len(), k * c * s, "weight shape mismatch for {p}");
    assert_eq!(out.len(), n * k * q, "output shape mismatch for {p}");
    let mut no_scratch: [usize; 0] = [];
    par_batch_chunks_scratch(
        out,
        k * q,
        col,
        c * s * q,
        &mut no_scratch[..],
        0,
        ctx.threads,
        |i, out_row, colb, _| {
            forward_im2col_single(p, &x[i * c * w..(i + 1) * c * w], w_kcs, colb, out_row);
        },
    );
}

/// Batched fused-epilogue im2col forward with caller-owned scratch — the
/// plan executor's post-op entry point for the baseline kernel. Batch
/// partitioning only (see [`forward_im2col_with_scratch`]).
#[allow(clippy::too_many_arguments)]
pub fn forward_im2col_post_with_scratch(
    p: &ConvParams,
    x: &[f32],
    w_kcs: &[f32],
    out: &mut [f32],
    ctx: ExecCtx,
    col: &mut [f32],
    ops: &PostOps,
    bias: &[f32],
    residual: Option<&[f32]>,
) {
    let (n, c, k, s, w, q) = (p.n, p.c, p.k, p.s, p.w, p.q());
    assert_eq!(x.len(), n * c * w, "input shape mismatch for {p}");
    assert_eq!(w_kcs.len(), k * c * s, "weight shape mismatch for {p}");
    assert_eq!(out.len(), n * k * q, "output shape mismatch for {p}");
    super::post::validate_args(ops, bias, residual, n, k, q);
    let mut no_scratch: [usize; 0] = [];
    par_batch_chunks_scratch(
        out,
        k * q,
        col,
        c * s * q,
        &mut no_scratch[..],
        0,
        ctx.threads,
        |i, out_row, colb, _| {
            let res_row = residual
                .filter(|_| ops.residual)
                .map(|r| &r[i * k * q..(i + 1) * k * q]);
            forward_im2col_single_post(
                p,
                &x[i * c * w..(i + 1) * c * w],
                w_kcs,
                colb,
                out_row,
                ops,
                bias,
                res_row,
            );
        },
    );
}

/// Batched im2col forward. The patch matrices are hoisted to one
/// allocation per call (one per worker), not one per image.
pub fn forward_im2col(p: &ConvParams, x: &[f32], w_kcs: &[f32], out: &mut [f32], threads: usize) {
    let workers = threads.max(1).min(p.n.max(1));
    let mut col = vec![0.0f32; workers * p.c * p.s * p.q()];
    forward_im2col_with_scratch(p, x, w_kcs, out, ExecCtx::with_threads(threads), &mut col);
}

/// Extra bytes moved by the im2col materialisation relative to BRGEMM —
/// used by the machine model to explain the baseline's efficiency cliff.
pub fn im2col_extra_bytes(p: &ConvParams) -> u64 {
    // Col write + Col read back in the GEMM, per batch element.
    2 * (p.n * p.c * p.s * p.q() * 4) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv1d::direct::forward_direct;
    use crate::conv1d::test_util::rnd;

    #[test]
    fn matches_direct() {
        for &(n, c, k, q, s, d) in &[
            (2, 15, 15, 128, 51, 8),
            (1, 64, 64, 200, 5, 1),
            (1, 3, 2, 100, 9, 4),
            (1, 1, 1, 64, 1, 1),
            (2, 5, 6, 77, 7, 3),
        ] {
            let p = ConvParams::new(n, c, k, q + (s - 1) * d, s, d).unwrap();
            let x = rnd(p.n * p.c * p.w, 1);
            let wt = rnd(p.k * p.c * p.s, 2);
            let mut got = vec![0.0; p.n * p.k * p.q()];
            forward_im2col(&p, &x, &wt, &mut got, 1);
            let mut want = vec![0.0; p.n * p.k * p.q()];
            forward_direct(&p, &x, &wt, &mut want);
            for (g, w_) in got.iter().zip(&want) {
                assert!((g - w_).abs() < 1e-4 * (1.0 + w_.abs()));
            }
        }
    }

    #[test]
    fn col_matrix_layout() {
        let p = ConvParams::new(1, 2, 1, 8, 2, 3).unwrap(); // Q = 5
        let x: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let mut col = vec![0.0; 2 * 2 * 5];
        im2col_single(&p, &x, &mut col);
        // Row (c=0, s=0): x[0..5]; row (c=0, s=1): x[3..8];
        // row (c=1, s=0): x[8..13]; row (c=1, s=1): x[11..16].
        assert_eq!(&col[0..5], &[0.0, 1.0, 2.0, 3.0, 4.0]);
        assert_eq!(&col[5..10], &[3.0, 4.0, 5.0, 6.0, 7.0]);
        assert_eq!(&col[10..15], &[8.0, 9.0, 10.0, 11.0, 12.0]);
        assert_eq!(&col[15..20], &[11.0, 12.0, 13.0, 14.0, 15.0]);
    }

    #[test]
    fn traffic_grows_with_s() {
        let p1 = ConvParams::new(1, 15, 15, 1400, 5, 8).unwrap();
        let p2 = ConvParams::new(1, 15, 15, 1400, 51, 8).unwrap();
        assert!(im2col_extra_bytes(&p2) > 5 * im2col_extra_bytes(&p1));
    }
}
