//! Batch-reduce GEMM (BRGEMM) — paper eq. (3).
//!
//! `C_j = β·C_j + Σ_{i<l_br} A_i · B_i`, where the `A_i`/`B_i` blocks are
//! addressed by *offset lists* into larger tensors (the paper's "arrays of
//! pointers"; offsets are the bounds-checkable Rust equivalent).
//!
//! The decisive property reproduced from LIBXSMM: the output block is kept
//! in a register/stack accumulator across the **whole** batch reduction —
//! one C load + one C store per element regardless of `l_br`. For the
//! convolution kernels `l_br = S`, so a 51-tap filter touches the output
//! exactly once instead of 51 times. This is where the paper's efficiency
//! on large filter widths comes from.
//!
//! The dominant `n = 64` width-block case routes through the explicit
//! SIMD micro-kernels ([`super::simd`]): the process resolves the ISA
//! (scalar / AVX2+FMA / AVX-512F) once into a
//! [`MicroKernelSet`](super::simd::MicroKernelSet) of function pointers,
//! and the `_with` variants below let benches and tests pin a specific
//! set. Remainder blocks (`n < 64`) run the generic scalar loop on every
//! ISA, so all levels stay bit-identical.

use super::bf16::Bf16;
use super::gemm::MAX_N;
use super::simd::{self, MicroKernelSet};

/// f32 BRGEMM through the process-active SIMD micro-kernel set.
///
/// * `a[a_offs[i] + row·lda + col]` is the `A_i` element `(row, col)`;
///   each `A_i` is `m×k`.
/// * `b[b_offs[i] + row·ldb + col]` is the `B_i` element; each `B_i` is `k×n`.
/// * `c[row·ldc + col]` is the output block (`m×n`).
/// * `beta_zero`: if true the output block is overwritten (β = 0),
///   otherwise accumulated into (β = 1). α is fixed at 1 as in the paper's
///   kernels.
#[allow(clippy::too_many_arguments)]
pub fn brgemm_f32(
    a: &[f32],
    a_offs: &[usize],
    lda: usize,
    b: &[f32],
    b_offs: &[usize],
    ldb: usize,
    c: &mut [f32],
    ldc: usize,
    m: usize,
    n: usize,
    k: usize,
    beta_zero: bool,
) {
    brgemm_f32_with(simd::active(), a, a_offs, lda, b, b_offs, ldb, c, ldc, m, n, k, beta_zero);
}

/// [`brgemm_f32`] with an explicit micro-kernel set — the entry point the
/// plan executor and the per-ISA benches/tests use.
#[allow(clippy::too_many_arguments)]
pub fn brgemm_f32_with(
    uks: &MicroKernelSet,
    a: &[f32],
    a_offs: &[usize],
    lda: usize,
    b: &[f32],
    b_offs: &[usize],
    ldb: usize,
    c: &mut [f32],
    ldc: usize,
    m: usize,
    n: usize,
    k: usize,
    beta_zero: bool,
) {
    assert_eq!(
        a_offs.len(),
        b_offs.len(),
        "brgemm_f32: batch length mismatch ({} A offsets vs {} B offsets, m={m} n={n} k={k})",
        a_offs.len(),
        b_offs.len()
    );
    assert!(
        n <= MAX_N,
        "brgemm_f32: n={n} exceeds MAX_N={MAX_N} (m={m}, k={k}, l_br={}) — \
         width blocks must fit the stack accumulator",
        a_offs.len()
    );
    if n == 64 {
        // The dominant case: full width blocks (paper Sec. 3 fixes the
        // block length at 64). The resolved ISA's register-resident row
        // kernels run here; rows are blocked by 4 so each B panel row is
        // loaded once per 4 FMA rows (LIBXSMM-style register blocking).
        let mut im = 0;
        while im + 4 <= m {
            (uks.row4_f32)(a, a_offs, lda, b, b_offs, ldb, im, k, c, ldc, beta_zero);
            im += 4;
        }
        while im < m {
            (uks.row_f32)(
                a,
                a_offs,
                lda,
                b,
                b_offs,
                ldb,
                im,
                k,
                &mut c[im * ldc..im * ldc + 64],
                beta_zero,
            );
            im += 1;
        }
        return;
    }
    // Remainder blocks (n < 64): generic scalar loop, identical on every
    // ISA — keeps the dispatch levels bit-exact on ragged tails.
    for im in 0..m {
        let mut acc = [0.0f32; MAX_N];
        // Batch-reduce: accumulator persists across all l_br blocks.
        for (&ao, &bo) in a_offs.iter().zip(b_offs) {
            let arow = &a[ao + im * lda..ao + im * lda + k];
            for (ik, &av) in arow.iter().enumerate() {
                let brow = &b[bo + ik * ldb..bo + ik * ldb + n];
                for j in 0..n {
                    acc[j] = av.mul_add(brow[j], acc[j]);
                }
            }
        }
        let crow = &mut c[im * ldc..im * ldc + n];
        if beta_zero {
            crow[..n].copy_from_slice(&acc[..n]);
        } else {
            for j in 0..n {
                crow[j] += acc[j];
            }
        }
    }
}

/// bf16 BRGEMM with f32 accumulation (`VDPBF16PS` semantics), f32 output,
/// through the process-active SIMD micro-kernel set.
#[allow(clippy::too_many_arguments)]
pub fn brgemm_bf16(
    a: &[Bf16],
    a_offs: &[usize],
    lda: usize,
    b: &[Bf16],
    b_offs: &[usize],
    ldb: usize,
    c: &mut [f32],
    ldc: usize,
    m: usize,
    n: usize,
    k: usize,
    beta_zero: bool,
) {
    brgemm_bf16_with(simd::active(), a, a_offs, lda, b, b_offs, ldb, c, ldc, m, n, k, beta_zero);
}

/// [`brgemm_bf16`] with an explicit micro-kernel set. The `n = 64` fast
/// path uses the same row-4 register blocking as f32 — this is what
/// brings the bf16 kernels to blocking parity with the f32 ones.
#[allow(clippy::too_many_arguments)]
pub fn brgemm_bf16_with(
    uks: &MicroKernelSet,
    a: &[Bf16],
    a_offs: &[usize],
    lda: usize,
    b: &[Bf16],
    b_offs: &[usize],
    ldb: usize,
    c: &mut [f32],
    ldc: usize,
    m: usize,
    n: usize,
    k: usize,
    beta_zero: bool,
) {
    assert_eq!(
        a_offs.len(),
        b_offs.len(),
        "brgemm_bf16: batch length mismatch ({} A offsets vs {} B offsets, m={m} n={n} k={k})",
        a_offs.len(),
        b_offs.len()
    );
    assert!(
        n <= MAX_N,
        "brgemm_bf16: n={n} exceeds MAX_N={MAX_N} (m={m}, k={k}, l_br={}) — \
         width blocks must fit the stack accumulator",
        a_offs.len()
    );
    if n == 64 {
        let mut im = 0;
        while im + 4 <= m {
            (uks.row4_bf16)(a, a_offs, lda, b, b_offs, ldb, im, k, c, ldc, beta_zero);
            im += 4;
        }
        while im < m {
            (uks.row_bf16)(
                a,
                a_offs,
                lda,
                b,
                b_offs,
                ldb,
                im,
                k,
                &mut c[im * ldc..im * ldc + 64],
                beta_zero,
            );
            im += 1;
        }
        return;
    }
    for im in 0..m {
        let mut acc = [0.0f32; MAX_N];
        for (&ao, &bo) in a_offs.iter().zip(b_offs) {
            let arow = &a[ao + im * lda..ao + im * lda + k];
            for (ik, &av) in arow.iter().enumerate() {
                let av = av.to_f32();
                let brow = &b[bo + ik * ldb..bo + ik * ldb + n];
                for j in 0..n {
                    acc[j] = av.mul_add(brow[j].to_f32(), acc[j]);
                }
            }
        }
        let crow = &mut c[im * ldc..im * ldc + n];
        if beta_zero {
            crow[..n].copy_from_slice(&acc[..n]);
        } else {
            for j in 0..n {
                crow[j] += acc[j];
            }
        }
    }
}

/// int8 BRGEMM with i32 accumulation (VNNI semantics), i32 output,
/// through the process-active SIMD micro-kernel set. Integer arithmetic
/// is exact, so the result is independent of ISA, blocking and
/// accumulation order — the quantized tier's bit-identity contract costs
/// nothing here.
#[allow(clippy::too_many_arguments)]
pub fn brgemm_i8(
    a: &[i8],
    a_offs: &[usize],
    lda: usize,
    b: &[i8],
    b_offs: &[usize],
    ldb: usize,
    c: &mut [i32],
    ldc: usize,
    m: usize,
    n: usize,
    k: usize,
    beta_zero: bool,
) {
    brgemm_i8_with(simd::active(), a, a_offs, lda, b, b_offs, ldb, c, ldc, m, n, k, beta_zero);
}

/// [`brgemm_i8`] with an explicit micro-kernel set.
#[allow(clippy::too_many_arguments)]
pub fn brgemm_i8_with(
    uks: &MicroKernelSet,
    a: &[i8],
    a_offs: &[usize],
    lda: usize,
    b: &[i8],
    b_offs: &[usize],
    ldb: usize,
    c: &mut [i32],
    ldc: usize,
    m: usize,
    n: usize,
    k: usize,
    beta_zero: bool,
) {
    assert_eq!(
        a_offs.len(),
        b_offs.len(),
        "brgemm_i8: batch length mismatch ({} A offsets vs {} B offsets, m={m} n={n} k={k})",
        a_offs.len(),
        b_offs.len()
    );
    assert!(
        n <= MAX_N,
        "brgemm_i8: n={n} exceeds MAX_N={MAX_N} (m={m}, k={k}, l_br={}) — \
         width blocks must fit the stack accumulator",
        a_offs.len()
    );
    if n == 64 {
        let mut im = 0;
        while im + 4 <= m {
            (uks.row4_i8)(a, a_offs, lda, b, b_offs, ldb, im, k, c, ldc, beta_zero);
            im += 4;
        }
        while im < m {
            (uks.row_i8)(
                a,
                a_offs,
                lda,
                b,
                b_offs,
                ldb,
                im,
                k,
                &mut c[im * ldc..im * ldc + 64],
                beta_zero,
            );
            im += 1;
        }
        return;
    }
    // Remainder blocks (n < 64): generic scalar loop on every ISA.
    for im in 0..m {
        let mut acc = [0i32; MAX_N];
        for (&ao, &bo) in a_offs.iter().zip(b_offs) {
            let arow = &a[ao + im * lda..ao + im * lda + k];
            for (ik, &av) in arow.iter().enumerate() {
                let av = av as i32;
                let brow = &b[bo + ik * ldb..bo + ik * ldb + n];
                for j in 0..n {
                    acc[j] += av * brow[j] as i32;
                }
            }
        }
        let crow = &mut c[im * ldc..im * ldc + n];
        if beta_zero {
            crow[..n].copy_from_slice(&acc[..n]);
        } else {
            for j in 0..n {
                crow[j] += acc[j];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv1d::gemm::gemm_f32;

    fn rnd(n: usize, seed: u64) -> Vec<f32> {
        let mut s = seed;
        (0..n)
            .map(|_| {
                s = s.wrapping_add(0x9E3779B97F4A7C15);
                let mut z = s;
                z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
                z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
                (z as f64 / u64::MAX as f64) as f32 - 0.5
            })
            .collect()
    }

    #[test]
    fn equals_sum_of_gemms() {
        // BRGEMM over l_br blocks == serial GEMM accumulation (eq. 3).
        let (m, n, k, lbr) = (7, 48, 11, 5);
        let a = rnd(lbr * m * k, 1);
        let b = rnd(lbr * k * n, 2);
        let a_offs: Vec<usize> = (0..lbr).map(|i| i * m * k).collect();
        let b_offs: Vec<usize> = (0..lbr).map(|i| i * k * n).collect();
        let mut c1 = vec![0.0; m * n];
        brgemm_f32(&a, &a_offs, k, &b, &b_offs, n, &mut c1, n, m, n, k, true);
        let mut c2 = vec![0.0; m * n];
        for i in 0..lbr {
            gemm_f32(&a[a_offs[i]..], k, &b[b_offs[i]..], n, &mut c2, n, m, n, k);
        }
        for (x, y) in c1.iter().zip(&c2) {
            assert!((x - y).abs() < 1e-5 * (1.0 + y.abs()));
        }
    }

    #[test]
    fn n64_fast_path_equals_sum_of_gemms() {
        // The dispatched n = 64 row kernels against the serial-GEMM oracle,
        // with an m that exercises both the row-4 and the tail row kernel.
        let (m, n, k, lbr) = (7, 64, 13, 5);
        let a = rnd(lbr * m * k, 11);
        let b = rnd(lbr * k * n, 12);
        let a_offs: Vec<usize> = (0..lbr).map(|i| i * m * k).collect();
        let b_offs: Vec<usize> = (0..lbr).map(|i| i * k * n).collect();
        let mut c1 = vec![0.0; m * n];
        brgemm_f32(&a, &a_offs, k, &b, &b_offs, n, &mut c1, n, m, n, k, true);
        let mut c2 = vec![0.0; m * n];
        for i in 0..lbr {
            gemm_f32(&a[a_offs[i]..], k, &b[b_offs[i]..], n, &mut c2, n, m, n, k);
        }
        for (x, y) in c1.iter().zip(&c2) {
            assert!((x - y).abs() < 1e-5 * (1.0 + y.abs()));
        }
    }

    #[test]
    fn beta_semantics() {
        let (m, n, k) = (2, 4, 3);
        let a = vec![1.0; m * k];
        let b = vec![2.0; k * n];
        let mut c = vec![100.0; m * n];
        // β = 1: accumulate.
        brgemm_f32(&a, &[0], k, &b, &[0], n, &mut c, n, m, n, k, false);
        assert!(c.iter().all(|&v| v == 106.0));
        // β = 0: overwrite.
        brgemm_f32(&a, &[0], k, &b, &[0], n, &mut c, n, m, n, k, true);
        assert!(c.iter().all(|&v| v == 6.0));
    }

    #[test]
    fn overlapping_b_blocks() {
        // The paper notes B_i blocks may overlap (Fig. 2) — the dilated
        // conv reads overlapping input panels. Offsets 0 and 1 into the
        // same buffer must both be readable.
        let (m, n, k) = (1, 4, 1);
        let a = vec![1.0, 1.0];
        let b = vec![10.0, 20.0, 30.0, 40.0, 50.0];
        let mut c = vec![0.0; n];
        brgemm_f32(&a, &[0, 1], 1, &b, &[0, 1], 5, &mut c, n, m, n, k, true);
        assert_eq!(c, vec![30.0, 50.0, 70.0, 90.0]);
    }

    #[test]
    fn empty_batch_zeroes_or_keeps() {
        let mut c = vec![5.0; 4];
        brgemm_f32(&[], &[], 1, &[], &[], 1, &mut c, 4, 1, 4, 1, false);
        assert_eq!(c, vec![5.0; 4]);
        brgemm_f32(&[], &[], 1, &[], &[], 1, &mut c, 4, 1, 4, 1, true);
        assert_eq!(c, vec![0.0; 4]);
    }

    #[test]
    #[should_panic(expected = "exceeds MAX_N")]
    fn oversized_width_block_panics_with_shape() {
        // The n ≤ MAX_N guard is a real assert in release builds too — a
        // bare slice-index panic deep in the kernel would hide the shape.
        let mut c = vec![0.0; MAX_N + 1];
        brgemm_f32(&[], &[], 1, &[], &[], 1, &mut c, MAX_N + 1, 1, MAX_N + 1, 1, true);
    }

    #[test]
    fn bf16_close_to_f32() {
        use crate::conv1d::bf16::to_bf16;
        let (m, n, k, lbr) = (4, 32, 8, 3);
        let a = rnd(lbr * m * k, 3);
        let b = rnd(lbr * k * n, 4);
        let a_offs: Vec<usize> = (0..lbr).map(|i| i * m * k).collect();
        let b_offs: Vec<usize> = (0..lbr).map(|i| i * k * n).collect();
        let mut cf = vec![0.0; m * n];
        brgemm_f32(&a, &a_offs, k, &b, &b_offs, n, &mut cf, n, m, n, k, true);
        let mut cb = vec![0.0; m * n];
        brgemm_bf16(
            &to_bf16(&a),
            &a_offs,
            k,
            &to_bf16(&b),
            &b_offs,
            n,
            &mut cb,
            n,
            m,
            n,
            k,
            true,
        );
        for (x, y) in cb.iter().zip(&cf) {
            assert!((x - y).abs() < 2e-2 * (1.0 + y.abs()), "{x} vs {y}");
        }
    }

    #[test]
    fn i8_equals_exact_integer_oracle() {
        // i8 BRGEMM (both the n=64 fast path and the generic remainder)
        // against a plain nested-loop i32 oracle — exact equality.
        for (m, n) in [(7usize, 64usize), (5, 48)] {
            let (k, lbr) = (9usize, 4usize);
            let quant = |v: &[f32]| -> Vec<i8> {
                v.iter().map(|&x| (x * 254.0).round() as i8).collect()
            };
            let a = quant(&rnd(lbr * m * k, 31));
            let b = quant(&rnd(lbr * k * n, 32));
            let a_offs: Vec<usize> = (0..lbr).map(|i| i * m * k).collect();
            let b_offs: Vec<usize> = (0..lbr).map(|i| i * k * n).collect();
            let mut c1 = vec![7i32; m * n];
            brgemm_i8(&a, &a_offs, k, &b, &b_offs, n, &mut c1, n, m, n, k, false);
            let mut c2 = vec![7i32; m * n];
            for i in 0..lbr {
                for im in 0..m {
                    for ik in 0..k {
                        let av = a[a_offs[i] + im * k + ik] as i32;
                        for j in 0..n {
                            c2[im * n + j] += av * b[b_offs[i] + ik * n + j] as i32;
                        }
                    }
                }
            }
            assert_eq!(c1, c2, "m={m} n={n}");
        }
    }

    #[test]
    fn bf16_n64_fast_path_matches_generic() {
        // The new bf16 row/row-4 kernels vs the generic loop run at a
        // non-64 ldc... easiest oracle: widen operands to f32 and compare
        // against the f32 fast path (bf16 widening is exact, both
        // accumulate in f32 with the same FMA order → bit-identical).
        use crate::conv1d::bf16::{to_bf16, to_f32};
        let (m, n, k, lbr) = (6, 64, 9, 4);
        let a16 = to_bf16(&rnd(lbr * m * k, 21));
        let b16 = to_bf16(&rnd(lbr * k * n, 22));
        let a_offs: Vec<usize> = (0..lbr).map(|i| i * m * k).collect();
        let b_offs: Vec<usize> = (0..lbr).map(|i| i * k * n).collect();
        let mut c_bf = vec![0.5; m * n];
        brgemm_bf16(&a16, &a_offs, k, &b16, &b_offs, n, &mut c_bf, n, m, n, k, false);
        let mut c_f = vec![0.5; m * n];
        brgemm_f32(
            &to_f32(&a16),
            &a_offs,
            k,
            &to_f32(&b16),
            &b_offs,
            n,
            &mut c_f,
            n,
            m,
            n,
            k,
            false,
        );
        assert_eq!(c_bf, c_f, "bf16 n=64 fast path must match exact-widened f32");
    }
}
