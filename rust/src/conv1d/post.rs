//! Post-op pipeline: the fused epilogue applied inside each kernel's
//! output-block loop (bias add, activation, residual add, output scale).
//!
//! The paper's end-to-end speedup depends on keeping the output block hot:
//! bias and activation are applied while the freshly-computed block still
//! sits in cache, instead of as separate full-tensor sweeps afterwards
//! (Georganas et al. and cuDNN's fused epilogues converge on the same
//! design). A [`PostOps`] spec is attached to a
//! [`crate::conv1d::ConvPlan`] at build time; the kernels call
//! [`apply_segment`] on every output block they produce, so a
//! `bias + relu` forward is **one** pass over the output tensor.
//!
//! Math (cuDNN epilogue order):
//!
//! ```text
//! y = act(scale · conv(x) + bias + residual)
//! ```
//!
//! and the fused backward prologue, derived once here so forward and
//! backward cannot drift apart:
//!
//! ```text
//! dz      = gout ⊙ act'(y)          (activation gradient, from the saved
//!                                    forward *output* — no pre-activation
//!                                    tensor is ever materialised)
//! d bias  = Σ_{n,q} dz              (folded into the same sweep)
//! d resid = dz
//! d conv  = scale · dz              (what backward_data/weight consume)
//! ```

/// Pointwise activation applied by the epilogue.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Activation {
    /// No activation.
    #[default]
    Identity,
    /// `max(0, v)`.
    Relu,
    /// `1 / (1 + e^(−v))`.
    Sigmoid,
}

impl Activation {
    /// Apply the activation to one value.
    #[inline]
    pub fn apply(self, v: f32) -> f32 {
        match self {
            Activation::Identity => v,
            Activation::Relu => {
                if v > 0.0 {
                    v
                } else {
                    0.0
                }
            }
            Activation::Sigmoid => 1.0 / (1.0 + (-v).exp()),
        }
    }

    /// Derivative `act'(z)` expressed through the saved *output*
    /// `y = act(z)` — every supported activation admits this form, so the
    /// fused backward never needs the pre-activation tensor.
    #[inline]
    pub fn grad_from_output(self, y: f32) -> f32 {
        match self {
            Activation::Identity => 1.0,
            Activation::Relu => {
                if y > 0.0 {
                    1.0
                } else {
                    0.0
                }
            }
            Activation::Sigmoid => y * (1.0 - y),
        }
    }

    /// Canonical token used in [`PostOps`] names.
    pub fn as_str(&self) -> &'static str {
        match self {
            Activation::Identity => "identity",
            Activation::Relu => "relu",
            Activation::Sigmoid => "sigmoid",
        }
    }
}

/// A post-op epilogue spec: what the kernel fuses onto each output block.
///
/// Specs round-trip through their canonical string names, which is how
/// configs (`post_ops = "bias_relu"`) and the CLI (`--post-ops`) select
/// them:
///
/// ```
/// use dilconv1d::conv1d::PostOps;
///
/// let ops = PostOps::parse("bias_relu").unwrap();
/// assert!(ops.bias && !ops.residual);
/// assert_eq!(ops.to_string(), "bias_relu");
/// assert_eq!(PostOps::bias_relu(), ops);
/// assert!(PostOps::parse("bias_tanh").is_err()); // unknown token
/// ```
///
/// `PartialEq` (not `Eq`): `scale` is a float.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PostOps {
    /// Add the plan's per-filter bias.
    pub bias: bool,
    /// Pointwise activation applied last.
    pub activation: Activation,
    /// Add a caller-supplied residual tensor (same shape as the output)
    /// before the activation.
    pub residual: bool,
    /// Scale the raw convolution output before bias/residual/activation.
    pub scale: f32,
}

impl Default for PostOps {
    fn default() -> Self {
        PostOps::none()
    }
}

impl PostOps {
    /// The identity epilogue: plain convolution.
    pub const fn none() -> PostOps {
        PostOps {
            bias: false,
            activation: Activation::Identity,
            residual: false,
            scale: 1.0,
        }
    }

    /// Bias add only (the framework-layer default).
    pub const fn bias() -> PostOps {
        PostOps {
            bias: true,
            ..PostOps::none()
        }
    }

    /// `relu(conv + bias)` — the hot configuration of the AtacWorks body.
    pub const fn bias_relu() -> PostOps {
        PostOps {
            bias: true,
            activation: Activation::Relu,
            ..PostOps::none()
        }
    }

    /// `relu(conv + bias + residual)` — the ResNet block tail.
    pub const fn bias_relu_residual() -> PostOps {
        PostOps {
            bias: true,
            activation: Activation::Relu,
            residual: true,
            ..PostOps::none()
        }
    }

    /// True when the epilogue is the identity (no work to fuse).
    pub fn is_none(&self) -> bool {
        !self.bias
            && self.activation == Activation::Identity
            && !self.residual
            && self.scale == 1.0
    }

    /// Builder: replace the activation.
    pub fn with_activation(mut self, a: Activation) -> PostOps {
        self.activation = a;
        self
    }

    /// Builder: replace the output scale.
    pub fn with_scale(mut self, scale: f32) -> PostOps {
        self.scale = scale;
        self
    }

    /// Builder: toggle the residual input.
    pub fn with_residual(mut self, residual: bool) -> PostOps {
        self.residual = residual;
        self
    }

    /// Parse a spec from its config/CLI name: `"none"` or `_`-separated
    /// tokens out of `bias`, `relu`, `sigmoid`, `identity`, `residual`
    /// (e.g. `"bias_relu"`, `"bias_relu_residual"`). `scale` is not
    /// nameable — it exists for programmatic users (e.g. gradient
    /// averaging) and defaults to 1.
    pub fn parse(name: &str) -> Result<PostOps, String> {
        let lower = name.to_ascii_lowercase();
        if lower == "none" {
            return Ok(PostOps::none());
        }
        let mut ops = PostOps::none();
        for tok in lower.split('_') {
            match tok {
                "bias" => ops.bias = true,
                "relu" => ops.activation = Activation::Relu,
                "sigmoid" => ops.activation = Activation::Sigmoid,
                "identity" => ops.activation = Activation::Identity,
                "residual" => ops.residual = true,
                other => return Err(format!("unknown post-op token '{other}' in '{name}'")),
            }
        }
        Ok(ops)
    }

    /// Canonical name (round-trips through [`PostOps::parse`] whenever
    /// `scale == 1`).
    pub fn name(&self) -> String {
        if self.is_none() {
            return "none".to_string();
        }
        let mut parts: Vec<&str> = Vec::new();
        if self.bias {
            parts.push("bias");
        }
        if self.activation != Activation::Identity {
            parts.push(self.activation.as_str());
        }
        if self.residual {
            parts.push("residual");
        }
        let mut s = if parts.is_empty() {
            "identity".to_string()
        } else {
            parts.join("_")
        };
        if self.scale != 1.0 {
            s.push_str(&format!("@scale{}", self.scale));
        }
        s
    }
}

impl std::fmt::Display for PostOps {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.name())
    }
}

/// Apply the epilogue to one contiguous output segment belonging to a
/// single filter: `seg[j] = act(scale·seg[j] + bias_k + res[j])`.
///
/// This is the primitive every kernel calls right after producing an
/// output block, while the block is still cache-hot. `res` must be `Some`
/// iff `ops.residual` is set, and at least `seg.len()` long.
#[inline]
pub fn apply_segment(ops: &PostOps, bias_k: f32, res: Option<&[f32]>, seg: &mut [f32]) {
    let b = if ops.bias { bias_k } else { 0.0 };
    let sc = ops.scale;
    match res {
        Some(r) => {
            debug_assert!(r.len() >= seg.len());
            for (v, rv) in seg.iter_mut().zip(r) {
                *v = ops.activation.apply(sc * *v + b + rv);
            }
        }
        None => {
            debug_assert!(!ops.residual, "residual post-op without residual data");
            for v in seg.iter_mut() {
                *v = ops.activation.apply(sc * *v + b);
            }
        }
    }
}

/// Validate a fused-forward argument set against its spec — the single
/// owner of the bias-length / residual-presence / residual-shape
/// contract every kernel entry point enforces.
pub fn validate_args(
    ops: &PostOps,
    bias: &[f32],
    residual: Option<&[f32]>,
    n: usize,
    k: usize,
    q: usize,
) {
    if ops.bias {
        assert_eq!(bias.len(), k, "post-op bias length mismatch");
    }
    if ops.residual {
        let r = residual.expect("residual post-op requires a residual tensor");
        assert_eq!(r.len(), n * k * q, "post-op residual shape mismatch");
    }
}

/// Apply the epilogue to the width block `pos .. pos+nb` of every filter
/// row of one image's `(K, Q)` output — the call every fused kernel makes
/// right after a block's BRGEMM, while the block is still cache-hot.
/// `res_row` is the image's `(K, Q)` residual row when `ops.residual`.
#[allow(clippy::too_many_arguments)]
#[inline]
pub fn apply_block(
    ops: &PostOps,
    bias: &[f32],
    res_row: Option<&[f32]>,
    out_row: &mut [f32],
    k: usize,
    q: usize,
    pos: usize,
    nb: usize,
) {
    if ops.is_none() {
        return;
    }
    for ik in 0..k {
        let at = ik * q + pos;
        let bias_k = if ops.bias { bias[ik] } else { 0.0 };
        let res = res_row.map(|r| &r[at..at + nb]);
        apply_segment(ops, bias_k, res, &mut out_row[at..at + nb]);
    }
}

/// [`apply_block`] for a *staged* contiguous `(K, nb)` block (`ldc = nb`)
/// whose bias/residual still live in the `(K, Q)` output-row geometry —
/// the grid workers' epilogue: they compute each width block into
/// private staging and store only their own column stripe of the shared
/// output row, so the post-ops run on the staging block before the
/// store. Same per-segment math as [`apply_block`] (both route through
/// [`apply_segment`]), so the two cannot drift.
pub fn apply_block_staged(
    ops: &PostOps,
    bias: &[f32],
    res_row: Option<&[f32]>,
    block: &mut [f32],
    k: usize,
    q: usize,
    pos: usize,
    nb: usize,
) {
    if ops.is_none() {
        return;
    }
    debug_assert!(block.len() >= k * nb);
    for ik in 0..k {
        let bias_k = if ops.bias { bias[ik] } else { 0.0 };
        let res = res_row.map(|r| &r[ik * q + pos..ik * q + pos + nb]);
        apply_segment(ops, bias_k, res, &mut block[ik * nb..(ik + 1) * nb]);
    }
}

/// Unfused reference sweep over a full `(N, K, Q)` output tensor — the
/// fallback for kernels that do not override the fused hook, and the
/// oracle the conformance matrix compares every fused kernel against.
pub fn apply_reference(
    ops: &PostOps,
    bias: &[f32],
    residual: Option<&[f32]>,
    out: &mut [f32],
    n: usize,
    k: usize,
    q: usize,
) {
    if ops.is_none() {
        return;
    }
    assert_eq!(out.len(), n * k * q, "post-op output shape mismatch");
    if ops.bias {
        assert_eq!(bias.len(), k, "post-op bias length mismatch");
    }
    if ops.residual {
        let r = residual.expect("residual post-op requires a residual tensor");
        assert_eq!(r.len(), n * k * q, "post-op residual shape mismatch");
    }
    for ib in 0..n {
        for ik in 0..k {
            let row = (ib * k + ik) * q;
            let bias_k = if ops.bias { bias[ik] } else { 0.0 };
            let res_row = residual.filter(|_| ops.residual).map(|r| &r[row..row + q]);
            apply_segment(ops, bias_k, res_row, &mut out[row..row + q]);
        }
    }
}

/// Fused backward prologue over a full `(N, K, Q)` tensor — **one** sweep
/// that turns the gradient w.r.t. the post-op output into the gradient
/// w.r.t. the raw convolution output, folding the bias gradient (and the
/// residual gradient, when requested) into the same pass:
///
/// * `dconv[i] = scale · gout[i] · act'(y[i])` — written to `dconv`,
/// * `gb[k] += Σ gout·act'` — accumulated when `gb` is `Some`
///   (caller zeroes it first),
/// * `gres[i] = gout[i] · act'(y[i])` — written when `gres` is `Some`.
pub fn backward_prologue(
    ops: &PostOps,
    gout: &[f32],
    y: &[f32],
    dconv: &mut [f32],
    n: usize,
    k: usize,
    q: usize,
    mut gb: Option<&mut [f32]>,
    mut gres: Option<&mut [f32]>,
) {
    assert_eq!(gout.len(), n * k * q, "grad-out shape mismatch");
    assert_eq!(y.len(), n * k * q, "saved-output shape mismatch");
    assert_eq!(dconv.len(), n * k * q, "dconv shape mismatch");
    if let Some(gb) = gb.as_deref() {
        assert_eq!(gb.len(), k, "bias-grad length mismatch");
    }
    if let Some(gr) = gres.as_deref() {
        assert_eq!(gr.len(), n * k * q, "residual-grad shape mismatch");
    }
    let act = ops.activation;
    let sc = ops.scale;
    for ib in 0..n {
        for ik in 0..k {
            let row = (ib * k + ik) * q;
            let mut acc = 0.0f32;
            for j in row..row + q {
                let dz = gout[j] * act.grad_from_output(y[j]);
                acc += dz;
                if let Some(gr) = gres.as_deref_mut() {
                    gr[j] = dz;
                }
                dconv[j] = sc * dz;
            }
            if let Some(gb) = gb.as_deref_mut() {
                gb[ik] += acc;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_name_round_trip() {
        for name in [
            "none",
            "bias",
            "relu",
            "bias_relu",
            "bias_sigmoid",
            "bias_relu_residual",
            "sigmoid",
            "residual",
        ] {
            let ops = PostOps::parse(name).unwrap();
            assert_eq!(ops.name(), name, "{name}");
            assert_eq!(PostOps::parse(&ops.name()).unwrap(), ops);
        }
        assert_eq!(PostOps::parse("BIAS_RELU").unwrap(), PostOps::bias_relu());
        assert!(PostOps::parse("bias_tanh").is_err());
        assert!(PostOps::none().is_none());
        assert!(!PostOps::bias().is_none());
    }

    #[test]
    fn segment_math() {
        let ops = PostOps::bias_relu_residual().with_scale(2.0);
        let mut seg = vec![1.0f32, -3.0];
        let res = vec![0.5f32, 1.0];
        apply_segment(&ops, 0.25, Some(&res), &mut seg);
        // 2·1 + 0.25 + 0.5 = 2.75; 2·(−3) + 0.25 + 1 = −4.75 → relu → 0.
        assert_eq!(seg, vec![2.75, 0.0]);
    }

    #[test]
    fn activation_grad_from_output() {
        for v in [-2.0f32, -0.5, 0.0, 0.5, 2.0] {
            let y = Activation::Sigmoid.apply(v);
            // d/dv sigmoid = sig·(1−sig)
            let want = y * (1.0 - y);
            assert!((Activation::Sigmoid.grad_from_output(y) - want).abs() < 1e-6);
        }
        assert_eq!(Activation::Relu.grad_from_output(3.0), 1.0);
        assert_eq!(Activation::Relu.grad_from_output(0.0), 0.0);
        assert_eq!(Activation::Identity.grad_from_output(-7.0), 1.0);
    }

    #[test]
    fn prologue_folds_bias_and_residual_grads() {
        let (n, k, q) = (1, 2, 3);
        let ops = PostOps::bias_relu_residual().with_scale(0.5);
        let y = vec![1.0f32, 0.0, 2.0, 0.0, 3.0, 1.0]; // relu outputs
        let gout = vec![1.0f32; 6];
        let mut dconv = vec![0.0f32; 6];
        let mut gb = vec![0.0f32; 2];
        let mut gres = vec![0.0f32; 6];
        backward_prologue(
            &ops,
            &gout,
            &y,
            &mut dconv,
            n,
            k,
            q,
            Some(&mut gb),
            Some(&mut gres),
        );
        assert_eq!(gres, vec![1.0, 0.0, 1.0, 0.0, 1.0, 1.0]);
        assert_eq!(gb, vec![2.0, 2.0]);
        assert_eq!(dconv, vec![0.5, 0.0, 0.5, 0.0, 0.5, 0.5]);
    }
}
