//! Small-GEMM micro-kernels — the LIBXSMM-analog substrate.
//!
//! The paper builds everything on LIBXSMM's JIT-generated small GEMMs and
//! BRGEMM. We reproduce the same design in Rust: a strided, accumulate-only
//! (`β = 1`) small-matrix multiply specialised for the kernel shapes the
//! convolution layer produces:
//!
//!   forward       : `m = K`, `n = WB(=64)`, `k = C`  (A = weight tap, row-major)
//!   backward-data : `m = C`, `n = WB`,     `k = K`
//!   backward-weight: `m = C`, `n = K`,     `k = WB`, `Bᵀ` access
//!
//! `n` is the width-block dimension and is contiguous in memory for both
//! `B` and `C`, so the inner loop is a unit-stride FMA chain the compiler
//! auto-vectorises to the host SIMD width (the portable analog of the
//! paper's AVX-512 columns). A row-local accumulator keeps `C` traffic to
//! one load + one store per (m, n) element per call — matching LIBXSMM's
//! register-blocked stores.

use super::bf16::Bf16;
use super::simd;

/// Width-block upper bound used for stack accumulators. Must be ≥ every
/// `n` the convolution kernels produce (WIDTH_BLOCK = 64 plus remainders).
pub const MAX_N: usize = 128;

/// `C[m×n] += A[m×k] · B[k×n]` with row strides `lda/ldb/ldc` (row-major).
///
/// The `n = 64` width-block case (im2col's block GEMM) routes through the
/// process-active SIMD micro-kernel set ([`super::simd::active`]) as a
/// single-block batch reduction; remainders run the portable loop.
///
/// Callers guarantee `a.len() ≥ (m−1)·lda + k`, `b.len() ≥ (k−1)·ldb + n`,
/// `c.len() ≥ (m−1)·ldc + n`; out-of-range indices panic.
#[inline]
pub fn gemm_f32(
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    c: &mut [f32],
    ldc: usize,
    m: usize,
    n: usize,
    k: usize,
) {
    assert!(
        n <= MAX_N,
        "gemm_f32: n={n} exceeds MAX_N={MAX_N} (m={m}, k={k}) — \
         width blocks must fit the stack accumulator"
    );
    debug_assert!(a.len() >= (m.saturating_sub(1)) * lda + k);
    debug_assert!(b.len() >= (k.saturating_sub(1)) * ldb + n);
    debug_assert!(c.len() >= (m.saturating_sub(1)) * ldc + n);
    if n == 64 {
        // One-block batch reduction: same β=1 accumulate semantics, same
        // per-element FMA order, explicit SIMD row kernels.
        let uks = simd::active();
        let mut im = 0;
        while im + 4 <= m {
            (uks.row4_f32)(a, &[0], lda, b, &[0], ldb, im, k, c, ldc, false);
            im += 4;
        }
        while im < m {
            (uks.row_f32)(
                a,
                &[0],
                lda,
                b,
                &[0],
                ldb,
                im,
                k,
                &mut c[im * ldc..im * ldc + 64],
                false,
            );
            im += 1;
        }
        return;
    }
    for im in 0..m {
        let mut acc = [0.0f32; MAX_N];
        let arow = &a[im * lda..im * lda + k];
        // k-dimension FMA chain; j-loop is unit-stride and auto-vectorised.
        for (ik, &av) in arow.iter().enumerate() {
            let brow = &b[ik * ldb..ik * ldb + n];
            for j in 0..n {
                acc[j] = av.mul_add(brow[j], acc[j]);
            }
        }
        let crow = &mut c[im * ldc..im * ldc + n];
        for j in 0..n {
            crow[j] += acc[j];
        }
    }
}

/// `C[m×n] += A[m×k] · B[k×n]ᵀ-free` variant where **B is accessed
/// transposed**: `B` is a `n×k` row-major matrix and we compute
/// `C[i][j] += Σ_l A[i][l] · B[j][l]`.
///
/// This is Algorithm 4's `GEMM(In_panel, transpose(Grad_out_panel))`:
/// both operands are read along their contiguous axis (the width block),
/// so no transpose materialisation is needed — the dot product itself is
/// unit-stride.
#[inline]
pub fn gemm_f32_bt(
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    c: &mut [f32],
    ldc: usize,
    m: usize,
    n: usize,
    k: usize,
) {
    debug_assert!(a.len() >= (m.saturating_sub(1)) * lda + k);
    debug_assert!(b.len() >= (n.saturating_sub(1)) * ldb + k);
    debug_assert!(c.len() >= (m.saturating_sub(1)) * ldc + n);
    // The dot product is computed in 16 independent lanes so the FMA
    // dependency chain is broken and the l-loop vectorises (a single
    // serial `dot = fma(..)` chain is latency-bound at <1 GFLOP/s —
    // measured; see EXPERIMENTS.md §Perf step 3; a further 4-column
    // blocking variant was tried and reverted, §Perf step 4).
    const LANES: usize = 16;
    let chunks = k / LANES;
    for im in 0..m {
        let arow = &a[im * lda..im * lda + k];
        let crow = &mut c[im * ldc..im * ldc + n];
        for j in 0..n {
            let brow = &b[j * ldb..j * ldb + k];
            let mut part = [0.0f32; LANES];
            for ch in 0..chunks {
                let av = &arow[ch * LANES..ch * LANES + LANES];
                let bv = &brow[ch * LANES..ch * LANES + LANES];
                for l in 0..LANES {
                    part[l] = av[l].mul_add(bv[l], part[l]);
                }
            }
            let mut dot = 0.0f32;
            for l in chunks * LANES..k {
                dot = arow[l].mul_add(brow[l], dot);
            }
            for &p in &part {
                dot += p;
            }
            crow[j] += dot;
        }
    }
}

/// bf16 × bf16 → f32-accumulate GEMM (`VDPBF16PS` semantics): operands are
/// widened to f32 per element, products accumulate in f32, `C` stays f32.
#[inline]
pub fn gemm_bf16(
    a: &[Bf16],
    lda: usize,
    b: &[Bf16],
    ldb: usize,
    c: &mut [f32],
    ldc: usize,
    m: usize,
    n: usize,
    k: usize,
) {
    assert!(
        n <= MAX_N,
        "gemm_bf16: n={n} exceeds MAX_N={MAX_N} (m={m}, k={k}) — \
         width blocks must fit the stack accumulator"
    );
    for im in 0..m {
        let mut acc = [0.0f32; MAX_N];
        let arow = &a[im * lda..im * lda + k];
        for (ik, &av) in arow.iter().enumerate() {
            let av = av.to_f32();
            let brow = &b[ik * ldb..ik * ldb + n];
            for j in 0..n {
                acc[j] = av.mul_add(brow[j].to_f32(), acc[j]);
            }
        }
        let crow = &mut c[im * ldc..im * ldc + n];
        for j in 0..n {
            crow[j] += acc[j];
        }
    }
}

/// Reference (naive, obviously-correct) GEMM used only by unit tests.
#[cfg(test)]
pub fn gemm_naive(
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    c: &mut [f32],
    ldc: usize,
    m: usize,
    n: usize,
    k: usize,
) {
    for i in 0..m {
        for j in 0..n {
            let mut s = 0.0;
            for l in 0..k {
                s += a[i * lda + l] * b[l * ldb + j];
            }
            c[i * ldc + j] += s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rnd(n: usize, seed: u64) -> Vec<f32> {
        // splitmix64-based deterministic pseudo-random floats in [-1, 1).
        let mut s = seed;
        (0..n)
            .map(|_| {
                s = s.wrapping_add(0x9E3779B97F4A7C15);
                let mut z = s;
                z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
                z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
                z ^= z >> 31;
                (z as f64 / u64::MAX as f64) as f32 * 2.0 - 1.0
            })
            .collect()
    }

    fn check_close(a: &[f32], b: &[f32], tol: f32) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!(
                (x - y).abs() <= tol * (1.0 + y.abs()),
                "idx {i}: {x} vs {y}"
            );
        }
    }

    #[test]
    fn matches_naive_square() {
        for &(m, n, k) in &[(4, 4, 4), (15, 64, 15), (64, 64, 64), (1, 1, 1), (3, 17, 9)] {
            let a = rnd(m * k, 1);
            let b = rnd(k * n, 2);
            let mut c1 = rnd(m * n, 3);
            let mut c2 = c1.clone();
            gemm_f32(&a, k, &b, n, &mut c1, n, m, n, k);
            gemm_naive(&a, k, &b, n, &mut c2, n, m, n, k);
            check_close(&c1, &c2, 1e-5);
        }
    }

    #[test]
    fn strided_operands() {
        // Embed operands in larger buffers with padding between rows.
        let (m, n, k) = (5, 32, 7);
        let (lda, ldb, ldc) = (k + 3, n + 11, n + 2);
        let a = rnd(m * lda, 4);
        let b = rnd(k * ldb, 5);
        let mut c1 = rnd(m * ldc, 6);
        let mut c2 = c1.clone();
        gemm_f32(&a, lda, &b, ldb, &mut c1, ldc, m, n, k);
        gemm_naive(&a, lda, &b, ldb, &mut c2, ldc, m, n, k);
        check_close(&c1, &c2, 1e-5);
        // Padding columns untouched.
        for i in 0..m {
            for j in n..ldc.min(c1.len() - i * ldc) {
                assert_eq!(c1[i * ldc + j], c2[i * ldc + j]);
            }
        }
    }

    #[test]
    fn bt_variant_matches_explicit_transpose() {
        let (m, n, k) = (6, 9, 33);
        let a = rnd(m * k, 7);
        let bt = rnd(n * k, 8); // n×k row-major == (k×n) transposed
        // Materialise B = btᵀ for the reference.
        let mut b = vec![0.0; k * n];
        for j in 0..n {
            for l in 0..k {
                b[l * n + j] = bt[j * k + l];
            }
        }
        let mut c1 = vec![0.5; m * n];
        let mut c2 = c1.clone();
        gemm_f32_bt(&a, k, &bt, k, &mut c1, n, m, n, k);
        gemm_naive(&a, k, &b, n, &mut c2, n, m, n, k);
        check_close(&c1, &c2, 1e-5);
    }

    #[test]
    #[should_panic(expected = "exceeds MAX_N")]
    fn oversized_n_panics_with_shape_message() {
        // Release builds must fail the shape guard, not a bare
        // slice-index panic later.
        let mut c = vec![0.0; 2 * (MAX_N + 1)];
        let a = vec![0.0; 2];
        let b = vec![0.0; MAX_N + 1];
        gemm_f32(&a, 1, &b, MAX_N + 1, &mut c, MAX_N + 1, 2, MAX_N + 1, 1);
    }

    #[test]
    fn accumulates_rather_than_overwrites() {
        let (m, n, k) = (2, 3, 2);
        let a = vec![1.0; m * k];
        let b = vec![1.0; k * n];
        let mut c = vec![10.0; m * n];
        gemm_f32(&a, k, &b, n, &mut c, n, m, n, k);
        assert!(c.iter().all(|&v| v == 12.0)); // 10 + k*1
    }

    #[test]
    fn bf16_matches_f32_at_bf16_precision() {
        use crate::conv1d::bf16::{quantize, to_bf16};
        let (m, n, k) = (8, 64, 16);
        let af = rnd(m * k, 10);
        let bf = rnd(k * n, 11);
        let a16 = to_bf16(&af);
        let b16 = to_bf16(&bf);
        let mut c_bf = vec![0.0f32; m * n];
        gemm_bf16(&a16, k, &b16, n, &mut c_bf, n, m, n, k);
        // Reference: f32 GEMM over bf16-quantised operands.
        let mut c_ref = vec![0.0f32; m * n];
        gemm_f32(&quantize(&af), k, &quantize(&bf), n, &mut c_ref, n, m, n, k);
        check_close(&c_bf, &c_ref, 1e-6); // identical math, tiny fp-order slack
    }
}
