//! The paper's contribution: an efficient, generic 1D dilated convolution
//! layer built on small-GEMM / batch-reduce-GEMM kernels with width
//! blocking (Chaudhary et al., 2021, Sec. 3).
//!
//! Module map (see rust/DESIGN.md §5):
//! * [`params`]  — problem descriptors, shape math, FLOP counts
//! * [`layout`]  — weight relayouts `(K,C,S) ↔ (S,K,C) ↔ (S,C,K)`
//! * [`gemm`]    — small-GEMM micro-kernels (the LIBXSMM analog)
//! * [`brgemm`]  — batch-reduce GEMM (paper eq. 3)
//! * [`forward`] / [`backward_data`] / [`backward_weight`] — Algorithms 2–4
//! * [`bf16`]    — BFloat16 storage + `VDPBF16PS`-semantics kernels
//! * [`im2col`]  — the library baseline (oneDNN-analog)
//! * [`direct`]  — naive oracle / unoptimised floor
//! * [`quant`]   — int8 symmetric quantization helpers (per-channel weight
//!   scales with all-zero guard, round-and-clamp ±127, staging quantize)
//! * [`post`]    — the fused post-op pipeline (bias/activation/residual/
//!   scale epilogues applied inside each kernel's output-block loop,
//!   DESIGN.md §5b)
//! * [`simd`]    — explicit SIMD BRGEMM micro-kernels (scalar / AVX2+FMA /
//!   AVX-512F) with runtime ISA dispatch resolved once into a
//!   `MicroKernelSet` (`CONV1D_FORCE_ISA` override for testing)
//! * [`plan`]    — `ConvPlan`/`ConvKernel`: the setup-once, run-many
//!   plan/executor API and the string-named backend registry (DESIGN.md §5a)
//! * [`tune`]    — shape-keyed kernel autotuner with a persistent
//!   (`util::json`) tuning table; the cache key is ISA-aware
//! * [`layer`]   — the framework-facing `Conv1dLayer` object (a thin
//!   compatibility wrapper over a cached plan)
//! * [`threading`] — work partitioning: batch-dimension (`Partition::Batch`)
//!   or the 2D `N × ceil(Q/64)` width-block grid (`Partition::Grid`)

pub mod backward_data;
pub mod backward_weight;
pub mod bf16;
pub mod brgemm;
pub mod direct;
pub mod forward;
pub mod gemm;
pub mod im2col;
pub mod layer;
pub mod layout;
pub mod params;
pub mod plan;
pub mod post;
pub mod quant;
pub mod simd;
pub mod threading;
pub mod tune;

pub use layer::{Backend, Conv1dLayer, FusedGrads};
pub use params::{ConvParams, WIDTH_BLOCK};
pub use plan::{
    kernels, lookup_kernel, ConvKernel, ConvPlan, PlanError, PlanOptions, PostOpArgs, Workspace,
};
pub use post::{Activation, PostOps};
pub use simd::{Isa, MicroKernelSet};
pub use threading::{ExecCtx, Partition};
pub use tune::{autotuner, Autotuner, TuneEntry};

/// Deterministic pseudo-random test vectors (splitmix64-derived), shared by
/// unit tests, integration tests and benches.
pub mod test_util {
    /// `n` floats in `[-0.5, 0.5)`, deterministic in `seed`.
    pub fn rnd(n: usize, seed: u64) -> Vec<f32> {
        let mut s = seed;
        (0..n)
            .map(|_| {
                s = s.wrapping_add(0x9E37_79B9_7F4A_7C15);
                let mut z = s;
                z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
                z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
                z ^= z >> 31;
                (z as f64 / u64::MAX as f64) as f32 - 0.5
            })
            .collect()
    }
}
