//! Portable scalar micro-kernels — the fallback level of the dispatch
//! table and the bit-exactness reference for the vector ISAs.
//!
//! These are the original auto-vectorised Rust loops: constant `n = 64`
//! trip counts keep the accumulators in registers across the whole batch
//! reduction, rows are blocked by 4 so each B-panel row is loaded once
//! per four FMA rows. `f32::mul_add` lowers to a fused multiply-add, the
//! same operation the AVX2/AVX-512 kernels issue per lane — which is why
//! every ISA level produces bit-identical outputs.

#![allow(clippy::too_many_arguments)]

use crate::conv1d::bf16::Bf16;

const N64: usize = 64;

/// One-row f32 kernel: `crow[0..64] (=|+)= Σ_i A_i[row, :] · B_i[:, 0..64]`.
pub fn row_n64_f32(
    a: &[f32],
    a_offs: &[usize],
    lda: usize,
    b: &[f32],
    b_offs: &[usize],
    ldb: usize,
    row: usize,
    k: usize,
    crow: &mut [f32],
    beta_zero: bool,
) {
    let mut acc = [0.0f32; N64];
    for (&ao, &bo) in a_offs.iter().zip(b_offs) {
        let arow = &a[ao + row * lda..ao + row * lda + k];
        for (ik, &av) in arow.iter().enumerate() {
            let brow = &b[bo + ik * ldb..bo + ik * ldb + N64];
            for j in 0..N64 {
                acc[j] = av.mul_add(brow[j], acc[j]);
            }
        }
    }
    let crow = &mut crow[..N64];
    if beta_zero {
        crow.copy_from_slice(&acc);
    } else {
        for j in 0..N64 {
            crow[j] += acc[j];
        }
    }
}

/// Four-row register-blocked f32 kernel: one B-panel row load feeds four
/// accumulator rows.
pub fn row4_n64_f32(
    a: &[f32],
    a_offs: &[usize],
    lda: usize,
    b: &[f32],
    b_offs: &[usize],
    ldb: usize,
    row0: usize,
    k: usize,
    c: &mut [f32],
    ldc: usize,
    beta_zero: bool,
) {
    let mut acc0 = [0.0f32; N64];
    let mut acc1 = [0.0f32; N64];
    let mut acc2 = [0.0f32; N64];
    let mut acc3 = [0.0f32; N64];
    for (&ao, &bo) in a_offs.iter().zip(b_offs) {
        let a0 = &a[ao + row0 * lda..ao + row0 * lda + k];
        let a1 = &a[ao + (row0 + 1) * lda..ao + (row0 + 1) * lda + k];
        let a2 = &a[ao + (row0 + 2) * lda..ao + (row0 + 2) * lda + k];
        let a3 = &a[ao + (row0 + 3) * lda..ao + (row0 + 3) * lda + k];
        for ik in 0..k {
            let brow = &b[bo + ik * ldb..bo + ik * ldb + N64];
            let (v0, v1, v2, v3) = (a0[ik], a1[ik], a2[ik], a3[ik]);
            for j in 0..N64 {
                let bj = brow[j];
                acc0[j] = v0.mul_add(bj, acc0[j]);
                acc1[j] = v1.mul_add(bj, acc1[j]);
                acc2[j] = v2.mul_add(bj, acc2[j]);
                acc3[j] = v3.mul_add(bj, acc3[j]);
            }
        }
    }
    for (r, acc) in [acc0, acc1, acc2, acc3].iter().enumerate() {
        let crow = &mut c[(row0 + r) * ldc..(row0 + r) * ldc + N64];
        if beta_zero {
            crow.copy_from_slice(acc);
        } else {
            for j in 0..N64 {
                crow[j] += acc[j];
            }
        }
    }
}

/// One-row bf16 kernel (`VDPBF16PS` semantics): operands widened exactly
/// to f32, fused multiply-add accumulation in f32, f32 output row.
pub fn row_n64_bf16(
    a: &[Bf16],
    a_offs: &[usize],
    lda: usize,
    b: &[Bf16],
    b_offs: &[usize],
    ldb: usize,
    row: usize,
    k: usize,
    crow: &mut [f32],
    beta_zero: bool,
) {
    let mut acc = [0.0f32; N64];
    for (&ao, &bo) in a_offs.iter().zip(b_offs) {
        let arow = &a[ao + row * lda..ao + row * lda + k];
        for (ik, &av) in arow.iter().enumerate() {
            let av = av.to_f32();
            let brow = &b[bo + ik * ldb..bo + ik * ldb + N64];
            for j in 0..N64 {
                acc[j] = av.mul_add(brow[j].to_f32(), acc[j]);
            }
        }
    }
    let crow = &mut crow[..N64];
    if beta_zero {
        crow.copy_from_slice(&acc);
    } else {
        for j in 0..N64 {
            crow[j] += acc[j];
        }
    }
}

/// One-row int8 kernel (VNNI semantics): i8 operands, exact widening
/// multiplies, i32 accumulation, i32 output row. Every product
/// `i8 × i8` and every partial sum is exact in i32 (≤ S·C·K terms of
/// magnitude ≤ 16129 each stay far from overflow for any plannable
/// shape), so accumulation order cannot change the result — the vector
/// ISAs are bit-identical to this loop by arithmetic, not by ordering
/// discipline.
pub fn row_n64_i8(
    a: &[i8],
    a_offs: &[usize],
    lda: usize,
    b: &[i8],
    b_offs: &[usize],
    ldb: usize,
    row: usize,
    k: usize,
    crow: &mut [i32],
    beta_zero: bool,
) {
    let mut acc = [0i32; N64];
    for (&ao, &bo) in a_offs.iter().zip(b_offs) {
        let arow = &a[ao + row * lda..ao + row * lda + k];
        for (ik, &av) in arow.iter().enumerate() {
            let av = av as i32;
            let brow = &b[bo + ik * ldb..bo + ik * ldb + N64];
            for j in 0..N64 {
                acc[j] += av * brow[j] as i32;
            }
        }
    }
    let crow = &mut crow[..N64];
    if beta_zero {
        crow.copy_from_slice(&acc);
    } else {
        for j in 0..N64 {
            crow[j] += acc[j];
        }
    }
}

/// Four-row register-blocked int8 kernel (i32 output).
pub fn row4_n64_i8(
    a: &[i8],
    a_offs: &[usize],
    lda: usize,
    b: &[i8],
    b_offs: &[usize],
    ldb: usize,
    row0: usize,
    k: usize,
    c: &mut [i32],
    ldc: usize,
    beta_zero: bool,
) {
    let mut acc0 = [0i32; N64];
    let mut acc1 = [0i32; N64];
    let mut acc2 = [0i32; N64];
    let mut acc3 = [0i32; N64];
    for (&ao, &bo) in a_offs.iter().zip(b_offs) {
        let a0 = &a[ao + row0 * lda..ao + row0 * lda + k];
        let a1 = &a[ao + (row0 + 1) * lda..ao + (row0 + 1) * lda + k];
        let a2 = &a[ao + (row0 + 2) * lda..ao + (row0 + 2) * lda + k];
        let a3 = &a[ao + (row0 + 3) * lda..ao + (row0 + 3) * lda + k];
        for ik in 0..k {
            let brow = &b[bo + ik * ldb..bo + ik * ldb + N64];
            let (v0, v1, v2, v3) = (
                a0[ik] as i32,
                a1[ik] as i32,
                a2[ik] as i32,
                a3[ik] as i32,
            );
            for j in 0..N64 {
                let bj = brow[j] as i32;
                acc0[j] += v0 * bj;
                acc1[j] += v1 * bj;
                acc2[j] += v2 * bj;
                acc3[j] += v3 * bj;
            }
        }
    }
    for (r, acc) in [acc0, acc1, acc2, acc3].iter().enumerate() {
        let crow = &mut c[(row0 + r) * ldc..(row0 + r) * ldc + N64];
        if beta_zero {
            crow.copy_from_slice(acc);
        } else {
            for j in 0..N64 {
                crow[j] += acc[j];
            }
        }
    }
}

/// Four-row register-blocked bf16 kernel (f32 output) — brings the bf16
/// path's blocking to parity with f32.
pub fn row4_n64_bf16(
    a: &[Bf16],
    a_offs: &[usize],
    lda: usize,
    b: &[Bf16],
    b_offs: &[usize],
    ldb: usize,
    row0: usize,
    k: usize,
    c: &mut [f32],
    ldc: usize,
    beta_zero: bool,
) {
    let mut acc0 = [0.0f32; N64];
    let mut acc1 = [0.0f32; N64];
    let mut acc2 = [0.0f32; N64];
    let mut acc3 = [0.0f32; N64];
    for (&ao, &bo) in a_offs.iter().zip(b_offs) {
        let a0 = &a[ao + row0 * lda..ao + row0 * lda + k];
        let a1 = &a[ao + (row0 + 1) * lda..ao + (row0 + 1) * lda + k];
        let a2 = &a[ao + (row0 + 2) * lda..ao + (row0 + 2) * lda + k];
        let a3 = &a[ao + (row0 + 3) * lda..ao + (row0 + 3) * lda + k];
        for ik in 0..k {
            let brow = &b[bo + ik * ldb..bo + ik * ldb + N64];
            let (v0, v1, v2, v3) = (
                a0[ik].to_f32(),
                a1[ik].to_f32(),
                a2[ik].to_f32(),
                a3[ik].to_f32(),
            );
            for j in 0..N64 {
                let bj = brow[j].to_f32();
                acc0[j] = v0.mul_add(bj, acc0[j]);
                acc1[j] = v1.mul_add(bj, acc1[j]);
                acc2[j] = v2.mul_add(bj, acc2[j]);
                acc3[j] = v3.mul_add(bj, acc3[j]);
            }
        }
    }
    for (r, acc) in [acc0, acc1, acc2, acc3].iter().enumerate() {
        let crow = &mut c[(row0 + r) * ldc..(row0 + r) * ldc + N64];
        if beta_zero {
            crow.copy_from_slice(acc);
        } else {
            for j in 0..N64 {
                crow[j] += acc[j];
            }
        }
    }
}
