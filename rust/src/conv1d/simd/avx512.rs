//! AVX-512F micro-kernels: 16-lane explicit-intrinsic implementations of
//! the `n = 64` BRGEMM row kernels — the closest native analog of the
//! paper's LIBXSMM JIT output on Cascade/Cooper Lake.
//!
//! Compiled only under the `avx512` cargo feature (the `_mm512_*`
//! intrinsics need a recent stable toolchain); runtime-gated behind
//! `is_x86_feature_detected!("avx512f")` like the AVX2 level.
//!
//! Register budget (32 × 512-bit `zmm`): the one-row kernel keeps the
//! 64-column accumulator in 4 `zmm`; the four-row kernel keeps all
//! 4 × 64 accumulators resident (16 `zmm` + 4 B registers + broadcasts —
//! the full LIBXSMM-style register block, no column chunking needed).
//! Per-element FMA order matches the scalar kernels exactly, so outputs
//! are bit-identical across ISAs.

#![allow(clippy::too_many_arguments)]

use std::arch::x86_64::*;

use crate::conv1d::bf16::Bf16;

use super::{Isa, MicroKernelSet};

const N64: usize = 64;

/// The AVX-512F dispatch table entry.
pub static SET: MicroKernelSet = MicroKernelSet {
    isa: Isa::Avx512,
    row_f32,
    row4_f32,
    row_bf16,
    row4_bf16,
    row_i8,
    row4_i8,
};

fn row_f32(
    a: &[f32],
    a_offs: &[usize],
    lda: usize,
    b: &[f32],
    b_offs: &[usize],
    ldb: usize,
    row: usize,
    k: usize,
    crow: &mut [f32],
    beta_zero: bool,
) {
    // SAFETY: this entry is only installed when AVX-512F was detected.
    unsafe { row_f32_impl(a, a_offs, lda, b, b_offs, ldb, row, k, crow, beta_zero) }
}

fn row4_f32(
    a: &[f32],
    a_offs: &[usize],
    lda: usize,
    b: &[f32],
    b_offs: &[usize],
    ldb: usize,
    row0: usize,
    k: usize,
    c: &mut [f32],
    ldc: usize,
    beta_zero: bool,
) {
    // SAFETY: this entry is only installed when AVX-512F was detected.
    unsafe { row4_f32_impl(a, a_offs, lda, b, b_offs, ldb, row0, k, c, ldc, beta_zero) }
}

fn row_bf16(
    a: &[Bf16],
    a_offs: &[usize],
    lda: usize,
    b: &[Bf16],
    b_offs: &[usize],
    ldb: usize,
    row: usize,
    k: usize,
    crow: &mut [f32],
    beta_zero: bool,
) {
    // SAFETY: this entry is only installed when AVX-512F was detected.
    unsafe { row_bf16_impl(a, a_offs, lda, b, b_offs, ldb, row, k, crow, beta_zero) }
}

fn row4_bf16(
    a: &[Bf16],
    a_offs: &[usize],
    lda: usize,
    b: &[Bf16],
    b_offs: &[usize],
    ldb: usize,
    row0: usize,
    k: usize,
    c: &mut [f32],
    ldc: usize,
    beta_zero: bool,
) {
    // SAFETY: this entry is only installed when AVX-512F was detected.
    unsafe { row4_bf16_impl(a, a_offs, lda, b, b_offs, ldb, row0, k, c, ldc, beta_zero) }
}

fn row_i8(
    a: &[i8],
    a_offs: &[usize],
    lda: usize,
    b: &[i8],
    b_offs: &[usize],
    ldb: usize,
    row: usize,
    k: usize,
    crow: &mut [i32],
    beta_zero: bool,
) {
    // SAFETY: this entry is only installed when AVX-512F was detected.
    unsafe { row_i8_impl(a, a_offs, lda, b, b_offs, ldb, row, k, crow, beta_zero) }
}

fn row4_i8(
    a: &[i8],
    a_offs: &[usize],
    lda: usize,
    b: &[i8],
    b_offs: &[usize],
    ldb: usize,
    row0: usize,
    k: usize,
    c: &mut [i32],
    ldc: usize,
    beta_zero: bool,
) {
    // SAFETY: this entry is only installed when AVX-512F was detected.
    unsafe { row4_i8_impl(a, a_offs, lda, b, b_offs, ldb, row0, k, c, ldc, beta_zero) }
}

/// Widen 16 bf16 lanes to f32 (exact `<< 16`, identical to
/// `Bf16::to_f32` per lane). `p` must point at 16 readable `u16`s.
/// `target_feature`: the `__m512` return value must not cross a
/// feature-mismatched ABI boundary (`abi_unsupported_vector_types`);
/// every caller is itself `#[target_feature(enable = "avx512f")]`.
#[target_feature(enable = "avx512f")]
#[inline]
unsafe fn widen16_bf16(p: *const Bf16) -> __m512 {
    unsafe {
        let raw = _mm256_loadu_si256(p as *const __m256i);
        _mm512_castsi512_ps(_mm512_slli_epi32::<16>(_mm512_cvtepu16_epi32(raw)))
    }
}

#[target_feature(enable = "avx512f")]
unsafe fn row_f32_impl(
    a: &[f32],
    a_offs: &[usize],
    lda: usize,
    b: &[f32],
    b_offs: &[usize],
    ldb: usize,
    row: usize,
    k: usize,
    crow: &mut [f32],
    beta_zero: bool,
) {
    unsafe {
        let mut acc = [_mm512_setzero_ps(); 4];
        for (&ao, &bo) in a_offs.iter().zip(b_offs) {
            let arow = &a[ao + row * lda..ao + row * lda + k];
            for (ik, &av) in arow.iter().enumerate() {
                let brow = &b[bo + ik * ldb..bo + ik * ldb + N64];
                let bp = brow.as_ptr();
                let av = _mm512_set1_ps(av);
                for (l, accl) in acc.iter_mut().enumerate() {
                    let bv = _mm512_loadu_ps(bp.add(l * 16));
                    *accl = _mm512_fmadd_ps(av, bv, *accl);
                }
            }
        }
        store_row(&acc, &mut crow[..N64], beta_zero);
    }
}

#[target_feature(enable = "avx512f")]
unsafe fn row_bf16_impl(
    a: &[Bf16],
    a_offs: &[usize],
    lda: usize,
    b: &[Bf16],
    b_offs: &[usize],
    ldb: usize,
    row: usize,
    k: usize,
    crow: &mut [f32],
    beta_zero: bool,
) {
    unsafe {
        let mut acc = [_mm512_setzero_ps(); 4];
        for (&ao, &bo) in a_offs.iter().zip(b_offs) {
            let arow = &a[ao + row * lda..ao + row * lda + k];
            for (ik, &av) in arow.iter().enumerate() {
                let brow = &b[bo + ik * ldb..bo + ik * ldb + N64];
                let bp = brow.as_ptr();
                let av = _mm512_set1_ps(av.to_f32());
                for (l, accl) in acc.iter_mut().enumerate() {
                    let bv = widen16_bf16(bp.add(l * 16));
                    *accl = _mm512_fmadd_ps(av, bv, *accl);
                }
            }
        }
        store_row(&acc, &mut crow[..N64], beta_zero);
    }
}

/// Store a 64-column accumulator into its output row.
#[target_feature(enable = "avx512f")]
unsafe fn store_row(acc: &[__m512; 4], crow: &mut [f32], beta_zero: bool) {
    unsafe {
        let cp = crow.as_mut_ptr();
        for (l, accl) in acc.iter().enumerate() {
            if beta_zero {
                _mm512_storeu_ps(cp.add(l * 16), *accl);
            } else {
                let cv = _mm512_loadu_ps(cp.add(l * 16));
                _mm512_storeu_ps(cp.add(l * 16), _mm512_add_ps(cv, *accl));
            }
        }
    }
}

/// Widen 16 i8 lanes to i32 (exact sign extension, identical to
/// `as i32` per lane). `p` must point at 16 readable `i8`s. Same ABI
/// note as [`widen16_bf16`].
#[target_feature(enable = "avx512f")]
#[inline]
unsafe fn widen16_i8(p: *const i8) -> __m512i {
    unsafe { _mm512_cvtepi8_epi32(_mm_loadu_si128(p as *const __m128i)) }
}

#[target_feature(enable = "avx512f")]
unsafe fn row_i8_impl(
    a: &[i8],
    a_offs: &[usize],
    lda: usize,
    b: &[i8],
    b_offs: &[usize],
    ldb: usize,
    row: usize,
    k: usize,
    crow: &mut [i32],
    beta_zero: bool,
) {
    unsafe {
        // VNNI-shaped blocking (broadcast A, stream 64-column B panels),
        // with exact widened i32 multiply-adds in place of `vpdpbusd` —
        // integer arithmetic is exact, so this is bit-identical to the
        // scalar and AVX2 levels whatever the lane width.
        let mut acc = [_mm512_setzero_si512(); 4];
        for (&ao, &bo) in a_offs.iter().zip(b_offs) {
            let arow = &a[ao + row * lda..ao + row * lda + k];
            for (ik, &av) in arow.iter().enumerate() {
                let brow = &b[bo + ik * ldb..bo + ik * ldb + N64];
                let bp = brow.as_ptr();
                let av = _mm512_set1_epi32(av as i32);
                for (l, accl) in acc.iter_mut().enumerate() {
                    let bv = widen16_i8(bp.add(l * 16));
                    *accl = _mm512_add_epi32(*accl, _mm512_mullo_epi32(av, bv));
                }
            }
        }
        store_row_i32(&acc, &mut crow[..N64], beta_zero);
    }
}

/// Store a 64-column i32 accumulator into its output row.
#[target_feature(enable = "avx512f")]
unsafe fn store_row_i32(acc: &[__m512i; 4], crow: &mut [i32], beta_zero: bool) {
    unsafe {
        let cp = crow.as_mut_ptr();
        for (l, accl) in acc.iter().enumerate() {
            let at = cp.add(l * 16);
            if beta_zero {
                _mm512_storeu_epi32(at, *accl);
            } else {
                let cv = _mm512_loadu_epi32(at as *const i32);
                _mm512_storeu_epi32(at, _mm512_add_epi32(cv, *accl));
            }
        }
    }
}

#[target_feature(enable = "avx512f")]
unsafe fn row4_i8_impl(
    a: &[i8],
    a_offs: &[usize],
    lda: usize,
    b: &[i8],
    b_offs: &[usize],
    ldb: usize,
    row0: usize,
    k: usize,
    c: &mut [i32],
    ldc: usize,
    beta_zero: bool,
) {
    unsafe {
        // Full 4-row × 64-column register block: 16 zmm accumulators.
        let mut acc = [[_mm512_setzero_si512(); 4]; 4];
        for (&ao, &bo) in a_offs.iter().zip(b_offs) {
            let a0 = &a[ao + row0 * lda..ao + row0 * lda + k];
            let a1 = &a[ao + (row0 + 1) * lda..ao + (row0 + 1) * lda + k];
            let a2 = &a[ao + (row0 + 2) * lda..ao + (row0 + 2) * lda + k];
            let a3 = &a[ao + (row0 + 3) * lda..ao + (row0 + 3) * lda + k];
            for ik in 0..k {
                let brow = &b[bo + ik * ldb..bo + ik * ldb + N64];
                let bp = brow.as_ptr();
                let bv = [
                    widen16_i8(bp),
                    widen16_i8(bp.add(16)),
                    widen16_i8(bp.add(32)),
                    widen16_i8(bp.add(48)),
                ];
                for (r, &av) in [a0[ik], a1[ik], a2[ik], a3[ik]].iter().enumerate() {
                    let av = _mm512_set1_epi32(av as i32);
                    for l in 0..4 {
                        acc[r][l] =
                            _mm512_add_epi32(acc[r][l], _mm512_mullo_epi32(av, bv[l]));
                    }
                }
            }
        }
        for (r, accr) in acc.iter().enumerate() {
            store_row_i32(
                accr,
                &mut c[(row0 + r) * ldc..(row0 + r) * ldc + N64],
                beta_zero,
            );
        }
    }
}

#[target_feature(enable = "avx512f")]
unsafe fn row4_f32_impl(
    a: &[f32],
    a_offs: &[usize],
    lda: usize,
    b: &[f32],
    b_offs: &[usize],
    ldb: usize,
    row0: usize,
    k: usize,
    c: &mut [f32],
    ldc: usize,
    beta_zero: bool,
) {
    unsafe {
        // Full 4-row × 64-column register block: 16 zmm accumulators.
        let mut acc = [[_mm512_setzero_ps(); 4]; 4];
        for (&ao, &bo) in a_offs.iter().zip(b_offs) {
            let a0 = &a[ao + row0 * lda..ao + row0 * lda + k];
            let a1 = &a[ao + (row0 + 1) * lda..ao + (row0 + 1) * lda + k];
            let a2 = &a[ao + (row0 + 2) * lda..ao + (row0 + 2) * lda + k];
            let a3 = &a[ao + (row0 + 3) * lda..ao + (row0 + 3) * lda + k];
            for ik in 0..k {
                let brow = &b[bo + ik * ldb..bo + ik * ldb + N64];
                let bp = brow.as_ptr();
                let bv = [
                    _mm512_loadu_ps(bp),
                    _mm512_loadu_ps(bp.add(16)),
                    _mm512_loadu_ps(bp.add(32)),
                    _mm512_loadu_ps(bp.add(48)),
                ];
                for (r, &av) in [a0[ik], a1[ik], a2[ik], a3[ik]].iter().enumerate() {
                    let av = _mm512_set1_ps(av);
                    for l in 0..4 {
                        acc[r][l] = _mm512_fmadd_ps(av, bv[l], acc[r][l]);
                    }
                }
            }
        }
        for (r, accr) in acc.iter().enumerate() {
            store_row(accr, &mut c[(row0 + r) * ldc..(row0 + r) * ldc + N64], beta_zero);
        }
    }
}

#[target_feature(enable = "avx512f")]
unsafe fn row4_bf16_impl(
    a: &[Bf16],
    a_offs: &[usize],
    lda: usize,
    b: &[Bf16],
    b_offs: &[usize],
    ldb: usize,
    row0: usize,
    k: usize,
    c: &mut [f32],
    ldc: usize,
    beta_zero: bool,
) {
    unsafe {
        let mut acc = [[_mm512_setzero_ps(); 4]; 4];
        for (&ao, &bo) in a_offs.iter().zip(b_offs) {
            let a0 = &a[ao + row0 * lda..ao + row0 * lda + k];
            let a1 = &a[ao + (row0 + 1) * lda..ao + (row0 + 1) * lda + k];
            let a2 = &a[ao + (row0 + 2) * lda..ao + (row0 + 2) * lda + k];
            let a3 = &a[ao + (row0 + 3) * lda..ao + (row0 + 3) * lda + k];
            for ik in 0..k {
                let brow = &b[bo + ik * ldb..bo + ik * ldb + N64];
                let bp = brow.as_ptr();
                let bv = [
                    widen16_bf16(bp),
                    widen16_bf16(bp.add(16)),
                    widen16_bf16(bp.add(32)),
                    widen16_bf16(bp.add(48)),
                ];
                let avs = [
                    a0[ik].to_f32(),
                    a1[ik].to_f32(),
                    a2[ik].to_f32(),
                    a3[ik].to_f32(),
                ];
                for (r, &av) in avs.iter().enumerate() {
                    let av = _mm512_set1_ps(av);
                    for l in 0..4 {
                        acc[r][l] = _mm512_fmadd_ps(av, bv[l], acc[r][l]);
                    }
                }
            }
        }
        for (r, accr) in acc.iter().enumerate() {
            store_row(accr, &mut c[(row0 + r) * ldc..(row0 + r) * ldc + N64], beta_zero);
        }
    }
}
