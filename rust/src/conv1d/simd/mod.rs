//! Explicit SIMD BRGEMM micro-kernels with runtime ISA dispatch.
//!
//! The paper's efficiency numbers (up to 80 % of peak on Cascade /
//! Cooper Lake) come from LIBXSMM's JIT-generated AVX-512 register-blocked
//! BRGEMM micro-kernels. This module is the native equivalent: hand-written
//! `std::arch` implementations of the `n = 64` width-block row kernels —
//! the innermost loops every forward / backward-data pass stands on — with
//! the ISA resolved **once at startup** into a [`MicroKernelSet`] of plain
//! function pointers:
//!
//! * [`scalar`] — the portable fallback (the pre-existing auto-vectorised
//!   Rust loops); always available, keeps non-x86 builds green.
//! * `avx2` — 8-lane AVX2+FMA kernels (x86-64, runtime-detected).
//! * `avx512` — 16-lane AVX-512F kernels; compiled only under the
//!   `avx512` cargo feature (the `_mm512_*` intrinsics need a recent
//!   toolchain), runtime-detected like AVX2.
//!
//! Every implementation performs the **same fused multiply-add per output
//! element in the same order** (`acc[j] = fma(a, b[j], acc[j])` over the
//! batch-reduce × k loop nest), so the ISAs are *bit-identical* — locked
//! down by `tests/simd_isa.rs`. Remainder blocks (`n < 64`) always run the
//! generic scalar path, on every ISA.
//!
//! Dispatch order: `CONV1D_FORCE_ISA=scalar|avx2|avx512` (testing
//! override, read once per process) → best runtime-detected ISA →
//! scalar. A forced ISA the host or build cannot serve falls back to the
//! best available one below it, with a warning on stderr — it never
//! silently runs mis-detected vector code.

pub mod scalar;

#[cfg(target_arch = "x86_64")]
pub mod avx2;

#[cfg(all(target_arch = "x86_64", feature = "avx512"))]
pub mod avx512;

use std::sync::OnceLock;

use super::bf16::Bf16;

/// Instruction-set level of a micro-kernel implementation, ordered from
/// most portable to widest vectors.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Isa {
    /// Portable Rust loops (compiler-vectorised); every target.
    Scalar,
    /// AVX2 + FMA, 8 f32 lanes (x86-64).
    Avx2,
    /// AVX-512F, 16 f32 lanes (x86-64, `avx512` cargo feature).
    Avx512,
}

impl Isa {
    /// Every ISA level, in dispatch-preference order (widest last).
    pub const ALL: [Isa; 3] = [Isa::Scalar, Isa::Avx2, Isa::Avx512];

    /// Canonical lowercase name (`scalar` / `avx2` / `avx512`) — the
    /// vocabulary of `CONV1D_FORCE_ISA` and the autotune cache key.
    pub fn name(&self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
            Isa::Avx512 => "avx512",
        }
    }

    /// Parse a `CONV1D_FORCE_ISA` value.
    pub fn parse(s: &str) -> Option<Isa> {
        match s.to_ascii_lowercase().as_str() {
            "scalar" => Some(Isa::Scalar),
            "avx2" => Some(Isa::Avx2),
            "avx512" | "avx512f" => Some(Isa::Avx512),
            _ => None,
        }
    }

    /// Whether this ISA can run on the current host *and* build
    /// (AVX-512 additionally needs the `avx512` cargo feature).
    pub fn available(self) -> bool {
        match self {
            Isa::Scalar => true,
            Isa::Avx2 => avx2_available(),
            Isa::Avx512 => avx512_available(),
        }
    }

    /// The widest ISA the host + build can serve.
    pub fn best_available() -> Isa {
        if Isa::Avx512.available() {
            Isa::Avx512
        } else if Isa::Avx2.available() {
            Isa::Avx2
        } else {
            Isa::Scalar
        }
    }

    /// The next narrower level (fallback order for a forced-but-missing
    /// ISA); `Scalar` is the floor.
    fn next_lower(self) -> Isa {
        match self {
            Isa::Avx512 => Isa::Avx2,
            _ => Isa::Scalar,
        }
    }
}

impl std::fmt::Display for Isa {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(target_arch = "x86_64")]
fn avx2_available() -> bool {
    std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
}

#[cfg(not(target_arch = "x86_64"))]
fn avx2_available() -> bool {
    false
}

#[cfg(all(target_arch = "x86_64", feature = "avx512"))]
fn avx512_available() -> bool {
    std::arch::is_x86_feature_detected!("avx512f")
}

#[cfg(not(all(target_arch = "x86_64", feature = "avx512")))]
fn avx512_available() -> bool {
    false
}

/// One-row `n = 64` f32 BRGEMM kernel: `crow[0..64] (=|+)= Σ_i A_i[row, :] ·
/// B_i[:, 0..64]` over the offset lists. `crow` is exactly the 64-column
/// output row; `beta_zero` selects overwrite vs accumulate.
pub type RowF32 = fn(
    a: &[f32],
    a_offs: &[usize],
    lda: usize,
    b: &[f32],
    b_offs: &[usize],
    ldb: usize,
    row: usize,
    k: usize,
    crow: &mut [f32],
    beta_zero: bool,
);

/// Four-row register-blocked `n = 64` f32 BRGEMM kernel: rows
/// `row0..row0+4` of `c` (row stride `ldc`), one B-panel load feeding
/// four accumulator rows.
pub type Row4F32 = fn(
    a: &[f32],
    a_offs: &[usize],
    lda: usize,
    b: &[f32],
    b_offs: &[usize],
    ldb: usize,
    row0: usize,
    k: usize,
    c: &mut [f32],
    ldc: usize,
    beta_zero: bool,
);

/// One-row `n = 64` bf16 kernel (`VDPBF16PS` semantics): bf16 operands
/// widened exactly, f32 accumulate, f32 output row.
pub type RowBf16 = fn(
    a: &[Bf16],
    a_offs: &[usize],
    lda: usize,
    b: &[Bf16],
    b_offs: &[usize],
    ldb: usize,
    row: usize,
    k: usize,
    crow: &mut [f32],
    beta_zero: bool,
);

/// Four-row register-blocked `n = 64` bf16 kernel (f32 output).
pub type Row4Bf16 = fn(
    a: &[Bf16],
    a_offs: &[usize],
    lda: usize,
    b: &[Bf16],
    b_offs: &[usize],
    ldb: usize,
    row0: usize,
    k: usize,
    c: &mut [f32],
    ldc: usize,
    beta_zero: bool,
);

/// One-row `n = 64` int8 kernel (VNNI semantics): i8 operands, exact
/// widening multiplies, **i32 accumulate**, i32 output row. Integer
/// arithmetic is exact, so every ISA level is bit-identical regardless
/// of lane width — the remaining contract is only that nothing
/// saturates before the i32 accumulator (|i8 × i8| ≤ 16129 fits i16;
/// the vector paths widen to i32 before any add).
pub type RowI8 = fn(
    a: &[i8],
    a_offs: &[usize],
    lda: usize,
    b: &[i8],
    b_offs: &[usize],
    ldb: usize,
    row: usize,
    k: usize,
    crow: &mut [i32],
    beta_zero: bool,
);

/// Four-row register-blocked `n = 64` int8 kernel (i32 output).
pub type Row4I8 = fn(
    a: &[i8],
    a_offs: &[usize],
    lda: usize,
    b: &[i8],
    b_offs: &[usize],
    ldb: usize,
    row0: usize,
    k: usize,
    c: &mut [i32],
    ldc: usize,
    beta_zero: bool,
);

/// The resolved micro-kernel dispatch table: one function pointer per
/// inner kernel, selected once (per process via [`active`], or explicitly
/// via [`MicroKernelSet::for_isa`] for benches and the bit-identity
/// tests). Function pointers rather than trait objects: the call sites
/// are the innermost loops and the table never changes after resolution.
pub struct MicroKernelSet {
    isa: Isa,
    /// f32 one-row n=64 kernel.
    pub row_f32: RowF32,
    /// f32 four-row register-blocked n=64 kernel.
    pub row4_f32: Row4F32,
    /// bf16 one-row n=64 kernel (f32 output).
    pub row_bf16: RowBf16,
    /// bf16 four-row register-blocked n=64 kernel (f32 output).
    pub row4_bf16: Row4Bf16,
    /// int8 one-row n=64 kernel (i32 output).
    pub row_i8: RowI8,
    /// int8 four-row register-blocked n=64 kernel (i32 output).
    pub row4_i8: Row4I8,
}

impl MicroKernelSet {
    /// The ISA these kernels were compiled for.
    pub fn isa(&self) -> Isa {
        self.isa
    }

    /// The kernel set for an ISA, clamped to what the host + build can
    /// serve: requesting an unavailable level returns the best available
    /// one below it (check [`MicroKernelSet::isa`] to see what you got).
    pub fn for_isa(isa: Isa) -> &'static MicroKernelSet {
        let mut level = isa;
        loop {
            if let Some(set) = set_for(level) {
                return set;
            }
            level = level.next_lower();
        }
    }
}

impl std::fmt::Debug for MicroKernelSet {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MicroKernelSet").field("isa", &self.isa).finish()
    }
}

/// The portable fallback set — always constructible.
static SCALAR_SET: MicroKernelSet = MicroKernelSet {
    isa: Isa::Scalar,
    row_f32: scalar::row_n64_f32,
    row4_f32: scalar::row4_n64_f32,
    row_bf16: scalar::row_n64_bf16,
    row4_bf16: scalar::row4_n64_bf16,
    row_i8: scalar::row_n64_i8,
    row4_i8: scalar::row4_n64_i8,
};

/// The table entry for one ISA, `None` when the host or build cannot
/// serve it.
fn set_for(isa: Isa) -> Option<&'static MicroKernelSet> {
    match isa {
        Isa::Scalar => Some(&SCALAR_SET),
        Isa::Avx2 => {
            #[cfg(target_arch = "x86_64")]
            {
                if Isa::Avx2.available() {
                    return Some(&avx2::SET);
                }
            }
            None
        }
        Isa::Avx512 => {
            #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
            {
                if Isa::Avx512.available() {
                    return Some(&avx512::SET);
                }
            }
            None
        }
    }
}

/// The process-wide micro-kernel set: `CONV1D_FORCE_ISA` override if set
/// (with fallback + warning when unavailable), else the best
/// runtime-detected ISA. Resolved exactly once; every later call is a
/// single atomic load.
pub fn active() -> &'static MicroKernelSet {
    static ACTIVE: OnceLock<&'static MicroKernelSet> = OnceLock::new();
    ACTIVE.get_or_init(|| {
        let forced = match std::env::var("CONV1D_FORCE_ISA") {
            Ok(v) => match Isa::parse(&v) {
                Some(isa) => Some(isa),
                None => {
                    eprintln!(
                        "WARN: CONV1D_FORCE_ISA='{v}' is not scalar|avx2|avx512; \
                         using auto-detection"
                    );
                    None
                }
            },
            Err(_) => None,
        };
        match forced {
            Some(isa) => {
                let set = MicroKernelSet::for_isa(isa);
                if set.isa() != isa {
                    eprintln!(
                        "WARN: CONV1D_FORCE_ISA={} is unavailable on this host/build; \
                         falling back to {}",
                        isa.name(),
                        set.isa().name()
                    );
                }
                set
            }
            None => MicroKernelSet::for_isa(Isa::best_available()),
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn isa_names_round_trip() {
        for isa in Isa::ALL {
            assert_eq!(Isa::parse(isa.name()), Some(isa));
            assert_eq!(Isa::parse(&isa.name().to_uppercase()), Some(isa));
        }
        assert_eq!(Isa::parse("avx512f"), Some(Isa::Avx512));
        assert_eq!(Isa::parse("neon"), None);
    }

    #[test]
    fn scalar_is_always_available() {
        assert!(Isa::Scalar.available());
        assert_eq!(MicroKernelSet::for_isa(Isa::Scalar).isa(), Isa::Scalar);
    }

    #[test]
    fn for_isa_clamps_to_available() {
        // Whatever the host, every request resolves to an available set at
        // or below the requested level.
        for isa in Isa::ALL {
            let set = MicroKernelSet::for_isa(isa);
            assert!(set.isa() <= isa);
            assert!(set.isa().available());
        }
    }

    #[test]
    fn active_resolves_once_and_is_available() {
        let a = active();
        assert!(a.isa().available());
        // Pointer-stable across calls.
        assert!(std::ptr::eq(a, active()));
    }

    #[test]
    fn best_available_is_consistent_with_availability() {
        let best = Isa::best_available();
        assert!(best.available());
        for isa in Isa::ALL {
            if isa > best {
                assert!(!isa.available(), "{isa} above best_available()");
            }
        }
    }
}
