//! AVX2 + FMA micro-kernels: 8-lane explicit-intrinsic implementations of
//! the `n = 64` BRGEMM row kernels.
//!
//! Register budget (16 × 256-bit `ymm`):
//! * one-row kernel — the 64-column accumulator lives in 8 `ymm`
//!   registers for the whole batch reduction; B loads stream through one
//!   register, the A value is broadcast.
//! * four-row kernel — 4 rows × 16 columns per column chunk (8 `ymm`
//!   accumulators + 2 B registers + broadcasts); the 64-column block is
//!   covered in four chunks so nothing spills. Chunking columns does not
//!   change the per-element FMA order, so the result stays bit-identical
//!   to the scalar and one-row kernels.
//!
//! Every arithmetic op is the lane-wise twin of the scalar kernel's
//! (`_mm256_fmadd_ps` ↔ `f32::mul_add`, exact `<< 16` widening for bf16),
//! so outputs are bit-identical across ISAs. Slice bounds are checked
//! with safe sub-slicing *before* the pointer loops — out-of-range
//! offsets panic exactly like the scalar kernels instead of reading wild.
//!
//! Safety: the `#[target_feature]` functions are only reachable through
//! [`SET`], which the dispatch table (`super::set_for`) hands out
//! strictly after `is_x86_feature_detected!("avx2")` && `("fma")` both
//! pass.

#![allow(clippy::too_many_arguments)]

use std::arch::x86_64::*;

use crate::conv1d::bf16::Bf16;

use super::{Isa, MicroKernelSet};

const N64: usize = 64;

/// The AVX2+FMA dispatch table entry.
pub static SET: MicroKernelSet = MicroKernelSet {
    isa: Isa::Avx2,
    row_f32,
    row4_f32,
    row_bf16,
    row4_bf16,
    row_i8,
    row4_i8,
};

fn row_f32(
    a: &[f32],
    a_offs: &[usize],
    lda: usize,
    b: &[f32],
    b_offs: &[usize],
    ldb: usize,
    row: usize,
    k: usize,
    crow: &mut [f32],
    beta_zero: bool,
) {
    // SAFETY: this entry is only installed when AVX2+FMA were detected.
    unsafe { row_f32_impl(a, a_offs, lda, b, b_offs, ldb, row, k, crow, beta_zero) }
}

fn row4_f32(
    a: &[f32],
    a_offs: &[usize],
    lda: usize,
    b: &[f32],
    b_offs: &[usize],
    ldb: usize,
    row0: usize,
    k: usize,
    c: &mut [f32],
    ldc: usize,
    beta_zero: bool,
) {
    // SAFETY: this entry is only installed when AVX2+FMA were detected.
    unsafe { row4_f32_impl(a, a_offs, lda, b, b_offs, ldb, row0, k, c, ldc, beta_zero) }
}

fn row_bf16(
    a: &[Bf16],
    a_offs: &[usize],
    lda: usize,
    b: &[Bf16],
    b_offs: &[usize],
    ldb: usize,
    row: usize,
    k: usize,
    crow: &mut [f32],
    beta_zero: bool,
) {
    // SAFETY: this entry is only installed when AVX2+FMA were detected.
    unsafe { row_bf16_impl(a, a_offs, lda, b, b_offs, ldb, row, k, crow, beta_zero) }
}

fn row4_bf16(
    a: &[Bf16],
    a_offs: &[usize],
    lda: usize,
    b: &[Bf16],
    b_offs: &[usize],
    ldb: usize,
    row0: usize,
    k: usize,
    c: &mut [f32],
    ldc: usize,
    beta_zero: bool,
) {
    // SAFETY: this entry is only installed when AVX2+FMA were detected.
    unsafe { row4_bf16_impl(a, a_offs, lda, b, b_offs, ldb, row0, k, c, ldc, beta_zero) }
}

fn row_i8(
    a: &[i8],
    a_offs: &[usize],
    lda: usize,
    b: &[i8],
    b_offs: &[usize],
    ldb: usize,
    row: usize,
    k: usize,
    crow: &mut [i32],
    beta_zero: bool,
) {
    // SAFETY: this entry is only installed when AVX2+FMA were detected.
    unsafe { row_i8_impl(a, a_offs, lda, b, b_offs, ldb, row, k, crow, beta_zero) }
}

fn row4_i8(
    a: &[i8],
    a_offs: &[usize],
    lda: usize,
    b: &[i8],
    b_offs: &[usize],
    ldb: usize,
    row0: usize,
    k: usize,
    c: &mut [i32],
    ldc: usize,
    beta_zero: bool,
) {
    // SAFETY: this entry is only installed when AVX2+FMA were detected.
    unsafe { row4_i8_impl(a, a_offs, lda, b, b_offs, ldb, row0, k, c, ldc, beta_zero) }
}

/// Widen 8 bf16 lanes to f32 (exact: bits `<< 16`, the inverse of bf16
/// truncation — identical to `Bf16::to_f32` per lane). `p` must point at
/// 8 readable `u16`s; `Bf16` is `repr(transparent)` over `u16`.
/// `target_feature`: the `__m256` return value must not cross a
/// feature-mismatched ABI boundary (`abi_unsupported_vector_types`);
/// every caller is itself `#[target_feature(enable = "avx2,fma")]`.
#[target_feature(enable = "avx2")]
#[inline]
unsafe fn widen8_bf16(p: *const Bf16) -> __m256 {
    unsafe {
        let raw = _mm_loadu_si128(p as *const __m128i);
        _mm256_castsi256_ps(_mm256_slli_epi32::<16>(_mm256_cvtepu16_epi32(raw)))
    }
}

#[target_feature(enable = "avx2,fma")]
unsafe fn row_f32_impl(
    a: &[f32],
    a_offs: &[usize],
    lda: usize,
    b: &[f32],
    b_offs: &[usize],
    ldb: usize,
    row: usize,
    k: usize,
    crow: &mut [f32],
    beta_zero: bool,
) {
    unsafe {
        let mut acc = [_mm256_setzero_ps(); 8];
        for (&ao, &bo) in a_offs.iter().zip(b_offs) {
            let arow = &a[ao + row * lda..ao + row * lda + k];
            for (ik, &av) in arow.iter().enumerate() {
                let brow = &b[bo + ik * ldb..bo + ik * ldb + N64];
                let bp = brow.as_ptr();
                let av = _mm256_set1_ps(av);
                for (l, accl) in acc.iter_mut().enumerate() {
                    let bv = _mm256_loadu_ps(bp.add(l * 8));
                    *accl = _mm256_fmadd_ps(av, bv, *accl);
                }
            }
        }
        store_row(&acc, &mut crow[..N64], beta_zero);
    }
}

#[target_feature(enable = "avx2,fma")]
unsafe fn row_bf16_impl(
    a: &[Bf16],
    a_offs: &[usize],
    lda: usize,
    b: &[Bf16],
    b_offs: &[usize],
    ldb: usize,
    row: usize,
    k: usize,
    crow: &mut [f32],
    beta_zero: bool,
) {
    unsafe {
        let mut acc = [_mm256_setzero_ps(); 8];
        for (&ao, &bo) in a_offs.iter().zip(b_offs) {
            let arow = &a[ao + row * lda..ao + row * lda + k];
            for (ik, &av) in arow.iter().enumerate() {
                let brow = &b[bo + ik * ldb..bo + ik * ldb + N64];
                let bp = brow.as_ptr();
                let av = _mm256_set1_ps(av.to_f32());
                for (l, accl) in acc.iter_mut().enumerate() {
                    let bv = widen8_bf16(bp.add(l * 8));
                    *accl = _mm256_fmadd_ps(av, bv, *accl);
                }
            }
        }
        store_row(&acc, &mut crow[..N64], beta_zero);
    }
}

/// Store a 64-column accumulator into its output row (overwrite or
/// lane-wise add, matching the scalar kernels' `+=`).
#[target_feature(enable = "avx2,fma")]
unsafe fn store_row(acc: &[__m256; 8], crow: &mut [f32], beta_zero: bool) {
    unsafe {
        let cp = crow.as_mut_ptr();
        for (l, accl) in acc.iter().enumerate() {
            if beta_zero {
                _mm256_storeu_ps(cp.add(l * 8), *accl);
            } else {
                let cv = _mm256_loadu_ps(cp.add(l * 8));
                _mm256_storeu_ps(cp.add(l * 8), _mm256_add_ps(cv, *accl));
            }
        }
    }
}

#[target_feature(enable = "avx2,fma")]
unsafe fn row4_f32_impl(
    a: &[f32],
    a_offs: &[usize],
    lda: usize,
    b: &[f32],
    b_offs: &[usize],
    ldb: usize,
    row0: usize,
    k: usize,
    c: &mut [f32],
    ldc: usize,
    beta_zero: bool,
) {
    unsafe {
        // 4 rows × 16 columns per chunk: 8 ymm accumulators, no spill.
        for chunk in 0..4usize {
            let col = chunk * 16;
            let mut acc = [_mm256_setzero_ps(); 8]; // [row*2 + half]
            for (&ao, &bo) in a_offs.iter().zip(b_offs) {
                let a0 = &a[ao + row0 * lda..ao + row0 * lda + k];
                let a1 = &a[ao + (row0 + 1) * lda..ao + (row0 + 1) * lda + k];
                let a2 = &a[ao + (row0 + 2) * lda..ao + (row0 + 2) * lda + k];
                let a3 = &a[ao + (row0 + 3) * lda..ao + (row0 + 3) * lda + k];
                for ik in 0..k {
                    let base = bo + ik * ldb + col;
                    let bp = b[base..base + 16].as_ptr();
                    let b0 = _mm256_loadu_ps(bp);
                    let b1 = _mm256_loadu_ps(bp.add(8));
                    let v0 = _mm256_set1_ps(a0[ik]);
                    acc[0] = _mm256_fmadd_ps(v0, b0, acc[0]);
                    acc[1] = _mm256_fmadd_ps(v0, b1, acc[1]);
                    let v1 = _mm256_set1_ps(a1[ik]);
                    acc[2] = _mm256_fmadd_ps(v1, b0, acc[2]);
                    acc[3] = _mm256_fmadd_ps(v1, b1, acc[3]);
                    let v2 = _mm256_set1_ps(a2[ik]);
                    acc[4] = _mm256_fmadd_ps(v2, b0, acc[4]);
                    acc[5] = _mm256_fmadd_ps(v2, b1, acc[5]);
                    let v3 = _mm256_set1_ps(a3[ik]);
                    acc[6] = _mm256_fmadd_ps(v3, b0, acc[6]);
                    acc[7] = _mm256_fmadd_ps(v3, b1, acc[7]);
                }
            }
            store_chunk4(&acc, c, ldc, row0, col, beta_zero);
        }
    }
}

#[target_feature(enable = "avx2,fma")]
unsafe fn row4_bf16_impl(
    a: &[Bf16],
    a_offs: &[usize],
    lda: usize,
    b: &[Bf16],
    b_offs: &[usize],
    ldb: usize,
    row0: usize,
    k: usize,
    c: &mut [f32],
    ldc: usize,
    beta_zero: bool,
) {
    unsafe {
        for chunk in 0..4usize {
            let col = chunk * 16;
            let mut acc = [_mm256_setzero_ps(); 8];
            for (&ao, &bo) in a_offs.iter().zip(b_offs) {
                let a0 = &a[ao + row0 * lda..ao + row0 * lda + k];
                let a1 = &a[ao + (row0 + 1) * lda..ao + (row0 + 1) * lda + k];
                let a2 = &a[ao + (row0 + 2) * lda..ao + (row0 + 2) * lda + k];
                let a3 = &a[ao + (row0 + 3) * lda..ao + (row0 + 3) * lda + k];
                for ik in 0..k {
                    let base = bo + ik * ldb + col;
                    let bp = b[base..base + 16].as_ptr();
                    let b0 = widen8_bf16(bp);
                    let b1 = widen8_bf16(bp.add(8));
                    let v0 = _mm256_set1_ps(a0[ik].to_f32());
                    acc[0] = _mm256_fmadd_ps(v0, b0, acc[0]);
                    acc[1] = _mm256_fmadd_ps(v0, b1, acc[1]);
                    let v1 = _mm256_set1_ps(a1[ik].to_f32());
                    acc[2] = _mm256_fmadd_ps(v1, b0, acc[2]);
                    acc[3] = _mm256_fmadd_ps(v1, b1, acc[3]);
                    let v2 = _mm256_set1_ps(a2[ik].to_f32());
                    acc[4] = _mm256_fmadd_ps(v2, b0, acc[4]);
                    acc[5] = _mm256_fmadd_ps(v2, b1, acc[5]);
                    let v3 = _mm256_set1_ps(a3[ik].to_f32());
                    acc[6] = _mm256_fmadd_ps(v3, b0, acc[6]);
                    acc[7] = _mm256_fmadd_ps(v3, b1, acc[7]);
                }
            }
            store_chunk4(&acc, c, ldc, row0, col, beta_zero);
        }
    }
}

/// Widen 8 i8 lanes to i32 (exact sign extension, identical to `as i32`
/// per lane). `p` must point at 8 readable `i8`s. Same ABI note as
/// [`widen8_bf16`]: every caller is `#[target_feature(enable =
/// "avx2,fma")]`.
#[target_feature(enable = "avx2")]
#[inline]
unsafe fn widen8_i8(p: *const i8) -> __m256i {
    unsafe { _mm256_cvtepi8_epi32(_mm_loadl_epi64(p as *const __m128i)) }
}

#[target_feature(enable = "avx2,fma")]
unsafe fn row_i8_impl(
    a: &[i8],
    a_offs: &[usize],
    lda: usize,
    b: &[i8],
    b_offs: &[usize],
    ldb: usize,
    row: usize,
    k: usize,
    crow: &mut [i32],
    beta_zero: bool,
) {
    unsafe {
        // The `maddubs`-shaped blocking (broadcast A, stream B panels),
        // but with exact sign-extended i32 multiplies instead of the
        // u8×s8 i16-saturating `_mm256_maddubs_epi16` pair — i32
        // arithmetic is exact, which is what makes every ISA level
        // bit-identical by construction.
        let mut acc = [_mm256_setzero_si256(); 8];
        for (&ao, &bo) in a_offs.iter().zip(b_offs) {
            let arow = &a[ao + row * lda..ao + row * lda + k];
            for (ik, &av) in arow.iter().enumerate() {
                let brow = &b[bo + ik * ldb..bo + ik * ldb + N64];
                let bp = brow.as_ptr();
                let av = _mm256_set1_epi32(av as i32);
                for (l, accl) in acc.iter_mut().enumerate() {
                    let bv = widen8_i8(bp.add(l * 8));
                    *accl = _mm256_add_epi32(*accl, _mm256_mullo_epi32(av, bv));
                }
            }
        }
        store_row_i32(&acc, &mut crow[..N64], beta_zero);
    }
}

/// Store a 64-column i32 accumulator into its output row (overwrite or
/// lane-wise add — exact either way).
#[target_feature(enable = "avx2,fma")]
unsafe fn store_row_i32(acc: &[__m256i; 8], crow: &mut [i32], beta_zero: bool) {
    unsafe {
        let cp = crow.as_mut_ptr();
        for (l, accl) in acc.iter().enumerate() {
            let at = cp.add(l * 8) as *mut __m256i;
            if beta_zero {
                _mm256_storeu_si256(at, *accl);
            } else {
                let cv = _mm256_loadu_si256(at as *const __m256i);
                _mm256_storeu_si256(at, _mm256_add_epi32(cv, *accl));
            }
        }
    }
}

#[target_feature(enable = "avx2,fma")]
unsafe fn row4_i8_impl(
    a: &[i8],
    a_offs: &[usize],
    lda: usize,
    b: &[i8],
    b_offs: &[usize],
    ldb: usize,
    row0: usize,
    k: usize,
    c: &mut [i32],
    ldc: usize,
    beta_zero: bool,
) {
    unsafe {
        for chunk in 0..4usize {
            let col = chunk * 16;
            let mut acc = [_mm256_setzero_si256(); 8]; // [row*2 + half]
            for (&ao, &bo) in a_offs.iter().zip(b_offs) {
                let a0 = &a[ao + row0 * lda..ao + row0 * lda + k];
                let a1 = &a[ao + (row0 + 1) * lda..ao + (row0 + 1) * lda + k];
                let a2 = &a[ao + (row0 + 2) * lda..ao + (row0 + 2) * lda + k];
                let a3 = &a[ao + (row0 + 3) * lda..ao + (row0 + 3) * lda + k];
                for ik in 0..k {
                    let base = bo + ik * ldb + col;
                    let bp = b[base..base + 16].as_ptr();
                    let b0 = widen8_i8(bp);
                    let b1 = widen8_i8(bp.add(8));
                    let v0 = _mm256_set1_epi32(a0[ik] as i32);
                    acc[0] = _mm256_add_epi32(acc[0], _mm256_mullo_epi32(v0, b0));
                    acc[1] = _mm256_add_epi32(acc[1], _mm256_mullo_epi32(v0, b1));
                    let v1 = _mm256_set1_epi32(a1[ik] as i32);
                    acc[2] = _mm256_add_epi32(acc[2], _mm256_mullo_epi32(v1, b0));
                    acc[3] = _mm256_add_epi32(acc[3], _mm256_mullo_epi32(v1, b1));
                    let v2 = _mm256_set1_epi32(a2[ik] as i32);
                    acc[4] = _mm256_add_epi32(acc[4], _mm256_mullo_epi32(v2, b0));
                    acc[5] = _mm256_add_epi32(acc[5], _mm256_mullo_epi32(v2, b1));
                    let v3 = _mm256_set1_epi32(a3[ik] as i32);
                    acc[6] = _mm256_add_epi32(acc[6], _mm256_mullo_epi32(v3, b0));
                    acc[7] = _mm256_add_epi32(acc[7], _mm256_mullo_epi32(v3, b1));
                }
            }
            store_chunk4_i32(&acc, c, ldc, row0, col, beta_zero);
        }
    }
}

/// Store one 4-row × 16-column i32 accumulator chunk at column `col`.
#[target_feature(enable = "avx2,fma")]
unsafe fn store_chunk4_i32(
    acc: &[__m256i; 8],
    c: &mut [i32],
    ldc: usize,
    row0: usize,
    col: usize,
    beta_zero: bool,
) {
    unsafe {
        for r in 0..4usize {
            let at = (row0 + r) * ldc + col;
            let cp = c[at..at + 16].as_mut_ptr();
            for half in 0..2usize {
                let dst = cp.add(half * 8) as *mut __m256i;
                let v = acc[r * 2 + half];
                if beta_zero {
                    _mm256_storeu_si256(dst, v);
                } else {
                    let cv = _mm256_loadu_si256(dst as *const __m256i);
                    _mm256_storeu_si256(dst, _mm256_add_epi32(cv, v));
                }
            }
        }
    }
}

/// Store one 4-row × 16-column accumulator chunk at column offset `col`.
#[target_feature(enable = "avx2,fma")]
unsafe fn store_chunk4(
    acc: &[__m256; 8],
    c: &mut [f32],
    ldc: usize,
    row0: usize,
    col: usize,
    beta_zero: bool,
) {
    unsafe {
        for r in 0..4usize {
            let at = (row0 + r) * ldc + col;
            let cp = c[at..at + 16].as_mut_ptr();
            for half in 0..2usize {
                let v = acc[r * 2 + half];
                if beta_zero {
                    _mm256_storeu_ps(cp.add(half * 8), v);
                } else {
                    let cv = _mm256_loadu_ps(cp.add(half * 8));
                    _mm256_storeu_ps(cp.add(half * 8), _mm256_add_ps(cv, v));
                }
            }
        }
    }
}
