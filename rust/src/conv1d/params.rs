//! Convolution problem descriptors and shape math (paper Sec. 2).
//!
//! Conventions follow the paper exactly:
//!   input  `In`     : (N, C, W)  — batch, channels, width (**pre-padded**)
//!   weight `Weight` : (K, C, S)  — filters, channels, filter width
//!   output `Out`    : (N, K, Q)  with `Q = W - (S-1)·d` (valid convolution)
//!
//! `same`-padding helpers compute the zero pad that makes `Q == W_unpadded`,
//! which is how the AtacWorks workload drives the layer (50 000-wide
//! segments padded to 60 000, paper Sec. 4.2).

/// Width-block length used by every kernel. The paper (Sec. 3) keeps the
/// block equal to 64 elements so that one GEMM dimension stays inside
/// LIBXSMM's cache-friendly problem-size bound `(m·n·k)^(1/3) ≤ 64`.
pub const WIDTH_BLOCK: usize = 64;

/// A fully-specified 1D dilated convolution problem.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvParams {
    /// Batch size `N`.
    pub n: usize,
    /// Input channels `C`.
    pub c: usize,
    /// Number of filters (output channels) `K`.
    pub k: usize,
    /// Padded input width `W`.
    pub w: usize,
    /// Filter width `S`.
    pub s: usize,
    /// Dilation `d` (standard convolution is `d = 1`).
    pub d: usize,
    /// Output stride (subsampling; the paper's layer is stride 1). The
    /// kernels compute at stride 1; stride > 1 is served generically by
    /// the plan executor, which subsamples inside the post-op epilogue.
    pub stride: usize,
}

impl ConvParams {
    /// Construct and validate a problem descriptor (stride 1).
    ///
    /// Returns `None` if any dimension is zero or the input is too narrow
    /// to produce at least one output column.
    pub fn new(n: usize, c: usize, k: usize, w: usize, s: usize, d: usize) -> Option<Self> {
        let p = ConvParams {
            n,
            c,
            k,
            w,
            s,
            d,
            stride: 1,
        };
        if n == 0 || c == 0 || k == 0 || w == 0 || s == 0 || d == 0 {
            return None;
        }
        if (s - 1) * d >= w {
            return None;
        }
        Some(p)
    }

    /// The same problem at a different output stride. Returns `None` for a
    /// zero stride.
    pub fn with_stride(self, stride: usize) -> Option<Self> {
        if stride == 0 {
            return None;
        }
        Some(ConvParams { stride, ..self })
    }

    /// The stride-1 twin of this problem — the geometry the kernels
    /// actually compute; the plan subsamples its output for `stride > 1`.
    #[inline]
    pub fn unit_stride(&self) -> Self {
        ConvParams { stride: 1, ..*self }
    }

    /// Output width `Q = ⌊(W − (S−1)·d − 1) / stride⌋ + 1` (paper eq. 2 at
    /// stride 1, where it reduces to `W − (S−1)·d`).
    #[inline]
    pub fn q(&self) -> usize {
        (self.w - (self.s - 1) * self.d - 1) / self.stride + 1
    }

    /// Receptive-field span of the dilated filter: `(S−1)·d + 1` input
    /// columns contribute to each output column.
    #[inline]
    pub fn span(&self) -> usize {
        (self.s - 1) * self.d + 1
    }

    /// FLOPs of one forward pass: `2·N·C·K·Q·S` (MACs × 2), the
    /// denominator of the paper's efficiency plots.
    #[inline]
    pub fn flops(&self) -> u64 {
        2 * self.n as u64 * self.c as u64 * self.k as u64 * self.q() as u64 * self.s as u64
    }

    /// `(left, right)` zero padding so that `Q == W` for an *unpadded*
    /// input of width `w_unpadded`.
    pub fn same_pad(s: usize, d: usize) -> (usize, usize) {
        let total = (s - 1) * d;
        (total / 2, total - total / 2)
    }

    /// Descriptor for the problem after `same`-padding an unpadded width.
    pub fn with_same_padding(
        n: usize,
        c: usize,
        k: usize,
        w_unpadded: usize,
        s: usize,
        d: usize,
    ) -> Option<Self> {
        let (l, r) = Self::same_pad(s, d);
        Self::new(n, c, k, w_unpadded + l + r, s, d)
    }

    /// Number of width blocks in the forward pass (`ceil(Q / 64)`).
    #[inline]
    pub fn q_blocks(&self) -> usize {
        self.q().div_ceil(WIDTH_BLOCK)
    }

    /// The paper's LIBXSMM problem-size heuristic: the per-block GEMM is
    /// cache-optimal whenever `sqrt(C·K) ≤ 64` (Sec. 3.1).
    #[inline]
    pub fn cache_optimal(&self) -> bool {
        self.c * self.k <= 64 * 64
    }

    /// Paper eq. (4): the parameter region where the BRGEMM layer is
    /// expected to beat the library baseline.
    #[inline]
    pub fn favours_brgemm(&self) -> bool {
        self.s >= 5 && self.q() >= 1000
    }

    /// Byte size of the input tensor (f32).
    pub fn input_bytes(&self) -> usize {
        self.n * self.c * self.w * 4
    }

    /// Byte size of the weight tensor (f32).
    pub fn weight_bytes(&self) -> usize {
        self.k * self.c * self.s * 4
    }

    /// Byte size of the output tensor (f32).
    pub fn output_bytes(&self) -> usize {
        self.n * self.k * self.q() * 4
    }
}

impl std::fmt::Display for ConvParams {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "N{}·C{}·K{}·W{}·S{}·d{}",
            self.n, self.c, self.k, self.w, self.s, self.d,
        )?;
        if self.stride != 1 {
            write!(f, "·st{}", self.stride)?;
        }
        write!(f, " (Q={})", self.q())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn q_matches_paper_examples() {
        // Fig. 1: C=5, W=17, K=4, S=3, d=3 -> Q = 17 - 2*3 = 11 on the
        // valid region (the paper pads to keep Q = 17; our same_pad does).
        let p = ConvParams::new(1, 5, 4, 17, 3, 3).unwrap();
        assert_eq!(p.q(), 11);
        let (l, r) = ConvParams::same_pad(3, 3);
        assert_eq!(l + r, 6);
        let padded = ConvParams::with_same_padding(1, 5, 4, 17, 3, 3).unwrap();
        assert_eq!(padded.q(), 17);
    }

    #[test]
    fn atacworks_shape() {
        // 50_000-wide segment padded by 5_000 on each side (Sec. 4.2).
        let p = ConvParams::new(1, 15, 15, 60_000, 51, 8).unwrap();
        assert_eq!(p.q(), 60_000 - 50 * 8);
        assert!(p.favours_brgemm());
        assert!(p.cache_optimal());
    }

    #[test]
    fn rejects_degenerate() {
        assert!(ConvParams::new(0, 1, 1, 10, 1, 1).is_none());
        assert!(ConvParams::new(1, 1, 1, 10, 5, 4).is_none()); // span 17 > 10
        assert!(ConvParams::new(1, 1, 1, 10, 1, 0).is_none());
    }

    #[test]
    fn flops_formula() {
        let p = ConvParams::new(1, 15, 15, 1000 + 50 * 8, 51, 8).unwrap();
        assert_eq!(p.flops(), 2 * 15 * 15 * 1000 * 51);
    }

    #[test]
    fn strided_output_width() {
        let p = ConvParams::new(1, 3, 4, 20, 3, 2).unwrap(); // span 5, Q=16
        assert_eq!(p.q(), 16);
        let p2 = p.with_stride(2).unwrap();
        assert_eq!(p2.q(), 8); // positions 0,2,..,14
        let p3 = p.with_stride(3).unwrap();
        assert_eq!(p3.q(), 6); // positions 0,3,..,15
        assert_eq!(p2.unit_stride(), p);
        assert!(p.with_stride(0).is_none());
        // Display mentions the stride only when it is not 1.
        assert!(!format!("{p}").contains("st"));
        assert!(format!("{p2}").contains("st2"));
    }

    #[test]
    fn span_and_blocks() {
        let p = ConvParams::new(1, 1, 1, 1000, 51, 8).unwrap();
        assert_eq!(p.span(), 401);
        assert_eq!(p.q_blocks(), p.q().div_ceil(64));
    }
}
