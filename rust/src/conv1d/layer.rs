//! `Conv1dLayer` — the public, framework-style layer object.
//!
//! Owns the weight (framework layout `(K, C, S)`) plus the two derived
//! layouts the paper's kernels need, a bias vector, and an implementation
//! selector. This is the Rust equivalent of the paper's PyTorch C++
//! extension module: construct once, call `forward` / `backward_*` per
//! batch, switch `Backend` to compare against the library baseline.

use super::backward_data::backward_data;
use super::backward_weight::backward_weight;
use super::bf16::{to_bf16, Bf16};
use super::direct::{backward_data_direct, forward_direct};
use super::forward::{forward, forward_bf16};
use super::im2col::forward_im2col;
use super::layout::{kcs_to_sck_flipped, kcs_to_skc, pad_width};
use super::params::ConvParams;

/// Kernel implementation selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Backend {
    /// The paper's BRGEMM kernels (Algorithms 2–4). Default.
    #[default]
    Brgemm,
    /// im2col + GEMM — the "oneDNN-analog" library baseline.
    Im2col,
    /// Naive direct loops — correctness oracle / unoptimised floor.
    Direct,
}

impl std::str::FromStr for Backend {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "brgemm" | "libxsmm" | "ours" => Ok(Backend::Brgemm),
            "im2col" | "onednn" | "baseline" => Ok(Backend::Im2col),
            "direct" | "naive" => Ok(Backend::Direct),
            other => Err(format!("unknown backend '{other}'")),
        }
    }
}

/// A 1D dilated convolution layer with owned parameters.
#[derive(Debug, Clone)]
pub struct Conv1dLayer {
    /// Input channels.
    pub c: usize,
    /// Filters (output channels).
    pub k: usize,
    /// Filter width.
    pub s: usize,
    /// Dilation.
    pub d: usize,
    /// Kernel implementation used by `forward`.
    pub backend: Backend,
    /// Threads for the batch-dimension parallelism.
    pub threads: usize,
    w_kcs: Vec<f32>,
    w_skc: Vec<f32>,        // forward layout (S, K, C)
    w_sck_flip: Vec<f32>,   // backward-data layout (S, C, K), taps reversed
    w_skc_bf16: Vec<Bf16>,  // bf16 copy of the forward layout
    /// Per-filter bias (added by `forward_same`, framework-style).
    pub bias: Vec<f32>,
}

impl Conv1dLayer {
    /// Create a layer with the given weight in framework `(K, C, S)` layout.
    pub fn new(c: usize, k: usize, s: usize, d: usize, w_kcs: Vec<f32>) -> Self {
        assert_eq!(w_kcs.len(), k * c * s, "weight shape mismatch");
        assert!(c > 0 && k > 0 && s > 0 && d > 0);
        let w_skc = kcs_to_skc(&w_kcs, k, c, s);
        let w_sck_flip = kcs_to_sck_flipped(&w_kcs, k, c, s);
        let w_skc_bf16 = to_bf16(&w_skc);
        Conv1dLayer {
            c,
            k,
            s,
            d,
            backend: Backend::Brgemm,
            threads: 1,
            w_kcs,
            w_skc,
            w_sck_flip,
            w_skc_bf16,
            bias: vec![0.0; k],
        }
    }

    /// Replace the weights (e.g. after an optimiser step); refreshes the
    /// derived layouts.
    pub fn set_weights(&mut self, w_kcs: Vec<f32>) {
        assert_eq!(w_kcs.len(), self.k * self.c * self.s);
        self.w_skc = kcs_to_skc(&w_kcs, self.k, self.c, self.s);
        self.w_sck_flip = kcs_to_sck_flipped(&w_kcs, self.k, self.c, self.s);
        self.w_skc_bf16 = to_bf16(&self.w_skc);
        self.w_kcs = w_kcs;
    }

    /// Framework-layout weights `(K, C, S)`.
    pub fn weights(&self) -> &[f32] {
        &self.w_kcs
    }

    /// Problem descriptor for a padded input of width `w`.
    pub fn params(&self, n: usize, w: usize) -> ConvParams {
        ConvParams::new(n, self.c, self.k, w, self.s, self.d)
            .unwrap_or_else(|| panic!("invalid conv problem: w={w} s={} d={}", self.s, self.d))
    }

    /// Valid convolution over a **pre-padded** `(N, C, W)` input.
    /// Returns `(N, K, Q)`.
    pub fn forward(&self, x: &[f32], n: usize, w: usize) -> Vec<f32> {
        let p = self.params(n, w);
        let mut out = vec![0.0f32; n * self.k * p.q()];
        match self.backend {
            Backend::Brgemm => forward(&p, x, &self.w_skc, &mut out, self.threads),
            Backend::Im2col => forward_im2col(&p, x, &self.w_kcs, &mut out, self.threads),
            Backend::Direct => forward_direct(&p, x, &self.w_kcs, &mut out),
        }
        out
    }

    /// Same-padded convolution + bias over an unpadded `(N, C, W)` input.
    /// Returns `(N, K, W)` — the AtacWorks usage.
    pub fn forward_same(&self, x: &[f32], n: usize, w: usize) -> Vec<f32> {
        let (l, r) = ConvParams::same_pad(self.s, self.d);
        let xp = pad_width(x, n, self.c, w, l, r);
        let mut out = self.forward(&xp, n, w + l + r);
        for ib in 0..n {
            for ik in 0..self.k {
                let b = self.bias[ik];
                if b != 0.0 {
                    for v in &mut out[(ib * self.k + ik) * w..(ib * self.k + ik) * w + w] {
                        *v += b;
                    }
                }
            }
        }
        out
    }

    /// bf16 forward over a pre-padded bf16 input (BRGEMM backend only).
    pub fn forward_bf16(&self, x: &[Bf16], n: usize, w: usize) -> Vec<Bf16> {
        let p = self.params(n, w);
        let mut out = vec![Bf16::ZERO; n * self.k * p.q()];
        forward_bf16(&p, x, &self.w_skc_bf16, &mut out, self.threads);
        out
    }

    /// Data gradient: `gout (N, K, Q)` → `(N, C, W)` (Algorithm 3).
    pub fn backward_data(&self, gout: &[f32], n: usize, w: usize) -> Vec<f32> {
        let p = self.params(n, w);
        let mut gin = vec![0.0f32; n * self.c * w];
        match self.backend {
            Backend::Brgemm | Backend::Im2col => {
                backward_data(&p, gout, &self.w_sck_flip, &mut gin, self.threads)
            }
            Backend::Direct => backward_data_direct(&p, gout, &self.w_kcs, &mut gin),
        }
        gin
    }

    /// Weight gradient in `(K, C, S)` layout (Algorithm 4).
    pub fn backward_weight(&self, gout: &[f32], x: &[f32], n: usize, w: usize) -> Vec<f32> {
        let p = self.params(n, w);
        backward_weight(&p, gout, x, self.threads)
    }

    /// Bias gradient: `Σ_{n,q} gout[n,k,q]` per filter.
    pub fn backward_bias(&self, gout: &[f32], n: usize, q: usize) -> Vec<f32> {
        let mut gb = vec![0.0f32; self.k];
        for ib in 0..n {
            for ik in 0..self.k {
                let row = &gout[(ib * self.k + ik) * q..(ib * self.k + ik) * q + q];
                gb[ik] += row.iter().sum::<f32>();
            }
        }
        gb
    }

    /// Number of learnable parameters (weights + bias).
    pub fn param_count(&self) -> usize {
        self.w_kcs.len() + self.bias.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv1d::test_util::rnd;

    fn layer(c: usize, k: usize, s: usize, d: usize) -> Conv1dLayer {
        Conv1dLayer::new(c, k, s, d, rnd(k * c * s, 9))
    }

    #[test]
    fn backends_agree() {
        let (n, w) = (2, 300);
        let l = layer(5, 7, 9, 4);
        let x = rnd(n * 5 * w, 10);
        let a = {
            let mut l = l.clone();
            l.backend = Backend::Brgemm;
            l.forward(&x, n, w)
        };
        let b = {
            let mut l = l.clone();
            l.backend = Backend::Im2col;
            l.forward(&x, n, w)
        };
        let c_ = {
            let mut l = l.clone();
            l.backend = Backend::Direct;
            l.forward(&x, n, w)
        };
        for ((x1, x2), x3) in a.iter().zip(&b).zip(&c_) {
            assert!((x1 - x2).abs() < 1e-4 * (1.0 + x2.abs()));
            assert!((x1 - x3).abs() < 1e-4 * (1.0 + x3.abs()));
        }
    }

    #[test]
    fn same_padding_preserves_width_and_adds_bias() {
        let (n, w) = (1, 97);
        let mut l = layer(3, 4, 5, 2);
        l.bias = vec![1.0, 2.0, 3.0, 4.0];
        let x = rnd(n * 3 * w, 11);
        let out = l.forward_same(&x, n, w);
        assert_eq!(out.len(), n * 4 * w);
        // Check the bias offset: zero input ⇒ output == bias everywhere.
        let zeros = vec![0.0; n * 3 * w];
        let out0 = l.forward_same(&zeros, n, w);
        for ik in 0..4 {
            assert!(out0[ik * w..(ik + 1) * w]
                .iter()
                .all(|&v| v == l.bias[ik]));
        }
    }

    #[test]
    fn grad_shapes() {
        let (n, w) = (2, 140);
        let l = layer(4, 6, 7, 3);
        let p = l.params(n, w);
        let x = rnd(n * 4 * w, 12);
        let gout = rnd(n * 6 * p.q(), 13);
        assert_eq!(l.backward_data(&gout, n, w).len(), n * 4 * w);
        assert_eq!(l.backward_weight(&gout, &x, n, w).len(), 6 * 4 * 7);
        assert_eq!(l.backward_bias(&gout, n, p.q()).len(), 6);
    }

    #[test]
    fn set_weights_refreshes_layouts() {
        let (n, w) = (1, 80);
        let mut l = layer(2, 3, 3, 2);
        let x = rnd(n * 2 * w, 14);
        let before = l.forward(&x, n, w);
        let new_w = rnd(3 * 2 * 3, 15);
        l.set_weights(new_w.clone());
        let after = l.forward(&x, n, w);
        assert_ne!(before, after);
        // And it matches a fresh layer with those weights.
        let fresh = Conv1dLayer::new(2, 3, 3, 2, new_w).forward(&x, n, w);
        assert_eq!(after, fresh);
    }

    #[test]
    fn backend_parses() {
        assert_eq!("onednn".parse::<Backend>().unwrap(), Backend::Im2col);
        assert_eq!("BRGEMM".parse::<Backend>().unwrap(), Backend::Brgemm);
        assert!("cuda".parse::<Backend>().is_err());
    }
}
