//! `Conv1dLayer` — the public, framework-style layer object.
//!
//! Since the plan/executor redesign (DESIGN.md §5a) this is a thin
//! compatibility wrapper over [`ConvPlan`]: the layer owns the framework
//! `(K, C, S)` weight and a bias, and lazily builds one plan per
//! `(shape, backend, threads)` combination. Repeated calls at the same
//! shape — the training steady state — reuse the cached plan, so the
//! derived layouts, offset tables and scratch are built once, exactly
//! like the paper's PyTorch C++ extension module.

use std::sync::Mutex;

use super::bf16::{to_bf16, Bf16};
use super::forward::forward_bf16;
use super::layout::{kcs_to_skc, pad_width};
use super::params::ConvParams;
use super::plan::{ConvPlan, PlanError, PlanOptions};
use super::post::PostOps;
use super::threading::Partition;
use crate::machine::Precision;

/// Kernel implementation selector. `Display` emits the canonical registry
/// name ([`super::plan::lookup_kernel`]) and round-trips with `FromStr`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Backend {
    /// The paper's BRGEMM kernels (Algorithms 2–4). Default.
    #[default]
    Brgemm,
    /// im2col + GEMM — the "oneDNN-analog" library baseline.
    Im2col,
    /// Naive direct loops — correctness oracle / unoptimised floor.
    Direct,
}

impl Backend {
    /// Every selectable backend, in preference order.
    pub const ALL: [Backend; 3] = [Backend::Brgemm, Backend::Im2col, Backend::Direct];

    /// Canonical registry name of this backend.
    pub fn as_str(&self) -> &'static str {
        match self {
            Backend::Brgemm => "brgemm",
            Backend::Im2col => "im2col",
            Backend::Direct => "direct",
        }
    }
}

impl std::fmt::Display for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for Backend {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        // Single alias vocabulary: resolve through the kernel registry so
        // the enum and `lookup_kernel` can never drift apart.
        match super::plan::lookup_kernel(s).map(|k| k.name()) {
            Some("brgemm") => Ok(Backend::Brgemm),
            Some("im2col") => Ok(Backend::Im2col),
            Some("direct") => Ok(Backend::Direct),
            Some(other) => Err(format!(
                "kernel '{other}' is not an enum backend; select it by name \
                 via the registry (e.g. TrainConfig::apply_backend_name)"
            )),
            None => Err(format!("unknown backend '{s}'")),
        }
    }
}

/// Gradients produced by one fused backward pass
/// ([`Conv1dLayer::try_backward_fused`]).
pub struct FusedGrads {
    /// Input gradient `(N, C, W)` (requested via `need_gin`).
    pub gin: Option<Vec<f32>>,
    /// Weight gradient `(K, C, S)`.
    pub w: Vec<f32>,
    /// Bias gradient (`K`) — folded into the prologue sweep.
    pub b: Vec<f32>,
    /// Residual gradient `(N, K, Q)` (requested via `need_gres`).
    pub res: Option<Vec<f32>>,
}

/// A 1D dilated convolution layer with owned parameters.
///
/// ```
/// use dilconv1d::conv1d::Conv1dLayer;
/// use dilconv1d::machine::Precision;
///
/// // C=2, K=3, S=5, d=2; input (N=1, C=2, W=32) → output (1, 3, 24).
/// let mut layer = Conv1dLayer::new(2, 3, 5, 2, vec![0.25f32; 3 * 2 * 5]);
/// let y32 = layer.forward(&vec![1.0f32; 2 * 32], 1, 32);
/// assert_eq!(y32.len(), 3 * 24);
///
/// // BF16 mixed precision: bf16 operands, f32 accumulation — the same
/// // call, routed through the bf16 kernel (weights of 0.25 and inputs
/// // of 1.0 are exact in bf16, so this particular result is identical).
/// layer.precision = Precision::Bf16;
/// assert_eq!(layer.forward(&vec![1.0f32; 2 * 32], 1, 32), y32);
/// ```
///
/// During BF16 *training* the trainer additionally keeps FP32 master
/// weights and loads their bf16 rounding into layers each step
/// ([`crate::model::MasterWeights`], DESIGN.md §6).
///
/// Concurrency note: the cached plan sits behind a `Mutex`, so sharing
/// one `&Conv1dLayer` across threads serialises its forward/backward
/// calls. For parallel inference give each worker its own layer (a
/// `clone()` is cheap — the clone rebuilds its plan lazily); in-layer
/// parallelism comes from `threads` instead.
#[derive(Debug)]
pub struct Conv1dLayer {
    /// Input channels.
    pub c: usize,
    /// Filters (output channels).
    pub k: usize,
    /// Filter width.
    pub s: usize,
    /// Dilation.
    pub d: usize,
    /// Kernel implementation used by `forward`.
    pub backend: Backend,
    /// Forward-pass precision. `Bf16` takes effect on the BRGEMM backend
    /// (the paper's bf16 path); other backends fall back to f32, exactly
    /// like the bench harness does.
    pub precision: Precision,
    /// Threads for the kernel-level parallelism.
    pub threads: usize,
    /// Work partitioning across those threads: `Batch` splits the batch
    /// dimension N (the paper's strategy); `Grid` splits the
    /// `N × ceil(Q/64)` width-block grid, so a single long-sequence image
    /// still uses every thread (the N ≤ 4 serving regime).
    pub partition: Partition,
    /// Post-op epilogue fused by `forward_post` / `backward_fused` —
    /// [`PostOps::none`] leaves the legacy APIs bit-identical.
    pub post_ops: PostOps,
    /// When set, the kernel is chosen per shape by the process-wide
    /// autotuner ([`crate::conv1d::autotuner`]) instead of `backend`.
    pub autotune: bool,
    /// Forward-only layer: plans are built via
    /// [`ConvPlan::with_inference`] (no backward scratch, backward calls
    /// panic) — the serving path (DESIGN.md §7).
    pub inference: bool,
    /// Calibrated per-tensor activation scale for the i8 precision tier
    /// (absmax/127 over a warm-up batch); 1.0 = uncalibrated. Ignored by
    /// the f32/bf16 kernels.
    pub input_scale: f32,
    w_kcs: Vec<f32>,
    /// Per-filter bias (added by `forward_same` and the fused post-op
    /// pipeline, framework-style).
    pub bias: Vec<f32>,
    /// Cached plan for the last-seen
    /// `(shape, backend, precision, threads, post_ops)`, tagged with
    /// whether the autotuner chose its kernel (a pinned-backend plan must
    /// not satisfy an `autotune` lookup, and vice versa the tag lets a
    /// tuned plan be reused without re-consulting the table).
    plan: Mutex<Option<(ConvPlan, bool)>>,
}

impl Clone for Conv1dLayer {
    fn clone(&self) -> Self {
        Conv1dLayer {
            c: self.c,
            k: self.k,
            s: self.s,
            d: self.d,
            backend: self.backend,
            precision: self.precision,
            threads: self.threads,
            partition: self.partition,
            post_ops: self.post_ops,
            autotune: self.autotune,
            inference: self.inference,
            input_scale: self.input_scale,
            w_kcs: self.w_kcs.clone(),
            bias: self.bias.clone(),
            plan: Mutex::new(None), // the clone rebuilds its plan lazily
        }
    }
}

impl Conv1dLayer {
    /// Create a layer with the given weight in framework `(K, C, S)` layout.
    pub fn new(c: usize, k: usize, s: usize, d: usize, w_kcs: Vec<f32>) -> Self {
        assert_eq!(w_kcs.len(), k * c * s, "weight shape mismatch");
        assert!(c > 0 && k > 0 && s > 0 && d > 0);
        Conv1dLayer {
            c,
            k,
            s,
            d,
            backend: Backend::Brgemm,
            precision: Precision::F32,
            threads: 1,
            partition: Partition::default(),
            post_ops: PostOps::none(),
            autotune: false,
            inference: false,
            input_scale: 1.0,
            w_kcs,
            bias: vec![0.0; k],
            plan: Mutex::new(None),
        }
    }

    /// Replace the weights (e.g. after an optimiser step); refreshes the
    /// cached plan's derived layouts in place.
    pub fn set_weights(&mut self, w_kcs: Vec<f32>) {
        assert_eq!(w_kcs.len(), self.k * self.c * self.s);
        if let Some((plan, _)) = self.plan.get_mut().unwrap().as_mut() {
            plan.set_weights(&w_kcs);
        }
        self.w_kcs = w_kcs;
    }

    /// Framework-layout weights `(K, C, S)`.
    pub fn weights(&self) -> &[f32] {
        &self.w_kcs
    }

    /// Problem descriptor for a padded input of width `w` — the
    /// `Result`-returning plan-building path (invalid geometry, e.g.
    /// `w < (S−1)·d + 1`, is an error, not a panic).
    pub fn try_params(&self, n: usize, w: usize) -> Result<ConvParams, PlanError> {
        ConvParams::new(n, self.c, self.k, w, self.s, self.d).ok_or_else(|| {
            PlanError(format!(
                "invalid conv problem: n={n} c={} k={} w={w} s={} d={} \
                 (need w > (S-1)*d and every dimension nonzero)",
                self.c, self.k, self.s, self.d
            ))
        })
    }

    /// Problem descriptor for a padded input of width `w`.
    ///
    /// Panics on invalid geometry; use [`Self::try_params`] for the
    /// error-returning variant.
    pub fn params(&self, n: usize, w: usize) -> ConvParams {
        self.try_params(n, w).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Effective plan precision: bf16 (paper Sec. 4.3) and the i8
    /// quantized tier are only meaningful on the BRGEMM backend;
    /// everything else runs f32.
    fn plan_precision(&self) -> Precision {
        if self.backend == Backend::Brgemm || self.autotune {
            self.precision
        } else {
            Precision::F32
        }
    }

    /// Run `f` against the cached plan, rebuilding it when the shape,
    /// backend, precision, thread count, partition or post-op spec
    /// changed since the last call. The plan's bias is re-synced from `self.bias` on every
    /// call (a `K`-element copy), so direct mutation of the `bias` field
    /// can never go stale.
    fn with_plan<R>(
        &self,
        p: &ConvParams,
        f: impl FnOnce(&mut ConvPlan) -> R,
    ) -> Result<R, PlanError> {
        let precision = self.plan_precision();
        let mut guard = self.plan.lock().unwrap();
        let reuse = guard.as_ref().is_some_and(|(plan, tuned)| {
            let kernel_ok = if self.autotune {
                // A tuner-chosen plan is reusable without re-consulting
                // the table (the tuner is deterministic per shape/
                // threads/precision); a pinned-backend plan is NOT — it
                // would silently bypass the autotuner.
                *tuned
                    && plan.params() == p
                    && plan.threads() == self.threads.max(1)
                    && plan.precision() == precision
            } else {
                plan.matches(p, self.backend, precision, self.threads)
            };
            kernel_ok
                && plan.post_ops() == &self.post_ops
                && plan.partition() == self.partition
                && plan.is_inference() == self.inference
        });
        if !reuse {
            let opts = PlanOptions::new()
                .precision(precision)
                .threads(self.threads)
                .partition(self.partition)
                .inference(self.inference)
                .post_ops(self.post_ops);
            let opts = if self.autotune {
                opts.tuned()
            } else {
                opts.backend(self.backend)
            };
            let plan = ConvPlan::build(*p, self.w_kcs.clone(), opts)?;
            *guard = Some((plan, self.autotune));
        }
        let (plan, _) = guard.as_mut().expect("plan just ensured");
        plan.set_bias(&self.bias);
        plan.set_input_scale(self.input_scale);
        Ok(f(plan))
    }

    /// Valid convolution over a **pre-padded** `(N, C, W)` input.
    /// Returns `(N, K, Q)`. Error-returning twin of [`Self::forward`].
    pub fn try_forward(&self, x: &[f32], n: usize, w: usize) -> Result<Vec<f32>, PlanError> {
        let p = self.try_params(n, w)?;
        let mut out = vec![0.0f32; n * self.k * p.q()];
        self.with_plan(&p, |plan| plan.execute_forward_into(x, &mut out))?;
        Ok(out)
    }

    /// Valid convolution over a **pre-padded** `(N, C, W)` input.
    /// Returns `(N, K, Q)`.
    pub fn forward(&self, x: &[f32], n: usize, w: usize) -> Vec<f32> {
        self.try_forward(x, n, w).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Fused post-op forward over a **pre-padded** input: applies
    /// `self.post_ops` (with `self.bias`) inside the kernel's output
    /// block loop — one pass over the output. `residual` is the
    /// `(N, K, Q)` residual tensor when the spec has `residual` set.
    pub fn try_forward_post(
        &self,
        x: &[f32],
        residual: Option<&[f32]>,
        n: usize,
        w: usize,
    ) -> Result<Vec<f32>, PlanError> {
        let p = self.try_params(n, w)?;
        let mut out = vec![0.0f32; n * self.k * p.q()];
        self.with_plan(&p, |plan| {
            plan.execute_forward_post_into(x, residual, &mut out)
        })?;
        Ok(out)
    }

    /// [`Self::try_forward_post`] into a caller-owned `(N, K, Q)` buffer
    /// — the net-level plan's per-layer entry point: the output lands
    /// directly in an arena slot, so the steady state allocates nothing.
    /// `out` must be zeroed by the caller (kernels that accumulate rely
    /// on it, exactly as `try_forward_post` zero-initialises its fresh
    /// output vector).
    pub fn try_forward_post_into(
        &self,
        x: &[f32],
        residual: Option<&[f32]>,
        n: usize,
        w: usize,
        out: &mut [f32],
    ) -> Result<(), PlanError> {
        let p = self.try_params(n, w)?;
        assert_eq!(out.len(), n * self.k * p.q(), "output buffer shape mismatch");
        self.with_plan(&p, |plan| plan.execute_forward_post_into(x, residual, out))
    }

    /// Fused backward through the post-op pipeline (adjoint of
    /// [`Self::try_forward_post`]): one prologue sweep folds the
    /// activation gradient (from the saved output `y`), the bias gradient
    /// and the residual gradient together, then runs the kernel backward
    /// passes. `need_gin`/`need_gres` control which gradients are
    /// produced (the stem skips `gin`; only residual-fused layers need
    /// `gres`).
    #[allow(clippy::too_many_arguments)]
    pub fn try_backward_fused(
        &self,
        gout: &[f32],
        y: &[f32],
        x: &[f32],
        n: usize,
        w: usize,
        need_gin: bool,
        need_gres: bool,
    ) -> Result<FusedGrads, PlanError> {
        let p = self.try_params(n, w)?;
        let mut gin = if need_gin {
            Some(vec![0.0f32; n * self.c * w])
        } else {
            None
        };
        let mut gres = if need_gres {
            Some(vec![0.0f32; n * self.k * p.q()])
        } else {
            None
        };
        let mut gw = vec![0.0f32; self.k * self.c * self.s];
        let mut gb = vec![0.0f32; self.k];
        self.with_plan(&p, |plan| {
            plan.execute_backward_fused_into(
                gout,
                y,
                x,
                gin.as_deref_mut(),
                &mut gw,
                Some(&mut gb),
                gres.as_deref_mut(),
            )
        })?;
        Ok(FusedGrads {
            gin,
            w: gw,
            b: gb,
            res: gres,
        })
    }

    /// Same-padded convolution + bias over an unpadded `(N, C, W)` input.
    /// Returns `(N, K, W)` — the AtacWorks usage.
    pub fn forward_same(&self, x: &[f32], n: usize, w: usize) -> Vec<f32> {
        let (l, r) = ConvParams::same_pad(self.s, self.d);
        let xp = pad_width(x, n, self.c, w, l, r);
        let mut out = self.forward(&xp, n, w + l + r);
        for ib in 0..n {
            for ik in 0..self.k {
                let b = self.bias[ik];
                if b != 0.0 {
                    for v in &mut out[(ib * self.k + ik) * w..(ib * self.k + ik) * w + w] {
                        *v += b;
                    }
                }
            }
        }
        out
    }

    /// bf16 forward over a pre-padded bf16 input (BRGEMM backend only).
    /// Compatibility path with a bf16 tensor interface; the bf16 weight
    /// layout is derived per call — steady-state bf16 execution belongs
    /// to a `Precision::Bf16` plan, which stages it once.
    pub fn forward_bf16(&self, x: &[Bf16], n: usize, w: usize) -> Vec<Bf16> {
        let p = self.params(n, w);
        let w_skc_bf16 = to_bf16(&kcs_to_skc(&self.w_kcs, self.k, self.c, self.s));
        let mut out = vec![Bf16::ZERO; n * self.k * p.q()];
        forward_bf16(&p, x, &w_skc_bf16, &mut out, self.threads);
        out
    }

    /// Data gradient: `gout (N, K, Q)` → `(N, C, W)` (Algorithm 3).
    /// Error-returning twin of [`Self::backward_data`].
    pub fn try_backward_data(&self, gout: &[f32], n: usize, w: usize) -> Result<Vec<f32>, PlanError> {
        let p = self.try_params(n, w)?;
        let mut gin = vec![0.0f32; n * self.c * w];
        self.with_plan(&p, |plan| plan.execute_backward_data_into(gout, &mut gin))?;
        Ok(gin)
    }

    /// Data gradient: `gout (N, K, Q)` → `(N, C, W)` (Algorithm 3).
    pub fn backward_data(&self, gout: &[f32], n: usize, w: usize) -> Vec<f32> {
        self.try_backward_data(gout, n, w)
            .unwrap_or_else(|e| panic!("{e}"))
    }

    /// Weight gradient in `(K, C, S)` layout (Algorithm 4).
    /// Error-returning twin of [`Self::backward_weight`].
    pub fn try_backward_weight(
        &self,
        gout: &[f32],
        x: &[f32],
        n: usize,
        w: usize,
    ) -> Result<Vec<f32>, PlanError> {
        let p = self.try_params(n, w)?;
        let mut gw = vec![0.0f32; self.k * self.c * self.s];
        self.with_plan(&p, |plan| plan.execute_backward_weight_into(gout, x, &mut gw))?;
        Ok(gw)
    }

    /// Weight gradient in `(K, C, S)` layout (Algorithm 4).
    pub fn backward_weight(&self, gout: &[f32], x: &[f32], n: usize, w: usize) -> Vec<f32> {
        self.try_backward_weight(gout, x, n, w)
            .unwrap_or_else(|e| panic!("{e}"))
    }

    /// Bias gradient: `Σ_{n,q} gout[n,k,q]` per filter.
    pub fn backward_bias(&self, gout: &[f32], n: usize, q: usize) -> Vec<f32> {
        let mut gb = vec![0.0f32; self.k];
        for ib in 0..n {
            for ik in 0..self.k {
                let row = &gout[(ib * self.k + ik) * q..(ib * self.k + ik) * q + q];
                gb[ik] += row.iter().sum::<f32>();
            }
        }
        gb
    }

    /// Number of learnable parameters (weights + bias).
    pub fn param_count(&self) -> usize {
        self.w_kcs.len() + self.bias.len()
    }

    /// Eagerly build (warm) the cached plan for a padded `(n, w)` problem
    /// without executing anything — the serving plan cache calls this at
    /// startup so the first real request never pays plan construction or
    /// autotuner probes.
    pub fn try_warm(&self, n: usize, w: usize) -> Result<(), PlanError> {
        let p = self.try_params(n, w)?;
        self.with_plan(&p, |_| ())
    }

    /// Workspace bytes held by the currently-cached plan (0 when no plan
    /// has been built yet) — the serving memory-accounting hook.
    pub fn plan_workspace_bytes(&self) -> usize {
        self.plan
            .lock()
            .unwrap()
            .as_ref()
            .map_or(0, |(plan, _)| plan.workspace_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv1d::test_util::rnd;

    fn layer(c: usize, k: usize, s: usize, d: usize) -> Conv1dLayer {
        Conv1dLayer::new(c, k, s, d, rnd(k * c * s, 9))
    }

    #[test]
    fn backends_agree() {
        let (n, w) = (2, 300);
        let l = layer(5, 7, 9, 4);
        let x = rnd(n * 5 * w, 10);
        let a = {
            let mut l = l.clone();
            l.backend = Backend::Brgemm;
            l.forward(&x, n, w)
        };
        let b = {
            let mut l = l.clone();
            l.backend = Backend::Im2col;
            l.forward(&x, n, w)
        };
        let c_ = {
            let mut l = l.clone();
            l.backend = Backend::Direct;
            l.forward(&x, n, w)
        };
        for ((x1, x2), x3) in a.iter().zip(&b).zip(&c_) {
            assert!((x1 - x2).abs() < 1e-4 * (1.0 + x2.abs()));
            assert!((x1 - x3).abs() < 1e-4 * (1.0 + x3.abs()));
        }
    }

    #[test]
    fn backend_switch_on_one_layer_rebuilds_plan() {
        // Mutating the pub field must be observed by the cached plan.
        let (n, w) = (1, 200);
        let mut l = layer(3, 4, 5, 2);
        let x = rnd(n * 3 * w, 21);
        let a = l.forward(&x, n, w);
        l.backend = Backend::Direct;
        let b = l.forward(&x, n, w);
        for (x1, x2) in a.iter().zip(&b) {
            assert!((x1 - x2).abs() < 1e-4 * (1.0 + x2.abs()));
        }
    }

    #[test]
    fn same_padding_preserves_width_and_adds_bias() {
        let (n, w) = (1, 97);
        let mut l = layer(3, 4, 5, 2);
        l.bias = vec![1.0, 2.0, 3.0, 4.0];
        let x = rnd(n * 3 * w, 11);
        let out = l.forward_same(&x, n, w);
        assert_eq!(out.len(), n * 4 * w);
        // Check the bias offset: zero input ⇒ output == bias everywhere.
        let zeros = vec![0.0; n * 3 * w];
        let out0 = l.forward_same(&zeros, n, w);
        for ik in 0..4 {
            assert!(out0[ik * w..(ik + 1) * w]
                .iter()
                .all(|&v| v == l.bias[ik]));
        }
    }

    #[test]
    fn grad_shapes() {
        let (n, w) = (2, 140);
        let l = layer(4, 6, 7, 3);
        let p = l.params(n, w);
        let x = rnd(n * 4 * w, 12);
        let gout = rnd(n * 6 * p.q(), 13);
        assert_eq!(l.backward_data(&gout, n, w).len(), n * 4 * w);
        assert_eq!(l.backward_weight(&gout, &x, n, w).len(), 6 * 4 * 7);
        assert_eq!(l.backward_bias(&gout, n, p.q()).len(), 6);
    }

    #[test]
    fn set_weights_refreshes_layouts() {
        let (n, w) = (1, 80);
        let mut l = layer(2, 3, 3, 2);
        let x = rnd(n * 2 * w, 14);
        let before = l.forward(&x, n, w);
        let new_w = rnd(3 * 2 * 3, 15);
        l.set_weights(new_w.clone());
        let after = l.forward(&x, n, w);
        assert_ne!(before, after);
        // And it matches a fresh layer with those weights.
        let fresh = Conv1dLayer::new(2, 3, 3, 2, new_w).forward(&x, n, w);
        assert_eq!(after, fresh);
    }

    #[test]
    fn backend_parses() {
        assert_eq!("onednn".parse::<Backend>().unwrap(), Backend::Im2col);
        assert_eq!("BRGEMM".parse::<Backend>().unwrap(), Backend::Brgemm);
        assert!("cuda".parse::<Backend>().is_err());
    }

    #[test]
    fn bf16_precision_selects_the_bf16_kernel() {
        let (n, w) = (1, 200);
        let mut l = layer(4, 4, 5, 2);
        let x = rnd(n * 4 * w, 31);
        let f32_out = l.forward(&x, n, w);
        l.precision = Precision::Bf16;
        let bf_out = l.forward(&x, n, w);
        assert_ne!(f32_out, bf_out, "bf16 path must actually quantise");
        for (a, b) in bf_out.iter().zip(&f32_out) {
            assert!((a - b).abs() < 5e-2 * (1.0 + b.abs()), "{a} vs {b}");
        }
        // Non-BRGEMM backends gracefully fall back to f32.
        l.backend = Backend::Direct;
        let direct_out = l.forward(&x, n, w);
        for (a, b) in direct_out.iter().zip(&f32_out) {
            assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()));
        }
    }

    #[test]
    fn i8_precision_selects_the_i8_kernel() {
        use crate::conv1d::quant::{absmax, scale_from_absmax};
        let (n, w) = (1, 200);
        let mut l = layer(4, 4, 5, 2);
        let x = rnd(n * 4 * w, 37);
        let f32_out = l.forward(&x, n, w);
        l.precision = Precision::I8;
        l.input_scale = scale_from_absmax(absmax(&x));
        let i8_out = l.forward(&x, n, w);
        assert_ne!(f32_out, i8_out, "i8 path must actually quantise");
        for (a, b) in i8_out.iter().zip(&f32_out) {
            assert!((a - b).abs() < 1.5e-1 * (1.0 + b.abs()), "{a} vs {b}");
        }
        // Non-BRGEMM backends gracefully fall back to f32.
        l.backend = Backend::Direct;
        let direct_out = l.forward(&x, n, w);
        for (a, b) in direct_out.iter().zip(&f32_out) {
            assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()));
        }
    }

    #[test]
    fn invalid_geometry_is_an_error_not_a_panic() {
        // w < (S-1)*d + 1: no output column fits.
        let l = layer(3, 4, 5, 2); // span = 9
        let x = rnd(3 * 8, 40);
        let err = l.try_forward(&x, 1, 8).unwrap_err();
        assert!(err.to_string().contains("invalid conv problem"), "{err}");
        assert!(l.try_params(1, 8).is_err());
        assert!(l.try_params(0, 100).is_err());
        assert!(l.try_params(1, 9).is_ok()); // exactly one output column
        assert!(l.try_backward_data(&[], 1, 8).is_err());
        assert!(l.try_backward_weight(&[], &[], 1, 8).is_err());
        assert!(l.try_forward_post(&x, None, 1, 8).is_err());
        assert!(l
            .try_backward_fused(&[], &[], &[], 1, 8, true, false)
            .is_err());
    }

    #[test]
    fn forward_post_fuses_bias_and_relu() {
        let (n, w) = (2, 120);
        let mut l = layer(3, 4, 5, 2);
        l.bias = vec![0.1, -0.2, 0.3, -0.4];
        let x = rnd(n * 3 * w, 41);
        let q = l.params(n, w).q();
        // Unfused oracle: forward, then bias, then relu.
        let mut want = l.forward(&x, n, w);
        for ib in 0..n {
            for ik in 0..4 {
                for v in &mut want[(ib * 4 + ik) * q..(ib * 4 + ik + 1) * q] {
                    *v = (*v + l.bias[ik]).max(0.0);
                }
            }
        }
        l.post_ops = PostOps::bias_relu();
        let got = l.try_forward_post(&x, None, n, w).unwrap();
        assert_eq!(got, want, "fused bias+relu must match the 3-pass oracle");
        // PostOps::none() keeps the fused entry point bit-identical to
        // the raw forward.
        l.post_ops = PostOps::none();
        let raw = l.try_forward_post(&x, None, n, w).unwrap();
        assert_eq!(raw, l.forward(&x, n, w));
    }

    #[test]
    fn autotuned_layer_matches_fixed_backend() {
        let (n, w) = (2, 150);
        let mut l = layer(4, 5, 7, 2);
        let x = rnd(n * 4 * w, 42);
        let want = l.forward(&x, n, w); // caches a pinned brgemm plan
        // Flipping autotune on must NOT reuse the pinned plan: the next
        // forward consults the tuner, which memoizes this shape's entry.
        l.autotune = true;
        let got = l.forward(&x, n, w);
        let p = l.params(n, w);
        assert!(
            crate::conv1d::autotuner()
                .entry(&p, l.threads, crate::machine::Precision::F32, l.partition)
                .is_some(),
            "autotuned forward must consult the tuner, not the stale plan"
        );
        for (g, ww) in got.iter().zip(&want) {
            assert!((g - ww).abs() < 1e-4 * (1.0 + ww.abs()), "{g} vs {ww}");
        }
        // Repeated calls reuse the tuned plan and stay deterministic.
        assert_eq!(l.forward(&x, n, w), got);
        // Flipping autotune back off must likewise drop the tuned plan.
        l.autotune = false;
        l.backend = Backend::Direct;
        let direct = l.forward(&x, n, w);
        for (g, ww) in direct.iter().zip(&want) {
            assert!((g - ww).abs() < 1e-4 * (1.0 + ww.abs()), "{g} vs {ww}");
        }
    }

    #[test]
    fn grid_partition_layer_matches_batch_bit_exact() {
        // Flipping the pub field must rebuild the cached plan and produce
        // bit-identical outputs (forward/backward-data are partition-
        // invariant).
        let (n, w) = (1, 400);
        let mut l = layer(4, 6, 7, 3);
        l.threads = 8;
        let x = rnd(n * 4 * w, 91);
        let want = l.forward(&x, n, w);
        l.partition = Partition::Grid;
        assert_eq!(l.forward(&x, n, w), want, "grid forward must be bit-exact");
        let p = l.params(n, w);
        let gout = rnd(n * 6 * p.q(), 92);
        l.partition = Partition::Batch;
        let gd = l.backward_data(&gout, n, w);
        l.partition = Partition::Grid;
        assert_eq!(l.backward_data(&gout, n, w), gd);
    }

    #[test]
    fn warm_builds_the_plan_and_inference_mode_round_trips() {
        let (n, w) = (2, 200);
        let mut l = layer(3, 4, 5, 2);
        l.inference = true;
        assert_eq!(l.plan_workspace_bytes(), 0, "no plan before warming");
        l.try_warm(n, w).unwrap();
        let warmed = l.plan_workspace_bytes();
        assert!(warmed > 0, "warm must build the cached plan");
        let x = rnd(n * 3 * w, 71);
        let y_inf = l.forward(&x, n, w);
        // The warm plan was reused (same workspace, no rebuild/growth).
        assert_eq!(l.plan_workspace_bytes(), warmed);
        // A training-mode layer computes the same bits with more scratch.
        let mut t = l.clone();
        t.inference = false;
        assert_eq!(t.forward(&x, n, w), y_inf);
        assert!(t.plan_workspace_bytes() > warmed);
    }

    #[test]
    #[should_panic(expected = "inference-only plan")]
    fn inference_layer_refuses_backward() {
        let (n, w) = (1, 100);
        let mut l = layer(2, 3, 5, 2);
        l.inference = true;
        let x = rnd(n * 2 * w, 72);
        let y = l.forward(&x, n, w);
        let _ = l.backward_data(&y, n, w);
    }

    #[test]
    fn backend_display_round_trips() {
        for b in Backend::ALL {
            assert_eq!(b.to_string().parse::<Backend>().unwrap(), b);
            // The registry resolves the same canonical name.
            let k = crate::conv1d::plan::lookup_kernel(b.as_str()).expect("registered");
            assert_eq!(k.name(), b.as_str());
        }
        assert_eq!(Backend::Brgemm.to_string(), "brgemm");
        assert_eq!(Backend::ALL.len(), 3);
    }
}
