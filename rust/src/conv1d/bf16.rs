//! Brain floating-point (BFloat16) storage type and conversions.
//!
//! The paper's Cooper Lake path uses AVX-512 BF16 (`VDPBF16PS`): operands
//! are stored as bf16, multiplied pairwise, and **accumulated in f32**.
//! We reproduce exactly those semantics: [`Bf16`] is a storage-only type;
//! every arithmetic kernel widens to f32, accumulates in f32 and only
//! narrows on the final store — so the numerics match the hardware
//! instruction, not a naive bf16-everywhere emulation.

/// A bfloat16 value: the upper 16 bits of an IEEE-754 f32.
///
/// `repr(transparent)` over `u16` is a layout guarantee the SIMD
/// micro-kernels rely on: [`crate::conv1d::simd`] reinterprets `&[Bf16]`
/// panels as raw `u16` lanes for the vectorised widening loads.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
#[repr(transparent)]
pub struct Bf16(pub u16);

impl Bf16 {
    pub const ZERO: Bf16 = Bf16(0);

    /// Convert from f32 with round-to-nearest-even (the hardware rounding
    /// mode of `VCVTNEPS2BF16`).
    #[inline(always)]
    pub fn from_f32(v: f32) -> Self {
        let bits = v.to_bits();
        if v.is_nan() {
            // Quiet NaN, preserving the sign.
            return Bf16(((bits >> 16) as u16) | 0x0040);
        }
        // Round to nearest even on the truncated 16 bits.
        let round_bit = 0x0000_8000u32;
        let lsb = (bits >> 16) & 1;
        let rounded = bits.wrapping_add(round_bit - 1 + lsb);
        Bf16((rounded >> 16) as u16)
    }

    /// Widen to f32 (exact; bf16 ⊂ f32).
    #[inline(always)]
    pub fn to_f32(self) -> f32 {
        f32::from_bits((self.0 as u32) << 16)
    }
}

impl From<f32> for Bf16 {
    fn from(v: f32) -> Self {
        Bf16::from_f32(v)
    }
}

impl From<Bf16> for f32 {
    fn from(v: Bf16) -> f32 {
        v.to_f32()
    }
}

/// Convert a f32 slice to bf16.
pub fn to_bf16(xs: &[f32]) -> Vec<Bf16> {
    xs.iter().map(|&v| Bf16::from_f32(v)).collect()
}

/// Convert a f32 slice to bf16 into a caller-owned buffer (the plan's
/// zero-allocation input staging for the bf16 kernel).
pub fn to_bf16_into(xs: &[f32], out: &mut [Bf16]) {
    assert_eq!(xs.len(), out.len(), "bf16 buffer length mismatch");
    narrow_row_into(xs, out);
}

/// Narrow one contiguous f32 row to bf16 — the single narrowing loop both
/// the bf16 forward store and the plan's input staging share. The body is
/// 8-wide `chunks_exact` so the round-to-nearest-even conversion runs as
/// straight-line integer code the compiler vectorises (the scalar
/// per-element loop it replaces was the bf16 path's store bottleneck).
pub fn narrow_row_into(src: &[f32], dst: &mut [Bf16]) {
    assert_eq!(src.len(), dst.len(), "bf16 narrow length mismatch");
    let mut s8 = src.chunks_exact(8);
    let mut d8 = dst.chunks_exact_mut(8);
    for (sc, dc) in (&mut s8).zip(&mut d8) {
        for (d, &s) in dc.iter_mut().zip(sc) {
            *d = Bf16::from_f32(s);
        }
    }
    for (d, &s) in d8.into_remainder().iter_mut().zip(s8.remainder()) {
        *d = Bf16::from_f32(s);
    }
}

/// Widen a bf16 slice to f32.
pub fn to_f32(xs: &[Bf16]) -> Vec<f32> {
    xs.iter().map(|v| v.to_f32()).collect()
}

/// Round-trip a f32 slice through bf16 — the precision the bf16 kernels
/// see. Used by tests to compute reference results at matched precision.
pub fn quantize(xs: &[f32]) -> Vec<f32> {
    xs.iter().map(|&v| Bf16::from_f32(v).to_f32()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_small_values() {
        for v in [0.0f32, 1.0, -1.0, 0.5, 2.0, -0.25, 1024.0] {
            assert_eq!(Bf16::from_f32(v).to_f32(), v, "{v} should be exact");
        }
    }

    #[test]
    fn round_to_nearest_even() {
        // 1.0 + 2^-8 is exactly half-way between two bf16 values around 1.0
        // (bf16 has 8 significand bits): must round to even (-> 1.0).
        let halfway = f32::from_bits(0x3F80_8000);
        assert_eq!(Bf16::from_f32(halfway).to_f32(), 1.0);
        // Slightly above half-way rounds up.
        let above = f32::from_bits(0x3F80_8001);
        assert_eq!(Bf16::from_f32(above).to_f32(), f32::from_bits(0x3F81_0000));
    }

    #[test]
    fn nan_and_inf() {
        assert!(Bf16::from_f32(f32::NAN).to_f32().is_nan());
        assert_eq!(Bf16::from_f32(f32::INFINITY).to_f32(), f32::INFINITY);
        assert_eq!(Bf16::from_f32(f32::NEG_INFINITY).to_f32(), f32::NEG_INFINITY);
    }

    #[test]
    fn relative_error_bound() {
        // bf16 has 8 mantissa bits -> rel err <= 2^-8.
        let mut v = 0.918_276_4f32;
        for _ in 0..50 {
            let q = Bf16::from_f32(v).to_f32();
            assert!((q - v).abs() <= v.abs() * (1.0 / 256.0) + f32::MIN_POSITIVE);
            v *= -1.37;
        }
    }

    #[test]
    fn narrow_row_matches_elementwise() {
        // The chunked narrowing loop must be bit-identical to the naive
        // per-element conversion, across remainder lengths 0..=17.
        for len in 0..=17usize {
            let src: Vec<f32> = (0..len).map(|i| (i as f32 - 4.3) * 0.731).collect();
            let mut dst = vec![Bf16::ZERO; len];
            narrow_row_into(&src, &mut dst);
            let want: Vec<Bf16> = src.iter().map(|&v| Bf16::from_f32(v)).collect();
            assert_eq!(dst, want, "len {len}");
        }
    }

    #[test]
    fn slice_roundtrip() {
        let xs: Vec<f32> = (0..100).map(|i| i as f32 * 0.125).collect();
        // Multiples of 0.125 below 2^8 are exact in bf16 only while the
        // mantissa fits; check via quantize idempotence instead.
        let q1 = quantize(&xs);
        let q2 = quantize(&q1);
        assert_eq!(q1, q2, "quantize must be idempotent");
    }
}
