//! Int8 symmetric quantization helpers for the i8 inference tier.
//!
//! Scheme (DESIGN.md §"Precision ladder"): per-output-channel symmetric
//! weight quantization — one f32 scale per K-row, `scale = absmax / 127`
//! with an all-zero-channel guard — plus a single per-tensor activation
//! scale calibrated from a warm-up batch (absmax / 127). Values map as
//! `q = round(v / scale)` clamped to `[-127, 127]`; the i8 BRGEMM
//! accumulates exactly in i32 and the output is dequantized with
//! `y = acc · (scale_x · scale_w[k])`.
//!
//! The clamp is symmetric at ±127 (not −128) so `|q·q| ≤ 16129` and
//! negation round-trips, matching the VNNI-style kernel contract in
//! [`super::simd`].

/// Symmetric quantization ceiling: quantized values live in `[-127, 127]`.
pub const QMAX: f32 = 127.0;

/// Per-tensor symmetric scale from an absolute maximum: `absmax / 127`,
/// guarded so an all-zero tensor gets scale 1.0 (any scale dequantizes
/// zeros to zeros; 1.0 keeps downstream divisions finite).
pub fn scale_from_absmax(absmax: f32) -> f32 {
    if absmax > 0.0 {
        absmax / QMAX
    } else {
        1.0
    }
}

/// Absolute maximum of a slice (0.0 for an empty slice).
pub fn absmax(v: &[f32]) -> f32 {
    v.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
}

/// Quantize one value: `round(v / scale)` clamped to `[-127, 127]`.
#[inline]
pub fn quantize(v: f32, scale: f32) -> i8 {
    (v / scale).round().clamp(-QMAX, QMAX) as i8
}

/// Quantize a slice into a pre-sized i8 staging buffer.
pub fn quantize_into(src: &[f32], scale: f32, dst: &mut [i8]) {
    debug_assert_eq!(src.len(), dst.len());
    let inv = 1.0 / scale;
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = (s * inv).round().clamp(-QMAX, QMAX) as i8;
    }
}

/// Per-output-channel symmetric weight scales for a `(K, C, S)` weight
/// tensor laid out K-major (`w[k*C*S ..][c*S ..][s]`): one scale per
/// K-row, `absmax(row) / 127`, all-zero rows guarded to 1.0.
pub fn channel_scales_kcs(w: &[f32], kk: usize, c: usize, s: usize) -> Vec<f32> {
    debug_assert_eq!(w.len(), kk * c * s);
    (0..kk)
        .map(|k| scale_from_absmax(absmax(&w[k * c * s..(k + 1) * c * s])))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv1d::test_util::rnd;

    #[test]
    fn round_trip_error_bounded_by_half_scale() {
        let v = rnd(256, 11);
        let scale = scale_from_absmax(absmax(&v));
        for &x in &v {
            let q = quantize(x, scale);
            let back = q as f32 * scale;
            assert!(
                (x - back).abs() <= scale / 2.0 + 1e-7,
                "x={x} back={back} scale={scale}"
            );
        }
    }

    #[test]
    fn clamp_saturates_at_plus_minus_127() {
        let scale = 0.01;
        assert_eq!(quantize(1e9, scale), 127);
        assert_eq!(quantize(-1e9, scale), -127);
        assert_eq!(quantize(0.0, scale), 0);
    }

    #[test]
    fn all_zero_channel_gets_unit_scale() {
        let (kk, c, s) = (3usize, 2usize, 4usize);
        let mut w = rnd(kk * c * s, 5);
        w[c * s..2 * c * s].fill(0.0);
        let scales = channel_scales_kcs(&w, kk, c, s);
        assert_eq!(scales[1], 1.0);
        assert!(scales[0] > 0.0 && scales[2] > 0.0);
        for &x in &w[c * s..2 * c * s] {
            assert_eq!(quantize(x, scales[1]), 0);
        }
    }

    #[test]
    fn channel_scales_are_per_row_absmax() {
        let (kk, c, s) = (2usize, 1usize, 3usize);
        let w = [0.5f32, -2.0, 1.0, 0.25, 0.1, -0.3];
        let scales = channel_scales_kcs(&w, kk, c, s);
        assert_eq!(scales[0], 2.0 / QMAX);
        assert_eq!(scales[1], 0.3 / QMAX);
    }
}
