//! Shape-keyed kernel autotuner (DESIGN.md §5b).
//!
//! cuDNN's `cudnnFindConvolutionForwardAlgorithm` and oneDNN's primitive
//! cache converge on the same design the paper implies: pick the kernel
//! *per shape* by measuring once, then reuse the choice for every later
//! plan at that shape. This module is the native version:
//!
//! * [`Autotuner::choose`] — given `(ConvParams, threads, precision,
//!   partition)`,
//!   return the fastest registered kernel. The first call for a shape
//!   micro-benchmarks every candidate on a width-capped probe problem and
//!   memoizes the winner; every later call is a pure table lookup — the
//!   determinism the tests lock down with [`Autotuner::measurement_count`].
//! * Persistence — the table round-trips through `util::json`
//!   ([`Autotuner::to_json`] / [`Autotuner::load_json`] and the
//!   file-level `save`/`load`), so sweeps and the trainer warm-start
//!   instead of re-measuring (`autotune = true`, `tune_cache = "…"`).
//!
//! The process-wide instance lives behind [`autotuner`];
//! [`super::plan::ConvPlan::tuned`] and `Conv1dLayer { autotune: true }`
//! route through it.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use super::params::{ConvParams, WIDTH_BLOCK};
use super::plan::{kernels, lookup_kernel, ConvKernel, ConvPlan};
use super::threading::Partition;
use crate::machine::Precision;
use crate::util::json::Json;

/// One memoized decision: the winning kernel and its measured time on the
/// probe problem (microseconds; informational).
#[derive(Debug, Clone, PartialEq)]
pub struct TuneEntry {
    pub kernel: String,
    pub micros: f64,
}

/// The shape-keyed kernel selection table.
pub struct Autotuner {
    table: Mutex<BTreeMap<String, TuneEntry>>,
    /// Serializes micro-benchmarks only (never table lookups): two
    /// concurrent measurements would contend for cores and memoize
    /// contended timings.
    measuring: Mutex<()>,
    /// Number of micro-benchmark runs performed (NOT table lookups) —
    /// lets tests assert that a repeated shape re-measures nothing.
    measurements: AtomicUsize,
}

impl Default for Autotuner {
    fn default() -> Self {
        Self::new()
    }
}

impl Autotuner {
    /// An empty tuner (tests use private instances; production code goes
    /// through [`autotuner`]).
    pub fn new() -> Autotuner {
        Autotuner {
            table: Mutex::new(BTreeMap::new()),
            measuring: Mutex::new(()),
            measurements: AtomicUsize::new(0),
        }
    }

    /// The cache key of one tuning decision: the full problem shape plus
    /// the execution context (thread count, precision, **active SIMD
    /// ISA**, **work partition**) — anything that can flip the kernel
    /// ranking. The ISA term means a table measured under
    /// `CONV1D_FORCE_ISA=scalar` (or on an AVX2-only host) is never
    /// served to an AVX-512 process and vice versa; the partition term
    /// keeps grid rankings (where only grid-capable kernels fan out at
    /// N < threads) separate from batch ones. Persisted entries from a
    /// different context simply miss and re-measure.
    pub fn key(
        p: &ConvParams,
        threads: usize,
        precision: Precision,
        partition: Partition,
    ) -> String {
        let prec = match precision {
            Precision::F32 => "f32",
            Precision::Bf16 => "bf16",
            Precision::I8 => "i8",
        };
        format!(
            "n{}c{}k{}w{}s{}d{}st{}t{}p{}i{}pt{}",
            p.n,
            p.c,
            p.k,
            p.w,
            p.s,
            p.d,
            p.stride,
            threads.max(1),
            prec,
            super::simd::active().isa().name(),
            partition
        )
    }

    /// Total micro-benchmark runs so far (one per candidate kernel per
    /// previously-unseen shape).
    pub fn measurement_count(&self) -> usize {
        self.measurements.load(Ordering::SeqCst)
    }

    /// Number of memoized decisions.
    pub fn len(&self) -> usize {
        self.table.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every memoized decision (tests).
    pub fn clear(&self) {
        self.table.lock().unwrap().clear();
    }

    /// The memoized entry for a shape, if any.
    pub fn entry(
        &self,
        p: &ConvParams,
        threads: usize,
        precision: Precision,
        partition: Partition,
    ) -> Option<TuneEntry> {
        self.table
            .lock()
            .unwrap()
            .get(&Self::key(p, threads, precision, partition))
            .cloned()
    }

    /// Pick the kernel for a problem: table hit → memoized winner with
    /// **zero** re-measurement; miss → micro-benchmark every candidate
    /// once and memoize. Reduced precisions (`Bf16`, `I8`) have exactly
    /// one candidate each, so they never measure.
    pub fn choose(
        &self,
        p: &ConvParams,
        threads: usize,
        precision: Precision,
        partition: Partition,
    ) -> &'static dyn ConvKernel {
        if precision != Precision::F32 {
            return kernels()
                .iter()
                .copied()
                .find(|k| k.precision() == precision)
                .expect("every reduced-precision tier has a registered kernel");
        }
        let key = Self::key(p, threads, precision, partition);
        if let Some(k) = self.hit(&key) {
            return k;
        }
        // Serialize measurements (not lookups): concurrent candidate
        // sweeps would compete for cores and memoize contended timings.
        // Re-check under the guard — another thread may have measured
        // this shape while we waited.
        let _serialize = self.measuring.lock().unwrap();
        if let Some(k) = self.hit(&key) {
            return k;
        }
        let (kernel, micros) = self.measure(p, threads, partition);
        self.table.lock().unwrap().insert(
            key,
            TuneEntry {
                kernel: kernel.name().to_string(),
                micros,
            },
        );
        kernel
    }

    /// Table lookup (fast path): the memoized kernel for a key, if any.
    fn hit(&self, key: &str) -> Option<&'static dyn ConvKernel> {
        self.table
            .lock()
            .unwrap()
            .get(key)
            .and_then(|e| lookup_kernel(&e.kernel))
    }

    /// Micro-benchmark every f32 candidate on a width-capped probe of `p`
    /// and return the fastest (name, best time in µs). The probe caps `Q`
    /// (and `N`) so tuning a 60 000-wide training shape costs
    /// milliseconds; the block structure that decides the ranking is
    /// preserved.
    fn measure(
        &self,
        p: &ConvParams,
        threads: usize,
        partition: Partition,
    ) -> (&'static dyn ConvKernel, f64) {
        let probe = probe_params(p, threads, partition);
        let wt = crate::conv1d::test_util::rnd(probe.k * probe.c * probe.s, 0x7E57);
        let x = crate::conv1d::test_util::rnd(probe.n * probe.c * probe.w, 0x7E58);
        let mut best: Option<(&'static dyn ConvKernel, f64)> = None;
        for &kernel in kernels() {
            // Only same-precision kernels compete: a reduced-precision
            // kernel must never win an f32-keyed entry.
            if kernel.precision() != Precision::F32 || !kernel.supports(&probe.unit_stride()) {
                continue;
            }
            // Measure under the partition the cache key promises — the
            // grid ranking at N < threads is nothing like the batch one.
            let mut plan = match ConvPlan::with_kernel(probe, kernel, threads, wt.clone()) {
                Ok(plan) => plan.with_partition(partition),
                Err(_) => continue,
            };
            let mut out = vec![0.0f32; probe.n * probe.k * probe.q()];
            plan.execute_forward_into(&x, &mut out); // warmup
            let mut best_us = f64::INFINITY;
            for _ in 0..3 {
                let t0 = Instant::now();
                plan.execute_forward_into(&x, &mut out);
                best_us = best_us.min(t0.elapsed().as_secs_f64() * 1e6);
            }
            self.measurements.fetch_add(1, Ordering::SeqCst);
            std::hint::black_box(&out);
            if best.is_none() || best_us < best.unwrap().1 {
                best = Some((kernel, best_us));
            }
        }
        best.expect("at least one registered kernel serves every problem")
    }

    /// Serialize the table as JSON (parseable by [`Autotuner::load_json`]
    /// and `util::json`).
    pub fn to_json(&self) -> String {
        let table = self.table.lock().unwrap();
        let mut s = String::from("{\n  \"version\": 1,\n  \"entries\": {");
        for (i, (key, e)) in table.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!(
                "\n    \"{}\": {{\"kernel\": \"{}\", \"micros\": {:.3}}}",
                key, e.kernel, e.micros
            ));
        }
        s.push_str("\n  }\n}\n");
        s
    }

    /// Merge a persisted table into this one (persisted entries win).
    /// Returns the number of entries loaded. Unknown kernels, keys whose
    /// precision tag this build doesn't recognize, and entries whose
    /// kernel disagrees with the key's precision tag are all skipped — a
    /// table written by a newer build (or hand-edited) must not poison
    /// this one, and must never cause a wrong-precision kernel to be
    /// served from the cache.
    pub fn load_json(&self, src: &str) -> Result<usize, String> {
        let doc = Json::parse(src).map_err(|e| e.to_string())?;
        match doc.get("version").and_then(Json::as_usize) {
            Some(1) => {}
            other => {
                return Err(format!(
                    "tune table: unsupported version {other:?} (this build reads version 1)"
                ))
            }
        }
        let entries = doc
            .get("entries")
            .and_then(Json::as_obj)
            .ok_or_else(|| "tune table: missing 'entries' object".to_string())?;
        let mut loaded = 0;
        let mut table = self.table.lock().unwrap();
        for (key, v) in entries {
            let kernel = match v.get("kernel").and_then(Json::as_str) {
                Some(name) => match lookup_kernel(name) {
                    Some(k) => k,
                    None => continue,
                },
                None => continue,
            };
            // A key with an unrecognized precision tag can never be
            // *generated* by this build, so it would sit inert — but an
            // entry whose kernel disagrees with the key's tag WOULD be
            // served (e.g. a bf16 kernel answering an f32-keyed lookup).
            // Skip both classes.
            match key_precision(key) {
                Some(prec) if kernel.precision() == prec => {}
                _ => continue,
            }
            let micros = v.get("micros").and_then(Json::as_f64).unwrap_or(0.0);
            table.insert(
                key.clone(),
                TuneEntry {
                    kernel: kernel.name().to_string(),
                    micros,
                },
            );
            loaded += 1;
        }
        Ok(loaded)
    }

    /// Persist the table to a file.
    pub fn save(&self, path: impl AsRef<std::path::Path>) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }

    /// Load a persisted table from a file (merging; see
    /// [`Autotuner::load_json`]).
    pub fn load(&self, path: impl AsRef<std::path::Path>) -> Result<usize, String> {
        let src = std::fs::read_to_string(path.as_ref())
            .map_err(|e| format!("reading tune table {:?}: {e}", path.as_ref()))?;
        self.load_json(&src)
    }
}

/// The precision tag embedded in a cache key, if this build recognizes
/// it. Every other key field is digits, so the `p<tag>i` marker can only
/// occur at the precision spot — a substring test is exact.
fn key_precision(key: &str) -> Option<Precision> {
    if key.contains("pf32i") {
        Some(Precision::F32)
    } else if key.contains("pbf16i") {
        Some(Precision::Bf16)
    } else if key.contains("pi8i") {
        Some(Precision::I8)
    } else {
        None
    }
}

/// The width-capped probe problem the micro-benchmark runs: same
/// `(C, K, S, d)` blocking behaviour, bounded cost. The batch is capped
/// but never below the worker count — the kernels parallelise across the
/// batch, so a probe with fewer rows than workers would measure a
/// different parallelism regime than the one the cache key promises.
/// Under [`Partition::Grid`] the width cap is raised until the probe's
/// `n·ceil(Q/WIDTH_BLOCK)` grid has at least one cell per worker, for
/// the same reason: a worker-starved grid probe (threads beyond the cell
/// count idle) would memoize a ranking the production shape — hundreds
/// of width blocks — never exhibits.
fn probe_params(p: &ConvParams, threads: usize, partition: Partition) -> ConvParams {
    const MAX_PROBE_Q: usize = 512;
    let n = p.n.min(threads.max(2));
    let q_cap = match partition {
        Partition::Batch => MAX_PROBE_Q,
        // n·ceil(q/WB) ≥ threads  ⇐  q ≥ ceil(threads/n)·WB.
        Partition::Grid => MAX_PROBE_Q.max(threads.max(1).div_ceil(n.max(1)) * WIDTH_BLOCK),
    };
    let q = p.q().min(q_cap).max(1);
    // Reconstruct a width giving exactly q output columns at p's stride.
    let w = (q - 1) * p.stride + (p.s - 1) * p.d + 1;
    let probe = ConvParams { n, w, ..*p };
    debug_assert_eq!(probe.q(), q);
    probe
}

/// The process-wide autotuner every production caller shares.
pub fn autotuner() -> &'static Autotuner {
    static GLOBAL: OnceLock<Autotuner> = OnceLock::new();
    GLOBAL.get_or_init(Autotuner::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probe_caps_width_but_keeps_blocking_dims() {
        let p = ConvParams::new(8, 15, 15, 60_000, 51, 8).unwrap();
        let probe = probe_params(&p, 1, Partition::Batch);
        assert_eq!(probe.q(), 512);
        assert_eq!((probe.c, probe.k, probe.s, probe.d), (15, 15, 51, 8));
        assert_eq!(probe.n, 2);
        // The probe batch never drops below the worker count (up to N),
        // so the measurement runs the same parallelism regime the cache
        // key promises.
        assert_eq!(probe_params(&p, 4, Partition::Batch).n, 4);
        assert_eq!(probe_params(&p, 64, Partition::Batch).n, 8);
        // Small problems are probed as-is.
        let small = ConvParams::new(1, 3, 3, 100, 5, 2).unwrap();
        assert_eq!(probe_params(&small, 1, Partition::Batch), small);
    }

    #[test]
    fn grid_probe_keeps_every_worker_busy() {
        // Under Partition::Grid the probe grid must have ≥ 1 cell per
        // worker, or the measurement runs worker-starved relative to the
        // production shape the cache key promises.
        let p = ConvParams::new(1, 15, 15, 60_000, 51, 8).unwrap();
        for threads in [8usize, 32, 64, 128] {
            let probe = probe_params(&p, threads, Partition::Grid);
            let cells = probe.n * probe.q().div_ceil(WIDTH_BLOCK);
            assert!(
                cells >= threads,
                "threads={threads}: only {cells} probe grid cells"
            );
        }
        // The batch probe is unchanged by the grid floor.
        assert_eq!(probe_params(&p, 64, Partition::Batch).q(), 512);
        // A problem narrower than the floor is never inflated past its
        // own width.
        let small = ConvParams::new(1, 3, 3, 100, 5, 2).unwrap();
        assert_eq!(probe_params(&small, 64, Partition::Grid), small);
    }

    #[test]
    fn key_distinguishes_every_dimension() {
        let p = ConvParams::new(1, 3, 4, 100, 5, 2).unwrap();
        let base = Autotuner::key(&p, 1, Precision::F32, Partition::Batch);
        let variants = [
            Autotuner::key(
                &ConvParams::new(2, 3, 4, 100, 5, 2).unwrap(),
                1,
                Precision::F32,
                Partition::Batch,
            ),
            Autotuner::key(&p.with_stride(2).unwrap(), 1, Precision::F32, Partition::Batch),
            Autotuner::key(&p, 4, Precision::F32, Partition::Batch),
            Autotuner::key(&p, 1, Precision::Bf16, Partition::Batch),
            Autotuner::key(&p, 1, Precision::F32, Partition::Grid),
        ];
        for v in &variants {
            assert_ne!(&base, v);
        }
        // The key is ISA- and partition-aware: entries recorded under one
        // ISA or partition are never served under another (the key simply
        // differs).
        let isa = crate::conv1d::simd::active().isa().name();
        assert!(
            base.contains(&format!("i{isa}")),
            "key '{base}' must carry the active ISA '{isa}'"
        );
        assert!(base.ends_with("ptbatch"), "key '{base}' must carry the partition");
    }

    #[test]
    fn bf16_precision_short_circuits() {
        let t = Autotuner::new();
        let p = ConvParams::new(1, 4, 4, 200, 5, 2).unwrap();
        let k = t.choose(&p, 1, Precision::Bf16, Partition::Batch);
        assert_eq!(k.name(), "bf16");
        assert_eq!(t.measurement_count(), 0);
    }

    #[test]
    fn i8_precision_short_circuits() {
        let t = Autotuner::new();
        let p = ConvParams::new(1, 4, 4, 200, 5, 2).unwrap();
        let k = t.choose(&p, 1, Precision::I8, Partition::Batch);
        assert_eq!(k.name(), "i8");
        assert_eq!(t.measurement_count(), 0);
    }

    #[test]
    fn load_skips_unknown_precision_tags_and_mismatched_kernels() {
        let t = Autotuner::new();
        let p = ConvParams::new(1, 4, 4, 200, 5, 2).unwrap();
        let good = Autotuner::key(&p, 1, Precision::F32, Partition::Batch);
        let quant = Autotuner::key(&p, 1, Precision::I8, Partition::Batch);
        // A cache written by a *newer* build, keyed under a precision tag
        // this build has never heard of.
        let future = good.replace("pf32i", "pfp4i");
        // A corrupted/hand-edited entry: f32-keyed but naming a bf16
        // kernel — serving it would silently change the output dtype.
        let mismatched = Autotuner::key(&p, 2, Precision::F32, Partition::Batch);
        let src = format!(
            "{{\"version\": 1, \"entries\": {{\n  \
             \"{good}\": {{\"kernel\": \"brgemm\", \"micros\": 1.0}},\n  \
             \"{quant}\": {{\"kernel\": \"i8\", \"micros\": 1.0}},\n  \
             \"{future}\": {{\"kernel\": \"brgemm\", \"micros\": 1.0}},\n  \
             \"{mismatched}\": {{\"kernel\": \"bf16\", \"micros\": 1.0}}\n}}}}"
        );
        assert_eq!(t.load_json(&src), Ok(2));
        let e = t.entry(&p, 1, Precision::F32, Partition::Batch).unwrap();
        assert_eq!(e.kernel, "brgemm");
        let e = t.entry(&p, 1, Precision::I8, Partition::Batch).unwrap();
        assert_eq!(e.kernel, "i8");
        assert!(t.entry(&p, 2, Precision::F32, Partition::Batch).is_none());
    }
}
