//! Backward-weight pass — paper Algorithm 4 (small GEMMs).
//!
//! For every width block and tap:
//!
//! ```text
//! Grad_w[s, :, :] += GEMM( In[:, pos+s·d : pos+s·d+64],        # (C, 64)
//!                          transpose(Grad_out[:, pos : pos+64]) )  # (64, K)
//! ```
//!
//! The accumulator lives in the paper's `(S, C, K)` layout and is converted
//! back to the framework's `(K, C, S)` at the end. The paper notes this
//! kernel is the least efficient of the three: the input blocks stream
//! through cache once and the accumulator is shared across the batch
//! dimension — which is why the batch reduction here is serial per
//! accumulator, with sharded per-worker accumulators merged at the end
//! when threading is requested.
//!
//! Work sharding follows the [`ExecCtx`] partition: **batch** shards
//! whole images across workers (the paper's Sec. 3.3 strategy);
//! **grid** shards `(image, width-block)` cells, so an N=1 long-sequence
//! backward-weight still uses every core. Either way each worker owns a
//! private `(S, C, K)` accumulator and the merge is a fixed-order sum, so
//! results are deterministic for a given `(threads, partition)`.

use super::gemm::gemm_f32_bt;
use super::layout::sck_to_kcs_into;
use super::params::{ConvParams, WIDTH_BLOCK};
use super::threading::{grid_cell, grid_runs, ExecCtx, Partition};

/// Accumulate one `(pos, nb)` width block of one batch element into
/// `gw_sck` (layout `(S, C, K)`, **not** zeroed) — the unit of work of
/// both partitionings.
#[inline]
fn backward_weight_block(
    p: &ConvParams,
    gout: &[f32],
    x: &[f32],
    gw_sck: &mut [f32],
    pos: usize,
    nb: usize,
) {
    let (c, k, s, d, w, q) = (p.c, p.k, p.s, p.d, p.w, p.q());
    for is in 0..s {
        // A = In panel (C × nb) at column pos + s·d, row stride W.
        // B (transposed access) = Grad_out panel (K × nb), row stride Q.
        gemm_f32_bt(
            &x[pos + is * d..],
            w,
            &gout[pos..],
            q,
            &mut gw_sck[is * c * k..(is + 1) * c * k],
            k,
            c,
            k,
            nb,
        );
    }
}

/// Accumulate the weight gradient of one batch element into `gw_sck`
/// (layout `(S, C, K)`, **not** zeroed by this function).
pub fn backward_weight_single(p: &ConvParams, gout: &[f32], x: &[f32], gw_sck: &mut [f32]) {
    let (c, k, s, w, q) = (p.c, p.k, p.s, p.w, p.q());
    debug_assert_eq!(gout.len(), k * q);
    debug_assert_eq!(x.len(), c * w);
    debug_assert_eq!(gw_sck.len(), s * c * k);
    let mut pos = 0;
    while pos < q {
        let nb = WIDTH_BLOCK.min(q - pos);
        backward_weight_block(p, gout, x, gw_sck, pos, nb);
        pos += nb;
    }
}

/// Effective worker count of one backward-weight call under a partition.
fn effective_workers(p: &ConvParams, threads: usize, partition: Partition) -> usize {
    let items = match partition {
        Partition::Batch => p.n,
        Partition::Grid => p.n * p.q_blocks(),
    };
    threads.max(1).min(items.max(1))
}

/// Batched backward-weight with caller-owned scratch — the plan
/// executor's entry point. `gw_kcs` receives the gradient in the
/// framework's `(K, C, S)` layout; `partials` must hold one `S·C·K`
/// accumulator per effective worker. With `ctx.threads <= 1` the call
/// performs zero heap allocations.
///
/// With more threads the work items (images, or `(image, width-block)`
/// cells under [`Partition::Grid`]) are sharded over per-worker
/// accumulators which are summed afterwards in worker order — the
/// deterministic equivalent of the paper's shared-weight-tensor
/// multithreading caveat (Sec. 3.3).
pub fn backward_weight_with_scratch(
    p: &ConvParams,
    gout: &[f32],
    x: &[f32],
    gw_kcs: &mut [f32],
    ctx: ExecCtx,
    partials: &mut [f32],
) {
    let (n, c, k, s, w, q) = (p.n, p.c, p.k, p.s, p.w, p.q());
    assert_eq!(gout.len(), n * k * q, "grad-out shape mismatch for {p}");
    assert_eq!(x.len(), n * c * w, "input shape mismatch for {p}");
    assert_eq!(gw_kcs.len(), k * c * s, "grad-weight shape mismatch for {p}");
    let t = effective_workers(p, ctx.threads, ctx.partition);
    let scl = s * c * k;
    assert!(partials.len() >= t * scl, "partials buffer too small");
    let partials = &mut partials[..t * scl];
    partials.fill(0.0);
    if t == 1 {
        for i in 0..n {
            backward_weight_single(
                p,
                &gout[i * k * q..(i + 1) * k * q],
                &x[i * c * w..(i + 1) * c * w],
                partials,
            );
        }
    } else {
        match ctx.partition {
            Partition::Batch => std::thread::scope(|scope| {
                for (tid, acc) in partials.chunks_mut(scl).enumerate() {
                    scope.spawn(move || {
                        let mut i = tid;
                        while i < n {
                            backward_weight_single(
                                p,
                                &gout[i * k * q..(i + 1) * k * q],
                                &x[i * c * w..(i + 1) * c * w],
                                acc,
                            );
                            i += t;
                        }
                    });
                }
            }),
            Partition::Grid => {
                // Contiguous runs of the N × ceil(Q/64) grid (the same
                // split as `par_grid_chunks_scratch`, via the shared
                // `grid_runs`/`grid_cell` helpers), one private
                // accumulator per worker.
                let qb = p.q_blocks();
                std::thread::scope(|scope| {
                    for ((start, count), acc) in
                        grid_runs(n * qb, t).zip(partials.chunks_mut(scl))
                    {
                        scope.spawn(move || {
                            for g in start..start + count {
                                let (i, pos, nb) = grid_cell(g, qb, q, WIDTH_BLOCK);
                                backward_weight_block(
                                    p,
                                    &gout[i * k * q..(i + 1) * k * q],
                                    &x[i * c * w..(i + 1) * c * w],
                                    acc,
                                    pos,
                                    nb,
                                );
                            }
                        });
                    }
                });
            }
        }
        // Tree-free deterministic merge (t is small).
        let (total, rest) = partials.split_at_mut(scl);
        for part in rest.chunks(scl) {
            for (a, b) in total.iter_mut().zip(part) {
                *a += b;
            }
        }
    }
    sck_to_kcs_into(&partials[..scl], s, c, k, gw_kcs);
}

/// Batched backward-weight pass. Returns the gradient in the framework's
/// `(K, C, S)` layout (allocating wrapper around
/// [`backward_weight_with_scratch`]).
pub fn backward_weight(p: &ConvParams, gout: &[f32], x: &[f32], threads: usize) -> Vec<f32> {
    let (c, k, s) = (p.c, p.k, p.s);
    let t = threads.max(1).min(p.n.max(1));
    let mut partials = vec![0.0f32; t * s * c * k];
    let mut gw = vec![0.0f32; k * c * s];
    backward_weight_with_scratch(
        p,
        gout,
        x,
        &mut gw,
        ExecCtx::with_threads(threads),
        &mut partials,
    );
    gw
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv1d::direct::backward_weight_direct;
    use crate::conv1d::test_util::rnd;

    fn check(p: ConvParams) {
        let gout = rnd(p.n * p.k * p.q(), 100);
        let x = rnd(p.n * p.c * p.w, 200);
        let got = backward_weight(&p, &gout, &x, 1);
        let want = backward_weight_direct(&p, &gout, &x);
        for (i, (g, w_)) in got.iter().zip(&want).enumerate() {
            assert!(
                (g - w_).abs() < 2e-3 * (1.0 + w_.abs()),
                "{p} idx {i}: {g} vs {w_}"
            );
        }
    }

    #[test]
    fn matches_direct_paper_shapes() {
        for &(n, c, k, q, s, d) in &[
            (2, 15, 15, 128, 51, 8),
            (1, 64, 64, 200, 5, 1),
            (2, 32, 32, 130, 9, 4),
            (1, 1, 1, 64, 1, 1),
            (1, 4, 8, 100, 15, 2),
            (3, 10, 16, 77, 21, 1),
        ] {
            check(ConvParams::new(n, c, k, q + (s - 1) * d, s, d).unwrap());
        }
    }

    #[test]
    fn batch_additivity() {
        // grad_w(batch) == Σ grad_w(sample) — Algorithm 4 is a reduction.
        let p = ConvParams::new(3, 4, 5, 120, 7, 2).unwrap();
        let gout = rnd(p.n * p.k * p.q(), 1);
        let x = rnd(p.n * p.c * p.w, 2);
        let full = backward_weight(&p, &gout, &x, 1);
        let single = ConvParams { n: 1, ..p };
        let mut acc = vec![0.0; p.k * p.c * p.s];
        for i in 0..p.n {
            let gi = backward_weight(
                &single,
                &gout[i * p.k * p.q()..(i + 1) * p.k * p.q()],
                &x[i * p.c * p.w..(i + 1) * p.c * p.w],
                1,
            );
            for (a, b) in acc.iter_mut().zip(&gi) {
                *a += b;
            }
        }
        for (a, b) in full.iter().zip(&acc) {
            assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()));
        }
    }

    #[test]
    fn threaded_matches_serial() {
        let p = ConvParams::new(6, 5, 4, 200, 9, 3).unwrap();
        let gout = rnd(p.n * p.k * p.q(), 3);
        let x = rnd(p.n * p.c * p.w, 4);
        let serial = backward_weight(&p, &gout, &x, 1);
        let par = backward_weight(&p, &gout, &x, 3);
        for (a, b) in serial.iter().zip(&par) {
            assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()));
        }
    }

    #[test]
    fn grid_partition_matches_serial() {
        // Grid-sharded accumulators (incl. the N=1 fan-out that batch
        // sharding cannot parallelise) agree with the serial reduction up
        // to fp reassociation.
        for &(n, threads) in &[(1usize, 8usize), (4, 3)] {
            let p = ConvParams::new(n, 5, 4, 400, 9, 3).unwrap();
            let gout = rnd(p.n * p.k * p.q(), 5);
            let x = rnd(p.n * p.c * p.w, 6);
            let serial = backward_weight(&p, &gout, &x, 1);
            let t = effective_workers(&p, threads, Partition::Grid);
            let mut partials = vec![0.0f32; t * p.s * p.c * p.k];
            let mut gw = vec![0.0f32; p.k * p.c * p.s];
            backward_weight_with_scratch(
                &p,
                &gout,
                &x,
                &mut gw,
                ExecCtx::new(threads, Partition::Grid),
                &mut partials,
            );
            for (a, b) in serial.iter().zip(&gw) {
                assert!(
                    (a - b).abs() < 1e-4 * (1.0 + b.abs()),
                    "N={n} threads={threads}: {a} vs {b}"
                );
            }
            // And the grid run is deterministic: a second pass is
            // bit-identical.
            let mut gw2 = vec![0.0f32; p.k * p.c * p.s];
            backward_weight_with_scratch(
                &p,
                &gout,
                &x,
                &mut gw2,
                ExecCtx::new(threads, Partition::Grid),
                &mut partials,
            );
            assert_eq!(gw, gw2);
        }
    }
}
