//! Backward-weight pass — paper Algorithm 4 (small GEMMs).
//!
//! For every width block and tap:
//!
//! ```text
//! Grad_w[s, :, :] += GEMM( In[:, pos+s·d : pos+s·d+64],        # (C, 64)
//!                          transpose(Grad_out[:, pos : pos+64]) )  # (64, K)
//! ```
//!
//! The accumulator lives in the paper's `(S, C, K)` layout and is converted
//! back to the framework's `(K, C, S)` at the end. The paper notes this
//! kernel is the least efficient of the three: the input blocks stream
//! through cache once and the accumulator is shared across the batch
//! dimension — which is why the batch reduction here is serial per
//! accumulator, with optional sharded accumulators merged at the end when
//! threading is requested.

use super::gemm::gemm_f32_bt;
use super::layout::sck_to_kcs_into;
use super::params::{ConvParams, WIDTH_BLOCK};

/// Accumulate the weight gradient of one batch element into `gw_sck`
/// (layout `(S, C, K)`, **not** zeroed by this function).
pub fn backward_weight_single(p: &ConvParams, gout: &[f32], x: &[f32], gw_sck: &mut [f32]) {
    let (c, k, s, d, w, q) = (p.c, p.k, p.s, p.d, p.w, p.q());
    debug_assert_eq!(gout.len(), k * q);
    debug_assert_eq!(x.len(), c * w);
    debug_assert_eq!(gw_sck.len(), s * c * k);
    let mut pos = 0;
    while pos < q {
        let nb = WIDTH_BLOCK.min(q - pos);
        for is in 0..s {
            // A = In panel (C × nb) at column pos + s·d, row stride W.
            // B (transposed access) = Grad_out panel (K × nb), row stride Q.
            gemm_f32_bt(
                &x[pos + is * d..],
                w,
                &gout[pos..],
                q,
                &mut gw_sck[is * c * k..(is + 1) * c * k],
                k,
                c,
                k,
                nb,
            );
        }
        pos += nb;
    }
}

/// Batched backward-weight with caller-owned scratch — the plan
/// executor's entry point. `gw_kcs` receives the gradient in the
/// framework's `(K, C, S)` layout; `partials` must hold
/// `min(threads, N)·S·C·K` elements of per-worker accumulator space.
/// With `threads <= 1` the call performs zero heap allocations.
///
/// With `threads > 1` the batch is sharded over per-worker accumulators
/// which are summed afterwards — the deterministic equivalent of the
/// paper's shared-weight-tensor multithreading caveat (Sec. 3.3).
pub fn backward_weight_with_scratch(
    p: &ConvParams,
    gout: &[f32],
    x: &[f32],
    gw_kcs: &mut [f32],
    threads: usize,
    partials: &mut [f32],
) {
    let (n, c, k, s, w, q) = (p.n, p.c, p.k, p.s, p.w, p.q());
    assert_eq!(gout.len(), n * k * q, "grad-out shape mismatch for {p}");
    assert_eq!(x.len(), n * c * w, "input shape mismatch for {p}");
    assert_eq!(gw_kcs.len(), k * c * s, "grad-weight shape mismatch for {p}");
    let t = threads.max(1).min(n.max(1));
    let scl = s * c * k;
    assert!(partials.len() >= t * scl, "partials buffer too small");
    let partials = &mut partials[..t * scl];
    partials.fill(0.0);
    if t == 1 {
        for i in 0..n {
            backward_weight_single(
                p,
                &gout[i * k * q..(i + 1) * k * q],
                &x[i * c * w..(i + 1) * c * w],
                partials,
            );
        }
    } else {
        std::thread::scope(|scope| {
            for (tid, acc) in partials.chunks_mut(scl).enumerate() {
                scope.spawn(move || {
                    let mut i = tid;
                    while i < n {
                        backward_weight_single(
                            p,
                            &gout[i * k * q..(i + 1) * k * q],
                            &x[i * c * w..(i + 1) * c * w],
                            acc,
                        );
                        i += t;
                    }
                });
            }
        });
        // Tree-free deterministic merge (t is small).
        let (total, rest) = partials.split_at_mut(scl);
        for part in rest.chunks(scl) {
            for (a, b) in total.iter_mut().zip(part) {
                *a += b;
            }
        }
    }
    sck_to_kcs_into(&partials[..scl], s, c, k, gw_kcs);
}

/// Batched backward-weight pass. Returns the gradient in the framework's
/// `(K, C, S)` layout (allocating wrapper around
/// [`backward_weight_with_scratch`]).
pub fn backward_weight(p: &ConvParams, gout: &[f32], x: &[f32], threads: usize) -> Vec<f32> {
    let (c, k, s) = (p.c, p.k, p.s);
    let t = threads.max(1).min(p.n.max(1));
    let mut partials = vec![0.0f32; t * s * c * k];
    let mut gw = vec![0.0f32; k * c * s];
    backward_weight_with_scratch(p, gout, x, &mut gw, threads, &mut partials);
    gw
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv1d::direct::backward_weight_direct;
    use crate::conv1d::test_util::rnd;

    fn check(p: ConvParams) {
        let gout = rnd(p.n * p.k * p.q(), 100);
        let x = rnd(p.n * p.c * p.w, 200);
        let got = backward_weight(&p, &gout, &x, 1);
        let want = backward_weight_direct(&p, &gout, &x);
        for (i, (g, w_)) in got.iter().zip(&want).enumerate() {
            assert!(
                (g - w_).abs() < 2e-3 * (1.0 + w_.abs()),
                "{p} idx {i}: {g} vs {w_}"
            );
        }
    }

    #[test]
    fn matches_direct_paper_shapes() {
        for &(n, c, k, q, s, d) in &[
            (2, 15, 15, 128, 51, 8),
            (1, 64, 64, 200, 5, 1),
            (2, 32, 32, 130, 9, 4),
            (1, 1, 1, 64, 1, 1),
            (1, 4, 8, 100, 15, 2),
            (3, 10, 16, 77, 21, 1),
        ] {
            check(ConvParams::new(n, c, k, q + (s - 1) * d, s, d).unwrap());
        }
    }

    #[test]
    fn batch_additivity() {
        // grad_w(batch) == Σ grad_w(sample) — Algorithm 4 is a reduction.
        let p = ConvParams::new(3, 4, 5, 120, 7, 2).unwrap();
        let gout = rnd(p.n * p.k * p.q(), 1);
        let x = rnd(p.n * p.c * p.w, 2);
        let full = backward_weight(&p, &gout, &x, 1);
        let single = ConvParams { n: 1, ..p };
        let mut acc = vec![0.0; p.k * p.c * p.s];
        for i in 0..p.n {
            let gi = backward_weight(
                &single,
                &gout[i * p.k * p.q()..(i + 1) * p.k * p.q()],
                &x[i * p.c * p.w..(i + 1) * p.c * p.w],
                1,
            );
            for (a, b) in acc.iter_mut().zip(&gi) {
                *a += b;
            }
        }
        for (a, b) in full.iter().zip(&acc) {
            assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()));
        }
    }

    #[test]
    fn threaded_matches_serial() {
        let p = ConvParams::new(6, 5, 4, 200, 9, 3).unwrap();
        let gout = rnd(p.n * p.k * p.q(), 3);
        let x = rnd(p.n * p.c * p.w, 4);
        let serial = backward_weight(&p, &gout, &x, 1);
        let par = backward_weight(&p, &gout, &x, 3);
        for (a, b) in serial.iter().zip(&par) {
            assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()));
        }
    }
}
