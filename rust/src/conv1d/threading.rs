//! Work partitioning across scoped OS threads — each "thread" plays the
//! role of one CPU core of the paper's 28-core socket.
//!
//! Two strategies (selected by [`Partition`]):
//!
//! * **Batch** (paper Sec. 2: "multithreading across the batch dimension
//!   (N)") — the output tensor is split into disjoint per-sample rows;
//!   rows are split into contiguous near-equal blocks (±1 row), so ragged
//!   batches stay balanced and each worker owns a private scratch window.
//! * **Grid** — the 2D `N × ceil(Q/64)` (batch × width-block) grid is
//!   split into contiguous near-equal runs of width blocks, so a *single*
//!   long-sequence image (the N ≤ 4 genomics serving shapes) still
//!   saturates a socket. Every `(image, width-block)` cell is computed by
//!   exactly one worker with the same inputs as the serial order, so
//!   results are **bit-identical** to the batch partitioning. Workers
//!   sharing an image never hold aliasing `&mut` row slices: all output
//!   goes through a [`GridStripe`] handle that materialises only the
//!   owning cell's disjoint per-line column stripes.
//!
//! With `threads == 1` no thread is spawned (the single-core fast path
//! used by the benchmarks on this host) and the loops perform zero heap
//! allocations.

use super::simd::{self, MicroKernelSet};
use crate::dist::Placement;

/// Work-partitioning strategy for the batched conv kernels.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Partition {
    /// Split the batch dimension `N` (the paper's strategy). Best when
    /// `N ≥ threads`.
    #[default]
    Batch,
    /// Split the 2D `N × ceil(Q/64)` width-block grid. Parallelises
    /// *inside* each image — the serving regime (`N < threads`, long Q).
    Grid,
}

impl Partition {
    /// Every strategy, in preference order.
    pub const ALL: [Partition; 2] = [Partition::Batch, Partition::Grid];

    /// Canonical name (`batch` / `grid`) — config/CLI vocabulary.
    pub fn as_str(&self) -> &'static str {
        match self {
            Partition::Batch => "batch",
            Partition::Grid => "grid",
        }
    }
}

impl std::fmt::Display for Partition {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for Partition {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "batch" | "n" => Ok(Partition::Batch),
            "grid" | "2d" => Ok(Partition::Grid),
            other => Err(format!("unknown partition '{other}' (batch|grid)")),
        }
    }
}

/// Execution context of one batched kernel call: worker count, work
/// partitioning strategy, and the resolved SIMD micro-kernel set. Built
/// once per [`crate::conv1d::ConvPlan`] and threaded through every hot
/// path, so the ISA decision is never re-made inside a kernel.
#[derive(Debug, Clone, Copy)]
pub struct ExecCtx {
    /// Scoped worker threads (1 = serial, zero-allocation fast path).
    pub threads: usize,
    /// Batch vs 2D-grid work splitting.
    pub partition: Partition,
    /// Resolved micro-kernel dispatch table (ISA).
    pub uks: &'static MicroKernelSet,
    /// Thread→socket layout (flat unless a NUMA-aware caller placed the
    /// workers). Carried next to `threads` so placement-aware consumers
    /// (socket-sharded pools, the hierarchical all-reduce) see the same
    /// shape the kernels were planned for.
    pub placement: Placement,
}

impl ExecCtx {
    /// Serial context with the process-active ISA.
    pub fn serial() -> ExecCtx {
        Self::with_threads(1)
    }

    /// Batch-partitioned context with the process-active ISA.
    pub fn with_threads(threads: usize) -> ExecCtx {
        Self::new(threads, Partition::Batch)
    }

    /// Context with the process-active ISA.
    pub fn new(threads: usize, partition: Partition) -> ExecCtx {
        ExecCtx {
            threads,
            partition,
            uks: simd::active(),
            placement: Placement::flat(threads.max(1)),
        }
    }

    /// Builder: pin a specific micro-kernel set (per-ISA benches/tests).
    pub fn with_uks(mut self, uks: &'static MicroKernelSet) -> ExecCtx {
        self.uks = uks;
        self
    }

    /// Builder: pin a thread→socket layout (NUMA-aware callers).
    pub fn with_placement(mut self, placement: Placement) -> ExecCtx {
        self.placement = placement;
        self
    }
}

/// Apply `f(batch_index, chunk)` to every `chunk_len`-sized row of `out`,
/// distributing rows across `threads` scoped threads. Thin scratch-free
/// wrapper over [`par_batch_chunks_scratch`].
///
/// `f` must be `Sync` (it is shared by reference) and is called exactly
/// once per batch element, in-order within a worker.
pub fn par_batch_chunks<F>(out: &mut [f32], chunk_len: usize, threads: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    let mut s1: [usize; 0] = [];
    let mut s2: [usize; 0] = [];
    par_batch_chunks_scratch(
        out,
        chunk_len,
        &mut s1[..],
        0,
        &mut s2[..],
        0,
        threads,
        |i, row, _, _| f(i, row),
    );
}

/// bf16 variant of [`par_batch_chunks`].
pub fn par_batch_chunks_bf16<F>(
    out: &mut [super::bf16::Bf16],
    chunk_len: usize,
    threads: usize,
    f: F,
) where
    F: Fn(usize, &mut [super::bf16::Bf16]) + Sync,
{
    let mut s1: [usize; 0] = [];
    let mut s2: [usize; 0] = [];
    par_batch_chunks_scratch(
        out,
        chunk_len,
        &mut s1[..],
        0,
        &mut s2[..],
        0,
        threads,
        |i, row, _, _| f(i, row),
    );
}

/// Scratch-aware batch partitioning — the zero-allocation substrate of the
/// plan executor ([`crate::conv1d::plan`]).
///
/// Splits `out` into `chunk_len`-sized rows and hands every worker a
/// *private* scratch window carved out of the caller-owned `s1`/`s2`
/// buffers (`s1_len`/`s2_len` elements each), so nothing is allocated per
/// row. With `threads <= 1` no thread is spawned and the loop itself
/// performs **zero** heap allocations; with more threads the rows are
/// split into contiguous near-equal blocks (`f` still sees global row
/// indices, so results are bit-identical to the serial order).
///
/// Requirements: `s1.len() >= t·s1_len` and `s2.len() >= t·s2_len` for the
/// effective worker count `t = min(threads, rows)`. A scratch length of 0
/// passes an empty slice.
#[allow(clippy::too_many_arguments)]
pub fn par_batch_chunks_scratch<O, T1, T2, F>(
    out: &mut [O],
    chunk_len: usize,
    s1: &mut [T1],
    s1_len: usize,
    s2: &mut [T2],
    s2_len: usize,
    threads: usize,
    f: F,
) where
    O: Send,
    T1: Send,
    T2: Send,
    F: Fn(usize, &mut [O], &mut [T1], &mut [T2]) + Sync,
{
    assert!(chunk_len > 0, "chunk_len must be positive");
    assert_eq!(out.len() % chunk_len, 0, "output not divisible into rows");
    let n = out.len() / chunk_len;
    let t = threads.max(1).min(n.max(1));
    if t <= 1 {
        for (i, row) in out.chunks_mut(chunk_len).enumerate() {
            f(i, row, &mut s1[..s1_len], &mut s2[..s2_len]);
        }
        return;
    }
    assert!(
        s1.len() >= t * s1_len && s2.len() >= t * s2_len,
        "scratch buffers too small for {t} workers"
    );
    let base = n / t;
    let rem = n % t;
    std::thread::scope(|scope| {
        let mut out_rest = &mut *out;
        let mut s1_rest = &mut *s1;
        let mut s2_rest = &mut *s2;
        let mut row0 = 0usize;
        for tid in 0..t {
            let rows = base + usize::from(tid < rem);
            let (o_chunk, o_rest) =
                std::mem::take(&mut out_rest).split_at_mut(rows * chunk_len);
            out_rest = o_rest;
            let (c1, r1) = std::mem::take(&mut s1_rest).split_at_mut(s1_len);
            s1_rest = r1;
            let (c2, r2) = std::mem::take(&mut s2_rest).split_at_mut(s2_len);
            s2_rest = r2;
            let start = row0;
            row0 += rows;
            let f = &f;
            scope.spawn(move || {
                for (j, row) in o_chunk.chunks_mut(chunk_len).enumerate() {
                    f(start + j, row, &mut c1[..], &mut c2[..]);
                }
            });
        }
    });
}

/// Contiguous near-equal runs of `total` grid cells across `workers`
/// workers: yields `(start, count)` per worker, in worker order. The
/// single source of truth for the grid work split — shared by
/// [`par_grid_chunks_scratch`] and the backward-weight grid sharding so
/// the two can never diverge.
pub fn grid_runs(total: usize, workers: usize) -> impl Iterator<Item = (usize, usize)> {
    let w = workers.max(1);
    let per = total / w;
    let rem = total % w;
    (0..w).scan(0usize, move |g0, tid| {
        let count = per + usize::from(tid < rem);
        let start = *g0;
        *g0 += count;
        Some((start, count))
    })
}

/// Decode global grid cell `g` (row-major over `qb = ceil(q/wb)` blocks
/// per image) into `(image, pos, nb)`.
#[inline]
pub fn grid_cell(g: usize, qb: usize, q: usize, wb: usize) -> (usize, usize, usize) {
    let (i, blk) = (g / qb, g % qb);
    let pos = blk * wb;
    (i, pos, wb.min(q - pos))
}

/// Raw base pointer a grid worker derives its stripe writes from.
/// Disjointness is structural: each `(image, width-block)` cell is owned
/// by exactly one worker, and [`GridStripe`] only ever materialises
/// references inside the owning worker's cell.
struct SendPtr<O>(*mut O);
// Manual impls: the pointer is Copy for any O (a derive would demand
// `O: Copy`), and sharing it across scoped workers is exactly the point.
impl<O> Clone for SendPtr<O> {
    fn clone(&self) -> Self {
        SendPtr(self.0)
    }
}
impl<O> Copy for SendPtr<O> {}
unsafe impl<O: Send> Send for SendPtr<O> {}
unsafe impl<O: Send> Sync for SendPtr<O> {}

/// Write handle for one `(image, width-block)` grid cell: exposes exactly
/// the `nb`-column stripe starting at column `pos` of each `q`-column
/// line of the owning image's row — and nothing else. Grid workers store
/// their results through this handle, so a safe closure physically
/// cannot touch a neighbouring worker's columns, and no two live `&mut`
/// slices ever overlap anywhere in the grid machinery: the only `&mut`
/// materialised over the shared output are the per-line stripe slices of
/// [`GridStripe::line_mut`], which are disjoint across workers by cell
/// ownership and serialised within a worker by `&mut self`.
pub struct GridStripe<'a, O> {
    /// Base of the owning image's `lines · q` row.
    base: *mut O,
    q: usize,
    lines: usize,
    pos: usize,
    nb: usize,
    _row: std::marker::PhantomData<&'a mut [O]>,
}

impl<'a, O> GridStripe<'a, O> {
    /// # Safety
    ///
    /// `base` must point to a live `lines·q`-element row valid for writes
    /// for `'a`, `pos + nb <= q` must hold, and the `(pos, nb)` column
    /// stripe of that row must be owned exclusively by this handle: no
    /// other reference or handle may access those elements while it (or
    /// any slice it hands out) is live.
    unsafe fn new(base: *mut O, q: usize, lines: usize, pos: usize, nb: usize) -> Self {
        debug_assert!(pos + nb <= q, "stripe [{pos}, {pos}+{nb}) exceeds line width {q}");
        GridStripe {
            base,
            q,
            lines,
            pos,
            nb,
            _row: std::marker::PhantomData,
        }
    }

    /// First column of the stripe.
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// Stripe width in columns.
    pub fn nb(&self) -> usize {
        self.nb
    }

    /// Number of `q`-column lines in the image row (`chunk_len / q`).
    pub fn lines(&self) -> usize {
        self.lines
    }

    /// The stripe of line `line`: the image row's
    /// `[line·q + pos, line·q + pos + nb)` window. At most one line slice
    /// is live at a time (`&mut self`), and distinct workers' slices are
    /// disjoint by construction, so this never creates aliasing `&mut`.
    pub fn line_mut(&mut self, line: usize) -> &mut [O] {
        assert!(
            line < self.lines,
            "grid stripe line {line} out of range ({} lines)",
            self.lines
        );
        // SAFETY: in-bounds by the assert plus the construction invariant
        // `pos + nb <= q`; exclusive by the construction contract (the
        // stripe belongs to this handle alone) and by `&mut self` (one
        // live slice per handle at a time).
        unsafe { std::slice::from_raw_parts_mut(self.base.add(line * self.q + self.pos), self.nb) }
    }

    /// Store a staged contiguous `lines × nb` block (`ldc = nb`) into the
    /// stripe: line `l` of `block` goes to the image row's
    /// `[l·q + pos, l·q + pos + nb)` window. The single store path of the
    /// grid kernels, so the stride geometry lives next to
    /// [`GridStripe::line_mut`]'s exclusivity reasoning instead of being
    /// repeated per kernel.
    pub fn store_block(&mut self, block: &[O])
    where
        O: Copy,
    {
        assert_eq!(
            block.len(),
            self.lines * self.nb,
            "staged block shape mismatch ({} lines × {} cols)",
            self.lines,
            self.nb
        );
        for line in 0..self.lines {
            self.line_mut(line)
                .copy_from_slice(&block[line * self.nb..(line + 1) * self.nb]);
        }
    }
}

/// 2D (batch × width-block) work partitioning — the grid substrate of
/// [`Partition::Grid`].
///
/// `out` is `rows × chunk_len` with `q` grid columns per row
/// (`chunk_len % q == 0`, e.g. `chunk_len = K·Q`); the global grid of
/// `rows · ceil(q / wb)` width blocks is split into contiguous near-equal
/// runs, one per worker. `f(i, pos, nb, stripe, s1, s2)` is called
/// exactly once per `(image i, block [pos, pos+nb))` cell with the
/// worker's private scratch windows; all output goes through the
/// [`GridStripe`] handle, which exposes only that cell's columns — the
/// API is sound for any safe closure (out-of-stripe writes are
/// impossible, not merely forbidden by contract).
///
/// With `threads <= 1` no thread is spawned, blocks run in `(i, pos)`
/// order and the loop performs zero heap allocations; the parallel runs
/// compute every cell with identical inputs, so results are bit-identical
/// to the serial order regardless of `threads`.
#[allow(clippy::too_many_arguments)]
pub fn par_grid_chunks_scratch<O, T1, T2, F>(
    out: &mut [O],
    chunk_len: usize,
    q: usize,
    wb: usize,
    s1: &mut [T1],
    s1_len: usize,
    s2: &mut [T2],
    s2_len: usize,
    threads: usize,
    f: F,
) where
    O: Send,
    T1: Send,
    T2: Send,
    F: Fn(usize, usize, usize, &mut GridStripe<'_, O>, &mut [T1], &mut [T2]) + Sync,
{
    assert!(chunk_len > 0, "chunk_len must be positive");
    assert!(q > 0 && wb > 0, "grid geometry must be positive");
    assert_eq!(out.len() % chunk_len, 0, "output not divisible into rows");
    assert_eq!(
        chunk_len % q,
        0,
        "rows must be whole multiples of the grid width q"
    );
    let n = out.len() / chunk_len;
    let lines = chunk_len / q;
    let qb = q.div_ceil(wb);
    let total = n * qb;
    let t = threads.max(1).min(total.max(1));
    if t <= 1 {
        for (i, row) in out.chunks_mut(chunk_len).enumerate() {
            let base = row.as_mut_ptr();
            let mut pos = 0;
            while pos < q {
                let nb = wb.min(q - pos);
                // SAFETY: `row` is exclusively borrowed and untouched
                // while the stripe lives, so the handle is the only
                // access path to its columns.
                let mut stripe = unsafe { GridStripe::new(base, q, lines, pos, nb) };
                f(i, pos, nb, &mut stripe, &mut s1[..s1_len], &mut s2[..s2_len]);
                pos += nb;
            }
        }
        return;
    }
    assert!(
        s1.len() >= t * s1_len && s2.len() >= t * s2_len,
        "scratch buffers too small for {t} workers"
    );
    let base = SendPtr(out.as_mut_ptr());
    std::thread::scope(|scope| {
        let mut s1_rest = &mut *s1;
        let mut s2_rest = &mut *s2;
        for (start, count) in grid_runs(total, t) {
            let (c1, r1) = std::mem::take(&mut s1_rest).split_at_mut(s1_len);
            s1_rest = r1;
            let (c2, r2) = std::mem::take(&mut s2_rest).split_at_mut(s2_len);
            s2_rest = r2;
            let f = &f;
            scope.spawn(move || {
                for g in start..start + count {
                    let (i, pos, nb) = grid_cell(g, qb, q, wb);
                    // SAFETY: `base` is derived from the caller's
                    // exclusive `&mut out` borrow, which outlives the
                    // scope and is not otherwise used inside it, so its
                    // provenance covers the whole output. `grid_runs`
                    // partitions `0..total`, so each (i, blk) cell — and
                    // hence each (pos, nb) column stripe of each image —
                    // belongs to exactly one worker: the handle's
                    // exclusivity contract holds, and the only `&mut`
                    // ever materialised (the per-line stripe slices of
                    // `line_mut`) are pairwise disjoint across the whole
                    // scope.
                    let mut stripe = unsafe {
                        GridStripe::new(base.0.add(i * chunk_len), q, lines, pos, nb)
                    };
                    f(i, pos, nb, &mut stripe, &mut c1[..], &mut c2[..]);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn visits_every_row_once() {
        let mut out = vec![0.0f32; 7 * 3];
        let count = AtomicUsize::new(0);
        par_batch_chunks(&mut out, 3, 4, |i, chunk| {
            count.fetch_add(1, Ordering::SeqCst);
            chunk.fill(i as f32 + 1.0);
        });
        assert_eq!(count.load(Ordering::SeqCst), 7);
        for i in 0..7 {
            assert!(out[i * 3..(i + 1) * 3].iter().all(|&v| v == i as f32 + 1.0));
        }
    }

    #[test]
    fn single_thread_path() {
        let mut out = vec![0.0f32; 4];
        par_batch_chunks(&mut out, 2, 1, |i, chunk| chunk.fill(i as f32));
        assert_eq!(out, vec![0.0, 0.0, 1.0, 1.0]);
    }

    #[test]
    fn more_threads_than_rows() {
        let mut out = vec![0.0f32; 2];
        par_batch_chunks(&mut out, 1, 16, |i, chunk| chunk.fill(i as f32 + 5.0));
        assert_eq!(out, vec![5.0, 6.0]);
    }

    #[test]
    fn partition_parses_and_displays() {
        for p in Partition::ALL {
            assert_eq!(p.as_str().parse::<Partition>().unwrap(), p);
            assert_eq!(p.to_string(), p.as_str());
        }
        assert_eq!("2d".parse::<Partition>().unwrap(), Partition::Grid);
        assert!("diagonal".parse::<Partition>().is_err());
    }

    #[test]
    fn grid_visits_every_cell_once() {
        // 3 images × q=10, wb=4 → blocks at pos 0 (4 wide), 4 (4), 8 (2);
        // chunk_len = 2·q (two lines per image, like K=2).
        let (n, q, wb, chunk) = (3usize, 10usize, 4usize, 20usize);
        let count = AtomicUsize::new(0);
        let mut out = vec![0.0f32; n * chunk];
        let mut s1: [usize; 0] = [];
        let mut s2: [usize; 0] = [];
        par_grid_chunks_scratch(
            &mut out,
            chunk,
            q,
            wb,
            &mut s1[..],
            0,
            &mut s2[..],
            0,
            4,
            |i, pos, nb, stripe, _, _| {
                count.fetch_add(1, Ordering::SeqCst);
                assert_eq!((stripe.pos(), stripe.nb(), stripe.lines()), (pos, nb, chunk / q));
                for line in 0..stripe.lines() {
                    for (off, v) in stripe.line_mut(line).iter_mut().enumerate() {
                        *v = (i * 100 + pos + off) as f32;
                    }
                }
            },
        );
        assert_eq!(count.load(Ordering::SeqCst), n * q.div_ceil(wb));
        for i in 0..n {
            for line in 0..2 {
                for j in 0..q {
                    assert_eq!(out[i * chunk + line * q + j], (i * 100 + j) as f32);
                }
            }
        }
    }

    #[test]
    fn grid_parallel_matches_serial_bit_exact() {
        // Each cell writes a value derived from (i, pos) plus staged
        // scratch; every thread count must agree exactly.
        let (n, q, wb, chunk, slen) = (2usize, 23usize, 8usize, 23usize, 2usize);
        let run = |threads: usize| {
            let mut out = vec![0.0f32; n * chunk];
            let mut s1 = vec![0usize; threads.max(1) * slen];
            let mut s2: [f32; 0] = [];
            par_grid_chunks_scratch(
                &mut out,
                chunk,
                q,
                wb,
                &mut s1[..],
                slen,
                &mut s2[..],
                0,
                threads,
                |i, pos, _nb, stripe, scr, _| {
                    assert_eq!(scr.len(), slen);
                    scr[0] = i + 1;
                    scr[1] = pos + 1;
                    for v in stripe.line_mut(0) {
                        *v = (scr[0] * 1000 + scr[1]) as f32;
                    }
                },
            );
            out
        };
        let serial = run(1);
        for threads in [2, 3, 5, 16] {
            assert_eq!(run(threads), serial, "threads={threads}");
        }
    }

    #[test]
    fn grid_single_image_uses_many_workers() {
        // N=1 must still fan out: count the distinct scratch windows that
        // actually got touched (one per worker).
        let (q, wb) = (64usize * 6, 64usize);
        let mut out = vec![0.0f32; q];
        let threads = 3;
        let mut s1 = vec![0usize; threads];
        let mut s2: [f32; 0] = [];
        par_grid_chunks_scratch(
            &mut out,
            q,
            q,
            wb,
            &mut s1[..],
            1,
            &mut s2[..],
            0,
            threads,
            |_i, _pos, _nb, stripe, scr, _| {
                scr[0] += 1;
                stripe.line_mut(0).fill(1.0);
            },
        );
        assert!(out.iter().all(|&v| v == 1.0));
        let touched = s1.iter().filter(|&&c| c > 0).count();
        assert_eq!(touched, threads, "all workers must receive grid cells");
    }

    #[test]
    fn stripe_handle_is_bounded() {
        // The write handle hands out exactly nb-wide line stripes and
        // rejects out-of-range lines — a safe closure cannot reach a
        // neighbouring worker's columns.
        let (q, wb, lines) = (10usize, 4usize, 2usize);
        let mut out = vec![0.0f32; lines * q];
        let mut s1: [usize; 0] = [];
        let mut s2: [usize; 0] = [];
        par_grid_chunks_scratch(
            &mut out,
            lines * q,
            q,
            wb,
            &mut s1[..],
            0,
            &mut s2[..],
            0,
            1,
            |_i, _pos, nb, stripe, _, _| {
                for line in 0..stripe.lines() {
                    assert_eq!(stripe.line_mut(line).len(), nb);
                }
                let lines = stripe.lines();
                assert!(std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    stripe.line_mut(lines);
                }))
                .is_err());
            },
        );
    }

    #[test]
    fn scratch_variant_matches_serial() {
        // Each row records its index plus a value staged through scratch;
        // serial and threaded runs must agree exactly.
        let (n, len, slen) = (9usize, 4usize, 3usize);
        let run = |threads: usize| {
            let mut out = vec![0.0f32; n * len];
            let mut s1 = vec![0usize; threads.max(1) * slen];
            let mut s2 = vec![0.0f32; 0];
            par_batch_chunks_scratch(
                &mut out[..],
                len,
                &mut s1[..],
                slen,
                &mut s2[..],
                0,
                threads,
                |i, row, scr, _| {
                    assert_eq!(scr.len(), slen);
                    scr.fill(i + 1);
                    row.fill(scr[0] as f32 * 10.0);
                },
            );
            out
        };
        assert_eq!(run(1), run(4));
        assert_eq!(run(1)[0..4], [10.0, 10.0, 10.0, 10.0]);
        assert_eq!(run(1)[32..36], [90.0, 90.0, 90.0, 90.0]);
    }
}
