//! Batch-dimension work partitioning (paper Sec. 2: "We employ
//! multithreading across the batch dimension (N) in the forward pass and
//! the backward pass kernels").
//!
//! The output tensor is split into disjoint per-sample rows handed to
//! scoped OS threads — each "thread" plays the role of one CPU core of the
//! paper's 28-core socket. Work is distributed round-robin so ragged
//! batches stay balanced. With `threads == 1` no thread is spawned (the
//! single-core fast path used by the benchmarks on this host).

/// Apply `f(batch_index, chunk)` to every `chunk_len`-sized row of `out`,
/// distributing rows across `threads` scoped threads.
///
/// `f` must be `Sync` (it is shared by reference) and is called exactly
/// once per batch element, in-order within a thread.
pub fn par_batch_chunks<F>(out: &mut [f32], chunk_len: usize, threads: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    assert!(chunk_len > 0, "chunk_len must be positive");
    assert_eq!(out.len() % chunk_len, 0, "output not divisible into rows");
    let n = out.len() / chunk_len;
    let t = threads.max(1).min(n.max(1));
    if t <= 1 {
        for (i, chunk) in out.chunks_mut(chunk_len).enumerate() {
            f(i, chunk);
        }
        return;
    }
    // Hand out rows round-robin: thread `tid` gets rows tid, tid+t, ...
    let rows: Vec<(usize, &mut [f32])> = out.chunks_mut(chunk_len).enumerate().collect();
    let mut buckets: Vec<Vec<(usize, &mut [f32])>> = (0..t).map(|_| Vec::new()).collect();
    for (i, row) in rows {
        buckets[i % t].push((i, row));
    }
    std::thread::scope(|scope| {
        for bucket in buckets {
            let f = &f;
            scope.spawn(move || {
                for (i, row) in bucket {
                    f(i, row);
                }
            });
        }
    });
}

/// Generic bf16 variant of [`par_batch_chunks`].
pub fn par_batch_chunks_bf16<F>(
    out: &mut [super::bf16::Bf16],
    chunk_len: usize,
    threads: usize,
    f: F,
) where
    F: Fn(usize, &mut [super::bf16::Bf16]) + Sync,
{
    assert!(chunk_len > 0, "chunk_len must be positive");
    assert_eq!(out.len() % chunk_len, 0, "output not divisible into rows");
    let n = out.len() / chunk_len;
    let t = threads.max(1).min(n.max(1));
    if t <= 1 {
        for (i, chunk) in out.chunks_mut(chunk_len).enumerate() {
            f(i, chunk);
        }
        return;
    }
    let rows: Vec<(usize, &mut [super::bf16::Bf16])> =
        out.chunks_mut(chunk_len).enumerate().collect();
    let mut buckets: Vec<Vec<(usize, &mut [super::bf16::Bf16])>> =
        (0..t).map(|_| Vec::new()).collect();
    for (i, row) in rows {
        buckets[i % t].push((i, row));
    }
    std::thread::scope(|scope| {
        for bucket in buckets {
            let f = &f;
            scope.spawn(move || {
                for (i, row) in bucket {
                    f(i, row);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn visits_every_row_once() {
        let mut out = vec![0.0f32; 7 * 3];
        let count = AtomicUsize::new(0);
        par_batch_chunks(&mut out, 3, 4, |i, chunk| {
            count.fetch_add(1, Ordering::SeqCst);
            chunk.fill(i as f32 + 1.0);
        });
        assert_eq!(count.load(Ordering::SeqCst), 7);
        for i in 0..7 {
            assert!(out[i * 3..(i + 1) * 3].iter().all(|&v| v == i as f32 + 1.0));
        }
    }

    #[test]
    fn single_thread_path() {
        let mut out = vec![0.0f32; 4];
        par_batch_chunks(&mut out, 2, 1, |i, chunk| chunk.fill(i as f32));
        assert_eq!(out, vec![0.0, 0.0, 1.0, 1.0]);
    }

    #[test]
    fn more_threads_than_rows() {
        let mut out = vec![0.0f32; 2];
        par_batch_chunks(&mut out, 1, 16, |i, chunk| chunk.fill(i as f32 + 5.0));
        assert_eq!(out, vec![5.0, 6.0]);
    }
}
