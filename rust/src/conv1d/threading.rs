//! Batch-dimension work partitioning (paper Sec. 2: "We employ
//! multithreading across the batch dimension (N) in the forward pass and
//! the backward pass kernels").
//!
//! The output tensor is split into disjoint per-sample rows handed to
//! scoped OS threads — each "thread" plays the role of one CPU core of the
//! paper's 28-core socket. Rows are split into contiguous near-equal
//! blocks (±1 row), so ragged batches stay balanced and each worker owns
//! a private scratch window. With `threads == 1` no thread is spawned
//! (the single-core fast path used by the benchmarks on this host) and
//! the loop performs zero heap allocations.

/// Apply `f(batch_index, chunk)` to every `chunk_len`-sized row of `out`,
/// distributing rows across `threads` scoped threads. Thin scratch-free
/// wrapper over [`par_batch_chunks_scratch`].
///
/// `f` must be `Sync` (it is shared by reference) and is called exactly
/// once per batch element, in-order within a worker.
pub fn par_batch_chunks<F>(out: &mut [f32], chunk_len: usize, threads: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    let mut s1: [usize; 0] = [];
    let mut s2: [usize; 0] = [];
    par_batch_chunks_scratch(
        out,
        chunk_len,
        &mut s1[..],
        0,
        &mut s2[..],
        0,
        threads,
        |i, row, _, _| f(i, row),
    );
}

/// bf16 variant of [`par_batch_chunks`].
pub fn par_batch_chunks_bf16<F>(
    out: &mut [super::bf16::Bf16],
    chunk_len: usize,
    threads: usize,
    f: F,
) where
    F: Fn(usize, &mut [super::bf16::Bf16]) + Sync,
{
    let mut s1: [usize; 0] = [];
    let mut s2: [usize; 0] = [];
    par_batch_chunks_scratch(
        out,
        chunk_len,
        &mut s1[..],
        0,
        &mut s2[..],
        0,
        threads,
        |i, row, _, _| f(i, row),
    );
}

/// Scratch-aware batch partitioning — the zero-allocation substrate of the
/// plan executor ([`crate::conv1d::plan`]).
///
/// Splits `out` into `chunk_len`-sized rows and hands every worker a
/// *private* scratch window carved out of the caller-owned `s1`/`s2`
/// buffers (`s1_len`/`s2_len` elements each), so nothing is allocated per
/// row. With `threads <= 1` no thread is spawned and the loop itself
/// performs **zero** heap allocations; with more threads the rows are
/// split into contiguous near-equal blocks (`f` still sees global row
/// indices, so results are bit-identical to the serial order).
///
/// Requirements: `s1.len() >= t·s1_len` and `s2.len() >= t·s2_len` for the
/// effective worker count `t = min(threads, rows)`. A scratch length of 0
/// passes an empty slice.
#[allow(clippy::too_many_arguments)]
pub fn par_batch_chunks_scratch<O, T1, T2, F>(
    out: &mut [O],
    chunk_len: usize,
    s1: &mut [T1],
    s1_len: usize,
    s2: &mut [T2],
    s2_len: usize,
    threads: usize,
    f: F,
) where
    O: Send,
    T1: Send,
    T2: Send,
    F: Fn(usize, &mut [O], &mut [T1], &mut [T2]) + Sync,
{
    assert!(chunk_len > 0, "chunk_len must be positive");
    assert_eq!(out.len() % chunk_len, 0, "output not divisible into rows");
    let n = out.len() / chunk_len;
    let t = threads.max(1).min(n.max(1));
    if t <= 1 {
        for (i, row) in out.chunks_mut(chunk_len).enumerate() {
            f(i, row, &mut s1[..s1_len], &mut s2[..s2_len]);
        }
        return;
    }
    assert!(
        s1.len() >= t * s1_len && s2.len() >= t * s2_len,
        "scratch buffers too small for {t} workers"
    );
    let base = n / t;
    let rem = n % t;
    std::thread::scope(|scope| {
        let mut out_rest = &mut *out;
        let mut s1_rest = &mut *s1;
        let mut s2_rest = &mut *s2;
        let mut row0 = 0usize;
        for tid in 0..t {
            let rows = base + usize::from(tid < rem);
            let (o_chunk, o_rest) =
                std::mem::take(&mut out_rest).split_at_mut(rows * chunk_len);
            out_rest = o_rest;
            let (c1, r1) = std::mem::take(&mut s1_rest).split_at_mut(s1_len);
            s1_rest = r1;
            let (c2, r2) = std::mem::take(&mut s2_rest).split_at_mut(s2_len);
            s2_rest = r2;
            let start = row0;
            row0 += rows;
            let f = &f;
            scope.spawn(move || {
                for (j, row) in o_chunk.chunks_mut(chunk_len).enumerate() {
                    f(start + j, row, &mut c1[..], &mut c2[..]);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn visits_every_row_once() {
        let mut out = vec![0.0f32; 7 * 3];
        let count = AtomicUsize::new(0);
        par_batch_chunks(&mut out, 3, 4, |i, chunk| {
            count.fetch_add(1, Ordering::SeqCst);
            chunk.fill(i as f32 + 1.0);
        });
        assert_eq!(count.load(Ordering::SeqCst), 7);
        for i in 0..7 {
            assert!(out[i * 3..(i + 1) * 3].iter().all(|&v| v == i as f32 + 1.0));
        }
    }

    #[test]
    fn single_thread_path() {
        let mut out = vec![0.0f32; 4];
        par_batch_chunks(&mut out, 2, 1, |i, chunk| chunk.fill(i as f32));
        assert_eq!(out, vec![0.0, 0.0, 1.0, 1.0]);
    }

    #[test]
    fn more_threads_than_rows() {
        let mut out = vec![0.0f32; 2];
        par_batch_chunks(&mut out, 1, 16, |i, chunk| chunk.fill(i as f32 + 5.0));
        assert_eq!(out, vec![5.0, 6.0]);
    }

    #[test]
    fn scratch_variant_matches_serial() {
        // Each row records its index plus a value staged through scratch;
        // serial and threaded runs must agree exactly.
        let (n, len, slen) = (9usize, 4usize, 3usize);
        let run = |threads: usize| {
            let mut out = vec![0.0f32; n * len];
            let mut s1 = vec![0usize; threads.max(1) * slen];
            let mut s2 = vec![0.0f32; 0];
            par_batch_chunks_scratch(
                &mut out[..],
                len,
                &mut s1[..],
                slen,
                &mut s2[..],
                0,
                threads,
                |i, row, scr, _| {
                    assert_eq!(scr.len(), slen);
                    scr.fill(i + 1);
                    row.fill(scr[0] as f32 * 10.0);
                },
            );
            out
        };
        assert_eq!(run(1), run(4));
        assert_eq!(run(1)[0..4], [10.0, 10.0, 10.0, 10.0]);
        assert_eq!(run(1)[32..36], [90.0, 90.0, 90.0, 90.0]);
    }
}
